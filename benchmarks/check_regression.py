"""Bench-regression gate: diff a fresh ``results/bench_summary.json``
against the committed ``results/bench_baseline.json``.

``PYTHONPATH=src python -m benchmarks.check_regression [--update-baseline]``

CI runs this right after ``benchmarks.run --smoke``, so the bench
trajectory is *gated*, not just uploaded: a silent perf regression in
the jitted round step (or a qualitative-claim flip) fails the push.

Metric classes and their failure rules (relative, per metric):

- ``pass`` booleans: a claim that held at the baseline may never flip
  to False (exact). This now includes the code-fast-path ordering
  claims (``kernels.code_fast_path.*.pass``): "a quantized round is
  at-or-under the fp32 round" is gated as a never-flip flag, not a
  noisy time ratio.
- ``*_speedup`` ratios: fail when fresh < baseline / ``--ratio-slack``
  (default 2.0). Checked before the time class so a speedup leaf keeps
  its direction even under a timing-ish path.
- ``us_per_call`` timings and ``pack_us``: fail when fresh >
  ``--fed-time-ratio`` x baseline (default 2.0). Every micro-bench now
  measures as a min over interleaved order-rotating reps (the fed_round
  protocol, shared via ``repro.profile.trace``), so the whole class
  carries the tightened bound the fed_round timings pioneered.
- remaining ``*_us`` leaves (``prefetch_us``): fail when fresh >
  ``--time-ratio`` x baseline (default 3.0 -- the prefetch number is a
  loop mean with a sleep-based simulated device step, inherently
  noisier than a min-of-reps, so it keeps the generous bound).
- ``final_loss`` per experiment: fail when fresh > (1 +
  ``--loss-rtol``) x baseline (default 0.5: catches divergence, not
  jitter).

Metrics present in the baseline but missing from the fresh run FAIL (a
silently dropped bench is a coverage regression); new metrics PASS
with a note suggesting ``--update-baseline``. The smoke flag must
match -- comparing a smoke run against a full-budget baseline would be
noise, not signal.
"""

from __future__ import annotations

import argparse
import json
import shutil
import sys

UPDATE_HINT = (
    "[bench-gate] intentional change? refresh with `python -m "
    "benchmarks.check_regression --update-baseline` and commit "
    "results/bench_baseline.json"
)


def flatten(tree: dict, prefix: str = "") -> dict:
    out = {}
    for k, v in tree.items():
        path = f"{prefix}.{k}" if prefix else str(k)
        if isinstance(v, dict):
            out.update(flatten(v, path))
        else:
            out[path] = v
    return out


def classify(path: str):
    """Metric class by path: how (and whether) to compare it.

    ``_speedup`` outranks the time class (a ratio's failure direction
    is inverted). All ``us_per_call`` leaves plus ``pack_us`` are
    min-over-interleaved-reps measurements and share the tightened
    ``fed_time`` bound; ``prefetch_us`` (a loop mean around a simulated
    device sleep) keeps the generous generic bound."""
    leaf = path.rsplit(".", 1)[-1]
    if leaf == "pass":
        return "bool"
    if leaf.endswith("_speedup"):
        return "speedup"
    if ".us_per_call." in path or leaf == "pack_us":
        return "fed_time"
    if leaf.endswith("_us"):
        return "time"
    if ".final_loss." in path:
        return "loss"
    return None


def compare(path: str, base, fresh, args):
    """-> (status, limit_text). status is "ok" or "FAIL"."""
    kind = classify(path)
    if kind == "bool":
        ok = bool(fresh) or not bool(base)
        return ("ok" if ok else "FAIL", "no true->false")
    if kind in ("time", "fed_time"):
        ratio = args.time_ratio if kind == "time" else args.fed_time_ratio
        limit = float(base) * ratio
        return ("ok" if float(fresh) <= limit else "FAIL", f"<= {limit:.1f}")
    if kind == "speedup":
        limit = float(base) / args.ratio_slack
        return ("ok" if float(fresh) >= limit else "FAIL", f">= {limit:.2f}")
    if kind == "loss":
        limit = float(base) * (1.0 + args.loss_rtol)
        return ("ok" if float(fresh) <= limit else "FAIL", f"<= {limit:.4f}")
    return ("ok", "info")


def run_gate(baseline: dict, summary: dict, args):
    """-> (table rows, failed). Pure so tests can drive it directly."""
    rows = []
    failed = False
    base_flat = flatten(baseline)
    fresh_flat = flatten(summary)
    if base_flat.get("smoke") != fresh_flat.get("smoke"):
        smoke = (base_flat.get("smoke"), fresh_flat.get("smoke"))
        rows.append(("smoke", smoke[0], smoke[1], "must match", "FAIL"))
        failed = True
    for path in sorted(set(base_flat) | set(fresh_flat)):
        if path == "smoke" or classify(path) is None:
            continue
        base = base_flat.get(path)
        fresh = fresh_flat.get(path)
        if base is None:
            note = "new metric: --update-baseline"
            rows.append((path, "-", fresh, note, "NOTE"))
            continue
        if fresh is None:
            rows.append((path, base, "-", "bench disappeared", "FAIL"))
            failed = True
            continue
        status, limit = compare(path, base, fresh, args)
        rows.append((path, base, fresh, limit, status))
        failed = failed or status == "FAIL"
    return rows, failed


def fmt_cell(v) -> str:
    return f"{v:>10.3f}" if isinstance(v, float) else f"{v!s:>10}"


def print_table(rows) -> None:
    w = max([len(r[0]) for r in rows] + [6])
    print(f"{'metric':<{w}}  {'baseline':>10}  {'fresh':>10}  limit  status")
    for path, base, fresh, limit, status in rows:
        cells = f"{fmt_cell(base)}  {fmt_cell(fresh)}  {limit:<28}"
        print(f"{path:<{w}}  {cells}  {status}")


def make_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--fresh", default="results/bench_summary.json")
    ap.add_argument("--baseline", default="results/bench_baseline.json")
    ap.add_argument("--time-ratio", type=float, default=3.0)
    ap.add_argument("--fed-time-ratio", type=float, default=2.0)
    ap.add_argument("--ratio-slack", type=float, default=2.0)
    ap.add_argument("--loss-rtol", type=float, default=0.5)
    ap.add_argument("--update-baseline", action="store_true")
    return ap


def main() -> int:
    args = make_parser().parse_args()
    if args.update_baseline:
        try:
            shutil.copyfile(args.fresh, args.baseline)
        except FileNotFoundError:
            print(f"[bench-gate] no fresh summary at {args.fresh}")
            print("[bench-gate] run `python -m benchmarks.run --smoke` first")
            return 1
        print(f"[bench-gate] baseline refreshed from {args.fresh}")
        return 0
    try:
        with open(args.fresh) as f:
            summary = json.load(f)
    except FileNotFoundError:
        print(f"[bench-gate] no fresh summary at {args.fresh}")
        print("[bench-gate] run `python -m benchmarks.run --smoke` first")
        return 1
    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        print(f"[bench-gate] no baseline at {args.baseline}")
        print("[bench-gate] seed one with --update-baseline and commit it")
        return 1
    rows, failed = run_gate(baseline, summary, args)
    print_table(rows)
    n_fail = sum(r[4] == "FAIL" for r in rows)
    verdict = "FAIL" if failed else "PASS"
    knobs = (
        f"time-ratio={args.time_ratio}, "
        f"fed-time-ratio={args.fed_time_ratio}, "
        f"loss-rtol={args.loss_rtol}"
    )
    print(f"[bench-gate] {verdict}: {n_fail}/{len(rows)} failing ({knobs})")
    if failed:
        print(UPDATE_HINT)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
