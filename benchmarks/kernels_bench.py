"""Micro-benchmarks of the Pallas kernel wrappers (interpret mode on
CPU — relative timings only; the jnp fallback is the CPU production
path) and the jnp blockwise implementations they target.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.kernels import ref
from repro.models.attention import blockwise_attention


def _time(fn, *args, n=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def bench_attention():
    rng = np.random.default_rng(0)
    B, S, H, Kv, D = 1, 1024, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Kv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Kv, D)), jnp.float32)
    blockwise = jax.jit(lambda q, k, v: blockwise_attention(q, k, v, causal=True, block_kv=256))
    naive = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    t_block = _time(blockwise, q, k, v)
    t_naive = _time(naive, q, k, v)
    print(csv_row("attention_blockwise_1k", t_block, f"naive_us={t_naive:.1f}"))
    return t_block, t_naive


def bench_rnnt_joint():
    """The paper-model hot-spot: fused (chunked) vs naive materialized joint."""
    rng = np.random.default_rng(1)
    B, T, U1, J, V = 4, 128, 24, 64, 512
    e = jnp.asarray(rng.normal(size=(B, T, J)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(B, U1, J)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(J, V)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.normal(size=(V,)) * 0.1, jnp.float32)
    lbl = jnp.asarray(rng.integers(0, V, (B, U1)), jnp.int32)

    from repro.kernels.ops import _joint_ref_chunked

    chunked = jax.jit(lambda *a: _joint_ref_chunked(*a))
    naive = jax.jit(lambda e, g, w, b, l: ref.rnnt_joint_ref(e, g, w, b, l))
    t_c = _time(chunked, e, g, w, b, lbl)
    t_n = _time(naive, e, g, w, b, lbl)
    # memory derived: naive materializes B*T*U1*V f32
    naive_bytes = B * T * U1 * V * 4
    chunk_bytes = B * T * 8 * V * 4
    print(csv_row("rnnt_joint_chunked", t_c,
                  f"naive_us={t_n:.1f};mem_ratio={naive_bytes/chunk_bytes:.0f}x"))
    return t_c, t_n


def _fed_round_setup():
    from repro.core import FederatedPlan, init_server_state
    from repro.launch.train import tiny_asr_setup
    from repro.data import FederatedSampler
    from repro.models import build_model

    cfg, corpus = tiny_asr_setup(0)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    s = FederatedSampler(corpus, 8, 4, seed=0)
    rb = s.next_round()
    batch = {"features": jnp.asarray(rb.features), "labels": jnp.asarray(rb.labels),
             "frame_len": jnp.asarray(rb.frame_len), "label_len": jnp.asarray(rb.label_len),
             "weight": jnp.asarray(rb.mask)}
    return bundle, params, batch


def _time_round(bundle, params, batch, plan, name, derived):
    from repro.core import init_server_state, make_round_step

    state = init_server_state(plan, params)
    step = jax.jit(make_round_step(bundle.loss_fn, plan, jax.random.PRNGKey(1)))
    state, _ = step(state, batch)          # compile
    t0 = time.perf_counter()
    for _ in range(3):
        state, m = step(state, batch)
    jax.block_until_ready(m["loss"])
    us = (time.perf_counter() - t0) / 3 * 1e6
    print(csv_row(name, us, derived))
    return us


def bench_fed_round():
    """Wall time of one jitted federated round at bench scale, plus the
    compressed/robust server-plane variants: the in-graph quantize->
    dequantize overhead vs the wire bytes it saves (bytes/round from
    the exact per-client accounting, clients=8)."""
    from repro.core import CompressionConfig, FederatedPlan, client_wire_bytes

    bundle, params, batch = _fed_round_setup()
    base = dict(clients_per_round=8, local_batch_size=4, client_lr=0.3)
    us = _time_round(bundle, params, batch, FederatedPlan(**base),
                     "fed_round_tiny_rnnt", "clients=8")
    times = {"fed_round_tiny_rnnt": us}
    for name, plan in [
        ("fed_round_tiny_rnnt_int8",
         FederatedPlan(**base, compression=CompressionConfig(kind="int8"))),
        # compression-only variants (weighted_mean) so the timings are
        # attributable to the quantize/sparsify plane alone
        ("fed_round_tiny_rnnt_top5",
         FederatedPlan(**base, compression=CompressionConfig(kind="topk",
                                                             topk_frac=0.05))),
        # packed-wire variants: materialize + round-trip the real
        # payload buffers (wire_pack kernels; bit-identical numerics)
        ("fed_round_tiny_rnnt_int8_packed",
         FederatedPlan(**base, compression=CompressionConfig(kind="int8",
                                                             packed=True))),
        ("fed_round_tiny_rnnt_int4_packed",
         FederatedPlan(**base, compression=CompressionConfig(kind="int4",
                                                             packed=True))),
        # EF21 error feedback: same wire bytes, per-client residual state
        ("fed_round_tiny_rnnt_top5_ef",
         FederatedPlan(**base, compression=CompressionConfig(
             kind="topk", topk_frac=0.05, error_feedback=True))),
    ]:
        up = 8 * client_wire_bytes(plan.compression, params)
        times[name] = _time_round(bundle, params, batch, plan, name,
                                  f"baseline_us={us:.1f};uplink_B_round={up}")
    return times


def main() -> dict:
    """Runs every micro-bench; returns {bench_name: us_per_call} so the
    harness can persist the timings for the CI regression gate."""
    times = {}
    times["attention_blockwise_1k"], _ = bench_attention()
    times["rnnt_joint_chunked"], _ = bench_rnnt_joint()
    times.update(bench_fed_round())
    return times


if __name__ == "__main__":
    main()
