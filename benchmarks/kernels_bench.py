"""Micro-benchmarks of the Pallas kernel wrappers (interpret mode on
CPU — relative timings only; the jnp fallback is the CPU production
path) and the jnp blockwise implementations they target.

Measurement protocol (all benches): interleaved order-rotating reps
with per-variant MIN, via ``benchmarks.common.interleaved_min_us``
(the fed_round protocol, shared through ``repro.profile.trace``).
Rep counts come from tuner knobs (``results/tuning.json``) unless the
``REPRO_BENCH_*_REPS`` environment pins them.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_reps, csv_row, interleaved_min_us
from repro.kernels import ref
from repro.models.attention import blockwise_attention


def bench_attention():
    rng = np.random.default_rng(0)
    B, S, H, Kv, D = 1, 1024, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Kv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Kv, D)), jnp.float32)
    blockwise = jax.jit(lambda q, k, v: blockwise_attention(q, k, v, causal=True, block_kv=256))
    naive = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    t = interleaved_min_us({"block": lambda: blockwise(q, k, v),
                            "naive": lambda: naive(q, k, v)},
                           reps=bench_reps("REPRO_BENCH_MICRO_REPS",
                                           "bench.micro_reps"))
    print(csv_row("attention_blockwise_1k", t["block"],
                  f"naive_us={t['naive']:.1f}"))
    return t["block"], t["naive"]


def bench_rnnt_joint():
    """The paper-model hot-spot: fused (chunked) vs naive materialized joint."""
    rng = np.random.default_rng(1)
    B, T, U1, J, V = 4, 128, 24, 64, 512
    e = jnp.asarray(rng.normal(size=(B, T, J)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(B, U1, J)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(J, V)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.normal(size=(V,)) * 0.1, jnp.float32)
    lbl = jnp.asarray(rng.integers(0, V, (B, U1)), jnp.int32)

    from repro.kernels.ops import _joint_ref_chunked

    chunked = jax.jit(lambda *a: _joint_ref_chunked(*a))
    naive = jax.jit(lambda e, g, w, b, l: ref.rnnt_joint_ref(e, g, w, b, l))
    t = interleaved_min_us({"chunked": lambda: chunked(e, g, w, b, lbl),
                            "naive": lambda: naive(e, g, w, b, lbl)},
                           reps=bench_reps("REPRO_BENCH_MICRO_REPS",
                                           "bench.micro_reps"))
    # memory derived: naive materializes B*T*U1*V f32
    naive_bytes = B * T * U1 * V * 4
    chunk_bytes = B * T * 8 * V * 4
    print(csv_row("rnnt_joint_chunked", t["chunked"],
                  f"naive_us={t['naive']:.1f};mem_ratio={naive_bytes/chunk_bytes:.0f}x"))
    return t["chunked"], t["naive"]


def bench_rnnt_joint_bwd():
    """The joint's *backward* at the same bench shapes: the U-chunked
    jnp rematerializing VJP (CPU production path, gated) vs the fused
    Pallas backward that recomputes the joint tile in VMEM
    (interpret mode here — relative number in the derived column)."""
    from repro.kernels.ops import _joint_ref_chunked
    from repro.kernels.rnnt_joint import rnnt_joint_bwd_fused, rnnt_joint_fused

    rng = np.random.default_rng(3)
    B, T, U1, J, V = 4, 128, 24, 64, 512
    e = jnp.asarray(rng.normal(size=(B, T, J)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(B, U1, J)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(J, V)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.normal(size=(V,)) * 0.1, jnp.float32)
    lbl = jnp.asarray(rng.integers(0, V, (B, U1)), jnp.int32)
    dbl = jnp.asarray(rng.normal(size=(B, T, U1)), jnp.float32)
    dlb = jnp.asarray(rng.normal(size=(B, T, U1)), jnp.float32)
    _, _, lse = rnnt_joint_fused(e, g, w, b, lbl, interpret=True,
                                 return_lse=True)
    jax.block_until_ready(lse)

    def chunked_bwd(e, g, w, b, dbl, dlb):
        _, vjp = jax.vjp(
            lambda e_, g_, w_, b_: _joint_ref_chunked(e_, g_, w_, b_, lbl),
            e, g, w, b)
        return vjp((dbl, dlb))

    chunked = jax.jit(chunked_bwd)
    pallas = jax.jit(lambda *a: rnnt_joint_bwd_fused(*a, interpret=True))
    t = interleaved_min_us(
        {"chunked": lambda: chunked(e, g, w, b, dbl, dlb),
         "pallas": lambda: pallas(e, g, w, b, lbl, lse, dbl, dlb)},
        reps=bench_reps("REPRO_BENCH_MICRO_REPS", "bench.micro_reps"))
    print(csv_row("rnnt_joint_bwd_chunked", t["chunked"],
                  f"pallas_us={t['pallas']:.1f};"
                  f"interp_ratio={t['pallas'] / max(t['chunked'], 1e-9):.2f}"))
    return t["chunked"], t["pallas"]


def bench_lstm_scan():
    """The per-client recurrent hot-spot: one grad step through an LSTM
    scan (S=32, B=8, H=128 — a kernel-eligible shape). The gated
    us_per_call is the lax.scan-over-fused-gates CPU production path;
    the full-scan Pallas kernel's custom-VJP grad runs in interpret
    mode and lands in the derived column as a relative number only."""
    from repro.kernels.lstm_gates import lstm_scan_fused_vjp
    from repro.models.lstm import lstm_gates

    rng = np.random.default_rng(2)
    S, B, H = 32, 8, 128
    xg = jnp.asarray(rng.normal(size=(S, B, 4 * H)) * 0.4, jnp.float32)
    w_hh = jnp.asarray(rng.normal(size=(H, 4 * H)) * 0.1, jnp.float32)
    h0 = jnp.zeros((B, H), jnp.float32)
    c0 = jnp.zeros((B, H), jnp.float32)

    def scan_loss(xg, w_hh):
        def step(carry, xg_t):
            h, c = carry
            h, c = lstm_gates(xg_t + h @ w_hh, c)
            return (h, c), h

        (h, c), ys = jax.lax.scan(step, (h0, c0), xg)
        return ys.sum() + h.sum() + c.sum()

    def kernel_loss(xg, w_hh):
        ys, hT, cT = lstm_scan_fused_vjp(xg, w_hh, h0, c0, interpret=True)
        return ys.sum() + hT.sum() + cT.sum()

    scan_grad = jax.jit(jax.grad(scan_loss, argnums=(0, 1)))
    kernel_grad = jax.jit(jax.grad(kernel_loss, argnums=(0, 1)))
    t = interleaved_min_us({"scan": lambda: scan_grad(xg, w_hh),
                            "kernel": lambda: kernel_grad(xg, w_hh)},
                           reps=bench_reps("REPRO_BENCH_MICRO_REPS",
                                           "bench.micro_reps"))
    print(csv_row("lstm_scan_grad", t["scan"],
                  f"kernel_us={t['kernel']:.1f};"
                  f"interp_ratio={t['kernel'] / max(t['scan'], 1e-9):.2f}"))
    return t["scan"], t["kernel"]


def _fed_round_setup():
    from repro.launch.train import tiny_asr_setup
    from repro.data import FederatedSampler
    from repro.models import build_model

    cfg, corpus = tiny_asr_setup(0)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    s = FederatedSampler(corpus, 8, 4, seed=0)
    rb = s.next_round()
    batch = {"features": jnp.asarray(rb.features), "labels": jnp.asarray(rb.labels),
             "frame_len": jnp.asarray(rb.frame_len), "label_len": jnp.asarray(rb.label_len),
             "weight": jnp.asarray(rb.mask)}
    return bundle, params, batch


def _round_variants(base):
    from repro.core import AsyncConfig, CompressionConfig, FederatedPlan

    variants = [
        ("fed_round_tiny_rnnt", FederatedPlan(**base)),
        # buffered-async engine: same client compute, plus the arrival
        # scan + staleness-discounted buffer flushes (B=5 of K=8, the
        # async_vs_sync sweep's configuration)
        ("fed_round_tiny_rnnt_async",
         FederatedPlan(**base, engine="async",
                       asynchrony=AsyncConfig(buffer_size=5,
                                              staleness_beta=0.5))),
        # compression-only variants (weighted_mean) so the timings are
        # attributable to the quantize/sparsify plane alone. int8/int4
        # take the code-domain fast path (shared-scale codes, int32
        # code-sum reduction, one server dequant).
        ("fed_round_tiny_rnnt_int8",
         FederatedPlan(**base, compression=CompressionConfig(kind="int8"))),
        # top5 is PINNED to the generic per-client dense plane (the
        # pre-code-path graph, via _FORCE_GENERIC_PLANE below) so the
        # metric keeps measuring what it always measured; _top5_code is
        # the same plan on the code-domain fast path (segment-bucketed
        # scatter-add of packed {values, idx} wires). Their adjacent
        # pairing is the topk_code_le_topk never-flip flag.
        ("fed_round_tiny_rnnt_top5",
         FederatedPlan(**base, compression=CompressionConfig(kind="topk",
                                                             topk_frac=0.05))),
        ("fed_round_tiny_rnnt_top5_code",
         FederatedPlan(**base, compression=CompressionConfig(kind="topk",
                                                             topk_frac=0.05))),
        # packed-wire variants: materialize + round-trip the real
        # payload buffers (wire_pack kernels; bit-identical numerics)
        ("fed_round_tiny_rnnt_int8_packed",
         FederatedPlan(**base, compression=CompressionConfig(kind="int8",
                                                             packed=True))),
        ("fed_round_tiny_rnnt_int4_packed",
         FederatedPlan(**base, compression=CompressionConfig(kind="int4",
                                                             packed=True))),
        # EF21 error feedback: same wire bytes, per-client residual state
        ("fed_round_tiny_rnnt_top5_ef",
         FederatedPlan(**base, compression=CompressionConfig(
             kind="topk", topk_frac=0.05, error_feedback=True))),
    ]
    # uniform triples: (name, plan, client_sharding). The sharded
    # variants run the SAME plans through the shard_map body on a
    # 1-device `clients` mesh — the pure dispatch/partitioner overhead
    # of the sharded lowering, gated by the sharded_le_fp32 flag.
    from repro.core.fedavg import ClientSharding
    from repro.launch.mesh import make_federated_mesh

    sh = ClientSharding(make_federated_mesh(1))
    return [(n, p, None) for n, p in variants] + [
        ("fed_round_tiny_rnnt_sharded", FederatedPlan(**base), sh),
        ("fed_round_tiny_rnnt_sharded_int8",
         FederatedPlan(**base, compression=CompressionConfig(kind="int8")), sh),
    ]


# Variants whose round step is traced with the code-domain fast path
# DISABLED (repro.core.fedavg._code_fast_path pinned False during the
# compile call): the pre-fast-path generic graph, kept as the slow side
# of the topk_code_le_topk pairing.
_FORCE_GENERIC_PLANE = frozenset({"fed_round_tiny_rnnt_top5"})


def bench_fed_round():
    """Wall time of one jitted federated round at bench scale, plus the
    compressed/robust server-plane variants (bytes/round from the exact
    per-client accounting, clients=8).

    Measurement protocol: every variant is compiled first, then timed
    over ``REPRO_BENCH_FED_REPS`` (default 5) *interleaved* cycles
    whose per-cycle order rotates. The per-variant MINIMUM is reported
    as us_per_call (the noise floor each graph can reach), and the
    fp32-vs-compressed ordering flags use *paired within-cycle ratios*:
    each cycle divides a variant's time by the fp32 time of the SAME
    cycle — temporally adjacent, so shared-runner load drift cancels —
    and the flag takes the median over cycles against a documented
    ``_NOISE_MARGIN``. Sequential per-variant loops (the pre-PR 5
    protocol) made this ordering a coin flip: cross-variant load drift
    dwarfs the sub-percent differential that is actually left now that
    the code fast path removed the compression plane's compute tax
    (the PR 4 baseline had int4_packed at 1.4x fp32).

    Returns (times, flags): flags are the never-flip bench-gate claims
    that a quantized round costs at-or-under the fp32 round (within
    the paired-measurement noise floor; the raw median ratios are
    printed in the derived column and persisted next to the flags).
    """
    import statistics

    from repro.core import client_wire_bytes, init_server_state, make_round_step

    bundle, params, batch = _fed_round_setup()
    base = dict(clients_per_round=8, local_batch_size=4, client_lr=0.3)
    variants = _round_variants(base)
    import repro.core.fedavg as _fedavg_mod

    steps, states = {}, {}
    for name, plan, sharding in variants:
        states[name] = init_server_state(plan, params)
        steps[name] = jax.jit(make_round_step(bundle.loss_fn, plan,
                                              jax.random.PRNGKey(1),
                                              client_sharding=sharding))
        # the code-fast-path branch is taken at TRACE time, so pinning
        # a variant to the generic plane only needs the patch while the
        # first (compiling) call traces; later calls replay the graph
        orig_fast = _fedavg_mod._code_fast_path
        if name in _FORCE_GENERIC_PLANE:
            _fedavg_mod._code_fast_path = lambda plane: False
        try:
            states[name], m = steps[name](states[name], batch)   # compile
            jax.block_until_ready(m["loss"])
        finally:
            _fedavg_mod._code_fast_path = orig_fast
    reps = bench_reps("REPRO_BENCH_FED_REPS", "bench.fed_reps")
    cycle_times = {name: [] for name, _, _ in variants}

    def step_once(name):
        t0 = time.perf_counter()
        states[name], m = steps[name](states[name], batch)
        jax.block_until_ready(m["loss"])
        us = (time.perf_counter() - t0) * 1e6
        cycle_times[name].append(us)
        return us

    for rep in range(reps):
        order = variants[rep % len(variants):] + variants[:rep % len(variants)]
        for name, _, _ in order:
            step_once(name)
    # The ordering flags: ADJACENT fp32<->variant pairs (back-to-back
    # steps, so host-steal drift has ~one round step to move instead of
    # a whole cycle), median of the pair ratios.
    flags = {}
    pair_reps = max(3, bench_reps("REPRO_BENCH_FED_PAIR_REPS",
                                  "bench.fed_pair_reps"))
    # sharded_le_fp32 is the never-flip floor on the shard_map lowering
    # itself: a 1-device `clients` mesh must stay within the noise
    # margin (<= 1.1x) of the plain vmap round — the sharded body adds
    # dispatch/partitioning, never a second copy of the compute.
    for tag, name in [("int8", "fed_round_tiny_rnnt_int8"),
                      ("int4_packed", "fed_round_tiny_rnnt_int4_packed"),
                      ("sharded", "fed_round_tiny_rnnt_sharded"),
                      ("sharded_int8", "fed_round_tiny_rnnt_sharded_int8")]:
        ratios = []
        for _ in range(pair_reps):
            f = step_once("fed_round_tiny_rnnt")
            v = step_once(name)
            ratios.append(v / f)
        r = statistics.median(ratios)
        flags[f"{tag}_le_fp32"] = {
            "pass": r <= 1.0 + _NOISE_MARGIN,
            "vs_fp32_ratio": round(r, 4),
        }
    # topk_code_le_topk: the code-domain top-k round (packed
    # {values, idx} wires + segment-bucketed scatter-add) must stay
    # at-or-under the generic dense top-k plane it replaced — adjacent
    # slow<->code pairs, same protocol as the fp32 flags but with the
    # pinned-generic top5 graph as the denominator.
    ratios = []
    for _ in range(pair_reps):
        s = step_once("fed_round_tiny_rnnt_top5")
        c = step_once("fed_round_tiny_rnnt_top5_code")
        ratios.append(c / s)
    r = statistics.median(ratios)
    flags["topk_code_le_topk"] = {
        "pass": r <= 1.0 + _NOISE_MARGIN,
        "vs_topk_ratio": round(r, 4),
    }
    times = {name: min(ts) for name, ts in cycle_times.items()}
    ratio = {name: flags[f"{tag}_le_fp32"]["vs_fp32_ratio"]
             for tag, name in [("int8", "fed_round_tiny_rnnt_int8"),
                               ("int4_packed", "fed_round_tiny_rnnt_int4_packed"),
                               ("sharded", "fed_round_tiny_rnnt_sharded"),
                               ("sharded_int8", "fed_round_tiny_rnnt_sharded_int8")]}
    for name, plan, sharding in variants:
        up = 8 * client_wire_bytes(plan.compression, params)
        if name == "fed_round_tiny_rnnt_top5_code":
            derived = (f"vs_topk_ratio="
                       f"{flags['topk_code_le_topk']['vs_topk_ratio']};"
                       f"uplink_B_round={up}")
        elif name in ratio:
            derived = f"vs_fp32_ratio={ratio[name]};uplink_B_round={up}"
        elif plan.compression.kind == "none":
            derived = "clients=8"
        else:
            derived = f"uplink_B_round={up}"
        print(csv_row(name, times[name], derived))
    return times, flags


# The discrimination floor of shared 2-core runners: the int8 and
# int8_packed fast paths compile to the SAME HLO (the static packed
# bit only changes which wrapper builds the graph) yet their median
# adjacent-pair ratios vs fp32 still land up to ~8% apart under host
# CPU steal — no estimator at this wall-time budget can certify a
# sub-percent ordering. The flag therefore gates the claim that
# actually regressed before PR 5 and is measurable: a quantized round
# costs AT MOST fp32 + this band (the PR 4 baseline had int4_packed at
# 1.40x fp32 — a regression back to a real compute tax trips this
# immediately), while the strict sub-1.0 orderings show up in quiet-
# window runs (persisted as vs_fp32_ratio next to each flag) and in
# the stable plane-only wire_plane_*_speedup metrics.
_NOISE_MARGIN = 0.10


def bench_wire_plane():
    """The compression plane in isolation at bench-model shapes: the
    slow path (per-client quantize->dequantize, K fp32 trees reduced by
    the aggregator) vs the code-domain fast path (shared-scale fused
    quantize(+pack), int32 code-sum, ONE dequant). The full-round bench
    above buries this differential under local training; here it is the
    whole measurement — timed as mins over interleaved slow/fast reps
    (same rationale as ``bench_fed_round``) so the ``*_speedup`` ratios
    are stable enough for the bench gate's speedup-floor class."""
    from repro.core.aggregation import get_aggregator
    from repro.core.compression import (
        CompressionConfig, code_domain_aggregate, make_compressor)

    rng = np.random.default_rng(7)
    K = 8
    tree = {f"l{i}": jnp.asarray(rng.normal(size=(K, 256, 91)), jnp.float32)
            for i in range(8)}
    n_k = jnp.full((K,), 16.0)
    pmask = jnp.ones((K,))
    key = jax.random.PRNGKey(0)
    ckeys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(K))
    wm = get_aggregator("weighted_mean")

    times, speedups = {}, {}
    for tag, cfg in [("int8", CompressionConfig(kind="int8")),
                     ("int4_packed", CompressionConfig(kind="int4",
                                                       packed=True))]:
        comp = make_compressor(cfg)
        slow = jax.jit(lambda tr, c=comp: wm(jax.vmap(c)(tr, ckeys),
                                             n_k, pmask, {}, key))
        fast = jax.jit(lambda tr, c=cfg: code_domain_aggregate(
            c, tr, n_k, pmask, ckeys))
        t = interleaved_min_us({"slow": lambda: slow(tree),
                                "fast": lambda: fast(tree)},
                               reps=bench_reps("REPRO_BENCH_WIRE_REPS",
                                               "bench.wire_reps"))
        t_slow, t_fast = t["slow"], t["fast"]
        speedup = t_slow / max(t_fast, 1e-9)
        times[f"wire_plane_{tag}"] = t_fast
        speedups[f"{tag}_speedup"] = round(speedup, 2)
        print(csv_row(f"wire_plane_{tag}", t_fast,
                      f"slow_us={t_slow:.1f};fast_speedup={speedup:.2f}"))
    return times, speedups


def main(trace_path: str = "results/trace_kernels.json") -> tuple[dict, dict]:
    """Runs every micro-bench; returns (times, extra): {bench_name:
    us_per_call} plus the extra gated sections — the never-flip
    code-fast-path pass flags and the wire-plane fast-vs-slow speedups
    — so the harness can persist all of it for the CI regression
    gate. Per-kernel timings also land in a profiling-plane trace
    (``trace_path``; empty string disables)."""
    times = {}
    times["attention_blockwise_1k"], _ = bench_attention()
    times["rnnt_joint_chunked"], _ = bench_rnnt_joint()
    times["rnnt_joint_bwd_chunked"], _ = bench_rnnt_joint_bwd()
    times["lstm_scan_grad"], _ = bench_lstm_scan()
    plane_times, plane_speedups = bench_wire_plane()
    times.update(plane_times)
    round_times, flags = bench_fed_round()
    times.update(round_times)
    if trace_path:
        from repro.profile.trace import write_trace

        write_trace(trace_path, "kernels", kernels=times,
                    meta={"wire_plane": plane_speedups})
        print(f"[trace] {trace_path}")
    return times, {"code_fast_path": flags, "wire_plane": plane_speedups}


if __name__ == "__main__":
    main()
