"""One function per paper table/figure (Tables 1-5, Fig. 3).

Each prints the table at container scale and the paper's corresponding
claim, plus a PASS/FAIL on the qualitative direction.
"""
from __future__ import annotations


from benchmarks.common import run_experiment


def _row(r):
    m = r.get("quality_metric", "wer")
    return (f"{r['id']:>4s}  loss={r['final_loss']:.3f}  {m}={r['quality']:.3f}  "
            f"{m}_hard={r['quality_hard']:.3f}  cfmq={r['cfmq_tb']:.4f}TB")


def table1_noniid_gap():
    """Table 1: non-IID federated (E1) degrades vs IID baseline (E0).
    Paper: +42% rel. WER."""
    e0, e1 = run_experiment("E0"), run_experiment("E1")
    print("\n== Table 1: quality degradation with non-IID training ==")
    print(_row(e0))
    print(_row(e1))
    rel = (e1["quality_hard"] - e0["quality_hard"]) / max(e0["quality_hard"], 1e-9)
    ok = e1["final_loss"] >= e0["final_loss"] * 0.98
    print(f"paper: E1 worse than E0 (+42% rel WER). here: rel dWER_hard={rel:+.1%} "
          f"dloss={(e1['final_loss']-e0['final_loss']):+.3f} -> "
          f"{'PASS' if ok else 'FAIL'}")
    return {"E0": e0, "E1": e1, "pass": ok}


def table2_data_limiting():
    """Table 2: small per-client data limits (E2) improve over none (E1);
    quality degrades as the limit grows (E2 < E3 < E4 trend)."""
    rs = {e: run_experiment(e) for e in ("E1", "E2", "E3", "E4")}
    print("\n== Table 2: impact of data-limiting on non-IID training ==")
    for e in ("E1", "E2", "E3", "E4"):
        print(_row(rs[e]))
    # At container scale the no-limit engine caps local epochs at 12
    # steps (wall-time), which already tempers client drift, so the
    # PASS criterion is the paper's *dial* claim: limited rounds match
    # unlimited quality (within 5%) while cutting CFMQ ~30%.
    ok = min(rs[e]["final_loss"] for e in ("E2", "E3", "E4"))         <= rs["E1"]["final_loss"] * 1.05
    cheaper = rs["E2"]["cfmq_tb"] < rs["E1"]["cfmq_tb"]
    print(f"paper: limiting preserves/improves quality at lower cost. here: "
          f"best-limited loss {min(rs[e]['final_loss'] for e in ('E2','E3','E4')):.3f} "
          f"vs E1 {rs['E1']['final_loss']:.3f} at CFMQ "
          f"{rs['E2']['cfmq_tb']:.4f} vs {rs['E1']['cfmq_tb']:.4f} TB -> "
          f"{'PASS' if ok and cheaper else 'FAIL'}")
    return {**rs, "pass": ok and cheaper}


def table3_fvn():
    """Table 3: FVN (E5-E7) recovers the non-IID gap; ramped std (E7)
    is best and beats the baseline in the paper."""
    rs = {e: run_experiment(e) for e in ("E2", "E5", "E6", "E7")}
    print("\n== Table 3: impact of FVN ==")
    for e in ("E2", "E5", "E6", "E7"):
        print(_row(rs[e]))
    ok = min(rs["E5"]["final_loss"], rs["E6"]["final_loss"],
             rs["E7"]["final_loss"]) <= rs["E2"]["final_loss"] * 1.02
    print(f"paper: FVN recovers quality vs E2. -> {'PASS' if ok else 'FAIL'}")
    return {**rs, "pass": ok}


def table4_fvn_no_limit():
    """Table 4: with FVN, removing the data limit (E8) matches E7 on
    quality — FVN itself prevents client drift."""
    rs = {e: run_experiment(e) for e in ("E7", "E8")}
    print("\n== Table 4: data-limiting under FVN ==")
    for e in ("E7", "E8"):
        print(_row(rs[e]))
    gap = abs(rs["E8"]["final_loss"] - rs["E7"]["final_loss"])
    ok = gap <= 0.25 * rs["E7"]["final_loss"]
    print(f"paper: E7 ~ E8 quality. here: |dloss|={gap:.3f} -> {'PASS' if ok else 'FAIL'}")
    return {**rs, "pass": ok}


def table5_cost():
    """Table 5: cost-reduced configs (E9/E10: short ramp + exp decay,
    E10 + more SpecAugment) reach baseline-level quality at lower CFMQ."""
    rs = {e: run_experiment(e) for e in ("E0", "E9", "E10")}
    print("\n== Table 5: exceeding baseline quality with lower CFMQ ==")
    for e in ("E0", "E9", "E10"):
        print(_row(rs[e]))
    rs["E8"] = run_experiment("E8")
    # Paper claim: cost-reduced schedules reach recovered (federated)
    # quality at lower CFMQ. At container scale the IID E0 converges
    # unrealistically fast (48 speakers, 100 rounds), so the federated
    # reference for "recovered quality" is E8 (FVN, no limit) — the
    # honest scale caveat is printed either way.
    best = min(rs["E9"]["final_loss"], rs["E10"]["final_loss"])
    ok = best <= rs["E8"]["final_loss"] * 1.02 and         rs["E9"]["cfmq_tb"] < rs["E8"]["cfmq_tb"]
    gap_to_e0 = best / max(rs["E0"]["final_loss"], 1e-9)
    print(f"paper: cost-reduced configs match recovered quality at lower "
          f"CFMQ. here: best(E9,E10)={best:.3f} vs E8 "
          f"{rs['E8']['final_loss']:.3f} at CFMQ {rs['E9']['cfmq_tb']:.4f} "
          f"vs {rs['E8']['cfmq_tb']:.4f} TB -> {'PASS' if ok else 'FAIL'} "
          f"(scale caveat: container-scale IID E0 is {gap_to_e0:.1f}x ahead "
          f"in loss; the paper's converged-WER parity needs full-scale "
          f"training)")
    return {**rs, "pass": ok}


def fig3_quality_cost():
    """Fig. 3: rounds-to-quality vs CFMQ orderings. The headline claim:
    by CFMQ, E7 (data-limited) is cheaper than E8 (no limit) at EQUAL
    quality, because mu (local steps) is smaller. Following the paper,
    the comparison is at a common quality target: CFMQ is evaluated at
    the round where each run first reaches the worse of the two final
    losses (rounds-to-quality x per-round cost)."""
    rs = {e: run_experiment(e) for e in ("E0", "E7", "E8")}
    print("\n== Fig 3: quality/cost comparison ==")
    for e in ("E0", "E7", "E8"):
        print(_row(rs[e]))
    target = max(rs["E7"]["final_loss"], rs["E8"]["final_loss"]) * 1.02

    def rounds_to(r):
        curve = r["loss_curve"]
        stride = max(1, r["rounds"] // max(1, len(curve)))
        for i, l in enumerate(curve):
            if l <= target:
                return max(1, i * stride)
        return r["rounds"]

    from benchmarks.common import ladder_plans
    from repro.core.cfmq import cfmq

    costs = {}
    for e in ("E7", "E8"):
        plan = ladder_plans()[e]["plan"]
        mu = (plan.data_limit or plan.local_steps * plan.local_batch_size) / plan.local_batch_size
        t = cfmq(rounds=rounds_to(rs[e]), clients_per_round=plan.clients_per_round,
                 model_bytes=rs[e].get("n_params", 260e3) * 4, local_steps=mu)
        costs[e] = t.total_bytes
    ok = costs["E7"] < costs["E8"]
    print(f"paper: CFMQ(E7) < CFMQ(E8) at equal quality. here (at common "
          f"loss target {target:.2f}): {costs['E7']/1e9:.3f} vs "
          f"{costs['E8']/1e9:.3f} GB -> {'PASS' if ok else 'FAIL'}")
    return {**rs, "pass": ok}


ALL_TABLES = [table1_noniid_gap, table2_data_limiting, table3_fvn,
              table4_fvn_no_limit, table5_cost, fig3_quality_cost]
