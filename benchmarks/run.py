"""Benchmark harness entry point — one function per paper table.

``PYTHONPATH=src python -m benchmarks.run [--smoke] [--tables t1,t3]``

Prints (a) name,us_per_call,derived CSV lines for the micro-benches and
(b) the paper's Tables 1-5 + Fig. 3 reproduced on the synthetic
speaker-split corpus with PASS/FAIL on each qualitative claim.
Set REPRO_BENCH_ROUNDS to control the round budget (default 150).

``--smoke`` is the CI mode: a tiny round budget and a tables subset
(<2 min) writing the same ``results/bench_summary.json`` schema.
"""
from __future__ import annotations

import argparse
import json
import os
import time

SMOKE_ROUNDS = "6"
SMOKE_TABLES = ["kernels", "data", "t1", "fig3"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tables", default=None,
                    help="comma list: t1,t2,t3,t4,t5,fig3,kernels,data or all")
    ap.add_argument("--smoke", action="store_true",
                    help="CI budget: tiny rounds + tables subset, same "
                         "summary schema")
    ap.add_argument("--out", default="results/bench_summary.json")
    args = ap.parse_args()

    if args.smoke:
        # must precede the benchmarks.common import: the round budget is
        # read at module import
        os.environ.setdefault("REPRO_BENCH_ROUNDS", SMOKE_ROUNDS)

    from benchmarks import data_bench, kernels_bench, tables

    if args.tables:
        want = args.tables.split(",")
    elif args.smoke:
        want = list(SMOKE_TABLES)
    else:
        want = ["kernels", "data", "t1", "t2", "t3", "t4", "t5", "fig3"]
    if want == ["all"]:
        want = ["kernels", "data", "t1", "t2", "t3", "t4", "t5", "fig3"]
    t0 = time.time()
    # the summary persists numbers, not just verdicts: us_per_call /
    # speedups / per-experiment losses feed benchmarks/check_regression
    # (the CI bench-regression gate against results/bench_baseline.json)
    summary = {"smoke": args.smoke}
    if "kernels" in want:
        print("== kernel micro-benches (name,us_per_call,derived) ==")
        times, extra = kernels_bench.main()
        summary["kernels"] = {
            "us_per_call": {k: round(v, 1) for k, v in times.items()},
            # never-flip claims (code-domain fast path keeps quantized
            # rounds at-or-under fp32) + stable plane-level speedups
            **extra}
    if "data" in want:
        print("== data-plane micro-benches (name,us_per_call,derived) ==")
        t_vec, _, speedup = data_bench.bench_packing()
        t_pref, _ = data_bench.bench_prefetch()
        # >=3x under the interleaved-min protocol: min-of-reps finds
        # the legacy loop's best case too, so the ratio runs ~1.5x
        # tighter than the old median-of-reps 5x bound measured.
        summary["data"] = {"pack_speedup": round(speedup, 2),
                           "pack_us": round(t_vec, 1),
                           "prefetch_us": round(t_pref, 1),
                           "pass": speedup >= 3.0}
    fns = {"t1": tables.table1_noniid_gap, "t2": tables.table2_data_limiting,
           "t3": tables.table3_fvn, "t4": tables.table4_fvn_no_limit,
           "t5": tables.table5_cost, "fig3": tables.fig3_quality_cost}
    passes = [summary["data"]["pass"]] if "data" in summary else []
    for k, fn in fns.items():
        if k in want:
            res = fn()
            entry = {"pass": res["pass"]}
            losses = {eid: round(vv["final_loss"], 4)
                      for eid, vv in res.items()
                      if isinstance(vv, dict) and "final_loss" in vv}
            if losses:
                entry["final_loss"] = losses
            summary[k] = entry
            passes.append(res["pass"])
    print(f"\n== summary: {sum(bool(p) for p in passes)}/{len(passes)} "
          f"qualitative claims reproduced; wall={time.time()-t0:.0f}s ==")
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(summary, f, indent=1)


if __name__ == "__main__":
    main()
