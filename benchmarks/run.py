"""Benchmark harness entry point — one function per paper table.

``PYTHONPATH=src python -m benchmarks.run [--rounds N] [--tables t1,t3]``

Prints (a) name,us_per_call,derived CSV lines for the micro-benches and
(b) the paper's Tables 1-5 + Fig. 3 reproduced on the synthetic
speaker-split corpus with PASS/FAIL on each qualitative claim.
Set REPRO_BENCH_ROUNDS to control the round budget (default 150).
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tables", default="all",
                    help="comma list: t1,t2,t3,t4,t5,fig3,kernels or all")
    ap.add_argument("--out", default="results/bench_summary.json")
    args = ap.parse_args()

    from benchmarks import kernels_bench, tables

    want = args.tables.split(",") if args.tables != "all" else \
        ["kernels", "t1", "t2", "t3", "t4", "t5", "fig3"]
    t0 = time.time()
    summary = {}
    if "kernels" in want:
        print("== kernel micro-benches (name,us_per_call,derived) ==")
        kernels_bench.main()
    fns = {"t1": tables.table1_noniid_gap, "t2": tables.table2_data_limiting,
           "t3": tables.table3_fvn, "t4": tables.table4_fvn_no_limit,
           "t5": tables.table5_cost, "fig3": tables.fig3_quality_cost}
    passes = []
    for k, fn in fns.items():
        if k in want:
            res = fn()
            summary[k] = {kk: vv for kk, vv in res.items() if kk == "pass"}
            passes.append(res["pass"])
    print(f"\n== summary: {sum(bool(p) for p in passes)}/{len(passes)} "
          f"qualitative claims reproduced; wall={time.time()-t0:.0f}s ==")
    import os
    os.makedirs("results", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(summary, f, indent=1)


if __name__ == "__main__":
    main()
