"""Host data-plane micro-benches: round packing + async prefetch.

``fed_pack_vectorized`` is the tentpole number: arena fancy-indexing
vs the legacy per-example Python loop at the bench round shape
(K=8, S=8, b=4 -> 256 examples/round), timed with the shared
interleaved order-rotating min protocol so container load drift
cancels out of the speedup ratio.
"""
from __future__ import annotations

import time

from benchmarks.common import bench_reps, csv_row, interleaved_min_us
from repro.data import FederatedSampler, PrefetchIterator, make_speaker_corpus, round_batches


def bench_packing(K: int = 8, S: int = 8, b: int = 4):
    """Vectorized vs legacy round packing (acceptance: >=3x as a
    min-over-interleaved-reps ratio; the old median protocol read
    ~5x because the legacy loop's median is far above its best rep)."""
    corpus = make_speaker_corpus(num_speakers=48, vocab_size=64, feat_dim=16,
                                 mean_utterances=40.0, seed=0)
    limit = S * b
    vec = FederatedSampler(corpus, K, b, data_limit=limit, seed=0)
    leg = FederatedSampler(corpus, K, b, data_limit=limit, seed=0, legacy=True)
    assert vec.steps == S, vec.steps
    t = interleaved_min_us({"vec": vec.next_round, "leg": leg.next_round},
                           reps=bench_reps("REPRO_BENCH_PACK_REPS",
                                           "bench.pack_reps"))
    t_vec, t_leg = t["vec"], t["leg"]
    speedup = t_leg / t_vec
    print(csv_row(f"fed_pack_vectorized_K{K}S{S}b{b}", t_vec,
                  f"legacy_us={t_leg:.1f};speedup={speedup:.1f}x"))
    return t_vec, t_leg, speedup


def bench_prefetch(rounds: int = 30, compute_ms: float = 3.0):
    """Serial pack->compute vs prefetch-overlapped (simulated device
    step of ``compute_ms``); ideal overlap hides all packing time."""
    corpus = make_speaker_corpus(num_speakers=48, vocab_size=64, feat_dim=16,
                                 mean_utterances=40.0, seed=0)

    def make_sampler():
        return FederatedSampler(corpus, 8, 4, data_limit=32, seed=0)

    t0 = time.perf_counter()
    for batch in round_batches(make_sampler(), rounds):
        time.sleep(compute_ms / 1e3)
    t_serial = (time.perf_counter() - t0) / rounds * 1e6

    with PrefetchIterator(round_batches(make_sampler(), rounds),
                          device_put=False) as it:
        t0 = time.perf_counter()
        for batch in it:
            time.sleep(compute_ms / 1e3)
        t_prefetch = (time.perf_counter() - t0) / rounds * 1e6

    hidden = t_serial - t_prefetch
    print(csv_row("fed_round_prefetch", t_prefetch,
                  f"serial_us={t_serial:.1f};hidden_us={hidden:.1f}"))
    return t_prefetch, t_serial


def main():
    bench_packing()
    bench_prefetch()


if __name__ == "__main__":
    main()
