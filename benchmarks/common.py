"""Shared benchmark scaffolding: the paper's experiment ladder at
container scale, cached per-experiment so tables reuse runs.

Scale disclosure: the paper trains a 122M RNN-T on 960h Librispeech
for thousands of rounds on TPU; this harness runs the SAME code paths
(FedAvg engine, FVN, data-limit dial, CFMQ accounting, WER metric) on
the synthetic speaker-split corpus at CPU scale. The deliverable is
the *qualitative ladder* (directions and orderings of E0-E10), not the
absolute WERs.
"""
from __future__ import annotations

import json
import os
import time

from repro.core import FederatedPlan, FVNConfig
from repro.launch.train import run_federated_asr, tiny_asr_setup

ROUNDS = int(os.environ.get("REPRO_BENCH_ROUNDS", "100"))
CACHE = os.environ.get("REPRO_BENCH_CACHE", "results/bench_cache")

BASE = dict(clients_per_round=8, local_batch_size=4, client_lr=0.3,
            server_lr=0.05, server_warmup_rounds=max(2, ROUNDS // 15),
            local_steps=12)   # pad cap for unlimited rounds (~2x mean data)
LIMIT = 8
FVN_STD = 0.02


def ladder_plans() -> dict:
    fvn = lambda std, ramp=0: FVNConfig(enabled=True, std=std, ramp_rounds=ramp)
    ramp = ROUNDS // 2
    decay = dict(server_warmup_rounds=max(2, ROUNDS // 30),
                 server_decay_rounds=max(5, ROUNDS // 4), server_decay_rate=0.85)
    plans = {
        "E0": dict(plan=FederatedPlan(**BASE, fvn=fvn(FVN_STD, ramp)), iid=True),
        "E1": dict(plan=FederatedPlan(**BASE), iid=False),
        "E2": dict(plan=FederatedPlan(**BASE, data_limit=LIMIT), iid=False),
        "E3": dict(plan=FederatedPlan(**BASE, data_limit=2 * LIMIT), iid=False),
        "E4": dict(plan=FederatedPlan(**BASE, data_limit=4 * LIMIT), iid=False),
        "E5": dict(plan=FederatedPlan(**BASE, data_limit=LIMIT, fvn=fvn(FVN_STD / 2)), iid=False),
        "E6": dict(plan=FederatedPlan(**BASE, data_limit=LIMIT, fvn=fvn(FVN_STD)), iid=False),
        "E7": dict(plan=FederatedPlan(**BASE, data_limit=LIMIT, fvn=fvn(1.5 * FVN_STD, ramp)), iid=False),
        "E8": dict(plan=FederatedPlan(**BASE, fvn=fvn(1.5 * FVN_STD, ramp)), iid=False),
        "E9": dict(plan=FederatedPlan(**{**BASE, **decay}, data_limit=LIMIT,
                                      fvn=fvn(1.5 * FVN_STD, ramp)), iid=False),
        "E10": dict(plan=FederatedPlan(**{**BASE, **decay}, data_limit=LIMIT,
                                       fvn=fvn(1.5 * FVN_STD, ramp)), iid=False,
                    specaug_scale=2.0),
    }
    return plans


_MEM = {}
MEAN_CLIENT_EXAMPLES = 24.0          # corpus mean_utterances


def experiment_rounds(plan) -> int:
    """Equal-examples budgeting: the paper trains every config to
    convergence; data-limited rounds see fewer examples, so they get
    proportionally more rounds ("the entire per-speaker dataset was
    still seen over the course of multiple rounds", §4.2.1)."""
    if plan.data_limit is None:
        return ROUNDS
    mult = MEAN_CLIENT_EXAMPLES / plan.data_limit
    return int(ROUNDS * max(1.0, min(mult, 5.0)))


def run_experiment(eid: str, seed: int = 0) -> dict:
    """Run (or fetch cached) experiment eid. Returns history summary."""
    key = f"{eid}_r{ROUNDS}_L{LIMIT}_s{seed}"
    if key in _MEM:
        return _MEM[key]
    os.makedirs(CACHE, exist_ok=True)
    path = os.path.join(CACHE, key + ".json")
    if os.path.exists(path):
        with open(path) as f:
            _MEM[key] = json.load(f)
        return _MEM[key]
    import dataclasses

    spec = ladder_plans()[eid]
    cfg, corpus = tiny_asr_setup(seed)
    t0 = time.time()
    n_rounds = experiment_rounds(spec["plan"])
    plan = spec["plan"]
    if plan.fvn.enabled and plan.fvn.ramp_rounds:
        plan = dataclasses.replace(
            plan, fvn=dataclasses.replace(plan.fvn, ramp_rounds=n_rounds // 2))
    if plan.server_decay_rounds:
        plan = dataclasses.replace(plan, server_decay_rounds=max(5, n_rounds // 4))
    spec = dict(spec, plan=plan)
    _, hist = run_federated_asr(
        cfg, corpus, spec["plan"], rounds=n_rounds, seed=seed, iid=spec["iid"],
        specaug_scale=spec.get("specaug_scale", 1.0), eval_examples=64)
    out = {
        "id": eid, "rounds": n_rounds,
        "final_loss": hist["final_loss"],
        "wer": hist["wer"], "wer_hard": hist["wer_hard"],
        "cfmq_tb": hist["cfmq_tb"], "cfmq_bytes": hist["cfmq_bytes"],
        "n_params": hist["n_params"],
        "wall_s": time.time() - t0,
        "loss_curve": hist["loss"][:: max(1, n_rounds // 50)],
    }
    with open(path, "w") as f:
        json.dump(out, f)
    _MEM[key] = out
    return out


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
