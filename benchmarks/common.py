"""Shared benchmark scaffolding: the paper's experiment ladder at
container scale, cached per-experiment so tables reuse runs.

The ladder itself is declared in ``repro.launch.sweeps`` (the
multi-sweep runner); this module owns the bench policy — round budget
via REPRO_BENCH_ROUNDS, a process-wide shared SweepRunner (one corpus,
one jit cache for all experiments) and the on-disk result cache.

Scale disclosure: the paper trains a 122M RNN-T on 960h Librispeech
for thousands of rounds on TPU; this harness runs the SAME code paths
(FedAvg engine, FVN, data-limit dial, CFMQ accounting, WER metric) on
the synthetic speaker-split corpus at CPU scale. The deliverable is
the *qualitative ladder* (directions and orderings of E0-E10), not the
absolute WERs.
"""
from __future__ import annotations

import json
import os

from repro.launch.sweeps import LADDER_LIMIT, SweepRunner, ladder_points, ladder_specs

ROUNDS = int(os.environ.get("REPRO_BENCH_ROUNDS", "100"))
CACHE = os.environ.get("REPRO_BENCH_CACHE", "results/bench_cache")

LIMIT = LADDER_LIMIT   # the ladder's E2 data limit (part of the cache key)

_MEM = {}
_RUNNER = None


def shared_runner() -> SweepRunner:
    """One corpus + one jitted-round-fn cache for every experiment."""
    global _RUNNER
    if _RUNNER is None:
        _RUNNER = SweepRunner(seed=0, eval_examples=64)
    return _RUNNER


def ladder_plans() -> dict:
    """The ladder's {eid: {plan, iid, ...}} specs (tables/fig3 use the
    plan objects for CFMQ accounting)."""
    return ladder_specs(ROUNDS)


def run_experiment(eid: str, seed: int = 0) -> dict:
    """Run (or fetch cached) experiment eid. Returns history summary."""
    # v2: summary rows renamed wer -> quality/quality_metric (FederatedTask
    # redesign); the suffix invalidates pre-rename cached rows
    key = f"{eid}_r{ROUNDS}_L{LIMIT}_s{seed}_v2"
    if key in _MEM:
        return _MEM[key]
    os.makedirs(CACHE, exist_ok=True)
    path = os.path.join(CACHE, key + ".json")
    if os.path.exists(path):
        with open(path) as f:
            _MEM[key] = json.load(f)
        return _MEM[key]

    (point,) = ladder_points(ROUNDS, seed=seed, experiments=[eid])
    # the sweep row IS the experiment summary: one schema
    # (repro.core.metrics.SUMMARY_KEYS) across train histories, sweep
    # rows and the bench cache — no hand-picked subset to drift
    row = shared_runner().run_point(point)
    with open(path, "w") as f:
        json.dump(row, f)
    _MEM[key] = row
    return row


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def bench_reps(env: str, knob: str) -> int:
    """Rep count for a bench loop: the environment variable wins (CI
    pins budgets), otherwise the tuner knob (results/tuning.json can
    retune per device)."""
    from repro.profile.tuner import get_knob

    raw = os.environ.get(env)
    return max(1, int(raw)) if raw else int(get_knob(knob))


def interleaved_min_us(fns: dict, reps=None) -> dict:
    """Microsecond wrapper over the profiling plane's shared
    interleaved order-rotating min protocol
    (``repro.profile.trace.measure_interleaved_min``) — the fed_round
    bench measurement style, now the default for every micro-bench:
    per-cycle order rotation cancels slow-drift runner load, and the
    per-fn MIN is the noise floor each graph can reach."""
    from repro.profile.trace import measure_interleaved_min

    return {k: v * 1e6 for k, v in measure_interleaved_min(fns, reps=reps).items()}
