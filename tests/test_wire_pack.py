"""Packed-wire plane: Pallas kernels vs jnp oracles, byte-exact payload
sizes vs the Python formulas, and bit-exact round-trips against the
in-graph quantize->dequantize path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import (
    CompressionConfig,
    leaf_wire_bytes,
    make_compressor,
    pack_leaf,
    packed_leaf_bytes,
    quantize_codes,
    sum_packed_codes,
    unpack_leaf,
)
from repro.kernels import ref, wire_pack

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - deterministic fallback below
    HAVE_HYPOTHESIS = False


def _codes(n, lo=-7, hi=7, seed=0):
    return jnp.asarray(np.random.default_rng(seed).integers(lo, hi + 1, n),
                       jnp.int8)


# ------------------------------------------------------- kernel parity

@pytest.mark.parametrize("n", [1, 2, 3, 101, 512, 1025, 2048])
def test_nibble_pack_kernel_matches_ref(n):
    codes = _codes(n, seed=n)
    out = wire_pack.nibble_pack_pallas(codes, interpret=True)
    expect = ref.nibble_pack_ref(codes)
    assert out.shape == ((n + 1) // 2,)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("n", [1, 2, 3, 101, 512, 1025, 2048])
def test_nibble_unpack_kernel_matches_ref_and_roundtrips(n):
    codes = _codes(n, seed=1000 + n)
    packed = ref.nibble_pack_ref(codes)
    out = wire_pack.nibble_unpack_pallas(packed, n, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.nibble_unpack_ref(packed, n)))
    # pack -> unpack is the identity on int4 codes
    np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))


@pytest.mark.parametrize("n", [1, 7, 300, 1024])
def test_dequantize_kernel_matches_ref(n):
    codes = _codes(n, lo=-127, hi=127, seed=n)
    scale = jnp.float32(0.0173)
    out = wire_pack.dequantize_pallas(codes, scale, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.dequantize_ref(codes, scale)))


@pytest.mark.parametrize("n,k", [(8, 1), (64, 5), (256, 32), (1, 1)])
def test_topk_unpack_kernel_matches_ref(n, k):
    rng = np.random.default_rng(k * 100 + n)
    vals = jnp.asarray(rng.normal(size=(k,)), jnp.float32)
    idx = jnp.asarray(rng.choice(n, size=k, replace=False), jnp.int32)
    out = wire_pack.topk_unpack_pallas(vals, idx, n, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.topk_unpack_ref(vals, idx, n)))


@pytest.mark.parametrize("n,k,seg", [(64, 5, 16), (256, 32, 64), (100, 11, 32),
                                     (4096, 200, 1024), (16, 16, 16), (1, 1, 8)])
def test_topk_unpack_segmented_matches_ref(n, k, seg):
    """The grid-parallel segmented scatter: sorted payload + per-segment
    searchsorted bounds must reproduce the serial scatter exactly —
    including entries straddling segment boundaries, a full payload
    (k == n) and the size-1 degenerate."""
    rng = np.random.default_rng(k * 7 + n)
    vals = jnp.asarray(rng.normal(size=(k,)), jnp.float32)
    idx = jnp.asarray(rng.choice(n, size=k, replace=False), jnp.int32)
    out = wire_pack.topk_unpack_segmented_pallas(vals, idx, n, seg=seg,
                                                 interpret=True)
    assert out.shape == (n,)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.topk_unpack_ref(vals, idx, n)))


def test_topk_unpack_segmented_boundary_indices():
    """Entries exactly on segment edges (0, seg-1, seg, n-1) land in the
    right cells."""
    n, seg = 128, 32
    idx = jnp.asarray([0, 31, 32, 63, 64, 127], jnp.int32)
    vals = jnp.arange(1.0, 7.0, dtype=jnp.float32)
    out = wire_pack.topk_unpack_segmented_pallas(vals, idx, n, seg=seg,
                                                 interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.topk_unpack_ref(vals, idx, n)))


# ----------------------------------------------- fused quantize -> pack

# the PR 3 ulp regression values: |x| / (|x| / levels) > levels in f32
_BOUNDARY = {8: 2.770888566970825, 4: 7.646292686462402}


def _fused_case(n, bits, seed, boundary=False, stochastic=True):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n,)).astype(np.float32)
    if boundary:
        x[0] = _BOUNDARY[bits]
        x[1:] = x[1:] * 0.1
    return jnp.asarray(x)


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("n", [1, 2, 3, 33, 101, 512, 1025])
@pytest.mark.parametrize("stochastic", [True, False])
def test_fused_quantize_pack_matches_composition(bits, n, stochastic):
    """The fused kernel == quantize_codes + pack_leaf's historical
    composition, code for code and byte for byte — odd sizes, size-1,
    both rounding modes."""
    from repro.core.compression import leaf_scale, _rounding_field

    x = _fused_case(n, bits, seed=n * bits)
    key = jax.random.PRNGKey(n + bits)
    scale = leaf_scale(x, bits)
    u = _rounding_field(key, x.shape, stochastic)
    codes_ref = ref.quantize_codes_with_scale_ref(
        x, scale, u, 2.0 ** (bits - 1) - 1.0)
    # dispatch wrapper (oracle on CPU)
    codes = wire_pack.quantize_with_scale(x, scale, u, bits)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(codes_ref))
    # pallas kernels in interpret mode
    k_codes = wire_pack.quantize_with_scale_pallas(x, scale, u, bits,
                                                   interpret=True)
    np.testing.assert_array_equal(np.asarray(k_codes), np.asarray(codes_ref))
    payload_ref = (ref.nibble_pack_ref(codes_ref) if bits == 4 else codes_ref)
    payload = wire_pack.quantize_pack(x, scale, u, bits)
    np.testing.assert_array_equal(np.asarray(payload), np.asarray(payload_ref))
    if bits == 4:
        k_payload = wire_pack.quantize_pack4_pallas(x, scale, u,
                                                    interpret=True)
        np.testing.assert_array_equal(np.asarray(k_payload),
                                      np.asarray(payload_ref))


@pytest.mark.parametrize("bits", [8, 4])
def test_fused_quantize_pack_absmax_boundary_never_wraps(bits):
    """The PR 3 ulp regression case through the FUSED kernel: the
    absmax coordinate must clamp before the rounding draw, or a
    boundary draw quantizes to levels+1 and the int8/nibble cast wraps
    the sign inside the packed buffer."""
    from repro.core.compression import leaf_scale, _rounding_field

    levels = 2 ** (bits - 1) - 1
    x = _fused_case(64, bits, seed=0, boundary=True)
    scale = leaf_scale(x, bits)
    for i in range(20):
        u = _rounding_field(jax.random.PRNGKey(i), x.shape, True)
        codes = np.asarray(wire_pack.quantize_with_scale_pallas(
            x, scale, u, bits, interpret=True))
        assert codes.min() >= -levels and codes.max() <= levels
        assert codes[0] == levels
        if bits == 4:
            packed = wire_pack.quantize_pack4_pallas(x, scale, u,
                                                     interpret=True)
            unpacked = np.asarray(ref.nibble_unpack_ref(packed, 64))
            np.testing.assert_array_equal(unpacked, codes)


# --------------------------------------- payload size == byte formula

_KIND_CFGS = [
    CompressionConfig(kind="int8", packed=True),
    CompressionConfig(kind="int4", packed=True),
    CompressionConfig(kind="topk", topk_frac=0.05, packed=True),
    CompressionConfig(kind="topk", topk_frac=1e-9, packed=True),  # k -> 1
    CompressionConfig(kind="topk", topk_frac=1.0, packed=True),
]


def _assert_payload_bytes(cfg, n, seed=0):
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(n,)), jnp.float32)
    payload = pack_leaf(cfg, x, jax.random.PRNGKey(seed))
    assert packed_leaf_bytes(payload) == leaf_wire_bytes(cfg, n), (cfg.kind, n)


@pytest.mark.parametrize("cfg", _KIND_CFGS, ids=lambda c: f"{c.kind}-{c.topk_frac}")
@pytest.mark.parametrize("n", [1, 2, 3, 33, 101, 4096])
def test_packed_payload_size_equals_formula(cfg, n):
    """The Python byte formula equals the materialized buffer size for
    every kind — including odd-size int4 nibble padding, topk_frac -> 0
    (k floors at 1) and size-1 tensors."""
    _assert_payload_bytes(cfg, n)


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(n=st.integers(1, 5000),
           kind=st.sampled_from(["int8", "int4", "topk"]),
           frac=st.floats(1e-9, 1.0))
    def test_packed_payload_size_property(n, kind, frac):
        cfg = CompressionConfig(kind=kind, topk_frac=frac, packed=True)
        _assert_payload_bytes(cfg, n, seed=n % 17)

else:  # deterministic fallback sweep

    @pytest.mark.parametrize("n", [1, 5, 17, 999, 5000])
    @pytest.mark.parametrize("kind,frac", [("int8", 0.05), ("int4", 0.05),
                                           ("topk", 1e-9), ("topk", 0.37),
                                           ("topk", 1.0)])
    def test_packed_payload_size_property(n, kind, frac):
        cfg = CompressionConfig(kind=kind, topk_frac=frac, packed=True)
        _assert_payload_bytes(cfg, n, seed=n % 17)


# ------------------------------------------------- bit-exact roundtrip

TREE = {
    "a": jnp.asarray(np.random.default_rng(0).normal(size=(16, 8)), jnp.float32),
    "b": {"c": jnp.asarray(np.random.default_rng(1).normal(size=(33,)), jnp.float32)},
    "s": jnp.asarray(np.random.default_rng(2).normal(size=(1,)), jnp.float32),
}


@pytest.mark.parametrize("kind,frac", [("int8", 0.05), ("int4", 0.05),
                                       ("topk", 0.05), ("topk", 0.25)])
def test_packed_roundtrip_bit_exact_vs_in_graph(kind, frac):
    """pack -> unpack == in-graph quantize -> dequantize, bit for bit:
    both consume the same codes, so the wire format is a pure re-layout."""
    key = jax.random.PRNGKey(42)
    plain = make_compressor(CompressionConfig(kind=kind, topk_frac=frac))(TREE, key)
    packed = make_compressor(
        CompressionConfig(kind=kind, topk_frac=frac, packed=True))(TREE, key)
    for a, b in zip(jax.tree.leaves(plain), jax.tree.leaves(packed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_packed_roundtrip_under_jit_and_vmap():
    """The round engine vmaps the compressor over clients; the packed
    path must survive jit+vmap unchanged."""
    X = jnp.asarray(np.random.default_rng(3).normal(size=(4, 16, 8)), jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    compress = make_compressor(CompressionConfig(kind="int4", packed=True))
    plain = make_compressor(CompressionConfig(kind="int4"))
    # both sides jit+vmap so the comparison isolates the wire re-layout
    # (jit-vs-eager would differ by fusion/FMA ulps unrelated to packing)
    out_p = jax.jit(jax.vmap(lambda x, k: compress({"w": x}, k)))(X, keys)
    out_q = jax.jit(jax.vmap(lambda x, k: plain({"w": x}, k)))(X, keys)
    np.testing.assert_array_equal(np.asarray(out_p["w"]), np.asarray(out_q["w"]))


def test_unpack_leaf_restores_shape_and_dtype():
    cfg = CompressionConfig(kind="int4", packed=True)
    x = jnp.asarray(np.random.default_rng(5).normal(size=(7, 3)), jnp.float32)
    payload = pack_leaf(cfg, x, jax.random.PRNGKey(1))
    out = unpack_leaf(cfg, payload, x.shape, x.dtype)
    assert out.shape == x.shape and out.dtype == x.dtype


# ------------------------------------------------ packed-form allreduce

def test_sum_packed_codes_matches_dequantized_sum():
    """With a shared scale, summing the *packed* codes (widened to
    int32) then dequantizing once equals summing the dequantized
    tensors — the packed-form all-reduce of the uplink."""
    rng = np.random.default_rng(9)
    K, n = 6, 64
    # same absmax for every client => identical scales
    X = rng.normal(size=(K, n)).astype(np.float32)
    X[:, 0] = 10.0
    X = jnp.asarray(X)
    keys = jax.random.split(jax.random.PRNGKey(11), K)
    for kind in ("int8", "int4"):
        cfg = CompressionConfig(kind=kind, packed=True)
        payloads = [pack_leaf(cfg, X[i], keys[i]) for i in range(K)]
        scales = np.asarray([p[1] for p in payloads])
        np.testing.assert_allclose(scales, scales[0])
        code_sum = sum_packed_codes(cfg, jnp.stack([p[0] for p in payloads]), n)
        packed_reduce = np.asarray(code_sum, np.float32) * scales[0]
        dense_reduce = sum(
            np.asarray(unpack_leaf(cfg, p, (n,))) for p in payloads)
        np.testing.assert_allclose(packed_reduce, dense_reduce, atol=1e-5)


def test_quantize_codes_range_never_wraps():
    """Codes live in [-levels, levels]: the pre-draw clamp keeps the
    int8 cast from wrapping (an unclamped boundary draw could yield
    levels+1, which int8-wraps to a sign flip in the packed buffer)."""
    rng = np.random.default_rng(13)
    # absmax values chosen so f32 division overshoots the grid boundary
    for a, bits in [(2.770888566970825, 8), (0.26362359523773193, 8),
                    (7.646292686462402, 4), (3.625833749771118, 4)]:
        x = jnp.asarray(np.concatenate([[a], rng.normal(size=63)]), jnp.float32)
        levels = 2 ** (bits - 1) - 1
        for i in range(20):
            codes, _ = quantize_codes(x, jax.random.PRNGKey(i), bits)
            c = np.asarray(codes)
            assert c.min() >= -levels and c.max() <= levels


def test_sum_packed_codes_rejects_topk():
    cfg = CompressionConfig(kind="topk", topk_frac=0.05, packed=True)
    with pytest.raises(ValueError, match="code-domain"):
        sum_packed_codes(cfg, jnp.zeros((2, 3), jnp.float32), 3)


# --------------------------------------------- in-kernel keyed PRNG (PR 10)
# The fast-path client kernels draw their stochastic-rounding uniforms
# from an in-kernel threefry hash of (key words, flat position) — the
# field never exists in HBM. The contract is BIT-parity with streaming
# jax.random.uniform(key, (n,)) in, which these tests pin on the jnp
# oracle, the Pallas kernels (interpret), and the public dispatchers.


@pytest.mark.parametrize("seed", [0, 42, 123456])
@pytest.mark.parametrize("n", [1, 2, 3, 7, 8, 512, 513, 4097])
def test_threefry_uniform_oracle_bit_parity(seed, n):
    key = jax.random.PRNGKey(seed)
    mine = ref.threefry_uniform_ref(key, n)
    theirs = jax.random.uniform(key, (n,))
    np.testing.assert_array_equal(np.asarray(mine), np.asarray(theirs))


def test_threefry_uniform_oracle_bit_parity_after_fold_in():
    """The production keys are per-client/per-leaf fold_in derivations —
    the oracle must track the full key-derivation chain."""
    key = jax.random.PRNGKey(3)
    for tag in (0, 1, 0x636D70, 917):
        key = jax.random.fold_in(key, tag)
        np.testing.assert_array_equal(
            np.asarray(ref.threefry_uniform_ref(key, 257)),
            np.asarray(jax.random.uniform(key, (257,))))


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("n", [1, 64, 513, 1025, 2048])
def test_keyed_quantize_bits_match_streamed_field(bits, n):
    """quantize_with_scale_keyed == quantize_with_scale fed the streamed
    uniform field, code for code — oracle path and Pallas kernel."""
    rng = np.random.default_rng(n + bits)
    x = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    key = jax.random.PRNGKey(100 + n)
    levels = 2.0 ** (bits - 1) - 1.0
    scale = jnp.maximum(jnp.max(jnp.abs(x)) / levels, 1e-8)
    u = jax.random.uniform(key, (n,))
    want = wire_pack.quantize_with_scale(x, scale, u, bits)
    got = wire_pack.quantize_with_scale_keyed(x, scale, key, bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    got_k = wire_pack.quantize_with_scale_keyed_pallas(x, scale, key, bits,
                                                       interpret=True)
    np.testing.assert_array_equal(np.asarray(got_k), np.asarray(want))


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("n", [1, 65, 513, 2048])
def test_keyed_pack_matches_streamed_pack(bits, n):
    """The fused keyed pack kernels produce the byte-identical wire
    buffer to the historical streamed-field quantize_pack."""
    rng = np.random.default_rng(7 * n + bits)
    x = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    key = jax.random.PRNGKey(n)
    levels = 2.0 ** (bits - 1) - 1.0
    scale = jnp.maximum(jnp.max(jnp.abs(x)) / levels, 1e-8)
    u = jax.random.uniform(key, (n,))
    want = wire_pack.quantize_pack(x, scale, u, bits)
    got = wire_pack.quantize_pack_keyed(x, scale, key, bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    if bits == 4:
        got_k = wire_pack.quantize_pack4_keyed_pallas(x, scale, key,
                                                      interpret=True)
        np.testing.assert_array_equal(np.asarray(got_k), np.asarray(want))


# ------------------------------------------------- topk scatter-add (PR 10)


@pytest.mark.parametrize("K,k,n", [(1, 1, 8), (3, 5, 64), (4, 16, 1000),
                                   (2, 7, 4096)])
def test_topk_scatter_add_matches_manual(K, k, n):
    """Weighted payload scatter-add: duplicates accumulate, weights
    scale per client — checked against a host-side loop, on the jnp
    oracle and the segmented Pallas kernel."""
    rng = np.random.default_rng(K * n + k)
    vals = jnp.asarray(rng.normal(size=(K, k)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, (K, k)), jnp.int32)
    w = jnp.asarray(rng.integers(0, 5, (K,)), jnp.float32)
    manual = np.zeros((n,), np.float64)
    for ci in range(K):
        for j in range(k):
            manual[int(idx[ci, j])] += float(w[ci]) * float(vals[ci, j])
    got = ref.topk_scatter_add_ref(vals, idx, w, n)
    np.testing.assert_allclose(np.asarray(got), manual.astype(np.float32),
                               rtol=1e-6, atol=1e-6)
    flat = (w[:, None] * vals).reshape(-1)
    got_k = wire_pack.topk_scatter_add_pallas(flat, idx.reshape(-1), n,
                                              seg=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got_k), manual.astype(np.float32),
                               rtol=1e-6, atol=1e-6)


def test_topk_scatter_add_dispatcher_zero_weight_cancels():
    """A dropped client (weight 0) contributes nothing even though its
    payload is present — the fast path's cohort-drop contract."""
    vals = jnp.asarray([[5.0, 5.0], [1.0, 2.0]], jnp.float32)
    idx = jnp.asarray([[0, 1], [1, 2]], jnp.int32)
    w = jnp.asarray([0.0, 3.0], jnp.float32)
    out = np.asarray(wire_pack.topk_scatter_add(vals, idx, w, 4))
    np.testing.assert_allclose(out, [0.0, 3.0, 6.0, 0.0])
