"""PrefetchIterator failure paths: worker-exception propagation and
clean shutdown mid-iteration (satellite of the server-plane PR; the
happy paths live in tests/test_data_plane.py)."""
import time

import pytest

from repro.data.prefetch import PrefetchIterator


class Boom(RuntimeError):
    pass


def test_worker_exception_delivered_after_good_items():
    """Items produced before the failure arrive in order; then the
    original exception (same type, same message) surfaces."""
    def source():
        yield 1
        yield 2
        raise Boom("worker died")

    it = PrefetchIterator(source(), device_put=False)
    assert next(it) == 1
    assert next(it) == 2
    with pytest.raises(Boom, match="worker died"):
        next(it)
    # exhausted after the error: iteration stays terminated
    with pytest.raises(StopIteration):
        next(it)
    it.close()


def test_transform_exception_propagates():
    it = PrefetchIterator(iter([1, 2]), device_put=False,
                          transform=lambda x: 1 // (x - 1))
    with pytest.raises(ZeroDivisionError):
        list(it)
    it.close()


def test_immediate_exception_no_items():
    def source():
        raise Boom("instant")
        yield  # pragma: no cover

    with pytest.raises(Boom, match="instant"):
        next(PrefetchIterator(source(), device_put=False))


def test_close_mid_iteration_stops_worker_and_is_idempotent():
    produced = []

    def source():
        for i in range(1000):
            produced.append(i)
            yield i

    it = PrefetchIterator(source(), depth=2, device_put=False)
    assert next(it) == 0
    it.close()
    assert not it._thread.is_alive()
    n = len(produced)
    time.sleep(0.05)
    assert len(produced) == n          # generator no longer advancing
    it.close()                         # idempotent
    with pytest.raises(StopIteration):
        next(it)


def test_context_manager_exit_joins_worker_on_consumer_error():
    """A consumer crash inside the with-block must still tear the
    worker down (the round loop's finally-close contract)."""
    def source():
        while True:
            yield 0

    with pytest.raises(Boom):
        with PrefetchIterator(source(), depth=2, device_put=False) as it:
            next(it)
            worker = it._thread
            raise Boom("consumer crashed")
    assert not worker.is_alive()


def test_close_unblocks_worker_stuck_on_full_queue():
    """Worker blocked in put() (consumer never drains) must observe the
    stop event and exit promptly on close()."""
    def source():
        i = 0
        while True:
            yield i
            i += 1

    it = PrefetchIterator(source(), depth=1, device_put=False)
    time.sleep(0.1)                    # let the worker fill the queue
    t0 = time.time()
    it.close()
    assert time.time() - t0 < 2.0
    assert not it._thread.is_alive()


def test_depth_validation():
    with pytest.raises(ValueError, match="depth"):
        PrefetchIterator(iter([]), depth=0)


def test_sharded_device_put_lands_on_target_sharding():
    """PR 10: a ``sharding`` routes the worker-thread transfer straight
    to the mesh placement, so the sharded round step never re-shards its
    input (and ``device_put=False`` is overridden — a sharding IS a
    placement request)."""
    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.launch.mesh import make_federated_mesh

    sh = NamedSharding(make_federated_mesh(1), PartitionSpec("clients"))
    src = [{"x": np.arange(8, dtype=np.float32).reshape(4, 2),
            "w": np.ones((4,), np.float32)}]
    with PrefetchIterator(iter(src), device_put=False, sharding=sh) as it:
        item = next(it)
    for k, v in item.items():
        assert isinstance(v, jax.Array), k
        assert v.sharding.is_equivalent_to(sh, v.ndim), (k, v.sharding)
        np.testing.assert_array_equal(np.asarray(v), src[0][k])
