"""One round-metrics / summary schema across every emitter.

``repro.core.metrics`` is the contract: the jitted engines emit exactly
``ROUND_METRIC_KEYS`` per round, and the three run-summary emitters —
``launch.train.run_federated_asr``, ``launch.sweeps.run_point`` and
``benchmarks.common.run_experiment`` — all build their dicts through
``summary_row``, so a key added to one cannot silently drift from the
others (the pre-schema code had three hand-maintained dicts).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ROUND_METRIC_KEYS,
    SUMMARY_KEYS,
    AsyncConfig,
    FederatedPlan,
    LatencyConfig,
    init_server_state,
    make_round_step,
    summary_row,
)


def _loss_fn(params, batch, rng):
    pred = batch["x"] @ params["w"]
    w = batch["weight"]
    l = jnp.sum((pred - batch["y"]) ** 2 * w[:, None]) / jnp.maximum(w.sum(), 1)
    return l, {}


def _batch(K=4, S=1, b=4):
    r = np.random.default_rng(0)
    x = r.normal(size=(K, S, b, 4)).astype(np.float32)
    w_true = r.normal(size=(4, 2)).astype(np.float32)
    return {"x": jnp.array(x), "y": jnp.array(x @ w_true),
            "weight": jnp.ones((K, S, b), np.float32)}


def _dummy_fields(**over):
    fields = {k: 0.0 for k in SUMMARY_KEYS}
    fields.update(over)
    return fields


# ------------------------------------------------------- summary_row

def test_summary_row_orders_schema_first():
    row = summary_row(extras={"id": "x", "loss_curve": [1.0]},
                      **_dummy_fields(rounds=3))
    assert list(row)[: len(SUMMARY_KEYS)] == list(SUMMARY_KEYS)
    assert row["rounds"] == 3 and row["id"] == "x"


def test_summary_row_rejects_missing_unknown_and_shadowing():
    fields = _dummy_fields()
    missing = dict(fields)
    del missing["quality"]
    with pytest.raises(ValueError, match="quality"):
        summary_row(**missing)
    with pytest.raises(ValueError, match="not_a_field"):
        summary_row(not_a_field=1.0, **fields)
    with pytest.raises(ValueError, match="quality"):
        summary_row(extras={"quality": 0.1}, **fields)


# ------------------------------------------- per-round metric schema

@pytest.mark.parametrize("plan", [
    FederatedPlan(clients_per_round=4, client_lr=0.1),
    FederatedPlan(clients_per_round=4, client_lr=0.1, engine="fedsgd"),
    FederatedPlan(clients_per_round=4, client_lr=0.1,
                  latency=LatencyConfig(enabled=True)),
    FederatedPlan(clients_per_round=4, client_lr=0.1, engine="async",
                  asynchrony=AsyncConfig(buffer_size=3)),
], ids=["fedavg", "fedsgd", "fedavg_latency", "async"])
def test_every_engine_emits_the_round_metric_schema(plan):
    step = jax.jit(make_round_step(_loss_fn, plan, jax.random.PRNGKey(0)))
    _, metrics = step(init_server_state(plan, {"w": jnp.zeros((4, 2))}),
                      _batch())
    assert set(metrics) == set(ROUND_METRIC_KEYS)


# ------------------------------------------------- the three emitters

@pytest.mark.slow
def test_train_sweep_and_bench_summaries_share_the_schema(tmp_path):
    from benchmarks import common
    from repro.launch.sweeps import SweepPoint, SweepRunner
    from repro.launch.train import run_federated_asr, tiny_asr_setup

    cfg, corpus = tiny_asr_setup(0)
    runner = SweepRunner(cfg=cfg, corpus=corpus, seed=0, eval_examples=8)
    plan = FederatedPlan(clients_per_round=8, local_batch_size=4,
                         data_limit=2, local_steps=4, client_lr=0.3,
                         server_lr=0.05)

    _, hist = run_federated_asr(cfg, corpus, plan, rounds=2, seed=0,
                                eval_examples=8, log=lambda *a: None)
    row = runner.run_point(SweepPoint(id="p", plan=plan, rounds=2),
                           log=lambda *a: None)
    common.ROUNDS, common.CACHE, common._RUNNER = 2, str(tmp_path), runner
    common._MEM.clear()
    bench = common.run_experiment("E1")

    for emitter, d in (("train", hist), ("sweep", row), ("bench", bench)):
        assert list(d)[: len(SUMMARY_KEYS)] == list(SUMMARY_KEYS), emitter
    # the emitters differ only in their documented extras
    assert set(hist) - set(SUMMARY_KEYS) == {"loss", "wire_bytes",
                                             "train_time_s"}
    assert set(row) - set(SUMMARY_KEYS) == {"id", "loss_curve",
                                            "sim_time_curve"}
    assert set(bench) - set(SUMMARY_KEYS) == {"id", "loss_curve",
                                              "sim_time_curve", "experiment"}
