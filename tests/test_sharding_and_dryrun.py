"""Sharding-rule engine + a miniature dry-run (8 fake devices in a
subprocess, since XLA device count locks at first jax init)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_cost import analyze, shape_bytes
from repro.launch.sharding import fsdpify, make_param_specs, sanitize_specs


class FakeMesh:
    axis_names = ("data", "model")

    class _D:
        shape = (4, 2)

    devices = _D()


def test_make_param_specs_first_match_wins():
    params = {"layers": {"attn": {"wq": np.zeros((2, 4, 8))}},
              "embed": np.zeros((16, 8))}
    rules = [(r"attn/wq$", P(None, None, "model")), (r"embed$", P("model", None))]
    specs = make_param_specs(params, rules)
    assert specs["layers"]["attn"]["wq"] == P(None, None, "model")
    assert specs["embed"] == P("model", None)


def test_sanitize_drops_nondivisible():
    params = {"w": np.zeros((6, 7))}
    specs = {"w": P("data", "model")}            # 6%4 != 0, 7%2 != 0
    out = sanitize_specs(params, specs, FakeMesh())
    assert out["w"] == P(None, None)
    params2 = {"w": np.zeros((8, 6))}
    out2 = sanitize_specs(params2, {"w": P("data", "model")}, FakeMesh())
    assert out2["w"] == P("data", "model")


def test_sanitize_strips_unknown_axes():
    params = {"w": np.zeros((8, 6))}
    out = sanitize_specs(params, {"w": P(("pod", "data"), None)}, FakeMesh())
    assert out["w"] == P("data", None)


def test_fsdpify_last_free_divisible_dim():
    params = {"big": np.zeros((36, 1024, 512)), "small": np.zeros((4,))}
    specs = {"big": P(None, None, "model"), "small": P()}
    out = fsdpify(params, specs, FakeMesh(), fsdp_axes=("data",), min_size=1024)
    assert out["big"] == P(None, "data", "model")
    assert tuple(out["small"]) in ((), (None,))


def test_hlo_cost_scan_scaling():
    import jax.numpy as jnp

    def f_scan(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, None, length=10)[0]

    s = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    r = analyze(jax.jit(f_scan).lower(s, s).compile().as_text())
    expected = 10 * 2 * 128**3
    assert abs(r["flops"] - expected) / expected < 0.05


def test_shape_bytes():
    assert shape_bytes("f32[4,8]{1,0}") == 128
    assert shape_bytes("bf16[10]") == 20
    assert shape_bytes("(f32[2,2], s32[3])") == 28
    assert shape_bytes("pred[]") == 1


DRYRUN_SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding

    from repro.configs import get_arch
    from repro.core import FederatedPlan, init_server_state, make_round_step
    from repro.core.fedavg import server_state_specs
    from repro.launch.mesh import compat_make_mesh
    from repro.launch.sharding import make_param_specs, sanitize_specs, named
    from repro.models import build_model

    mesh = compat_make_mesh((4, 2), ("data", "model"))
    arch = get_arch("qwen3-8b")
    cfg = arch.make_smoke_config()
    bundle = build_model(cfg)
    plan = FederatedPlan(clients_per_round=4, local_batch_size=2, engine=arch.engine)
    params = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    pspecs = sanitize_specs(params, make_param_specs(params, arch.param_rules), mesh)
    state = jax.eval_shape(lambda p: init_server_state(plan, p), params)
    sspecs = server_state_specs(plan, pspecs)
    batch = {
        "tokens": jax.ShapeDtypeStruct((4, 1, 2, 32), jnp.int32),
        "weight": jax.ShapeDtypeStruct((4, 1, 2), jnp.float32),
    }
    bspecs = jax.tree.map(lambda _: P("data"), batch)
    step = make_round_step(bundle.loss_fn, plan, jax.random.PRNGKey(1))
    fn = jax.jit(step, in_shardings=(named(mesh, sspecs), named(mesh, bspecs)),
                 out_shardings=(named(mesh, sspecs), None))
    compiled = fn.lower(state, batch).compile()
    ma = compiled.memory_analysis()
    print(json.dumps({"ok": True, "temp": ma.temp_size_in_bytes}))
""")


def test_mini_dryrun_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", DRYRUN_SNIPPET], env=env,
                         capture_output=True, text=True, timeout=420,
                         cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"]


def test_hlo_cost_in_place_update_charged_at_slice_size():
    """Scan carries update one slice per step; the byte model must
    charge the slice, not the whole stacked buffer (cost model v2)."""
    import jax.numpy as jnp

    def f(x):
        def body(c, _):
            return jnp.tanh(c), c
        _, ys = jax.lax.scan(body, x, None, length=64)
        return ys

    s = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    r = analyze(jax.jit(f).lower(s).compile().as_text())
    # v1 charged ~64 x full (64,128,128) buffer ~ 268 MB; v2 charges
    # ~64 x (slice io + tanh io) ~ 64 x ~0.26 MB
    assert r["bytes"] < 5e7, r["bytes"]
