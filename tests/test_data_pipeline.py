"""Speaker corpus + federated sampler: the non-IID dial's mechanics."""
import numpy as np
import pytest

from repro.data import FederatedSampler, make_speaker_corpus, pack_round

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - deterministic fallback below
    HAVE_HYPOTHESIS = False


@pytest.fixture(scope="module")
def corpus():
    return make_speaker_corpus(num_speakers=12, vocab_size=32, feat_dim=8,
                               mean_utterances=10.0, seed=1)


def test_corpus_shapes_and_histogram(corpus):
    assert corpus.num_speakers == 12
    hist = corpus.utterance_histogram()
    assert hist.min() >= 2 and hist.shape == (12,)
    # log-normal-ish spread (Fig. 2): not all speakers equal
    assert hist.max() > hist.min()
    for s in corpus.speakers:
        n = s["n"]
        assert s["features"].shape[0] == n
        assert np.isfinite(s["features"]).all()
        assert (s["label_len"] >= 4).all()


def test_speaker_bias_makes_data_noniid(corpus):
    """Per-speaker mean features differ far more across speakers than
    the within-speaker noise would explain — the non-IID signature."""
    means = np.array([s["features"][:, : s["frame_len"].min()].mean() for s in corpus.speakers])
    assert means.std() > 0.05


def test_data_limit_caps_examples(corpus):
    s = FederatedSampler(corpus, clients_per_round=4, local_batch_size=2,
                         data_limit=3, seed=0)
    rb = s.next_round()
    assert rb.features.shape[:3] == (4, s.steps, 2)
    assert (rb.n_k == 3).all()
    assert rb.mask.sum() == 12


def test_no_limit_uses_full_client_data(corpus):
    s = FederatedSampler(corpus, clients_per_round=4, local_batch_size=2, seed=0)
    rb = s.next_round()
    assert rb.mask.sum() == rb.n_k.sum()
    assert rb.n_k.min() >= 2


def test_limited_rounds_traverse_all_data(corpus):
    """Paper §4.2.1: 'the entire per-speaker dataset was still seen over
    the course of multiple rounds' — cursors advance across rounds."""
    s = FederatedSampler(corpus, clients_per_round=12, local_batch_size=1,
                         data_limit=2, seed=0)
    max_n = max(sp["n"] for sp in corpus.speakers)
    for _ in range(max_n):                    # enough rounds for full pass
        s.next_round()
    cursors = np.array([s._cursors.get(i, 0) for i in range(corpus.num_speakers)])
    assert (cursors >= np.array([min(sp["n"], 2) for sp in corpus.speakers])).all()
    assert cursors.sum() >= 12 * 2


def _check_sampler_shapes(limit, K, b):
    corpus = make_speaker_corpus(num_speakers=8, vocab_size=16, feat_dim=4,
                                 mean_utterances=6.0, seed=3)
    s = FederatedSampler(corpus, clients_per_round=K, local_batch_size=b,
                         data_limit=limit, seed=1)
    rb = s.next_round()
    K_, S_, b_ = rb.mask.shape
    assert (K_, b_) == (K, b)
    assert S_ * b >= limit                    # room for the limit
    assert (rb.n_k <= limit).all()
    # mask count == n_k per client
    np.testing.assert_allclose(rb.mask.sum(axis=(1, 2)), rb.n_k)


@pytest.mark.parametrize("limit,K,b", [(1, 1, 1), (1, 6, 4), (8, 1, 1),
                                       (8, 6, 4), (3, 4, 2), (5, 2, 3)])
def test_sampler_shapes_deterministic(limit, K, b):
    _check_sampler_shapes(limit, K, b)


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(limit=st.integers(1, 8), K=st.integers(1, 6), b=st.integers(1, 4))
    def test_sampler_shapes_property(limit, K, b):
        _check_sampler_shapes(limit, K, b)


def test_pack_round_iid():
    corpus = make_speaker_corpus(num_speakers=6, vocab_size=16, feat_dim=4,
                                 mean_utterances=6.0, seed=4)
    rb = pack_round(corpus.iid_pool(), K=3, steps=2, batch=2)
    assert rb.features.shape[:3] == (3, 2, 2)
    assert rb.mask.all()


def test_eval_split_hard_is_noisier():
    corpus = make_speaker_corpus(num_speakers=6, vocab_size=16, feat_dim=4, seed=5)
    ev = corpus.eval_split(16)
    ev_hard = corpus.eval_split(16, hard=True)
    assert ev["features"].std() < ev_hard["features"].std()
