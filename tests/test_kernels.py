"""Pallas kernels vs. jnp oracles: shape/dtype sweeps, interpret=True."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import flash_decode
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lstm_gates import lstm_gates_fused
from repro.kernels.rnnt_joint import rnnt_joint_fused

def _rng():
    return np.random.default_rng(1234)


def _rand(shape, dtype, rng=None):
    x = (rng or np.random.default_rng(abs(hash(shape)) % 2**31)).normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Sk,H,Kv,D,causal,window",
    [
        (2, 128, 128, 4, 2, 32, True, 0),
        (1, 256, 256, 8, 8, 16, True, 64),
        (2, 128, 128, 4, 1, 32, False, 0),
        (1, 512, 512, 2, 2, 64, True, 0),
        (1, 128, 256, 4, 4, 32, False, 0),   # cross-attention shape
    ],
)
def test_flash_attention_sweep(B, Sq, Sk, H, Kv, D, causal, window, dtype):
    q = _rand((B, Sq, H, D), dtype)
    k = _rand((B, Sk, Kv, D), dtype)
    v = _rand((B, Sk, Kv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          tq=64, tk=64, interpret=True)
    expected = ref.attention_ref(q, k, v, causal=causal,
                                 window=window if window else None)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,Kv,D,window,pos",
    [
        (2, 512, 8, 2, 32, 0, 173),
        (1, 1024, 4, 4, 16, 128, 900),
        (3, 256, 2, 1, 64, 0, 0),
        (1, 2048, 8, 8, 32, 0, 2047),
    ],
)
def test_flash_decode_sweep(B, S, H, Kv, D, window, pos, dtype):
    q = _rand((B, H, D), dtype)
    kc = _rand((B, S, Kv, D), dtype)
    vc = _rand((B, S, Kv, D), dtype)
    out = flash_decode(q, kc, vc, jnp.asarray(pos, jnp.int32),
                       window=window, ts=128, interpret=True)
    expected = ref.decode_attention_ref(q, kc, vc, pos,
                                        window=window if window else None)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize(
    "B,T,U1,J,V,tq,tu,tv",
    [
        (2, 32, 16, 24, 64, 16, 8, 32),
        (1, 16, 8, 16, 128, 8, 4, 64),
        (2, 24, 12, 8, 48, 8, 4, 16),
        (1, 64, 8, 32, 256, 16, 8, 128),
    ],
)
def test_rnnt_joint_sweep(B, T, U1, J, V, tq, tu, tv):
    e = _rand((B, T, J), jnp.float32)
    g = _rand((B, U1, J), jnp.float32)
    w = _rand((J, V), jnp.float32) * 0.3
    b = _rand((V,), jnp.float32) * 0.1
    lbl = jnp.asarray(np.random.default_rng(7).integers(0, V, (B, U1)), jnp.int32)
    blank, label = rnnt_joint_fused(e, g, w, b, lbl, tq=tq, tu=tu, tv=tv,
                                    interpret=True)
    blank_ref, label_ref = ref.rnnt_joint_ref(e, g, w, b, lbl)
    np.testing.assert_allclose(np.asarray(blank), np.asarray(blank_ref), atol=3e-5)
    np.testing.assert_allclose(np.asarray(label), np.asarray(label_ref), atol=3e-5)


def test_rnnt_joint_custom_vjp_matches_ref_grad():
    from repro.kernels.ops import rnnt_joint

    B, T, U1, J, V = 2, 16, 8, 12, 32
    e = _rand((B, T, J), jnp.float32)
    g = _rand((B, U1, J), jnp.float32)
    w = _rand((J, V), jnp.float32) * 0.3
    b = _rand((V,), jnp.float32) * 0.1
    lbl = jnp.asarray(np.random.default_rng(7).integers(0, V, (B, U1)), jnp.int32)

    def f_kernel(e, g, w, b):
        bb, ll = rnnt_joint(e, g, w, b, lbl)
        return (bb * 1.3 + ll).sum()

    def f_ref(e, g, w, b):
        bb, ll = ref.rnnt_joint_ref(e, g, w, b, lbl)
        return (bb * 1.3 + ll).sum()

    gk = jax.grad(f_kernel, argnums=(0, 1, 2, 3))(e, g, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2, 3))(e, g, w, b)
    for a, bgrad in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bgrad), atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,th", [(4, 512, 256), (1, 128, 128), (8, 1024, 512)])
def test_lstm_gates_sweep(B, H, th, dtype):
    gates = _rand((B, 4 * H), dtype)
    c = _rand((B, H), jnp.float32)
    h1, c1 = lstm_gates_fused(gates, c, th=th, interpret=True)
    h2, c2 = ref.lstm_gates_ref(gates, c)
    np.testing.assert_allclose(np.asarray(h1, np.float32), np.asarray(h2, np.float32),
                               atol=TOL[dtype])
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=TOL[dtype])


@pytest.mark.parametrize("B,H,th", [(4, 512, 256), (1, 128, 128), (3, 256, 256)])
def test_lstm_gates_fused_backward_matches_autodiff(B, H, th):
    """The fused custom-VJP backward == jax.grad through the jnp
    reference cell, for both output cotangents (h feeds the next
    matmul, c_new the next step's state)."""
    from repro.kernels.lstm_gates import lstm_gates_fused_vjp

    gates = _rand((B, 4 * H), jnp.float32)
    c = _rand((B, H), jnp.float32)
    dh = _rand((B, H), jnp.float32)
    dcn = _rand((B, H), jnp.float32)

    def scalar(fn):
        def f(g_, c_):
            h, cn = fn(g_, c_)
            return (h * dh).sum() + (cn * dcn).sum()
        return f

    gk = jax.grad(scalar(lambda g_, c_: lstm_gates_fused_vjp(
        g_, c_, th=th, interpret=True)), argnums=(0, 1))(gates, c)
    gr = jax.grad(scalar(ref.lstm_gates_ref), argnums=(0, 1))(gates, c)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_lstm_gates_fused_vjp_forward_matches_ref():
    gates = _rand((2, 4 * 256), jnp.float32)
    c = _rand((2, 256), jnp.float32)
    from repro.kernels.lstm_gates import lstm_gates_fused_vjp

    h1, c1 = lstm_gates_fused_vjp(gates, c, th=256, interpret=True)
    h2, c2 = ref.lstm_gates_ref(gates, c)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-6)


def test_model_lstm_dispatch_matches_ref_path():
    """models.lstm routes through the fused-VJP kernel on TPU and the
    jnp reference on CPU; the tile picker must only offer shapes the
    kernel accepts."""
    from repro.models.lstm import _fused_tile, _lstm_gates_dispatch, lstm_gates

    assert _fused_tile(256) == 256
    assert _fused_tile(128) == 128
    assert _fused_tile(384) == 128
    assert _fused_tile(100) is None
    gates = _rand((2, 4 * 96), jnp.float32)
    c = _rand((2, 96), jnp.float32)
    h1, c1 = _lstm_gates_dispatch(gates, c)       # CPU: the jnp path
    h2, c2 = lstm_gates(gates, c)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_blockwise_attention_matches_kernel_oracle():
    """Chain of custody: models' jnp blockwise == kernels' oracle."""
    from repro.models.attention import blockwise_attention

    q = _rand((2, 64, 8, 16), jnp.float32)
    k = _rand((2, 64, 2, 16), jnp.float32)
    v = _rand((2, 64, 2, 16), jnp.float32)
    o1 = blockwise_attention(q, k, v, causal=True, window=24, block_kv=16)
    o2 = ref.attention_ref(q, k, v, causal=True, window=24)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)
