"""Pallas kernels vs. jnp oracles: shape/dtype sweeps, interpret=True."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import flash_decode
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lstm_gates import lstm_gates_fused
from repro.kernels.rnnt_joint import rnnt_joint_fused

def _rng():
    return np.random.default_rng(1234)


def _rand(shape, dtype, rng=None):
    x = (rng or np.random.default_rng(abs(hash(shape)) % 2**31)).normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Sk,H,Kv,D,causal,window",
    [
        (2, 128, 128, 4, 2, 32, True, 0),
        (1, 256, 256, 8, 8, 16, True, 64),
        (2, 128, 128, 4, 1, 32, False, 0),
        (1, 512, 512, 2, 2, 64, True, 0),
        (1, 128, 256, 4, 4, 32, False, 0),   # cross-attention shape
    ],
)
def test_flash_attention_sweep(B, Sq, Sk, H, Kv, D, causal, window, dtype):
    q = _rand((B, Sq, H, D), dtype)
    k = _rand((B, Sk, Kv, D), dtype)
    v = _rand((B, Sk, Kv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          tq=64, tk=64, interpret=True)
    expected = ref.attention_ref(q, k, v, causal=causal,
                                 window=window if window else None)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,Kv,D,window,pos",
    [
        (2, 512, 8, 2, 32, 0, 173),
        (1, 1024, 4, 4, 16, 128, 900),
        (3, 256, 2, 1, 64, 0, 0),
        (1, 2048, 8, 8, 32, 0, 2047),
    ],
)
def test_flash_decode_sweep(B, S, H, Kv, D, window, pos, dtype):
    q = _rand((B, H, D), dtype)
    kc = _rand((B, S, Kv, D), dtype)
    vc = _rand((B, S, Kv, D), dtype)
    out = flash_decode(q, kc, vc, jnp.asarray(pos, jnp.int32),
                       window=window, ts=128, interpret=True)
    expected = ref.decode_attention_ref(q, kc, vc, pos,
                                        window=window if window else None)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize(
    "B,T,U1,J,V,tq,tu,tv",
    [
        (2, 32, 16, 24, 64, 16, 8, 32),
        (1, 16, 8, 16, 128, 8, 4, 64),
        (2, 24, 12, 8, 48, 8, 4, 16),
        (1, 64, 8, 32, 256, 16, 8, 128),
    ],
)
def test_rnnt_joint_sweep(B, T, U1, J, V, tq, tu, tv):
    e = _rand((B, T, J), jnp.float32)
    g = _rand((B, U1, J), jnp.float32)
    w = _rand((J, V), jnp.float32) * 0.3
    b = _rand((V,), jnp.float32) * 0.1
    lbl = jnp.asarray(np.random.default_rng(7).integers(0, V, (B, U1)), jnp.int32)
    blank, label = rnnt_joint_fused(e, g, w, b, lbl, tq=tq, tu=tu, tv=tv,
                                    interpret=True)
    blank_ref, label_ref = ref.rnnt_joint_ref(e, g, w, b, lbl)
    np.testing.assert_allclose(np.asarray(blank), np.asarray(blank_ref), atol=3e-5)
    np.testing.assert_allclose(np.asarray(label), np.asarray(label_ref), atol=3e-5)


def test_rnnt_joint_custom_vjp_matches_ref_grad():
    from repro.kernels.ops import rnnt_joint

    B, T, U1, J, V = 2, 16, 8, 12, 32
    e = _rand((B, T, J), jnp.float32)
    g = _rand((B, U1, J), jnp.float32)
    w = _rand((J, V), jnp.float32) * 0.3
    b = _rand((V,), jnp.float32) * 0.1
    lbl = jnp.asarray(np.random.default_rng(7).integers(0, V, (B, U1)), jnp.int32)

    def f_kernel(e, g, w, b):
        bb, ll = rnnt_joint(e, g, w, b, lbl)
        return (bb * 1.3 + ll).sum()

    def f_ref(e, g, w, b):
        bb, ll = ref.rnnt_joint_ref(e, g, w, b, lbl)
        return (bb * 1.3 + ll).sum()

    gk = jax.grad(f_kernel, argnums=(0, 1, 2, 3))(e, g, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2, 3))(e, g, w, b)
    for a, bgrad in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bgrad), atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,th", [(4, 512, 256), (1, 128, 128), (8, 1024, 512)])
def test_lstm_gates_sweep(B, H, th, dtype):
    gates = _rand((B, 4 * H), dtype)
    c = _rand((B, H), jnp.float32)
    h1, c1 = lstm_gates_fused(gates, c, th=th, interpret=True)
    h2, c2 = ref.lstm_gates_ref(gates, c)
    np.testing.assert_allclose(np.asarray(h1, np.float32), np.asarray(h2, np.float32),
                               atol=TOL[dtype])
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=TOL[dtype])


@pytest.mark.parametrize("B,H,th", [(4, 512, 256), (1, 128, 128), (3, 256, 256)])
def test_lstm_gates_fused_backward_matches_autodiff(B, H, th):
    """The fused custom-VJP backward == jax.grad through the jnp
    reference cell, for both output cotangents (h feeds the next
    matmul, c_new the next step's state)."""
    from repro.kernels.lstm_gates import lstm_gates_fused_vjp

    gates = _rand((B, 4 * H), jnp.float32)
    c = _rand((B, H), jnp.float32)
    dh = _rand((B, H), jnp.float32)
    dcn = _rand((B, H), jnp.float32)

    def scalar(fn):
        def f(g_, c_):
            h, cn = fn(g_, c_)
            return (h * dh).sum() + (cn * dcn).sum()
        return f

    gk = jax.grad(scalar(lambda g_, c_: lstm_gates_fused_vjp(
        g_, c_, th=th, interpret=True)), argnums=(0, 1))(gates, c)
    gr = jax.grad(scalar(ref.lstm_gates_ref), argnums=(0, 1))(gates, c)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_lstm_gates_fused_vjp_forward_matches_ref():
    gates = _rand((2, 4 * 256), jnp.float32)
    c = _rand((2, 256), jnp.float32)
    from repro.kernels.lstm_gates import lstm_gates_fused_vjp

    h1, c1 = lstm_gates_fused_vjp(gates, c, th=256, interpret=True)
    h2, c2 = ref.lstm_gates_ref(gates, c)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-6)


def test_model_lstm_dispatch_matches_ref_path():
    """models.lstm routes through the fused-VJP kernel on TPU and the
    jnp reference on CPU; the tile picker must only offer shapes the
    kernel accepts."""
    from repro.models.lstm import _fused_tile, _lstm_gates_dispatch, lstm_gates

    assert _fused_tile(256) == 256
    assert _fused_tile(128) == 128
    assert _fused_tile(384) == 128
    assert _fused_tile(100) is None
    gates = _rand((2, 4 * 96), jnp.float32)
    c = _rand((2, 96), jnp.float32)
    h1, c1 = _lstm_gates_dispatch(gates, c)       # CPU: the jnp path
    h2, c2 = lstm_gates(gates, c)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_blockwise_attention_matches_kernel_oracle():
    """Chain of custody: models' jnp blockwise == kernels' oracle."""
    from repro.models.attention import blockwise_attention

    q = _rand((2, 64, 8, 16), jnp.float32)
    k = _rand((2, 64, 2, 16), jnp.float32)
    v = _rand((2, 64, 2, 16), jnp.float32)
    o1 = blockwise_attention(q, k, v, causal=True, window=24, block_kv=16)
    o2 = ref.attention_ref(q, k, v, causal=True, window=24)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


# ------------------------------------------------- full LSTM scan (PR 10)


def _lstm_scan_ref(xg, w_hh, h0, c0):
    """lax.scan over the jnp gate math — what the kernel must match.
    xg: (S, B, 4H) hoisted input projections."""
    from repro.models.lstm import lstm_gates

    def step(carry, xg_t):
        h, c = carry
        gates = xg_t + h @ w_hh.astype(xg_t.dtype)
        h, c = lstm_gates(gates, c)
        return (h, c), h

    (h, c), ys = jax.lax.scan(step, (h0, c0), xg)
    return ys, h, c


def _lstm_scan_case(S, B, H, seed=0):
    rng = np.random.default_rng(seed)
    xg = jnp.asarray(rng.normal(size=(S, B, 4 * H)) * 0.5, jnp.float32)
    w_hh = jnp.asarray(rng.normal(size=(H, 4 * H)) * 0.3, jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(B, H)) * 0.1, jnp.float32)
    c0 = jnp.asarray(rng.normal(size=(B, H)) * 0.1, jnp.float32)
    return xg, w_hh, h0, c0


@pytest.mark.parametrize("S,B,H", [(1, 2, 8), (5, 3, 8), (12, 2, 16),
                                   (32, 1, 8)])
def test_lstm_scan_kernel_matches_scan(S, B, H):
    from repro.kernels.lstm_gates import lstm_scan_fused

    xg, w_hh, h0, c0 = _lstm_scan_case(S, B, H, seed=S)
    ys, cs = lstm_scan_fused(xg, w_hh, h0, c0, interpret=True)
    ys_r, hT_r, cT_r = _lstm_scan_ref(xg, w_hh, h0, c0)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(ys_r), atol=2e-6)
    np.testing.assert_allclose(np.asarray(cs[-1]), np.asarray(cT_r), atol=2e-6)


@pytest.mark.parametrize("S,B,H", [(1, 2, 8), (5, 3, 8), (12, 2, 16)])
def test_lstm_scan_vjp_matches_scan_grads(S, B, H):
    """The reversed-scan backward kernel: gradients wrt ALL inputs match
    autodiff through lax.scan (the kernel recomputes gates in VMEM; the
    reference rematerializes via XLA)."""
    from repro.kernels.lstm_gates import lstm_scan_fused_vjp

    xg, w_hh, h0, c0 = _lstm_scan_case(S, B, H, seed=100 + S)
    wy = jnp.asarray(np.random.default_rng(5).normal(size=(S, B, H)),
                     jnp.float32)

    def f_kernel(xg, w_hh, h0, c0):
        ys, hT, cT = lstm_scan_fused_vjp(xg, w_hh, h0, c0, interpret=True)
        return (ys * wy).sum() + 1.7 * hT.sum() + 0.9 * cT.sum()

    def f_ref(xg, w_hh, h0, c0):
        ys, hT, cT = _lstm_scan_ref(xg, w_hh, h0, c0)
        return (ys * wy).sum() + 1.7 * hT.sum() + 0.9 * cT.sum()

    gk = jax.grad(f_kernel, argnums=(0, 1, 2, 3))(xg, w_hh, h0, c0)
    gr = jax.grad(f_ref, argnums=(0, 1, 2, 3))(xg, w_hh, h0, c0)
    for name, a, r in zip(("dxg", "dw_hh", "dh0", "dc0"), gk, gr):
        denom = float(jnp.abs(r).max()) + 1e-30
        np.testing.assert_allclose(np.asarray(a) / denom,
                                   np.asarray(r) / denom,
                                   atol=1e-5, err_msg=name)


def test_lstm_layer_scan_dispatch_parity():
    """models.lstm.lstm_layer under the forced-Pallas tuner knob equals
    the lax.scan path — outputs and grads through a full layer (w_ih,
    w_hh, b all differentiated)."""
    from repro.models.lstm import lstm_cell_init, lstm_layer
    from repro.profile import tuner

    B, S, D, H = 2, 16, 12, 128
    p = lstm_cell_init(jax.random.PRNGKey(0), D, H)
    xs = jnp.asarray(np.random.default_rng(2).normal(size=(B, S, D)),
                     jnp.float32)

    def loss(p, xs):
        ys, (h, c) = lstm_layer(p, xs)
        return (ys ** 2).sum() + h.sum() + c.sum()

    reg = tuner.TuningRegistry(path="/tmp/test_lstm_dispatch_tuning.json")
    tuner.set_registry(reg)
    try:
        reg.set_override("lstm.scan_dispatch", "ref")
        l_ref, g_ref = jax.value_and_grad(loss)(p, xs)
        reg.set_override("lstm.scan_dispatch", "pallas")
        l_k, g_k = jax.value_and_grad(loss)(p, xs)
    finally:
        tuner.set_registry(None)
    np.testing.assert_allclose(float(l_k), float(l_ref), rtol=1e-6)
    for k in g_ref:
        np.testing.assert_allclose(np.asarray(g_k[k]), np.asarray(g_ref[k]),
                                   rtol=2e-5, atol=2e-5, err_msg=k)


# ----------------------------------------- fused RNN-T joint bwd (PR 10)


def _joint_case(B, T, U1, J, V, seed=0):
    rng = np.random.default_rng(seed)
    e = jnp.asarray(rng.standard_normal((B, T, J)), jnp.float32)
    g = jnp.asarray(rng.standard_normal((B, U1, J)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((J, V)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((V,)) * 0.1, jnp.float32)
    lbl = jnp.asarray(rng.integers(0, V, (B, U1)), jnp.int32)
    return e, g, w, b, lbl


def test_rnnt_joint_forward_lse_output():
    e, g, w, b, lbl = _joint_case(2, 32, 16, 24, 64, seed=3)
    _, _, lse = rnnt_joint_fused(e, g, w, b, lbl, tq=16, tu=8, tv=32,
                                 interpret=True, return_lse=True)
    h = jnp.tanh(e[:, :, None, :] + g[:, None, :, :])
    lse_ref = jax.nn.logsumexp(h @ w + b, axis=-1)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref),
                               atol=3e-5)


def test_joint_ref_chunked_multichunk_matches_dense():
    """Regression: with more than one U-chunk the chunked reference used
    to flatten (chunks, T, c) in the wrong axis order, scrambling the U
    axis of both the forward and (through jax.vjp) the backward."""
    from repro.kernels.ops import _joint_ref_chunked

    e, g, w, b, lbl = _joint_case(2, 16, 24, 12, 48, seed=5)
    cb, cl = _joint_ref_chunked(e, g, w, b, lbl, u_chunk=8)
    rb, rl = ref.rnnt_joint_ref(e, g, w, b, lbl)
    np.testing.assert_allclose(np.asarray(cb), np.asarray(rb), atol=3e-5)
    np.testing.assert_allclose(np.asarray(cl), np.asarray(rl), atol=3e-5)


@pytest.mark.parametrize(
    "B,T,U1,J,V,tq,tu,tv",
    [
        (2, 32, 16, 24, 64, 16, 8, 32),   # multi u-tile, multi v-slab
        (1, 16, 8, 16, 128, 8, 4, 64),
        (2, 24, 12, 8, 48, 8, 4, 16),
        (1, 64, 8, 32, 256, 16, 8, 128),
    ],
)
def test_rnnt_joint_bwd_fused_matches_chunked_ref(B, T, U1, J, V, tq, tu, tv):
    """The two backward kernels (dh/de/dg with vocab innermost, dW/db
    with vocab outermost) against autodiff through the chunked jnp
    joint, on the forward's own saved lse."""
    from repro.kernels.ops import _joint_ref_chunked
    from repro.kernels.rnnt_joint import rnnt_joint_bwd_fused

    e, g, w, b, lbl = _joint_case(B, T, U1, J, V, seed=B * T)
    rng = np.random.default_rng(9)
    dbl = jnp.asarray(rng.standard_normal((B, T, U1)), jnp.float32)
    dlb = jnp.asarray(rng.standard_normal((B, T, U1)), jnp.float32)
    _, _, lse = rnnt_joint_fused(e, g, w, b, lbl, tq=tq, tu=tu, tv=tv,
                                 interpret=True, return_lse=True)
    de, dg, dw, db = rnnt_joint_bwd_fused(e, g, w, b, lbl, lse, dbl, dlb,
                                          tq=tq, tu=tu, tv=tv, interpret=True)
    _, vjp = jax.vjp(lambda e_, g_, w_, b_: _joint_ref_chunked(e_, g_, w_, b_, lbl),
                     e, g, w, b)
    for name, a, r in zip(("de", "dg", "dw", "db"), (de, dg, dw, db),
                          vjp((dbl, dlb))):
        denom = float(jnp.abs(r).max()) + 1e-30
        np.testing.assert_allclose(np.asarray(a) / denom,
                                   np.asarray(r) / denom,
                                   atol=5e-5, err_msg=name)


def test_rnnt_joint_custom_vjp_pallas_dispatch_multichunk():
    """End-to-end: ops.rnnt_joint with the joint-backward knob forced to
    the Pallas kernels matches plain-jnp reference grads — on a
    multi-chunk U1 so the dispatch covers the shape class the chunked
    path buckets."""
    from repro.kernels.ops import rnnt_joint
    from repro.profile import tuner

    e, g, w, b, lbl = _joint_case(2, 32, 24, 16, 64, seed=11)

    def f(fn):
        def loss(e, g, w, b):
            bb, ll = fn(e, g, w, b, lbl)
            return (bb * 1.3 + ll).sum()
        return jax.grad(loss, argnums=(0, 1, 2, 3))(e, g, w, b)

    reg = tuner.TuningRegistry(path="/tmp/test_joint_dispatch_tuning.json")
    tuner.set_registry(reg)
    try:
        reg.set_override("rnnt.joint_bwd_dispatch", "pallas")
        gk = f(rnnt_joint)
    finally:
        tuner.set_registry(None)
    gr = f(ref.rnnt_joint_ref)
    for name, a, r in zip(("de", "dg", "dw", "db"), gk, gr):
        denom = float(jnp.abs(r).max()) + 1e-30
        np.testing.assert_allclose(np.asarray(a) / denom,
                                   np.asarray(r) / denom,
                                   atol=1e-4, err_msg=name)
