"""Per-client evaluation plane: panel batches, fairness spread, curves.

``repro.data.per_client_eval_batch`` must hand the plane the SAME
utterances every round (first-n per client, weight-0 padded), and
``repro.core.clienteval`` must reduce the panel to the summary
schema's fairness fields for every task metric family.
"""
import jax
import numpy as np
import pytest

from repro.core import SUMMARY_KEYS, get_task
from repro.core.clienteval import (
    SPREAD_KEYS,
    ClientEvalPlane,
    default_panel,
    empty_spread,
    fairness_spread,
)
from repro.data import VirtualPopulation, make_speaker_corpus, per_client_eval_batch


@pytest.fixture(scope="module")
def corpus():
    return make_speaker_corpus(num_speakers=8, vocab_size=64, feat_dim=16,
                               mean_utterances=6.0, seed=0)


# ------------------------------------------------ per_client_eval_batch

def test_eval_batch_layout_and_determinism(corpus):
    ids = np.array([0, 3, 7])
    b = per_client_eval_batch(corpus, ids, n=2)
    assert b["features"].shape[:2] == (3, 2)
    assert b["labels"].shape[:2] == (3, 2)
    assert b["weight"].shape == (3, 2)
    assert b["frame_len"].shape == (3, 2)
    # fixed panel: the same utterances on every call
    b2 = per_client_eval_batch(corpus, ids, n=2)
    np.testing.assert_array_equal(b["features"], b2["features"])
    # first-n: client 0's row 0 is its arena example 0
    np.testing.assert_array_equal(b["features"][0, 0],
                                  corpus.arena_features[0, 0])


def test_eval_batch_pads_short_clients(corpus):
    n = int(corpus.counts.max()) + 3
    b = per_client_eval_batch(corpus, np.arange(corpus.num_speakers), n=n)
    counts = np.asarray(corpus.counts)
    expect = (np.arange(n)[None, :] < counts[:, None]).astype(np.float32)
    np.testing.assert_array_equal(b["weight"], expect)
    pad = b["weight"] == 0.0
    assert pad.any()
    assert (b["frame_len"][pad] == 0).all()
    assert (b["features"][pad] == 0.0).all()


def test_eval_batch_virtual_clients_use_base_speaker(corpus):
    pop = VirtualPopulation(corpus, 1_000_000)
    P = corpus.num_speakers
    v = np.array([5, 5 + P, 5 + 7 * P])   # three clones of speaker 5
    b = per_client_eval_batch(pop, v, n=2)
    base = per_client_eval_batch(corpus, np.array([5]), n=2)
    for k in b:
        for c in range(3):
            np.testing.assert_array_equal(b[k][c], base[k][0])


def test_default_panel_is_deterministic_and_spans(corpus):
    panel = default_panel(corpus, 4)
    np.testing.assert_array_equal(panel, default_panel(corpus, 4))
    assert panel[0] == 0 and panel[-1] == corpus.num_speakers - 1
    # clipped to the population, deduped
    assert len(default_panel(corpus, 100)) == corpus.num_speakers
    pop = VirtualPopulation(corpus, 10_000)
    big = default_panel(pop, 5)
    assert big[-1] == 9_999 and len(big) == 5


# ------------------------------------------------------ fairness spread

def test_fairness_spread_fields():
    spread = fairness_spread(np.linspace(1.0, 2.0, 10), np.full(10, 0.25))
    assert set(spread) == set(SPREAD_KEYS) <= set(SUMMARY_KEYS)
    assert spread["clients_tracked"] == 10
    assert spread["client_loss_p10"] < spread["client_loss_p90"]
    assert spread["client_loss_gap"] == pytest.approx(
        spread["client_loss_p90"] - spread["client_loss_p10"])
    assert spread["client_quality_gap"] == 0.0


def test_empty_spread_matches_schema():
    spread = empty_spread()
    assert set(spread) == set(SPREAD_KEYS)
    assert spread["clients_tracked"] == 0


# -------------------------------------------------------- the plane

@pytest.mark.parametrize("name", ["lm-transformer", "keyword"])
def test_plane_measures_per_round(corpus, name):
    task = get_task(name)
    params = task.bundle.init(jax.random.PRNGKey(0))
    plane = ClientEvalPlane(task, corpus, clients=4, n=2)
    assert plane.spread() == empty_spread()
    for _ in range(3):
        rec = plane.measure(params)
        assert rec["client_loss"].shape == rec["client_quality"].shape
        assert np.isfinite(rec["client_loss"]).all()
        assert np.isfinite(rec["client_quality"]).all()
    spread = plane.spread()
    assert spread["clients_tracked"] == len(plane.client_ids)
    assert all(np.isfinite(spread[k]) for k in SPREAD_KEYS)
    curves = plane.curves()
    assert curves["quality_metric"] == task.quality_metric
    assert np.asarray(curves["client_loss"]).shape == (3, len(plane.client_ids))
    assert np.asarray(curves["client_quality"]).shape == (3, len(plane.client_ids))


def test_plane_wer_quality_is_per_client(corpus):
    """The ASR hook decodes the flattened panel and scores per client."""
    task = get_task("asr-rnnt")
    params = task.bundle.init(jax.random.PRNGKey(0))
    plane = ClientEvalPlane(task, corpus, clients=3, n=2)
    rec = plane.measure(params)
    assert rec["client_quality"].shape == (3,)
    assert (rec["client_quality"] >= 0.0).all()
