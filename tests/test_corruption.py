"""Adversarial client-corruption plane: registry semantics, the
corruption x cohort x error-feedback composition invariants, traced
rate/scale compile sharing, and the data-plane label_shuffle knob."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AggregatorConfig,
    CohortConfig,
    CompressionConfig,
    CorruptionConfig,
    FederatedPlan,
    available_corruptions,
    get_corruption,
    init_server_state,
    make_hyper_round_step,
    make_round_step,
    plan_hypers,
)
from repro.core.corruption import DELTA_KINDS, KINDS, make_corruption_fn

W_TRUE = np.random.default_rng(42).normal(size=(4, 2)).astype(np.float32)


def loss_fn(params, batch, rng):
    pred = batch["x"] @ params["w"]
    w = batch["weight"]
    l = jnp.sum((pred - batch["y"]) ** 2 * w[:, None]) / jnp.maximum(w.sum(), 1)
    return l, {}


def make_batch(K, S, b, seed=0, weights=None):
    r = np.random.default_rng(seed)
    x = r.normal(size=(K, S, b, 4)).astype(np.float32)
    y = x @ W_TRUE
    w = np.ones((K, S, b), np.float32) if weights is None else weights
    return {"x": jnp.array(x), "y": jnp.array(y), "weight": jnp.array(w)}


def params0():
    return {"w": jnp.zeros((4, 2))}


BASE = dict(clients_per_round=4, client_lr=0.1, server_optimizer="sgd",
            server_lr=1.0)


def run_one(corruption=None, plan_kw=None, seed=0, key=0, state=None,
            rounds=1):
    plan = FederatedPlan(**dict(BASE, **(plan_kw or {})),
                         corruption=corruption or CorruptionConfig())
    step = jax.jit(make_round_step(loss_fn, plan, jax.random.PRNGKey(key)))
    state = state if state is not None else init_server_state(plan, params0())
    for r in range(rounds):
        state, m = step(state, make_batch(4, 2, 4, seed=seed + r))
    return state, m


# ------------------------------------------------------------ registry

def test_registry_contents():
    assert set(DELTA_KINDS) == {"sign_flip", "gaussian", "zero", "stale"}
    assert set(available_corruptions()) == set(DELTA_KINDS)
    assert "label_shuffle" in KINDS and "none" in KINDS
    with pytest.raises(KeyError, match="unknown corruption"):
        get_corruption("krum")


def test_config_validation():
    with pytest.raises(ValueError, match="unknown corruption kind"):
        CorruptionConfig(kind="bitrot")
    with pytest.raises(ValueError, match="rate"):
        CorruptionConfig(kind="zero", rate=1.5)
    assert not CorruptionConfig().active
    assert CorruptionConfig(kind="zero", rate=0.1).active
    assert CorruptionConfig(kind="sign_flip", rate=0.1).in_graph
    assert not CorruptionConfig(kind="label_shuffle", rate=0.1).in_graph


def test_fedsgd_rejects_delta_corruptions():
    plan = FederatedPlan(engine="fedsgd",
                        corruption=CorruptionConfig(kind="sign_flip", rate=0.1))
    with pytest.raises(ValueError, match="fedsgd"):
        make_round_step(loss_fn, plan, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="fedsgd"):
        make_hyper_round_step(loss_fn, "fedsgd", "adam", corruption="zero")
    # the data-plane adversary composes with either engine
    make_hyper_round_step(loss_fn, "fedsgd", "adam", corruption="label_shuffle")


def test_stale_without_cache_raises():
    fn = make_corruption_fn("stale", 1.0, 1.0)
    deltas = {"w": jnp.ones((3, 2))}
    with pytest.raises(ValueError, match="ServerState"):
        fn(jax.random.PRNGKey(0), deltas, jnp.ones((3,)), None)


# ------------------------------------------------- adversary semantics

def test_rate_zero_is_bit_exact_parity():
    """An armed adversary at rate 0 must equal the honest plane exactly
    (the clean row of a robustness grid is the paper's run)."""
    s_honest, m_honest = run_one()
    for kind in DELTA_KINDS:
        s, m = run_one(CorruptionConfig(kind=kind, rate=0.0, scale=3.0))
        np.testing.assert_array_equal(np.asarray(s_honest.params["w"]),
                                      np.asarray(s.params["w"]))
        assert float(m["corrupted"]) == 0.0


def test_sign_flip_negates_the_update():
    s_honest, _ = run_one()
    s_bad, m = run_one(CorruptionConfig(kind="sign_flip", rate=1.0, scale=1.0))
    assert float(m["corrupted"]) == 4.0
    np.testing.assert_allclose(np.asarray(s_bad.params["w"]),
                               -np.asarray(s_honest.params["w"]), atol=1e-7)


def test_zero_update_freezes_the_server():
    s, m = run_one(CorruptionConfig(kind="zero", rate=1.0))
    np.testing.assert_array_equal(np.asarray(s.params["w"]),
                                  np.asarray(params0()["w"]))
    assert float(m["corrupted"]) == 4.0


def test_gaussian_noise_tracks_delta_scale():
    """Noise rides at scale x rms(delta): honest direction survives at
    tiny scale, drowns at huge scale."""
    s_honest, _ = run_one()
    s_small, _ = run_one(CorruptionConfig(kind="gaussian", rate=1.0, scale=1e-3))
    s_big, _ = run_one(CorruptionConfig(kind="gaussian", rate=1.0, scale=1e3))
    honest = np.asarray(s_honest.params["w"])
    small = np.linalg.norm(np.asarray(s_small.params["w"]) - honest)
    big = np.linalg.norm(np.asarray(s_big.params["w"]) - honest)
    assert small < 1e-3 * np.linalg.norm(honest) * 10
    assert big > 1e2 * np.linalg.norm(honest)


def test_stale_replays_last_transmission():
    """Round 0 an all-stale cohort sends the zero cache (server frozen);
    round 1 it replays round 0's honest deltas — two corrupted rounds
    land where ONE honest round would have."""
    cfg = CorruptionConfig(kind="stale", rate=1.0, scale=1.0)
    s_stale, m = run_one(cfg, rounds=2)
    assert s_stale.stale is not None
    s_honest, _ = run_one()                      # one honest round, same data
    np.testing.assert_allclose(np.asarray(s_stale.params["w"]),
                               np.asarray(s_honest.params["w"]), atol=1e-6)


def test_corruption_never_changes_wire_bytes():
    """A corrupted participant still pays full uplink: CFMQ accounting
    is identical under any adversary (the grid moves quality only)."""
    _, m_honest = run_one()
    for kind in DELTA_KINDS:
        _, m = run_one(CorruptionConfig(kind=kind, rate=1.0))
        assert float(m["uplink_bytes"]) == float(m_honest["uplink_bytes"])
        assert float(m["downlink_bytes"]) == float(m_honest["downlink_bytes"])
        assert float(m["participants"]) == float(m_honest["participants"])


# -------------------------------------- composition: cohort x EF x adv

def test_corrupted_nonparticipant_contributes_nothing():
    """Regression (cohort x corruption x error_feedback): a client that
    is both corrupted and a cohort non-participant must contribute
    neither delta nor EF residual update — dropout always wins."""
    from repro.core.cohort import participation_mask
    from repro.core.fedavg import _plane_keys

    base_key = jax.random.PRNGKey(3)
    plan_kw = dict(cohort=CohortConfig(participation=0.5),
                   compression=CompressionConfig(kind="topk", topk_frac=0.2,
                                                 error_feedback=True))
    cfg = CorruptionConfig(kind="sign_flip", rate=1.0, scale=5.0)
    plan = FederatedPlan(**dict(BASE, **plan_kw), corruption=cfg)
    state = init_server_state(plan, params0())
    marker = jax.tree.map(lambda e: jnp.full_like(e, 0.125), state.ef)
    state = state._replace(ef=marker)
    step = jax.jit(make_round_step(loss_fn, plan, base_key))
    state2, m = step(state, make_batch(4, 2, 4, seed=7))

    ckey, _, _, _ = _plane_keys(base_key, jnp.zeros((), jnp.int32))
    pmask = np.asarray(participation_mask(jax.random.fold_in(ckey, 0), 4,
                                          plan.cohort.participation))
    assert 0 < pmask.sum() < 4                      # the draw actually split
    # every corrupted client is a participant: cmask = drawn * pmask
    assert float(m["corrupted"]) == float(pmask.sum())
    ef = np.asarray(state2.ef["w"])
    for k in range(4):
        if pmask[k]:
            assert np.abs(ef[k] - 0.125).max() > 1e-9
        else:
            np.testing.assert_array_equal(ef[k], np.full((4, 2), 0.125))


def test_corrupted_dropped_client_delta_is_not_resurrected():
    """sign_flip at rate 1 with a partial cohort must equal sign_flip
    applied to the participants only: a dropped client's zero delta
    stays zero (flipping 0 is 0, but a stale/gaussian adversary could
    re-inject mass — the cmask*pmask select is what prevents it)."""
    plan_kw = dict(cohort=CohortConfig(participation=0.5))
    # stale with a warm cache is the dangerous kind: round 2's replay
    # would hand every client (participant or not) a nonzero delta
    cfg = CorruptionConfig(kind="stale", rate=1.0, scale=1.0)
    plan = FederatedPlan(**dict(BASE, **plan_kw), corruption=cfg)
    step = jax.jit(make_round_step(loss_fn, plan, jax.random.PRNGKey(5)))
    state = init_server_state(plan, params0())
    for r in range(3):
        state, m = step(state, make_batch(4, 2, 4, seed=30 + r))
        assert float(m["corrupted"]) <= float(m["participants"])
        # stale cache rows of non-participants never update; all rows
        # stay finite
        assert np.isfinite(np.asarray(state.stale["w"])).all()


def test_hyper_path_matches_plan_path_under_attack():
    plan = FederatedPlan(
        clients_per_round=4, client_lr=0.1, server_optimizer="adam",
        server_lr=0.05,
        cohort=CohortConfig(participation=0.6),
        aggregation=AggregatorConfig(name="trimmed_mean", trim_frac=0.2),
        corruption=CorruptionConfig(kind="sign_flip", rate=0.5, scale=2.0))
    key = jax.random.PRNGKey(11)
    plain = jax.jit(make_round_step(loss_fn, plan, key))
    hyper = jax.jit(make_hyper_round_step(loss_fn, "fedavg", "adam",
                                          "trimmed_mean",
                                          corruption="sign_flip"))
    hypers = plan_hypers(plan)
    s1 = s2 = init_server_state(plan, params0())
    for r in range(3):
        batch = make_batch(4, 2, 4, seed=20 + r)
        s1, m1 = plain(s1, batch)
        s2, m2 = hyper(s2, batch, hypers, key)
    np.testing.assert_allclose(np.asarray(s1.params["w"]),
                               np.asarray(s2.params["w"]), atol=1e-6)
    assert float(m1["corrupted"]) == float(m2["corrupted"])


def test_hyper_shares_compile_across_adversary_rates():
    """rate/scale are traced: a whole adversary-rate grid hits ONE
    compilation per (aggregator, kind) — the acceptance criterion."""
    hyper = jax.jit(make_hyper_round_step(loss_fn, "fedavg", "adam",
                                          corruption="sign_flip"))
    key = jax.random.PRNGKey(0)
    batch = make_batch(4, 2, 4)
    for rate, scale in [(0.0, 1.0), (0.3, 3.0), (1.0, 0.5)]:
        plan = FederatedPlan(
            clients_per_round=4,
            corruption=CorruptionConfig(kind="sign_flip", rate=rate,
                                        scale=scale))
        state = init_server_state(plan, params0())
        hyper(state, batch, plan_hypers(plan), key)
    assert hyper._cache_size() == 1


# --------------------------------------------- data plane: label_shuffle

def _tiny_corpus():
    from repro.data import make_speaker_corpus

    return make_speaker_corpus(num_speakers=8, vocab_size=16, feat_dim=4,
                               mean_utterances=10.0, seed=0)


def test_label_shuffle_helper_permutes_valid_rows_only():
    from repro.data import label_shuffle

    rng = np.random.default_rng(0)
    labels = np.arange(12, dtype=np.int32).reshape(6, 2)
    label_len = np.arange(6, dtype=np.int32)
    valid = np.array([True, True, True, True, False, False])
    before = labels.copy()
    n = label_shuffle(labels, label_len, valid, rng)
    assert n == 4
    # padding rows untouched; valid rows are a permutation, rows intact
    np.testing.assert_array_equal(labels[4:], before[4:])
    assert sorted(map(tuple, labels[:4])) == sorted(map(tuple, before[:4]))
    np.testing.assert_array_equal(labels[:, 0] // 2, label_len)  # rows move together
    # fewer than two valid rows: nothing to permute
    assert label_shuffle(labels, label_len, valid & (label_len == 0), rng) == 0


def test_label_shuffle_rejects_iid_runs():
    """IID rounds bypass the FederatedSampler, so a label_shuffle plan
    would silently never fire — both drivers must refuse instead."""
    from repro.launch.sweeps import SweepPoint, SweepRunner
    from repro.launch.train import run_federated_asr

    plan = FederatedPlan(
        corruption=CorruptionConfig(kind="label_shuffle", rate=0.5))
    with pytest.raises(ValueError, match="label_shuffle"):
        run_federated_asr(None, None, plan, rounds=1, iid=True)
    runner = SweepRunner.__new__(SweepRunner)      # no corpus build needed
    point = SweepPoint(id="bad", plan=plan, rounds=1, iid=True)
    with pytest.raises(ValueError, match="label_shuffle"):
        runner.run_point(point)


def test_sampler_label_shuffle_rate_zero_is_identity():
    from repro.data import FederatedSampler

    corpus = _tiny_corpus()
    clean = FederatedSampler(corpus, 4, 2, seed=3)
    knob = FederatedSampler(corpus, 4, 2, seed=3, label_shuffle_rate=0.0)
    a, b = clean.next_round(), knob.next_round()
    np.testing.assert_array_equal(a.labels, b.labels)
    np.testing.assert_array_equal(a.features, b.features)
    assert knob.corrupted_counts == []


def test_sampler_label_shuffle_poisons_labels_not_features():
    from repro.data import FederatedSampler

    corpus = _tiny_corpus()
    clean = FederatedSampler(corpus, 4, 2, seed=3)
    bad = FederatedSampler(corpus, 4, 2, seed=3, label_shuffle_rate=1.0)
    a, b = clean.next_round(), bad.next_round()
    np.testing.assert_array_equal(a.features, b.features)
    np.testing.assert_array_equal(a.mask, b.mask)
    assert bad.corrupted_counts == [4]
    K = a.labels.shape[0]
    moved = 0
    for k in range(K):
        la = a.labels[k].reshape(-1, a.labels.shape[-1])
        lb = b.labels[k].reshape(-1, b.labels.shape[-1])
        # same multiset of transcripts per client, possibly reordered
        assert sorted(map(tuple, la)) == sorted(map(tuple, lb))
        moved += int((la != lb).any())
    assert moved >= 2          # shuffling visibly moved most clients' labels


@pytest.mark.slow
def test_robustness_grid_smoke_end_to_end(tmp_path):
    """The CI gate's invariants, in-process: per-row corrupted counts,
    exact wire bytes, one compilation per (aggregator, kind), and the
    trimmed-beats-weighted claim under sign_flip."""
    from repro.launch.sweeps import SweepRunner, check_robustness, run_grid

    runner = SweepRunner(seed=0, eval_examples=24, pad_steps=True)
    frontier = run_grid("robustness", smoke=True, runner=runner,
                        out=str(tmp_path / "robust.json"), log=lambda *a: None)
    check_robustness(frontier, log=lambda *a: None)
    ids = {r["id"] for r in frontier["points"]}
    assert "trimmed_mean_sign_flip_r30" in ids
    # label_shuffle rows report host-side realized counts
    ls = next(r for r in frontier["points"]
              if r["id"] == "weighted_mean_label_shuffle_r30")
    assert ls["corrupted_mean"] > 0
    # ONE compilation per (aggregator, adversary-kind): 2 aggregators x
    # {honest, sign_flip} — label_shuffle rides the honest entry, and
    # every rate of a kind shares its entry's single compilation
    assert len(runner._jit_cache) == 4
    assert all(fn._cache_size() == 1 for fn in runner._jit_cache.values())
