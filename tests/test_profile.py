"""Profiling plane: trace schema round-trip, cost-predictor
calibration, tuner knob registry, wire_pack dispatch wiring and the
sweep-grid pruner's never-drop-pareto contract.

The slow-marked test at the bottom is the acceptance loop itself:
measure the five tiny-RNN-T plans, calibrate, and assert in-sample
predicted-vs-measured round seconds within the documented tolerance.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.profile import predict, trace, tuner


@pytest.fixture()
def tmp_registry(tmp_path):
    """Process-wide registry pointed at a tmp file; restored after."""
    reg = tuner.TuningRegistry(path=str(tmp_path / "tuning.json"))
    tuner.set_registry(reg)
    yield reg
    tuner.set_registry(None)


# ----------------------------------------------------------------------
# Trace schema
# ----------------------------------------------------------------------

def test_trace_write_load_round_trip(tmp_path):
    rec = trace.TraceRecorder()
    with rec.section("pack"):
        pass
    with rec.section("round"):
        pass
    with rec.section("round"):
        pass
    path = str(tmp_path / "trace_round.json")
    trace.write_trace(path, "round", structural_key="fedavg|adam",
                      sections=rec, counters={"rounds": 2},
                      features={"flops": 1.0}, meta={"id": "t"})
    got = trace.load_trace(path)
    assert got["kind"] == "round"
    assert got["structural_key"] == "fedavg|adam"
    assert got["sections"]["round"]["count"] == 2
    assert set(got["sections"]["pack"]) == set(trace.SECTION_STAT_KEYS)
    assert got["counters"]["rounds"] == 2.0
    assert got["device_key"] == trace.device_key()


def test_trace_validate_rejects_bad_records():
    good = trace.trace_record("kernels", kernels={"k": 1.0})
    with pytest.raises(ValueError, match="kind"):
        trace.validate_trace({**good, "kind": "nonsense"})
    with pytest.raises(ValueError, match="schema_version"):
        trace.validate_trace({**good, "schema_version": 999})
    with pytest.raises(ValueError, match="missing keys"):
        trace.validate_trace({k: v for k, v in good.items() if k != "sections"})
    with pytest.raises(ValueError, match="stats must be exactly"):
        trace.validate_trace({**good, "sections": {"round": {"min_s": 0.1}}})


def test_load_traces_skips_invalid(tmp_path):
    trace.write_trace(str(tmp_path / "trace_a.json"), "sweep",
                      sections={}, meta={"id": "a"})
    (tmp_path / "trace_bad.json").write_text("{not json")
    (tmp_path / "trace_wrong.json").write_text(json.dumps({"kind": "sweep"}))
    (tmp_path / "unrelated.json").write_text("{}")
    got = trace.load_traces(str(tmp_path))
    assert [r["meta"]["id"] for r in got] == ["a"]
    assert trace.load_traces(str(tmp_path), kind="round") == []


def test_recorder_stats_and_wrap():
    rec = trace.TraceRecorder()
    calls = []
    fn = rec.wrap("work", lambda x: calls.append(x) or x * 2)
    assert fn(3) == 6
    assert fn(4) == 8
    s = rec.stats()["work"]
    assert s["count"] == 2
    assert s["total_s"] >= s["min_s"] >= 0.0
    assert s["mean_s"] == pytest.approx(s["total_s"] / 2)


def test_measure_interleaved_min_visits_every_fn():
    counts = {"a": 0, "b": 0}

    def mk(name):
        def fn():
            counts[name] += 1
        return fn

    got = trace.measure_interleaved_min({"a": mk("a"), "b": mk("b")},
                                        reps=4, warmup=2)
    assert set(got) == {"a", "b"}
    assert all(v >= 0.0 and np.isfinite(v) for v in got.values())
    assert counts == {"a": 6, "b": 6}       # 2 warmup + 4 timed each


# ----------------------------------------------------------------------
# Calibration
# ----------------------------------------------------------------------

def test_nnls_exact_recovery():
    rng = np.random.default_rng(0)
    x = rng.uniform(0.5, 2.0, size=(12, 5))
    true = np.array([0.3, 0.0, 1.5, 0.2, 0.7])
    got = predict.nnls(x, x @ true)
    np.testing.assert_allclose(got, true, atol=1e-9)


def test_nnls_clamps_negative_directions_to_zero():
    # y decreases with the second column: unconstrained lstsq would go
    # negative, which would flip the pruner's cost ordering
    x = np.array([[1.0, 1.0], [2.0, 2.0], [3.0, 2.9]])
    y = np.array([1.0, 2.0, 3.05])
    got = predict.nnls(x, y)
    assert (got >= 0.0).all()


def test_calibrate_recovers_synthetic_coefficients():
    true = {"flops": 3e-10, "hbm_bytes": 2e-11, "wire_bytes": 1.5e-9,
            "server_steps": 2e-3, "overhead": 4e-3}
    rng = np.random.default_rng(1)
    samples = []
    for _ in range(20):
        f = {"flops": rng.uniform(1e9, 5e9),
             "hbm_bytes": rng.uniform(1e8, 9e8),
             "wire_bytes": rng.uniform(1e6, 4e7),
             "server_steps": rng.uniform(1.0, 3.0),
             "overhead": 1.0}
        samples.append((f, predict.predict_round_seconds(f, true)))
    got = predict.calibrate(samples)
    for k in predict.FEATURE_KEYS:
        # features absent from the samples (ici_bytes: unsharded runs)
        # are a zero column — NNLS must pin their coefficient to 0
        assert got[k] == pytest.approx(true.get(k, 0.0), rel=1e-6), k
    with pytest.raises(ValueError):
        predict.calibrate([])


def test_expected_server_steps():
    from repro.core import AsyncConfig, FederatedPlan

    sync = FederatedPlan(clients_per_round=8, local_batch_size=4)
    assert predict.expected_server_steps(sync) == 1.0
    a = FederatedPlan(clients_per_round=8, local_batch_size=4,
                      engine="async", asynchrony=AsyncConfig(buffer_size=5))
    assert predict.expected_server_steps(a) == pytest.approx(8 / 5)


def _fake_params():
    return {"w": np.zeros((64, 32), np.float32), "b": np.zeros((32,), np.float32)}


def _abstract_fake_params():
    return jax.eval_shape(lambda: jax.tree.map(jnp.asarray, _fake_params()))


def test_features_and_cfmq_identical_on_abstract_params():
    """The predictor's core property: ShapeDtypeStruct trees price
    byte-for-byte like materialized ones — zero-allocation planning."""
    from repro.core import CompressionConfig, FederatedPlan

    plan = FederatedPlan(clients_per_round=8, local_batch_size=4, data_limit=4,
                         compression=CompressionConfig(kind="int4"))
    real, abstract = _fake_params(), _abstract_fake_params()
    f_real = predict.plan_round_features(plan, real, steps=1)
    f_abs = predict.plan_round_features(plan, abstract, steps=1)
    assert f_real == f_abs
    assert (predict.point_cfmq_tb(plan, real, steps=1, rounds=6)
            == predict.point_cfmq_tb(plan, abstract, steps=1, rounds=6))


def test_point_cfmq_matches_sweep_arithmetic():
    """point_cfmq_tb mirrors SweepRunner.run_point term for term."""
    from repro.core import FederatedPlan
    from repro.core.cfmq import cfmq, measured_payload

    plan = FederatedPlan(clients_per_round=8, local_batch_size=4, data_limit=4)
    params = _fake_params()
    n_params = 64 * 32 + 32
    mu = plan.local_epochs * plan.data_limit
    expect = cfmq(rounds=6, clients_per_round=8,
                  model_bytes=n_params * plan.param_bytes,
                  local_steps=mu / plan.local_batch_size, alpha=plan.alpha,
                  payload_bytes=measured_payload(plan, params, 8.0))
    assert predict.point_cfmq_tb(plan, params, steps=1, rounds=6) == \
        expect.total_terabytes


def test_wire_cost_profile():
    from repro.core import CompressionConfig
    from repro.core.compression import client_wire_bytes, wire_cost_profile

    params = _fake_params()
    dense = wire_cost_profile(CompressionConfig(), params)
    assert dense["ratio"] == 1.0
    assert dense["uplink_bytes"] == dense["dense_bytes"] == 4 * (64 * 32 + 32)
    int4 = wire_cost_profile(CompressionConfig(kind="int4"), params)
    assert int4["uplink_bytes"] == client_wire_bytes(
        CompressionConfig(kind="int4"), params)
    assert int4["ratio"] > 6.0        # ~8x minus per-leaf scale overhead
    # abstract trees price identically
    assert wire_cost_profile(CompressionConfig(kind="int4"),
                             _abstract_fake_params()) == int4


# ----------------------------------------------------------------------
# Tuner registry
# ----------------------------------------------------------------------

def test_tuner_defaults_and_unknown_knob(tmp_registry):
    assert tuner.get_knob("wire_pack.topk_seg_min_n") == 4096
    assert tuner.get_knob("wire_pack.dispatch") == "auto"
    with pytest.raises(KeyError, match="unknown tuning knob"):
        tuner.get_knob("nope.missing")
    with pytest.raises(KeyError):
        tmp_registry.set_override("nope.missing", 1)


def test_tuner_override_persist_round_trip(tmp_registry):
    tmp_registry.set_override("wire_pack.topk_seg_min_n", 1024, persist=True)
    tmp_registry.set_coefficients("analytic", {"flops": 1e-10}, persist=True)
    # a fresh registry over the same file sees both, keyed per device
    reloaded = tuner.TuningRegistry(path=tmp_registry.path)
    assert reloaded.get("wire_pack.topk_seg_min_n") == 1024
    assert reloaded.get_coefficients("analytic") == {"flops": 1e-10}
    assert reloaded.get_coefficients("hlo") is None
    reloaded.clear_override("wire_pack.topk_seg_min_n")
    assert reloaded.get("wire_pack.topk_seg_min_n") == 4096
    doc = json.load(open(tmp_registry.path))
    assert trace.device_key() in doc["devices"]


def test_tuner_validation(tmp_registry):
    with pytest.raises(ValueError, match="not in"):
        tmp_registry.set_override("wire_pack.dispatch", "cuda")
    with pytest.raises(ValueError, match="positive"):
        tmp_registry.set_override("bench.fed_reps", 0)
    # numeric strings coerce (CLI path)
    assert tmp_registry.set_override("bench.fed_reps", "7") == 7


def test_tuner_corrupt_file_falls_back_to_defaults(tmp_path):
    path = tmp_path / "tuning.json"
    path.write_text("{broken")
    reg = tuner.TuningRegistry(path=str(path))
    assert reg.get("wire_pack.topk_seg_min_n") == 4096


def test_tuner_env_path(tmp_path, monkeypatch):
    monkeypatch.setenv(tuner.ENV_PATH, str(tmp_path / "env_tuning.json"))
    reg = tuner.TuningRegistry()
    assert reg.path == str(tmp_path / "env_tuning.json")


def test_bench_reps_env_wins_over_knob(tmp_registry, monkeypatch):
    from benchmarks.common import bench_reps

    tmp_registry.set_override("bench.fed_reps", 9)
    assert bench_reps("REPRO_BENCH_FED_REPS", "bench.fed_reps") == 9
    monkeypatch.setenv("REPRO_BENCH_FED_REPS", "2")
    assert bench_reps("REPRO_BENCH_FED_REPS", "bench.fed_reps") == 2


# ----------------------------------------------------------------------
# wire_pack dispatch goes through the tuner
# ----------------------------------------------------------------------

def test_wire_pack_dispatch_modes(tmp_registry):
    from repro.kernels import wire_pack

    codes = jnp.asarray(np.arange(32) % 16 - 8, jnp.int32)  # signed nibbles
    for mode in ("auto", "ref", "pallas"):
        tmp_registry.set_override("wire_pack.dispatch", mode)
        packed = wire_pack.nibble_pack(codes)
        out = wire_pack.nibble_unpack(packed, 32)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))


def test_wire_pack_topk_threshold_knob(tmp_registry):
    """Lowering topk_seg_min_n must flip topk_unpack onto the segmented
    kernel without changing results."""
    from repro.kernels import wire_pack

    n = 96
    vals = jnp.asarray(np.linspace(1.0, 4.0, 8), jnp.float32)
    idx = jnp.asarray(np.arange(0, 64, 8), jnp.int32)
    baseline = np.asarray(wire_pack.topk_unpack(vals, idx, n))
    tmp_registry.set_override("wire_pack.topk_seg_min_n", 16)
    tmp_registry.set_override("wire_pack.topk_seg_size", 32)
    segmented = np.asarray(wire_pack.topk_unpack(vals, idx, n))
    np.testing.assert_array_equal(segmented, baseline)
    dense = np.zeros(n, np.float32)
    dense[np.asarray(idx)] = np.asarray(vals)
    np.testing.assert_array_equal(segmented, dense)


# ----------------------------------------------------------------------
# Pruner
# ----------------------------------------------------------------------

def _rows(pareto_ids, all_ids, cfmq):
    return [{"id": i, "pareto": i in pareto_ids, "cfmq_tb": cfmq[i]}
            for i in all_ids]


def test_prune_report_and_check_pass():
    cfmq = {"a": 1.0, "b": 2.0, "c": 5.0}
    report = tuner.prune_report(cfmq, budget=3.0, axis="cfmq_tb")
    assert [report[i].keep for i in ("a", "b", "c")] == [True, True, False]
    assert report["c"].as_dict()["keep"] is False
    rows = _rows({"a"}, ("a", "b", "c"), cfmq)
    assert tuner.check_prune(rows, report, log=lambda *_: None) == 1


def test_check_prune_rejects_empty_drop_and_pareto_drop():
    cfmq = {"a": 1.0, "b": 2.0}
    nothing = tuner.prune_report(cfmq, budget=10.0, axis="cfmq_tb")
    with pytest.raises(AssertionError, match="dropped nothing"):
        tuner.check_prune(_rows({"a"}, ("a", "b"), cfmq), nothing,
                          log=lambda *_: None)
    report = tuner.prune_report(cfmq, budget=1.5, axis="cfmq_tb")
    with pytest.raises(AssertionError, match="PARETO"):
        tuner.check_prune(_rows({"a", "b"}, ("a", "b"), cfmq), report,
                          log=lambda *_: None)


def test_check_prune_rejects_prediction_drift():
    predicted = {"a": 1.0, "b": 3.0}
    measured = {"a": 1.0, "b": 2.0}        # b predicted 50% high
    report = tuner.prune_report(predicted, budget=2.5, axis="cfmq_tb")
    with pytest.raises(AssertionError, match="rel err"):
        tuner.check_prune(_rows({"a"}, ("a", "b"), measured), report,
                          log=lambda *_: None)


def test_check_prune_flags_missing_decision():
    report = tuner.prune_report({"a": 1.0}, budget=0.5, axis="cfmq_tb")
    with pytest.raises(AssertionError, match="no prune decision"):
        tuner.check_prune([{"id": "ghost", "pareto": False, "cfmq_tb": 1.0}],
                          report, log=lambda *_: None)


def test_compression_grid_prune_budget_drops_only_fp32(tmp_registry):
    """The CI configuration, verified without running anything: at
    budget 1e-4 TB the smoke compression grid loses exactly fp32, and
    every predicted cfmq_tb is exact arithmetic (machine-independent,
    so this asserts the values the sweep would measure)."""
    from repro.launch.sweeps import (SweepRunner, compression_points,
                                     predict_grid_costs)

    runner = SweepRunner(seed=0, eval_examples=24, pad_steps=True)
    points = compression_points(smoke=True)
    predicted = predict_grid_costs(runner, points, axis="cfmq_tb")
    report = tuner.prune_report(predicted, budget=1e-4, axis="cfmq_tb")
    assert {pid for pid, d in report.items() if not d.keep} == {"fp32"}
    assert predicted["fp32"] == pytest.approx(1.1043102720e-4)
    assert predicted["top5"] < predicted["int4"] < predicted["int8"]


# ----------------------------------------------------------------------
# hlo_cost robustness (satellite: malformed HLO degrades, not raises)
# ----------------------------------------------------------------------

def test_hlo_cost_counts_unparsed_ops():
    from repro.launch import hlo_cost

    text = """
HloModule m

ENTRY %main (p: f32[<=128,8]) -> f32[<=128,8] {
  %p = f32[<=128,8] parameter(0)
  %a = f32[<=128,8] add(%p, %p)
  ROOT %t = f32[<=128,8] tanh(%a)
}
"""
    got = hlo_cost.analyze(text)
    assert got["unparsed_ops"] == 3.0


def test_hlo_cost_garbage_degrades_not_raises():
    from repro.launch import hlo_cost

    assert hlo_cost.analyze("complete nonsense, no HLO here")["flops"] == 0.0
    text = """
HloModule m

ENTRY %main (p: f32[8]) -> f32[8] {
  %p = f32[8] parameter(0)
  %bad = f32[8] dot(%p), lhs_contracting_dims=
  ROOT %a = f32[8] add(%p, %p)
}
"""
    got = hlo_cost.analyze(text)
    # the well-formed add is still priced: 8 flops
    assert got["flops"] >= 8.0


def test_hlo_cost_clean_module_has_zero_unparsed():
    from repro.launch import hlo_cost

    text = """
HloModule m

ENTRY %main (p: f32[64,32]) -> f32[64,32] {
  %p = f32[64,32] parameter(0)
  ROOT %a = f32[64,32] add(%p, %p)
}
"""
    got = hlo_cost.analyze(text)
    assert got["unparsed_ops"] == 0.0
    assert got["flops"] == 64 * 32


# ----------------------------------------------------------------------
# Structural key slug
# ----------------------------------------------------------------------

def test_structural_key_str_is_flat_and_deterministic():
    from repro.core import FederatedPlan, build_round_engine
    from repro.core.engine import structural_key_str

    plan = FederatedPlan(clients_per_round=8, local_batch_size=4)
    eng = build_round_engine(plan, lambda p, b, k: (jnp.float32(0.0), {}))
    slug = structural_key_str(eng.structural_key)
    assert slug == structural_key_str(eng.structural_key)
    assert "\n" not in slug and slug.startswith("fedavg|")
    assert "CompressionConfig(kind=none" in slug


# ----------------------------------------------------------------------
# The acceptance loop (slow): measure, calibrate, predict within tol
# ----------------------------------------------------------------------

@pytest.mark.slow
def test_predict_report_within_tolerance(tmp_registry, tmp_path):
    report = predict.predict_report(
        reps=3, trace_path=str(tmp_path / "trace_predict.json"),
        log=lambda *_: None)
    assert set(r["plan"] for r in report["rows"]) == {
        "fp32", "int8", "int4_packed", "top5", "async"}
    for source in ("analytic", "hlo"):
        assert report["max_rel_err"][source] <= report["tolerance"], source
    # compiled-graph pricing parsed every op of every acceptance plan
    assert all(r["unparsed_ops"] == 0.0 for r in report["rows"])
    # coefficients persisted to the (tmp) registry for the pruner
    assert tmp_registry.get_coefficients("analytic") is not None
    assert tmp_registry.get_coefficients("hlo") is not None
    got = trace.load_trace(str(tmp_path / "trace_predict.json"))
    assert got["kind"] == "predict"
