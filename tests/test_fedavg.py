"""Federated engine correctness: the paper's Alg. 1 invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FederatedPlan, FVNConfig, init_server_state, make_round_step

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - deterministic fallback below
    HAVE_HYPOTHESIS = False

W_TRUE = np.random.default_rng(42).normal(size=(4, 2)).astype(np.float32)


def loss_fn(params, batch, rng):
    pred = batch["x"] @ params["w"]
    w = batch["weight"]
    l = jnp.sum((pred - batch["y"]) ** 2 * w[:, None]) / jnp.maximum(w.sum(), 1)
    return l, {}


def make_batch(K, S, b, seed=0, weights=None):
    r = np.random.default_rng(seed)
    x = r.normal(size=(K, S, b, 4)).astype(np.float32)
    y = x @ W_TRUE
    w = np.ones((K, S, b), np.float32) if weights is None else weights
    return {"x": jnp.array(x), "y": jnp.array(y), "weight": jnp.array(w)}


def params0():
    return {"w": jnp.zeros((4, 2))}


def test_single_client_single_step_equals_sgd():
    plan = FederatedPlan(clients_per_round=1, client_lr=0.1,
                         server_optimizer="sgd", server_lr=1.0)
    step = jax.jit(make_round_step(loss_fn, plan, jax.random.PRNGKey(0)))
    state = init_server_state(plan, params0())
    batch = make_batch(1, 1, 8)
    state2, _ = step(state, batch)
    g = jax.grad(lambda p: loss_fn(p, jax.tree.map(lambda a: a[0, 0], batch), None)[0])(params0())
    manual = params0()["w"] - 0.1 * g["w"]
    np.testing.assert_allclose(np.asarray(state2.params["w"]), np.asarray(manual), atol=1e-6)


def test_fedsgd_equals_fedavg_one_local_step():
    kw = dict(clients_per_round=4, client_lr=0.1, server_optimizer="sgd", server_lr=1.0)
    batch = make_batch(4, 1, 8, seed=1)
    outs = []
    for engine in ("fedavg", "fedsgd"):
        plan = FederatedPlan(engine=engine, **kw)
        st_ = init_server_state(plan, params0())
        st2, _ = jax.jit(make_round_step(loss_fn, plan, jax.random.PRNGKey(0)))(st_, batch)
        outs.append(np.asarray(st2.params["w"]))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-6)


def _check_client_permutation_invariance(perm_seed):
    plan = FederatedPlan(clients_per_round=4, client_lr=0.1,
                         server_optimizer="adam", server_lr=0.05)
    step = jax.jit(make_round_step(loss_fn, plan, jax.random.PRNGKey(0)))
    state = init_server_state(plan, params0())
    batch = make_batch(4, 2, 4, seed=2)
    perm = np.random.default_rng(perm_seed).permutation(4)
    batch_p = jax.tree.map(lambda a: a[perm], batch)
    s1, _ = step(state, batch)
    s2, _ = step(state, batch_p)
    np.testing.assert_allclose(np.asarray(s1.params["w"]),
                               np.asarray(s2.params["w"]), atol=1e-5)


@pytest.mark.parametrize("perm_seed", [0, 17, 123, 999])
def test_client_permutation_invariance_deterministic(perm_seed):
    _check_client_permutation_invariance(perm_seed)


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(perm_seed=st.integers(0, 1000))
    def test_client_permutation_invariance(perm_seed):
        _check_client_permutation_invariance(perm_seed)


def test_zero_weight_clients_contribute_nothing():
    plan = FederatedPlan(clients_per_round=3, client_lr=0.1,
                         server_optimizer="sgd", server_lr=1.0)
    step = jax.jit(make_round_step(loss_fn, plan, jax.random.PRNGKey(0)))
    state = init_server_state(plan, params0())
    w = np.ones((3, 2, 4), np.float32)
    w[2] = 0.0                                  # client 2 is all padding
    b3 = make_batch(3, 2, 4, seed=3, weights=w)
    b2 = jax.tree.map(lambda a: a[:2], make_batch(3, 2, 4, seed=3))
    plan2 = FederatedPlan(clients_per_round=2, client_lr=0.1,
                          server_optimizer="sgd", server_lr=1.0)
    s3, _ = step(state, b3)
    s2, _ = jax.jit(make_round_step(loss_fn, plan2, jax.random.PRNGKey(0)))(
        init_server_state(plan2, params0()), b2)
    np.testing.assert_allclose(np.asarray(s3.params["w"]),
                               np.asarray(s2.params["w"]), atol=1e-6)


def test_example_weighted_aggregation():
    """A client with 3x the examples pulls the average 3x harder (n_k/n)."""
    plan = FederatedPlan(clients_per_round=2, client_lr=0.1,
                         server_optimizer="sgd", server_lr=1.0)
    step = jax.jit(make_round_step(loss_fn, plan, jax.random.PRNGKey(0)))
    state = init_server_state(plan, params0())
    w = np.ones((2, 1, 8), np.float32)
    w[1, :, 2:] = 0.0                            # client 1 has 2 real examples
    batch = make_batch(2, 1, 8, seed=5, weights=w)
    s, _ = step(state, batch)

    # manual: per-client one SGD step, delta weighted by n_k/n
    deltas = []
    for k in range(2):
        cb = jax.tree.map(lambda a: a[k, 0], batch)
        g = jax.grad(lambda p: loss_fn(p, cb, None)[0])(params0())
        deltas.append(0.1 * g["w"])
    n = np.array([8.0, 2.0])
    wbar = (n[0] * deltas[0] + n[1] * deltas[1]) / n.sum()
    manual = params0()["w"] - wbar
    np.testing.assert_allclose(np.asarray(s.params["w"]), np.asarray(manual), atol=1e-6)


def test_fvn_determinism_and_effect():
    kw = dict(clients_per_round=2, client_lr=0.1,
              server_optimizer="sgd", server_lr=1.0)
    plan = FederatedPlan(fvn=FVNConfig(enabled=True, std=0.05), **kw)
    step = jax.jit(make_round_step(loss_fn, plan, jax.random.PRNGKey(9)))
    state = init_server_state(plan, params0())
    batch = make_batch(2, 2, 4, seed=6)
    s1, m1 = step(state, batch)
    s2, m2 = step(state, batch)
    np.testing.assert_allclose(np.asarray(s1.params["w"]), np.asarray(s2.params["w"]))
    plan_off = FederatedPlan(**kw)
    s3, _ = jax.jit(make_round_step(loss_fn, plan_off, jax.random.PRNGKey(9)))(
        init_server_state(plan_off, params0()), batch)
    assert float(jnp.abs(s1.params["w"] - s3.params["w"]).max()) > 1e-7


def test_fvn_sigma_ramp():
    from repro.core.fvn import fvn_sigma

    cfg = FVNConfig(enabled=True, std=0.03, ramp_rounds=100)
    assert float(fvn_sigma(cfg, 0)) == 0.0
    np.testing.assert_allclose(float(fvn_sigma(cfg, 50)), 0.015, rtol=1e-6)
    np.testing.assert_allclose(float(fvn_sigma(cfg, 100)), 0.03, rtol=1e-6)
    np.testing.assert_allclose(float(fvn_sigma(cfg, 500)), 0.03, rtol=1e-6)
    assert float(fvn_sigma(FVNConfig(enabled=False), 10)) == 0.0


def test_convergence_on_regression():
    plan = FederatedPlan(clients_per_round=4, client_lr=0.05,
                         server_optimizer="adam", server_lr=0.05,
                         fvn=FVNConfig(enabled=True, std=0.01, ramp_rounds=10))
    step = jax.jit(make_round_step(loss_fn, plan, jax.random.PRNGKey(1)))
    state = init_server_state(plan, params0())
    losses = []
    for r in range(40):
        state, m = step(state, make_batch(4, 3, 8, seed=100 + r))
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.15 * losses[0]


# ------------------------------------------------------ error feedback

def test_error_feedback_recovers_topk_quality_at_same_bytes():
    """The acceptance criterion in miniature: EF21 top-k at 5% reaches
    strictly better final loss than plain top-k at *identical* wire
    bytes (EF changes what travels in the payload, not its size).

    Plain-SGD server (w += wbar), matching the ef_compression grid:
    EF21's guarantee is for the update applied as-is — an adaptive
    server renormalizes the delayed residual bursts and can diverge
    (documented in the grid's docstring and ROADMAP)."""
    from repro.core import CompressionConfig, client_wire_bytes

    def run(comp):
        plan = FederatedPlan(clients_per_round=4, client_lr=0.05,
                             server_optimizer="sgd", server_lr=1.0,
                             compression=comp)
        step = jax.jit(make_round_step(loss_fn, plan, jax.random.PRNGKey(1)))
        state = init_server_state(plan, params0())
        losses = []
        for r in range(40):
            state, m = step(state, make_batch(4, 3, 8, seed=100 + r))
            losses.append(float(m["loss"]))
        return float(np.mean(losses[-5:])), plan

    plain_loss, plain_plan = run(CompressionConfig(kind="topk", topk_frac=0.05))
    ef_loss, ef_plan = run(CompressionConfig(kind="topk", topk_frac=0.05,
                                             error_feedback=True))
    assert (client_wire_bytes(ef_plan.compression, params0())
            == client_wire_bytes(plain_plan.compression, params0()))
    assert ef_loss < plain_loss


def test_error_feedback_state_threads_through_rounds():
    from repro.core import CompressionConfig

    plan = FederatedPlan(clients_per_round=3, client_lr=0.1,
                         server_optimizer="sgd", server_lr=1.0,
                         compression=CompressionConfig(kind="topk",
                                                       topk_frac=0.2,
                                                       error_feedback=True))
    state = init_server_state(plan, params0())
    assert state.ef is not None
    np.testing.assert_array_equal(np.asarray(state.ef["w"]),
                                  np.zeros((3, 4, 2)))
    step = jax.jit(make_round_step(loss_fn, plan, jax.random.PRNGKey(0)))
    state2, _ = step(state, make_batch(3, 2, 4))
    # top-k drops coordinates, so some residual must be nonzero
    assert float(jnp.abs(state2.ef["w"]).max()) > 0
    # without EF no residual state exists
    plan_off = FederatedPlan(clients_per_round=3)
    assert init_server_state(plan_off, params0()).ef is None


def test_error_feedback_keeps_dropped_client_residuals():
    """A non-participant uploads nothing: its residual must survive the
    round untouched (C(0 + e_k) is nonzero, so this needs the explicit
    participant select, unlike the plain path where delta is 0)."""
    from repro.core import CompressionConfig, CohortConfig
    from repro.core.cohort import participation_mask
    from repro.core.fedavg import _plane_keys

    base_key = jax.random.PRNGKey(3)
    plan = FederatedPlan(clients_per_round=4, client_lr=0.1,
                         server_optimizer="sgd", server_lr=1.0,
                         cohort=CohortConfig(participation=0.5),
                         compression=CompressionConfig(kind="topk",
                                                       topk_frac=0.2,
                                                       error_feedback=True))
    state = init_server_state(plan, params0())
    marker = jax.tree.map(lambda e: jnp.full_like(e, 0.125), state.ef)
    state = state._replace(ef=marker)
    step = jax.jit(make_round_step(loss_fn, plan, base_key))
    state2, m = step(state, make_batch(4, 2, 4, seed=7))

    ckey, _, _, _ = _plane_keys(base_key, jnp.zeros((), jnp.int32))
    pmask = np.asarray(participation_mask(jax.random.fold_in(ckey, 0), 4,
                                          plan.cohort.participation))
    assert 0 < pmask.sum() < 4                       # the draw actually split
    ef = np.asarray(state2.ef["w"])
    for k in range(4):
        if pmask[k]:
            assert np.abs(ef[k] - 0.125).max() > 1e-9
        else:
            np.testing.assert_array_equal(ef[k], np.full((4, 2), 0.125))


def test_error_feedback_rejects_fedsgd():
    from repro.core import CompressionConfig, make_hyper_round_step

    plan = FederatedPlan(engine="fedsgd",
                         compression=CompressionConfig(kind="int8",
                                                       error_feedback=True))
    with pytest.raises(ValueError, match="per-client"):
        make_round_step(loss_fn, plan, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="per-client"):
        make_hyper_round_step(loss_fn, engine="fedsgd",
                              compression=plan.compression)
