"""Vectorized data plane: arena packing parity, prefetch, sampling
strategies, the hyper-parameterized round step, and the sweep runner."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (
    FederatedSampler,
    PrefetchIterator,
    available_strategies,
    get_strategy,
    make_speaker_corpus,
    round_batches,
)

FIELDS = ("features", "labels", "label_len", "frame_len", "mask", "n_k")


@pytest.fixture(scope="module")
def corpus():
    return make_speaker_corpus(num_speakers=12, vocab_size=32, feat_dim=8,
                               mean_utterances=10.0, seed=1)


# ----------------------------------------------------------- arena + parity

def test_corpus_arena_views(corpus):
    assert corpus.arena_features.shape[0] == 12
    assert corpus.arena_features.shape[1] == corpus.n_max
    for i, s in enumerate(corpus.speakers):
        n = s["n"]
        assert corpus.counts[i] == n
        # speakers are views into the arena, not copies
        np.testing.assert_array_equal(corpus.arena_features[i, :n], s["features"])
        assert np.shares_memory(corpus.arena_features, s["features"])


@pytest.mark.parametrize("kw", [
    dict(data_limit=3),
    dict(),                                   # no limit: full client data
    dict(data_limit=5, local_epochs=2),       # epoch tiling
    dict(data_limit=20),                      # limit > n: multi-pass reshuffle
    dict(data_limit=1),
])
def test_vectorized_matches_legacy(corpus, kw):
    """The tentpole parity oracle: for a fixed seed the vectorized
    gather produces bit-identical round batches to the per-example
    loop, across enough rounds to hit cursor wraps + reshuffles."""
    vec = FederatedSampler(corpus, clients_per_round=4, local_batch_size=2,
                           seed=0, **kw)
    leg = FederatedSampler(corpus, clients_per_round=4, local_batch_size=2,
                           seed=0, legacy=True, **kw)
    for r in range(12):
        rv, rl = vec.next_round(), leg.next_round()
        for f in FIELDS:
            np.testing.assert_array_equal(
                getattr(rv, f), getattr(rl, f), err_msg=f"round {r} field {f}")
    assert vec._cursors == leg._cursors


def test_next_round_dtypes_and_no_arena_aliasing(corpus):
    s = FederatedSampler(corpus, clients_per_round=4, local_batch_size=2,
                         data_limit=3, seed=0)
    rb = s.next_round()
    assert rb.features.dtype == np.float32
    assert rb.labels.dtype == np.int32
    assert not np.shares_memory(rb.features, corpus.arena_features)


def test_steps_override_pads_with_zero_weight(corpus):
    s8 = FederatedSampler(corpus, clients_per_round=4, local_batch_size=2,
                          data_limit=3, seed=0, steps=8)
    rb = s8.next_round()
    assert rb.mask.shape == (4, 8, 2)
    assert rb.mask.sum() == 12                # only the real examples
    # padded slots are zeroed
    assert (rb.features[rb.mask == 0] == 0).all()


# ----------------------------------------------------------------- strategies

def test_strategy_registry_contents():
    names = available_strategies()
    assert {"uniform", "weighted-by-examples", "stratified"} <= set(names)
    with pytest.raises(KeyError):
        get_strategy("nope")


@pytest.mark.parametrize("name", ["uniform", "weighted-by-examples", "stratified"])
def test_strategies_select_distinct_valid_clients(corpus, name):
    fn = get_strategy(name)
    rng = np.random.default_rng(0)
    for _ in range(20):
        chosen = np.asarray(fn(rng, corpus, 6))
        assert chosen.shape == (6,)
        assert len(set(chosen.tolist())) == 6
        assert (0 <= chosen).all() and (chosen < corpus.num_speakers).all()


def test_weighted_strategy_prefers_data_rich_clients(corpus):
    counts = corpus.utterance_histogram()
    rng_u, rng_w = np.random.default_rng(0), np.random.default_rng(0)
    uni, wei = [], []
    for _ in range(300):
        uni.append(counts[get_strategy("uniform")(rng_u, corpus, 4)].mean())
        wei.append(counts[get_strategy("weighted-by-examples")(rng_w, corpus, 4)].mean())
    assert np.mean(wei) > np.mean(uni) * 1.05


def test_stratified_strategy_mixes_quantiles(corpus):
    counts = corpus.utterance_histogram()
    order = np.argsort(counts, kind="stable")
    strata = [set(s.tolist()) for s in np.array_split(order, 4)]
    rng = np.random.default_rng(3)
    for _ in range(20):
        chosen = set(get_strategy("stratified")(rng, corpus, 4).tolist())
        # one client from every utterance-count quantile
        assert all(chosen & s for s in strata)


def test_sampler_accepts_strategy(corpus):
    s = FederatedSampler(corpus, clients_per_round=4, local_batch_size=2,
                         data_limit=2, seed=0, strategy="stratified")
    rb = s.next_round()
    assert rb.mask.sum() == 8


# ------------------------------------------------------------------ prefetch

def test_prefetch_preserves_order_and_values(corpus):
    mk = lambda: FederatedSampler(corpus, 4, 2, data_limit=3, seed=0)
    serial = list(round_batches(mk(), 10))
    with PrefetchIterator(round_batches(mk(), 10), device_put=False) as it:
        prefetched = list(it)
    assert len(prefetched) == 10
    for a, b in zip(serial, prefetched):
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])


def test_prefetch_device_put_yields_jax_arrays(corpus):
    with PrefetchIterator(round_batches(FederatedSampler(corpus, 2, 2, seed=0), 2),
                          depth=1) as it:
        batch = next(it)
    assert isinstance(batch["features"], jax.Array)


def test_prefetch_early_close_stops_worker():
    started = threading.Event()

    def slow_source():
        for i in range(1000):
            started.wait(0)
            yield {"i": np.asarray(i)}
            time.sleep(0.001)

    it = PrefetchIterator(slow_source(), depth=2, device_put=False)
    assert next(it)["i"] == 0
    it.close()
    assert not it._thread.is_alive()
    with pytest.raises(StopIteration):
        next(it)


def test_prefetch_propagates_source_exception():
    def bad_source():
        yield {"i": np.asarray(0)}
        raise RuntimeError("boom")

    with PrefetchIterator(bad_source(), device_put=False) as it:
        assert next(it)["i"] == 0
        with pytest.raises(RuntimeError, match="boom"):
            next(it)


def test_prefetch_overlaps_host_work():
    """Consumer 'compute' and producer packing run concurrently: total
    wall must be well under the serial sum."""
    delay = 0.01

    def source():
        for i in range(10):
            time.sleep(delay)
            yield i

    t0 = time.perf_counter()
    with PrefetchIterator(source(), depth=2, device_put=False) as it:
        for _ in it:
            time.sleep(delay)
    wall = time.perf_counter() - t0
    assert wall < 10 * 2 * delay * 0.85, wall


# --------------------------------------------- hyper round step + sweep glue

W_TRUE = np.random.default_rng(42).normal(size=(4, 2)).astype(np.float32)


def toy_loss(params, batch, rng):
    pred = batch["x"] @ params["w"]
    w = batch["weight"]
    l = jnp.sum((pred - batch["y"]) ** 2 * w[:, None]) / jnp.maximum(w.sum(), 1)
    return l, {}


def toy_batch(K, S, b, seed=0):
    r = np.random.default_rng(seed)
    x = r.normal(size=(K, S, b, 4)).astype(np.float32)
    return {"x": jnp.asarray(x), "y": jnp.asarray(x @ W_TRUE),
            "weight": jnp.ones((K, S, b), jnp.float32)}


def pad_toy_batch(batch, total_steps):
    """Append weight-0 steps (the sweep runner's pad_steps layout)."""
    def pad(a):
        extra = np.zeros((a.shape[0], total_steps - a.shape[1]) + a.shape[2:],
                         np.asarray(a).dtype)
        return jnp.concatenate([a, jnp.asarray(extra)], axis=1)

    return {k: pad(v) for k, v in batch.items()}


@pytest.mark.parametrize("schedule_kw", [
    dict(server_warmup_rounds=2, server_decay_rounds=6, server_decay_rate=0.8),
    dict(server_warmup_rounds=0, server_decay_rounds=6, server_decay_rate=0.8),
    dict(server_warmup_rounds=3),
    dict(),                                   # constant lr
])
def test_hyper_round_step_matches_plain(schedule_kw):
    from repro.core import (FederatedPlan, FVNConfig, init_server_state,
                            make_hyper_round_step, make_round_step, plan_hypers)

    plan = FederatedPlan(clients_per_round=4, client_lr=0.1,
                         server_optimizer="adam", server_lr=0.05,
                         fvn=FVNConfig(enabled=True, std=0.05, ramp_rounds=4),
                         **schedule_kw)
    key = jax.random.PRNGKey(9)
    plain = jax.jit(make_round_step(toy_loss, plan, key))
    hyper = jax.jit(make_hyper_round_step(toy_loss, plan.engine,
                                          plan.server_optimizer))
    hypers = plan_hypers(plan)
    s1 = s2 = init_server_state(plan, {"w": jnp.zeros((4, 2))})
    for r in range(6):
        batch = toy_batch(4, 2, 4, seed=r)
        s1, _ = plain(s1, batch)
        s2, _ = hyper(s2, batch, hypers, key)
    np.testing.assert_allclose(np.asarray(s1.params["w"]),
                               np.asarray(s2.params["w"]), atol=1e-6)


def test_hyper_round_step_shares_compilation_across_hypers():
    from repro.core import (FederatedPlan, FVNConfig, init_server_state,
                            make_hyper_round_step, plan_hypers)

    plans = [
        FederatedPlan(clients_per_round=4, client_lr=0.1, server_lr=0.05),
        FederatedPlan(clients_per_round=4, client_lr=0.3, server_lr=0.01,
                      server_warmup_rounds=5,
                      fvn=FVNConfig(enabled=True, std=0.02, ramp_rounds=3)),
    ]
    hyper = jax.jit(make_hyper_round_step(toy_loss, "fedavg", "adam"))
    key = jax.random.PRNGKey(0)
    batch = toy_batch(4, 2, 4)
    for plan in plans:
        state = init_server_state(plan, {"w": jnp.zeros((4, 2))})
        hyper(state, batch, plan_hypers(plan), key)
    # both plans hit one trace: hypers are traced args, not constants
    assert hyper._cache_size() == 1


def test_padded_zero_weight_steps_are_noops():
    """pad_steps correctness: a batch padded with weight-0 steps gives
    the same server update as the unpadded batch."""
    from repro.core import (FederatedPlan, init_server_state,
                            make_hyper_round_step, plan_hypers)

    plan = FederatedPlan(clients_per_round=3, client_lr=0.1,
                         server_optimizer="adam", server_lr=0.05)
    hyper = jax.jit(make_hyper_round_step(toy_loss, "fedavg", "adam"))
    hypers = plan_hypers(plan)
    key = jax.random.PRNGKey(1)
    state0 = init_server_state(plan, {"w": jnp.zeros((4, 2))})

    native = toy_batch(3, 2, 4, seed=5)
    padded = pad_toy_batch(native, 6)
    # identical real content
    np.testing.assert_array_equal(np.asarray(native["x"]),
                                  np.asarray(padded["x"][:, :2]))
    s_native, m_native = hyper(state0, native, hypers, key)
    s_padded, m_padded = hyper(state0, padded, hypers, key)
    np.testing.assert_allclose(np.asarray(s_native.params["w"]),
                               np.asarray(s_padded.params["w"]), atol=1e-6)
    np.testing.assert_allclose(float(m_native["loss"]),
                               float(m_padded["loss"]), atol=1e-6)


def test_pack_round_pad_steps_is_weight_zero():
    """IID points padded to a grid shape must gain weight-0 no-op
    steps, never extra weight-1 recycled examples."""
    from repro.data import pack_round

    corpus = make_speaker_corpus(num_speakers=6, vocab_size=16, feat_dim=4,
                                 mean_utterances=6.0, seed=4)
    rb = pack_round(corpus.iid_pool(), K=3, steps=2, batch=2).pad_steps(5)
    assert rb.mask.shape == (3, 5, 2)
    assert rb.mask[:, :2].all() and not rb.mask[:, 2:].any()
    assert (rb.features[:, 2:] == 0).all()
    np.testing.assert_array_equal(rb.n_k, np.full(3, 4.0))


def test_mark_pareto():
    from repro.launch.sweeps import mark_pareto

    rows = [
        {"id": "a", "cfmq_tb": 1.0, "quality": 0.5},
        {"id": "b", "cfmq_tb": 2.0, "quality": 0.4},
        {"id": "c", "cfmq_tb": 2.0, "quality": 0.6},   # dominated by a and b
        {"id": "d", "cfmq_tb": 0.5, "quality": 0.9},
    ]
    out = {r["id"]: r["pareto"] for r in mark_pareto(rows)}
    assert out == {"a": True, "b": True, "c": False, "d": True}


def test_noniid_fvn_grid_spec():
    from repro.launch.sweeps import GRIDS, noniid_fvn_points

    assert set(GRIDS) >= {"noniid_fvn", "ladder"}
    pts = noniid_fvn_points(smoke=True)
    assert len(pts) >= 6
    assert len({p.id for p in pts}) == len(pts)
    limits = {p.meta["limit"] for p in pts}
    assert None in limits and len(limits) >= 3
    assert {p.meta["fvn"] for p in pts} == {False, True}


def test_ladder_points_budgets():
    from repro.launch.sweeps import ladder_points

    pts = {p.id: p for p in ladder_points(rounds=30)}
    assert set(pts) == {f"E{i}" for i in range(11)}
    assert pts["E0"].iid and not pts["E1"].iid
    # equal-examples budgeting: tighter limits get more rounds
    assert pts["E2"].rounds > pts["E3"].rounds > pts["E1"].rounds == 30
    assert pts["E10"].specaug_scale == 2.0


@pytest.mark.slow
def test_sweep_runner_end_to_end(tmp_path):
    """Two-point micro-sweep on a micro RNN-T: one shared jitted round
    fn, frontier JSON written, rows carry quality/cost fields."""
    from repro.asr.specaugment import SpecAugmentConfig
    from repro.core import FederatedPlan, FVNConfig
    from repro.launch.sweeps import SweepPoint, SweepRunner, mark_pareto
    from repro.models.rnnt import RNNTConfig

    cfg = RNNTConfig(name="rnnt-micro", feat_dim=8, vocab=16,
                     enc_layers=1, enc_hidden=16, pred_layers=1, pred_hidden=16,
                     pred_embed=8, joint_dim=16, time_stride=1,
                     specaug=SpecAugmentConfig(freq_masks=1, freq_mask_width=2,
                                               time_masks=1, time_mask_frac=0.05),
                     dtype="float32", param_dtype="float32")
    corpus = make_speaker_corpus(num_speakers=8, vocab_size=16, feat_dim=8,
                                 mean_utterances=6.0, seed=3)
    runner = SweepRunner(cfg=cfg, corpus=corpus, eval_examples=8,
                         pad_steps=True)
    points = [
        SweepPoint(id="a", rounds=2, meta={"limit": 1},
                   plan=FederatedPlan(clients_per_round=4, local_batch_size=2,
                                      data_limit=1, client_lr=0.3, server_lr=0.05)),
        SweepPoint(id="b", rounds=2, meta={"limit": 4},
                   plan=FederatedPlan(clients_per_round=4, local_batch_size=2,
                                      data_limit=4, client_lr=0.1, server_lr=0.01,
                                      fvn=FVNConfig(enabled=True, std=0.01))),
    ]
    rows = mark_pareto(runner.run(points, log=lambda *a, **k: None))
    assert [r["id"] for r in rows] == ["a", "b"]
    for r in rows:
        for k in ("final_loss", "quality", "quality_hard", "quality_metric",
                  "cfmq_tb", "rounds", "loss_curve", "pareto", "limit"):
            assert k in r
        assert np.isfinite(r["final_loss"])
    # the two points differ in every traced hyper but share one compile
    assert len(runner._jit_cache) == 1
    (fn,) = runner._jit_cache.values()
    assert fn._cache_size() == 1


def test_ef_compression_grid_spec():
    """Plain/EF pairs sit at identical wire bytes; the packed point
    exercises the materialized wire path."""
    from repro.core import client_wire_bytes
    from repro.launch.sweeps import ef_compression_points

    pts = {p.id: p for p in ef_compression_points(smoke=True)}
    assert {"top5", "top5_ef", "int4", "int4_ef", "int4_packed_ef"} <= set(pts)
    tree = {"w": np.zeros((33, 7), np.float32)}
    for a, b in [("top5", "top5_ef"), ("int4", "int4_ef"),
                 ("int4", "int4_packed_ef")]:
        assert (client_wire_bytes(pts[a].plan.compression, tree)
                == client_wire_bytes(pts[b].plan.compression, tree))
    assert pts["top5_ef"].plan.compression.error_feedback
    assert not pts["top5"].plan.compression.error_feedback
    assert pts["int4_packed_ef"].plan.compression.packed
    full = {p.id for p in ef_compression_points(smoke=False)}
    assert {"top1", "top1_ef"} <= full
