"""Integration: the paper's qualitative claims on the synthetic corpus.

Small-scale but real: federated RNN-T rounds must (a) learn, (b) show
the IID-vs-non-IID ordering of Table 1, (c) let FVN help (Table 3
direction). The full ladder runs in benchmarks/.
"""
import jax
import numpy as np
import pytest

from repro.core import FederatedPlan
from repro.launch.train import run_federated_asr, tiny_asr_setup

# multi-round end-to-end parity: the slowest tests in the suite (CI
# always runs them via -m "slow or not slow"; local default skips)
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def setup():
    return tiny_asr_setup(seed=0)


def _plan(**kw):
    base = dict(clients_per_round=6, local_batch_size=4, client_lr=0.3,
                server_lr=0.05, server_warmup_rounds=4, local_steps=8)
    base.update(kw)
    return FederatedPlan(**base)


def test_federated_training_learns(setup):
    cfg, corpus = setup
    _, hist = run_federated_asr(cfg, corpus, _plan(), rounds=16, seed=0)
    first = np.mean(hist["loss"][:3])
    last = np.mean(hist["loss"][-3:])
    assert last < 0.9 * first, (first, last)
    assert hist["quality_metric"] == "wer"
    assert np.isfinite(hist["quality"]) and 0 <= hist["quality"] <= 1.5


def test_cfmq_recorded(setup):
    cfg, corpus = setup
    _, hist = run_federated_asr(cfg, corpus, _plan(data_limit=4), rounds=4, seed=0)
    assert hist["cfmq_bytes"] > 0
    _, hist2 = run_federated_asr(cfg, corpus, _plan(data_limit=8), rounds=4, seed=0)
    assert hist2["cfmq_bytes"] > hist["cfmq_bytes"]   # more local steps -> costlier


def test_iid_not_worse_than_noniid(setup):
    """Table 1 direction at miniature scale (same budget)."""
    cfg, corpus = setup
    _, non = run_federated_asr(cfg, corpus, _plan(), rounds=14, seed=1, iid=False)
    _, iid = run_federated_asr(cfg, corpus, _plan(), rounds=14, seed=1, iid=True)
    # allow tolerance: tiny scale is noisy; IID should not be clearly worse
    assert iid["final_loss"] <= non["final_loss"] * 1.15, (iid["final_loss"], non["final_loss"])


def test_checkpointing_during_training(setup, tmp_path):
    cfg, corpus = setup
    state, _ = run_federated_asr(cfg, corpus, _plan(), rounds=3, seed=0,
                                 ckpt_dir=str(tmp_path))
    from repro.checkpoint import Checkpointer

    ck = Checkpointer(str(tmp_path))
    assert ck.latest_round() is not None
    restored, _ = ck.restore_latest(state.params)
    n_equal = sum(
        int(np.allclose(np.asarray(a), np.asarray(b)))
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state.params)))
    assert n_equal == len(jax.tree.leaves(state.params))
