"""Optimizer substrate + schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - deterministic fallback below
    HAVE_HYPOTHESIS = False


def quad_loss(p):
    return jnp.sum((p["w"] - 3.0) ** 2)


def run(opt, steps=200, p0=None):
    p = p0 or {"w": jnp.zeros((4,))}
    s = opt.init(p)
    for _ in range(steps):
        g = jax.grad(quad_loss)(p)
        u, s = opt.update(g, s, p)
        p = optim.apply_updates(p, u)
    return p


def test_sgd_converges():
    p = run(optim.sgd(0.1))
    np.testing.assert_allclose(np.asarray(p["w"]), 3.0, atol=1e-3)


def test_momentum_converges():
    p = run(optim.momentum(0.05, 0.9))
    np.testing.assert_allclose(np.asarray(p["w"]), 3.0, atol=1e-2)


def test_adam_converges():
    p = run(optim.adam(0.1), steps=400)
    np.testing.assert_allclose(np.asarray(p["w"]), 3.0, atol=1e-2)


def test_yogi_converges():
    p = run(optim.yogi(0.1), steps=400)
    np.testing.assert_allclose(np.asarray(p["w"]), 3.0, atol=5e-2)


def test_clip_by_global_norm():
    opt = optim.clip_by_global_norm(optim.sgd(1.0), 0.5)
    p = {"w": jnp.zeros((4,))}
    s = opt.init(p)
    g = {"w": jnp.full((4,), 100.0)}
    u, s = opt.update(g, s, p)
    np.testing.assert_allclose(float(optim.global_norm(u)), 0.5, rtol=1e-5)


def test_adamw_decays_weights():
    opt = optim.adamw(0.0, weight_decay=0.1)   # lr 0 isolates decay? lr scales decay too
    opt = optim.adamw(0.1, weight_decay=0.1)
    p = {"w": jnp.full((4,), 10.0)}
    s = opt.init(p)
    u, s = opt.update({"w": jnp.zeros((4,))}, s, p)
    assert float(u["w"].max()) < 0            # pure decay pulls toward 0


def test_schedules():
    s = optim.linear_rampup(1.0, 10)
    assert float(s(0)) == 0.0
    np.testing.assert_allclose(float(s(5)), 0.5)
    assert float(s(100)) == 1.0

    d = optim.linear_rampup_exp_decay(1.0, 4, 10, 0.5)
    np.testing.assert_allclose(float(d(4)), 1.0, rtol=1e-6)
    np.testing.assert_allclose(float(d(14)), 0.5, rtol=1e-6)

    r = optim.linear_ramp_to(0.03, 100)
    np.testing.assert_allclose(float(r(50)), 0.015, rtol=1e-6)

    pw = optim.piecewise([10, 20], [1.0, 0.5, 0.1])
    np.testing.assert_allclose([float(pw(5)), float(pw(15)), float(pw(25))], [1.0, 0.5, 0.1], rtol=1e-5)


def _check_sgd_step_is_linear_in_grad(lr, seed):
    opt = optim.sgd(lr)
    p = {"w": jnp.zeros((3,))}
    s = opt.init(p)
    g = jnp.asarray(np.random.default_rng(seed).normal(size=3), jnp.float32)
    u1, _ = opt.update({"w": g}, s, p)
    u2, _ = opt.update({"w": 2 * g}, s, p)
    np.testing.assert_allclose(np.asarray(u2["w"]), 2 * np.asarray(u1["w"]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(u1["w"]), -lr * np.asarray(g), rtol=1e-5)


@pytest.mark.parametrize("lr,seed", [(1e-4, 0), (0.5, 100), (0.01, 7), (0.1, 42)])
def test_sgd_step_is_linear_in_grad_deterministic(lr, seed):
    _check_sgd_step_is_linear_in_grad(lr, seed)


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(lr=st.floats(1e-4, 0.5), seed=st.integers(0, 100))
    def test_sgd_step_is_linear_in_grad(lr, seed):
        _check_sgd_step_is_linear_in_grad(lr, seed)


def test_checkpointer_roundtrip(tmp_path):
    from repro.checkpoint import Checkpointer

    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones((4,), jnp.int32)}}
    ck = Checkpointer(str(tmp_path), keep=2)
    ck.save(1, tree)
    ck.save(2, jax.tree.map(lambda x: x + 1, tree))
    ck.save(3, jax.tree.map(lambda x: x + 2, tree))
    assert ck.latest_round() == 3
    restored, extra = ck.restore_latest(tree)
    np.testing.assert_allclose(np.asarray(restored["a"]), np.asarray(tree["a"]) + 2)
    assert extra["round"] == 3
    import os
    assert not os.path.exists(tmp_path / "ckpt_1.npz")   # gc'd
