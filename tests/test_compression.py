"""Uplink compression: quantization correctness + exact wire bytes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import (
    CompressionConfig,
    client_wire_bytes,
    leaf_wire_bytes,
    make_compressor,
    tree_param_bytes,
)

TREE = {
    "a": jnp.asarray(np.random.default_rng(0).normal(size=(16, 8)), jnp.float32),
    "b": {"c": jnp.asarray(np.random.default_rng(1).normal(size=(33,)), jnp.float32)},
}


def test_config_validation():
    with pytest.raises(ValueError, match="unknown compression kind"):
        CompressionConfig(kind="fp8")
    with pytest.raises(ValueError, match="topk_frac"):
        CompressionConfig(kind="topk", topk_frac=0.0)
    with pytest.raises(ValueError, match="topk_frac"):
        CompressionConfig(kind="topk", topk_frac=1.5)
    # an inert topk_frac (e.g. a CLI default of 0) must not block other
    # kinds — only the knob actually in use is validated
    CompressionConfig(kind="int8", topk_frac=0.0)
    CompressionConfig(kind="none", topk_frac=-1.0)


def test_none_is_identity():
    out = make_compressor(CompressionConfig())(TREE, jax.random.PRNGKey(0))
    for a, b in zip(jax.tree.leaves(TREE), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("kind,levels", [("int8", 127.0), ("int4", 7.0)])
def test_quantization_error_bounded_by_scale(kind, levels):
    cfg = CompressionConfig(kind=kind)
    out = make_compressor(cfg)(TREE, jax.random.PRNGKey(1))
    for a, b in zip(jax.tree.leaves(TREE), jax.tree.leaves(out)):
        scale = float(jnp.max(jnp.abs(a))) / levels
        err = float(jnp.max(jnp.abs(a - b)))
        assert err <= scale + 1e-6           # stochastic rounding: one grid cell
        # dequantized values sit on the quantization grid
        q = np.asarray(b) / scale
        np.testing.assert_allclose(q, np.round(q), atol=1e-3)


def test_stochastic_rounding_is_unbiased():
    # absmax 0.7 -> int4 grid step 0.1; the 0.33 coordinates sit
    # between grid points, so rounding must split 0.3/0.4 at 70/30
    vals = np.full(256, 0.33, np.float32)
    vals[0] = 0.7
    x = {"w": jnp.asarray(vals)}
    cfg = CompressionConfig(kind="int4")
    compress = jax.jit(make_compressor(cfg))
    outs = np.stack([np.asarray(compress(x, jax.random.PRNGKey(i))["w"][1:])
                     for i in range(200)])
    np.testing.assert_allclose(outs.mean(), 0.33, rtol=0.05)
    assert len(np.unique(outs)) > 1          # actually stochastic


def test_stochastic_rounding_unbiased_at_grid_boundary():
    """The absmax coordinate must quantize deterministically to the top
    grid level: f32 division can land it one ulp *outside* the grid,
    and an unclamped Bernoulli draw there rounds up to levels+1 and
    gets clipped back — biasing E[Q(x)] below x exactly at the
    boundary (and, in the packed path, wrapping the int8 cast).
    2.770888566970825 is such a value: |x| / (|x|/127) > 127 in f32."""
    a = 2.770888566970825
    vals = np.full(64, 0.5, np.float32)
    vals[0] = a
    x = {"w": jnp.asarray(vals)}
    compress = jax.jit(make_compressor(CompressionConfig(kind="int8")))
    outs = np.stack([np.asarray(compress(x, jax.random.PRNGKey(i))["w"][0])
                     for i in range(300)])
    scale = np.float32(a) / np.float32(127.0)
    # deterministic (no boundary randomness) and exactly on the top level
    assert len(np.unique(outs)) == 1
    np.testing.assert_array_equal(outs, np.float32(127.0) * scale)
    # E[Q] == Q == x up to the scale-quantization ulp, never below-biased
    np.testing.assert_allclose(outs.mean(), a, rtol=1e-6)

    # adversarial key: base key 178975's leaf-0 draw fires at
    # p = 7.6e-6, so an unclamped implementation rounds the absmax
    # coordinate to 128 — which the int8 codes path wraps to -128
    from repro.core.compression import quantize_codes

    key = jax.random.split(jax.random.PRNGKey(178975), 1)[0]
    codes, _ = quantize_codes(jnp.asarray(vals), key, 8)
    assert int(codes[0]) == 127


def test_nearest_rounding_is_deterministic():
    cfg = CompressionConfig(kind="int8", stochastic=False)
    compress = make_compressor(cfg)
    a = compress(TREE, jax.random.PRNGKey(0))
    b = compress(TREE, jax.random.PRNGKey(99))
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_topk_keeps_exactly_k_largest():
    cfg = CompressionConfig(kind="topk", topk_frac=0.25)
    x = {"w": jnp.asarray(np.random.default_rng(3).normal(size=(40,)), jnp.float32)}
    out = np.asarray(make_compressor(cfg)(x, jax.random.PRNGKey(0))["w"])
    k = 10                                   # ceil(0.25 * 40)
    nz = np.flatnonzero(out)
    assert len(nz) == k
    # survivors are the k largest magnitudes, passed through unchanged
    xs = np.asarray(x["w"])
    expect = set(np.argsort(-np.abs(xs))[:k])
    assert set(nz) == expect
    np.testing.assert_array_equal(out[nz], xs[nz])


def test_wire_byte_formulas():
    assert leaf_wire_bytes(CompressionConfig(), 100) == 400
    assert leaf_wire_bytes(CompressionConfig(kind="int8"), 100) == 104
    assert leaf_wire_bytes(CompressionConfig(kind="int4"), 101) == 55   # 51 + 4
    assert leaf_wire_bytes(CompressionConfig(kind="topk", topk_frac=0.1), 100) == 80

    n = 16 * 8 + 33
    assert client_wire_bytes(CompressionConfig(), TREE) == 4 * n
    assert client_wire_bytes(CompressionConfig(kind="int8"), TREE) == n + 8
    assert tree_param_bytes(TREE) == 4 * n


def test_compression_strictly_shrinks_uplink():
    sizes = [client_wire_bytes(CompressionConfig(kind=k), TREE)
             for k in ("none", "int8", "int4")]
    assert sizes[0] > sizes[1] > sizes[2]
    topk = client_wire_bytes(CompressionConfig(kind="topk", topk_frac=0.05), TREE)
    assert topk < sizes[0]
