"""Uplink compression: quantization correctness + exact wire bytes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import (
    CompressionConfig,
    client_wire_bytes,
    leaf_wire_bytes,
    make_compressor,
    tree_param_bytes,
)

TREE = {
    "a": jnp.asarray(np.random.default_rng(0).normal(size=(16, 8)), jnp.float32),
    "b": {"c": jnp.asarray(np.random.default_rng(1).normal(size=(33,)), jnp.float32)},
}


def test_config_validation():
    with pytest.raises(ValueError, match="unknown compression kind"):
        CompressionConfig(kind="fp8")
    with pytest.raises(ValueError, match="topk_frac"):
        CompressionConfig(kind="topk", topk_frac=0.0)
    with pytest.raises(ValueError, match="topk_frac"):
        CompressionConfig(kind="topk", topk_frac=1.5)
    # an inert topk_frac (e.g. a CLI default of 0) must not block other
    # kinds — only the knob actually in use is validated
    CompressionConfig(kind="int8", topk_frac=0.0)
    CompressionConfig(kind="none", topk_frac=-1.0)


def test_none_is_identity():
    out = make_compressor(CompressionConfig())(TREE, jax.random.PRNGKey(0))
    for a, b in zip(jax.tree.leaves(TREE), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("kind,levels", [("int8", 127.0), ("int4", 7.0)])
def test_quantization_error_bounded_by_scale(kind, levels):
    cfg = CompressionConfig(kind=kind)
    out = make_compressor(cfg)(TREE, jax.random.PRNGKey(1))
    for a, b in zip(jax.tree.leaves(TREE), jax.tree.leaves(out)):
        scale = float(jnp.max(jnp.abs(a))) / levels
        err = float(jnp.max(jnp.abs(a - b)))
        assert err <= scale + 1e-6           # stochastic rounding: one grid cell
        # dequantized values sit on the quantization grid
        q = np.asarray(b) / scale
        np.testing.assert_allclose(q, np.round(q), atol=1e-3)


def test_stochastic_rounding_is_unbiased():
    # absmax 0.7 -> int4 grid step 0.1; the 0.33 coordinates sit
    # between grid points, so rounding must split 0.3/0.4 at 70/30
    vals = np.full(256, 0.33, np.float32)
    vals[0] = 0.7
    x = {"w": jnp.asarray(vals)}
    cfg = CompressionConfig(kind="int4")
    compress = jax.jit(make_compressor(cfg))
    outs = np.stack([np.asarray(compress(x, jax.random.PRNGKey(i))["w"][1:])
                     for i in range(200)])
    np.testing.assert_allclose(outs.mean(), 0.33, rtol=0.05)
    assert len(np.unique(outs)) > 1          # actually stochastic


def test_nearest_rounding_is_deterministic():
    cfg = CompressionConfig(kind="int8", stochastic=False)
    compress = make_compressor(cfg)
    a = compress(TREE, jax.random.PRNGKey(0))
    b = compress(TREE, jax.random.PRNGKey(99))
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_topk_keeps_exactly_k_largest():
    cfg = CompressionConfig(kind="topk", topk_frac=0.25)
    x = {"w": jnp.asarray(np.random.default_rng(3).normal(size=(40,)), jnp.float32)}
    out = np.asarray(make_compressor(cfg)(x, jax.random.PRNGKey(0))["w"])
    k = 10                                   # ceil(0.25 * 40)
    nz = np.flatnonzero(out)
    assert len(nz) == k
    # survivors are the k largest magnitudes, passed through unchanged
    xs = np.asarray(x["w"])
    expect = set(np.argsort(-np.abs(xs))[:k])
    assert set(nz) == expect
    np.testing.assert_array_equal(out[nz], xs[nz])


def test_wire_byte_formulas():
    assert leaf_wire_bytes(CompressionConfig(), 100) == 400
    assert leaf_wire_bytes(CompressionConfig(kind="int8"), 100) == 104
    assert leaf_wire_bytes(CompressionConfig(kind="int4"), 101) == 55   # 51 + 4
    assert leaf_wire_bytes(CompressionConfig(kind="topk", topk_frac=0.1), 100) == 80

    n = 16 * 8 + 33
    assert client_wire_bytes(CompressionConfig(), TREE) == 4 * n
    assert client_wire_bytes(CompressionConfig(kind="int8"), TREE) == n + 8
    assert tree_param_bytes(TREE) == 4 * n


def test_compression_strictly_shrinks_uplink():
    sizes = [client_wire_bytes(CompressionConfig(kind=k), TREE)
             for k in ("none", "int8", "int4")]
    assert sizes[0] > sizes[1] > sizes[2]
    topk = client_wire_bytes(CompressionConfig(kind="topk", topk_frac=0.05), TREE)
    assert topk < sizes[0]
