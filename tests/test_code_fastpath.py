"""Code-domain aggregation fast path: shared-scale negotiation,
exact int32 code sums, and parity against dequantize-then-weighted-mean
(the slow path's semantics on the same shared-scale codes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AggregatorConfig,
    CompressionConfig,
    FederatedPlan,
    init_server_state,
    make_round_step,
)
from repro.core.compression import (
    code_domain_aggregate,
    fastpath_leaf_keys,
    quantize_codes_with_scale,
    shared_leaf_scale,
    sum_packed_codes,
    _BITS,
)
from repro.core.fedavg import _code_fast_path, _plan_server_plane

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - deterministic fallback below
    HAVE_HYPOTHESIS = False


def _client_keys(seed, K):
    key = jax.random.PRNGKey(seed)
    return key, jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(K))


def _tree(rng, K, shapes):
    return {f"l{i}": jnp.asarray(rng.normal(size=(K,) + s), jnp.float32)
            for i, s in enumerate(shapes)}


def _reference_wbar(cfg, deltas, n_k, pmask, ckeys):
    """Dequantize-then-weighted-mean over the SAME shared-scale codes
    the fast path transmits: the slow-path semantics of the negotiated
    wire protocol, computed leaf by leaf in f64 so the comparison
    target carries no accumulated f32 rounding of its own."""
    bits = _BITS[cfg.kind]
    leaves, treedef = jax.tree_util.tree_flatten(deltas)
    n = max(float(n_k.sum()), 1.0)
    w = np.asarray(n_k, np.float64) / n
    out = []
    for li, d in enumerate(leaves):
        scale = shared_leaf_scale(d, pmask, bits)
        lkeys = fastpath_leaf_keys(ckeys, li)
        K = d.shape[0]
        flat = d.reshape(K, -1)
        codes = np.stack([
            np.asarray(quantize_codes_with_scale(
                flat[k], lkeys[k], scale, bits, cfg.stochastic))
            for k in range(K)])
        dequant = codes.astype(np.float64) * float(scale)   # K dequants
        out.append((w @ dequant).reshape(d.shape[1:]))
    return jax.tree_util.tree_unflatten(treedef, out)


@pytest.mark.parametrize("kind,packed", [("int8", False), ("int8", True),
                                         ("int4", False), ("int4", True)])
def test_fast_path_matches_dequantize_then_mean_equal_weights(kind, packed):
    """Equal weights: the int32 code sum is exact, so the only
    divergence from dequantize-then-mean is final f32 rounding — the
    fast path computes fl(csum * fl(scale/n)), two roundings against
    the f64 reference's one, i.e. <= 2 ulp per coordinate (the K
    dequants and K-term f32 accumulation of the slow path are gone;
    bit-exactness proper holds on power-of-two scales, tested below)."""
    rng = np.random.default_rng(3)
    K = 5
    deltas = _tree(rng, K, [(33,), (16, 8), (1,)])
    n_k = jnp.full((K,), 12.0)
    pmask = jnp.ones((K,))
    _, ckeys = _client_keys(0, K)
    cfg = CompressionConfig(kind=kind, packed=packed)
    fast = code_domain_aggregate(cfg, deltas, n_k, pmask, ckeys)
    ref = _reference_wbar(cfg, deltas, n_k, pmask, ckeys)
    for a, b in zip(jax.tree.leaves(fast), jax.tree.leaves(ref)):
        np.testing.assert_allclose(
            np.asarray(a), b.astype(np.float32), rtol=3e-7, atol=1e-9,
            err_msg="fast path beyond 2 ulp of the exact reference")


def test_fast_path_bit_exact_on_pow2_scale_equal_weights():
    """Power-of-two shared scale: every product code * scale is exact
    in f32, so code-domain aggregation and dequantize-then-weighted-
    mean are the SAME real number — bit-exact, no tolerance."""
    K, n = 4, 64
    rng = np.random.default_rng(9)
    # absmax 8.0 in every client's leaf => shared scale = 8/127... not
    # pow2; build codes directly instead: values already on a pow2 grid
    scale = np.float32(0.03125)                      # 2**-5
    codes = rng.integers(-127, 128, size=(K, n)).astype(np.float32)
    deltas = {"w": jnp.asarray(codes * scale)}
    # absmax coordinate pinned so the negotiated scale is exactly pow2
    deltas["w"] = deltas["w"].at[:, 0].set(127.0 * scale)
    n_k = jnp.full((K,), 4.0)
    pmask = jnp.ones((K,))
    _, ckeys = _client_keys(1, K)
    cfg = CompressionConfig(kind="int8", stochastic=False)
    s = shared_leaf_scale(deltas["w"], pmask, 8)
    assert float(s) == 0.03125
    fast = np.asarray(code_domain_aggregate(cfg, deltas, n_k, pmask, ckeys)["w"])
    # slow-path semantics in f32: K dequants then the weighted mean
    lkeys = fastpath_leaf_keys(ckeys, 0)
    deq = jnp.stack([
        quantize_codes_with_scale(deltas["w"][k], lkeys[k], s, 8, False)
        .astype(jnp.float32) * s
        for k in range(K)])
    slow = np.asarray(jnp.tensordot(n_k / n_k.sum(), deq, axes=(0, 0)))
    np.testing.assert_array_equal(fast, slow)


def _weighted_case(seed, weights):
    rng = np.random.default_rng(seed)
    K = len(weights)
    deltas = _tree(rng, K, [(128,)])
    n_k = jnp.asarray(weights, jnp.float32)
    pmask = jnp.ones((K,))
    _, ckeys = _client_keys(seed, K)
    return deltas, n_k, pmask, ckeys


@pytest.mark.parametrize("kind", ["int8", "int4"])
def test_fast_path_weighted_within_stochastic_tolerance(kind):
    """Example weighting: the weighted int32 code sum is still exact
    integer arithmetic, so the fast path matches the f64 reference to
    f32 rounding; against the *unquantized* weighted mean it stays
    within one stochastic-rounding grid cell."""
    deltas, n_k, pmask, ckeys = _weighted_case(11, [8, 2, 16, 1])
    cfg = CompressionConfig(kind=kind)
    fast = np.asarray(jax.tree.leaves(
        code_domain_aggregate(cfg, deltas, n_k, pmask, ckeys))[0])
    ref = np.asarray(jax.tree.leaves(
        _reference_wbar(cfg, deltas, n_k, pmask, ckeys))[0])
    np.testing.assert_allclose(fast, ref, rtol=0, atol=1e-6)
    # quantization error bound: |wbar - exact mean| <= shared grid step
    exact = np.tensordot(np.asarray(n_k) / float(n_k.sum()),
                         np.asarray(deltas["l0"]), axes=(0, 0))
    step = float(shared_leaf_scale(deltas["l0"], pmask, _BITS[kind]))
    assert np.abs(fast - exact).max() <= step + 1e-6


def test_shared_scale_excludes_non_participants():
    """A dropped client's (never-transmitted) huge delta must not
    coarsen the cohort's negotiated grid."""
    K = 3
    d = jnp.asarray(np.ones((K, 8), np.float32))
    d = d.at[2].mul(1000.0)
    pmask = jnp.asarray([1.0, 1.0, 0.0])
    s_masked = shared_leaf_scale(d, pmask, 8)
    s_full = shared_leaf_scale(d, jnp.ones((K,)), 8)
    np.testing.assert_allclose(float(s_masked), 1.0 / 127.0, rtol=1e-6)
    np.testing.assert_allclose(float(s_full), 1000.0 / 127.0, rtol=1e-6)
    # all-dropped (cohort rescue guarantees >= 1 participant in the
    # engine; the helper still guards the degenerate scale)
    assert float(shared_leaf_scale(jnp.zeros((K, 8)), pmask, 8) ) > 0


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000),
           kind=st.sampled_from(["int8", "int4"]),
           packed=st.booleans(),
           weights=st.lists(st.integers(0, 50), min_size=2, max_size=6))
    def test_fast_path_parity_property(seed, kind, packed, weights):
        if sum(weights) == 0:
            weights[0] = 1
        deltas, n_k, pmask, ckeys = _weighted_case(seed, weights)
        cfg = CompressionConfig(kind=kind, packed=packed)
        fast = np.asarray(jax.tree.leaves(
            code_domain_aggregate(cfg, deltas, n_k, pmask, ckeys))[0])
        ref = np.asarray(jax.tree.leaves(
            _reference_wbar(cfg, deltas, n_k, pmask, ckeys))[0])
        np.testing.assert_allclose(fast, ref, rtol=0, atol=1e-6)

else:  # deterministic fallback sweep

    @pytest.mark.parametrize("seed,kind,packed,weights", [
        (0, "int8", False, [3, 1]), (1, "int4", True, [5, 0, 2]),
        (2, "int8", True, [1, 1, 1, 7]), (3, "int4", False, [50, 2, 9]),
    ])
    def test_fast_path_parity_property(seed, kind, packed, weights):
        deltas, n_k, pmask, ckeys = _weighted_case(seed, weights)
        cfg = CompressionConfig(kind=kind, packed=packed)
        fast = np.asarray(jax.tree.leaves(
            code_domain_aggregate(cfg, deltas, n_k, pmask, ckeys))[0])
        ref = np.asarray(jax.tree.leaves(
            _reference_wbar(cfg, deltas, n_k, pmask, ckeys))[0])
        np.testing.assert_allclose(fast, ref, rtol=0, atol=1e-6)


# ----------------------------------------------------- engine selection

def _plane(plan):
    return _plan_server_plane(plan)


def test_fast_path_static_selection():
    """The fast path is compile-time structure: every compressing plane
    under the paper's weighted mean takes it — int8/int4/topk, with or
    without EF (PR 10: the residual update reads the transmitted codes
    directly). Only robust aggregators, delta adversaries, and the fp32
    plane keep the existing graph."""
    from repro.core.plan import CorruptionConfig

    on = [FederatedPlan(compression=CompressionConfig(kind="int8")),
          FederatedPlan(compression=CompressionConfig(kind="int4", packed=True)),
          FederatedPlan(compression=CompressionConfig(kind="topk")),
          FederatedPlan(compression=CompressionConfig(kind="int8",
                                                      error_feedback=True)),
          FederatedPlan(compression=CompressionConfig(kind="topk",
                                                      error_feedback=True)),
          FederatedPlan(compression=CompressionConfig(kind="int8"),
                        corruption=CorruptionConfig(kind="label_shuffle",
                                                    rate=0.3))]
    for plan in on:
        assert _code_fast_path(_plane(plan)), plan

    off = [FederatedPlan(),
           FederatedPlan(compression=CompressionConfig(kind="int8"),
                         aggregation=AggregatorConfig(name="trimmed_mean")),
           FederatedPlan(compression=CompressionConfig(kind="int8"),
                         corruption=CorruptionConfig(kind="sign_flip",
                                                     rate=0.3))]
    for plan in off:
        assert not _code_fast_path(_plane(plan)), plan


def _round_pieces():
    W = np.random.default_rng(42).normal(size=(4, 2)).astype(np.float32)

    def loss_fn(params, batch, rng):
        pred = batch["x"] @ params["w"]
        w = batch["weight"]
        l = jnp.sum((pred - batch["y"]) ** 2 * w[:, None]) / jnp.maximum(w.sum(), 1)
        return l, {}

    def make_batch(K, S, b, seed=0):
        r = np.random.default_rng(seed)
        x = r.normal(size=(K, S, b, 4)).astype(np.float32)
        return {"x": jnp.array(x), "y": jnp.array(x @ W),
                "weight": jnp.ones((K, S, b), jnp.float32)}

    return loss_fn, make_batch


def test_fast_path_round_wire_metrics_and_convergence():
    """Engine-level: the fast path reports byte-identical wire metrics
    to the accounting formulas (CFMQ parity) and still trains."""
    from repro.core.compression import client_wire_bytes

    loss_fn, make_batch = _round_pieces()
    params0 = {"w": jnp.zeros((4, 2))}
    for kind, packed in [("int8", False), ("int4", True)]:
        plan = FederatedPlan(clients_per_round=4, client_lr=0.1,
                             server_optimizer="sgd", server_lr=1.0,
                             compression=CompressionConfig(kind=kind,
                                                           packed=packed))
        assert _code_fast_path(_plane(plan))
        step = jax.jit(make_round_step(loss_fn, plan, jax.random.PRNGKey(0)))
        state = init_server_state(plan, params0)
        losses = []
        for r in range(20):
            state, m = step(state, make_batch(4, 2, 8, seed=r))
            losses.append(float(m["loss"]))
        assert losses[-1] < 0.05 * losses[0]
        up = client_wire_bytes(plan.compression, params0)
        assert float(m["participants"]) == 4.0
        assert float(m["uplink_bytes"]) == 4.0 * up
        assert float(m["corrupted"]) == 0.0


def test_fast_path_packed_and_unpacked_identical():
    """packed=True only materializes the wire buffer; the codes (and
    therefore the trained model) are bit-identical to the unpacked
    fast path."""
    loss_fn, make_batch = _round_pieces()
    outs = []
    for packed in (False, True):
        plan = FederatedPlan(clients_per_round=4, client_lr=0.1,
                             server_optimizer="sgd", server_lr=1.0,
                             compression=CompressionConfig(kind="int4",
                                                           packed=packed))
        step = jax.jit(make_round_step(loss_fn, plan, jax.random.PRNGKey(0)))
        state = init_server_state(plan, {"w": jnp.zeros((4, 2))})
        for r in range(3):
            state, _ = step(state, make_batch(4, 2, 8, seed=r))
        outs.append(np.asarray(state.params["w"]))
    np.testing.assert_array_equal(outs[0], outs[1])


# ----------------------------------------------- int32 overflow guard

def test_sum_packed_codes_all_saturated_exact():
    """K clients of all-saturated codes accumulate exactly in int32 —
    the property that licenses the code-domain psum. The documented
    wrap bound: sum(weights) * levels < 2**31, i.e. 16,909,320
    saturated int8 clients (306M for int4); far above any cohort."""
    for kind, levels in [("int8", 127), ("int4", 7)]:
        cfg = CompressionConfig(kind=kind)
        for K in (2, 64, 1024):
            codes = jnp.full((K, 33), levels, jnp.int8)
            out = np.asarray(sum_packed_codes(cfg, codes, 33))
            np.testing.assert_array_equal(out, np.full((33,), K * levels))
            assert out.dtype == np.int32
            # weighted: sum(w_k) * levels stays exact too
            w = jnp.full((K,), 16, jnp.int32)
            out = np.asarray(sum_packed_codes(cfg, codes, 33, weights=w))
            np.testing.assert_array_equal(out, np.full((33,), 16 * K * levels))


def test_sum_packed_codes_weighted_matches_manual():
    rng = np.random.default_rng(0)
    cfg = CompressionConfig(kind="int8")
    codes = jnp.asarray(rng.integers(-127, 128, size=(5, 17)), jnp.int8)
    w = jnp.asarray([3, 0, 7, 1, 2], jnp.int32)
    out = np.asarray(sum_packed_codes(cfg, codes, 17, weights=w))
    manual = np.tensordot(np.asarray(w, np.int64),
                          np.asarray(codes, np.int64), axes=(0, 0))
    np.testing.assert_array_equal(out, manual)


def test_sum_packed_codes_packed_int4_unpacks_first():
    from repro.kernels import ref

    cfg = CompressionConfig(kind="int4", packed=True)
    rng = np.random.default_rng(1)
    codes = jnp.asarray(rng.integers(-7, 8, size=(3, 9)), jnp.int8)
    packed = jnp.stack([ref.nibble_pack_ref(codes[i]) for i in range(3)])
    out = np.asarray(sum_packed_codes(cfg, packed, 9))
    np.testing.assert_array_equal(out, np.asarray(codes, np.int32).sum(0))


# --------------------------------------------------- topk payload domain


def test_topk_fast_path_matches_dense_weighted_mean():
    """The payload scatter-add equals the slow path's weighted mean of
    dense top-k trees (top-k transmits exact values, so only f32
    summation order separates them)."""
    from repro.core.compression import _topk_leaf

    rng = np.random.default_rng(21)
    K = 5
    deltas = _tree(rng, K, [(57,), (9, 7), (1,)])
    n_k = jnp.asarray([8.0, 2.0, 16.0, 1.0, 5.0])
    pmask = jnp.ones((K,))
    _, ckeys = _client_keys(4, K)
    cfg = CompressionConfig(kind="topk", topk_frac=0.25)
    fast = code_domain_aggregate(cfg, deltas, n_k, pmask, ckeys)
    w = np.asarray(n_k, np.float64) / float(n_k.sum())
    for name, a in fast.items():
        dense = np.stack([np.asarray(_topk_leaf(deltas[name][k],
                                                cfg.topk_frac), np.float64)
                          for k in range(K)])
        slow = np.tensordot(w, dense, axes=(0, 0))
        np.testing.assert_allclose(np.asarray(a), slow.astype(np.float32),
                                   rtol=1e-6, atol=1e-6, err_msg=name)


def test_topk_fast_path_zero_weight_client_cancels():
    """A dropped client (n_k = 0) contributes nothing to the payload
    scatter even though its (huge) payload is present — mirrors the
    slow path's weighted mean."""
    K = 3
    d = {"w": jnp.asarray(np.ones((K, 16), np.float32))}
    d["w"] = d["w"].at[2].mul(1e6)
    n_k = jnp.asarray([4.0, 4.0, 0.0])
    pmask = jnp.asarray([1.0, 1.0, 0.0])
    _, ckeys = _client_keys(2, K)
    cfg = CompressionConfig(kind="topk", topk_frac=0.5)
    out = np.asarray(code_domain_aggregate(cfg, d, n_k, pmask, ckeys)["w"])
    assert np.abs(out).max() <= 1.0 + 1e-6


# ------------------------------------------------- error feedback (PR 10)


def _ef_case(seed, K, shapes, drop=None):
    rng = np.random.default_rng(seed)
    deltas = _tree(rng, K, shapes)
    ef0 = jax.tree.map(lambda d: jnp.asarray(
        rng.normal(size=d.shape) * 0.1, jnp.float32), deltas)
    n_k = jnp.asarray(rng.integers(1, 9, (K,)), jnp.float32)
    pmask = np.ones((K,), np.float32)
    if drop is not None:
        pmask[drop] = 0.0
        n_k = n_k.at[drop].set(0.0)
    _, ckeys = _client_keys(seed, K)
    return deltas, ef0, n_k, jnp.asarray(pmask), ckeys


@pytest.mark.parametrize("kind,packed", [("int8", False), ("int4", False),
                                         ("int4", True)])
def test_ef_intn_residual_is_transmitted_error(kind, packed):
    """new_ef = (delta + old_ef) - codes * shared_scale, with the codes
    recomputed from the same keys/scale — bitwise; a dropped client
    keeps its old residual bitwise."""
    from repro.core.compression import code_domain_aggregate_ef

    deltas, ef0, n_k, pmask, ckeys = _ef_case(6, 4, [(40,), (6, 5)], drop=1)
    cfg = CompressionConfig(kind=kind, packed=packed, error_feedback=True)
    bits = _BITS[kind]
    wbar, ef1 = code_domain_aggregate_ef(cfg, deltas, n_k, pmask, ckeys, ef0)
    for li, name in enumerate(deltas):
        target = deltas[name] + ef0[name]
        scale = shared_leaf_scale(target, pmask, bits)
        lkeys = fastpath_leaf_keys(ckeys, li)
        K = target.shape[0]
        flat = target.reshape(K, -1)
        codes = jnp.stack([
            quantize_codes_with_scale(flat[k], lkeys[k], scale, bits,
                                      cfg.stochastic) for k in range(K)])
        resid = (flat - codes.astype(jnp.float32) * scale).reshape(target.shape)
        expect = np.where(np.asarray(pmask).reshape((K,) + (1,) * (target.ndim - 1)) > 0,
                          np.asarray(resid), np.asarray(ef0[name]))
        np.testing.assert_array_equal(np.asarray(ef1[name]), expect,
                                      err_msg=name)
        # dropped client: old residual untouched, bitwise
        np.testing.assert_array_equal(np.asarray(ef1[name][1]),
                                      np.asarray(ef0[name][1]))


def test_ef_topk_residual_zeroes_selected_coordinates():
    """top-k sends selected coordinates exactly, so the residual is the
    target with exactly those coordinates zeroed — nothing else moves."""
    from repro.core.compression import code_domain_aggregate_ef, topk_select

    deltas, ef0, n_k, pmask, ckeys = _ef_case(7, 3, [(60,)])
    cfg = CompressionConfig(kind="topk", topk_frac=0.2, error_feedback=True)
    _, ef1 = code_domain_aggregate_ef(cfg, deltas, n_k, pmask, ckeys, ef0)
    target = np.asarray(deltas["l0"] + ef0["l0"])
    got = np.asarray(ef1["l0"])
    for k in range(3):
        _, idx = topk_select(jnp.asarray(target[k]), cfg.topk_frac)
        sel = np.zeros(target.shape[1], bool)
        sel[np.asarray(idx)] = True
        np.testing.assert_array_equal(got[k][sel], 0.0)
        np.testing.assert_array_equal(got[k][~sel], target[k][~sel])


def test_ef_with_zero_residual_matches_plain_aggregate():
    """Round 0 (ef = 0): the EF twin must reproduce the plain fast path
    bitwise — same target, same negotiated scale, same keys."""
    from repro.core.compression import code_domain_aggregate_ef

    for kind in ("int8", "int4", "topk"):
        deltas, _, n_k, pmask, ckeys = _ef_case(8, 4, [(33,), (8, 4)])
        ef0 = jax.tree.map(lambda d: jnp.zeros_like(d), deltas)
        cfg = CompressionConfig(kind=kind, error_feedback=True)
        wbar_ef, _ = code_domain_aggregate_ef(cfg, deltas, n_k, pmask,
                                              ckeys, ef0)
        wbar = code_domain_aggregate(cfg, deltas, n_k, pmask, ckeys)
        for a, b in zip(jax.tree.leaves(wbar_ef), jax.tree.leaves(wbar)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("kind,frac", [("int8", None), ("topk", 0.25),
                                       ("int4", None)])
def test_ef_engine_trains_and_caps_residual(kind, frac):
    """Engine-level EF: the fast path trains through the residual state
    and the residual stays bounded by one grid step (intN) / the dropped
    mass (topk) — EF21's contraction, not a drifting accumulator."""
    loss_fn, make_batch = _round_pieces()
    kw = {"kind": kind, "error_feedback": True}
    if frac is not None:
        kw["topk_frac"] = frac
    plan = FederatedPlan(clients_per_round=4, client_lr=0.1,
                         server_optimizer="sgd", server_lr=1.0,
                         compression=CompressionConfig(**kw))
    assert _code_fast_path(_plane(plan))
    step = jax.jit(make_round_step(loss_fn, plan, jax.random.PRNGKey(0)))
    state = init_server_state(plan, {"w": jnp.zeros((4, 2))})
    assert state.ef is not None
    losses = []
    for r in range(25):
        state, m = step(state, make_batch(4, 2, 8, seed=r))
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.1 * losses[0], losses
    assert np.isfinite(np.asarray(state.ef["w"])).all()


def test_topk_ef_beats_plain_topk_at_aggressive_sparsity():
    """The reason EF exists (paper §compression): at harsh sparsity the
    residual recovers the dropped mass over rounds."""
    loss_fn, make_batch = _round_pieces()

    def run(ef):
        plan = FederatedPlan(clients_per_round=4, client_lr=0.1,
                             server_optimizer="sgd", server_lr=1.0,
                             compression=CompressionConfig(
                                 kind="topk", topk_frac=0.13,
                                 error_feedback=ef))
        step = jax.jit(make_round_step(loss_fn, plan, jax.random.PRNGKey(0)))
        state = init_server_state(plan, {"w": jnp.zeros((4, 2))})
        for r in range(30):
            state, m = step(state, make_batch(4, 2, 8, seed=r))
        return float(m["loss"])

    assert run(True) < run(False)


# ---------------------------------------- fast vs slow path, engine level


def test_topk_fast_vs_slow_engine_wire_bytes_and_state(monkeypatch):
    """Force the generic (slow) graph and compare: wire metrics must be
    BYTE-identical (accounting is static), per-round losses identical
    (client compute untouched), and the trained state equal to f32
    reduction order."""
    import repro.core.fedavg as fedavg_mod

    loss_fn, make_batch = _round_pieces()
    plan = FederatedPlan(clients_per_round=4, client_lr=0.1,
                         server_optimizer="sgd", server_lr=1.0,
                         compression=CompressionConfig(kind="topk",
                                                       topk_frac=0.25))

    def run(force_slow):
        if force_slow:
            monkeypatch.setattr(fedavg_mod, "_code_fast_path",
                                lambda plane: False)
        else:
            monkeypatch.undo()
        step = jax.jit(make_round_step(loss_fn, plan, jax.random.PRNGKey(0)))
        state = init_server_state(plan, {"w": jnp.zeros((4, 2))})
        losses, wire = [], []
        for r in range(5):
            state, m = step(state, make_batch(4, 2, 8, seed=r))
            losses.append(float(m["loss"]))
            wire.append((int(m["uplink_bytes"]), int(m["downlink_bytes"])))
        return state, losses, wire

    s_fast, l_fast, w_fast = run(False)
    s_slow, l_slow, w_slow = run(True)
    assert w_fast == w_slow              # byte-identical wire accounting
    assert l_fast[0] == l_slow[0]        # same client compute, round 0
    np.testing.assert_allclose(np.asarray(s_fast.params["w"]),
                               np.asarray(s_slow.params["w"]),
                               rtol=1e-5, atol=1e-6)
