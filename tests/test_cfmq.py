"""CFMQ (Eqs. 1-2) unit + property tests, incl. the paper's own numbers.

The property tests run under hypothesis when it is installed and fall
back to a fixed deterministic case list otherwise, so tier-1 collects
and passes without the dev extra.
"""
import numpy as np
import pytest

from repro.core.cfmq import (
    accumulate_wire_bytes,
    cfmq,
    mu_local_steps,
    paper_payload,
    paper_peak_memory,
    round_wire_bytes,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs the dev extra
    HAVE_HYPOTHESIS = False


def test_eq1_mu():
    # mu = e*N/(b*K)
    assert mu_local_steps(1, 128, 4, 8) == 4.0
    assert mu_local_steps(2, 128, 4, 8) == 8.0


def test_paper_approximations():
    """Paper §4.3.1: 122M params x 4B ~ 480MB model; round trip ~960MB,
    peak memory ~ model + 10% ~ 660MB (paper quotes 960/660 MB)."""
    model_bytes = 122e6 * 4
    assert abs(paper_payload(model_bytes) - 976e6) / 976e6 < 0.02
    # paper's 660MB uses a slightly different model-size accounting;
    # we check the 1.1x structure rather than the rounded constant
    assert paper_peak_memory(model_bytes) == 1.1 * model_bytes


def test_paper_scale_cfmq():
    """E0-magnitude sanity: R*K*(P + mu*nu) lands in the paper's TB
    range (Table 5 reports ~3000 TB for the baseline config)."""
    model_bytes = 122e6 * 4
    terms = cfmq(rounds=3000, clients_per_round=128, model_bytes=model_bytes,
                 local_steps=1.0)
    assert 100 < terms.total_terabytes < 10000


def _check_cfmq_properties(rounds, K, mb, mu, alpha):
    t = cfmq(rounds=rounds, clients_per_round=K, model_bytes=mb,
             local_steps=mu, alpha=alpha)
    # positivity & linearity in rounds
    assert t.total_bytes > 0
    t2 = cfmq(rounds=2 * rounds, clients_per_round=K, model_bytes=mb,
              local_steps=mu, alpha=alpha)
    np.testing.assert_allclose(t2.total_bytes, 2 * t.total_bytes, rtol=1e-9)
    # monotone in K, mu, alpha
    tK = cfmq(rounds=rounds, clients_per_round=K + 1, model_bytes=mb,
              local_steps=mu, alpha=alpha)
    assert tK.total_bytes >= t.total_bytes
    tmu = cfmq(rounds=rounds, clients_per_round=K, model_bytes=mb,
               local_steps=mu * 2, alpha=alpha)
    assert tmu.total_bytes >= t.total_bytes
    # alpha=0 isolates pure communication R*K*P
    t0 = cfmq(rounds=rounds, clients_per_round=K, model_bytes=mb,
              local_steps=mu, alpha=0.0)
    np.testing.assert_allclose(t0.total_bytes,
                               rounds * K * paper_payload(mb), rtol=1e-9)


# Deterministic fallback grid: corners + paper-magnitude interior points.
CFMQ_CASES = [
    (1, 1, 1e6, 0.1, 0.0),
    (1, 512, 1e12, 100.0, 10.0),
    (3000, 128, 488e6, 1.0, 1.0),
    (10000, 1, 1e6, 100.0, 0.0),
    (7, 32, 5e8, 4.0, 2.5),
    (250, 64, 1e9, 0.5, 0.1),
]


@pytest.mark.parametrize("rounds,K,mb,mu,alpha", CFMQ_CASES)
def test_cfmq_properties_deterministic(rounds, K, mb, mu, alpha):
    _check_cfmq_properties(rounds, K, mb, mu, alpha)


if HAVE_HYPOTHESIS:

    @settings(max_examples=50, deadline=None)
    @given(
        rounds=st.integers(1, 10000),
        K=st.integers(1, 512),
        mb=st.floats(1e6, 1e12),
        mu=st.floats(0.1, 100),
        alpha=st.floats(0.0, 10.0),
    )
    def test_cfmq_properties(rounds, K, mb, mu, alpha):
        _check_cfmq_properties(rounds, K, mb, mu, alpha)


def test_data_limit_reduces_cfmq_e7_vs_e8():
    """Paper Fig. 3b: E7 (data limit 32) beats E8 (no limit) on CFMQ at
    equal quality because mu is smaller."""
    mb = 122e6 * 4
    e7 = cfmq(rounds=3000, clients_per_round=128, model_bytes=mb,
              local_epochs=1, examples_per_round=32 * 128, batch_size=1)
    e8 = cfmq(rounds=3000, clients_per_round=128, model_bytes=mb,
              local_epochs=1, examples_per_round=80 * 128, batch_size=1)
    assert e7.total_bytes < e8.total_bytes


def test_wire_byte_totals_are_exact_ints():
    """Byte totals must accumulate as host-side Python ints: one round
    of a big model exceeds f32's integer-exact range (2^24), where an
    f32 running total silently drops bytes."""
    up = 40 * 1024 * 1024 + 3          # 40 MiB + 3 B per reporting client
    down = 8 * (160 * 1024 * 1024 + 1)
    participants = [7.0, 8.0, 6.0] * 40                       # 120 rounds

    total = accumulate_wire_bytes(up, down, participants)
    assert isinstance(total, int)
    expect = sum(down + up * int(p) for p in participants)
    assert total == expect

    one = round_wire_bytes(up, down, np.float32(7.0))
    assert isinstance(one, int) and one == down + 7 * up

    # the f32 path this replaces really does lose bytes
    f32_total = np.float32(0.0)
    for p in participants:
        f32_total += np.float32(down) + np.float32(up) * np.float32(p)
    assert int(f32_total) != expect
