"""Buffered-async engine + RoundEngine facade correctness.

The load-bearing claims: (a) with B = K, one device tier and zero
jitter the async engine IS the sync engine bit-for-bit (the arrival
stream inserts in client order and flushes exactly once at staleness
0); (b) the buffer carries partial waves across rounds instead of
dropping them; (c) staleness discounts engage exactly when the server
version moves under a buffered delta; (d) invalid engine/plane
combinations fail at ``build_round_engine`` construction, before any
tracing.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AggregatorConfig,
    AsyncConfig,
    CompressionConfig,
    CorruptionConfig,
    FederatedPlan,
    LatencyConfig,
    build_round_engine,
    engine_structural_key,
    init_server_state,
    make_round_step,
    validate_plan,
)
from repro.core.async_engine import staleness_discount

W_TRUE = np.random.default_rng(7).normal(size=(4, 2)).astype(np.float32)


def loss_fn(params, batch, rng):
    pred = batch["x"] @ params["w"]
    w = batch["weight"]
    l = jnp.sum((pred - batch["y"]) ** 2 * w[:, None]) / jnp.maximum(w.sum(), 1)
    return l, {}


def make_batch(K, S, b, seed=0):
    r = np.random.default_rng(seed)
    x = r.normal(size=(K, S, b, 4)).astype(np.float32)
    y = x @ W_TRUE
    return {"x": jnp.array(x), "y": jnp.array(y),
            "weight": jnp.ones((K, S, b), np.float32)}


def params0():
    return {"w": jnp.zeros((4, 2))}


# One device tier, zero jitter: every arrival lands at the same time,
# the stable argsort keeps client order — the sync-parity configuration.
PARITY_LATENCY = LatencyConfig(base_s=60.0, spread=0.0,
                               tier_speeds=(1.0,), tier_probs=(1.0,))


def _plan(**kw):
    base = dict(clients_per_round=4, client_lr=0.1,
                server_optimizer="sgd", server_lr=1.0)
    base.update(kw)
    return FederatedPlan(**base)


def _run(plan, rounds=1, K=None, seed=0):
    K = K or plan.clients_per_round
    step = jax.jit(make_round_step(loss_fn, plan, jax.random.PRNGKey(3)))
    state = init_server_state(plan, params0())
    metrics = None
    for r in range(rounds):
        state, metrics = step(state, make_batch(K, 2, 4, seed=seed + r))
    return state, metrics


# ------------------------------------------------------- sync parity

@pytest.mark.parametrize("beta", [0.0, 0.5, 2.0])
def test_async_b_equals_k_zero_spread_matches_sync_bitwise(beta):
    """B = K + single tier + zero spread: every wave inserts K arrivals
    in client order and flushes once at staleness 0 — the discount is
    exactly 1.0 for ANY beta, so async == sync bit-for-bit over
    multiple rounds."""
    sync, _ = _run(_plan(), rounds=3)
    asyn, m = _run(_plan(engine="async",
                         asynchrony=AsyncConfig(buffer_size=4,
                                                staleness_beta=beta),
                         latency=PARITY_LATENCY), rounds=3)
    np.testing.assert_array_equal(np.asarray(sync.params["w"]),
                                  np.asarray(asyn.params["w"]))
    assert float(m["server_steps"]) == 1.0
    assert float(m["staleness_mean"]) == 0.0
    assert float(m["sim_time_s"]) == 60.0


def test_async_hyper_path_matches_plan_path():
    plan = _plan(engine="async",
                 asynchrony=AsyncConfig(buffer_size=3, staleness_beta=0.5),
                 latency=LatencyConfig(base_s=45.0, spread=0.3))
    key = jax.random.PRNGKey(3)
    eng = build_round_engine(plan, loss_fn, base_key=key)
    state_p = eng.init_state(params0())
    state_h = eng.init_state(params0())
    hyper = jax.jit(eng.hyper_step)
    for r in range(3):
        batch = make_batch(4, 2, 4, seed=r)
        state_p, mp = eng.step(state_p, batch)
        state_h, mh = hyper(state_h, batch, eng.hypers(), key)
        np.testing.assert_array_equal(np.asarray(state_p.params["w"]),
                                      np.asarray(state_h.params["w"]))
        np.testing.assert_array_equal(np.asarray(mp["sim_time_s"]),
                                      np.asarray(mh["sim_time_s"]))


# --------------------------------------------------- buffer dynamics

def test_buffer_never_fills_holds_updates_and_params():
    """B > K: the wave ends with the buffer partially filled, zero
    server steps, params bitwise unchanged — and the arrivals WAIT in
    state.abuf rather than being dropped."""
    plan = _plan(engine="async",
                 asynchrony=AsyncConfig(buffer_size=6, staleness_beta=0.5),
                 latency=PARITY_LATENCY)
    state, m = _run(plan, rounds=1)
    assert float(m["server_steps"]) == 0.0
    np.testing.assert_array_equal(np.asarray(state.params["w"]),
                                  np.asarray(params0()["w"]))
    assert int(state.abuf.count) == 4
    assert int(state.abuf.version) == 0
    # a flushless wave still observes its stream to the last arrival
    assert float(m["sim_time_s"]) == 60.0
    # the second wave's 2 arrivals complete the buffer -> one flush of
    # now-stale wave-1 deltas
    step = jax.jit(make_round_step(loss_fn, plan, jax.random.PRNGKey(3)))
    state2, m2 = step(state, make_batch(4, 2, 4, seed=1))
    assert float(m2["server_steps"]) == 1.0
    assert int(state2.abuf.count) == 2


def test_all_stale_flush_statistics():
    """B = 2, K = 4, full participation: flush 1 lands mid-wave at
    staleness 0, bumping the version under the remaining arrivals, so
    flush 2 is ALL-stale (both deltas downloaded one version ago).
    staleness_mean = (0 + 0 + 1 + 1) / 4."""
    plan = _plan(engine="async",
                 asynchrony=AsyncConfig(buffer_size=2, staleness_beta=0.5),
                 latency=PARITY_LATENCY)
    state, m = _run(plan, rounds=1)
    assert float(m["server_steps"]) == 2.0
    assert float(m["staleness_mean"]) == pytest.approx(0.5)
    assert int(state.abuf.version) == 2


def test_staleness_discount_exactness_and_effect():
    # bitwise-exact 1.0 on both parity axes: s = 0 (any beta) and
    # beta = 0 (any s) — the sync-parity tests cost no tolerance
    s = jnp.asarray([0.0, 1.0, 3.0, 10.0])
    assert np.all(np.asarray(staleness_discount(jnp.zeros(4), 1.7)) == 1.0)
    assert np.all(np.asarray(staleness_discount(s, 0.0)) == 1.0)
    np.testing.assert_allclose(np.asarray(staleness_discount(s, 1.0)),
                               1.0 / (1.0 + np.asarray(s)), rtol=1e-6)
    # beta = 0 is the unweighted engine; a nonzero beta must actually
    # change the params whenever a stale flush occurs (B = 2 above)
    mk = lambda b: _plan(engine="async",
                         asynchrony=AsyncConfig(buffer_size=2,
                                                staleness_beta=b),
                         latency=PARITY_LATENCY)
    w0 = np.asarray(_run(mk(0.0), rounds=1)[0].params["w"])
    w0b = np.asarray(_run(mk(0.0), rounds=1)[0].params["w"])
    w1 = np.asarray(_run(mk(1.0), rounds=1)[0].params["w"])
    np.testing.assert_array_equal(w0, w0b)
    assert not np.array_equal(w0, w1)


def test_async_wins_wall_clock_when_buffer_not_divisor():
    """B does not divide K: leftovers cycle across waves, so the last
    flush of a wave generally precedes the slowest arrival — async's
    sim_time_s must undercut the sync barrier's on the same latency
    draw."""
    lat = LatencyConfig(enabled=True, base_s=60.0, spread=0.4)
    _, ms = _run(_plan(latency=lat), rounds=2, seed=5)
    _, ma = _run(_plan(engine="async",
                       asynchrony=AsyncConfig(buffer_size=3,
                                              staleness_beta=0.5),
                       latency=lat), rounds=2, seed=5)
    assert float(ma["sim_time_s"]) < float(ms["sim_time_s"])


# -------------------------------------- construction-time validation

def test_build_round_engine_rejects_invalid_plans():
    bad = [
        _plan(engine="fedsgd",
              aggregation=AggregatorConfig(name="coordinate_median")),
        _plan(engine="fedsgd",
              compression=CompressionConfig(kind="topk",
                                            error_feedback=True)),
        _plan(engine="async",
              asynchrony=AsyncConfig(buffer_size=-1)),
        _plan(engine="async",
              asynchrony=AsyncConfig(staleness_beta=-0.5)),
        dataclasses.replace(_plan(), engine="fedmystery"),
    ]
    for plan in bad:
        with pytest.raises(ValueError):
            build_round_engine(plan, loss_fn)
        with pytest.raises(ValueError):
            validate_plan(plan)
    # the messages carry the capability gap, not a traced-shape error
    with pytest.raises(ValueError, match="fedsgd"):
        build_round_engine(bad[0], loss_fn)


def test_structural_key_shares_traced_knobs_only():
    a = _plan(engine="async",
              asynchrony=AsyncConfig(buffer_size=3, staleness_beta=0.5),
              latency=LatencyConfig(base_s=60.0, spread=0.3))
    # beta / base_s / spread are traced: same compiled graph
    b = dataclasses.replace(
        a, asynchrony=AsyncConfig(buffer_size=3, staleness_beta=2.0),
        latency=LatencyConfig(base_s=10.0, spread=0.9))
    assert engine_structural_key(a) == engine_structural_key(b)
    # buffer size shapes the buffer: different graph
    c = dataclasses.replace(a, asynchrony=AsyncConfig(buffer_size=4))
    assert engine_structural_key(a) != engine_structural_key(c)
    # sync plans only grow a latency facet when pricing is enabled
    assert engine_structural_key(_plan()) == engine_structural_key(
        _plan(latency=LatencyConfig(base_s=999.0)))
    assert engine_structural_key(_plan()) != engine_structural_key(
        _plan(latency=LatencyConfig(enabled=True)))


def test_legacy_aggregator_kwargs_warn_and_fold_in():
    with pytest.warns(DeprecationWarning, match="AggregatorConfig"):
        plan = FederatedPlan(aggregator="trimmed_mean", agg_trim_frac=0.2,
                             dp_sigma=0.3)
    assert plan.aggregation == AggregatorConfig(name="trimmed_mean",
                                                trim_frac=0.2, dp_sigma=0.3)
    # dataclasses.replace must neither re-warn nor clobber
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        plan2 = dataclasses.replace(plan, clients_per_round=2)
        plan3 = dataclasses.replace(
            plan, aggregation=AggregatorConfig(name="weighted_mean"))
    assert plan2.aggregation == plan.aggregation
    assert plan3.aggregation.name == "weighted_mean"
