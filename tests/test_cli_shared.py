"""The shared CLI surface and the AggregatorConfig migration gate.

``repro.launch.cli`` is the single source of the plan-shaping flags;
both drivers (``launch.train``, ``launch.sweeps``) must expose exactly
the builder inventories (snapshot-style, so a flag added to one parser
but not the builder fails here). The deprecation gate asserts no
in-repo code path still constructs plans through the flat aggregator
kwargs the 0.2 removal will break.
"""
import argparse
import warnings

import pytest

from repro.launch.cli import (
    CLIENT_EVAL_FLAGS,
    PLAN_FLAGS,
    SCALE_FLAGS,
    add_client_eval_args,
    add_plan_args,
    add_scale_args,
    plan_kwargs,
    plan_overrides,
)


def _flags(parser: argparse.ArgumentParser) -> set:
    return {opt for a in parser._actions for opt in a.option_strings
            if opt.startswith("--")}


# ------------------------------------------------- builder inventories

def test_builders_match_their_inventories():
    for build, inventory in ((add_plan_args, PLAN_FLAGS),
                             (add_scale_args, SCALE_FLAGS),
                             (add_client_eval_args, CLIENT_EVAL_FLAGS)):
        ap = build(argparse.ArgumentParser(add_help=False))
        assert _flags(ap) == set(inventory), build.__name__


@pytest.mark.parametrize("main_module", ["repro.launch.train",
                                         "repro.launch.sweeps"])
def test_both_drivers_expose_the_shared_surface(main_module, monkeypatch, capsys):
    """--help snapshot: every shared flag appears in each driver's
    parser (the drivers add their own schedule/budget flags on top)."""
    import importlib

    mod = importlib.import_module(main_module)
    monkeypatch.setattr("sys.argv", [main_module, "--help"])
    with pytest.raises(SystemExit) as e:
        mod.main()
    assert e.value.code == 0
    helptext = capsys.readouterr().out
    for flag in PLAN_FLAGS + SCALE_FLAGS + CLIENT_EVAL_FLAGS:
        assert flag in helptext, (main_module, flag)


def test_plan_kwargs_roundtrip():
    """Defaults parse to a default plan; every knob lands in its
    config dataclass (never the deprecated flat kwargs)."""
    from repro.core import FederatedPlan

    ap = argparse.ArgumentParser()
    add_plan_args(ap)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        plan = FederatedPlan(**plan_kwargs(ap.parse_args([])))
        assert plan == FederatedPlan()
        args = ap.parse_args([
            "--engine", "async", "--buffer-size", "3",
            "--staleness-beta", "0.9", "--aggregator", "trimmed_mean",
            "--trim-frac", "0.2", "--dp-clip", "0.5", "--dp-sigma", "0.1",
            "--compression", "topk", "--topk-frac", "0.1",
            "--error-feedback", "--participation", "0.8",
            "--straggler-frac", "0.1", "--corrupt-kind", "sign_flip",
            "--corrupt-rate", "0.25", "--corrupt-scale", "2.0",
            "--latency", "--latency-base-s", "30.0",
        ])
        plan = FederatedPlan(**plan_kwargs(args))
    assert plan.engine == "async"
    assert plan.asynchrony.buffer_size == 3
    assert plan.asynchrony.staleness_beta == 0.9
    assert plan.aggregation.name == "trimmed_mean"
    assert plan.aggregation.trim_frac == 0.2
    assert plan.aggregation.dp_clip == 0.5
    assert plan.aggregation.dp_sigma == 0.1
    assert plan.compression.kind == "topk"
    assert plan.compression.error_feedback
    assert plan.cohort.participation == 0.8
    assert plan.corruption.kind == "sign_flip"
    assert plan.corruption.rate == 0.25
    assert plan.latency.enabled and plan.latency.base_s == 30.0


def test_plan_overrides_is_sparse():
    """Only the groups the command line touched override grid plans."""
    ap = add_plan_args(argparse.ArgumentParser(add_help=False))
    assert plan_overrides(ap.parse_args([])) == {}
    over = plan_overrides(ap.parse_args(["--aggregator", "trimmed_mean",
                                         "--participation", "0.9"]))
    assert set(over) == {"aggregation", "cohort"}
    assert over["aggregation"].name == "trimmed_mean"
    assert over["cohort"].participation == 0.9


# ------------------------------------- AggregatorConfig migration gate

def test_flat_agg_kwargs_warn_with_removal_version():
    from repro.core import FederatedPlan

    with pytest.warns(DeprecationWarning, match=r"removed in repro 0\.2"):
        plan = FederatedPlan(aggregator="coordinate_median", dp_sigma=0.5)
    assert plan.aggregation.name == "coordinate_median"
    assert plan.aggregation.dp_sigma == 0.5


def test_no_in_repo_path_emits_the_deprecation():
    """Every plan-constructing surface in the repo — the experiment
    ladder, the sweep grids, the CLI builders — must construct plans
    through AggregatorConfig. Warnings-as-errors over all of them."""
    from repro.launch import sweeps

    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        sweeps.ladder_specs(rounds=4)
        for grid in sweeps.GRIDS.values():
            grid(smoke=True)
        ap = argparse.ArgumentParser()
        add_plan_args(ap)
        plan_kwargs(ap.parse_args([]))
