"""benchmarks/check_regression — the CI bench-regression gate's
comparison semantics, driven directly (no subprocess, no bench run)."""
import json

from benchmarks.check_regression import classify, flatten, make_parser, run_gate

BASE = {
    "smoke": True,
    "kernels": {"us_per_call": {"fed_round_tiny_rnnt": 100.0}},
    "data": {"pack_speedup": 6.0, "pack_us": 50.0, "prefetch_us": 50.0,
             "pass": True},
    "t1": {"pass": True, "final_loss": {"E0": 2.0, "E1": 2.5}},
}


def args(**kw):
    a = make_parser().parse_args([])
    for k, v in kw.items():
        setattr(a, k, v)
    return a


def gate(fresh, **kw):
    return run_gate(BASE, fresh, args(**kw))


def fresh_copy(**edits):
    f = json.loads(json.dumps(BASE))
    for path, v in edits.items():
        node = f
        keys = path.split(".")
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = v
    return f


def failed_paths(rows):
    return {r[0] for r in rows if r[4] == "FAIL"}


def test_identical_passes():
    rows, failed = gate(fresh_copy())
    assert not failed
    assert failed_paths(rows) == set()


def test_classify_paths():
    assert classify("t1.pass") == "bool"
    assert classify("kernels.us_per_call.fed_round_tiny_rnnt") == "fed_time"
    assert classify("kernels.us_per_call.fed_round_tiny_rnnt_int4_packed") == "fed_time"
    # every us_per_call leaf + pack_us is min-over-interleaved-reps
    # now, so the whole family shares the tightened fed_time class
    assert classify("kernels.us_per_call.attention_blockwise_1k") == "fed_time"
    assert classify("kernels.us_per_call.wire_plane_int8") == "fed_time"
    assert classify("data.pack_us") == "fed_time"
    # the sleep-mean prefetch bench keeps the generous generic bound
    assert classify("data.prefetch_us") == "time"
    assert classify("data.pack_speedup") == "speedup"
    # a speedup ratio keeps its direction even under a timing-ish path
    assert classify("kernels.us_per_call.wire_plane_int8_speedup") == "speedup"
    assert classify("kernels.wire_plane.int8_speedup") == "speedup"
    assert classify("kernels.code_fast_path.int8_le_fp32.pass") == "bool"
    assert classify("t1.final_loss.E0") == "loss"
    assert classify("smoke") is None


def test_flatten_nested():
    flat = flatten(BASE)
    assert flat["kernels.us_per_call.fed_round_tiny_rnnt"] == 100.0
    assert flat["t1.final_loss.E1"] == 2.5


def test_time_regression_fails_at_ratio():
    # the fed-round metrics are the tightened class: 2x, not 3x
    rows, failed = gate(fresh_copy(**{"kernels.us_per_call.fed_round_tiny_rnnt": 199.0}))
    assert not failed
    rows, failed = gate(fresh_copy(**{"kernels.us_per_call.fed_round_tiny_rnnt": 201.0}))
    assert failed
    assert failed_paths(rows) == {"kernels.us_per_call.fed_round_tiny_rnnt"}
    # pack_us rides the same tightened 2x class
    rows, failed = gate(fresh_copy(**{"data.pack_us": 101.0}))
    assert failed and failed_paths(rows) == {"data.pack_us"}
    # the sleep-mean prefetch number keeps the generous 3x ceiling
    rows, failed = gate(fresh_copy(**{"data.prefetch_us": 149.0}))
    assert not failed
    rows, failed = gate(fresh_copy(**{"data.prefetch_us": 151.0}))
    assert failed and failed_paths(rows) == {"data.prefetch_us"}


def test_time_improvement_never_fails():
    _, failed = gate(fresh_copy(**{"kernels.us_per_call.fed_round_tiny_rnnt": 1.0}))
    assert not failed


def test_bool_claim_may_not_flip_false():
    rows, failed = gate(fresh_copy(**{"t1.pass": False}))
    assert failed and "t1.pass" in failed_paths(rows)
    # false -> true is an improvement, never a failure
    base = json.loads(json.dumps(BASE))
    base["t1"]["pass"] = False
    rows, failed = run_gate(base, fresh_copy(), args())
    assert not failed


def test_speedup_floor():
    rows, failed = gate(fresh_copy(**{"data.pack_speedup": 2.9}))
    assert failed and "data.pack_speedup" in failed_paths(rows)
    _, failed = gate(fresh_copy(**{"data.pack_speedup": 3.1}))
    assert not failed


def test_loss_rtol():
    _, failed = gate(fresh_copy(**{"t1.final_loss.E0": 2.9}))
    assert not failed                       # within 1.5x
    rows, failed = gate(fresh_copy(**{"t1.final_loss.E0": 3.1}))
    assert failed and "t1.final_loss.E0" in failed_paths(rows)


def test_missing_bench_fails_new_bench_notes():
    f = fresh_copy()
    del f["data"]["pack_us"]
    rows, failed = gate(f)
    assert failed and "data.pack_us" in failed_paths(rows)
    rows, failed = gate(fresh_copy(**{"kernels.us_per_call.new_bench": 5.0}))
    assert not failed
    assert any(r[0].endswith("new_bench") and r[4] == "NOTE" for r in rows)


def test_smoke_flag_must_match():
    rows, failed = gate(fresh_copy(smoke=False))
    assert failed and "smoke" in failed_paths(rows)


def test_knobs_are_tunable():
    f = fresh_copy(**{"kernels.us_per_call.fed_round_tiny_rnnt": 150.0})
    _, failed = gate(f, fed_time_ratio=1.2)
    assert failed
    _, failed = gate(f, fed_time_ratio=2.0)
    assert not failed
    f = fresh_copy(**{"data.prefetch_us": 100.0})
    _, failed = gate(f, time_ratio=1.5)
    assert failed
    _, failed = gate(f, time_ratio=2.5)
    assert not failed


def test_fast_path_claim_never_flips():
    """The 'quantized round <= fp32 round' claims ride the never-flip
    bool class: once the baseline records them True, a fresh run where
    the ordering inverts fails the gate."""
    base = fresh_copy(
        **{"kernels.code_fast_path.int8_le_fp32.pass": True,
           "kernels.code_fast_path.int4_packed_le_fp32.pass": True})
    flipped = json.loads(json.dumps(base))
    flipped["kernels"]["code_fast_path"]["int4_packed_le_fp32"]["pass"] = False
    rows, failed = run_gate(base, flipped, args())
    assert failed
    assert "kernels.code_fast_path.int4_packed_le_fp32.pass" in failed_paths(rows)
    rows, failed = run_gate(base, base, args())
    assert not failed


def test_committed_baseline_matches_fresh_schema():
    """The committed baseline must stay diffable against what
    benchmarks.run --smoke emits today: every gated metric class
    present, smoke flag set."""
    with open("results/bench_baseline.json") as f:
        baseline = json.load(f)
    flat = flatten(baseline)
    assert flat.get("smoke") is True
    kinds = {classify(p) for p in flat}
    assert {"bool", "time", "fed_time", "speedup", "loss"} <= kinds
    # the code-fast-path ordering claims are committed as never-flip
    assert flat.get("kernels.code_fast_path.int8_le_fp32.pass") is True
    assert flat.get("kernels.code_fast_path.int4_packed_le_fp32.pass") is True
    rows, failed = run_gate(baseline, baseline, args())
    assert not failed


def test_cli_missing_baseline_returns_error(tmp_path, monkeypatch, capsys):
    from benchmarks import check_regression

    fresh = tmp_path / "fresh.json"
    fresh.write_text(json.dumps(BASE))
    monkeypatch.setattr(
        "sys.argv",
        ["prog", "--fresh", str(fresh), "--baseline", str(tmp_path / "nope.json")],
    )
    assert check_regression.main() == 1
    assert "no baseline" in capsys.readouterr().out


def test_cli_missing_fresh_returns_error(tmp_path, monkeypatch, capsys):
    from benchmarks import check_regression

    missing = str(tmp_path / "nope.json")
    base = tmp_path / "base.json"
    base.write_text(json.dumps(BASE))
    for extra in ([], ["--update-baseline"]):
        argv = ["prog", "--fresh", missing, "--baseline", str(base)] + extra
        monkeypatch.setattr("sys.argv", argv)
        assert check_regression.main() == 1
        assert "no fresh summary" in capsys.readouterr().out


def test_cli_update_baseline_roundtrip(tmp_path, monkeypatch):
    from benchmarks import check_regression

    fresh = tmp_path / "fresh.json"
    base = tmp_path / "base.json"
    fresh.write_text(json.dumps(BASE))
    monkeypatch.setattr(
        "sys.argv",
        ["prog", "--fresh", str(fresh), "--baseline", str(base),
         "--update-baseline"],
    )
    assert check_regression.main() == 0
    monkeypatch.setattr(
        "sys.argv", ["prog", "--fresh", str(fresh), "--baseline", str(base)]
    )
    assert check_regression.main() == 0
