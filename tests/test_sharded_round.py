"""Client-sharded round parity: the refactor's correctness bar.

The sharded execution path (``fedavg.ClientSharding`` over a mesh with
a named ``clients`` axis) must reproduce the plain vmap round
BIT-FOR-BIT on a 1-device mesh — fp32, the int8/int4 code-domain fast
path, the async engine, and the hyper path all included. The reduction
story makes this provable rather than hoped-for: the code fast path's
cross-client ops are a pmax (exact), an int32 code psum (exact and
order-independent), and an f32 psum of integer-valued n_k (exact below
2^24); the per-client scan itself is untouched because the sharded body
runs the same vmap on each shard's slice with global client indices.

On a multi-device host mesh (these tests skip unless
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` was exported
before jax initialized — the dedicated CI job does this) the code-path
variants stay bitwise; fp32 is allclose-only because XLA fuses the
per-client matmul differently at per-shard batch sizes.

Also here: the VirtualPopulation sampling contract (deterministic,
distinct, O(visited) host state at million-client scale) and the cost
predictor's sharded feature layout.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CompressionConfig, FederatedPlan
from repro.core.engine import build_round_engine
from repro.core.fedavg import ClientSharding
from repro.launch.mesh import make_federated_mesh

W_TRUE = np.random.default_rng(42).normal(size=(4, 2)).astype(np.float32)


def loss_fn(params, batch, rng):
    pred = batch["x"] @ params["w"]
    w = batch["weight"]
    return jnp.sum((pred - batch["y"]) ** 2 * w[:, None]) / jnp.maximum(w.sum(), 1), {}


def make_batch(K, S, b, seed=0):
    r = np.random.default_rng(seed)
    x = r.normal(size=(K, S, b, 4)).astype(np.float32)
    return {"x": jnp.array(x), "y": jnp.array(x @ W_TRUE),
            "weight": jnp.ones((K, S, b), jnp.float32)}


def params0():
    return {"w": jnp.zeros((4, 2), jnp.float32)}


def _plan(name, K):
    return {
        "fp32": FederatedPlan(clients_per_round=K),
        "int8": FederatedPlan(clients_per_round=K,
                              compression=CompressionConfig(kind="int8")),
        "int4p": FederatedPlan(clients_per_round=K,
                               compression=CompressionConfig(kind="int4", packed=True)),
        "topk": FederatedPlan(clients_per_round=K,
                              compression=CompressionConfig(kind="topk")),
        "int8ef": FederatedPlan(clients_per_round=K,
                                compression=CompressionConfig(
                                    kind="int8", error_feedback=True)),
        "topkef": FederatedPlan(clients_per_round=K,
                                compression=CompressionConfig(
                                    kind="topk", error_feedback=True)),
        "async": FederatedPlan(clients_per_round=K, engine="async"),
    }[name]


def _run_pair(plan, sharding, K):
    base = build_round_engine(plan, loss_fn, base_key=jax.random.PRNGKey(0))
    shard = build_round_engine(plan, loss_fn, base_key=jax.random.PRNGKey(0),
                               client_sharding=sharding)
    assert base.structural_key != shard.structural_key
    batch = make_batch(K, 2, 3)
    s0 = base.init_state(params0())
    sa, ma = jax.jit(base.step)(s0, batch)
    sb, mb = jax.jit(shard.step)(s0, batch)
    return (sa, ma), (sb, mb), (base, shard, s0, batch)


def _assert_tree_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _assert_tree_close(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-6, atol=1e-7)


# ------------------------------------------------- 1-device bit-for-bit

VARIANTS = ["fp32", "int8", "int4p", "topk", "int8ef", "topkef", "async"]


@pytest.mark.parametrize("name", VARIANTS)
def test_one_device_mesh_is_bitwise(name):
    """The hard bar: a 1-shard mesh reproduces the vmap round exactly —
    state leaves AND every metric, plan-constant AND hyper path."""
    K = 4
    sh = ClientSharding(make_federated_mesh(1))
    (sa, ma), (sb, mb), (base, shard, s0, batch) = _run_pair(_plan(name, K), sh, K)
    _assert_tree_equal(sa, sb)
    for k in ma:
        np.testing.assert_array_equal(np.asarray(ma[k]), np.asarray(mb[k]),
                                      err_msg=k)
    ha, mha = jax.jit(base.hyper_step)(s0, batch, base.hypers(),
                                       jax.random.PRNGKey(0))
    hb, mhb = jax.jit(shard.hyper_step)(s0, batch, shard.hypers(),
                                        jax.random.PRNGKey(0))
    _assert_tree_equal(ha, hb)
    for k in mha:
        np.testing.assert_array_equal(np.asarray(mha[k]), np.asarray(mhb[k]),
                                      err_msg=k)


# --------------------------------------------------- 8-device host mesh

needs_8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "before jax initializes (the sharded-smoke CI job)")


@needs_8
@pytest.mark.parametrize("name", ["int8", "int4p", "int8ef", "async"])
def test_eight_device_code_paths_bitwise(name):
    """Across real shards the code-domain variants keep the SERVER
    STATE bitwise: pmax, int32 psum and the integer-valued n_k psum are
    all exact, and the per-client scan arithmetic is shard-local. The
    reported mean-loss metric is an f32 sum reduced in a different
    order (8 partials + psum vs one pass over 16), so it gets a 1-ulp
    tolerance; integer-semantics metrics stay exact."""
    K = 16
    sh = ClientSharding(make_federated_mesh(8))
    (sa, ma), (sb, mb), _ = _run_pair(_plan(name, K), sh, K)
    _assert_tree_equal(sa, sb)
    for k in ("participants", "corrupted", "server_steps"):
        np.testing.assert_array_equal(np.asarray(ma[k]), np.asarray(mb[k]),
                                      err_msg=k)
    for k in ma:
        np.testing.assert_allclose(np.asarray(ma[k]), np.asarray(mb[k]),
                                   rtol=1e-6, atol=1e-7, err_msg=k)


@needs_8
def test_eight_device_fp32_allclose():
    """fp32 deltas are f32-summed, and XLA fuses the per-client matmul
    differently at per-shard batch 2 vs global 16 — allclose, not
    bitwise, is the honest contract off the code path."""
    K = 16
    sh = ClientSharding(make_federated_mesh(8))
    (sa, _), (sb, _), _ = _run_pair(_plan("fp32", K), sh, K)
    _assert_tree_close(sa, sb)


@needs_8
def test_eight_device_convergence_matches():
    """Five sharded rounds track five vmap rounds on the same stream."""
    K = 16
    plan = _plan("int8", K)
    sh = ClientSharding(make_federated_mesh(8))
    base = build_round_engine(plan, loss_fn, base_key=jax.random.PRNGKey(0))
    shard = build_round_engine(plan, loss_fn, base_key=jax.random.PRNGKey(0),
                               client_sharding=sh)
    sa = sb = base.init_state(params0())
    for r in range(5):
        batch = make_batch(K, 2, 3, seed=r)
        sa, ma = jax.jit(base.step)(sa, batch)
        sb, mb = jax.jit(shard.step)(sb, batch)
    _assert_tree_equal(sa, sb)
    assert float(ma["loss"]) == pytest.approx(float(mb["loss"]), rel=1e-6)


# ----------------------------------------------- construction contracts

def test_sharding_validation():
    sh = ClientSharding(make_federated_mesh(1))
    assert sh.num_shards == 1
    assert sh.structural() == ("clients_sharded", "clients", 1)
    with pytest.raises(ValueError, match="needs"):
        make_federated_mesh(max(9, jax.device_count() + 1))
    with pytest.raises(ValueError):
        make_federated_mesh(0)
    # fedsgd has no per-client axis to shard
    plan = FederatedPlan(clients_per_round=4, engine="fedsgd")
    with pytest.raises(ValueError, match="fedsgd"):
        build_round_engine(plan, loss_fn, base_key=jax.random.PRNGKey(0),
                           client_sharding=sh)


@needs_8
def test_sharding_requires_divisible_cohort():
    sh = ClientSharding(make_federated_mesh(8))
    plan = FederatedPlan(clients_per_round=12)   # 12 % 8 != 0
    with pytest.raises(ValueError, match="divide"):
        build_round_engine(plan, loss_fn, base_key=jax.random.PRNGKey(0),
                           client_sharding=sh)


# -------------------------------------------- predictor sharded layout

def test_predictor_sharded_features():
    """Per-shard compute, invariant client wire bytes, a ring-psum ICI
    term that is exactly zero on one device (so unsharded calibration
    and every committed coefficient set stay valid)."""
    from repro.profile import predict

    params = {"w": np.zeros((64, 32), np.float32)}
    plan = FederatedPlan(clients_per_round=8, local_batch_size=4)
    f1 = predict.plan_round_features(plan, params, steps=3)
    f8 = predict.plan_round_features(plan, params, steps=3, client_shards=8)
    assert f1["ici_bytes"] == 0.0
    assert f8["flops"] == f1["flops"] / 8
    assert f8["hbm_bytes"] == f1["hbm_bytes"] / 8
    assert f8["wire_bytes"] == f1["wire_bytes"]     # uplink is per-client
    assert f8["ici_bytes"] == 2.0 * (7 / 8) * 4.0 * (64 * 32)
    # pre-sharding feature dicts (no ici_bytes key) must stay loadable
    legacy = {k: v for k, v in f1.items() if k != "ici_bytes"}
    assert predict.predict_round_seconds(legacy) == \
        predict.predict_round_seconds(f1)


# ------------------------------------------------- virtual populations

def _vp(n_clients=1_000_000, seed=1):
    from repro.data import VirtualPopulation, make_speaker_corpus

    base = make_speaker_corpus(num_speakers=12, vocab_size=32, feat_dim=8,
                               mean_utterances=10.0, seed=seed)
    return VirtualPopulation(base, n_clients)


def test_virtual_population_sampling_deterministic():
    """Fixed seed -> identical cohorts; every draw distinct and in
    range; all three registry strategies run in O(K log P) over a
    million-client population."""
    from repro.data import get_strategy

    vp = _vp()
    for name in ("uniform", "weighted-by-examples", "stratified"):
        strat = get_strategy(name)
        a = strat(np.random.default_rng(7), vp, 32)
        b = strat(np.random.default_rng(7), vp, 32)
        np.testing.assert_array_equal(a, b)
        assert len(set(int(v) for v in a)) == 32
        assert a.min() >= 0 and a.max() < vp.num_clients
        c = strat(np.random.default_rng(8), vp, 32)
        assert not np.array_equal(a, c)


def test_virtual_population_memory_envelope():
    """A round over 1e6 virtual clients must not allocate any N-sized
    array: sampler state stays O(participants-visited)."""
    from repro.data import FederatedSampler

    vp = _vp()
    s = FederatedSampler(vp, clients_per_round=32, local_batch_size=2,
                         data_limit=2, seed=0)
    for _ in range(3):
        rb = s.next_round()
        assert rb.features.shape[0] == 32
    assert len(s._orders) <= 3 * 32
    assert len(s._cursors) <= 3 * 32
    assert max(s._cursors) < vp.num_clients


def test_virtual_population_weighted_follows_counts():
    """weighted-by-examples over the virtual population still tracks
    the base histogram: heavy base speakers surface more often."""
    from repro.data import get_strategy

    vp = _vp(n_clients=120_000)
    counts = vp.base_counts
    rng = np.random.default_rng(0)
    strat = get_strategy("weighted-by-examples")
    hits = np.zeros(len(counts), np.int64)
    for _ in range(200):
        np.add.at(hits, vp.base_of(strat(rng, vp, 16)), 1)
    heavy, light = int(np.argmax(counts)), int(np.argmin(counts))
    assert hits[heavy] > hits[light]


def test_virtual_population_validation():
    from repro.data import VirtualPopulation, make_speaker_corpus

    base = make_speaker_corpus(num_speakers=12, vocab_size=32, feat_dim=8,
                               mean_utterances=10.0, seed=1)
    with pytest.raises(ValueError):
        VirtualPopulation(base, 11)          # fewer clients than speakers
    vp = VirtualPopulation(base, 25)
    assert vp.clone_counts().sum() == 25
    assert vp.num_speakers == 25
    np.testing.assert_array_equal(vp.base_of([0, 12, 24]), [0, 0, 0])
