"""Cross-path model consistency: decode==prefill, ring==full cache,
MLA absorbed decode == expanded forward, SSM/RWKV state streaming."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model
from repro.models.hybrid import HybridConfig
from repro.models.mla import MLAConfig, mla_decode, mla_forward, mla_init
from repro.models.model_zoo import RWKVModelConfig
from repro.models.rwkv import RWKVConfig
from repro.models.transformer import TransformerConfig

RNG = np.random.default_rng(0)


def test_mla_decode_matches_forward():
    cfg = MLAConfig(d_model=64, n_heads=4, kv_lora=32, qk_nope_dim=16,
                    qk_rope_dim=8, v_dim=16)
    p = mla_init(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 8, 64)), jnp.float32)
    out_fwd, (ckv, krope) = mla_forward(p, cfg, x)
    ckv_c = jnp.zeros((2, 8, 32))
    kr_c = jnp.zeros((2, 8, 8))
    outs = []
    for t in range(8):
        o, ckv_c, kr_c = mla_decode(p, cfg, x[:, t : t + 1], ckv_c, kr_c,
                                    jnp.asarray(t, jnp.int32))
        outs.append(o[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)), np.asarray(out_fwd),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(ckv_c), np.asarray(ckv), atol=1e-6)


def test_sliding_window_ring_cache_equals_full():
    cfg = TransformerConfig(name="sw", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                            head_dim=16, d_ff=128, vocab=64, dtype="float32",
                            window=8, loss_chunk=16)
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    tok = jnp.asarray(RNG.integers(0, 64, (2, 32)), jnp.int32)
    cfull = m.init_cache(2, 32)
    cring = m.init_cache(2, 32, ring=True)
    assert jax.tree.leaves(cring)[0].shape[2] == 8       # ring buffer = window
    df = jax.jit(lambda *a: m.decode_step(*a, ring=False))
    dr = jax.jit(lambda *a: m.decode_step(*a, ring=True))
    for t in range(32):
        lf, cfull = df(p, cfull, tok[:, t : t + 1], jnp.asarray(t, jnp.int32))
        lr, cring = dr(p, cring, tok[:, t : t + 1], jnp.asarray(t, jnp.int32))
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lr), atol=1e-5)


def test_gemma_pattern_window_layers():
    cfg = TransformerConfig(name="g", n_layers=6, d_model=32, n_heads=2, n_kv=1,
                            head_dim=16, d_ff=64, vocab=32, dtype="float32",
                            window=4, global_every=3)
    w = np.asarray(cfg.layer_windows())
    np.testing.assert_array_equal(w, [4, 4, 0, 4, 4, 0])


def test_hybrid_decode_matches_forward_logits():
    cfg = HybridConfig(name="hy", n_layers=5, d_model=64, n_heads=4, n_kv=4,
                       head_dim=16, d_ff=128, vocab=64, attn_every=2,
                       ssm_state=16, ssm_headdim=16, dtype="float32", loss_chunk=8)
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    S = 12
    tok = jnp.asarray(RNG.integers(0, 64, (2, S)), jnp.int32)
    # teacher-forced final hidden -> logits of last token
    from repro.models.hybrid import forward

    h = forward(cfg, p, tok)
    logits_tf = (h[:, -1] @ p["unembed"]).astype(jnp.float32)
    cache = m.init_cache(2, S)
    dstep = jax.jit(m.decode_step)
    for t in range(S):
        lg, cache = dstep(p, cache, tok[:, t : t + 1], jnp.asarray(t, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_tf),
                               atol=5e-4, rtol=5e-4)


def test_rwkv_streaming_equals_batch():
    cfg = RWKVModelConfig(name="rw", n_layers=2,
                          rwkv=RWKVConfig(d_model=64, head_size=16, d_ff=128,
                                          decay_lora=8),
                          vocab=64, dtype="float32", loss_chunk=16)
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    tok = jnp.asarray(RNG.integers(0, 64, (2, 16)), jnp.int32)
    lp, _ = jax.jit(m.prefill)(p, {"tokens": tok})
    cache = m.init_cache(2, 16)
    dstep = jax.jit(m.decode_step)
    for t in range(16):
        lg, cache = dstep(p, cache, tok[:, t : t + 1], jnp.asarray(t, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lp), atol=1e-5)


def test_mamba_step_streams_forward():
    from repro.models.ssm import MambaConfig, mamba_forward, mamba_init, mamba_init_state, mamba_step

    cfg = MambaConfig(d_model=32, headdim=16, d_state=8)
    p = mamba_init(jax.random.PRNGKey(2), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 10, 32)), jnp.float32)
    y_full = mamba_forward(p, cfg, x)
    st = mamba_init_state(cfg, 2)
    ys = []
    for t in range(10):
        y, st = mamba_step(p, cfg, x[:, t : t + 1], st)
        ys.append(y[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)), np.asarray(y_full),
                               atol=2e-5)


def test_specaugment_masks_and_preserves_shape():
    from repro.asr.specaugment import SpecAugmentConfig, spec_augment

    x = jnp.ones((2, 50, 16))
    cfg = SpecAugmentConfig(freq_masks=2, freq_mask_width=4, time_masks=2,
                            time_mask_frac=0.2)
    y = spec_augment(jax.random.PRNGKey(0), x, cfg)
    assert y.shape == x.shape
    assert float(y.sum()) < float(x.sum())          # something was masked
    y2 = spec_augment(jax.random.PRNGKey(0), x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2))  # deterministic
    assert float(jnp.abs(
        spec_augment(jax.random.PRNGKey(1), x, cfg) - y).max()) > 0


def test_vlm_loss_masks_image_positions():
    from repro.models.vlm import VLMConfig

    lm = TransformerConfig(name="lm", n_layers=1, d_model=32, n_heads=2, n_kv=2,
                           head_dim=16, d_ff=64, vocab=32, dtype="float32",
                           loss_chunk=8)
    cfg = VLMConfig(name="v", lm=lm, vit_dim=16, n_img_tokens=4)
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    batch = {
        "image_embeds": jnp.asarray(RNG.normal(size=(2, 4, 16)), jnp.float32),
        "tokens": jnp.asarray(RNG.integers(0, 32, (2, 8)), jnp.int32),
    }
    loss, _ = m.loss_fn(p, batch, None)
    assert bool(jnp.isfinite(loss))
    # changing image content changes the loss (cross-modal flow)
    batch2 = dict(batch, image_embeds=batch["image_embeds"] + 1.0)
    loss2, _ = m.loss_fn(p, batch2, None)
    assert abs(float(loss - loss2)) > 1e-6


def test_mamba_chunked_ssd_matches_scan():
    """The §Perf chunked SSD formulation is exact vs the sequential scan."""
    from repro.models.ssm import MambaConfig, mamba_forward, mamba_forward_chunked, mamba_init

    cfg = MambaConfig(d_model=32, headdim=16, d_state=8)
    p = mamba_init(jax.random.PRNGKey(2), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 40, 32)), jnp.float32)
    y1 = mamba_forward(p, cfg, x)
    for chunk in (5, 8, 40):
        y2 = mamba_forward_chunked(p, cfg, x, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y2), np.asarray(y1), atol=3e-5)
    # grads agree where the sequential-scan reference is finite. (The
    # scan path's VJP can underflow to NaN through 40-step decay
    # products; the chunked-SSD path works in cumulative log-decays and
    # stays finite — a robustness win of the SSD formulation.)
    g1 = jax.grad(lambda pp: mamba_forward(pp, cfg, x).sum())(p)
    g2 = jax.grad(lambda pp: mamba_forward_chunked(pp, cfg, x, chunk=8).sum())(p)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g2))
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        a, b = np.asarray(a), np.asarray(b)
        finite = np.isfinite(a)
        np.testing.assert_allclose(a[finite], b[finite], atol=1e-3, rtol=1e-3)


def test_hybrid_chunked_flag():
    import dataclasses as dc

    from repro.models.hybrid import HybridConfig, forward

    cfg = HybridConfig(name="hy", n_layers=4, d_model=32, n_heads=2, n_kv=2,
                       head_dim=16, d_ff=64, vocab=32, attn_every=2,
                       ssm_state=8, ssm_headdim=16, dtype="float32", loss_chunk=8)
    from repro.models import build_model

    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    tok = jnp.asarray(RNG.integers(0, 32, (2, 16)), jnp.int32)
    h1 = forward(cfg, p, tok)
    h2 = forward(dc.replace(cfg, ssm_chunked=True), p, tok)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h1), atol=3e-5)
