"""Server plane: aggregation registry, cohort dynamics, and the
legacy-parity guarantee of the composed round pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AggregatorConfig,
    CohortConfig,
    CompressionConfig,
    FederatedPlan,
    available_aggregators,
    get_aggregator,
    init_server_state,
    make_hyper_round_step,
    make_round_step,
    plan_hypers,
)
from repro.core.aggregation import AGG_HYPER_DEFAULTS
from repro.core.cohort import make_cohort_fn, participation_mask, straggler_step_mask

W_TRUE = np.random.default_rng(42).normal(size=(4, 2)).astype(np.float32)


def loss_fn(params, batch, rng):
    pred = batch["x"] @ params["w"]
    w = batch["weight"]
    l = jnp.sum((pred - batch["y"]) ** 2 * w[:, None]) / jnp.maximum(w.sum(), 1)
    return l, {}


def make_batch(K, S, b, seed=0, weights=None):
    r = np.random.default_rng(seed)
    x = r.normal(size=(K, S, b, 4)).astype(np.float32)
    y = x @ W_TRUE
    w = np.ones((K, S, b), np.float32) if weights is None else weights
    return {"x": jnp.array(x), "y": jnp.array(y), "weight": jnp.array(w)}


def params0():
    return {"w": jnp.zeros((4, 2))}


# ------------------------------------------------------------ registry

def test_registry_contents():
    assert {"weighted_mean", "trimmed_mean", "coordinate_median",
            "clipped_mean"} <= set(available_aggregators())
    with pytest.raises(KeyError, match="unknown aggregator"):
        get_aggregator("krum")


def _deltas(vals):
    """(K,) per-client scalar deltas as a 1-leaf tree of shape (K, 1)."""
    return {"w": jnp.asarray(np.asarray(vals, np.float32)[:, None])}


def _run(name, vals, pmask=None, n_k=None, hypers=None, key=0):
    vals = np.asarray(vals, np.float32)
    K = len(vals)
    pmask = jnp.ones((K,)) if pmask is None else jnp.asarray(pmask, jnp.float32)
    n_k = pmask if n_k is None else jnp.asarray(n_k, jnp.float32)
    h = dict(AGG_HYPER_DEFAULTS, **(hypers or {}))
    out = get_aggregator(name)(_deltas(vals), n_k, pmask, h, jax.random.PRNGKey(key))
    return float(out["w"][0])


def test_weighted_mean_is_example_weighted():
    v = _run("weighted_mean", [1.0, 4.0], n_k=[3.0, 1.0])
    np.testing.assert_allclose(v, (3 * 1.0 + 1 * 4.0) / 4.0, rtol=1e-6)


def test_trimmed_mean_rejects_outlier():
    # 5 participants, one wild outlier; trim 20% per side drops it
    v = _run("trimmed_mean", [1.0, 1.1, 0.9, 1.0, 100.0],
             hypers={"trim_frac": 0.2})
    np.testing.assert_allclose(v, np.mean([1.0, 1.1, 1.0]), rtol=1e-5)


def test_trimmed_mean_never_trims_everyone():
    """Degenerate trim_frac must not silently zero the update: the trim
    is clamped so at least one client survives."""
    for frac in (0.5, 0.9):
        v = _run("trimmed_mean", [1.0, 2.0, 3.0, 4.0], hypers={"trim_frac": frac})
        np.testing.assert_allclose(v, 2.5, rtol=1e-5)    # middle two survive
    v = _run("trimmed_mean", [7.0], hypers={"trim_frac": 0.9})
    np.testing.assert_allclose(v, 7.0, rtol=1e-6)


def test_trimmed_mean_ignores_non_participants():
    # dropped clients carry delta 0 — they must not drag the trim window
    v = _run("trimmed_mean", [1.0, 1.2, 0.8, 0.0, 0.0],
             pmask=[1, 1, 1, 0, 0], hypers={"trim_frac": 0.0})
    np.testing.assert_allclose(v, 1.0, rtol=1e-5)


def test_coordinate_median_odd_and_even():
    v = _run("coordinate_median", [1.0, 5.0, 2.0])
    np.testing.assert_allclose(v, 2.0, rtol=1e-6)
    v = _run("coordinate_median", [1.0, 5.0, 2.0, 4.0])
    np.testing.assert_allclose(v, 3.0, rtol=1e-6)     # mean of middle two
    v = _run("coordinate_median", [1.0, 5.0, 2.0, 999.0], pmask=[1, 1, 1, 0])
    np.testing.assert_allclose(v, 2.0, rtol=1e-6)     # masked client excluded


ROBUST = ("trimmed_mean", "coordinate_median", "clipped_mean")


def test_robust_rules_survive_nan_client():
    """A hostile client shipping NaN must be excluded, not propagated:
    NaN * 0 == NaN, so mask-multiplied sums are NOT protection. The
    robust rules treat non-finite coordinates like non-participants."""
    vals = [1.0, 1.2, 0.8, np.nan]
    v = _run("trimmed_mean", vals, hypers={"trim_frac": 0.0})
    np.testing.assert_allclose(v, np.mean([1.0, 1.2, 0.8]), rtol=1e-5)
    v = _run("coordinate_median", vals)
    np.testing.assert_allclose(v, 1.0, rtol=1e-5)
    v = _run("clipped_mean", vals, hypers={"dp_clip": 100.0})
    np.testing.assert_allclose(v, (1.0 + 1.2 + 0.8) / 4.0, rtol=1e-5)


def test_robust_rules_survive_inf_client():
    vals = [1.0, 1.2, 0.8, np.inf, -np.inf]
    v = _run("trimmed_mean", vals, hypers={"trim_frac": 0.0})
    np.testing.assert_allclose(v, np.mean([1.0, 1.2, 0.8]), rtol=1e-5)
    v = _run("coordinate_median", vals)
    np.testing.assert_allclose(v, 1.0, rtol=1e-5)
    v = _run("clipped_mean", vals, hypers={"dp_clip": 100.0})
    np.testing.assert_allclose(v, (1.0 + 1.2 + 0.8) / 5.0, rtol=1e-5)


def test_robust_rules_all_clients_hostile():
    """Every client NaN: the only finite answer is a zero update —
    nothing may leak into the server state."""
    for name in ROBUST:
        v = _run(name, [np.nan] * 4)
        assert np.isfinite(v) and v == 0.0, (name, v)


def test_robust_rules_nan_excluded_per_coordinate():
    """A NaN in one coordinate must not disturb the other coordinates
    of the same client (exclusion is per coordinate, like rank
    masking), except clipped_mean, which must drop the whole client
    (its L2 norm — the DP sensitivity bound — is undefined)."""
    deltas = {"w": jnp.asarray(np.array(
        [[1.0, 5.0], [1.2, 6.0], [0.8, np.nan]], np.float32))}
    ones = jnp.ones((3,))
    h = dict(AGG_HYPER_DEFAULTS, trim_frac=0.0)
    out = np.asarray(get_aggregator("trimmed_mean")(
        deltas, ones, ones, h, jax.random.PRNGKey(0))["w"])
    np.testing.assert_allclose(out, [1.0, 5.5], rtol=1e-5)
    out = np.asarray(get_aggregator("clipped_mean")(
        deltas, ones, ones, dict(AGG_HYPER_DEFAULTS, dp_clip=100.0),
        jax.random.PRNGKey(0))["w"])
    np.testing.assert_allclose(out, [(1.0 + 1.2) / 3.0, 11.0 / 3.0], rtol=1e-5)


def test_trimmed_mean_tie_breaking_even_cohort():
    """Tied values at the trim boundary (even cohort): sort stability
    gives ties distinct ranks, so exactly t clients drop per side —
    a tied pair is never double-trimmed or double-kept."""
    v = _run("trimmed_mean", [1.0, 1.0, 2.0, 2.0], hypers={"trim_frac": 0.25})
    np.testing.assert_allclose(v, 1.5, rtol=1e-6)     # one 1.0 + one 2.0 kept
    # all-tied: any trim keeps the common value
    v = _run("trimmed_mean", [3.0, 3.0, 3.0, 3.0], hypers={"trim_frac": 0.25})
    np.testing.assert_allclose(v, 3.0, rtol=1e-6)


def test_clipped_mean_zero_norm_updates():
    """All-zero deltas have norm 0; the clip scale must clamp (not
    divide by zero) and the result is a clean zero update."""
    v = _run("clipped_mean", [0.0, 0.0, 0.0])
    assert np.isfinite(v) and v == 0.0
    # mixed: zero-norm client contributes nothing but stays counted
    v = _run("clipped_mean", [0.0, 3.0], hypers={"dp_clip": 1.0})
    np.testing.assert_allclose(v, 0.5, rtol=1e-5)


def test_clipped_mean_clips_and_noise():
    # norms 1 and 10; clip 1 -> second contributes its direction only
    v = _run("clipped_mean", [1.0, 10.0], hypers={"dp_clip": 1.0, "dp_sigma": 0.0})
    np.testing.assert_allclose(v, (1.0 + 1.0) / 2.0, rtol=1e-5)
    # DP noise: deterministic per key, different across keys, zero-mean scale
    a = _run("clipped_mean", [1.0, 10.0], hypers={"dp_sigma": 0.5}, key=7)
    b = _run("clipped_mean", [1.0, 10.0], hypers={"dp_sigma": 0.5}, key=7)
    c = _run("clipped_mean", [1.0, 10.0], hypers={"dp_sigma": 0.5}, key=8)
    assert a == b and a != c


# ------------------------------------------------------------ cohort

def test_participation_mask_full_and_never_empty():
    key = jax.random.PRNGKey(0)
    full = participation_mask(key, 8, 1.0)
    np.testing.assert_array_equal(np.asarray(full), np.ones(8))
    # p ~ 0: the rescue keeps exactly the most-available client
    none = participation_mask(key, 8, 1e-9)
    assert float(none.sum()) == 1.0


def test_rescue_selects_exactly_one_on_ties():
    """Float ties in the uniform draw (real at large K in f32) must not
    rescue a whole sub-cohort: the one-hot-over-argmin rescue keeps
    exactly one client, where a ``u == u.min()`` comparison marks all
    tied minima."""
    from repro.core.cohort import rescue_mask

    u = jnp.asarray([0.7, 0.25, 0.25, 0.25, 0.9], jnp.float32)   # 3-way tie
    m = np.asarray(rescue_mask(u))
    assert m.sum() == 1 and m[1]                      # first tied minimum
    # all-tied draw (the worst case): still exactly one
    assert np.asarray(rescue_mask(jnp.zeros(64, jnp.float32))).sum() == 1
    # rescue never fires when any Bernoulli draw survives, so the mask
    # stays one-hot end-to-end at tiny participation too
    for i in range(20):
        mask = participation_mask(jax.random.PRNGKey(i), 256, 1e-9)
        assert float(mask.sum()) == 1.0


def test_straggler_step_mask_truncates():
    key = jax.random.PRNGKey(1)
    w = jnp.ones((6, 4, 2))
    m = straggler_step_mask(key, w, 1.0, 0.5)         # everyone straggles
    np.testing.assert_array_equal(np.asarray(m),
                                  np.tile([1, 1, 0, 0], (6, 1)))
    m = straggler_step_mask(key, w, 0.0, 0.5)         # nobody does
    np.testing.assert_array_equal(np.asarray(m), np.ones((6, 4)))


def test_straggler_mask_ignores_padded_steps():
    """The deadline cut counts *real* steps, so zero-weight padding
    (the sweep runner's pad_steps) never shifts straggler semantics."""
    key = jax.random.PRNGKey(1)
    w = np.ones((6, 8, 2), np.float32)
    w[:, 4:] = 0.0                                    # 4 real + 4 padded steps
    m = straggler_step_mask(key, jnp.asarray(w), 1.0, 0.5)
    # keep ceil(0.5 * 4) = 2 steps — same cut as the unpadded round
    np.testing.assert_array_equal(np.asarray(m)[:, :4],
                                  np.tile([1, 1, 0, 0], (6, 1)))


def test_padded_round_equals_unpadded_with_stragglers():
    """End-to-end pad_steps no-op invariant survives cohort dynamics."""
    plan = FederatedPlan(clients_per_round=3, client_lr=0.1,
                         server_optimizer="sgd", server_lr=1.0,
                         cohort=CohortConfig(straggler_frac=1.0,
                                             straggler_keep=0.5))
    key = jax.random.PRNGKey(6)
    step = jax.jit(make_round_step(loss_fn, plan, key))
    state = init_server_state(plan, params0())
    native = make_batch(3, 4, 2, seed=9)
    pad = np.zeros((3, 4, 2), np.float32)
    padded = {
        "x": jnp.concatenate([native["x"], jnp.zeros((3, 4, 2, 4))], axis=1),
        "y": jnp.concatenate([native["y"], jnp.zeros((3, 4, 2, 2))], axis=1),
        "weight": jnp.concatenate([native["weight"], jnp.asarray(pad)], axis=1),
    }
    s1, m1 = step(state, native)
    s2, m2 = jax.jit(make_round_step(loss_fn, plan, key))(state, padded)
    np.testing.assert_allclose(np.asarray(s1.params["w"]),
                               np.asarray(s2.params["w"]), atol=1e-6)
    assert float(m1["examples"]) == float(m2["examples"])


def test_dropped_clients_contribute_nothing():
    """A round where cohort masks client k equals a round where client
    k's weights are zeroed by hand (the engine's padding semantics)."""
    plan = FederatedPlan(clients_per_round=3, client_lr=0.1,
                         server_optimizer="sgd", server_lr=1.0,
                         cohort=CohortConfig(participation=0.5))
    key = jax.random.PRNGKey(4)
    step = jax.jit(make_round_step(loss_fn, plan, key))
    state = init_server_state(plan, params0())
    batch = make_batch(3, 2, 4, seed=3)
    s1, m1 = step(state, batch)
    assert 1.0 <= float(m1["participants"]) < 3.0     # this key drops someone

    # replicate the realized mask by hand on the parity engine
    from repro.core.fedavg import _plane_keys
    ckey, _, _, _ = _plane_keys(key, state.round_idx)
    pmask = participation_mask(jax.random.fold_in(ckey, 0), 3,
                               plan.cohort.participation)
    w = np.ones((3, 2, 4), np.float32) * np.asarray(pmask)[:, None, None]
    plan_full = FederatedPlan(clients_per_round=3, client_lr=0.1,
                              server_optimizer="sgd", server_lr=1.0)
    s2, _ = jax.jit(make_round_step(loss_fn, plan_full, key))(
        init_server_state(plan_full, params0()),
        make_batch(3, 2, 4, seed=3, weights=w))
    np.testing.assert_allclose(np.asarray(s1.params["w"]),
                               np.asarray(s2.params["w"]), atol=1e-6)


def test_cohort_fn_weight_shapes():
    cohort = make_cohort_fn(0.5, 0.5, 0.5)
    w, pmask = cohort(jax.random.PRNGKey(2), jnp.ones((4, 6, 2)))
    assert w.shape == (4, 6, 2) and pmask.shape == (4,)
    # masked weights only ever shrink
    assert float(w.max()) <= 1.0 and float(w.min()) >= 0.0


# ------------------------------------------------- pipeline + parity

def test_parity_default_pipeline_matches_manual_fedavg():
    """Acceptance: weighted_mean + no compression + full participation
    reproduces the legacy example-weighted FedAvg round exactly."""
    plan = FederatedPlan(clients_per_round=2, client_lr=0.1,
                         server_optimizer="sgd", server_lr=1.0)
    step = jax.jit(make_round_step(loss_fn, plan, jax.random.PRNGKey(0)))
    state = init_server_state(plan, params0())
    w = np.ones((2, 1, 8), np.float32)
    w[1, :, 2:] = 0.0
    batch = make_batch(2, 1, 8, seed=5, weights=w)
    s, m = step(state, batch)

    deltas = []
    for k in range(2):
        cb = jax.tree.map(lambda a: a[k, 0], batch)
        g = jax.grad(lambda p: loss_fn(p, cb, None)[0])(params0())
        deltas.append(0.1 * g["w"])
    n = np.array([8.0, 2.0])
    wbar = (n[0] * deltas[0] + n[1] * deltas[1]) / n.sum()
    np.testing.assert_allclose(np.asarray(s.params["w"]),
                               np.asarray(params0()["w"] - wbar), atol=1e-6)
    assert float(m["participants"]) == 2.0


def test_parity_fedsgd_default_pipeline():
    """fedsgd with the default plane still equals fedavg at one local
    step (the §2.2 IID-limit equivalence)."""
    kw = dict(clients_per_round=4, client_lr=0.1, server_optimizer="sgd",
              server_lr=1.0)
    batch = make_batch(4, 1, 8, seed=1)
    outs = []
    for engine in ("fedavg", "fedsgd"):
        plan = FederatedPlan(engine=engine, **kw)
        st = init_server_state(plan, params0())
        s2, _ = jax.jit(make_round_step(loss_fn, plan, jax.random.PRNGKey(0)))(st, batch)
        outs.append(np.asarray(s2.params["w"]))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-6)


def test_hyper_matches_plan_with_all_knobs_on():
    """Plan path (Python-constant knobs) == hyper path (traced knobs)
    for cohort + compression + robust aggregation together."""
    plan = FederatedPlan(clients_per_round=4, client_lr=0.1,
                         server_optimizer="adam", server_lr=0.05,
                         cohort=CohortConfig(participation=0.6,
                                             straggler_frac=0.5,
                                             straggler_keep=0.5),
                         compression=CompressionConfig(kind="int8"),
                         aggregation=AggregatorConfig(name="trimmed_mean",
                                                      trim_frac=0.2))
    key = jax.random.PRNGKey(11)
    plain = jax.jit(make_round_step(loss_fn, plan, key))
    hyper = jax.jit(make_hyper_round_step(loss_fn, "fedavg", "adam",
                                          "trimmed_mean", plan.compression))
    hypers = plan_hypers(plan)
    s1 = s2 = init_server_state(plan, params0())
    for r in range(3):
        batch = make_batch(4, 2, 4, seed=20 + r)
        s1, _ = plain(s1, batch)
        s2, _ = hyper(s2, batch, hypers, key)
    np.testing.assert_allclose(np.asarray(s1.params["w"]),
                               np.asarray(s2.params["w"]), atol=1e-6)


def test_hyper_shares_compile_across_cohort_grid():
    """participation/straggler/trim knobs are traced: a whole cohort
    grid hits one compilation of the round fn."""
    hyper = jax.jit(make_hyper_round_step(loss_fn, "fedavg", "adam"))
    key = jax.random.PRNGKey(0)
    batch = make_batch(4, 2, 4)
    for p, s in [(1.0, 0.0), (0.5, 0.5), (0.25, 0.9)]:
        plan = FederatedPlan(clients_per_round=4,
                             cohort=CohortConfig(participation=p,
                                                 straggler_frac=s))
        state = init_server_state(plan, params0())
        hyper(state, batch, plan_hypers(plan), key)
    assert hyper._cache_size() == 1


def test_wire_metrics_exact_bytes():
    from repro.core import client_wire_bytes, tree_param_bytes

    plan = FederatedPlan(clients_per_round=3, client_lr=0.1,
                         server_optimizer="sgd", server_lr=1.0,
                         compression=CompressionConfig(kind="int8"))
    step = jax.jit(make_round_step(loss_fn, plan, jax.random.PRNGKey(0)))
    state = init_server_state(plan, params0())
    _, m = step(state, make_batch(3, 1, 4))
    up = client_wire_bytes(plan.compression, params0())      # 8 + 4
    down = tree_param_bytes(params0())                       # 32
    assert float(m["uplink_bytes"]) == 3 * up
    assert float(m["downlink_bytes"]) == 3 * down
    assert up < down                                         # compressed uplink


def test_compressed_round_still_converges():
    plan = FederatedPlan(clients_per_round=4, client_lr=0.05,
                         server_optimizer="adam", server_lr=0.05,
                         compression=CompressionConfig(kind="int8"))
    step = jax.jit(make_round_step(loss_fn, plan, jax.random.PRNGKey(1)))
    state = init_server_state(plan, params0())
    losses = []
    for r in range(40):
        state, m = step(state, make_batch(4, 3, 8, seed=200 + r))
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.2 * losses[0]


def test_cohort_plan_rejects_weightless_batches():
    """Silently skipping cohort masking would corrupt training AND the
    CFMQ accounting — weight-less batches must raise instead."""
    plan = FederatedPlan(clients_per_round=2, client_lr=0.1,
                         server_optimizer="sgd", server_lr=1.0,
                         cohort=CohortConfig(participation=0.5))
    step = make_round_step(loss_fn, plan, jax.random.PRNGKey(0))
    state = init_server_state(plan, params0())
    batch = {k: v for k, v in make_batch(2, 1, 4).items() if k != "weight"}

    def weightless_loss(params, b, rng):
        pred = b["x"] @ params["w"]
        return jnp.mean((pred - b["y"]) ** 2), {}

    step = make_round_step(weightless_loss, plan, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="weight"):
        step(state, batch)


def test_fedsgd_rejects_robust_aggregators():
    plan = FederatedPlan(
        engine="fedsgd",
        aggregation=AggregatorConfig(name="coordinate_median"))
    with pytest.raises(ValueError, match="fedsgd"):
        make_round_step(loss_fn, plan, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="fedsgd"):
        make_hyper_round_step(loss_fn, "fedsgd", "adam", "trimmed_mean")
