"""FederatedTask registry: every registered task trains federated.

The redesign's contract: ``build_round_engine(plan, task)`` must take
any registered task through a real federated round — same engine, same
corpus, same wire accounting — with the task supplying the loss
adapter and the eval metric. One smoke per zoo family here (enc-dec,
transformer LM, MoE, RWKV, keyword spotting, and the paper's RNN-T),
plus the million-virtual-client keyword round the CI job runs.
"""
import jax
import numpy as np
import pytest

from repro.core import (
    FederatedPlan,
    FederatedTask,
    available_tasks,
    build_round_engine,
    get_task,
    plan_wire_accounting,
    task_for_config,
)
from repro.core.task import default_corpus
from repro.data import FederatedSampler, VirtualPopulation, make_speaker_corpus

# Small corpus with the tasks' shared modality (feat_dim=16, vocab=64).
_CORPUS = {}


def _corpus(seed=0):
    if seed not in _CORPUS:
        _CORPUS[seed] = make_speaker_corpus(
            num_speakers=8, vocab_size=64, feat_dim=16,
            mean_utterances=6.0, seed=seed)
    return _CORPUS[seed]


def _plan(**kw):
    base = dict(clients_per_round=4, local_batch_size=2, local_steps=2,
                data_limit=2, client_lr=0.1, server_lr=0.01)
    base.update(kw)
    return FederatedPlan(**base)


def _one_round(task, plan, corpus=None, seed=0):
    corpus = corpus if corpus is not None else _corpus()
    params = task.bundle.init(jax.random.PRNGKey(seed))
    engine = build_round_engine(plan, task, base_key=jax.random.PRNGKey(seed + 1))
    sampler = FederatedSampler(
        corpus, clients_per_round=plan.clients_per_round,
        local_batch_size=plan.local_batch_size, data_limit=plan.data_limit,
        seed=seed, max_steps=plan.local_steps)
    state, metrics = jax.jit(engine.step)(
        engine.init_state(params), sampler.next_round().engine_batch())
    return engine, params, state, metrics


def test_registry_names():
    assert available_tasks() == sorted(available_tasks())
    assert {"asr-rnnt", "asr-encdec", "lm-transformer", "lm-moe",
            "lm-rwkv", "keyword"} <= set(available_tasks())
    with pytest.raises(KeyError, match="unknown task"):
        get_task("no-such-task")


@pytest.mark.parametrize("name", sorted(
    {"asr-rnnt", "asr-encdec", "lm-transformer", "lm-moe", "lm-rwkv",
     "keyword"}))
def test_every_task_trains_one_federated_round(name):
    """One real round per task: finite loss, byte-exact wire metrics."""
    task = get_task(name)
    assert isinstance(task, FederatedTask)
    assert task.quality_metric in ("wer", "ppl", "err")
    plan = _plan()
    engine, params, state, metrics = _one_round(task, plan)
    assert np.isfinite(float(metrics["loss"]))
    # the engine's in-graph wire metrics agree with the exact host
    # accounting for this task's param tree
    up, down = plan_wire_accounting(plan, params)
    participants = float(metrics["participants"])
    assert float(metrics["downlink_bytes"]) == down
    assert float(metrics["uplink_bytes"]) == up * participants
    # the model learned something: params moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(state.params)))
    assert moved


def test_tasks_never_share_a_jit_cache_entry():
    keys = {get_task(n).name: build_round_engine(
        _plan(), get_task(n), base_key=jax.random.PRNGKey(0)).structural_key
        for n in available_tasks()}
    assert len(set(keys.values())) == len(keys)
    for name, key in keys.items():
        assert ("task", name) in key


def test_engine_accepts_bare_loss_fn():
    """The pre-task form keeps working (no task component in the key)."""
    task = get_task("keyword")
    engine = build_round_engine(_plan(), task.bundle.loss_fn,
                                base_key=jax.random.PRNGKey(0))
    assert engine.task is None
    assert ("task", task.name) not in engine.structural_key


def test_task_for_config_rejects_unsupported_kind():
    from repro.configs import get_arch

    cfg = get_arch("llava-next-mistral-7b").make_smoke_config()
    with pytest.raises(ValueError, match="no federated task adapter"):
        task_for_config(cfg)


def test_task_evaluate_smoke():
    """Each metric family's evaluate returns finite lower-is-better
    numbers out of the box (untrained params)."""
    corpus = _corpus()
    for name, lo, hi in (("asr-rnnt", 0.0, 10.0), ("lm-transformer", 1.0,
                                                   np.exp(20.0) + 1),
                         ("keyword", 0.0, 1.0)):
        task = get_task(name)
        params = task.bundle.init(jax.random.PRNGKey(0))
        q = task.evaluate(params, corpus, 8)
        assert set(q) == {"quality", "quality_hard"}
        for v in q.values():
            assert np.isfinite(v) and lo <= v <= hi, (name, q)


def test_keyword_million_client_round():
    """The CI-scale workload: one keyword round over a 1M-virtual-client
    population (host memory stays O(corpus + K))."""
    task = get_task("keyword")
    corpus = VirtualPopulation(default_corpus(0), 1_000_000)
    plan = _plan(clients_per_round=8)
    engine, params, state, metrics = _one_round(task, plan, corpus=corpus)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["participants"]) == 8.0
