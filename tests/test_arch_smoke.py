"""Per-assigned-architecture smoke tests: reduced config (<=2-ish
layers, d_model <= 512, <=4 experts) runs one forward/train step on CPU
— shapes + finiteness — plus one federated round through the engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.core import FederatedPlan, init_server_state, make_round_step
from repro.models import build_model

ARCHS = list_archs()
RNG = np.random.default_rng(0)


def smoke_batch(arch, cfg, K=2, S=1, b=2, seq=32):
    kind = arch.kind
    w = np.ones((K, S, b), np.float32)
    if kind == "audio":
        return {
            "frames": jnp.asarray(RNG.normal(size=(K, S, b, cfg.max_source, cfg.d_model)), jnp.float32),
            "tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (K, S, b, 16)), jnp.int32),
            "weight": jnp.asarray(w),
        }
    if kind == "vlm":
        return {
            "image_embeds": jnp.asarray(RNG.normal(size=(K, S, b, cfg.n_img_tokens, cfg.vit_dim)), jnp.float32),
            "tokens": jnp.asarray(RNG.integers(0, cfg.lm.vocab, (K, S, b, seq)), jnp.int32),
            "weight": jnp.asarray(w),
        }
    if kind == "rnnt":
        t, u = 12, 6
        return {
            "features": jnp.asarray(RNG.normal(size=(K, S, b, t, cfg.feat_dim)), jnp.float32),
            "labels": jnp.asarray(RNG.integers(1, cfg.vocab, (K, S, b, u)), jnp.int32),
            "frame_len": jnp.full((K, S, b), t, jnp.int32),
            "label_len": jnp.full((K, S, b), u, jnp.int32),
            "weight": jnp.asarray(w),
        }
    vocab = cfg.vocab if hasattr(cfg, "vocab") else cfg.lm.vocab
    return {
        "tokens": jnp.asarray(RNG.integers(0, vocab, (K, S, b, seq)), jnp.int32),
        "weight": jnp.asarray(w),
    }


# the two heaviest archs dominate the suite (~50s combined): their
# round-step smoke runs under the slow mark, CI-only by default
_SLOW_ARCHS = {"zamba2-7b", "deepseek-v2-lite-16b"}


@pytest.mark.parametrize(
    "arch_id",
    [pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS
     else a for a in ARCHS])
def test_smoke_forward_and_fed_round(arch_id):
    arch = get_arch(arch_id)
    cfg = arch.make_smoke_config()
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))

    batch = smoke_batch(arch, cfg)
    flat = jax.tree.map(lambda a: a[0, 0], batch)
    loss, aux = jax.jit(bundle.loss_fn)(params, flat, jax.random.PRNGKey(1))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch_id

    plan = FederatedPlan(clients_per_round=2, local_batch_size=2,
                         client_lr=0.05, engine=arch.engine)
    step = jax.jit(make_round_step(bundle.loss_fn, plan, jax.random.PRNGKey(2)))
    state = init_server_state(plan, params)
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), arch_id
    assert float(metrics["delta_norm"]) > 0
    # params actually moved
    moved = any(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()) > 0
        for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(state2.params)))
    assert moved, arch_id


@pytest.mark.parametrize("arch_id", [a for a in ARCHS
                                     if get_arch(a).kind not in ("rnnt",)])
def test_smoke_decode_step(arch_id):
    arch = get_arch(arch_id)
    cfg = arch.make_smoke_config()
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    B, L = 2, 16
    cache = bundle.init_cache(B, L)
    tok = jnp.asarray(RNG.integers(0, 8, (B, 1)), jnp.int32)
    logits, cache2 = jax.jit(bundle.decode_step)(params, cache, tok,
                                                 jnp.asarray(0, jnp.int32))
    vocab = cfg.vocab if hasattr(cfg, "vocab") else cfg.lm.vocab
    assert logits.shape == (B, vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch_id


@pytest.mark.parametrize("arch_id", ["qwen3-8b", "gemma3-4b", "rwkv6-1.6b",
                                     "deepseek-v2-lite-16b"])
def test_smoke_decode_matches_prefill(arch_id):
    """Stateful decode == teacher-forced forward on the same tokens."""
    import dataclasses

    arch = get_arch(arch_id)
    cfg = arch.make_smoke_config()
    if getattr(cfg, "moe", None) is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    vocab = cfg.vocab if hasattr(cfg, "vocab") else cfg.lm.vocab
    S = 24
    tok = jnp.asarray(RNG.integers(0, vocab, (2, S)), jnp.int32)
    logits_pre, _ = jax.jit(bundle.prefill)(params, {"tokens": tok})
    cache = bundle.init_cache(2, S)
    dstep = jax.jit(bundle.decode_step)
    for t in range(S):
        lg, cache = dstep(params, cache, tok[:, t : t + 1], jnp.asarray(t, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_pre),
                               atol=2e-4, rtol=2e-4)


def test_full_configs_match_billed_param_counts():
    expected = {
        "phi3.5-moe-42b-a6.6b": (40e9, 44e9),
        "zamba2-7b": (6e9, 7.5e9),
        "deepseek-67b": (64e9, 70e9),
        "command-r-35b": (30e9, 37e9),
        "qwen3-8b": (7.5e9, 9e9),
        "whisper-base": (0.05e9, 0.1e9),
        "llava-next-mistral-7b": (6.8e9, 7.8e9),
        "deepseek-v2-lite-16b": (14e9, 17e9),
        "gemma3-4b": (3.8e9, 5e9),
        "rwkv6-1.6b": (1.4e9, 1.8e9),
        "rnnt-librispeech": (0.09e9, 0.15e9),
    }
    for arch_id, (lo, hi) in expected.items():
        arch = get_arch(arch_id)
        bundle = build_model(arch.make_config())
        struct = jax.eval_shape(lambda b=bundle: b.init(jax.random.PRNGKey(0)))
        n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(struct))
        assert lo <= n <= hi, (arch_id, n)
