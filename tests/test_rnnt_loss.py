"""Transducer loss vs. brute-force alignment-enumeration oracle."""
import math
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.asr.rnnt_loss import rnnt_loss


def brute_force_nll(logp, labels, T, U):
    @lru_cache(None)
    def f(t, u):
        if t == T - 1 and u == U:
            return float(logp[t, u, 0])
        opts = []
        if t < T - 1:
            opts.append(logp[t, u, 0] + f(t + 1, u))
        if u < U:
            opts.append(logp[t, u, labels[u]] + f(t, u + 1))
        if not opts:
            return -1e30
        m = max(opts)
        return m + math.log(sum(math.exp(o - m) for o in opts))

    return -f(0, 0)


@pytest.mark.parametrize("seed,T,U,V", [(0, 5, 4, 7), (1, 8, 3, 5), (2, 3, 2, 12)])
def test_rnnt_loss_matches_bruteforce(seed, T, U, V):
    rng = np.random.default_rng(seed)
    B = 3
    logits = rng.normal(size=(B, T, U + 1, V)).astype(np.float32)
    labels = rng.integers(1, V, size=(B, U)).astype(np.int32)
    frame_len = rng.integers(1, T + 1, size=(B,)).astype(np.int32)
    label_len = rng.integers(0, U + 1, size=(B,)).astype(np.int32)
    loss = rnnt_loss(jnp.array(logits), jnp.array(labels),
                     jnp.array(frame_len), jnp.array(label_len))
    lp = np.asarray(jax.nn.log_softmax(jnp.array(logits), axis=-1))
    for b in range(B):
        ref = brute_force_nll(lp[b], labels[b], int(frame_len[b]), int(label_len[b]))
        assert abs(float(loss[b]) - ref) < 1e-3, (b, float(loss[b]), ref)


def test_rnnt_loss_grad_finite():
    rng = np.random.default_rng(3)
    B, T, U, V = 2, 6, 4, 9
    logits = jnp.array(rng.normal(size=(B, T, U + 1, V)), jnp.float32)
    labels = jnp.array(rng.integers(1, V, (B, U)), jnp.int32)
    fl = jnp.array([6, 4], jnp.int32)
    ll = jnp.array([4, 2], jnp.int32)
    g = jax.grad(lambda l: rnnt_loss(l, labels, fl, ll).sum())(logits)
    assert bool(jnp.all(jnp.isfinite(g)))
    # grads must vanish outside the valid lattice of example 1 (t >= 4 rows
    # contribute nothing except through earlier alphas -> zero cols beyond)
    assert float(jnp.abs(g[1, 4:, :, :]).sum()) == 0.0


def test_rnnt_loss_single_path():
    """T=1: the only alignment is emit-all-labels-then-blank at t=0."""
    rng = np.random.default_rng(4)
    V, U = 6, 3
    logits = jnp.array(rng.normal(size=(1, 1, U + 1, V)), jnp.float32)
    labels = jnp.array([[2, 3, 1]], jnp.int32)
    lp = jax.nn.log_softmax(logits, axis=-1)
    expected = -(lp[0, 0, 0, 2] + lp[0, 0, 1, 3] + lp[0, 0, 2, 1] + lp[0, 0, 3, 0])
    loss = rnnt_loss(logits, labels, jnp.array([1]), jnp.array([3]))
    np.testing.assert_allclose(float(loss[0]), float(expected), rtol=1e-5)
