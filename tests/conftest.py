"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU
device (the 512-device override belongs to the dry-run only)."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
