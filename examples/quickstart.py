"""Quickstart: 20 federated rounds of a tiny RNN-T on the synthetic
speaker-split corpus — the paper's Alg. 1 end to end in ~a minute.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import FederatedPlan, FVNConfig
from repro.launch.train import run_federated_asr, tiny_asr_setup


def main():
    cfg, corpus = tiny_asr_setup(seed=0)
    print(f"corpus: {corpus.num_speakers} speakers, "
          f"{int(corpus.utterance_histogram().sum())} utterances")

    plan = FederatedPlan(
        clients_per_round=8,          # K
        local_batch_size=4,           # b
        local_steps=12,               # local epoch cap
        data_limit=None,              # the paper's non-IID dial (§4.2.1);
                                      # try 4 to push the round toward IID
        client_lr=0.3,                # client SGD
        server_lr=0.05,               # server Adam
        server_warmup_rounds=4,
        fvn=FVNConfig(enabled=True, std=0.02, ramp_rounds=15),  # §4.2.2
    )
    state, hist = run_federated_asr(cfg, corpus, plan, rounds=30, seed=0,
                                    eval_every=10, eval_examples=32)
    print(f"\nfinal loss {hist['final_loss']:.3f}  WER {hist['quality']:.3f} "
          f"(hard {hist['quality_hard']:.3f})")
    print(f"CFMQ for this run: {hist['cfmq_tb']:.5f} TB "
          f"({hist['n_params']/1e6:.2f}M params, Eq. 2)")


if __name__ == "__main__":
    main()
