"""The paper's core idea as one picture: sweep the non-IID dial (per-
client data limit) and plot quality vs CFMQ cost (Fig. 3 flavor).

Thin wrapper over the multi-sweep runner (``repro.launch.sweeps``),
which shares one corpus + one jitted round fn across all sweep points
and prefetches round batches asynchronously:

    PYTHONPATH=src python examples/noniid_tradeoff.py --rounds 60
    PYTHONPATH=src python -m repro.launch.sweeps --grid noniid_fvn  # same engine
"""
import argparse

from repro.launch.sweeps import run_grid


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--fvn", action="store_true", help="also sweep with FVN on")
    ap.add_argument("--smoke", action="store_true", help="tiny CI budget")
    ap.add_argument("--out", default="results/noniid_tradeoff.json")
    args = ap.parse_args()

    frontier = run_grid(
        "noniid_fvn", rounds=args.rounds, smoke=args.smoke, out=args.out,
        fvn_opts=(False, True) if args.fvn else (False,))
    for r in frontier["points"]:
        print(f"limit={str(r['limit']):>4s} fvn={r['fvn']}: "
              f"loss={r['final_loss']:.3f} wer={r['quality']:.3f} "
              f"cfmq={r['cfmq_tb']:.5f}TB{'  <- pareto' if r['pareto'] else ''}")
    print("\nsmaller limit -> closer to IID (better quality per round) but "
          "more rounds/bytes per example — the paper's §2.2 trade-off.")


if __name__ == "__main__":
    main()
