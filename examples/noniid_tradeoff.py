"""The paper's core idea as one picture: sweep the non-IID dial (per-
client data limit) and plot quality vs CFMQ cost (Fig. 3 flavor).

    PYTHONPATH=src python examples/noniid_tradeoff.py --rounds 60
"""
import argparse
import json

from repro.core import FederatedPlan, FVNConfig
from repro.launch.train import run_federated_asr, tiny_asr_setup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--fvn", action="store_true", help="also sweep with FVN on")
    ap.add_argument("--out", default="results/noniid_tradeoff.json")
    args = ap.parse_args()

    cfg, corpus = tiny_asr_setup(seed=0)
    rows = []
    fvn_opts = [False, True] if args.fvn else [False]
    for fvn_on in fvn_opts:
        for limit in (1, 2, 4, 8, None):
            plan = FederatedPlan(
                clients_per_round=8, local_batch_size=4, data_limit=limit,
                client_lr=0.3, server_lr=0.05, server_warmup_rounds=4,
                fvn=FVNConfig(enabled=fvn_on, std=0.03,
                              ramp_rounds=args.rounds // 2))
            _, h = run_federated_asr(cfg, corpus, plan, rounds=args.rounds,
                                     seed=0, eval_examples=48)
            rows.append(dict(limit=limit, fvn=fvn_on, loss=h["final_loss"],
                             wer=h["wer"], cfmq_tb=h["cfmq_tb"]))
            print(f"limit={str(limit):>4s} fvn={fvn_on}: loss={h['final_loss']:.3f} "
                  f"wer={h['wer']:.3f} cfmq={h['cfmq_tb']:.5f}TB")
    print("\nsmaller limit -> closer to IID (better quality per round) but "
          "more rounds/bytes per example — the paper's §2.2 trade-off.")
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
