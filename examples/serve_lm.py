"""Serving-path demo: prefill + batched KV-cache decode on a smoke-size
assigned architecture (the same serve_step the dry-run lowers at
decode_32k / long_500k on the 256-chip mesh).

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-8b --tokens 48
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=48)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cfg = arch.make_smoke_config()
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    vocab = cfg.vocab if hasattr(cfg, "vocab") else cfg.lm.vocab

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, vocab, (args.batch, args.prompt_len)),
                          jnp.int32)
    total = args.prompt_len + args.tokens

    # prefill via decode loop when the arch has no batch prefill (hybrid)
    cache = bundle.init_cache(args.batch, total)
    dstep = jax.jit(bundle.decode_step)
    t0 = time.perf_counter()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = dstep(params, cache, prompts[:, t : t + 1],
                              jnp.asarray(t, jnp.int32))
    t_prefill = time.perf_counter() - t0

    out = []
    t0 = time.perf_counter()
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for t in range(args.prompt_len, total):
        out.append(np.asarray(tok[:, 0]))
        logits, cache = dstep(params, cache, tok, jnp.asarray(t, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    gen = np.stack(out, 1)
    print(f"arch={args.arch} (smoke config, {bundle.param_count(params)/1e6:.1f}M params)")
    print(f"prefill {args.prompt_len} toks x{args.batch}: {t_prefill*1e3:.0f} ms "
          f"(incl. compile)")
    print(f"decode {args.tokens} toks x{args.batch}: "
          f"{t_decode/args.tokens*1e3:.1f} ms/token")
    print("sample continuation ids:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
