"""End-to-end driver: federated RNN-T training with the paper's full
experiment surface — non-IID dial, FVN, server LR schedule, CFMQ
accounting, periodic WER eval, checkpointing.

Container default is a scaled config (a few hundred rounds of the tiny
model); pass ``--size paper`` to instantiate the paper's 122M-class
RNN-T (8x1152 LSTM encoder, 4096 word-pieces) — the same code path the
dry-run lowers onto the 256-chip mesh.

    PYTHONPATH=src python examples/train_federated_asr.py --rounds 200
"""
import argparse
import json

from repro.configs import get_arch
from repro.core import FederatedPlan, FVNConfig
from repro.data import make_speaker_corpus
from repro.launch.train import run_federated_asr, tiny_asr_setup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="tiny", choices=["tiny", "small", "paper"])
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--data-limit", type=int, default=4)
    ap.add_argument("--fvn-std", type=float, default=0.03)
    ap.add_argument("--ckpt-dir", default="results/ckpt_asr")
    ap.add_argument("--out", default="results/train_federated_asr.json")
    args = ap.parse_args()

    if args.size == "tiny":
        cfg, corpus = tiny_asr_setup(seed=0)
    elif args.size == "small":
        from repro.asr.specaugment import SpecAugmentConfig
        from repro.models.rnnt import RNNTConfig

        cfg = RNNTConfig(name="rnnt-small", feat_dim=32, vocab=256,
                         enc_layers=4, enc_hidden=256, pred_layers=2,
                         pred_hidden=256, pred_embed=128, joint_dim=160,
                         specaug=SpecAugmentConfig(freq_masks=2, freq_mask_width=6),
                         dtype="float32", param_dtype="float32")
        corpus = make_speaker_corpus(num_speakers=96, vocab_size=256,
                                     feat_dim=32, mean_utterances=30.0, seed=0)
    else:
        cfg = get_arch("rnnt-librispeech").make_config()
        corpus = make_speaker_corpus(num_speakers=2338, vocab_size=4096,
                                     feat_dim=128, mean_utterances=180.0, seed=0)

    plan = FederatedPlan(
        clients_per_round=args.clients, local_batch_size=4,
        data_limit=args.data_limit, client_lr=0.3, server_lr=0.05,
        server_warmup_rounds=max(4, args.rounds // 20),
        server_decay_rounds=args.rounds // 3, server_decay_rate=0.9,
        fvn=FVNConfig(enabled=True, std=args.fvn_std,
                      ramp_rounds=args.rounds // 2),
    )
    state, hist = run_federated_asr(
        cfg, corpus, plan, rounds=args.rounds, seed=0,
        eval_every=max(5, args.rounds // 10), ckpt_dir=args.ckpt_dir)
    print(json.dumps({k: v for k, v in hist.items() if k != "loss"}, indent=1))
    with open(args.out, "w") as f:
        json.dump(hist, f)


if __name__ == "__main__":
    main()
