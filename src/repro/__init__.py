"""repro — the paper's quality/cost federated-learning framework.

The stable public surface, re-exported from ``repro.core``:

- ``FederatedPlan`` — the experiment configuration (cohort,
  compression, aggregation, corruption, engine, schedules);
- ``FederatedTask`` + the task registry (``get_task`` /
  ``available_tasks`` / ``task_for_config`` / ``register_task``) —
  model init, loss, eval and quality metric as one bundle;
- ``build_round_engine(plan, task)`` — the engine factory over the
  sync/async round engines (``RoundEngine``);
- the CFMQ helpers (``cfmq``, ``plan_wire_accounting``,
  ``measured_payload``, ``accumulate_wire_bytes``,
  ``seconds_to_target``) — the cost axis;
- the metrics schema (``summary_row``, ``SUMMARY_KEYS``,
  ``ROUND_METRIC_KEYS``) and the per-client evaluation plane
  (``ClientEvalPlane``, ``fairness_spread``).

Anything not re-exported here or from ``repro.core`` is internal and
may change without notice.
"""

from repro.core import (
    ROUND_METRIC_KEYS,
    SUMMARY_KEYS,
    CFMQTerms,
    ClientEvalPlane,
    FederatedPlan,
    FederatedTask,
    RoundEngine,
    accumulate_wire_bytes,
    arch_task,
    available_tasks,
    build_round_engine,
    cfmq,
    fairness_spread,
    get_task,
    measured_payload,
    plan_wire_accounting,
    register_task,
    seconds_to_target,
    summary_row,
    task_for_config,
    validate_plan,
)

__all__ = [
    "ROUND_METRIC_KEYS",
    "SUMMARY_KEYS",
    "CFMQTerms",
    "ClientEvalPlane",
    "FederatedPlan",
    "FederatedTask",
    "RoundEngine",
    "accumulate_wire_bytes",
    "arch_task",
    "available_tasks",
    "build_round_engine",
    "cfmq",
    "fairness_spread",
    "get_task",
    "measured_payload",
    "plan_wire_accounting",
    "register_task",
    "seconds_to_target",
    "summary_row",
    "task_for_config",
    "validate_plan",
]
