"""Word-error-rate metric (host-side numpy) for the synthetic corpus."""
from __future__ import annotations

import numpy as np


def levenshtein(ref, hyp) -> int:
    """Edit distance between two token sequences."""
    m, n = len(ref), len(hyp)
    if m == 0:
        return n
    if n == 0:
        return m
    prev = np.arange(n + 1)
    for i in range(1, m + 1):
        cur = np.empty(n + 1, dtype=np.int64)
        cur[0] = i
        for j in range(1, n + 1):
            cost = 0 if ref[i - 1] == hyp[j - 1] else 1
            cur[j] = min(prev[j] + 1, cur[j - 1] + 1, prev[j - 1] + cost)
        prev = cur
    return int(prev[n])


def wer(refs, hyps) -> float:
    """Corpus-level WER: sum(edits) / sum(ref lengths)."""
    edits = 0
    total = 0
    for r, h in zip(refs, hyps):
        r = [t for t in r if t != 0]
        h = [t for t in h if t != 0]
        edits += levenshtein(r, h)
        total += max(len(r), 1)
    return edits / max(total, 1)


def greedy_decode_rnnt(*args, **kwargs):
    # Re-exported from the model zoo to keep loss/metric deps acyclic.
    from repro.models.rnnt import greedy_decode

    return greedy_decode(*args, **kwargs)
