"""SpecAugment (Park et al., 2019) — time/frequency masking on log-mel
features. The Baseline (E0) and the cost-reduced federated config E10
("increased the amount of SpecAugment") both use it; the multiplicity
and widths are config so E10's sweep is expressible.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SpecAugmentConfig:
    freq_masks: int = 2
    freq_mask_width: int = 27     # F parameter of the paper
    time_masks: int = 2
    time_mask_frac: float = 0.05  # max time-mask width as fraction of T
    enabled: bool = True


def _mask_axis(key, x, axis_len, max_width, num_masks, axis):
    """Apply ``num_masks`` random contiguous zero-masks along ``axis``."""
    def body(x, key):
        k1, k2 = jax.random.split(key)
        width = jax.random.randint(k1, (), 0, max_width + 1)
        start = jax.random.randint(k2, (), 0, jnp.maximum(axis_len - width, 1))
        idx = jnp.arange(axis_len)
        mask = (idx >= start) & (idx < start + width)
        shape = [1] * x.ndim
        shape[axis] = axis_len
        return x * (1.0 - mask.reshape(shape).astype(x.dtype)), None

    keys = jax.random.split(key, num_masks)
    x, _ = jax.lax.scan(body, x, keys)
    return x


def spec_augment(key: jax.Array, features: jnp.ndarray, cfg: SpecAugmentConfig) -> jnp.ndarray:
    """features: (..., T, F). Pure function of the PRNG key (per-client
    keys under FL, so each client augments independently)."""
    if not cfg.enabled:
        return features
    t_len, f_len = features.shape[-2], features.shape[-1]
    kf, kt = jax.random.split(key)
    max_f = min(cfg.freq_mask_width, f_len)
    max_t = max(1, int(t_len * cfg.time_mask_frac))
    if cfg.freq_masks > 0:
        features = _mask_axis(kf, features, f_len, max_f, cfg.freq_masks, axis=-1)
    if cfg.time_masks > 0:
        features = _mask_axis(kt, features, t_len, max_t, cfg.time_masks, axis=-2)
    return features
