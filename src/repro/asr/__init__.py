"""ASR substrate: SpecAugment, transducer (RNN-T) loss, greedy decode, WER."""
from repro.asr.specaugment import SpecAugmentConfig, spec_augment
from repro.asr.rnnt_loss import rnnt_loss, rnnt_loss_from_logprobs
from repro.asr.wer import wer, levenshtein, greedy_decode_rnnt

__all__ = [
    "SpecAugmentConfig",
    "spec_augment",
    "rnnt_loss",
    "rnnt_loss_from_logprobs",
    "wer",
    "levenshtein",
    "greedy_decode_rnnt",
]
