"""RNN-T (transducer) loss — Graves 2012 — in pure JAX.

The forward DP over the (T, U+1) lattice:
    alpha[0,0] = 0
    alpha[t,u] = logaddexp(alpha[t-1,u] + blank[t-1,u],
                           alpha[t,u-1] + label[t,u-1])
    loss       = -(alpha[T-1,U] + blank[T-1,U])

The inner u-recurrence of each row is a log-semiring *linear*
recurrence x_u = logaddexp(A_u, x_{u-1} + L_{u-1}); we evaluate it with
``jax.lax.associative_scan`` (elements (l, a) compose as
(l1+l2, logaddexp(a2, l2+a1))), wrapped in a ``lax.scan`` over T. This
is wavefront-free, TPU-friendly (no per-element gather), and
autodiff-able — the jnp oracle for the fused Pallas joint kernel.

Inputs here are the per-lattice-point blank/label log-probs — the
B×T×(U+1)×2 tensors the fused joint kernel emits — *not* the full
B×T×U×V logits (the memory hot-spot the paper's model hits at V=4096).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


def _log_linear_scan(A: jnp.ndarray, L_shift: jnp.ndarray) -> jnp.ndarray:
    """Solve x_u = logaddexp(A_u, x_{u-1} + L_shift_u) along the last axis
    (L_shift_0 is ignored / should be NEG_INF)."""

    def combine(e1, e2):
        l1, a1 = e1
        l2, a2 = e2
        return l1 + l2, jnp.logaddexp(a2, l2 + a1)

    _, x = jax.lax.associative_scan(combine, (L_shift, A), axis=-1)
    return x


def rnnt_alpha(blank_lp: jnp.ndarray, label_lp: jnp.ndarray) -> jnp.ndarray:
    """Forward variables alpha for one example.

    blank_lp, label_lp: (T, U1) with U1 = U_max + 1. label_lp[:, -1]
    must be masked to NEG_INF by the caller (no label past U).
    Returns alpha: (T, U1).
    """
    T, U1 = blank_lp.shape

    # L_shift[u] = label_lp[t, u-1]; L_shift[0] = -inf
    def row(alpha_prev, inp):
        b_prev, l_row, first = inp
        A = jnp.where(first, jnp.where(jnp.arange(U1) == 0, 0.0, NEG_INF),
                      alpha_prev + b_prev)
        L_shift = jnp.concatenate([jnp.array([NEG_INF]), l_row[:-1]])
        alpha = _log_linear_scan(A, L_shift)
        return alpha, alpha

    first = jnp.zeros((T,), bool).at[0].set(True)
    b_prev = jnp.concatenate([jnp.zeros((1, U1)), blank_lp[:-1]], axis=0)
    _, alphas = jax.lax.scan(row, jnp.full((U1,), NEG_INF), (b_prev, label_lp, first))
    return alphas


def rnnt_loss_from_logprobs(
    blank_lp: jnp.ndarray,
    label_lp: jnp.ndarray,
    frame_len: jnp.ndarray,
    label_len: jnp.ndarray,
) -> jnp.ndarray:
    """Batched negative log-likelihood.

    blank_lp, label_lp: (B, T, U1); frame_len: (B,) in [1, T];
    label_len: (B,) in [0, U1-1]. Positions u >= label_len emit no
    label (masked here). Returns per-example loss (B,).
    """
    B, T, U1 = blank_lp.shape
    u_idx = jnp.arange(U1)[None, None, :]
    label_lp = jnp.where(u_idx >= label_len[:, None, None], NEG_INF, label_lp)

    alphas = jax.vmap(rnnt_alpha)(blank_lp, label_lp)  # (B, T, U1)
    t_last = jnp.clip(frame_len - 1, 0, T - 1)
    b_idx = jnp.arange(B)
    final_alpha = alphas[b_idx, t_last, label_len]
    final_blank = blank_lp[b_idx, t_last, label_len]
    return -(final_alpha + final_blank)


def rnnt_loss(
    logits: jnp.ndarray,
    labels: jnp.ndarray,
    frame_len: jnp.ndarray,
    label_len: jnp.ndarray,
    blank_id: int = 0,
) -> jnp.ndarray:
    """Convenience entry from full joint logits (B, T, U1, V) — only for
    small vocab/tests; the production path fuses the joint (kernels/rnnt_joint)
    and never materializes V at every lattice point.

    labels: (B, U1-1) — label u is emitted moving (t,u)->(t,u+1).
    """
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    blank_lp = lp[..., blank_id]
    B, T, U1, V = logits.shape
    lbl = jnp.concatenate([labels, jnp.zeros((B, 1), labels.dtype)], axis=1)  # (B, U1)
    label_lp = jnp.take_along_axis(lp, lbl[:, None, :, None], axis=-1)[..., 0]
    return rnnt_loss_from_logprobs(blank_lp, label_lp, frame_len, label_len)
