"""Pure-JAX optimizer substrate (optax-like, no external deps).

Gradient transformations are (init_fn, update_fn) pairs operating on
pytrees. Used both as the *client* optimizer (SGD inside the federated
local loop) and the *server* optimizer (Adam on aggregated deltas), per
the paper's two-level FedAvg optimization.
"""
from repro.optim.optimizers import (
    Optimizer,
    sgd,
    momentum,
    adam,
    adamw,
    yogi,
    clip_by_global_norm,
    chain,
    scale_by_schedule,
    apply_updates,
    global_norm,
)
from repro.optim.schedules import (
    constant,
    linear_rampup,
    linear_rampup_exp_decay,
    linear_ramp_to,
    piecewise,
)

__all__ = [
    "Optimizer",
    "sgd",
    "momentum",
    "adam",
    "adamw",
    "yogi",
    "clip_by_global_norm",
    "chain",
    "scale_by_schedule",
    "apply_updates",
    "global_norm",
    "constant",
    "linear_rampup",
    "linear_rampup_exp_decay",
    "linear_ramp_to",
    "piecewise",
]
