"""Learning-rate and noise schedules used by the paper's experiments.

The Baseline (E0) uses a linear ramp-up LR; the cost-reduced federated
configs (E9/E10) use a *shorter* ramp-up plus exponential decay; FVN
(E7) linearly ramps the noise std-dev to a target (0.03 in the paper).
All schedules are ``step -> scalar`` pure functions of an integer count.
"""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    def schedule(count):
        return jnp.full((), value, jnp.float32)

    return schedule


def linear_rampup(peak: float, warmup_steps: int):
    """Linear 0->peak over warmup_steps, then constant (Baseline E0)."""

    def schedule(count):
        c = jnp.asarray(count, jnp.float32)
        frac = jnp.minimum(c / jnp.maximum(warmup_steps, 1), 1.0)
        return peak * frac

    return schedule


def linear_rampup_exp_decay(peak: float, warmup_steps: int, decay_steps: int, decay_rate: float):
    """Short ramp-up + exponential decay — the E9/E10 cost-reducing schedule."""

    def schedule(count):
        c = jnp.asarray(count, jnp.float32)
        warm = jnp.minimum(c / jnp.maximum(warmup_steps, 1), 1.0)
        decay = decay_rate ** (jnp.maximum(c - warmup_steps, 0.0) / jnp.maximum(decay_steps, 1))
        return peak * warm * decay

    return schedule


def linear_ramp_to(target: float, ramp_steps: int, start: float = 0.0):
    """Linear start->target over ramp_steps then hold — FVN sigma ramp (E7)."""

    def schedule(count):
        c = jnp.asarray(count, jnp.float32)
        frac = jnp.minimum(c / jnp.maximum(ramp_steps, 1), 1.0)
        return start + (target - start) * frac

    return schedule


def piecewise(boundaries, values):
    """Step function: values[i] for count in [boundaries[i-1], boundaries[i])."""
    assert len(values) == len(boundaries) + 1

    def schedule(count):
        c = jnp.asarray(count, jnp.int32)
        idx = jnp.sum(jnp.asarray(boundaries, jnp.int32) <= c)
        return jnp.asarray(values, jnp.float32)[idx]

    return schedule
