"""Gradient-transformation optimizers as pure pytree functions.

Design mirrors optax: an ``Optimizer`` is a pair of pure functions
``init(params) -> state`` and ``update(grads, state, params) ->
(updates, state)``; ``apply_updates`` adds the (already negated)
updates to the params. All state is a pytree of arrays so it shards,
vmaps and scans transparently — the federated engine vmaps client
optimizers over the client axis and FSDP-shards server state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple[PyTree, PyTree]]


def _tree_zeros_like(params: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, params)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates)


def _resolve_lr(lr, count):
    if callable(lr):
        return lr(count)
    return jnp.asarray(lr, jnp.float32)


class ScaleState(NamedTuple):
    count: jnp.ndarray


def sgd(learning_rate) -> Optimizer:
    """Plain SGD — the paper's client optimizer (constant lr 0.008)."""

    def init(params):
        return ScaleState(count=jnp.zeros((), jnp.int32))

    def update(grads, state, params=None):
        lr = _resolve_lr(learning_rate, state.count)
        updates = jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads)
        return updates, ScaleState(count=state.count + 1)

    return Optimizer(init, update)


class MomentumState(NamedTuple):
    count: jnp.ndarray
    trace: PyTree


def momentum(learning_rate, decay: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return MomentumState(count=jnp.zeros((), jnp.int32), trace=_tree_zeros_like(params))

    def update(grads, state, params=None):
        lr = _resolve_lr(learning_rate, state.count)
        trace = jax.tree.map(lambda t, g: decay * t + g.astype(jnp.float32), state.trace, grads)
        if nesterov:
            upd = jax.tree.map(lambda t, g: -(lr * (decay * t + g.astype(jnp.float32))), trace, grads)
        else:
            upd = jax.tree.map(lambda t: -lr * t, trace)
        return upd, MomentumState(count=state.count + 1, trace=trace)

    return Optimizer(init, update)


class AdamState(NamedTuple):
    count: jnp.ndarray
    mu: PyTree
    nu: PyTree


def adam(learning_rate, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    """Adam — the paper's server optimizer (Reddi et al. adaptive FL)."""

    def init(params):
        return AdamState(
            count=jnp.zeros((), jnp.int32),
            mu=_tree_zeros_like(params),
            nu=_tree_zeros_like(params),
        )

    def update(grads, state, params=None):
        count = state.count + 1
        lr = _resolve_lr(learning_rate, state.count)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.nu, grads
        )
        c = count.astype(jnp.float32)
        mu_hat_scale = 1.0 / (1 - b1**c)
        nu_hat_scale = 1.0 / (1 - b2**c)
        upd = jax.tree.map(
            lambda m, v: -lr * (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + eps), mu, nu
        )
        return upd, AdamState(count=count, mu=mu, nu=nu)

    return Optimizer(init, update)


def adamw(learning_rate, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01) -> Optimizer:
    base = adam(learning_rate, b1, b2, eps)

    def update(grads, state, params):
        upd, state = base.update(grads, state, params)
        lr = _resolve_lr(learning_rate, state.count - 1)
        upd = jax.tree.map(
            lambda u, p: u - lr * weight_decay * p.astype(jnp.float32), upd, params
        )
        return upd, state

    return Optimizer(base.init, update)


def yogi(learning_rate, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-3) -> Optimizer:
    """Yogi (additive second moment) — from Adaptive Federated Optimization."""

    def init(params):
        return AdamState(
            count=jnp.zeros((), jnp.int32),
            mu=_tree_zeros_like(params),
            nu=_tree_zeros_like(params),
        )

    def update(grads, state, params=None):
        count = state.count + 1
        lr = _resolve_lr(learning_rate, state.count)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)

        def nu_update(v, g):
            g2 = jnp.square(g.astype(jnp.float32))
            return v - (1 - b2) * jnp.sign(v - g2) * g2

        nu = jax.tree.map(nu_update, state.nu, grads)
        c = count.astype(jnp.float32)
        mu_hat_scale = 1.0 / (1 - b1**c)
        upd = jax.tree.map(lambda m, v: -lr * (m * mu_hat_scale) / (jnp.sqrt(jnp.abs(v)) + eps), mu, nu)
        return upd, AdamState(count=count, mu=mu, nu=nu)

    return Optimizer(init, update)


class ClipState(NamedTuple):
    inner: PyTree


def clip_by_global_norm(inner: Optimizer, max_norm: float) -> Optimizer:
    def init(params):
        return ClipState(inner=inner.init(params))

    def update(grads, state, params=None):
        norm = global_norm(grads)
        scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)
        upd, inner_state = inner.update(grads, state.inner, params)
        return upd, ClipState(inner=inner_state)

    return Optimizer(init, update)


class ChainState(NamedTuple):
    states: tuple


def chain(*optimizers: Optimizer) -> Optimizer:
    """Compose transformations left-to-right on the update stream."""

    def init(params):
        return ChainState(states=tuple(o.init(params) for o in optimizers))

    def update(grads, state, params=None):
        upd = grads
        new_states = []
        for o, s in zip(optimizers, state.states):
            upd, s = o.update(upd, s, params)
            new_states.append(s)
        return upd, ChainState(states=tuple(new_states))

    return Optimizer(init, update)


def scale_by_schedule(schedule: Schedule) -> Optimizer:
    def init(params):
        return ScaleState(count=jnp.zeros((), jnp.int32))

    def update(grads, state, params=None):
        s = schedule(state.count)
        upd = jax.tree.map(lambda g: g * s, grads)
        return upd, ScaleState(count=state.count + 1)

    return Optimizer(init, update)
