"""Fed-round cost predictor: static FLOP/byte counts x per-device
coefficients calibrated from captured traces.

Prices any ``FederatedPlan`` WITHOUT running it, on two axes:

- ``cfmq_tb`` — the paper's cost metric is *exactly* predictable from
  the plan + param shapes (wire accounting is arithmetic over leaf
  sizes; see :func:`point_cfmq_tb`, which mirrors the sweep runner's
  accounting term for term). Full-participation plans predict the
  measured row bit-for-bit; partial participation predicts the
  expectation of the sampled cohort size.
- ``seconds`` — wall time needs the device. A round's static cost
  features (FLOPs, HBM bytes, wire bytes, server steps) map to seconds
  through per-device coefficients fit by non-negative least squares
  over measured traces (:func:`calibrate`), the byteprofile replayer
  idea with the repo's own HLO cost model as the DAG side. Two feature
  sources share one coefficient shape: ``hlo`` (exact counts from
  ``launch/hlo_cost`` over the compiled round step — used when a
  lowering is in hand) and ``analytic`` (closed-form over the plan +
  param count — no compilation, which is what lets the sweep pruner
  run before anything compiles).

``predict_report`` is the calibrate->predict loop behind
``python -m repro.launch.roofline --predict``: measure the five
tiny-RNN-T acceptance plans (fp32 / int8 / int4_packed / top5 /
async), fit both coefficient sources, persist them to tuning.json, and
report per-plan relative error. The documented in-sample tolerance is
:data:`PREDICT_REL_TOL`; CI captures the report and a warn-only drift
step compares runs over time.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import numpy as np

FEATURE_KEYS = ("flops", "hbm_bytes", "wire_bytes", "ici_bytes", "server_steps", "overhead")

# Documented tolerance for predicted-vs-measured round seconds on the
# calibration plans (asserted by tests and the roofline --strict path).
# The five plans share ~identical client compute — only the
# compression plane differs — so the fit's residual is dominated by
# the measured side's scatter across near-equal-cost graphs; on a
# quiet host the in-sample max lands ~0.1-0.3, and 0.5 gives the
# shared-2-core-CI measured side room without letting an
# order-of-magnitude modeling error through.
PREDICT_REL_TOL = 0.5

# Uncalibrated fallback (rough CPU-host magnitudes): lets the pruner
# rank plans before any trace exists on this device. Rankings only —
# absolute seconds from these are fiction until calibrated.
DEFAULT_COEFFS = {
    "flops": 2e-10,
    "hbm_bytes": 5e-11,
    "wire_bytes": 1e-9,
    "ici_bytes": 2e-11,  # ~ICI_BW magnitude; 0 on 1-device layouts
    "server_steps": 1e-3,
    "overhead": 5e-3,
}


def abstract_params(bundle, seed: int = 0):
    """Param tree as ShapeDtypeStructs — byte-exact wire accounting
    with zero allocation (predict plans you could never fit)."""
    return jax.eval_shape(bundle.init, jax.random.PRNGKey(seed))


def _n_params(params) -> int:
    return sum(int(l.size) for l in jax.tree_util.tree_leaves(params))


def expected_server_steps(plan) -> float:
    """Server-optimizer applications per round: the sync barrier takes
    one; the buffered-async engine flushes ~K*participation/B waves."""
    k = plan.clients_per_round
    if plan.engine != "async":
        return 1.0
    buffer = plan.asynchrony.resolve_buffer(k)
    return max(1.0, k * plan.cohort.participation / buffer)


def plan_round_features(plan, params, steps: int, client_shards: int = 1) -> dict:
    """Closed-form static cost features for one round — no compilation.

    ``flops`` uses the 6*N*examples fwd+bwd rule of thumb and
    ``hbm_bytes`` charges param+grad+optimizer traffic per local step;
    both are proportional, not exact — the per-device coefficients
    absorb the constants, the features only need to scale correctly
    across plans. ``wire_bytes`` IS exact (same accounting the CFMQ
    axis uses).

    With ``client_shards`` > 1 (the round's client axis sharded over a
    ``clients`` mesh, see ``core.fedavg.ClientSharding``) the compute
    features become PER-SHARD (the critical path is one shard's
    K/shards clients) and ``ici_bytes`` prices the collectives the
    sharded round adds: a ring all-reduce moves ``2*(S-1)/S`` of the
    payload per device, and the round's reductions (code-sum psum /
    delta gather + scale pmax) are params-tree-sized, so ``4*n_params``
    stands in for the payload. On 1 device the column is exactly 0 —
    unsharded calibration zeroes its NNLS coefficient and every
    unsharded prediction is untouched."""
    from repro.core.cfmq import plan_wire_accounting

    n_params = _n_params(params)
    shards = max(1, int(client_shards))
    k = plan.clients_per_round
    up, down = plan_wire_accounting(plan, params)
    expected_clients = k * plan.cohort.participation
    examples = k * steps * plan.local_batch_size
    ici = 0.0 if shards == 1 else 2.0 * (shards - 1) / shards * 4.0 * n_params
    return {
        "flops": 6.0 * n_params * examples / shards,
        "hbm_bytes": 4.0 * n_params * (3.0 * k * steps + 2.0 * k + 2.0) / shards,
        "wire_bytes": float(down) + float(up) * expected_clients,
        "ici_bytes": ici,
        "server_steps": expected_server_steps(plan),
        "overhead": 1.0,
    }


def hlo_round_features(
    hlo_analysis: dict, plan, params, steps: int, client_shards: int = 1
) -> dict:
    """Same feature shape, with FLOPs/HBM bytes taken from the HLO
    cost model's walk of the compiled round step (``hlo_cost.analyze``
    output) instead of the closed form. The compiled module is already
    per-shard under a sharded lowering, so only the analytic fallback
    divides by ``client_shards``."""
    feats = plan_round_features(plan, params, steps, client_shards)
    feats["flops"] = float(hlo_analysis["flops"])
    feats["hbm_bytes"] = float(hlo_analysis["bytes"])
    return feats


def feature_vector(features: dict) -> np.ndarray:
    # Missing keys read as 0 so feature dicts persisted before a key was
    # added (e.g. pre-sharding traces without ici_bytes) stay loadable.
    return np.array([float(features.get(k, 0.0)) for k in FEATURE_KEYS], dtype=np.float64)


# -------------------------------------------------------- calibration


def nnls(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Non-negative least squares by active-set elimination: solve the
    unconstrained problem, drop the most-negative coefficient from the
    support, repeat. Deterministic; exact whenever the unconstrained
    solution is already non-negative (the well-posed calibration case).
    Negative coefficients would let collinear features (all five
    acceptance plans share client compute) flip the pruner's cost
    ranking — a nonsense like "more wire bytes makes rounds faster"
    must round to a zero coefficient instead."""
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    coef = np.zeros(x.shape[1])
    support = list(range(x.shape[1]))
    while support:
        sol, *_ = np.linalg.lstsq(x[:, support], y, rcond=None)
        if (sol >= -1e-12).all():
            coef[support] = np.clip(sol, 0.0, None)
            return coef
        support.pop(int(np.argmin(sol)))
    return coef


def calibrate(samples: list[tuple[dict, float]]) -> dict:
    """Fit per-device coefficients from (features, measured_seconds)
    samples — trace records or fresh measurements. Returns a coeffs
    dict over FEATURE_KEYS (>= 0 each)."""
    if not samples:
        raise ValueError("calibrate needs at least one (features, seconds) sample")
    x = np.stack([feature_vector(f) for f, _ in samples])
    y = np.array([float(s) for _, s in samples])
    # column scaling: feature magnitudes span ~12 decades (flops vs
    # overhead); normalize for lstsq conditioning, undo after
    scale = np.maximum(np.abs(x).max(axis=0), 1e-30)
    coef = nnls(x / scale, y) / scale
    return dict(zip(FEATURE_KEYS, (float(c) for c in coef)))


def predict_round_seconds(features: dict, coeffs: Optional[dict] = None) -> float:
    coeffs = coeffs or DEFAULT_COEFFS
    return float(
        sum(float(coeffs.get(k, 0.0)) * float(features.get(k, 0.0)) for k in FEATURE_KEYS)
    )


# ------------------------------------------------------- point pricing


def point_cfmq_tb(plan, params, steps: int, rounds: int) -> float:
    """Predicted CFMQ terabytes for a sweep point — mirrors
    ``SweepRunner.run_point``'s accounting exactly, with the expected
    cohort size standing in for the measured participant mean (equal
    at full participation, the expectation otherwise)."""
    from repro.core.cfmq import cfmq, measured_payload

    n_params = _n_params(params)
    expected_clients = plan.clients_per_round * plan.cohort.participation
    payload = measured_payload(plan, params, expected_clients)
    mu = plan.local_epochs * (plan.data_limit or steps * plan.local_batch_size)
    terms = cfmq(
        rounds=rounds,
        clients_per_round=plan.clients_per_round,
        model_bytes=n_params * plan.param_bytes,
        local_steps=mu / plan.local_batch_size,
        alpha=plan.alpha,
        payload_bytes=payload,
    )
    return terms.total_terabytes


def predict_point(
    plan, params, steps: int, rounds: int, coeffs: Optional[dict] = None
) -> dict:
    """Everything the planner needs about a sweep point, without
    running it: per-round seconds, whole-point seconds, the CFMQ cost
    axis, and the compression scheme's wire profile."""
    from repro.core.compression import wire_cost_profile

    feats = plan_round_features(plan, params, steps)
    round_s = predict_round_seconds(feats, coeffs)
    return {
        "round_s": round_s,
        "point_s": rounds * round_s,
        "cfmq_tb": point_cfmq_tb(plan, params, steps, rounds),
        "features": feats,
        "wire": wire_cost_profile(plan.compression, params),
    }


# ------------------------------------------- calibrate->predict report


def tiny_rnnt_plans() -> dict:
    """The five acceptance plans (the compression smoke schemes plus
    the buffered-async engine) on the tiny-RNN-T bench base."""
    from repro.core import AsyncConfig, CompressionConfig, FederatedPlan, LatencyConfig

    base = dict(
        clients_per_round=8,
        local_batch_size=4,
        data_limit=4,
        local_steps=12,
        client_lr=0.3,
        server_lr=0.05,
        server_warmup_rounds=4,
    )
    return {
        "fp32": FederatedPlan(**base),
        "int8": FederatedPlan(**base, compression=CompressionConfig(kind="int8")),
        "int4_packed": FederatedPlan(
            **base, compression=CompressionConfig(kind="int4", packed=True)
        ),
        "top5": FederatedPlan(
            **base, compression=CompressionConfig(kind="topk", topk_frac=0.05)
        ),
        "async": FederatedPlan(
            **{**base, "server_lr": 0.05 * 5 / 8},
            engine="async",
            asynchrony=AsyncConfig(buffer_size=5),
            latency=LatencyConfig(enabled=True, base_s=60.0, spread=0.35),
        ),
    }


def predict_report(
    reps: int = 3,
    seed: int = 0,
    plans: Optional[dict] = None,
    persist_coeffs: bool = True,
    trace_path: Optional[str] = None,
    log: Callable = print,
) -> dict:
    """Measure the acceptance plans' round time, fit both coefficient
    sources, report per-plan predicted-vs-measured relative error.

    In-sample by design: the report documents how well the feature
    model can explain THIS device (tolerance ``PREDICT_REL_TOL``);
    cross-run drift is what the CI warn-only step watches via the
    persisted report JSON."""
    from repro.core import build_round_engine
    from repro.core.engine import structural_key_str
    from repro.data import FederatedSampler
    from repro.launch import hlo_cost
    from repro.launch.train import tiny_asr_setup
    from repro.models import build_model
    from repro.profile import trace as trace_mod
    from repro.profile import tuner

    plans = plans or tiny_rnnt_plans()
    cfg, corpus = tiny_asr_setup(seed)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(seed))
    base_key = jax.random.PRNGKey(seed + 1)

    prepared = {}
    fns = {}
    for name, plan in plans.items():
        engine = build_round_engine(plan, bundle.loss_fn)
        steps = FederatedSampler.natural_steps(
            corpus,
            plan.local_batch_size,
            data_limit=plan.data_limit,
            local_epochs=plan.local_epochs,
            max_steps=plan.local_steps,
        )
        sampler = FederatedSampler(
            corpus,
            clients_per_round=plan.clients_per_round,
            local_batch_size=plan.local_batch_size,
            data_limit=plan.data_limit,
            local_epochs=plan.local_epochs,
            seed=seed,
            steps=steps,
        )
        batch = jax.tree.map(jax.numpy.asarray, sampler.next_round().engine_batch())
        state = engine.init_state(params)
        hypers = engine.hypers()
        log(f"[predict] compiling {name} ({structural_key_str(engine.structural_key)})")
        compiled = jax.jit(engine.hyper_step).lower(state, batch, hypers, base_key).compile()
        analysis = hlo_cost.analyze(compiled.as_text())
        prepared[name] = {
            "plan": plan,
            "steps": steps,
            "structural_key": structural_key_str(engine.structural_key),
            "analytic": plan_round_features(plan, params, steps),
            "hlo": hlo_round_features(analysis, plan, params, steps),
            "unparsed_ops": analysis["unparsed_ops"],
        }
        fns[name] = (lambda c=compiled, a=(state, batch, hypers, base_key): c(*a))

    measured = trace_mod.measure_interleaved_min(fns, reps=reps)

    coeffs = {
        source: calibrate([(prepared[n][source], measured[n]) for n in plans])
        for source in ("analytic", "hlo")
    }
    rows = []
    for name in plans:
        row = {
            "plan": name,
            "structural_key": prepared[name]["structural_key"],
            "measured_s": measured[name],
            "unparsed_ops": prepared[name]["unparsed_ops"],
        }
        for source in ("analytic", "hlo"):
            pred = predict_round_seconds(prepared[name][source], coeffs[source])
            row[f"predicted_{source}_s"] = pred
            row[f"rel_err_{source}"] = abs(pred - measured[name]) / max(measured[name], 1e-12)
        rows.append(row)
    report = {
        "schema_version": 1,
        "device_key": trace_mod.device_key(),
        "reps": reps,
        "tolerance": PREDICT_REL_TOL,
        "coefficients": coeffs,
        "rows": rows,
        "max_rel_err": {
            source: max(r[f"rel_err_{source}"] for r in rows) for source in ("analytic", "hlo")
        },
    }
    if persist_coeffs:
        reg = tuner.registry()
        for source, c in coeffs.items():
            reg.set_coefficients(source, c)
        reg.save()
        log(f"[predict] coefficients (analytic+hlo) -> {reg.path}")
    if trace_path:
        trace_mod.write_trace(
            trace_path,
            "predict",
            kernels={f"round_{n}": measured[n] * 1e6 for n in plans},
            counters={"reps": reps, "n_plans": len(plans)},
            meta={"rows": rows, "coefficients": coeffs},
        )
        log(f"[predict] trace -> {trace_path}")
    return report
