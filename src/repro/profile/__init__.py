"""Profiling plane: trace capture, fed-round cost prediction, and the
autotuning planner.

Three layers (see each module's docstring):

- ``repro.profile.trace`` — versioned trace JSON from real runs; one
  writer for train / sweeps / bench, keyed by the RoundEngine
  structural key + a device fingerprint.
- ``repro.profile.predict`` — static FLOP/byte features x per-device
  least-squares coefficients: price any FederatedPlan without running
  it.
- ``repro.profile.tuner`` — the registry that owns kernel dispatch
  thresholds (measured overrides persist to results/tuning.json) and
  the predicted-cost sweep-grid pruner.

Submodules are imported lazily: the kernel layer reads tuner knobs
from its dispatch path, so this package must be importable mid-way
through ``repro.core`` / ``repro.kernels`` imports without touching
them back.
"""

from __future__ import annotations

import importlib

_SUBMODULES = ("predict", "trace", "tuner")


def __getattr__(name: str):
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.profile.{name}")
    raise AttributeError(f"module 'repro.profile' has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_SUBMODULES))
