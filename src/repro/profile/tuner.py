"""Autotuning planner: the registry that owns every kernel dispatch
threshold, with measured-on-this-device overrides, plus the sweep-grid
pruner.

Before this module, dispatch constants (the 4096-element
``topk_unpack`` serial-vs-segmented cutoff, bench interleave rep
counts, the Pallas-vs-ref backend choice) were hard-coded from one
machine's benchmarks. Here every such constant is a named *knob* with
a documented default; call sites read them through :func:`get_knob`,
and per-device measured overrides persist to ``results/tuning.json``
keyed by the trace plane's device fingerprint — so a new backend tunes
itself once and every later run picks the measured value up.

The same JSON document stores the cost predictor's calibrated
per-device coefficients (see ``repro.profile.predict``), which is what
lets ``sweeps.py --prune-budget`` drop grid points whose *predicted*
cost exceeds a budget before anything compiles. ``check_prune`` is the
safety property behind ``--check``: pruning must never drop a row the
measured run marked pareto.

This module is import-light on purpose (stdlib only at module level):
``repro.kernels.wire_pack`` reads knobs from the hot dispatch path, so
nothing here may import jax, the kernels, or the core planes at import
time.

CLI::

    PYTHONPATH=src python -m repro.profile.tuner --show
    PYTHONPATH=src python -m repro.profile.tuner --set wire_pack.topk_seg_min_n 8192
    PYTHONPATH=src python -m repro.profile.tuner --autotune topk
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Callable, Optional

TUNING_SCHEMA_VERSION = 1
DEFAULT_PATH = os.path.join("results", "tuning.json")
ENV_PATH = "REPRO_TUNING_JSON"


@dataclasses.dataclass(frozen=True)
class Knob:
    default: object
    doc: str
    choices: Optional[tuple] = None


KNOBS: dict[str, Knob] = {
    "wire_pack.topk_seg_min_n": Knob(
        4096,
        "Output elements above which topk_unpack dispatches the segmented "
        "grid-parallel scatter instead of the serial single-block kernel "
        "(PR 5 measured the crossover at 4096 on one TPU host).",
    ),
    "wire_pack.topk_seg_size": Knob(
        2048,
        "Segment length of the segmented top-k scatter (one grid cell "
        "owns one segment of the output).",
    ),
    "wire_pack.dispatch": Knob(
        "auto",
        "Pallas-vs-ref backend choice for the wire kernels: 'auto' picks "
        "Pallas off-CPU and the jnp oracle on CPU; 'ref' forces the "
        "oracle everywhere (a measured escape hatch for backends where "
        "Pallas lowering regresses); 'pallas' forces Pallas kernels "
        "(interpret mode on CPU — test/debug only).",
        choices=("auto", "pallas", "ref"),
    ),
    "lstm.scan_dispatch": Knob(
        "auto",
        "Backend choice for the full-sequence Pallas LSTM scan kernel "
        "(w_hh VMEM-resident across steps): 'auto' picks the kernel "
        "off-CPU when the shape is eligible, the jnp lax.scan otherwise; "
        "'ref' forces the jnp scan; 'pallas' forces the kernel "
        "(interpret mode on CPU — test/debug only).",
        choices=("auto", "pallas", "ref"),
    ),
    "lstm.scan_min_seq": Knob(
        16,
        "Sequence length at or above which the LSTM layer dispatches "
        "the full-scan Pallas kernel; below it the per-step w_hh "
        "refetch is too small to matter and lax.scan wins "
        "(re-measure with --autotune lstm).",
    ),
    "lstm.scan_max_vmem_mb": Knob(
        8,
        "VMEM budget (MB) for the scan kernel's resident w_hh block; "
        "hidden sizes whose (H x 4H) fp32 weight exceeds it fall back "
        "to lax.scan.",
    ),
    "rnnt.joint_bwd_dispatch": Knob(
        "auto",
        "Backend choice for the fused RNN-T joint backward: 'auto' "
        "picks the Pallas recompute-in-VMEM backward off-CPU and the "
        "U-chunked jnp rematerialization on CPU; 'ref' forces the "
        "chunked jnp backward; 'pallas' forces the kernel (interpret "
        "mode on CPU — test/debug only).",
        choices=("auto", "pallas", "ref"),
    ),
    "prefetch.depth": Knob(
        2,
        "Queue depth of the host->device prefetch pipeline "
        "(data/prefetch.PrefetchIterator) used by launch/train.",
    ),
    "bench.fed_reps": Knob(
        5,
        "Interleaved order-rotating cycles for the fed_round bench "
        "(min per variant over this many visits).",
    ),
    "bench.fed_pair_reps": Knob(
        6,
        "Adjacent fp32-vs-variant A/B pairs per fed_round ratio "
        "(median over pairs).",
    ),
    "bench.wire_reps": Knob(
        12,
        "Interleaved min reps for the wire-plane micro benches "
        "(pack/unpack kernels).",
    ),
    "bench.micro_reps": Knob(
        5,
        "Interleaved min reps for the remaining micro benches "
        "(attention, RNN-T joint).",
    ),
    "bench.pack_reps": Knob(
        30,
        "Interleaved min reps for the host round-packing bench "
        "(fed_pack_vectorized; host-side, cheap, so many reps).",
    ),
}


def _coerce(name: str, value):
    knob = KNOBS[name]
    if knob.choices is not None:
        if value not in knob.choices:
            raise ValueError(f"{name}: {value!r} not in {knob.choices}")
        return value
    kind = type(knob.default)
    out = kind(value)
    if isinstance(out, (int, float)) and out <= 0:
        raise ValueError(f"{name}: must be positive, got {out}")
    return out


class TuningRegistry:
    """``results/tuning.json`` facade: knob overrides + predictor
    coefficients, both keyed by device fingerprint so one file serves a
    fleet of heterogeneous machines."""

    def __init__(self, path: Optional[str] = None, device_key: Optional[str] = None):
        self.path = path or os.environ.get(ENV_PATH, DEFAULT_PATH)
        self._device_key = device_key
        self._doc = self._load()

    # ------------------------------------------------------------ store

    def _load(self) -> dict:
        doc = {"schema_version": TUNING_SCHEMA_VERSION, "devices": {}}
        try:
            with open(self.path) as f:
                on_disk = json.load(f)
            if on_disk.get("schema_version") == TUNING_SCHEMA_VERSION:
                doc = on_disk
                doc.setdefault("devices", {})
        except FileNotFoundError:
            pass
        except (json.JSONDecodeError, OSError, AttributeError):
            # a corrupt tuning file must never brick the dispatch path;
            # defaults are always safe
            pass
        return doc

    @property
    def device_key(self) -> str:
        if self._device_key is None:
            from repro.profile.trace import device_key

            self._device_key = device_key()
        return self._device_key

    def _device_entry(self, create: bool = False) -> dict:
        devices = self._doc["devices"]
        if create and self.device_key not in devices:
            from repro.profile.trace import device_fingerprint

            devices[self.device_key] = {
                "fingerprint": device_fingerprint(),
                "overrides": {},
                "coefficients": {},
            }
        return devices.get(self.device_key, {})

    def save(self) -> str:
        self._doc["updated_unix"] = time.time()
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self._doc, f, indent=1, sort_keys=True)
        os.replace(tmp, self.path)
        return self.path

    # ------------------------------------------------------------ knobs

    def get(self, name: str):
        if name not in KNOBS:
            raise KeyError(f"unknown tuning knob {name!r}; known: {sorted(KNOBS)}")
        overrides = self._device_entry().get("overrides", {})
        if name in overrides:
            return _coerce(name, overrides[name])
        return KNOBS[name].default

    def overrides(self) -> dict:
        return dict(self._device_entry().get("overrides", {}))

    def set_override(self, name: str, value, persist: bool = False):
        if name not in KNOBS:
            raise KeyError(f"unknown tuning knob {name!r}; known: {sorted(KNOBS)}")
        value = _coerce(name, value)
        self._device_entry(create=True)["overrides"][name] = value
        if persist:
            self.save()
        return value

    def clear_override(self, name: str, persist: bool = False):
        self._device_entry().get("overrides", {}).pop(name, None)
        if persist:
            self.save()

    # ----------------------------------------------- predictor coeffs

    def set_coefficients(self, source: str, coeffs: dict, persist: bool = False):
        entry = self._device_entry(create=True)
        entry.setdefault("coefficients", {})[source] = {k: float(v) for k, v in coeffs.items()}
        if persist:
            self.save()

    def get_coefficients(self, source: str) -> Optional[dict]:
        got = self._device_entry().get("coefficients", {}).get(source)
        return dict(got) if got is not None else None


_ACTIVE: Optional[TuningRegistry] = None


def registry() -> TuningRegistry:
    """The process-wide registry (created lazily from $REPRO_TUNING_JSON
    or results/tuning.json)."""
    global _ACTIVE
    if _ACTIVE is None:
        _ACTIVE = TuningRegistry()
    return _ACTIVE


def set_registry(reg: Optional[TuningRegistry]) -> None:
    """Install (or with None: reset) the process-wide registry — tests
    point it at a tmp path."""
    global _ACTIVE
    _ACTIVE = reg


def get_knob(name: str):
    """Hot-path accessor used by kernel dispatchers and the bench
    harness; resolves override-else-default for this device."""
    return registry().get(name)


# ----------------------------------------------------------------------
# Autotune: measure the dispatch candidates on THIS device and persist
# the observed crossover as an override.
# ----------------------------------------------------------------------


def autotune_topk_dispatch(
    reg: Optional[TuningRegistry] = None,
    sizes=(1024, 2048, 4096, 8192, 16384, 32768),
    frac: float = 0.05,
    reps: int = 5,
    persist: bool = True,
    log=print,
) -> int:
    """Measure serial vs segmented ``topk_unpack`` Pallas kernels over
    ``sizes`` and persist the first size where the segmented scatter
    wins as ``wire_pack.topk_seg_min_n``.

    On CPU both candidates run in interpret mode, so the measured
    crossover validates the machinery rather than the production
    dispatch (CPU dispatch always takes the jnp oracle); on TPU this is
    the real PR 5 threshold, re-measured for the local chip.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import wire_pack
    from repro.profile.trace import measure_interleaved_min

    reg = reg or registry()
    interpret = jax.default_backend() == "cpu"
    crossover = None
    for n in sizes:
        k = max(1, int(frac * n))
        key = jax.random.PRNGKey(n)
        values = jax.random.normal(key, (k,), jnp.float32)
        idx = jnp.arange(k, dtype=jnp.int32) * (n // k)
        serial = jax.jit(
            lambda v, i: wire_pack.topk_unpack_pallas(v, i, n, interpret=interpret)
        )
        seg = jax.jit(
            lambda v, i: wire_pack.topk_unpack_segmented_pallas(
                v, i, n, seg=reg.get("wire_pack.topk_seg_size"), interpret=interpret
            )
        )
        t = measure_interleaved_min(
            {"serial": lambda: serial(values, idx), "segmented": lambda: seg(values, idx)},
            reps=reps,
        )
        log(
            f"[tuner] topk_unpack n={n}: serial {t['serial'] * 1e6:.1f}us "
            f"segmented {t['segmented'] * 1e6:.1f}us"
        )
        if crossover is None and t["segmented"] < t["serial"]:
            crossover = n
    chosen = crossover if crossover is not None else max(sizes) * 2
    reg.set_override("wire_pack.topk_seg_min_n", chosen, persist=persist)
    log(f"[tuner] wire_pack.topk_seg_min_n <- {chosen} (device {reg.device_key})")
    return chosen


def autotune_lstm_scan(
    reg: Optional[TuningRegistry] = None,
    seq_lens=(4, 8, 16, 32, 64, 128),
    batch: int = 8,
    hidden: int = 128,
    reps: int = 5,
    persist: bool = True,
    log=print,
) -> int:
    """Measure the full-scan Pallas LSTM kernel against the jnp
    ``lax.scan`` over ``seq_lens`` (forward + backward, the training
    shape) and persist the first length where the kernel wins as
    ``lstm.scan_min_seq``.

    On CPU the kernel runs in interpret mode, so the crossover
    validates the machinery rather than the production dispatch (CPU
    dispatch always takes lax.scan); on TPU this is the real
    w_hh-residency threshold for the local chip."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.lstm_gates import lstm_scan_fused_vjp
    from repro.models.lstm import lstm_gates
    from repro.profile.trace import measure_interleaved_min

    reg = reg or registry()
    interpret = jax.default_backend() == "cpu"
    key = jax.random.PRNGKey(0)
    w_hh = jax.random.normal(key, (hidden, 4 * hidden), jnp.float32) * 0.1
    crossover = None
    for S in seq_lens:
        xg = jax.random.normal(key, (S, batch, 4 * hidden), jnp.float32)
        h0 = jnp.zeros((batch, hidden), jnp.float32)
        c0 = jnp.zeros((batch, hidden), jnp.float32)

        def scan_loss(xg, w):
            def step(carry, xg_t):
                h, c = carry
                h, c = lstm_gates(xg_t + h @ w, c)
                return (h, c), h

            _, ys = jax.lax.scan(step, (h0, c0), xg)
            return jnp.sum(ys * ys)

        def kernel_loss(xg, w):
            ys, _, _ = lstm_scan_fused_vjp(xg, w, h0, c0, interpret=interpret)
            return jnp.sum(ys * ys)

        scan_g = jax.jit(jax.grad(scan_loss, argnums=(0, 1)))
        kern_g = jax.jit(jax.grad(kernel_loss, argnums=(0, 1)))
        t = measure_interleaved_min(
            {"scan": lambda: scan_g(xg, w_hh), "kernel": lambda: kern_g(xg, w_hh)},
            reps=reps,
        )
        log(
            f"[tuner] lstm_scan S={S}: lax.scan {t['scan'] * 1e6:.1f}us "
            f"kernel {t['kernel'] * 1e6:.1f}us"
        )
        if crossover is None and t["kernel"] < t["scan"]:
            crossover = S
    chosen = crossover if crossover is not None else max(seq_lens) * 2
    reg.set_override("lstm.scan_min_seq", chosen, persist=persist)
    log(f"[tuner] lstm.scan_min_seq <- {chosen} (device {reg.device_key})")
    return chosen


AUTOTUNERS: dict[str, Callable] = {
    "topk": autotune_topk_dispatch,
    "lstm": autotune_lstm_scan,
}


# ----------------------------------------------------------------------
# Sweep-grid pruner: drop points whose predicted cost exceeds a budget
# BEFORE anything compiles; --check proves the frontier survives.
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PruneDecision:
    point_id: str
    axis: str
    predicted: float
    budget: float

    @property
    def keep(self) -> bool:
        return self.predicted <= self.budget

    def as_dict(self) -> dict:
        return {
            "point_id": self.point_id,
            "axis": self.axis,
            "predicted": self.predicted,
            "budget": self.budget,
            "keep": self.keep,
        }


def prune_report(predicted: dict[str, float], budget: float, axis: str) -> dict:
    """{point_id: PruneDecision} over per-point predicted costs."""
    return {
        pid: PruneDecision(point_id=pid, axis=axis, predicted=float(cost), budget=float(budget))
        for pid, cost in predicted.items()
    }


def check_prune(rows: list[dict], report: dict, *, rtol: float = 0.05, log=print) -> int:
    """The pruner-never-drops-pareto property, asserted against a full
    measured run: (a) the budget must actually drop >= 1 point, (b) no
    measured-pareto row may be dropped, (c) where the budget axis is a
    measured row column (cfmq_tb), prediction must agree with the
    measurement within ``rtol``. Returns the dropped count."""
    dropped = [d.point_id for d in report.values() if not d.keep]
    if not dropped:
        raise AssertionError(
            f"--prune-budget dropped nothing: every predicted cost is under "
            f"{next(iter(report.values())).budget if report else float('nan')}"
        )
    for row in rows:
        pid = row.get("id")
        if pid not in report:
            raise AssertionError(f"measured row {pid!r} has no prune decision")
        d = report[pid]
        if row.get("pareto") and not d.keep:
            raise AssertionError(
                f"prune budget {d.budget} would drop PARETO point {pid!r} "
                f"(predicted {d.axis}={d.predicted:.6g}) — raise the budget"
            )
        if d.axis in row:
            measured = float(row[d.axis])
            err = abs(d.predicted - measured) / max(abs(measured), 1e-12)
            if err > rtol:
                raise AssertionError(
                    f"{pid!r}: predicted {d.axis}={d.predicted:.6g} vs measured "
                    f"{measured:.6g} (rel err {err:.3f} > {rtol})"
                )
    log(
        f"[tuner] prune check OK: {len(dropped)}/{len(report)} points over "
        f"budget ({', '.join(sorted(dropped))}), pareto frontier intact"
    )
    return len(dropped)


# ------------------------------------------------------------------ CLI


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--path", default=None, help="tuning JSON (default results/tuning.json)")
    ap.add_argument("--show", action="store_true", help="print knobs + overrides for this device")
    ap.add_argument("--set", nargs=2, metavar=("NAME", "VALUE"), action="append", default=[])
    ap.add_argument("--autotune", choices=sorted(AUTOTUNERS), action="append", default=[])
    args = ap.parse_args(argv)
    reg = TuningRegistry(path=args.path)
    for name, value in args.set:
        reg.set_override(name, value, persist=True)
        print(f"{name} <- {reg.get(name)!r}")
    for target in args.autotune:
        AUTOTUNERS[target](reg)
    if args.show or not (args.set or args.autotune):
        overrides = reg.overrides()
        print(f"# device {reg.device_key} ({reg.path})")
        for name in sorted(KNOBS):
            src = "override" if name in overrides else "default"
            print(f"{name:32s} = {reg.get(name)!r:10} [{src}] {KNOBS[name].doc.split('.')[0]}")


if __name__ == "__main__":
    main()
