"""Trace capture: versioned trace JSON from real runs, one writer for
train / sweeps / bench.

The profiling plane's ground truth. Every emitter — the train driver's
round loop, the sweep runner's per-point timing, the kernel micro
benches, the roofline predictor — produces the SAME record shape
through :func:`write_trace` (mirroring how ``repro.core.metrics`` owns
one summary-row schema for train/sweeps/bench), so the predictor's
calibration can consume any of them:

- ``schema_version`` / ``kind``: one of :data:`TRACE_KINDS`;
- ``device`` + ``device_key``: the fingerprint that keys tuning.json —
  coefficients calibrated on one machine never silently price another;
- ``structural_key``: the RoundEngine jit-cache identity of the traced
  plan (``repro.core.engine.structural_key_str``), so traces join
  against compiled-graph identities, not point names;
- ``sections``: per-stage wall timers ({count, total_s, min_s,
  mean_s}) from a :class:`TraceRecorder` wrapped around the host
  pipeline stages (pack -> round step -> eval; the round step itself
  is ONE jitted graph, so in-graph stages are priced by the HLO cost
  model instead);
- ``kernels``: per-kernel us from the micro benches;
- ``features`` + ``counters``: the predictor's static per-round cost
  features and run bookkeeping (rounds, n_params, ...).

Also home to :func:`measure_interleaved_min` — the order-rotating
min-of-reps protocol the fed_round bench established (PR 5/6), shared
here so benches and the predictor measure the same way.
"""

from __future__ import annotations

import json
import os
import platform
import threading
import time
from contextlib import contextmanager
from typing import Callable, Optional

import jax

TRACE_SCHEMA_VERSION = 1
TRACE_KINDS = ("round", "sweep", "kernels", "predict")
SECTION_STAT_KEYS = ("count", "total_s", "min_s", "mean_s")


# ------------------------------------------------------------ identity


def device_fingerprint() -> dict:
    """What makes timings from this process comparable: accelerator
    kind + count, host arch, and the jax version (Pallas lowering and
    XLA fusion choices move between releases)."""
    devices = jax.devices()
    return {
        "backend": jax.default_backend(),
        "device_kind": devices[0].device_kind if devices else "unknown",
        "device_count": len(devices),
        "host_arch": platform.machine(),
        "jax_version": jax.__version__,
    }


def device_key(fp: Optional[dict] = None) -> str:
    """Stable slug of the fingerprint — the tuning.json / trace join
    key (e.g. ``cpu_x8_cpu_x86_64_jax0.4.37``)."""
    fp = fp or device_fingerprint()
    raw = (
        f"{fp['backend']}_x{fp['device_count']}_{fp['device_kind']}"
        f"_{fp['host_arch']}_jax{fp['jax_version']}"
    )
    return "".join(c if c.isalnum() or c in "._-" else "_" for c in raw.lower())


# ----------------------------------------------------------- recorder


class TraceRecorder:
    """Lightweight per-section wall timers (thread-safe: the data
    plane's prefetch worker packs on a background thread)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._sections: dict[str, list[float]] = {}

    def add(self, name: str, seconds: float) -> None:
        with self._lock:
            self._sections.setdefault(name, []).append(float(seconds))

    @contextmanager
    def section(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.add(name, time.perf_counter() - t0)

    def wrap(self, name: str, fn: Callable) -> Callable:
        """fn -> fn that times every call into section ``name``."""

        def timed(*args, **kwargs):
            with self.section(name):
                return fn(*args, **kwargs)

        return timed

    def stats(self) -> dict:
        with self._lock:
            out = {}
            for name, samples in self._sections.items():
                out[name] = {
                    "count": len(samples),
                    "total_s": sum(samples),
                    "min_s": min(samples),
                    "mean_s": sum(samples) / len(samples),
                }
            return out


# ------------------------------------------------------------- schema


def trace_record(
    kind: str,
    *,
    structural_key: Optional[str] = None,
    sections: Optional[dict] = None,
    kernels: Optional[dict] = None,
    counters: Optional[dict] = None,
    features: Optional[dict] = None,
    meta: Optional[dict] = None,
) -> dict:
    """Build a schema-valid trace record (the one writer's payload)."""
    rec = {
        "schema_version": TRACE_SCHEMA_VERSION,
        "kind": kind,
        "created_unix": time.time(),
        "device": device_fingerprint(),
        "device_key": device_key(),
        "structural_key": structural_key,
        "sections": dict(sections or {}),
        "kernels": {k: float(v) for k, v in (kernels or {}).items()},
        "counters": {k: float(v) for k, v in (counters or {}).items()},
        "features": {k: float(v) for k, v in (features or {}).items()},
        "meta": dict(meta or {}),
    }
    return validate_trace(rec)


def validate_trace(rec: dict) -> dict:
    """Strict schema check — same contract style as
    ``repro.core.metrics.summary_row``: unknown shapes fail loudly at
    the writer, not in a reader three PRs later."""
    if rec.get("schema_version") != TRACE_SCHEMA_VERSION:
        raise ValueError(
            f"trace schema_version {rec.get('schema_version')!r} != {TRACE_SCHEMA_VERSION}"
        )
    if rec.get("kind") not in TRACE_KINDS:
        raise ValueError(f"trace kind {rec.get('kind')!r} not in {TRACE_KINDS}")
    required = (
        "created_unix",
        "device",
        "device_key",
        "structural_key",
        "sections",
        "kernels",
        "counters",
        "features",
        "meta",
    )
    missing = [k for k in required if k not in rec]
    if missing:
        raise ValueError(f"trace record missing keys: {missing}")
    for name, stats in rec["sections"].items():
        extra = set(stats) - set(SECTION_STAT_KEYS)
        lacking = set(SECTION_STAT_KEYS) - set(stats)
        if extra or lacking:
            raise ValueError(
                f"section {name!r}: stats must be exactly {SECTION_STAT_KEYS} "
                f"(extra={sorted(extra)}, missing={sorted(lacking)})"
            )
    return rec


def write_trace(path: str, kind: str, **kwargs) -> str:
    """THE trace writer — every emitter goes through here. ``kwargs``
    are :func:`trace_record` fields; a TraceRecorder may be passed
    directly as ``sections``."""
    sections = kwargs.get("sections")
    if isinstance(sections, TraceRecorder):
        kwargs["sections"] = sections.stats()
    rec = trace_record(kind, **kwargs)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(rec, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    return path


def load_trace(path: str) -> dict:
    with open(path) as f:
        return validate_trace(json.load(f))


def load_traces(dirpath: str, kind: Optional[str] = None) -> list[dict]:
    """All ``trace_*.json`` records under ``dirpath`` (optionally one
    kind), skipping files that fail validation — foreign/stale traces
    must not break calibration."""
    out = []
    if not os.path.isdir(dirpath):
        return out
    for name in sorted(os.listdir(dirpath)):
        if not (name.startswith("trace_") and name.endswith(".json")):
            continue
        try:
            rec = load_trace(os.path.join(dirpath, name))
        except (ValueError, json.JSONDecodeError, OSError):
            continue
        if kind is None or rec["kind"] == kind:
            out.append(rec)
    return out


# -------------------------------------------------------- measurement


def _block(x):
    try:
        return jax.block_until_ready(x)
    except Exception:
        return x


def measure_interleaved_min(
    fns: dict[str, Callable], reps: Optional[int] = None, warmup: int = 1
) -> dict[str, float]:
    """Order-rotating interleaved min-of-reps wall timing, in seconds.

    The fed_round bench protocol, generalized: warm every candidate
    first (compile excluded), then run ``reps`` cycles, each visiting
    every fn once in an order rotated per cycle (so drift hits each
    candidate equally), and report the per-fn MIN — the lowest
    observed time is the least-noise estimate on a shared machine.
    """
    if reps is None:
        from repro.profile.tuner import get_knob

        reps = int(get_knob("bench.micro_reps"))
    names = list(fns)
    for _ in range(max(warmup, 1)):
        for name in names:
            _block(fns[name]())
    best = {name: float("inf") for name in names}
    for r in range(reps):
        order = names[r % len(names) :] + names[: r % len(names)]
        for name in order:
            t0 = time.perf_counter()
            _block(fns[name]())
            best[name] = min(best[name], time.perf_counter() - t0)
    return best
