"""Federated Variational Noise (paper §4.2.2).

Variational Noise (Graves 2011) adds Gaussian noise to model
parameters at each optimization step. Under FL's two-level
optimization the paper adapts it so *each client draws its own noise
tensors during local optimization* — all clients sample from the same
N(0, sigma(round)) so client parameters approximate draws from one
shared Q(beta), which is the paper's argued mechanism for limiting
per-client drift. sigma follows a linear ramp over rounds (E7:
"Ramp to 0.03").

Keys are derived as fold_in(fold_in(fold_in(base, round), client),
step): deterministic, per-client, per-step — reproducible across the
vmap over clients and the scan over local steps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.plan import FVNConfig


def fvn_sigma(cfg: FVNConfig, round_idx) -> jnp.ndarray:
    """Noise std for a round (linear ramp, paper E7)."""
    if not cfg.enabled:
        return jnp.zeros(())
    if cfg.ramp_rounds > 0:
        frac = jnp.minimum(jnp.asarray(round_idx, jnp.float32) / cfg.ramp_rounds, 1.0)
        return cfg.std * frac
    return jnp.full((), cfg.std, jnp.float32)


def fvn_key(base_key, round_idx, client_idx, step_idx):
    k = jax.random.fold_in(base_key, round_idx)
    k = jax.random.fold_in(k, client_idx)
    return jax.random.fold_in(k, step_idx)


def perturb(params, key, sigma):
    """params + N(0, sigma) — one independent draw per tensor."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = jax.random.split(key, len(leaves))
    noisy = [
        (p.astype(jnp.float32) + sigma * jax.random.normal(k, p.shape, jnp.float32)).astype(p.dtype)
        for p, k in zip(leaves, keys)
    ]
    return jax.tree_util.tree_unflatten(treedef, noisy)
