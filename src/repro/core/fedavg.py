"""FedAvg round engines (paper Alg. 1) as single pjit-able functions.

A federated round is ONE pure function of (server state, round batch):
clients are a leading array axis — ``jax.vmap`` over clients wrapping a
``jax.lax.scan`` over local steps — so under pjit with the client axis
sharded over the mesh's ("pod","data") axes, client-parallel local
training and the delta-aggregation all-reduce lower exactly like the
production system's communication pattern.

Two engines (see DESIGN.md §3):

- ``fedavg``: general case. Per-client weight replicas live on the
  client's model-parallel group; supports local_steps >= 1 and
  per-client FVN. Weights must fit one model-parallel group.
- ``fedsgd``: the paper's §2.2 IID-limit (one local step). No
  per-client weight state exists, so weights can be FSDP-sharded; the
  round is one example-weighted forward/backward over all clients'
  data. FVN degrades to one shared draw per round (documented).

The server update treats the example-weighted average delta
``wbar = sum_k (n_k/n) (w^r - w_k)`` as a pseudo-gradient for the
server optimizer (Adam in the paper), i.e. adaptive federated
optimization (Reddi et al.).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import fvn as fvn_lib
from repro.core.plan import FederatedPlan, make_server_optimizer
from repro.optim import Optimizer, apply_updates, sgd

PyTree = Any


class ServerState(NamedTuple):
    params: PyTree
    opt_state: PyTree
    round_idx: jnp.ndarray


def init_server_state(plan: FederatedPlan, params: PyTree) -> ServerState:
    opt = make_server_optimizer(plan)
    return ServerState(params=params, opt_state=opt.init(params),
                       round_idx=jnp.zeros((), jnp.int32))


def _client_update(
    loss_fn: Callable,
    client_opt: Optimizer,
    plan: FederatedPlan,
    base_key,
    params: PyTree,
    client_batch: PyTree,
    client_idx,
    round_idx,
):
    """Local optimization for one client (vmapped over the K axis).

    client_batch leaves have shape (S_local, b, ...). Returns
    (delta = w^r - w_hat, mean loss, examples seen).
    """
    n_steps = jax.tree.leaves(client_batch)[0].shape[0]

    def local_step(carry, inp):
        p, opt_state = carry
        step_batch, step_idx = inp
        sigma = fvn_lib.fvn_sigma(plan.fvn, round_idx)
        key = fvn_lib.fvn_key(base_key, round_idx, client_idx, step_idx)
        p_eval = fvn_lib.perturb(p, key, sigma) if plan.fvn.enabled else p
        data_key = jax.random.fold_in(key, 1)
        (loss, _aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            p_eval, step_batch, data_key)
        updates, opt_state = client_opt.update(grads, opt_state, p)
        p = apply_updates(p, updates)
        w = step_batch.get("weight")
        n = w.sum() if w is not None else jnp.asarray(
            jax.tree.leaves(step_batch)[0].shape[0], jnp.float32)
        return (p, opt_state), (loss, n)

    init = (params, client_opt.init(params))
    (p_final, _), (losses, ns) = jax.lax.scan(
        local_step, init, (client_batch, jnp.arange(n_steps)))
    delta = jax.tree.map(lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                         params, p_final)
    n_k = ns.sum()
    step_mask = (ns > 0).astype(jnp.float32)
    mean_loss = (losses * step_mask).sum() / jnp.maximum(step_mask.sum(), 1.0)
    return delta, mean_loss, n_k


def make_fedavg_round(
    loss_fn: Callable,
    plan: FederatedPlan,
    base_key,
) -> Callable[[ServerState, PyTree], tuple[ServerState, dict]]:
    """Returns round_step(state, round_batch) -> (state, metrics).

    round_batch leaves: (K, S_local, b, ...); must contain "weight"
    (K, S_local, b) marking real examples (the paper's n_k weighting).
    """
    client_opt = sgd(plan.client_lr)
    server_opt = make_server_optimizer(plan)

    def round_step(state: ServerState, round_batch: PyTree):
        K = jax.tree.leaves(round_batch)[0].shape[0]

        deltas, losses, n_k = jax.vmap(
            lambda cb, ci: _client_update(
                loss_fn, client_opt, plan, base_key,
                state.params, cb, ci, state.round_idx)
        )(round_batch, jnp.arange(K))

        n = jnp.maximum(n_k.sum(), 1.0)
        w = (n_k / n).astype(jnp.float32)                       # (K,)
        wbar = jax.tree.map(
            lambda d: jnp.tensordot(w, d, axes=(0, 0)), deltas)  # Σ_k n_k/n Δ_k

        updates, opt_state = server_opt.update(wbar, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        metrics = {
            "loss": (losses * n_k).sum() / n,
            "examples": n_k.sum(),
            "delta_norm": jnp.sqrt(sum(jnp.sum(jnp.square(x))
                                       for x in jax.tree.leaves(wbar))),
        }
        return ServerState(params, opt_state, state.round_idx + 1), metrics

    return round_step


def make_fedsgd_round(
    loss_fn: Callable,
    plan: FederatedPlan,
    base_key,
) -> Callable[[ServerState, PyTree], tuple[ServerState, dict]]:
    """Large-model engine: one local step at the round-start weights.

    round_batch leaves: (K, 1, b, ...) (same layout as fedavg with
    S_local = 1). Equivalent to fedavg(local_steps=1) up to FVN
    granularity: grads are taken at w^r for every client, so the round
    collapses to one example-weighted forward/backward — weights stay
    FSDP-sharded, no per-client weight replicas exist.
    """
    server_opt = make_server_optimizer(plan)

    def round_step(state: ServerState, round_batch: PyTree):
        K, S = jax.tree.leaves(round_batch)[0].shape[:2]
        flat = jax.tree.map(
            lambda x: x.reshape((K * S * x.shape[2],) + x.shape[3:]), round_batch)
        sigma = fvn_lib.fvn_sigma(plan.fvn, state.round_idx)
        key = fvn_lib.fvn_key(base_key, state.round_idx, 0, 0)
        p_eval = (fvn_lib.perturb(state.params, key, sigma)
                  if plan.fvn.enabled else state.params)
        data_key = jax.random.fold_in(key, 1)
        (loss, _aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            p_eval, flat, data_key)
        # delta of the 1-step client update = client_lr * grad
        wbar = jax.tree.map(lambda g: plan.client_lr * g.astype(jnp.float32), grads)
        updates, opt_state = server_opt.update(wbar, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        w = flat.get("weight")
        n = w.sum() if w is not None else jnp.asarray(K * S, jnp.float32)
        metrics = {
            "loss": loss,
            "examples": n,
            "delta_norm": jnp.sqrt(sum(jnp.sum(jnp.square(x))
                                       for x in jax.tree.leaves(wbar))),
        }
        return ServerState(params, opt_state, state.round_idx + 1), metrics

    return round_step


def make_round_step(loss_fn, plan: FederatedPlan, base_key):
    if plan.engine == "fedsgd":
        return make_fedsgd_round(loss_fn, plan, base_key)
    return make_fedavg_round(loss_fn, plan, base_key)


def server_state_specs(plan: FederatedPlan, param_specs, moment_specs=None):
    """PartitionSpec tree matching init_server_state's output.

    ``moment_specs`` lets the launcher FSDP-shard optimizer moments
    independently of the live params (they only touch aggregation)."""
    from jax.sharding import PartitionSpec as P

    from repro.optim.optimizers import AdamState, MomentumState, ScaleState

    moment_specs = param_specs if moment_specs is None else moment_specs
    opt = plan.server_optimizer
    if opt == "sgd":
        os_ = ScaleState(count=P())
    elif opt == "momentum":
        os_ = MomentumState(count=P(), trace=moment_specs)
    else:  # adam | yogi
        os_ = AdamState(count=P(), mu=moment_specs, nu=moment_specs)
    return ServerState(params=param_specs, opt_state=os_,
                       round_idx=P())
