"""FedAvg round engines (paper Alg. 1) as single pjit-able functions.

A federated round is ONE pure function of (server state, round batch):
clients are a leading array axis — ``jax.vmap`` over clients wrapping a
``jax.lax.scan`` over local steps — so under pjit with the client axis
sharded over the mesh's ("pod","data") axes, client-parallel local
training and the delta-aggregation all-reduce lower exactly like the
production system's communication pattern.

Three engines (see DESIGN.md §3 and ``repro.core.engine`` for the
unified ``RoundEngine`` facade):

- ``fedavg``: general case. Per-client weight replicas live on the
  client's model-parallel group; supports local_steps >= 1 and
  per-client FVN. Weights must fit one model-parallel group.
- ``fedsgd``: the paper's §2.2 IID-limit (one local step). No
  per-client weight state exists, so weights can be FSDP-sharded; the
  round is one example-weighted forward/backward over all clients'
  data. FVN degrades to one shared draw per round (documented).
- ``async``: buffered-asynchronous (FedBuff-style) streaming server —
  see ``repro.core.async_engine``. Shares this module's client update,
  cohort stage and payload pipeline; replaces the barrier aggregate
  with a staleness-discounted buffer.

The server update treats the aggregated delta ``wbar`` as a
pseudo-gradient for the server optimizer (Adam in the paper), i.e.
adaptive federated optimization (Reddi et al.).

The round step is a composed server-side pipeline (one jitted graph):

    client deltas -> cohort mask -> uplink compression -> corruption
                  -> aggregator -> server optimizer

Each stage is pluggable (see ``repro.core.cohort`` / ``compression`` /
``aggregation`` / ``corruption``); the defaults — full participation,
no compression, no adversary, example-weighted mean — reproduce the
paper's Alg. 1 exactly and are the parity baseline for tests. The
round metrics report the *exact* wire bytes of the configured
compression so CFMQ can account measured (not approximated)
communication cost, and carry exactly the keys of
``repro.core.metrics.ROUND_METRIC_KEYS`` — including the simulated
wall-clock axis (``sim_time_s``), which for a barrier round is the
slowest participant's arrival under the plan's ``LatencyConfig``
device-tier model (0.0 when disabled: the paper prices bytes only).

When the plane quantizes (int8/int4) under the paper's weighted mean
with no EF and no delta adversary, the engine statically swaps the
compress->aggregate stages for the *code-domain fast path*
(``compression.code_domain_aggregate``): per-leaf scales are
negotiated by a max-reduce over the client axis, each client runs ONE
fused quantize(+nibble-pack) kernel, the reduction is an exact int32
code sum (``sum_packed_codes``) and the server dequantizes once —
per-client fp32 deltas are never rematerialized, wire bytes are
untouched, and every other configuration (including the fp32 parity
plane) keeps its previous graph byte for byte.

With ``compression.error_feedback`` the pipeline carries EF21-style
per-client residuals in ``ServerState.ef``: client k uploads
C(delta_k + ef_k) and keeps ef_k' = (delta_k + ef_k) - C(...), so the
error of aggressive compression (top-k at small fractions, int4) is
compensated over rounds instead of lost. Wire bytes are unchanged.
With ``compression.packed`` the uplink payloads are materialized
(int8 / int4-nibble / top-k (value, index) buffers via
``repro.kernels.wire_pack``) and round-tripped bit-exactly.

The corruption stage (``repro.core.corruption``) models Byzantine /
faulty clients on what the server *receives* (the post-compression
deltas): its rate and magnitude are traced ``HYPER_KEYS`` scalars, so
an adversary grid shares one compilation per (aggregator, kind), and a
corrupted client still pays its full uplink bytes — the wire metrics
count participants, not honesty.

With a ``ClientSharding`` (a mesh with a named ``clients`` axis,
threaded through ``build_round_engine``), the per-client stage runs
under ``shard_map``: each shard owns K/shards clients of the round
batch and the local-steps scan runs unchanged per client, so the
sharded round is bit-for-bit the vmap round on a 1-device mesh. The
code-domain fast path additionally keeps its whole aggregate inside
the shard_map — the shared-scale negotiation becomes a ``lax.pmax``
over 4-byte scalars and ``sum_packed_codes`` becomes a literal
``lax.psum`` of int32 partial code sums (exact integer arithmetic, so
the single-server-dequant semantics and the int32 overflow bound carry
over unchanged). The slow path (EF / robust aggregators / delta
adversaries) shards the client compute only and aggregates on the
gathered global axis.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import fvn as fvn_lib
from repro.core.aggregation import AGG_HYPER_DEFAULTS, get_aggregator
from repro.core.cohort import LatencyConfig, identity_cohort, make_cohort_fn, make_latency_fn
from repro.core.compression import (
    CompressionConfig,
    client_wire_bytes,
    code_domain_aggregate,
    code_domain_aggregate_ef,
    make_compressor,
    tree_param_bytes,
)
from repro.core.corruption import DELTA_KINDS, identity_corruption, make_corruption_fn
from repro.core.plan import FederatedPlan, make_server_optimizer
from repro.optim import Optimizer, apply_updates, sgd

PyTree = Any


class ClientSharding(NamedTuple):
    """Construction-time capability: run a round's per-client stage
    under ``shard_map`` over a named mesh axis, each shard owning
    K/num_shards clients of the round batch.

    The mesh axis name and size are compile-time structure (they shape
    the lowered collectives), so engines fold ``structural()`` into
    their jit-cache identity; the concrete device assignment is not —
    the same program lowers on any mesh of the same shape."""

    mesh: Any  # jax.sharding.Mesh with ``axis`` in its axis names
    axis: str = "clients"

    @property
    def num_shards(self) -> int:
        return int(self.mesh.shape[self.axis])

    def structural(self) -> tuple:
        return ("clients_sharded", self.axis, self.num_shards)

    def check_clients(self, clients: int) -> None:
        if self.axis not in self.mesh.shape:
            raise ValueError(
                f"client sharding axis {self.axis!r} is not on the mesh "
                f"(axes: {tuple(self.mesh.shape)}) — build it with "
                "launch.mesh.make_federated_mesh"
            )
        if clients % self.num_shards:
            raise ValueError(
                f"clients_per_round={clients} does not divide over the "
                f"{self.num_shards}-way {self.axis!r} mesh axis — each "
                "shard owns an equal slice of the round batch"
            )


class ServerState(NamedTuple):
    params: PyTree
    opt_state: PyTree
    round_idx: jnp.ndarray
    # EF21 per-client compression residuals: a params-shaped tree with a
    # leading K axis when plan.compression.error_feedback, else None.
    # Client k compresses (delta_k + ef_k) and keeps the compression
    # error as next round's residual, so top-k/int4 error is
    # compensated across rounds instead of lost.
    ef: Optional[PyTree] = None
    # Stale-replay cache (plan.corruption.kind == "stale", else None):
    # each participant's last honestly-computed (post-compression)
    # delta, leading K axis — what a stale adversary re-sends next
    # round (honest even for corrupted clients: staleness stays one
    # round deep, never a replay-of-replay).
    stale: Optional[PyTree] = None
    # Buffered-async engine state (plan.engine == "async", else None):
    # an ``async_engine.AsyncBuffer`` of pending staleness-tagged
    # deltas that persists ACROSS waves — a straggler's update lands in
    # a later wave's flush instead of being dropped.
    abuf: Optional[Any] = None


class ServerPlane(NamedTuple):
    """The composed server side of one round: cohort -> compression ->
    corruption -> aggregation. Built once per (static) configuration;
    every traced knob rides in via the closures (plan constants or
    hyper inputs). ``aggregator_name`` / ``corruption_kind`` mirror the
    closures as static strings so the engine can select the code-domain
    fast path at trace time (see ``_code_fast_path``)."""

    cohort: Callable  # (key, weight) -> (weight', pmask)
    compress: Callable  # (delta_tree, key) -> delta_tree
    compression: CompressionConfig  # static: wire-byte accounting
    aggregate: Callable  # (deltas, n_k, pmask, key) -> wbar
    corrupt: Callable = identity_corruption
    # (key, deltas, pmask, stale) -> (deltas', cmask, stale')
    aggregator_name: str = "weighted_mean"
    corruption_kind: str = "none"


def _code_fast_path(plane: ServerPlane) -> bool:
    """Static selector for the code-domain aggregation fast path: the
    plane compresses (int8/int4/topk), aggregates with the paper's
    weighted mean, and no delta-domain adversary needs the per-client
    fp32 deltas the fast path never materializes (corruption transforms
    what the server receives; in the fast path the server receives code
    sums / payload scatters). EF planes are eligible since PR 10:
    ``code_domain_aggregate_ef`` computes the residual straight from
    the transmitted codes' dequant (intN) or the selected-coordinate
    zeroing (topk), so no separately compressed fp32 tree is needed.
    Everything here is compile-time structure, so the fp32 parity graph
    is byte-for-byte untouched and each configuration keeps one
    compilation."""
    return (
        plane.compression.kind in ("int8", "int4", "topk")
        and plane.aggregator_name == "weighted_mean"
        and plane.corruption_kind not in DELTA_KINDS
    )


# Distinct fold_in tags keep the plane's RNG streams away from the FVN
# stream (which folds small client/step indices).
_COHORT_TAG, _COMPRESS_TAG, _AGG_TAG, _CORRUPT_TAG = (0x636F68, 0x636D70, 0x616767, 0x626164)
# Arrival-latency stream: its own tag so enabling the latency model
# never perturbs the cohort/compression/aggregation/corruption draws.
_LATENCY_TAG = 0x6C6174


def _plane_keys(base_key, round_idx):
    rk = jax.random.fold_in(base_key, round_idx)
    return (
        jax.random.fold_in(rk, _COHORT_TAG),
        jax.random.fold_in(rk, _COMPRESS_TAG),
        jax.random.fold_in(rk, _AGG_TAG),
        jax.random.fold_in(rk, _CORRUPT_TAG),
    )


def _latency_key(base_key, round_idx):
    return jax.random.fold_in(jax.random.fold_in(base_key, round_idx), _LATENCY_TAG)


def _make_server_plane(
    aggregator: str = "weighted_mean",
    compression: Optional[CompressionConfig] = None,
    cohort_knobs: Optional[tuple] = None,  # (participation, frac, keep) or None
    agg_hypers: Optional[dict] = None,
    corruption_kind: str = "none",
    corruption_knobs: Optional[tuple] = None,  # (rate, scale) or None
) -> ServerPlane:
    """Compose a server plane. ``cohort_knobs=None`` means the paper's
    full-participation assumption (no cohort RNG enters the graph);
    knob values may be Python floats or traced scalars. Likewise
    ``corruption_kind="none"`` (and the data-plane "label_shuffle")
    keeps the identity corruption stage with no adversary RNG."""
    compression = compression or CompressionConfig()
    cohort = identity_cohort if cohort_knobs is None else make_cohort_fn(*cohort_knobs)
    agg_fn = get_aggregator(aggregator)
    hyp = dict(AGG_HYPER_DEFAULTS, **(agg_hypers or {}))
    rate, scale = corruption_knobs if corruption_knobs is not None else (0.0, 1.0)
    return ServerPlane(
        cohort=cohort,
        compress=make_compressor(compression),
        compression=compression,
        aggregate=lambda deltas, n_k, pmask, key: agg_fn(deltas, n_k, pmask, hyp, key),
        corrupt=make_corruption_fn(corruption_kind, rate, scale),
        aggregator_name=aggregator,
        corruption_kind=corruption_kind,
    )


def _plan_server_plane(plan: FederatedPlan) -> ServerPlane:
    """The plan's server plane with all knobs as Python constants."""
    knobs = None
    if not plan.cohort.full:
        knobs = (plan.cohort.participation, plan.cohort.straggler_frac, plan.cohort.straggler_keep)
    return _make_server_plane(
        plan.aggregation.name,
        plan.compression,
        knobs,
        plan.aggregation.hypers,
        corruption_kind=plan.corruption.kind,
        corruption_knobs=(plan.corruption.rate, plan.corruption.scale),
    )


_PARITY_PLANE = _make_server_plane()


def _apply_cohort(plane: ServerPlane, ckey, round_batch: PyTree):
    """Mask the round batch's example weights by the drawn cohort."""
    weight = round_batch.get("weight") if hasattr(round_batch, "get") else None
    K = jax.tree.leaves(round_batch)[0].shape[0]
    if weight is None:
        # legacy weight-less layout: nothing to mask. Only the paper's
        # full-participation plane may proceed — silently reporting
        # participants=K for a plan that asked to drop clients would
        # corrupt both training and the CFMQ accounting.
        if plane.cohort is not identity_cohort:
            raise ValueError(
                "cohort dynamics (partial participation / stragglers) mask "
                "the round batch's example weights, but this batch has no "
                "'weight' leaf — pack rounds through the data plane (which "
                "always emits one). Plan-path alternative: a full-"
                "participation plan. The hyper round step always draws a "
                "cohort (its knobs are traced, so participation=1.0 cannot "
                "be detected at trace time) and therefore requires the "
                "weight leaf unconditionally"
            )
        return round_batch, jnp.ones((K,), jnp.float32)
    weight, pmask = plane.cohort(ckey, weight)
    return dict(round_batch, weight=weight), pmask


def _wire_metrics(plane: ServerPlane, params: PyTree, pmask, K: int) -> dict:
    """Wire bytes for this round. Uplink counts only reporting clients
    (compressed deltas); downlink counts every sampled client (the
    server broadcasts the full model before it knows who reports).

    ``participants`` is the exact reporting count (a small integer,
    lossless in f32); the byte totals are f32 conveniences that round
    above ~16 MB/round. Byte-exact accounting multiplies
    ``participants`` by the Python-int per-client counts host-side —
    ``cfmq.plan_wire_accounting`` — which is what train/sweeps feed
    into CFMQ."""
    up = client_wire_bytes(plane.compression, params)
    down = tree_param_bytes(params)
    return {
        "participants": pmask.sum(),
        "uplink_bytes": pmask.sum() * jnp.float32(up),
        "downlink_bytes": jnp.float32(K * down),
    }


def _sim_time_metrics(latency_fn, base_key, round_idx, pmask, K: int) -> dict:
    """The barrier engines' wall-clock/staleness metric trio: a sync
    round's simulated duration is its slowest reporting participant's
    arrival (the barrier waits for everyone who reports), it applies
    exactly one server step, and nothing is ever stale. With no latency
    model the duration is 0.0 — the paper's CFMQ axis prices bytes, not
    seconds, and a disabled model keeps that parity path RNG-free."""
    if latency_fn is None:
        sim_time = jnp.float32(0.0)
    else:
        times = latency_fn(_latency_key(base_key, round_idx), K)
        sim_time = (times * pmask).max()
    return {
        "sim_time_s": sim_time,
        "server_steps": jnp.float32(1.0),
        "staleness_mean": jnp.float32(0.0),
    }


def _client_axis_zeros(params: PyTree, K: int) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros((K,) + jnp.shape(p), jnp.float32), params)


def init_server_state(plan: FederatedPlan, params: PyTree) -> ServerState:
    opt = make_server_optimizer(plan)
    K = plan.clients_per_round
    ef = _client_axis_zeros(params, K) if plan.compression.error_feedback else None
    stale = _client_axis_zeros(params, K) if plan.corruption.kind == "stale" else None
    abuf = None
    if plan.engine == "async":
        from repro.core.async_engine import init_async_buffer

        abuf = init_async_buffer(params, plan.asynchrony.resolve_buffer(K))
    return ServerState(
        params=params,
        opt_state=opt.init(params),
        round_idx=jnp.zeros((), jnp.int32),
        ef=ef,
        stale=stale,
        abuf=abuf,
    )


def _client_update(
    loss_fn: Callable,
    client_opt: Optimizer,
    sigma_fn: Optional[Callable],
    base_key,
    params: PyTree,
    client_batch: PyTree,
    client_idx,
    round_idx,
):
    """Local optimization for one client (vmapped over the K axis).

    client_batch leaves have shape (S_local, b, ...). ``sigma_fn``
    maps round_idx -> FVN noise std (None disables the perturbation
    entirely; a sigma of 0.0 is numerically identical but keeps the
    draw in the graph so one compilation covers FVN on AND off).
    Returns (delta = w^r - w_hat, mean loss, examples seen).
    """
    n_steps = jax.tree.leaves(client_batch)[0].shape[0]

    def local_step(carry, inp):
        p, opt_state = carry
        step_batch, step_idx = inp
        key = fvn_lib.fvn_key(base_key, round_idx, client_idx, step_idx)
        p_eval = p if sigma_fn is None else fvn_lib.perturb(p, key, sigma_fn(round_idx))
        data_key = jax.random.fold_in(key, 1)
        (loss, _aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            p_eval, step_batch, data_key
        )
        updates, opt_state = client_opt.update(grads, opt_state, p)
        p = apply_updates(p, updates)
        w = step_batch.get("weight")
        n = w.sum() if w is not None else jnp.asarray(
            jax.tree.leaves(step_batch)[0].shape[0], jnp.float32
        )
        return (p, opt_state), (loss, n)

    init = (params, client_opt.init(params))
    (p_final, _), (losses, ns) = jax.lax.scan(local_step, init, (client_batch, jnp.arange(n_steps)))
    delta = jax.tree.map(
        lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32), params, p_final
    )
    n_k = ns.sum()
    step_mask = (ns > 0).astype(jnp.float32)
    mean_loss = (losses * step_mask).sum() / jnp.maximum(step_mask.sum(), 1.0)
    return delta, mean_loss, n_k


def _client_key_fanout(plane: ServerPlane, qkey, K: int):
    """The round's client-key fan-out, built ONCE and threaded through
    every consumer (EF, plain compression, the code fast path) — the
    fold_in vmap used to be rebuilt per compress call site."""
    if plane.compression.kind == "none":
        return None
    return jax.vmap(lambda i: jax.random.fold_in(qkey, i))(jnp.arange(K))


def _client_update_stage(
    loss_fn, client_opt, sigma_fn, base_key, params, round_batch, round_idx,
    sharding: Optional[ClientSharding] = None,
):
    """The round's per-client compute — vmap over the K axis wrapping
    the local-steps scan — optionally shard_mapped over ``sharding``'s
    mesh axis. Each shard runs the identical per-client arithmetic on
    its K/num_shards slice (client indices stay global through the
    sharded arange, so the FVN/RNG streams are untouched), which is
    what makes the sharded round bit-for-bit the vmap round on a
    1-device mesh. Returns (deltas, losses, n_k) with a global leading
    K axis either way."""
    K = jax.tree.leaves(round_batch)[0].shape[0]

    def stage(p, batch, cidx, bkey, ridx):
        return jax.vmap(
            lambda cb, ci: _client_update(loss_fn, client_opt, sigma_fn, bkey, p, cb, ci, ridx)
        )(batch, cidx)

    args = (params, round_batch, jnp.arange(K), base_key, round_idx)
    if sharding is None:
        return stage(*args)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    sharding.check_clients(K)
    ax = sharding.axis
    return shard_map(
        stage,
        mesh=sharding.mesh,
        in_specs=(P(), P(ax), P(ax), P(), P()),
        out_specs=(P(ax), P(ax), P(ax)),
        check_rep=False,
    )(*args)


def _sharded_code_fastpath(
    plane: ServerPlane,
    loss_fn,
    client_opt,
    sigma_fn,
    base_key,
    params,
    round_batch,
    round_idx,
    pmask,
    ckeys,
    sharding: ClientSharding,
    ef=None,
):
    """Client compute AND the code-domain aggregate in ONE shard_map:
    local deltas never leave their shard — the scale negotiation is a
    ``lax.pmax`` over 4-byte scalars and the code reduction a literal
    ``lax.psum`` of int32 partial sums (exact, order-independent), so
    ``wbar`` replicates bit-for-bit what the unsharded fast path
    computes. With ``ef`` (EF planes) the per-client residual tree
    rides the same client-axis sharding in and out — its update is
    purely local to each shard's clients, so no extra collectives
    appear. Returns (wbar replicated, losses (K,), n_k (K,), ef')."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    K = jax.tree.leaves(round_batch)[0].shape[0]
    sharding.check_clients(K)
    ax = sharding.axis

    def client_stage(p, batch, cidx, bkey, ridx):
        return jax.vmap(
            lambda cb, ci: _client_update(loss_fn, client_opt, sigma_fn, bkey, p, cb, ci, ridx)
        )(batch, cidx)

    if ef is None:

        def stage(p, batch, cidx, pm, cks, bkey, ridx):
            deltas, losses, n_k = client_stage(p, batch, cidx, bkey, ridx)
            wbar = code_domain_aggregate(plane.compression, deltas, n_k, pm, cks, axis=ax)
            return wbar, losses, n_k

        wbar, losses, n_k = shard_map(
            stage,
            mesh=sharding.mesh,
            in_specs=(P(), P(ax), P(ax), P(ax), P(ax), P(), P()),
            out_specs=(P(), P(ax), P(ax)),
            check_rep=False,
        )(params, round_batch, jnp.arange(K), pmask, ckeys, base_key, round_idx)
        return wbar, losses, n_k, None

    def stage_ef(p, batch, cidx, pm, cks, bkey, ridx, e):
        deltas, losses, n_k = client_stage(p, batch, cidx, bkey, ridx)
        wbar, e2 = code_domain_aggregate_ef(
            plane.compression, deltas, n_k, pm, cks, e, axis=ax
        )
        return wbar, losses, n_k, e2

    return shard_map(
        stage_ef,
        mesh=sharding.mesh,
        in_specs=(P(), P(ax), P(ax), P(ax), P(ax), P(), P(), P(ax)),
        out_specs=(P(), P(ax), P(ax), P(ax)),
        check_rep=False,
    )(params, round_batch, jnp.arange(K), pmask, ckeys, base_key, round_idx, ef)


def _delta_payload_stage(plane: ServerPlane, deltas, ef, pmask, ckeys, xkey, stale):
    """The generic per-client payload pipeline — (EF-)compression then
    the delta-domain adversary — shared by the sync slow path and the
    async engine (which buffers per-client deltas the code-domain fast
    path never materializes, so it always routes here). Returns
    (deltas', ef', cmask, stale')."""
    if plane.compression.error_feedback:
        # EF21: each client compresses delta + residual and keeps
        # the compression error. Non-participants send nothing and
        # keep their residual untouched — the pmask select matters
        # because, unlike the plain path (where a dropped client's
        # delta is exactly 0), C(0 + e_k) is generally nonzero.
        target = jax.tree.map(lambda d, e: d + e, deltas, ef)
        sent = jax.vmap(plane.compress)(target, ckeys)
        sel = lambda a, b: jnp.where(pmask.reshape((-1,) + (1,) * (a.ndim - 1)) > 0, a, b)
        deltas = jax.tree.map(lambda s: sel(s, jnp.zeros_like(s)), sent)
        ef = jax.tree.map(lambda t, s, e: sel(t - s, e), target, sent, ef)
    elif plane.compression.kind != "none":
        # each client quantizes its own delta with its own RNG stream
        deltas = jax.vmap(plane.compress)(deltas, ckeys)

    # Adversary stage: corrupts what the server receives (the
    # post-compression deltas). cmask is already pmask-masked — a
    # corrupted non-participant contributes neither delta nor EF
    # residual update; wire bytes are untouched (corrupted
    # participants pay full uplink).
    deltas, cmask, stale = plane.corrupt(xkey, deltas, pmask, stale)
    return deltas, ef, cmask, stale


def _fedavg_round_body(
    loss_fn,
    client_opt,
    server_opt,
    sigma_fn,
    base_key,
    state: ServerState,
    round_batch: PyTree,
    plane: Optional[ServerPlane] = None,
    latency_fn=None,
    sharding: Optional[ClientSharding] = None,
):
    """One FedAvg round: client deltas -> cohort -> compression ->
    corruption -> aggregator -> server optimizer (one jitted graph)."""
    plane = plane or _PARITY_PLANE
    K = jax.tree.leaves(round_batch)[0].shape[0]
    ckey, qkey, akey, xkey = _plane_keys(base_key, state.round_idx)

    round_batch, pmask = _apply_cohort(plane, ckey, round_batch)
    ckeys = _client_key_fanout(plane, qkey, K)

    ef = state.ef
    if _code_fast_path(plane) and sharding is not None:
        # Sharded code-domain fast path: client compute and the int32
        # code-sum psum live in one shard_map — per-client deltas never
        # leave their shard (see _sharded_code_fastpath). EF residuals
        # ride the same client-axis sharding in and out.
        wbar, losses, n_k, ef2 = _sharded_code_fastpath(
            plane, loss_fn, client_opt, sigma_fn, base_key, state.params,
            round_batch, state.round_idx, pmask, ckeys, sharding,
            ef=ef if plane.compression.error_feedback else None,
        )
        if plane.compression.error_feedback:
            ef = ef2
        cmask = jnp.zeros((K,), jnp.float32)
        stale = state.stale
    elif _code_fast_path(plane):
        # Code-domain fast path: shared-scale negotiation + in-graph
        # int32 code-sum (or payload scatter-add) reduction, ONE server
        # dequant — per-client fp32 deltas are never rematerialized.
        # Statically selected, so every other configuration keeps its
        # existing graph. The corruption stage here is the honest
        # identity (delta adversaries force the slow path), matching
        # its cmask = 0. EF planes route through the _ef twin, whose
        # residual update reads the transmitted codes directly.
        deltas, losses, n_k = _client_update_stage(
            loss_fn, client_opt, sigma_fn, base_key, state.params, round_batch,
            state.round_idx,
        )
        if plane.compression.error_feedback:
            wbar, ef = code_domain_aggregate_ef(
                plane.compression, deltas, n_k, pmask, ckeys, ef
            )
        else:
            wbar = code_domain_aggregate(plane.compression, deltas, n_k, pmask, ckeys)
        cmask = jnp.zeros((K,), jnp.float32)
        stale = state.stale
    else:
        deltas, losses, n_k = _client_update_stage(
            loss_fn, client_opt, sigma_fn, base_key, state.params, round_batch,
            state.round_idx, sharding,
        )
        deltas, ef, cmask, stale = _delta_payload_stage(
            plane, deltas, ef, pmask, ckeys, xkey, state.stale
        )
        wbar = plane.aggregate(deltas, n_k, pmask, akey)

    updates, opt_state = server_opt.update(wbar, state.opt_state, state.params)
    params = apply_updates(state.params, updates)
    n = jnp.maximum(n_k.sum(), 1.0)
    metrics = {
        "loss": (losses * n_k).sum() / n,
        "examples": n_k.sum(),
        "delta_norm": jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(wbar))),
        "corrupted": cmask.sum(),
        **_wire_metrics(plane, state.params, pmask, K),
        **_sim_time_metrics(latency_fn, base_key, state.round_idx, pmask, K),
    }
    return ServerState(params, opt_state, state.round_idx + 1, ef, stale, state.abuf), metrics


def _make_fedavg_round(
    loss_fn: Callable,
    plan: FederatedPlan,
    base_key,
    client_sharding: Optional[ClientSharding] = None,
) -> Callable[[ServerState, PyTree], tuple[ServerState, dict]]:
    """Returns round_step(state, round_batch) -> (state, metrics).

    round_batch leaves: (K, S_local, b, ...); must contain "weight"
    (K, S_local, b) marking real examples (the paper's n_k weighting).
    """
    client_opt = sgd(plan.client_lr)
    server_opt = make_server_optimizer(plan)
    sigma_fn = (lambda r: fvn_lib.fvn_sigma(plan.fvn, r)) if plan.fvn.enabled else None
    plane = _plan_server_plane(plan)
    latency_fn = make_latency_fn(plan.latency) if plan.latency.enabled else None
    if client_sharding is not None:
        client_sharding.check_clients(plan.clients_per_round)

    def round_step(state: ServerState, round_batch: PyTree):
        return _fedavg_round_body(
            loss_fn, client_opt, server_opt, sigma_fn, base_key, state, round_batch, plane,
            latency_fn, client_sharding,
        )

    return round_step


def _make_fedsgd_round(
    loss_fn: Callable,
    plan: FederatedPlan,
    base_key,
) -> Callable[[ServerState, PyTree], tuple[ServerState, dict]]:
    """Large-model engine: one local step at the round-start weights.

    round_batch leaves: (K, 1, b, ...) (same layout as fedavg with
    S_local = 1). Equivalent to fedavg(local_steps=1) up to FVN
    granularity: grads are taken at w^r for every client, so the round
    collapses to one example-weighted forward/backward — weights stay
    FSDP-sharded, no per-client weight replicas exist.
    """
    _check_fedsgd_aggregator(plan.aggregation.name)
    _check_fedsgd_compression(plan.compression)
    _check_fedsgd_corruption(plan.corruption.kind)
    server_opt = make_server_optimizer(plan)
    sigma_fn = (lambda r: fvn_lib.fvn_sigma(plan.fvn, r)) if plan.fvn.enabled else None
    plane = _plan_server_plane(plan)
    latency_fn = make_latency_fn(plan.latency) if plan.latency.enabled else None

    def round_step(state: ServerState, round_batch: PyTree):
        return _fedsgd_round_body(
            loss_fn, server_opt, sigma_fn, plan.client_lr, base_key, state, round_batch, plane,
            latency_fn,
        )

    return round_step


def _check_fedsgd_aggregator(aggregator: str) -> None:
    if aggregator != "weighted_mean":
        raise ValueError(
            "fedsgd collapses clients into one weighted forward/backward — "
            "per-client deltas never exist, so robust aggregators "
            f"({aggregator!r}) need the fedavg engine"
        )


def _check_fedsgd_compression(compression: Optional[CompressionConfig]) -> None:
    if compression is not None and compression.error_feedback:
        raise ValueError(
            "error feedback keeps a per-client compression residual, but "
            "fedsgd collapses clients into one weighted forward/backward — "
            "per-client deltas never exist; use the fedavg engine"
        )


def _check_fedsgd_corruption(kind: str) -> None:
    from repro.core.corruption import DELTA_KINDS

    if kind in DELTA_KINDS:
        raise ValueError(
            "delta corruptions transform per-client deltas, but fedsgd "
            "collapses clients into one weighted forward/backward — use "
            f"the fedavg engine for corruption kind {kind!r} (the "
            "data-plane 'label_shuffle' adversary works on either engine)"
        )


def _fedsgd_round_body(
    loss_fn,
    server_opt,
    sigma_fn,
    client_lr,
    base_key,
    state: ServerState,
    round_batch: PyTree,
    plane: Optional[ServerPlane] = None,
    latency_fn=None,
):
    plane = plane or _PARITY_PLANE
    K, S = jax.tree.leaves(round_batch)[0].shape[:2]
    ckey, qkey, _, _ = _plane_keys(base_key, state.round_idx)
    round_batch, pmask = _apply_cohort(plane, ckey, round_batch)
    flat = jax.tree.map(lambda x: x.reshape((K * S * x.shape[2],) + x.shape[3:]), round_batch)
    key = fvn_lib.fvn_key(base_key, state.round_idx, 0, 0)
    p_eval = (
        state.params
        if sigma_fn is None
        else fvn_lib.perturb(state.params, key, sigma_fn(state.round_idx))
    )
    data_key = jax.random.fold_in(key, 1)
    (loss, _aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(p_eval, flat, data_key)
    # delta of the 1-step client update = client_lr * grad
    wbar = jax.tree.map(lambda g: client_lr * g.astype(jnp.float32), grads)
    if plane.compression.kind != "none":
        # the collapsed engine has no per-client deltas; quantizing the
        # aggregate is the server-side proxy (bytes still counted
        # per reporting client in the wire metrics)
        wbar = plane.compress(wbar, qkey)
    updates, opt_state = server_opt.update(wbar, state.opt_state, state.params)
    params = apply_updates(state.params, updates)
    w = flat.get("weight")
    n = w.sum() if w is not None else jnp.asarray(K * S, jnp.float32)
    metrics = {
        "loss": loss,
        "examples": n,
        "delta_norm": jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(wbar))),
        # delta corruptions are fedavg-only (no per-client deltas here);
        # the data-plane label_shuffle adversary reports host-side
        "corrupted": jnp.float32(0.0),
        **_wire_metrics(plane, state.params, pmask, K),
        **_sim_time_metrics(latency_fn, base_key, state.round_idx, pmask, K),
    }
    return ServerState(
        params, opt_state, state.round_idx + 1, state.ef, state.stale, state.abuf
    ), metrics


def _check_sharding_engine(engine: str, client_sharding) -> None:
    if client_sharding is not None and engine == "fedsgd":
        raise ValueError(
            "fedsgd collapses clients into one weighted forward/backward — "
            "there is no per-client axis to shard; use the fedavg or async "
            "engine with client sharding (fedsgd weights shard over the "
            "model axes instead, see launch.sharding)"
        )


def make_round_step(loss_fn, plan: FederatedPlan, base_key, client_sharding=None):
    _check_sharding_engine(plan.engine, client_sharding)
    if plan.engine == "async":
        from repro.core.async_engine import make_async_round

        return make_async_round(loss_fn, plan, base_key, client_sharding)
    if plan.engine == "fedsgd":
        return _make_fedsgd_round(loss_fn, plan, base_key)
    return _make_fedavg_round(loss_fn, plan, base_key, client_sharding)


# ----------------------------------------------------------------------
# Hyper-parameterized round steps: every scalar knob a sweep varies
# (client/server lr, warmup/decay, FVN std + ramp) enters as a *traced*
# input instead of a Python constant, so ONE compiled round function
# serves every point of a sweep grid that shares batch shapes and the
# structural plan (engine + server optimizer family).
# ----------------------------------------------------------------------

HYPER_KEYS = (
    "client_lr",
    "server_lr",
    "warmup_rounds",
    "decay_rounds",
    "decay_rate",
    "fvn_std",
    "fvn_ramp",
    # server-plane knobs (cohort + aggregator), all traced
    "participation",
    "straggler_frac",
    "straggler_keep",
    "trim_frac",
    "dp_clip",
    "dp_sigma",
    # adversary knobs: rate/magnitude traced, kind static —
    # one compilation per (aggregator, kind) across a grid
    "corrupt_rate",
    "corrupt_scale",
    # async/wall-clock knobs: the staleness-discount exponent and the
    # latency model's scale/jitter are traced (buffer size and the
    # device-tier tables are static structure)
    "async_beta",
    "latency_base_s",
    "latency_spread",
)


def plan_hypers(plan: FederatedPlan) -> dict:
    """The plan's dynamic scalars as f32 arrays (FVN off -> std 0)."""
    return {
        "client_lr": jnp.float32(plan.client_lr),
        "server_lr": jnp.float32(plan.server_lr),
        "warmup_rounds": jnp.float32(plan.server_warmup_rounds),
        "decay_rounds": jnp.float32(plan.server_decay_rounds),
        "decay_rate": jnp.float32(plan.server_decay_rate),
        "fvn_std": jnp.float32(plan.fvn.std if plan.fvn.enabled else 0.0),
        "fvn_ramp": jnp.float32(plan.fvn.ramp_rounds if plan.fvn.enabled else 0),
        "participation": jnp.float32(plan.cohort.participation),
        "straggler_frac": jnp.float32(plan.cohort.straggler_frac),
        "straggler_keep": jnp.float32(plan.cohort.straggler_keep),
        "trim_frac": jnp.float32(plan.aggregation.trim_frac),
        "dp_clip": jnp.float32(plan.aggregation.dp_clip),
        "dp_sigma": jnp.float32(plan.aggregation.dp_sigma),
        "corrupt_rate": jnp.float32(plan.corruption.rate),
        "corrupt_scale": jnp.float32(plan.corruption.scale),
        "async_beta": jnp.float32(plan.asynchrony.staleness_beta),
        "latency_base_s": jnp.float32(plan.latency.base_s),
        "latency_spread": jnp.float32(plan.latency.spread),
    }


def _hyper_server_lr(hypers, count):
    """Unifies constant / linear-rampup / rampup+exp-decay (the three
    schedules of server_lr_schedule) into one traced formula, matching
    plan.server_lr_schedule exactly — including the decay path's
    max(warmup, 1) floor on the warmup window."""
    c = jnp.asarray(count, jnp.float32)
    w = jnp.where(
        hypers["decay_rounds"] > 0,
        jnp.maximum(hypers["warmup_rounds"], 1.0),
        hypers["warmup_rounds"],
    )
    warm = jnp.where(w > 0, jnp.minimum(c / jnp.maximum(w, 1.0), 1.0), 1.0)
    decay = jnp.where(
        hypers["decay_rounds"] > 0,
        hypers["decay_rate"]
        ** (jnp.maximum(c - w, 0.0) / jnp.maximum(hypers["decay_rounds"], 1.0)),
        1.0,
    )
    return hypers["server_lr"] * warm * decay


def _hyper_fvn_sigma(hypers, round_idx):
    c = jnp.asarray(round_idx, jnp.float32)
    frac = jnp.where(
        hypers["fvn_ramp"] > 0, jnp.minimum(c / jnp.maximum(hypers["fvn_ramp"], 1.0), 1.0), 1.0
    )
    return hypers["fvn_std"] * frac


def make_hyper_round_step(
    loss_fn,
    engine: str = "fedavg",
    server_optimizer: str = "adam",
    aggregator: str = "weighted_mean",
    compression: Optional[CompressionConfig] = None,
    corruption: str = "none",
    latency: Optional[LatencyConfig] = None,
    buffer_size: Optional[int] = None,
    client_sharding: Optional[ClientSharding] = None,
):
    """Returns round_step(state, round_batch, hypers, base_key).

    Only ``engine``, ``server_optimizer``, ``aggregator``,
    ``compression``, the ``corruption`` *kind*, the ``latency`` model's
    tier tables and the async ``buffer_size`` are compile-time
    structure (they change the graph / the wire layout); everything in
    ``hypers`` (see HYPER_KEYS / plan_hypers) is traced. The FVN
    perturbation, the cohort draw and the corruption draw always stay
    in the graph with traced knobs (sigma 0.0 / participation 1.0 /
    corrupt_rate 0.0 == off, bit-identical to the plain path), so
    on/off points share the compilation too. Because the cohort draw is
    unconditional, round batches must carry the data plane's "weight"
    leaf — the legacy weight-less layout is plan-path only.

    The latency draw is structural (``latency=None`` or
    ``enabled=False`` keeps it out of sync graphs entirely) because a
    zero-base draw cannot be distinguished from "no model" at trace
    time without burning RNG; its base/spread knobs are traced so one
    compilation serves a latency grid. ``engine="async"`` always draws
    arrivals and requires ``buffer_size`` (a static buffer shape).
    """
    from repro import optim

    server_opt_fns = {
        "adam": optim.adam,
        "sgd": optim.sgd,
        "momentum": optim.momentum,
        "yogi": optim.yogi,
    }
    make_server = server_opt_fns[server_optimizer]
    _check_sharding_engine(engine, client_sharding)
    if engine == "fedsgd":
        _check_fedsgd_aggregator(aggregator)
        _check_fedsgd_compression(compression)
        _check_fedsgd_corruption(corruption)
    if engine == "async":
        if not buffer_size or buffer_size < 1:
            raise ValueError(
                "the async engine's buffer is compile-time structure: pass "
                f"buffer_size >= 1 to make_hyper_round_step (got {buffer_size!r})"
            )
        latency = latency or LatencyConfig()

    def round_step(state: ServerState, round_batch: PyTree, hypers: dict, base_key):
        server_opt = make_server(lambda count: _hyper_server_lr(hypers, count))
        sigma_fn = lambda r: _hyper_fvn_sigma(hypers, r)
        plane = _make_server_plane(
            aggregator,
            compression,
            (hypers["participation"], hypers["straggler_frac"], hypers["straggler_keep"]),
            {
                "trim_frac": hypers["trim_frac"],
                "dp_clip": hypers["dp_clip"],
                "dp_sigma": hypers["dp_sigma"],
            },
            corruption_kind=corruption,
            corruption_knobs=(hypers["corrupt_rate"], hypers["corrupt_scale"]),
        )
        latency_fn = None
        if latency is not None and (latency.enabled or engine == "async"):
            latency_fn = make_latency_fn(
                latency, hypers["latency_base_s"], hypers["latency_spread"]
            )
        if engine == "fedsgd":
            return _fedsgd_round_body(
                loss_fn, server_opt, sigma_fn, hypers["client_lr"], base_key, state,
                round_batch, plane, latency_fn,
            )
        client_opt = sgd(lambda count: hypers["client_lr"])
        if engine == "async":
            from repro.core.async_engine import _async_round_body

            return _async_round_body(
                loss_fn, client_opt, server_opt, sigma_fn, base_key, state, round_batch,
                plane, latency_fn, buffer_size, hypers["async_beta"], client_sharding,
            )
        return _fedavg_round_body(
            loss_fn, client_opt, server_opt, sigma_fn, base_key, state, round_batch, plane,
            latency_fn, client_sharding,
        )

    return round_step


def server_state_specs(
    plan: FederatedPlan,
    param_specs,
    moment_specs=None,
    ef_specs=None,
    stale_specs=None,
):
    """PartitionSpec tree matching init_server_state's output.

    ``moment_specs`` lets the launcher FSDP-shard optimizer moments
    independently of the live params (they only touch aggregation).
    ``ef_specs`` shards the per-client EF residuals; the default keeps
    each residual with its client's replica (leading K axis unsharded,
    trailing axes like the params). ``stale_specs`` does the same for
    the stale-replay delta cache. The async buffer's pending deltas
    reuse the same leading-axis layout (buffer slots unsharded)."""
    from jax.sharding import PartitionSpec as P

    from repro.optim.optimizers import AdamState, MomentumState, ScaleState

    moment_specs = param_specs if moment_specs is None else moment_specs
    opt = plan.server_optimizer
    if opt == "sgd":
        os_ = ScaleState(count=P())
    elif opt == "momentum":
        os_ = MomentumState(count=P(), trace=moment_specs)
    else:  # adam | yogi
        os_ = AdamState(count=P(), mu=moment_specs, nu=moment_specs)

    def client_axis_specs(override):
        if override is not None:
            return override
        return jax.tree.map(
            lambda s: P(*((None,) + tuple(s))), param_specs, is_leaf=lambda x: isinstance(x, P)
        )

    ef = client_axis_specs(ef_specs) if plan.compression.error_feedback else None
    stale = client_axis_specs(stale_specs) if plan.corruption.kind == "stale" else None
    abuf = None
    if plan.engine == "async":
        from repro.core.async_engine import AsyncBuffer

        abuf = AsyncBuffer(
            deltas=client_axis_specs(None),
            weights=P(),
            versions=P(),
            count=P(),
            version=P(),
        )
    return ServerState(
        params=param_specs, opt_state=os_, round_idx=P(), ef=ef, stale=stale, abuf=abuf
    )
