"""FederatedTask — the engine's task-level entry point.

``build_round_engine`` used to take a bare ``loss_fn``, which left
everything above the loss — eval/decode, the task's quality metric,
the batch layout — hardcoded in the drivers (``launch.train`` carried
an RNN-T ``greedy_decode``/WER path no other model could use). A
``FederatedTask`` bundles the full task contract:

- the model (a ``ModelBundle``: init / loss_fn / param_count),
- a jit-traceable ``adapt_batch`` mapping the engine's round-batch
  layout ({features, labels, frame_len, label_len, weight}) onto the
  model's batch contract (LM models read ``labels`` as ``tokens``;
  the enc-dec reads ``features`` as precomputed frames),
- ``evaluate(params, corpus, n)`` -> {"quality", "quality_hard"} in
  the task's own metric (WER for ASR, perplexity for LM/enc-dec,
  classification error for keyword spotting),
- ``client_quality(params, batch)`` -> per-client quality over a
  stacked (C, n, ...) eval batch — the per-client evaluation plane's
  quality hook (``repro.core.clienteval``).

``build_round_engine(plan, task)`` consumes a task directly (the bare
``loss_fn`` form keeps working); the task name joins the engine's
``structural_key`` so two tasks never share a jit cache entry.

Two registries map configs to tasks:

- ``task_for_config(cfg)`` dispatches on the zoo config type (any
  ``repro.configs`` smoke/full config becomes a task), and
- ``get_task(name)`` / ``available_tasks()`` name container-scale
  tasks — one per model family plus the keyword-spotting tiny model
  where a million-virtual-client round is cheap enough for CI.

Every task trains on the same speaker-split corpus: LM tasks read the
label sequences (per-speaker Dirichlet vocab skew = real non-IID text)
and the keyword task reads the first word-piece as the class label
(vocab skew = label shift), so the paper's non-IID ladder moves every
task, not just ASR.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

# Caps exp(loss) so an early-training eval can't overflow to inf and
# poison downstream pareto/fairness arithmetic.
_PPL_CLIP = 20.0


@dataclasses.dataclass(frozen=True)
class FederatedTask:
    """One federated workload: model + batch adapter + eval metric."""

    name: str
    kind: str  # the ModelBundle kind (rnnt | audio | dense | moe | ssm | keyword)
    quality_metric: str  # "wer" | "ppl" | "err" — what "quality" means
    bundle: object  # repro.models.ModelBundle
    evaluate: Callable  # (params, corpus, n) -> {"quality", "quality_hard"}
    adapt_batch: Optional[Callable] = None  # engine batch -> model batch
    client_quality: Optional[Callable] = None  # (params, (C, n, ...) batch) -> (C,)
    make_corpus: Callable = None  # (seed) -> corpus

    @functools.cached_property
    def loss_fn(self) -> Callable:
        """The engine-facing loss: the model's loss behind the batch
        adapter. Cached so every engine built from this task shares one
        function object (and one jit trace cache)."""
        base = self.bundle.loss_fn
        adapt = self.adapt_batch
        if adapt is None:
            return base

        def loss_fn(params, batch, rng=None):
            return base(params, adapt(batch), rng)

        return loss_fn


# ---------------------------------------------------------------- corpus


def default_corpus(seed: int = 0):
    """The shared container-scale speaker corpus (same shape every
    task trains on — and bit-identical to the historical
    ``launch.train.tiny_asr_setup`` corpus)."""
    from repro.data import make_speaker_corpus

    return make_speaker_corpus(
        num_speakers=48, vocab_size=64, feat_dim=16, mean_utterances=24.0, seed=seed
    )


def _eval_batch(ev: dict) -> dict:
    """An ``eval_split`` dict in the engine-batch layout (weight 1)."""
    return {
        "features": jnp.asarray(ev["features"]),
        "labels": jnp.asarray(ev["labels"]),
        "frame_len": jnp.asarray(ev["frame_len"]),
        "label_len": jnp.asarray(ev["label_len"]),
        "weight": jnp.ones((ev["labels"].shape[0],), jnp.float32),
    }


# ------------------------------------------------------- batch adapters


def _lm_adapt(batch: dict) -> dict:
    """LM models read the word-piece label sequence as tokens — the
    per-speaker vocab skew makes this genuinely non-IID text."""
    return {"tokens": batch["labels"], "weight": batch.get("weight")}


def _encdec_adapt(batch: dict) -> dict:
    """Enc-dec (Whisper-style) consumes precomputed frame embeddings;
    the corpus feature dim doubles as d_model at container scale."""
    return {
        "frames": batch["features"],
        "tokens": batch["labels"],
        "weight": batch.get("weight"),
    }


# ------------------------------------------------------ eval functions


@functools.lru_cache(maxsize=None)
def _jitted_rnnt_decode(cfg):
    """One jitted greedy_decode per config; jit's own cache then keys
    on the eval-batch shapes, so repeated sweep-point evals at the
    same (cfg, shape) reuse one compilation."""
    from repro.models.rnnt import greedy_decode

    return jax.jit(functools.partial(greedy_decode, cfg))


def _decode_wer(cfg, params, ev) -> float:
    from repro.asr.wer import wer

    n = ev["labels"].shape[0]
    hyp = _jitted_rnnt_decode(cfg)(
        params, jnp.asarray(ev["features"]), jnp.asarray(ev["frame_len"])
    )
    refs = [ev["labels"][i, : ev["label_len"][i]].tolist() for i in range(n)]
    hyps = [h[h != 0].tolist() for h in np.asarray(hyp)]
    return wer(refs, hyps)


def _wer_evaluate(cfg) -> Callable:
    """ASR eval: greedy RNN-T decode + WER on the clean and hard
    (Other-style) eval splits."""

    def evaluate(params, corpus, n: int = 64) -> dict:
        return {
            "quality": _decode_wer(cfg, params, corpus.eval_split(n)),
            "quality_hard": _decode_wer(cfg, params, corpus.eval_split(n, hard=True)),
        }

    return evaluate


def _ppl_evaluate(loss_fn) -> Callable:
    """LM/enc-dec eval: clipped perplexity of the task loss over the
    eval splits (one jitted loss per task, shape-cached by jit)."""
    jloss = jax.jit(lambda p, b: loss_fn(p, b)[0])

    def one(params, ev) -> float:
        return float(np.exp(min(float(jloss(params, _eval_batch(ev))), _PPL_CLIP)))

    def evaluate(params, corpus, n: int = 64) -> dict:
        return {
            "quality": one(params, corpus.eval_split(n)),
            "quality_hard": one(params, corpus.eval_split(n, hard=True)),
        }

    return evaluate


def _err_evaluate(cfg) -> Callable:
    """Keyword eval: classification error rate of the pooled MLP."""
    from repro.models.keyword import predict

    jpredict = jax.jit(functools.partial(predict, cfg))

    def one(params, ev) -> float:
        pred = np.asarray(
            jpredict(params, jnp.asarray(ev["features"]), jnp.asarray(ev["frame_len"]))
        )
        return float(np.mean(pred != ev["labels"][:, 0]))

    def evaluate(params, corpus, n: int = 64) -> dict:
        return {
            "quality": one(params, corpus.eval_split(n)),
            "quality_hard": one(params, corpus.eval_split(n, hard=True)),
        }

    return evaluate


# -------------------------------------------- per-client quality hooks


def _ppl_client_quality(loss_fn) -> Callable:
    """(C,) clipped perplexity per tracked client, one vmapped jit."""
    jloss = jax.jit(jax.vmap(lambda p, b: loss_fn(p, b)[0], in_axes=(None, 0)))

    def client_quality(params, batch) -> np.ndarray:
        losses = np.asarray(jloss(params, batch), np.float64)
        return np.exp(np.minimum(losses, _PPL_CLIP))

    return client_quality


def _err_client_quality(cfg) -> Callable:
    """(C,) weighted classification error per tracked client."""
    from repro.models.keyword import forward

    def one(params, b):
        logits = forward(cfg, params, b["features"], b["frame_len"])
        hit = (jnp.argmax(logits, axis=-1) == b["labels"][:, 0]).astype(jnp.float32)
        w = b["weight"]
        return 1.0 - (hit * w).sum() / jnp.maximum(w.sum(), 1.0)

    jerr = jax.jit(jax.vmap(one, in_axes=(None, 0)))

    def client_quality(params, batch) -> np.ndarray:
        return np.asarray(jerr(params, batch), np.float64)

    return client_quality


def _wer_client_quality(cfg) -> Callable:
    """(C,) WER per tracked client: one jitted decode over the
    flattened (C * n) batch, host-side per-client edit distance."""
    from repro.asr.wer import wer

    def client_quality(params, batch) -> np.ndarray:
        C, n = np.asarray(batch["weight"]).shape
        feats = jnp.asarray(batch["features"]).reshape((C * n,) + batch["features"].shape[2:])
        flens = jnp.asarray(batch["frame_len"]).reshape(C * n)
        hyp = np.asarray(_jitted_rnnt_decode(cfg)(params, feats, flens)).reshape(C, n, -1)
        labels = np.asarray(batch["labels"])
        label_len = np.asarray(batch["label_len"])
        weight = np.asarray(batch["weight"])
        out = np.zeros((C,), np.float64)
        for c in range(C):
            real = np.flatnonzero(weight[c] > 0)
            refs = [labels[c, i, : label_len[c, i]].tolist() for i in real]
            hyps = [hyp[c, i][hyp[c, i] != 0].tolist() for i in real]
            out[c] = wer(refs, hyps) if refs else 0.0
        return out

    return client_quality


# ------------------------------------------------------------ dispatch

# ModelBundle kind -> (quality metric, batch adapter). None adapter =
# the model consumes the engine layout directly.
_KIND_ADAPTERS = {
    "rnnt": ("wer", None),
    "audio": ("ppl", _encdec_adapt),
    "dense": ("ppl", _lm_adapt),
    "moe": ("ppl", _lm_adapt),
    "ssm": ("ppl", _lm_adapt),
    "hybrid": ("ppl", _lm_adapt),
    "keyword": ("err", None),
}


def task_for_config(cfg, name: Optional[str] = None) -> FederatedTask:
    """THE zoo-config -> task mapping: build the model bundle, pick the
    batch adapter + quality metric by model kind, wire the eval fns.
    Any ``repro.configs`` smoke config becomes a federated task."""
    from repro.models import build_model

    bundle = build_model(cfg)
    if bundle.kind not in _KIND_ADAPTERS:
        raise ValueError(
            f"no federated task adapter for model kind {bundle.kind!r} "
            f"(config {type(cfg).__name__}); the speaker corpus has no "
            f"modality for it — adapters exist for {sorted(_KIND_ADAPTERS)}"
        )
    metric, adapt = _KIND_ADAPTERS[bundle.kind]
    if adapt is None:
        loss_fn = bundle.loss_fn
    else:
        loss_fn = lambda p, b, rng=None: bundle.loss_fn(p, adapt(b), rng)  # noqa: E731
    if metric == "wer":
        evaluate = _wer_evaluate(cfg)
        client_quality = _wer_client_quality(cfg)
    elif metric == "err":
        evaluate = _err_evaluate(cfg)
        client_quality = _err_client_quality(cfg)
    else:
        evaluate = _ppl_evaluate(loss_fn)
        client_quality = _ppl_client_quality(loss_fn)
    return FederatedTask(
        name=name or cfg.name,
        kind=bundle.kind,
        quality_metric=metric,
        bundle=bundle,
        evaluate=evaluate,
        adapt_batch=adapt,
        client_quality=client_quality,
        make_corpus=default_corpus,
    )


def arch_task(arch_id: str) -> FederatedTask:
    """A task from the ``--arch`` registry's smoke config."""
    from repro.configs import get_arch

    return task_for_config(get_arch(arch_id).make_smoke_config(), name=arch_id)


# ----------------------------------------------------- named registry

_TASKS: dict = {}


def register_task(name: str) -> Callable:
    """Decorator: register a task factory ``(seed) -> FederatedTask``."""

    def deco(factory):
        _TASKS[name] = factory
        return factory

    return deco


def available_tasks() -> list:
    return sorted(_TASKS)


def get_task(name: str, seed: int = 0) -> FederatedTask:
    if name not in _TASKS:
        raise KeyError(f"unknown task {name!r}; available: {available_tasks()}")
    return _TASKS[name](seed)


@register_task("asr-rnnt")
def _asr_rnnt_task(seed: int = 0) -> FederatedTask:
    """The paper's task at container scale (tiny_asr_setup's RNN-T)."""
    from repro.asr.specaugment import SpecAugmentConfig
    from repro.models.rnnt import RNNTConfig

    cfg = RNNTConfig(
        name="rnnt-tiny",
        feat_dim=16,
        vocab=64,
        enc_layers=2,
        enc_hidden=96,
        pred_layers=1,
        pred_hidden=96,
        pred_embed=32,
        joint_dim=64,
        time_stride=1,
        specaug=SpecAugmentConfig(
            freq_masks=1, freq_mask_width=3, time_masks=1, time_mask_frac=0.05
        ),
        dtype="float32",
        param_dtype="float32",
    )
    return task_for_config(cfg, name="asr-rnnt")


@register_task("asr-encdec")
def _asr_encdec_task(seed: int = 0) -> FederatedTask:
    """Whisper-style enc-dec over precomputed frame features (d_model
    == the corpus feat_dim, so arena features are the frame embeds)."""
    from repro.models.encdec import EncDecConfig

    cfg = EncDecConfig(
        name="encdec-tiny",
        enc_layers=1,
        dec_layers=1,
        d_model=16,
        n_heads=2,
        n_kv=2,
        head_dim=8,
        d_ff=32,
        vocab=64,
        max_source=24,
        max_target=12,
        dtype="float32",
        loss_chunk=12,
    )
    return task_for_config(cfg, name="asr-encdec")


@register_task("lm-transformer")
def _lm_transformer_task(seed: int = 0) -> FederatedTask:
    from repro.models.transformer import TransformerConfig

    cfg = TransformerConfig(
        name="lm-tiny",
        n_layers=2,
        d_model=32,
        n_heads=2,
        n_kv=2,
        head_dim=16,
        d_ff=64,
        vocab=64,
        dtype="float32",
        loss_chunk=12,
    )
    return task_for_config(cfg, name="lm-transformer")


@register_task("lm-moe")
def _lm_moe_task(seed: int = 0) -> FederatedTask:
    from repro.models.moe import MoEConfig
    from repro.models.transformer import TransformerConfig

    cfg = TransformerConfig(
        name="moe-tiny",
        n_layers=2,
        d_model=32,
        n_heads=2,
        n_kv=2,
        head_dim=16,
        d_ff=64,
        vocab=64,
        moe=MoEConfig(n_experts=4, top_k=2, expert_ff=32, capacity_factor=2.0),
        dtype="float32",
        loss_chunk=12,
    )
    return task_for_config(cfg, name="lm-moe")


@register_task("lm-rwkv")
def _lm_rwkv_task(seed: int = 0) -> FederatedTask:
    from repro.models.model_zoo import RWKVModelConfig
    from repro.models.rwkv import RWKVConfig

    cfg = RWKVModelConfig(
        name="rwkv-tiny",
        n_layers=2,
        rwkv=RWKVConfig(d_model=32, head_size=16, d_ff=64),
        vocab=64,
        dtype="float32",
        loss_chunk=12,
    )
    return task_for_config(cfg, name="lm-rwkv")


@register_task("keyword")
def _keyword_task(seed: int = 0) -> FederatedTask:
    """The million-client CI workload: ~10k params."""
    from repro.models.keyword import KeywordConfig

    return task_for_config(
        KeywordConfig(name="keyword-tiny", feat_dim=16, n_classes=64, hidden=64),
        name="keyword",
    )
