"""The paper's experiment ladder E0-E10 as FederatedPlans.

The paper's absolute settings (K=128 clients, lr=0.008, 4k word-piece
RNN-T on Librispeech) are kept where they are *structural* (optimizer
types, FVN stds, which knob each experiment turns) and made scale
parameters where they are resource-bound (K, batch, rounds) so the
benchmark harness can run the full ladder on the synthetic corpus at
container scale. The *relationships between experiments* — what E2
changes vs E1, E7 vs E5/E6, E9/E10 vs E0 — are exactly the paper's.
"""
from __future__ import annotations

import dataclasses

from repro.core.plan import FederatedPlan, FVNConfig


def ladder(
    clients_per_round: int = 8,
    local_batch_size: int = 4,
    data_limit: int = 8,
    server_lr: float = 0.01,
    client_lr: float = 0.05,
    warmup_rounds: int = 10,
    fvn_std: float = 0.01,
    fvn_ramp_rounds: int = 60,
) -> dict[str, FederatedPlan]:
    """Scaled E0-E10. E0 (the IID Baseline) is *expressed* as a
    federated plan fed IID-shuffled data (the paper's §2.2 observation
    that central mini-batch SGD is the IID limit of FedAvg)."""
    base = FederatedPlan(
        clients_per_round=clients_per_round,
        local_batch_size=local_batch_size,
        local_epochs=1,
        client_lr=client_lr,
        server_optimizer="adam",
        server_lr=server_lr,
        server_warmup_rounds=warmup_rounds,
    )
    fvn = lambda std, ramp=0: FVNConfig(enabled=True, std=std, ramp_rounds=ramp)
    return {
        # E0: central IID baseline (run on IID-shuffled pools)
        "E0": dataclasses.replace(base, fvn=fvn(fvn_std, fvn_ramp_rounds)),
        # E1: non-IID, no data limit, no FVN (Table 1)
        "E1": base,
        # E2-E4: data limiting sweep (Table 2)
        "E2": dataclasses.replace(base, data_limit=data_limit),
        "E3": dataclasses.replace(base, data_limit=data_limit * 2),
        "E4": dataclasses.replace(base, data_limit=data_limit * 4),
        # E5-E7: FVN sweep at the E2 data limit (Table 3)
        "E5": dataclasses.replace(base, data_limit=data_limit, fvn=fvn(fvn_std)),
        "E6": dataclasses.replace(base, data_limit=data_limit, fvn=fvn(2 * fvn_std)),
        "E7": dataclasses.replace(base, data_limit=data_limit,
                                  fvn=fvn(3 * fvn_std, fvn_ramp_rounds)),
        # E8: FVN without data limit (Table 4)
        "E8": dataclasses.replace(base, fvn=fvn(3 * fvn_std, fvn_ramp_rounds)),
        # E9/E10: cost-reduced — shorter ramp-up + exp decay; E10 also
        # increases SpecAugment (applied by the ASR benchmark driver)
        "E9": dataclasses.replace(base, data_limit=data_limit,
                                  fvn=fvn(3 * fvn_std, fvn_ramp_rounds),
                                  server_warmup_rounds=max(2, warmup_rounds // 4),
                                  server_decay_rounds=40, server_decay_rate=0.85),
        "E10": dataclasses.replace(base, data_limit=data_limit,
                                   fvn=fvn(3 * fvn_std, fvn_ramp_rounds),
                                   server_warmup_rounds=max(2, warmup_rounds // 4),
                                   server_decay_rounds=40, server_decay_rate=0.85),
    }
