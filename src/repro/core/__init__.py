"""The paper's contribution: federated training with a quality/cost dial.

- ``fedavg``  — FedAvg round engines (Alg. 1) as pjit-able pure functions,
  composed as client deltas -> cohort -> compression -> aggregation
  -> server optimizer
- ``async_engine`` — buffered-asynchronous (FedBuff-style) round
  engine: staleness-discounted size-B buffer over a simulated
  arrival stream
- ``engine``  — the unified RoundEngine facade
  (``build_round_engine(plan, task)``) over all three engines
- ``task``    — FederatedTask (model + batch adapter + eval metric)
  and the zoo-config -> task registry
- ``clienteval`` — the per-client evaluation plane (fairness spread)
- ``metrics`` — the single round-metrics / summary-row schema
- ``cohort``  — partial participation / dropout / straggler masks
- ``compression`` — uplink delta compression with exact wire bytes
- ``aggregation`` — pluggable server aggregators (weighted/trimmed
  mean, coordinate median, clipped mean + DP noise)
- ``corruption`` — adversarial client corruptions (sign_flip /
  gaussian / zero / stale replay / data-plane label_shuffle)
- ``fvn``     — Federated Variational Noise (§4.2.2)
- ``cfmq``    — Cost of Federated Model Quality (§2.3, Eqs. 1-2)
- ``plan``    — FederatedPlan experiment configuration
- ``experiments`` — the paper's E0-E10 ladder as plans
"""
from repro.core.plan import (
    AggregatorConfig,
    AsyncConfig,
    CohortConfig,
    FederatedPlan,
    FVNConfig,
    make_server_optimizer,
    server_lr_schedule,
)
from repro.core.cohort import LatencyConfig, draw_latencies, make_latency_fn
from repro.core.fedavg import (
    ServerState,
    init_server_state,
    make_hyper_round_step,
    make_round_step,
    plan_hypers,
)
from repro.core.async_engine import AsyncBuffer, init_async_buffer, make_async_round
from repro.core.task import (
    FederatedTask,
    arch_task,
    available_tasks,
    get_task,
    register_task,
    task_for_config,
)
from repro.core.engine import (
    RoundEngine,
    build_round_engine,
    engine_structural_key,
    structural_key_str,
    validate_plan,
)
from repro.core.clienteval import (
    ClientEvalPlane,
    empty_spread,
    fairness_spread,
)
from repro.core.metrics import ROUND_METRIC_KEYS, SUMMARY_KEYS, summary_row
from repro.core.aggregation import available_aggregators, get_aggregator, register_aggregator
from repro.core.compression import CompressionConfig, client_wire_bytes, tree_param_bytes
from repro.core.corruption import (
    CorruptionConfig,
    available_corruptions,
    get_corruption,
    register_corruption,
)
from repro.core.cfmq import (
    CFMQTerms,
    accumulate_wire_bytes,
    cfmq,
    measured_payload,
    mu_local_steps,
    paper_payload,
    paper_peak_memory,
    plan_wire_accounting,
    round_wire_bytes,
    seconds_to_target,
    wire_payload,
)
from repro.core import fvn

__all__ = [
    "AggregatorConfig",
    "AsyncBuffer",
    "AsyncConfig",
    "ClientEvalPlane",
    "CohortConfig",
    "FederatedPlan",
    "FederatedTask",
    "FVNConfig",
    "LatencyConfig",
    "ROUND_METRIC_KEYS",
    "RoundEngine",
    "SUMMARY_KEYS",
    "arch_task",
    "available_tasks",
    "build_round_engine",
    "draw_latencies",
    "empty_spread",
    "engine_structural_key",
    "fairness_spread",
    "get_task",
    "init_async_buffer",
    "make_async_round",
    "make_latency_fn",
    "register_task",
    "structural_key_str",
    "summary_row",
    "task_for_config",
    "validate_plan",
    "make_server_optimizer",
    "server_lr_schedule",
    "ServerState",
    "init_server_state",
    "make_hyper_round_step",
    "make_round_step",
    "plan_hypers",
    "available_aggregators",
    "get_aggregator",
    "register_aggregator",
    "CompressionConfig",
    "client_wire_bytes",
    "tree_param_bytes",
    "CorruptionConfig",
    "available_corruptions",
    "get_corruption",
    "register_corruption",
    "CFMQTerms",
    "accumulate_wire_bytes",
    "cfmq",
    "measured_payload",
    "mu_local_steps",
    "paper_payload",
    "paper_peak_memory",
    "plan_wire_accounting",
    "round_wire_bytes",
    "seconds_to_target",
    "wire_payload",
    "fvn",
]
