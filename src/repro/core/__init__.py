"""The paper's contribution: federated training with a quality/cost dial.

- ``fedavg``  — FedAvg round engines (Alg. 1) as pjit-able pure functions,
  composed as client deltas -> cohort -> compression -> aggregation
  -> server optimizer
- ``cohort``  — partial participation / dropout / straggler masks
- ``compression`` — uplink delta compression with exact wire bytes
- ``aggregation`` — pluggable server aggregators (weighted/trimmed
  mean, coordinate median, clipped mean + DP noise)
- ``corruption`` — adversarial client corruptions (sign_flip /
  gaussian / zero / stale replay / data-plane label_shuffle)
- ``fvn``     — Federated Variational Noise (§4.2.2)
- ``cfmq``    — Cost of Federated Model Quality (§2.3, Eqs. 1-2)
- ``plan``    — FederatedPlan experiment configuration
- ``experiments`` — the paper's E0-E10 ladder as plans
"""
from repro.core.plan import (
    CohortConfig,
    FederatedPlan,
    FVNConfig,
    make_server_optimizer,
    server_lr_schedule,
)
from repro.core.fedavg import (
    ServerPlane,
    ServerState,
    init_server_state,
    make_fedavg_round,
    make_fedsgd_round,
    make_hyper_round_step,
    make_round_step,
    make_server_plane,
    plan_hypers,
    plan_server_plane,
)
from repro.core.aggregation import available_aggregators, get_aggregator, register_aggregator
from repro.core.compression import CompressionConfig, client_wire_bytes, tree_param_bytes
from repro.core.corruption import (
    CorruptionConfig,
    available_corruptions,
    get_corruption,
    register_corruption,
)
from repro.core.cfmq import (
    CFMQTerms,
    accumulate_wire_bytes,
    cfmq,
    measured_payload,
    mu_local_steps,
    paper_payload,
    paper_peak_memory,
    plan_wire_accounting,
    round_wire_bytes,
    wire_payload,
)
from repro.core import fvn

__all__ = [
    "CohortConfig",
    "FederatedPlan",
    "FVNConfig",
    "make_server_optimizer",
    "server_lr_schedule",
    "ServerPlane",
    "ServerState",
    "init_server_state",
    "make_fedavg_round",
    "make_fedsgd_round",
    "make_hyper_round_step",
    "make_round_step",
    "make_server_plane",
    "plan_hypers",
    "plan_server_plane",
    "available_aggregators",
    "get_aggregator",
    "register_aggregator",
    "CompressionConfig",
    "client_wire_bytes",
    "tree_param_bytes",
    "CorruptionConfig",
    "available_corruptions",
    "get_corruption",
    "register_corruption",
    "CFMQTerms",
    "accumulate_wire_bytes",
    "cfmq",
    "measured_payload",
    "mu_local_steps",
    "paper_payload",
    "paper_peak_memory",
    "plan_wire_accounting",
    "round_wire_bytes",
    "wire_payload",
    "fvn",
]
