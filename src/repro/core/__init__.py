"""The paper's contribution: federated training with a quality/cost dial.

- ``fedavg``  — FedAvg round engines (Alg. 1) as pjit-able pure functions
- ``fvn``     — Federated Variational Noise (§4.2.2)
- ``cfmq``    — Cost of Federated Model Quality (§2.3, Eqs. 1-2)
- ``plan``    — FederatedPlan experiment configuration
- ``experiments`` — the paper's E0-E10 ladder as plans
"""
from repro.core.plan import FederatedPlan, FVNConfig, make_server_optimizer, server_lr_schedule
from repro.core.fedavg import (
    ServerState,
    init_server_state,
    make_fedavg_round,
    make_fedsgd_round,
    make_hyper_round_step,
    make_round_step,
    plan_hypers,
)
from repro.core.cfmq import CFMQTerms, cfmq, mu_local_steps, paper_payload, paper_peak_memory
from repro.core import fvn

__all__ = [
    "FederatedPlan",
    "FVNConfig",
    "make_server_optimizer",
    "server_lr_schedule",
    "ServerState",
    "init_server_state",
    "make_fedavg_round",
    "make_fedsgd_round",
    "make_hyper_round_step",
    "make_round_step",
    "plan_hypers",
    "CFMQTerms",
    "cfmq",
    "mu_local_steps",
    "paper_payload",
    "paper_peak_memory",
    "fvn",
]
