"""Server-side aggregation registry — where non-IID damage is won or lost.

The paper aggregates client deltas with the example-weighted mean
(Alg. 1); related work (Hard et al. 2005.10406, Cui et al. 2102.04429)
shows the aggregation rule itself is a lever against non-IID drift and
corrupted/outlier clients. This registry makes the rule pluggable
inside the jitted round step:

- ``weighted_mean``  — Σ_k (n_k/n) Δ_k, the paper's rule and the
  parity default (bit-identical to the legacy engine).
- ``trimmed_mean``   — per coordinate, drop the ``trim_frac`` lowest
  and highest participating clients, mean the rest (Yin et al. 2018).
- ``coordinate_median`` — per-coordinate median over participants.
- ``clipped_mean``   — per-client L2 clip to ``dp_clip`` then uniform
  mean over participants plus N(0, (dp_sigma * dp_clip / m)^2) noise:
  the DP-FedAvg Gaussian mechanism (noise off at dp_sigma=0).

Every aggregator takes (deltas, n_k, pmask, hypers, key): ``deltas``
leaves are (K, ...), ``n_k``/``pmask`` are (K,) with dropped clients
already at 0 (see ``repro.core.cohort``), ``hypers`` carries the
*traced* knobs (trim_frac, dp_clip, dp_sigma) so one compilation
serves a grid. The robust rules are unweighted over participants
(their robustness guarantee is per-client, not per-example) and mask
non-participants by rank: values are sorted with non-participants
pushed to +inf, so participant ranks occupy [0, m) and rank tests
against traced m work for any cohort size.

``weighted_mean`` has a second, semantically-equivalent realization:
when the compression plane quantizes (int8/int4) the round engine
bypasses this registry and computes the weighted mean in the *code
domain* (``repro.core.compression.code_domain_aggregate``: shared
negotiated scale, exact int32 weighted code sum, one server dequant).
The robust rules can never take that path — they need per-client fp32
order statistics — which is exactly the static condition
``fedavg._code_fast_path`` checks.

Hostile inputs: a Byzantine client (see ``repro.core.corruption``) can
ship NaN/Inf coordinates, and ``NaN * 0 == NaN`` means a masked sum is
NOT protection. The robust rules therefore treat non-finite
coordinates exactly like non-participants (excluded per coordinate,
with per-coordinate effective cohort sizes), so no hostile update can
poison the server state. ``weighted_mean`` stays the paper's exact
rule — it is the *measurement* of what a plain mean does under attack,
not a defense.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

PyTree = Any

Aggregator = Callable[..., PyTree]

_AGGREGATORS: Dict[str, Aggregator] = {}

# Traced aggregator knobs and their plan defaults (see plan.FederatedPlan).
AGG_HYPER_DEFAULTS = {"trim_frac": 0.1, "dp_clip": 1.0, "dp_sigma": 0.0}


def register_aggregator(name: str):
    def deco(fn: Aggregator) -> Aggregator:
        _AGGREGATORS[name] = fn
        return fn

    return deco


def get_aggregator(name: str) -> Aggregator:
    try:
        return _AGGREGATORS[name]
    except KeyError:
        raise KeyError(f"unknown aggregator {name!r}; "
                       f"available: {sorted(_AGGREGATORS)}") from None


def available_aggregators() -> list[str]:
    return sorted(_AGGREGATORS)


@register_aggregator("weighted_mean")
def weighted_mean(deltas: PyTree, n_k, pmask, hypers, key) -> PyTree:
    """The paper's Σ_k (n_k/n) Δ_k — the legacy-parity default."""
    n = jnp.maximum(n_k.sum(), 1.0)
    w = (n_k / n).astype(jnp.float32)
    return jax.tree.map(lambda d: jnp.tensordot(w, d, axes=(0, 0)), deltas)


def _contributors(flat, pmask):
    """(K, M) bool: participating AND finite per coordinate — the
    robust rules' effective cohort. Hostile clients ship NaN/Inf
    deltas; excluding them per coordinate (instead of relying on a
    mask-multiply, which NaN survives) keeps the server state finite
    under any attack."""
    return (pmask[:, None] > 0) & jnp.isfinite(flat)


def _contributor_ranks(flat, ok):
    """Ranks of each client's value per coordinate, contributors first.

    flat: (K, M); non-contributors sort to the end (+inf, with any NaN
    after that), so a contributor's rank is its order statistic among
    the per-coordinate m contributors. Ties (equal values, real after
    quantization) get distinct ranks via sort stability, so a tied pair
    at a trim boundary drops exactly one of the two, never both.
    """
    vals = jnp.where(ok, flat, jnp.inf)
    order = jnp.argsort(vals, axis=0)
    return jnp.argsort(order, axis=0).astype(jnp.float32)


def _masked_mean(flat, keep):
    """Mean of flat over the keep mask; where() (not multiply) so a
    dropped NaN/Inf coordinate cannot re-enter as NaN * 0."""
    cnt = jnp.maximum(keep.sum(axis=0), 1.0)
    return jnp.where(keep, flat, 0.0).sum(axis=0) / cnt


@register_aggregator("trimmed_mean")
def trimmed_mean(deltas: PyTree, n_k, pmask, hypers, key) -> PyTree:
    def agg(d):
        flat = d.astype(jnp.float32).reshape(d.shape[0], -1)
        ok = _contributors(flat, pmask)
        m = jnp.maximum(ok.sum(axis=0).astype(jnp.float32), 1.0)   # (M,)
        # trimmed per side, clamped so at least one client always
        # survives (trim_frac >= 0.5 would otherwise zero the update
        # silently)
        t = jnp.clip(jnp.floor(hypers["trim_frac"] * m),
                     0.0, jnp.ceil(m / 2.0) - 1.0)
        ranks = _contributor_ranks(flat, ok)
        keep = (ranks >= t) & (ranks < m - t) & ok
        return _masked_mean(flat, keep).reshape(d.shape[1:])

    return jax.tree.map(agg, deltas)


@register_aggregator("coordinate_median")
def coordinate_median(deltas: PyTree, n_k, pmask, hypers, key) -> PyTree:
    def agg(d):
        flat = d.astype(jnp.float32).reshape(d.shape[0], -1)
        ok = _contributors(flat, pmask)
        m = jnp.maximum(ok.sum(axis=0).astype(jnp.float32), 1.0)   # (M,)
        lo = jnp.floor((m - 1.0) / 2.0)
        hi = jnp.ceil((m - 1.0) / 2.0)
        ranks = _contributor_ranks(flat, ok)
        keep = ((ranks == lo) | (ranks == hi)) & ok
        return _masked_mean(flat, keep).reshape(d.shape[1:])

    return jax.tree.map(agg, deltas)


@register_aggregator("clipped_mean")
def clipped_mean(deltas: PyTree, n_k, pmask, hypers, key) -> PyTree:
    """DP-FedAvg: per-client L2 clip, uniform participant mean, then
    Gaussian noise scaled to the clip-bounded sensitivity clip/m.

    A client with any non-finite coordinate gets weight 0 (a NaN norm
    cannot be clipped into the sensitivity bound, so the only sound
    move is to drop the whole update), and its coordinates are zeroed
    before the weighted sum so ``0 * inf`` cannot produce NaN. A
    zero-norm update is fine as-is: scale clamps to 1 and the update
    contributes nothing."""
    clip = hypers["dp_clip"]
    sigma = hypers["dp_sigma"]
    m = jnp.maximum(pmask.sum(), 1.0)
    sq = sum(jnp.sum(jnp.square(d.astype(jnp.float32)),
                     axis=tuple(range(1, d.ndim)))
             for d in jax.tree.leaves(deltas))              # (K,)
    finite = jnp.isfinite(sq)
    scale = jnp.minimum(1.0, clip / jnp.sqrt(jnp.maximum(sq, 1e-24)))
    w = jnp.where(finite, scale, 0.0) * pmask / m

    leaves, treedef = jax.tree_util.tree_flatten(deltas)
    keys = jax.random.split(key, len(leaves))
    out = [jnp.tensordot(w, jnp.where(jnp.isfinite(d), d, 0.0).astype(jnp.float32),
                         axes=(0, 0))
           + (sigma * clip / m) * jax.random.normal(k, d.shape[1:], jnp.float32)
           for d, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)
