"""CFMQ — Cost of Federated Model Quality (paper §2.3, Eqs. 1-2).

    mu   = e*N / (b*K)                       average local steps/client
    CFMQ = R * K * (P + alpha * mu * nu)     [bytes]

with R rounds, K clients/round, P round-trip payload bytes, nu peak
client memory per step, alpha the balance term. The paper approximates
P = 2 * model_bytes and nu = 1.1 * model_bytes (10% intermediate
storage) with alpha = 1; those are the defaults here but every term is
overridable so the launcher can substitute *measured* values from the
dry-run's memory analysis — and, since the uplink-compression
subsystem (repro.core.compression), measured wire bytes from the round
metrics via ``wire_payload`` (the sweep runner does this whenever a
plan compresses or drops clients; default plans keep the paper
formula as the parity path).

Computation-side invariance: the code-domain aggregation fast path
(``compression.code_domain_aggregate``, selected statically in the
round engine) changes WHERE the dequantization happens (once at the
server instead of once per client), never what travels — per-client
payload buffers keep the exact shapes ``leaf_wire_bytes`` prices, plus
the same one fp32 scale per tensor (negotiated by max-reduce instead
of computed locally: identical four bytes on the wire). Every formula
in this module is therefore fast-path-agnostic by construction, and
tests/test_code_fastpath.py asserts the round metrics' uplink bytes
stay byte-identical when the fast path engages.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class CFMQTerms:
    rounds: int
    clients_per_round: int          # K
    payload_bytes: float            # P (round-trip)
    local_steps: float              # mu
    peak_memory_bytes: float        # nu
    alpha: float = 1.0

    @property
    def per_round_bytes(self) -> float:
        return self.clients_per_round * (
            self.payload_bytes + self.alpha * self.local_steps * self.peak_memory_bytes
        )

    @property
    def total_bytes(self) -> float:
        return self.rounds * self.per_round_bytes

    @property
    def total_terabytes(self) -> float:
        return self.total_bytes / 1e12


def mu_local_steps(local_epochs: float, examples_per_round: float,
                   batch_size: float, clients_per_round: float) -> float:
    """Eq. 1: mu = e*N/(b*K)."""
    return local_epochs * examples_per_round / (batch_size * clients_per_round)


def paper_payload(model_bytes: float) -> float:
    """Paper approximation: round trip = 2x model size (the default /
    parity path — exact for fp32 uplink and full participation)."""
    return 2.0 * model_bytes


def wire_payload(downlink_bytes: float, uplink_bytes: float,
                 clients_per_round: int) -> float:
    """Measured per-client round-trip payload P from wire-accurate
    round totals (the round metrics' ``downlink_bytes`` /
    ``uplink_bytes``, summed or averaged over rounds). With no
    compression and full participation this equals ``paper_payload``:
    down = up = K * model_bytes, so P = 2 * model_bytes.
    """
    return (downlink_bytes + uplink_bytes) / max(clients_per_round, 1)


def plan_wire_accounting(plan, params) -> tuple[int, int]:
    """(uplink bytes per reporting client, downlink bytes per round) as
    exact Python ints over the param-tree shapes."""
    from repro.core.compression import client_wire_bytes, tree_param_bytes

    return (client_wire_bytes(plan.compression, params),
            plan.clients_per_round * tree_param_bytes(params))


def round_wire_bytes(up_per_client: int, down_per_round: int,
                     participants: float) -> int:
    """Exact bytes one round puts on the wire, as a host-side Python
    int. ``participants`` is the round metric's f32 count — a small
    integer, exact in f32 — so the product stays byte-exact, where an
    f32 accumulation of the byte *totals* silently drops bytes once a
    round exceeds ~16 MB (2^24: f32's integer-exact range)."""
    return int(down_per_round) + int(up_per_client) * int(round(float(participants)))


def accumulate_wire_bytes(up_per_client: int, down_per_round: int,
                          participants) -> int:
    """Exact multi-round wire-byte total (Python int) from the per-round
    participant counts — the accounting train/sweep histories persist."""
    return sum(round_wire_bytes(up_per_client, down_per_round, p)
               for p in participants)


def measured_payload(plan, params, mean_participants: float) -> Optional[float]:
    """The single measured-vs-paper payload policy shared by the train
    driver and the sweep runner: ``None`` for the paper/parity default
    (no compression, full participation — callers fall back to
    ``paper_payload``), else the wire-accurate per-client P with uplink
    scaled by the mean number of reporting clients.

    Client corruption (``plan.corruption``) deliberately does NOT enter
    this policy: a corrupted participant still transmits a full payload
    (a sign-flipped or zero delta costs the same bytes), so the
    adversary moves the *quality* axis of the frontier at byte-exact
    identical CFMQ cost — asserted per grid in
    ``sweeps.check_robustness``."""
    if plan.compression.kind == "none" and plan.cohort.full:
        return None
    up_per_client, down_per_round = plan_wire_accounting(plan, params)
    return wire_payload(down_per_round, up_per_client * mean_participants,
                        plan.clients_per_round)


def seconds_to_target(losses, sim_times_s, target: float) -> Optional[float]:
    """The wall-clock axis of the quality/cost frontier: simulated
    seconds until the loss curve first reaches ``target``.

    ``losses`` and ``sim_times_s`` are the per-round histories (the
    round metrics' ``loss`` and ``sim_time_s``); round r's cost is the
    cumulative simulated duration through r. Returns None when the run
    never reaches the target — a point that never converges has no
    finite time-to-quality, which keeps it off the frontier instead of
    silently pricing it at the run length.

    This is CFMQ's second cost axis: bytes (``CFMQTerms``) price the
    fleet's communication/compute budget, seconds price how long the
    deployment waits for a model of the target quality. The async
    engine moves the seconds axis (no barrier on the latency tail) at
    byte-identical CFMQ — asserted per grid in
    ``sweeps.check_async_vs_sync``."""
    total = 0.0
    for loss, t in zip(losses, sim_times_s):
        total += float(t)
        if float(loss) <= target:
            return total
    return None


def paper_peak_memory(model_bytes: float) -> float:
    """Paper approximation: model + 10% intermediate storage."""
    return 1.1 * model_bytes


def cfmq(
    rounds: int,
    clients_per_round: int,
    model_bytes: float,
    local_epochs: float = 1.0,
    examples_per_round: Optional[float] = None,
    batch_size: float = 1.0,
    alpha: float = 1.0,
    payload_bytes: Optional[float] = None,
    peak_memory_bytes: Optional[float] = None,
    local_steps: Optional[float] = None,
) -> CFMQTerms:
    """Build CFMQ terms with the paper's approximations as defaults."""
    if local_steps is None:
        assert examples_per_round is not None
        local_steps = mu_local_steps(local_epochs, examples_per_round,
                                     batch_size, clients_per_round)
    return CFMQTerms(
        rounds=rounds,
        clients_per_round=clients_per_round,
        payload_bytes=paper_payload(model_bytes) if payload_bytes is None else payload_bytes,
        local_steps=local_steps,
        peak_memory_bytes=(paper_peak_memory(model_bytes)
                           if peak_memory_bytes is None else peak_memory_bytes),
        alpha=alpha,
    )
