"""The single round-metrics / summary-row schema.

CHANGES.md records a field-drift incident: the train history said
``wire_bytes`` where the sweep rows said ``wire_bytes_total`` for the
same quantity. This module is the fix — ONE schema, three emitters:

- every round engine's in-graph metrics dict carries exactly
  ``ROUND_METRIC_KEYS`` (asserted per engine in tests);
- the train driver (``launch.train.run_federated_asr``), the sweep
  runner (``launch.sweeps.SweepRunner.run_point``) and the benchmark
  tables (``benchmarks.common.experiment_summary``) all build their
  per-run summaries through ``summary_row``, which rejects a missing
  or unknown field at emit time instead of letting the schemas drift.

Emitter-specific payloads (curves, sweep metadata, legacy aliases)
ride in ``extras`` — deliberately open, because they are labelled by
the emitter, not shared across them.
"""

from __future__ import annotations

from typing import Optional

# Keys every round engine's jitted metrics dict must carry (sync
# engines emit the wall-clock/staleness trio as constants: one server
# step per round, zero staleness, sim_time_s = 0.0 unless the plan's
# latency model is enabled).
ROUND_METRIC_KEYS = (
    "loss",
    "examples",
    "delta_norm",
    "corrupted",
    "participants",
    "uplink_bytes",
    "downlink_bytes",
    "sim_time_s",
    "server_steps",
    "staleness_mean",
)

# Keys of one run summary (a sweep row / train history summary / bench
# table entry). Grouped: quality, per-client fairness spread, CFMQ
# cost, wire accounting, cohort and adversary tallies, wall-clock
# axis, run bookkeeping.
#
# "quality"/"quality_hard" are in the TASK's metric — WER for ASR,
# perplexity for LM tasks, error rate for keyword spotting —
# discriminated by "quality_metric" ("wer" | "ppl" | "err"; lower is
# better for all three). They were named "wer"/"wer_hard" before the
# FederatedTask redesign made the schema model-agnostic.
#
# The client_* sextet is the per-client evaluation plane's fairness
# spread (repro.core.clienteval): p10/p90/gap over a fixed client
# panel at the final round. Runs without per-client eval emit zeros
# with clients_tracked = 0.
SUMMARY_KEYS = (
    "rounds",
    "final_loss",
    "quality",
    "quality_hard",
    "quality_metric",
    "client_loss_p10",
    "client_loss_p90",
    "client_loss_gap",
    "client_quality_p10",
    "client_quality_p90",
    "client_quality_gap",
    "clients_tracked",
    "cfmq_tb",
    "cfmq_bytes",
    "payload_bytes",
    "uplink_bytes_client",
    "uplink_bytes_total",
    "wire_bytes_total",
    "downlink_bytes_round",
    "participants_mean",
    "corrupted_mean",
    "corrupted_total",
    "n_params",
    "sim_time_s",
    "server_steps_total",
    "staleness_mean",
    "wall_s",
)


def summary_row(extras: Optional[dict] = None, **fields) -> dict:
    """Build one summary row, strictly: every ``SUMMARY_KEYS`` field
    must be present and nothing else may ride as a field. Emitter-
    specific keys (curves, ids, legacy aliases) go in ``extras`` and
    may not shadow a schema field."""
    missing = [k for k in SUMMARY_KEYS if k not in fields]
    unknown = [k for k in fields if k not in SUMMARY_KEYS]
    if missing or unknown:
        raise ValueError(
            f"summary_row: missing fields {missing}, unknown fields {unknown} "
            "(schema drift — see repro.core.metrics.SUMMARY_KEYS)")
    extras = dict(extras or {})
    shadowed = [k for k in extras if k in SUMMARY_KEYS]
    if shadowed:
        raise ValueError(
            f"summary_row: extras {shadowed} shadow schema fields — pass "
            "them as fields, not extras")
    row = {k: fields[k] for k in SUMMARY_KEYS}
    row.update(extras)
    return row
