"""Per-client evaluation plane: quality per CLIENT, not just the fleet.

IBM's federated acoustic-modeling study (PAPERS.md, 2102.04429)
reports that fleet-average WER hides a long tail: under speaker-split
non-IID data some clients improve far less than the average suggests.
This plane measures that tail. A ``ClientEvalPlane`` fixes a panel of
clients at construction, packs each one's FIRST ``n`` arena examples
once (``repro.data.per_client_eval_batch`` — the same utterances every
round, so the curves move only because the model moved), and per round
measures

- ``client_loss``  : (C,) the task loss per tracked client, one jitted
  ``vmap`` over the client axis — adds a single device call per round;
- ``client_quality``: (C,) the task's own metric per client (WER for
  ASR, perplexity for LM, error rate for keyword) via the task's
  ``client_quality`` hook.

``fairness_spread`` reduces the final round's panel to the shared
summary-schema fields (p10/p90/gap for loss and quality,
``clients_tracked``); the per-round curves ride in the emitters'
``extras["client_eval"]`` so sweep frontier JSON carries both the
spread columns and the full trajectories.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import per_client_eval_batch

# The summary-schema fields this plane owns (see core.metrics).
SPREAD_KEYS = (
    "client_loss_p10",
    "client_loss_p90",
    "client_loss_gap",
    "client_quality_p10",
    "client_quality_p90",
    "client_quality_gap",
    "clients_tracked",
)


def default_panel(corpus, clients: int) -> np.ndarray:
    """A deterministic panel: client ids evenly spaced over the
    population, so every ladder point tracks the SAME clients and the
    fairness spread is comparable across sweep rows."""
    num = int(getattr(corpus, "num_clients", None) or corpus.num_speakers)
    clients = min(clients, num)
    return np.unique(np.linspace(0, num - 1, clients).astype(np.int64))


def empty_spread() -> dict:
    """The schema fields when per-client eval is off (zeros, tracked
    count 0) — emitters always fill every summary column."""
    out = {k: 0.0 for k in SPREAD_KEYS}
    out["clients_tracked"] = 0
    return out


def fairness_spread(client_loss, client_quality) -> dict:
    """p10/p90/gap over the panel, for loss and for the task metric.
    The gap (p90 - p10) is the fairness number: how much worse the
    hardest-served decile of clients has it than the best-served."""
    loss = np.asarray(client_loss, np.float64)
    qual = np.asarray(client_quality, np.float64)
    lo_l, hi_l = np.percentile(loss, [10.0, 90.0])
    lo_q, hi_q = np.percentile(qual, [10.0, 90.0])
    return {
        "client_loss_p10": float(lo_l),
        "client_loss_p90": float(hi_l),
        "client_loss_gap": float(hi_l - lo_l),
        "client_quality_p10": float(lo_q),
        "client_quality_p90": float(hi_q),
        "client_quality_gap": float(hi_q - lo_q),
        "clients_tracked": int(loss.shape[0]),
    }


class ClientEvalPlane:
    """A fixed client panel measured once per round.

    Usage::

        plane = ClientEvalPlane(task, corpus, clients=6)
        for r in range(rounds):
            state, metrics = engine.step(state, batch)
            plane.measure(state.params)   # appends one round's panel
        row = summary_row(**plane.spread(), ...)
        extras = {"client_eval": plane.curves()}
    """

    def __init__(self, task, corpus, clients: int = 6, n: int = 4, client_ids=None):
        self.task = task
        self.client_ids = (
            np.asarray(client_ids, np.int64)
            if client_ids is not None
            else default_panel(corpus, clients)
        )
        host = per_client_eval_batch(corpus, self.client_ids, n=n)
        self.batch = {k: jnp.asarray(v) for k, v in host.items()}
        self._jloss = jax.jit(
            jax.vmap(lambda p, b: task.loss_fn(p, b)[0], in_axes=(None, 0))
        )
        self.history: list = []

    def measure(self, params) -> dict:
        """One round's panel: per-client loss + per-client quality."""
        rec = {
            "client_loss": np.asarray(self._jloss(params, self.batch), np.float64),
            "client_quality": np.asarray(
                self.task.client_quality(params, self.batch), np.float64
            ),
        }
        self.history.append(rec)
        return rec

    def spread(self) -> dict:
        """The summary-schema fairness fields from the LAST measured
        round (the end-of-run panel); ``empty_spread()`` if none ran."""
        if not self.history:
            return empty_spread()
        last = self.history[-1]
        return fairness_spread(last["client_loss"], last["client_quality"])

    def curves(self) -> dict:
        """The full per-round per-client trajectories, JSON-ready:
        {client_ids: (C,), quality_metric, client_loss: (R, C),
        client_quality: (R, C)}."""
        return {
            "client_ids": self.client_ids.tolist(),
            "quality_metric": self.task.quality_metric,
            "client_loss": [r["client_loss"].tolist() for r in self.history],
            "client_quality": [r["client_quality"].tolist() for r in self.history],
        }
