"""FederatedPlan — the experiment configuration of the paper's Alg. 1.

One plan fully determines a federated optimization: client count and
sampling, the non-IID dial (per-client data limit), client/server
optimizers, FVN, the round engine (sync barrier or buffered-async),
and the CFMQ accounting constants. The experiment ladder E0–E10 is
expressed as plans (see repro/core/experiments.py).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

from repro.core.cohort import LatencyConfig
from repro.core.compression import CompressionConfig
from repro.core.corruption import CorruptionConfig


@dataclasses.dataclass(frozen=True)
class CohortConfig:
    """Cohort dynamics (see repro.core.cohort): the fraction of sampled
    clients that report back and the straggler deadline model. All
    rates are traced in the hyper round step, so a participation grid
    shares one compilation."""

    participation: float = 1.0  # P(sampled client reports back)
    straggler_frac: float = 0.0  # P(reporting client hits the deadline)
    straggler_keep: float = 0.5  # fraction of local steps a straggler completes

    @property
    def full(self) -> bool:
        """True iff the cohort is the paper's all-K-report assumption."""
        return self.participation >= 1.0 and self.straggler_frac <= 0.0


@dataclasses.dataclass(frozen=True)
class FVNConfig:
    """Federated Variational Noise (paper §4.2.2): per-client Gaussian
    weight noise at each local step, std ramped linearly over rounds."""

    enabled: bool = False
    std: float = 0.01  # target std (E5: 0.01, E6: 0.02, E7: ramp to 0.03)
    ramp_rounds: int = 0  # 0 = constant std; >0 = linear 0 -> std


@dataclasses.dataclass(frozen=True)
class AggregatorConfig:
    """Server aggregation stage (see repro.core.aggregation): which
    registered aggregator reduces the client deltas and its knobs. The
    knobs are traced in the hyper round step (one compilation per
    aggregator name across a knob grid)."""

    name: str = "weighted_mean"  # see repro.core.aggregation registry
    trim_frac: float = 0.1  # trimmed_mean: fraction trimmed per side
    dp_clip: float = 1.0  # clipped_mean: per-client L2 clip norm
    dp_sigma: float = 0.0  # clipped_mean: DP noise multiplier

    @property
    def hypers(self) -> dict:
        """The traced-knob dict the aggregation registry consumes."""
        return {
            "trim_frac": self.trim_frac,
            "dp_clip": self.dp_clip,
            "dp_sigma": self.dp_sigma,
        }


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Buffered-async round engine (FedBuff-style, plan.engine="async"):
    the server accumulates arriving client deltas into a size-B buffer
    and steps when it fills, discounting each delta by its staleness
    ``exp(-beta * log1p(s))`` == ``1 / (1 + s)**beta`` with ``s`` the
    number of server versions applied since that client downloaded.

    ``buffer_size`` is compile-time structure (it shapes the buffer);
    ``staleness_beta`` is a traced hyper scalar. ``buffer_size=0``
    resolves to the plan's clients-per-round K (one flush per wave
    under full participation — the sync-parity configuration)."""

    buffer_size: int = 0  # B; 0 resolves to clients_per_round
    staleness_beta: float = 0.5  # staleness discount exponent

    def resolve_buffer(self, clients_per_round: int) -> int:
        return self.buffer_size if self.buffer_size > 0 else clients_per_round


@dataclasses.dataclass(frozen=True)
class FederatedPlan:
    clients_per_round: int = 4  # K (paper sweeps 32 -> 128)
    local_batch_size: int = 2  # b
    local_epochs: int = 1  # e
    local_steps: Optional[int] = None  # fixed step count (engine shape); None = from data
    data_limit: Optional[int] = None  # paper §4.2.1 non-IID dial (None = no limit)
    client_sampling: str = "uniform"  # see repro.data.strategies registry
    client_lr: float = 0.008  # paper's coarse-swept client SGD lr
    server_optimizer: str = "adam"  # "adam" | "sgd" | "momentum" | "yogi"
    server_lr: float = 1e-3
    server_warmup_rounds: int = 0  # linear ramp-up (Baseline style)
    server_decay_rounds: int = 0  # >0: exponential decay (E9/E10 style)
    server_decay_rate: float = 0.9
    fvn: FVNConfig = dataclasses.field(default_factory=FVNConfig)
    engine: str = "fedavg"  # "fedavg" | "fedsgd" (FSDP path) | "async" (FedBuff)
    # Server-side federated plane (cohort -> compression -> aggregation)
    cohort: CohortConfig = dataclasses.field(default_factory=CohortConfig)
    compression: CompressionConfig = dataclasses.field(default_factory=CompressionConfig)
    aggregation: AggregatorConfig = dataclasses.field(default_factory=AggregatorConfig)
    # Adversarial client corruption (see repro.core.corruption): kind is
    # compile-time structure, rate/scale are traced hyper scalars.
    corruption: CorruptionConfig = dataclasses.field(default_factory=CorruptionConfig)
    # Buffered-async engine knobs (engine="async") and the device-tier
    # arrival-latency model that orders the update stream. ``latency``
    # also prices sync rounds: enabled=True reports a barrier round's
    # simulated duration (slowest participant) in the round metrics.
    asynchrony: AsyncConfig = dataclasses.field(default_factory=AsyncConfig)
    latency: LatencyConfig = dataclasses.field(default_factory=LatencyConfig)
    # CFMQ constants (paper §4.3.1): payload/memory approximations
    alpha: float = 1.0
    param_bytes: int = 4  # bytes per parameter on the wire


_LEGACY_AGG_KNOBS = {
    "aggregator": "name",
    "agg_trim_frac": "trim_frac",
    "dp_clip": "dp_clip",
    "dp_sigma": "dp_sigma",
}

_plan_field_init = FederatedPlan.__init__


def _plan_compat_init(self, *args, **kwargs):
    legacy = {
        dest: kwargs.pop(name)
        for name, dest in _LEGACY_AGG_KNOBS.items()
        if name in kwargs
    }
    if legacy:
        warnings.warn(
            "FederatedPlan's loose aggregator knobs (aggregator, agg_trim_frac, "
            "dp_clip, dp_sigma) moved into AggregatorConfig — pass "
            "aggregation=AggregatorConfig(name=..., trim_frac=..., dp_clip=..., "
            "dp_sigma=...) instead. The flat kwargs will be removed in "
            "repro 0.2.",
            DeprecationWarning,
            stacklevel=2,
        )
        base = kwargs.get("aggregation", AggregatorConfig())
        kwargs["aggregation"] = dataclasses.replace(base, **legacy)
    _plan_field_init(self, *args, **kwargs)


# Constructor-compat shim for the pre-AggregatorConfig knob layout:
# FederatedPlan(aggregator=..., agg_trim_frac=..., dp_clip=..., dp_sigma=...)
# still constructs (folded into ``aggregation`` with a DeprecationWarning).
# A wrapped __init__ — not InitVar fields — so dataclasses.replace() round-
# trips plans without ever re-passing the deprecated names.
FederatedPlan.__init__ = _plan_compat_init


def server_lr_schedule(plan: FederatedPlan):
    from repro.optim import constant, linear_rampup, linear_rampup_exp_decay

    if plan.server_decay_rounds > 0:
        return linear_rampup_exp_decay(
            plan.server_lr,
            max(plan.server_warmup_rounds, 1),
            plan.server_decay_rounds,
            plan.server_decay_rate,
        )
    if plan.server_warmup_rounds > 0:
        return linear_rampup(plan.server_lr, plan.server_warmup_rounds)
    return constant(plan.server_lr)


def make_server_optimizer(plan: FederatedPlan):
    from repro import optim

    sched = server_lr_schedule(plan)
    return {
        "adam": lambda: optim.adam(sched),
        "sgd": lambda: optim.sgd(sched),
        "momentum": lambda: optim.momentum(sched),
        "yogi": lambda: optim.yogi(sched),
    }[plan.server_optimizer]()
