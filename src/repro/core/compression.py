"""Uplink delta compression — the wire side of the CFMQ cost axis.

The paper approximates the round-trip payload as ``2 x model_bytes``
(§4.3.1); production cross-device FL compresses the *uplink* (client
-> server) aggressively because client bandwidth dominates. This
module provides in-graph quantize->dequantize compressors for the
per-client deltas so the round step both (a) trains through the real
quantization error and (b) reports the *exact* bytes each client
would put on the wire:

- ``int8`` / ``int4``: per-tensor absmax stochastic quantization.
  Stochastic rounding keeps the dequantized delta unbiased
  (E[Q(x)] = x), which is what lets the example-weighted mean still
  converge; a 4-byte fp32 scale per tensor rides along.
- ``topk``: per-tensor magnitude sparsification; only ``k = ceil(frac
  * size)`` (value, index) pairs travel (4 + 4 bytes each).
- ``none``: identity, fp32 on the wire (the paper/parity path).

Kind and fractions are *static* (compile-time structure — they change
wire layout and graph shape); the RNG key is traced. Byte accounting
is pure Python over leaf shapes (``client_wire_bytes``) so CFMQ and
the round metrics agree to the byte by construction.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any

KINDS = ("none", "int8", "int4", "topk")

# fp32 scalar (scale) / value / index — all 4 bytes on the wire.
_WORD = 4


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Static uplink compression spec (part of the jit cache key)."""
    kind: str = "none"          # none | int8 | int4 | topk
    topk_frac: float = 0.05     # fraction of coordinates kept per tensor
    stochastic: bool = True     # stochastic (unbiased) vs nearest rounding

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown compression kind {self.kind!r}; available: {KINDS}")
        # only validate the knob that is actually in use, so callers can
        # pass an inert topk_frac (e.g. a CLI default) with other kinds
        if self.kind == "topk" and not 0.0 < self.topk_frac <= 1.0:
            raise ValueError(f"topk_frac must be in (0, 1], got {self.topk_frac}")


def _topk_count(frac: float, size: int) -> int:
    return max(1, min(size, int(math.ceil(frac * size))))


def leaf_wire_bytes(cfg: CompressionConfig, size: int) -> int:
    """Exact uplink bytes for one tensor of ``size`` elements."""
    if cfg.kind == "none":
        return _WORD * size
    if cfg.kind == "int8":
        return size + _WORD                      # 1 B/elt + fp32 scale
    if cfg.kind == "int4":
        return (size + 1) // 2 + _WORD           # two elts per byte + scale
    if cfg.kind == "topk":
        return 2 * _WORD * _topk_count(cfg.topk_frac, size)
    raise ValueError(cfg.kind)


def client_wire_bytes(cfg: CompressionConfig, tree: PyTree) -> int:
    """Exact per-client uplink bytes for one delta pytree."""
    return sum(leaf_wire_bytes(cfg, int(l.size)) for l in jax.tree.leaves(tree))


def tree_param_bytes(tree: PyTree) -> int:
    """Downlink bytes: the server broadcasts the full model."""
    return sum(int(l.size) * jnp.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(tree))


# ----------------------------------------------------------------------
# In-graph compressors: delta -> dequantized delta (same shape/dtype).
# ----------------------------------------------------------------------

def _quantize_leaf(x, key, bits: int, stochastic: bool):
    """Per-tensor absmax intN quantize->dequantize (symmetric grid)."""
    levels = 2.0 ** (bits - 1) - 1.0             # 127 (int8) / 7 (int4)
    x32 = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x32)) / levels
    scale = jnp.where(scale > 0, scale, 1.0)
    y = x32 / scale                              # in [-levels, levels]
    if stochastic:
        lo = jnp.floor(y)
        q = lo + jax.random.bernoulli(key, y - lo).astype(jnp.float32)
    else:
        q = jnp.round(y)
    q = jnp.clip(q, -levels, levels)
    return (q * scale).astype(x.dtype)


def _topk_leaf(x, frac: float):
    """Keep the k largest-|x| coordinates, zero the rest (exact k)."""
    flat = x.reshape(-1)
    k = _topk_count(frac, flat.size)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    out = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return out.reshape(x.shape)


def make_compressor(cfg: CompressionConfig):
    """Returns compress(delta_tree, key) -> delta_tree (dequantized).

    One independent RNG key per leaf; the caller supplies a per-client
    key (vmapped over the K axis), so every client quantizes its own
    delta with its own noise — exactly the production wire protocol,
    minus the byte packing (accounted by ``client_wire_bytes``).
    """
    if cfg.kind == "none":
        return lambda tree, key: tree
    if cfg.kind == "topk":
        return lambda tree, key: jax.tree.map(
            lambda x: _topk_leaf(x, cfg.topk_frac), tree)

    bits = {"int8": 8, "int4": 4}[cfg.kind]

    def compress(tree, key):
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        keys = jax.random.split(key, len(leaves))
        out = [_quantize_leaf(x, k, bits, cfg.stochastic)
               for x, k in zip(leaves, keys)]
        return jax.tree_util.tree_unflatten(treedef, out)

    return compress
