"""Uplink delta compression — the wire side of the CFMQ cost axis.

The paper approximates the round-trip payload as ``2 x model_bytes``
(§4.3.1); production cross-device FL compresses the *uplink* (client
-> server) aggressively because client bandwidth dominates. This
module provides the compressors for the per-client deltas so the round
step both (a) trains through the real quantization error and (b)
reports the *exact* bytes each client would put on the wire:

- ``int8`` / ``int4``: per-tensor absmax stochastic quantization.
  Stochastic rounding keeps the dequantized delta unbiased
  (E[Q(x)] = x), which is what lets the example-weighted mean still
  converge; a 4-byte fp32 scale per tensor rides along.
- ``topk``: per-tensor magnitude sparsification; only ``k = ceil(frac
  * size)`` (value, index) pairs travel (4 + 4 bytes each).
- ``none``: identity, fp32 on the wire (the paper/parity path).

The implementation is layered so the byte formulas are backed by real
buffers, not just arithmetic:

1. a *codes* layer (``quantize_codes`` / ``dequantize_codes`` /
   ``topk_select``) that maps tensors to the integer codes and
   (value, index) pairs a client would actually transmit;
2. an in-graph quantize->dequantize path (``make_compressor`` with
   ``packed=False``) that composes the codes layer without ever
   leaving fp32 — the cheap simulation path;
3. a *packed-wire* path (``packed=True``) that materializes the int8
   buffer / int4 nibble-packed buffer / top-k (value, index) payload
   via the ``repro.kernels.wire_pack`` kernels and round-trips it.
   Pack->unpack is bit-exact against path 2 by construction: both
   consume the same codes, so the dequantized deltas are identical
   while the payload's materialized byte size equals
   ``leaf_wire_bytes`` for every kind (property-tested).

``error_feedback`` turns on EF21-style residual accumulation in the
round engine (see ``repro.core.fedavg``): each client compresses
``delta + residual`` and keeps the compression error as next round's
residual, which recovers the quality that plain top-k loses at
aggressive sparsity. It changes no wire bytes — only what travels in
them.

Kind and fractions are *static* (compile-time structure — they change
wire layout and graph shape); the RNG key is traced. Byte accounting
is pure Python over leaf shapes (``client_wire_bytes``) so CFMQ and
the round metrics agree to the byte by construction.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any

KINDS = ("none", "int8", "int4", "topk")

# fp32 scalar (scale) / value / index — all 4 bytes on the wire.
_WORD = 4

_BITS = {"int8": 8, "int4": 4}


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Static uplink compression spec (part of the jit cache key)."""
    kind: str = "none"          # none | int8 | int4 | topk
    topk_frac: float = 0.05     # fraction of coordinates kept per tensor
    stochastic: bool = True     # stochastic (unbiased) vs nearest rounding
    packed: bool = False        # materialize + round-trip the wire payload
    error_feedback: bool = False  # EF21 per-client residual accumulation

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown compression kind {self.kind!r}; available: {KINDS}")
        # only validate the knob that is actually in use, so callers can
        # pass an inert topk_frac (e.g. a CLI default) with other kinds
        if self.kind == "topk" and not 0.0 < self.topk_frac <= 1.0:
            raise ValueError(f"topk_frac must be in (0, 1], got {self.topk_frac}")
        if self.kind == "none" and self.packed:
            raise ValueError(
                "packed=True materializes a quantized wire payload; "
                "kind='none' ships raw fp32 and has nothing to pack")
        if self.kind == "none" and self.error_feedback:
            raise ValueError(
                "error_feedback compensates compression error; with "
                "kind='none' there is no error to feed back")


def _topk_count(frac: float, size: int) -> int:
    return max(1, min(size, int(math.ceil(frac * size))))


def leaf_wire_bytes(cfg: CompressionConfig, size: int) -> int:
    """Exact uplink bytes for one tensor of ``size`` elements."""
    if cfg.kind == "none":
        return _WORD * size
    if cfg.kind == "int8":
        return size + _WORD                      # 1 B/elt + fp32 scale
    if cfg.kind == "int4":
        return (size + 1) // 2 + _WORD           # two elts per byte + scale
    if cfg.kind == "topk":
        return 2 * _WORD * _topk_count(cfg.topk_frac, size)
    raise ValueError(cfg.kind)


def client_wire_bytes(cfg: CompressionConfig, tree: PyTree) -> int:
    """Exact per-client uplink bytes for one delta pytree."""
    return sum(leaf_wire_bytes(cfg, int(l.size)) for l in jax.tree.leaves(tree))


def tree_param_bytes(tree: PyTree) -> int:
    """Downlink bytes: the server broadcasts the full model."""
    return sum(int(l.size) * jnp.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(tree))


# ----------------------------------------------------------------------
# Codes layer: tensors <-> the integers / (value, index) pairs that a
# client actually transmits. Both the in-graph and the packed path are
# built on these, which is what makes them bit-exact to each other.
# ----------------------------------------------------------------------

def quantize_codes(x, key, bits: int, stochastic: bool = True):
    """Per-tensor absmax intN codes: -> (int8 codes shaped like x, fp32
    scale scalar), with codes in [-levels, levels].

    ``y`` is clamped into the grid *before* the Bernoulli draw: f32
    division can land the absmax coordinate one ulp outside the grid
    (|x|/ (|x|/levels) > levels), and a boundary draw would round up to
    levels+1 and get clipped back — biasing E[Q(x)] *below* x exactly
    at the max-magnitude coordinate. Clamped, the boundary is
    deterministic and the documented unbiasedness holds on the whole
    grid.
    """
    levels = 2.0 ** (bits - 1) - 1.0             # 127 (int8) / 7 (int4)
    x32 = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x32)) / levels
    scale = jnp.where(scale > 0, scale, 1.0)
    y = jnp.clip(x32 / scale, -levels, levels)
    if stochastic:
        lo = jnp.floor(y)
        q = lo + jax.random.bernoulli(key, y - lo).astype(jnp.float32)
    else:
        q = jnp.round(y)
    return q.astype(jnp.int8), scale


def dequantize_codes(codes, scale, dtype=jnp.float32):
    """codes * scale; int8 codes are exact in f32, so this reproduces
    the in-graph quantize->dequantize value bit-for-bit."""
    return (codes.astype(jnp.float32) * scale).astype(dtype)


def topk_select(x, frac: float):
    """The top-k wire payload of one tensor: -> (fp32 values (k,),
    int32 flat indices (k,)), k = ceil(frac * size)."""
    flat = x.reshape(-1)
    k = _topk_count(frac, flat.size)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx].astype(jnp.float32), idx.astype(jnp.int32)


def _quantize_leaf(x, key, bits: int, stochastic: bool):
    """Per-tensor absmax intN quantize->dequantize (symmetric grid)."""
    codes, scale = quantize_codes(x, key, bits, stochastic)
    return dequantize_codes(codes, scale, x.dtype)


def _topk_leaf(x, frac: float):
    """Keep the k largest-|x| coordinates, zero the rest (exact k)."""
    vals, idx = topk_select(x, frac)
    out = jnp.zeros((x.size,), x.dtype).at[idx].set(vals.astype(x.dtype))
    return out.reshape(x.shape)


# ----------------------------------------------------------------------
# Packed-wire payloads: the materialized buffers behind the formulas.
# ----------------------------------------------------------------------

def pack_leaf(cfg: CompressionConfig, x, key):
    """Materialize one tensor's uplink payload as a tuple of arrays
    whose total byte size equals ``leaf_wire_bytes`` exactly:

    - int8: (int8 codes (n,), fp32 scale ())          -> n + 4 bytes
    - int4: (int8 nibble bytes ((n+1)//2,), scale ()) -> (n+1)//2 + 4
    - topk: (fp32 values (k,), int32 indices (k,))    -> 8k bytes
    """
    from repro.kernels import wire_pack

    if cfg.kind == "topk":
        return topk_select(x, cfg.topk_frac)
    codes, scale = quantize_codes(x, key, _BITS[cfg.kind], cfg.stochastic)
    flat = codes.reshape(-1)
    if cfg.kind == "int4":
        return wire_pack.nibble_pack(flat), scale
    return flat, scale


def unpack_leaf(cfg: CompressionConfig, payload, shape, dtype=jnp.float32):
    """Reverse of ``pack_leaf``: payload -> dequantized tensor. Equals
    the in-graph quantize->dequantize of the same tensor bit-exactly
    (same codes, same dequant arithmetic)."""
    from repro.kernels import wire_pack

    size = int(math.prod(shape)) if shape else 1
    if cfg.kind == "topk":
        vals, idx = payload
        return wire_pack.topk_unpack(vals, idx, size).reshape(shape).astype(dtype)
    data, scale = payload
    codes = wire_pack.nibble_unpack(data, size) if cfg.kind == "int4" else data
    return wire_pack.dequantize(codes.reshape(-1), scale).reshape(shape).astype(dtype)


def packed_leaf_bytes(payload) -> int:
    """Byte size of a materialized payload (host-side Python int) —
    property-tested equal to ``leaf_wire_bytes`` for every kind."""
    return sum(int(a.size) * jnp.dtype(a.dtype).itemsize for a in payload)


def sum_packed_codes(cfg: CompressionConfig, data, size: int):
    """All-reduce a stack of packed intN payload buffers *in the code
    domain*: (K, nbytes) packed bytes -> (size,) int32 code sums.

    This is the packed-form all-reduce of the uplink: int8/int4 codes
    widen to int32 (K * levels stays far below 2^31), so the server can
    ``psum`` the widened codes across the client mesh axis and
    dequantize once — valid whenever the cohort shares one scale (the
    per-tensor scales are 4-byte scalars, cheap to max-reduce first).
    """
    from repro.kernels import wire_pack

    if cfg.kind not in _BITS:
        raise ValueError(
            f"sum_packed_codes is the intN code-domain reduction; a "
            f"{cfg.kind!r} payload carries fp32 values, not codes")
    if cfg.kind == "int4":
        codes = jax.vmap(lambda b: wire_pack.nibble_unpack(b, size))(data)
    else:
        codes = data
    return codes.astype(jnp.int32).sum(axis=0)


# ----------------------------------------------------------------------
# In-graph compressors: delta -> dequantized delta (same shape/dtype).
# ----------------------------------------------------------------------

def make_compressor(cfg: CompressionConfig):
    """Returns compress(delta_tree, key) -> delta_tree (dequantized).

    One independent RNG key per leaf; the caller supplies a per-client
    key (vmapped over the K axis), so every client quantizes its own
    delta with its own noise — exactly the production wire protocol.
    With ``cfg.packed`` the payload is additionally materialized and
    round-tripped through the wire_pack kernels (bit-identical output,
    but the packed buffer the byte formulas price actually exists in
    the graph and is what a deployment would all-reduce).
    """
    if cfg.kind == "none":
        return lambda tree, key: tree
    if cfg.kind == "topk" and not cfg.packed:
        return lambda tree, key: jax.tree.map(
            lambda x: _topk_leaf(x, cfg.topk_frac), tree)

    if cfg.packed:
        def leaf_fn(x, k):
            return unpack_leaf(cfg, pack_leaf(cfg, x, k), x.shape, x.dtype)
    else:
        bits = _BITS[cfg.kind]

        def leaf_fn(x, k):
            return _quantize_leaf(x, k, bits, cfg.stochastic)

    def compress(tree, key):
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        keys = jax.random.split(key, len(leaves))
        out = [leaf_fn(x, k) for x, k in zip(leaves, keys)]
        return jax.tree_util.tree_unflatten(treedef, out)

    return compress
