"""Uplink delta compression — the wire side of the CFMQ cost axis.

The paper approximates the round-trip payload as ``2 x model_bytes``
(§4.3.1); production cross-device FL compresses the *uplink* (client
-> server) aggressively because client bandwidth dominates. This
module provides the compressors for the per-client deltas so the round
step both (a) trains through the real quantization error and (b)
reports the *exact* bytes each client would put on the wire:

- ``int8`` / ``int4``: per-tensor absmax stochastic quantization.
  Stochastic rounding keeps the dequantized delta unbiased
  (E[Q(x)] = x), which is what lets the example-weighted mean still
  converge; a 4-byte fp32 scale per tensor rides along.
- ``topk``: per-tensor magnitude sparsification; only ``k = ceil(frac
  * size)`` (value, index) pairs travel (4 + 4 bytes each).
- ``none``: identity, fp32 on the wire (the paper/parity path).

The implementation is layered so the byte formulas are backed by real
buffers, not just arithmetic:

1. a *codes* layer (``quantize_codes`` / ``dequantize_codes`` /
   ``topk_select``) that maps tensors to the integer codes and
   (value, index) pairs a client would actually transmit;
2. an in-graph quantize->dequantize path (``make_compressor`` with
   ``packed=False``) that composes the codes layer without ever
   leaving fp32 — the cheap simulation path;
3. a *packed-wire* path (``packed=True``) that materializes the int8
   buffer / int4 nibble-packed buffer / top-k (value, index) payload
   via the ``repro.kernels.wire_pack`` kernels and round-trips it.
   Pack->unpack is bit-exact against path 2 by construction: both
   consume the same codes, so the dequantized deltas are identical
   while the payload's materialized byte size equals
   ``leaf_wire_bytes`` for every kind (property-tested);
4. a *code-domain fast path* (``code_domain_aggregate``; the round
   engine selects it statically for quantizing planes under the
   paper's weighted mean) that never rematerializes per-client fp32
   deltas: scales are negotiated cohort-wide by a max-reduce over the
   client axis (so the integer code sums are exact), each client runs
   the fused ``wire_pack.quantize_pack`` kernel, ``sum_packed_codes``
   reduces in int32, and the server dequantizes ONCE. Same wire bytes,
   same payload buffers — only the compute drops.

``error_feedback`` turns on EF21-style residual accumulation in the
round engine (see ``repro.core.fedavg``): each client compresses
``delta + residual`` and keeps the compression error as next round's
residual, which recovers the quality that plain top-k loses at
aggressive sparsity. It changes no wire bytes — only what travels in
them.

Kind and fractions are *static* (compile-time structure — they change
wire layout and graph shape); the RNG key is traced. Byte accounting
is pure Python over leaf shapes (``client_wire_bytes``) so CFMQ and
the round metrics agree to the byte by construction.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any

KINDS = ("none", "int8", "int4", "topk")

# fp32 scalar (scale) / value / index — all 4 bytes on the wire.
_WORD = 4

_BITS = {"int8": 8, "int4": 4}


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Static uplink compression spec (part of the jit cache key)."""

    kind: str = "none"  # none | int8 | int4 | topk
    topk_frac: float = 0.05  # fraction of coordinates kept per tensor
    stochastic: bool = True  # stochastic (unbiased) vs nearest rounding
    packed: bool = False  # materialize + round-trip the wire payload
    error_feedback: bool = False  # EF21 per-client residual accumulation

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown compression kind {self.kind!r}; available: {KINDS}")
        # only validate the knob that is actually in use, so callers can
        # pass an inert topk_frac (e.g. a CLI default) with other kinds
        if self.kind == "topk" and not 0.0 < self.topk_frac <= 1.0:
            raise ValueError(f"topk_frac must be in (0, 1], got {self.topk_frac}")
        if self.kind == "none" and self.packed:
            raise ValueError(
                "packed=True materializes a quantized wire payload; "
                "kind='none' ships raw fp32 and has nothing to pack"
            )
        if self.kind == "none" and self.error_feedback:
            raise ValueError(
                "error_feedback compensates compression error; with "
                "kind='none' there is no error to feed back"
            )


def _topk_count(frac: float, size: int) -> int:
    return max(1, min(size, int(math.ceil(frac * size))))


def leaf_wire_bytes(cfg: CompressionConfig, size: int) -> int:
    """Exact uplink bytes for one tensor of ``size`` elements."""
    if cfg.kind == "none":
        return _WORD * size
    if cfg.kind == "int8":
        return size + _WORD  # 1 B/elt + fp32 scale
    if cfg.kind == "int4":
        return (size + 1) // 2 + _WORD  # two elts per byte + scale
    if cfg.kind == "topk":
        return 2 * _WORD * _topk_count(cfg.topk_frac, size)
    raise ValueError(cfg.kind)


def client_wire_bytes(cfg: CompressionConfig, tree: PyTree) -> int:
    """Exact per-client uplink bytes for one delta pytree."""
    return sum(leaf_wire_bytes(cfg, int(l.size)) for l in jax.tree.leaves(tree))


def tree_param_bytes(tree: PyTree) -> int:
    """Downlink bytes: the server broadcasts the full model."""
    return sum(int(l.size) * jnp.dtype(l.dtype).itemsize for l in jax.tree.leaves(tree))


def wire_cost_profile(cfg: CompressionConfig, tree: PyTree) -> dict:
    """Static wire-cost profile of one client delta under ``cfg`` — the
    profiling plane's per-scheme feature block (``repro.profile.predict``
    attaches it to point predictions): exact uplink bytes, the fp32
    dense baseline, and the realized compression ratio. Pure arithmetic
    over leaf sizes, so abstract (``eval_shape``) trees price
    identically to materialized ones."""
    up = client_wire_bytes(cfg, tree)
    dense = _WORD * sum(int(l.size) for l in jax.tree.leaves(tree))
    return {
        "kind": cfg.kind,
        "uplink_bytes": up,
        "dense_bytes": dense,
        "ratio": dense / up if up else float("inf"),
    }


# ----------------------------------------------------------------------
# Codes layer: tensors <-> the integers / (value, index) pairs that a
# client actually transmits. Both the in-graph and the packed path are
# built on these, which is what makes them bit-exact to each other.
# ----------------------------------------------------------------------


def leaf_scale(x, bits: int):
    """Per-tensor absmax scale: max|x| / levels, guarded against the
    all-zero tensor (scale 1.0 keeps the codes at exactly 0)."""
    levels = 2.0 ** (bits - 1) - 1.0  # 127 (int8) / 7 (int4)
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / levels
    return jnp.where(scale > 0, scale, 1.0)


def _rounding_field(key, shape, stochastic: bool):
    """The stochastic-rounding uniforms (None = nearest). ``u < frac``
    is jax.random.bernoulli's own draw. Since PR 10 the production
    kernels generate this field *in-kernel* (threefry hashed from the
    key words + each element's flat position — never materialized in
    HBM); this streamed form remains the oracle the bit-parity tests
    check the in-kernel draw against."""
    return jax.random.uniform(key, shape) if stochastic else None


def _key_words(key):
    """The raw (2,) uint32 threefry words of ``key`` (typed or raw
    PRNG key) — what the in-kernel PRNG hashes."""
    key = jnp.asarray(key)
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        return jax.random.key_data(key)
    return key


def quantize_codes_with_scale(x, key, scale, bits: int, stochastic: bool = True):
    """intN codes of ``x`` against a *given* scale — the cohort-shared
    entry point of the code-domain fast path (every client quantizing
    on one negotiated grid is what makes code sums exact).

    ``y`` is clamped into the grid *before* the rounding draw: f32
    division can land the absmax coordinate one ulp outside the grid
    (|x| / (|x|/levels) > levels), and a boundary draw would round up
    to levels+1 and get clipped back — biasing E[Q(x)] *below* x
    exactly at the max-magnitude coordinate. Clamped, the boundary is
    deterministic and the documented unbiasedness holds on the whole
    grid.
    """
    from repro.kernels import wire_pack

    xf = x.astype(jnp.float32)
    if stochastic:
        return wire_pack.quantize_with_scale_keyed(xf, scale, _key_words(key), bits)
    return wire_pack.quantize_with_scale(xf, scale, None, bits)


def quantize_codes(x, key, bits: int, stochastic: bool = True):
    """Per-tensor absmax intN codes: -> (int8 codes shaped like x, fp32
    scale scalar), with codes in [-levels, levels]."""
    scale = leaf_scale(x, bits)
    return quantize_codes_with_scale(x, key, scale, bits, stochastic), scale


def dequantize_codes(codes, scale, dtype=jnp.float32):
    """codes * scale; int8 codes are exact in f32, so this reproduces
    the in-graph quantize->dequantize value bit-for-bit."""
    return (codes.astype(jnp.float32) * scale).astype(dtype)


def topk_select(x, frac: float):
    """The top-k wire payload of one tensor: -> (fp32 values (k,),
    int32 flat indices (k,)), k = ceil(frac * size)."""
    flat = x.reshape(-1)
    k = _topk_count(frac, flat.size)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx].astype(jnp.float32), idx.astype(jnp.int32)


def _quantize_leaf(x, key, bits: int, stochastic: bool):
    """Per-tensor absmax intN quantize->dequantize (symmetric grid)."""
    codes, scale = quantize_codes(x, key, bits, stochastic)
    return dequantize_codes(codes, scale, x.dtype)


def _topk_leaf(x, frac: float):
    """Keep the k largest-|x| coordinates, zero the rest (exact k)."""
    vals, idx = topk_select(x, frac)
    out = jnp.zeros((x.size,), x.dtype).at[idx].set(vals.astype(x.dtype))
    return out.reshape(x.shape)


# ----------------------------------------------------------------------
# Packed-wire payloads: the materialized buffers behind the formulas.
# ----------------------------------------------------------------------


def pack_leaf(cfg: CompressionConfig, x, key):
    """Materialize one tensor's uplink payload as a tuple of arrays
    whose total byte size equals ``leaf_wire_bytes`` exactly:

    - int8: (int8 codes (n,), fp32 scale ())          -> n + 4 bytes
    - int4: (int8 nibble bytes ((n+1)//2,), scale ()) -> (n+1)//2 + 4
    - topk: (fp32 values (k,), int32 indices (k,))    -> 8k bytes
    """
    from repro.kernels import wire_pack

    if cfg.kind == "topk":
        return topk_select(x, cfg.topk_frac)
    bits = _BITS[cfg.kind]
    scale = leaf_scale(x, bits)
    flat = x.astype(jnp.float32).reshape(-1)
    # stochastic rounding draws in-kernel (threefry of the key words +
    # flat position — bit-identical to the historical streamed
    # jax.random.uniform field, which never touches HBM anymore)
    if cfg.stochastic:
        payload = wire_pack.quantize_pack_keyed(flat, scale, _key_words(key), bits)
    else:
        payload = wire_pack.quantize_pack(flat, scale, None, bits)
    return payload, scale


def unpack_leaf(cfg: CompressionConfig, payload, shape, dtype=jnp.float32):
    """Reverse of ``pack_leaf``: payload -> dequantized tensor. Equals
    the in-graph quantize->dequantize of the same tensor bit-exactly
    (same codes, same dequant arithmetic)."""
    from repro.kernels import wire_pack

    size = int(math.prod(shape)) if shape else 1
    if cfg.kind == "topk":
        vals, idx = payload
        return wire_pack.topk_unpack(vals, idx, size).reshape(shape).astype(dtype)
    data, scale = payload
    codes = wire_pack.nibble_unpack(data, size) if cfg.kind == "int4" else data
    return wire_pack.dequantize(codes.reshape(-1), scale).reshape(shape).astype(dtype)


def packed_leaf_bytes(payload) -> int:
    """Byte size of a materialized payload (host-side Python int) —
    property-tested equal to ``leaf_wire_bytes`` for every kind."""
    return sum(int(a.size) * jnp.dtype(a.dtype).itemsize for a in payload)


def sum_packed_codes(cfg: CompressionConfig, data, size: int, weights=None, axis=None):
    """All-reduce a stack of intN payload buffers *in the code domain*:
    (K, nbytes) payload -> (size,) int32 code sums. ``data`` is the
    wire buffer of ``cfg`` — nibble-packed bytes for a packed int4
    plane, raw int8 codes otherwise.

    This is the packed-form all-reduce of the uplink: int8/int4 codes
    widen to int32, so the server can ``psum`` the widened codes across
    the client mesh axis and dequantize ONCE — valid whenever the
    cohort shares one scale (the per-tensor scales are 4-byte scalars,
    cheap to max-reduce first; see ``shared_leaf_scale``). With
    ``weights`` (int32 per-client example counts n_k — integral by
    data-plane construction, the weight leaves are 0/1 masks) the
    reduction is the example-weighted code sum the paper's aggregator
    needs, still in exact integer arithmetic.

    int32 overflow bound (property-tested in tests/test_code_fastpath.py):
    |sum| <= levels * sum(w_k) (or levels * K unweighted), so int8
    accumulation is exact up to sum(n_k) < 2**31 / 127 = 16,909,320
    examples (clients) per round, int4 up to 2**31 / 7 ~= 306M — far
    above any real cohort; past that, widen to int64 before the psum.

    With ``axis`` (a named mesh axis inside ``shard_map``) the local
    per-shard code sum is followed by a literal ``jax.lax.psum`` over
    that axis — int32 addition is associative and commutative, so the
    sharded total is bit-identical to the single-device reduction and
    the overflow bound above applies to the *global* cohort unchanged.
    """
    from repro.kernels import wire_pack

    if cfg.kind not in _BITS:
        raise ValueError(
            f"sum_packed_codes is the intN code-domain reduction; a "
            f"{cfg.kind!r} payload carries fp32 values, not codes"
        )
    if cfg.kind == "int4" and cfg.packed:
        codes = jax.vmap(lambda b: wire_pack.nibble_unpack(b, size))(data)
    else:
        codes = data
    wide = codes.astype(jnp.int32)
    if weights is None:
        total = wide.sum(axis=0)
    else:
        total = jnp.tensordot(weights.astype(jnp.int32), wide, axes=(0, 0))
    if axis is not None:
        total = jax.lax.psum(total, axis)
    return total


# ----------------------------------------------------------------------
# Code-domain fast path: shared-scale negotiation + in-graph code-sum
# aggregation. Clients never rematerialize fp32 deltas — the round
# engine calls this INSTEAD of compress-then-aggregate whenever the
# plane quantizes under the paper's weighted mean (selected statically
# in repro.core.fedavg, so the fp32 parity graph is untouched).
# ----------------------------------------------------------------------


def shared_leaf_scale(d, pmask, bits: int, axis=None):
    """Negotiate one scale for a (K, ...) client-stacked leaf: each
    client's absmax (masked by participation — dropped clients transmit
    nothing, so they must not coarsen the grid), max-reduced over the
    client axis. With ``axis`` (a named mesh axis inside ``shard_map``,
    where ``d``/``pmask`` hold only this shard's clients) the local max
    is followed by ``jax.lax.pmax`` over that axis — an all-reduce over
    a 4-byte scalar, the cheap half of the negotiation that makes the
    code sums exact. max is associative/commutative and exact in f32,
    so the sharded scale is bit-identical to the single-device one."""
    levels = 2.0 ** (bits - 1) - 1.0
    am = jnp.max(jnp.abs(d.astype(jnp.float32).reshape(d.shape[0], -1)), axis=1)
    m = jnp.max(am * (pmask > 0))
    if axis is not None:
        m = jax.lax.pmax(m, axis)
    scale = m / levels
    return jnp.where(scale > 0, scale, 1.0)


def fastpath_leaf_keys(ckeys, leaf_idx: int):
    """Per-client rounding keys for one leaf: the round's cached client
    key fan-out (one fold_in per client per round, hoisted in the round
    engine) folded with the leaf index."""
    return jax.vmap(lambda ck: jax.random.fold_in(ck, leaf_idx))(ckeys)


def code_domain_aggregate(
    cfg: CompressionConfig, deltas: PyTree, n_k, pmask, ckeys, axis=None
) -> PyTree:
    """Example-weighted mean of K quantized client deltas without ever
    rematerializing fp32 per-client tensors:

        per leaf:  absmax_k --max-reduce--> shared scale s
                   fused quantize(+pack) per client  -> intN payload
                   sum_packed_codes (int32, weighted by n_k)  -> csum
                   wbar = csum * (s / n)          [ONE dequant, server]

    vs the slow path's K dequantized fp32 trees reduced by an fp32
    tensordot. With the shared scale the integer code sum is *exact*,
    so this equals dequantize-then-weighted-mean up to one final f32
    rounding (bit-exact for equal weights on power-of-two scales;
    property-tested in tests/test_code_fastpath.py). Wire accounting is
    untouched: the payload per client is byte-identical to
    ``pack_leaf`` (codes against a shared scale instead of its own —
    same buffer shapes, same ``leaf_wire_bytes``).

    ``topk`` planes aggregate in the payload domain instead: each
    client's (value, index) pairs — exactly the wire payload — go
    through one weighted segment-bucketed scatter-add
    (``wire_pack.topk_scatter_add``) into the dense mean, so the slow
    path's K rematerialized dense fp32 trees (and their K-deep
    tensordot) never exist. Dropped clients carry weight n_k = 0, so
    their payloads cancel exactly as in the slow path.

    With ``axis`` (called inside ``shard_map`` where ``deltas``/``n_k``/
    ``pmask``/``ckeys`` hold only this shard's slice of the cohort) the
    scale negotiation pmax-es, the code sum psum-s, and ``n`` psum-s
    over that axis — each reduction is exact (f32 max; int32 add; f32
    add of integer-valued example counts, exact below 2**24), so the
    sharded aggregate is bit-identical to the single-device one and
    every shard returns the same replicated ``wbar``. The topk dense
    sums psum in f32 (bit-identical on a 1-device mesh, tolerance-level
    elsewhere — same contract as the fp32 slow path's reduction order).
    """
    from repro.kernels import wire_pack

    leaves, treedef = jax.tree_util.tree_flatten(deltas)
    n_total = n_k.sum()
    if axis is not None:
        n_total = jax.lax.psum(n_total, axis)
    n = jnp.maximum(n_total, 1.0)
    out = []
    if cfg.kind == "topk":
        for d in leaves:
            K = d.shape[0]
            flat = d.astype(jnp.float32).reshape(K, -1)
            size = flat.shape[1]
            vals, idx = jax.vmap(lambda x: topk_select(x, cfg.topk_frac))(flat)
            dsum = wire_pack.topk_scatter_add(vals, idx, n_k.astype(jnp.float32), size)
            if axis is not None:
                dsum = jax.lax.psum(dsum, axis)
            out.append((dsum / n).reshape(d.shape[1:]))
        return jax.tree_util.tree_unflatten(treedef, out)
    bits = _BITS[cfg.kind]
    w_int = jnp.round(n_k).astype(jnp.int32)
    for li, d in enumerate(leaves):
        K = d.shape[0]
        flat = d.astype(jnp.float32).reshape(K, -1)
        size = flat.shape[1]
        scale = shared_leaf_scale(d, pmask, bits, axis=axis)
        lkeys = fastpath_leaf_keys(ckeys, li)

        def client(x, k, scale=scale):
            if cfg.stochastic:
                kw = _key_words(k)
                if cfg.packed:
                    return wire_pack.quantize_pack_keyed(x, scale, kw, bits)
                return wire_pack.quantize_with_scale_keyed(x, scale, kw, bits)
            if cfg.packed:
                return wire_pack.quantize_pack(x, scale, None, bits)
            return wire_pack.quantize_with_scale(x, scale, None, bits)

        payload = jax.vmap(client)(flat, lkeys)
        csum = sum_packed_codes(cfg, payload, size, weights=w_int, axis=axis)
        out.append((csum.astype(jnp.float32) * (scale / n)).reshape(d.shape[1:]))
    return jax.tree_util.tree_unflatten(treedef, out)


def code_domain_aggregate_ef(
    cfg: CompressionConfig, deltas: PyTree, n_k, pmask, ckeys, ef: PyTree, axis=None
) -> tuple[PyTree, PyTree]:
    """Error-feedback twin of ``code_domain_aggregate``: compresses each
    client's ``delta + residual``, aggregates in the code/payload
    domain, and returns ``(wbar, new_ef)`` with the EF21 residual
    update computed from the *transmitted codes' dequant* — never from
    a separately compressed fp32 tree, so what feeds the residual is
    bit-identical to what went on the wire.

    - intN: new_ef = target - codes * shared_scale for participants
      (codes from the same fused keyed kernel whose int32 sum builds
      wbar); dropped clients keep their old residual untouched.
    - topk: the transmitted coordinates are sent *exactly*, so the
      residual is just the target with its selected coordinates zeroed
      (one in-place scatter per client — no dense subtraction).

    Aggregation and scale negotiation shard over ``axis`` exactly as in
    ``code_domain_aggregate``; the residual update is purely local to
    each shard's clients (ef is sharded along the client axis), so no
    extra collectives appear.
    """
    from repro.kernels import wire_pack

    leaves, treedef = jax.tree_util.tree_flatten(deltas)
    ef_leaves = jax.tree_util.tree_flatten(ef)[0]
    n_total = n_k.sum()
    if axis is not None:
        n_total = jax.lax.psum(n_total, axis)
    n = jnp.maximum(n_total, 1.0)
    out, ef_out = [], []
    if cfg.kind == "topk":
        for d, e in zip(leaves, ef_leaves):
            K = d.shape[0]
            target = d.astype(jnp.float32) + e.astype(jnp.float32)
            flat = target.reshape(K, -1)
            size = flat.shape[1]
            vals, idx = jax.vmap(lambda x: topk_select(x, cfg.topk_frac))(flat)
            dsum = wire_pack.topk_scatter_add(vals, idx, n_k.astype(jnp.float32), size)
            if axis is not None:
                dsum = jax.lax.psum(dsum, axis)
            out.append((dsum / n).reshape(d.shape[1:]))
            resid = jax.vmap(lambda t, i: t.at[i].set(0.0))(flat, idx).reshape(d.shape)
            sel = pmask.reshape((K,) + (1,) * (d.ndim - 1)) > 0
            ef_out.append(jnp.where(sel, resid, e).astype(e.dtype))
        return (jax.tree_util.tree_unflatten(treedef, out),
                jax.tree_util.tree_unflatten(treedef, ef_out))
    bits = _BITS[cfg.kind]
    w_int = jnp.round(n_k).astype(jnp.int32)
    for li, (d, e) in enumerate(zip(leaves, ef_leaves)):
        K = d.shape[0]
        target = d.astype(jnp.float32) + e.astype(jnp.float32)
        flat = target.reshape(K, -1)
        size = flat.shape[1]
        scale = shared_leaf_scale(target, pmask, bits, axis=axis)
        lkeys = fastpath_leaf_keys(ckeys, li)

        def client(x, k, scale=scale):
            if cfg.stochastic:
                return wire_pack.quantize_with_scale_keyed(x, scale, _key_words(k), bits)
            return wire_pack.quantize_with_scale(x, scale, None, bits)

        codes = jax.vmap(client)(flat, lkeys)
        if cfg.packed and bits == 4:
            # materialize the nibble-packed wire buffer (byte accounting's
            # payload) and reduce through it — pack->unpack is the
            # identity on codes, so csum is unchanged
            payload = jax.vmap(wire_pack.nibble_pack)(codes)
            csum = sum_packed_codes(cfg, payload, size, weights=w_int, axis=axis)
        else:
            csum = sum_packed_codes(cfg, codes, size, weights=w_int, axis=axis)
        out.append((csum.astype(jnp.float32) * (scale / n)).reshape(d.shape[1:]))
        resid = (flat - codes.astype(jnp.float32) * scale).reshape(d.shape)
        sel = pmask.reshape((K,) + (1,) * (d.ndim - 1)) > 0
        ef_out.append(jnp.where(sel, resid, e).astype(e.dtype))
    return (jax.tree_util.tree_unflatten(treedef, out),
            jax.tree_util.tree_unflatten(treedef, ef_out))


# ----------------------------------------------------------------------
# In-graph compressors: delta -> dequantized delta (same shape/dtype).
# ----------------------------------------------------------------------


def make_compressor(cfg: CompressionConfig):
    """Returns compress(delta_tree, key) -> delta_tree (dequantized).

    One independent RNG key per leaf; the caller supplies a per-client
    key (vmapped over the K axis), so every client quantizes its own
    delta with its own noise — exactly the production wire protocol.
    With ``cfg.packed`` the payload is additionally materialized and
    round-tripped through the wire_pack kernels (bit-identical output,
    but the packed buffer the byte formulas price actually exists in
    the graph and is what a deployment would all-reduce).
    """
    if cfg.kind == "none":
        return lambda tree, key: tree
    if cfg.kind == "topk" and not cfg.packed:
        return lambda tree, key: jax.tree.map(lambda x: _topk_leaf(x, cfg.topk_frac), tree)

    if cfg.packed:

        def leaf_fn(x, k):
            return unpack_leaf(cfg, pack_leaf(cfg, x, k), x.shape, x.dtype)

    else:
        bits = _BITS[cfg.kind]

        def leaf_fn(x, k):
            return _quantize_leaf(x, k, bits, cfg.stochastic)

    def compress(tree, key):
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        keys = jax.random.split(key, len(leaves))
        out = [leaf_fn(x, k) for x, k in zip(leaves, keys)]
        return jax.tree_util.tree_unflatten(treedef, out)

    return compress
