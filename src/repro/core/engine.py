"""RoundEngine — one facade over the sync and async round engines.

``train.py``, ``launch.sweeps`` and ``benchmarks/common.py`` used to
wire up ``make_round_step`` / ``make_hyper_round_step`` / the fedsgd
path by hand, each duplicating the engine dispatch, the hyper
extraction and the capability checks. ``build_round_engine(plan, ...)``
is now the single entry point: it validates the plan at CONSTRUCTION
time (an invalid engine/plane combination fails before any tracing or
data movement) and returns a ``RoundEngine`` whose fields cover every
way the drivers consume an engine:

- ``step``: the plan-constant round function (all knobs baked in) —
  the train/bench path. Built only when a ``base_key`` is supplied.
- ``hyper_step``: the traced-knob round function — the sweep path.
  One compilation serves every grid point that shares
  ``structural_key``.
- ``structural_key``: the engine's compile identity (engine name,
  server optimizer family, aggregator, compression config, corruption
  kind, plus the latency tier tables and async buffer size when they
  shape the graph). Two plans with equal keys can share a jitted
  ``hyper_step`` — this is exactly what the sweep runner's jit cache
  keys on.
- ``init_state`` / ``state_specs`` / ``hypers``: state construction,
  pjit PartitionSpecs, and the plan's traced-scalar dict.

``client_sharding`` (a ``fedavg.ClientSharding`` over a mesh with a
named ``clients`` axis, see ``launch.mesh.make_federated_mesh``) is a
construction-time capability like everything else: it is validated
here (fedsgd has no client axis; K must divide over the shards), both
``step`` and ``hyper_step`` select the sharded bodies, and the axis
name + shard count fold into ``structural_key`` — a sharded and an
unsharded engine never collide in a jit cache.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple, Optional

from repro.core.fedavg import (
    ClientSharding,
    _check_fedsgd_aggregator,
    _check_fedsgd_compression,
    _check_fedsgd_corruption,
    _check_sharding_engine,
    init_server_state,
    make_hyper_round_step,
    make_round_step,
    plan_hypers,
    server_state_specs,
)
from repro.core.plan import FederatedPlan
from repro.core.task import FederatedTask

ENGINES = ("fedavg", "fedsgd", "async")


class RoundEngine(NamedTuple):
    name: str                     # "fedavg" | "fedsgd" | "async"
    plan: FederatedPlan
    structural_key: tuple         # hashable compile identity
    init_state: Callable          # (params) -> ServerState
    hyper_step: Callable          # (state, batch, hypers, base_key) -> (state, metrics)
    hypers: Callable              # () -> plan's traced-scalar dict
    state_specs: Callable         # (param_specs, ...) -> ServerState specs
    step: Optional[Callable] = None   # (state, batch) -> (state, metrics)
    task: Optional[FederatedTask] = None  # set when built from a FederatedTask


def validate_plan(plan: FederatedPlan) -> None:
    """Engine-capability validation, centralized at the construction
    seam: every invalid engine/plane combination fails HERE with the
    message that explains the capability gap, not rounds later inside
    a traced body."""
    if plan.engine not in ENGINES:
        raise ValueError(f"unknown engine {plan.engine!r}; available: {ENGINES}")
    if plan.engine == "fedsgd":
        _check_fedsgd_aggregator(plan.aggregation.name)
        _check_fedsgd_compression(plan.compression)
        _check_fedsgd_corruption(plan.corruption.kind)
    if plan.engine == "async":
        if plan.asynchrony.buffer_size < 0:
            raise ValueError(
                f"async buffer_size must be >= 0 (0 resolves to K), got "
                f"{plan.asynchrony.buffer_size}"
            )
        if plan.asynchrony.staleness_beta < 0:
            raise ValueError(
                "staleness_beta < 0 would UP-weight stale deltas, got "
                f"{plan.asynchrony.staleness_beta}"
            )


def _graph_corruption_kind(plan: FederatedPlan) -> str:
    """The corruption kind as the jitted graph sees it: data-plane
    adversaries (label_shuffle) poison host-side and keep the identity
    in-graph stage, so they share the honest compilation."""
    return plan.corruption.kind if plan.corruption.in_graph else "none"


def engine_structural_key(plan: FederatedPlan) -> tuple:
    """The plan facets that are compile-time structure for the round
    step. Everything else (lrs, schedules, FVN, cohort rates, agg
    knobs, corruption rate/scale, latency base/spread, staleness beta)
    is traced through ``hyper_step`` and deliberately absent."""
    key = (
        plan.engine,
        plan.server_optimizer,
        plan.aggregation.name,
        plan.compression,
        _graph_corruption_kind(plan),
    )
    lat = plan.latency
    if plan.engine == "async":
        # async always draws arrivals; enabled does not change its graph
        key += (lat.tier_speeds, lat.tier_probs,
                plan.asynchrony.resolve_buffer(plan.clients_per_round))
    elif lat.enabled:
        key += (True, lat.tier_speeds, lat.tier_probs)
    return key


def structural_key_str(key) -> str:
    """Canonical string form of a structural key (or any facet of one)
    — the trace-JSON join identity. ``structural_key`` tuples contain
    frozen config dataclasses whose repr is deterministic, but raw
    reprs are noisy; this flattens to a compact slug so trace records
    keyed on two machines compare equal for equal graphs."""
    if isinstance(key, tuple):
        return "|".join(structural_key_str(k) for k in key)
    if dataclasses.is_dataclass(key) and not isinstance(key, type):
        fields = ",".join(
            f"{f.name}={structural_key_str(getattr(key, f.name))}"
            for f in dataclasses.fields(key)
        )
        return f"{type(key).__name__}({fields})"
    return str(key)


def build_round_engine(
    plan: FederatedPlan,
    task: Callable | FederatedTask,
    base_key=None,
    client_sharding: Optional[ClientSharding] = None,
) -> RoundEngine:
    """THE engine factory: validate the plan, then wire every consumer
    surface of the selected engine. ``task`` is a ``FederatedTask``
    (the model + batch adapter + eval contract; its name joins the
    structural key so tasks never share a jit cache entry) or — the
    original form, still supported — a bare ``loss_fn`` callable.
    ``base_key`` is only needed for the plan-constant ``step``
    (train/bench); sweep-style callers that only use ``hyper_step``
    may omit it. ``client_sharding`` runs the per-client stage under
    ``shard_map`` over its mesh's ``clients`` axis (bit-for-bit the
    vmap round on a 1-device mesh)."""
    if isinstance(task, FederatedTask):
        loss_fn = task.loss_fn
    else:
        task, loss_fn = None, task
    validate_plan(plan)
    if client_sharding is not None:
        _check_sharding_engine(plan.engine, client_sharding)
        client_sharding.check_clients(plan.clients_per_round)
    latency = plan.latency if (plan.engine == "async" or plan.latency.enabled) else None
    buffer_size = None
    if plan.engine == "async":
        buffer_size = plan.asynchrony.resolve_buffer(plan.clients_per_round)
    hyper_step = make_hyper_round_step(
        loss_fn,
        engine=plan.engine,
        server_optimizer=plan.server_optimizer,
        aggregator=plan.aggregation.name,
        compression=plan.compression,
        corruption=_graph_corruption_kind(plan),
        latency=latency,
        buffer_size=buffer_size,
        client_sharding=client_sharding,
    )
    step = (
        make_round_step(loss_fn, plan, base_key, client_sharding)
        if base_key is not None
        else None
    )
    structural_key = engine_structural_key(plan)
    if client_sharding is not None:
        structural_key += (client_sharding.structural(),)
    if task is not None:
        structural_key += (("task", task.name),)
    return RoundEngine(
        name=plan.engine,
        plan=plan,
        structural_key=structural_key,
        init_state=functools.partial(init_server_state, plan),
        hyper_step=hyper_step,
        hypers=functools.partial(plan_hypers, plan),
        state_specs=functools.partial(server_state_specs, plan),
        step=step,
        task=task,
    )
