"""Buffered-asynchronous round engine (FedBuff-style streaming server).

The sync engines simulate the paper's barrier round: sample K clients,
wait for every report, aggregate once. The motivating deployment is
millions of phones reporting whenever they are charging and idle — the
barrier is a simulator artifact, and it prices the round at the
SLOWEST participant's arrival. This engine removes the barrier the way
production async FL does (FedBuff, Nguyen et al. 2022):

- every wave, K sampled clients download the CURRENT params and train
  locally (the same vmapped ``fedavg._client_update``, cohort stage
  and compression/corruption payload pipeline — one code path for
  what a client computes and uploads);
- each upload *arrives* at a simulated time drawn from the device-tier
  latency model (``cohort.LatencyConfig``): tiers are categorical
  compile-time structure, base latency and lognormal jitter are traced
  hyper scalars;
- the server consumes arrivals in time order into a size-B buffer
  (``AsyncBuffer``) and applies one optimizer step whenever the buffer
  fills, discounting each buffered delta by its staleness
  ``1 / (1 + s)**beta`` where ``s`` counts server versions applied
  since that client downloaded;
- the buffer PERSISTS across waves in ``ServerState.abuf``: a
  straggler's update lands in a later flush (stale-discounted) instead
  of being dropped, exactly the behaviour the ``ServerState.stale``
  replay cache approximated adversarially in PR 4.

Staleness discipline: all of a wave's clients download at the wave's
opening version ``v0``; a flush mid-wave bumps the server version, so
later arrivals of the same wave are already one version stale when
they eventually flush. The discount SCALES each delta *before* the
aggregator's weight normalization — a discount folded into the
aggregation weights would cancel whenever a flush's staleness is
uniform (the weighted mean renormalizes), which is precisely the
common case.

Wall-clock accounting: a wave's simulated duration ``sim_time_s`` is
the arrival time of its LAST FLUSH — the moment the final server step
of the wave landed. Arrivals after the last flush sit in the buffer
and are paid for in the wave that flushes them. A wave with no flush
costs its last participant arrival (the stream still had to be
observed). This is what gives async its genuine edge over the barrier
engine on the CFMQ wall-clock axis: the tail of the latency
distribution stops gating every server step.

Parity (tested bit-for-bit): with B = K, full participation, one
device tier and zero jitter spread, a wave inserts K equal-time
arrivals in client order (the arrival argsort is stable, so equal
times keep the identity permutation), flushes exactly once with
staleness 0 — ``staleness_discount`` returns exactly 1.0 — and the
flush reduces to the sync engine's aggregate + server step.

Everything jit-friendly: ``buffer_size`` is static (it shapes the
buffer), ``beta`` and the latency knobs are traced, and the arrival
stream is a ``lax.scan`` whose flush is a ``lax.cond`` — one
compilation serves an async sweep grid.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import fvn as fvn_lib
from repro.core.cohort import make_latency_fn
from repro.core.fedavg import (
    ServerPlane,
    ServerState,
    _apply_cohort,
    _client_axis_zeros,
    _client_key_fanout,
    _client_update_stage,
    _delta_payload_stage,
    _latency_key,
    _plane_keys,
    _wire_metrics,
    _plan_server_plane,
)
from repro.core.plan import FederatedPlan, make_server_optimizer
from repro.optim import apply_updates, sgd

PyTree = Any


class AsyncBuffer(NamedTuple):
    """The server's pending-update buffer (lives in ServerState.abuf).

    Slots [0, count) are filled; a flush logically empties the buffer
    by resetting ``count`` (stale slot payloads are overwritten before
    they can be read again). ``version`` counts applied server steps —
    the staleness clock."""

    deltas: PyTree          # (B, ...) pending per-client deltas
    weights: jnp.ndarray    # (B,) f32 example counts n_k per slot
    versions: jnp.ndarray   # (B,) i32 server version at download time
    count: jnp.ndarray      # () i32 filled slots
    version: jnp.ndarray    # () i32 server version (total flushes)


def init_async_buffer(params: PyTree, buffer_size: int) -> AsyncBuffer:
    return AsyncBuffer(
        deltas=_client_axis_zeros(params, buffer_size),
        weights=jnp.zeros((buffer_size,), jnp.float32),
        versions=jnp.zeros((buffer_size,), jnp.int32),
        count=jnp.zeros((), jnp.int32),
        version=jnp.zeros((), jnp.int32),
    )


def staleness_discount(staleness, beta):
    """``1/(1+s)**beta`` computed as ``exp(-beta * log1p(s))``: exactly
    1.0 (bitwise) both at s == 0 for any beta and at beta == 0 for any
    s — exp(0.0) is exact — so the sync-parity and unweighted edge
    cases cost no tolerance."""
    return jnp.exp(-beta * jnp.log1p(jnp.asarray(staleness, jnp.float32)))


def _async_round_body(
    loss_fn,
    client_opt,
    server_opt,
    sigma_fn,
    base_key,
    state: ServerState,
    round_batch: PyTree,
    plane: ServerPlane,
    latency_fn: Callable,
    buffer_size: int,
    beta,
    sharding=None,
):
    """One wave of the buffered-async engine (one jitted graph):
    client deltas -> cohort -> payload pipeline -> time-ordered arrival
    stream -> buffer inserts -> staleness-discounted flushes.

    With ``sharding`` only the client-update stage shards (the heavy
    per-client local training); the arrival stream is inherently
    sequential server-side state and stays on the gathered global axis,
    so the sharded wave is bit-for-bit the vmap wave."""
    B = buffer_size
    K = jax.tree.leaves(round_batch)[0].shape[0]
    ckey, qkey, akey, xkey = _plane_keys(base_key, state.round_idx)

    round_batch, pmask = _apply_cohort(plane, ckey, round_batch)

    deltas, losses, n_k = _client_update_stage(
        loss_fn, client_opt, sigma_fn, base_key, state.params, round_batch,
        state.round_idx, sharding,
    )

    ckeys = _client_key_fanout(plane, qkey, K)
    deltas, ef, cmask, stale = _delta_payload_stage(
        plane, deltas, state.ef, pmask, ckeys, xkey, state.stale
    )

    # Arrival order: participants by simulated upload time, then
    # non-participants (time +inf — they never upload). The argsort is
    # stable, so the zero-spread parity configuration (all times equal)
    # keeps the identity permutation and stays bit-compatible with the
    # sync engine's client order.
    times = latency_fn(_latency_key(base_key, state.round_idx), K)
    order = jnp.argsort(jnp.where(pmask > 0, times, jnp.inf))
    arr = (
        jax.tree.map(lambda d: d[order], deltas),
        n_k[order],
        pmask[order],
        times[order],
    )
    v0 = state.abuf.version  # every wave client downloaded at wave start

    def arrival(carry, inp):
        params, opt_state, buf, flushed, t_last, stale_sum, applied = carry
        d_i, w_i, p_i, t_i = inp

        # Insert: always WRITE slot buf.count (it is beyond the filled
        # region, so a non-participant's write is never read), but only
        # a participant bumps count. A dropped client therefore
        # occupies no slot and triggers no flush.
        new_deltas = jax.tree.map(
            lambda bl, d: jax.lax.dynamic_update_index_in_dim(bl, d, buf.count, 0),
            buf.deltas,
            d_i,
        )
        new_w = jax.lax.dynamic_update_index_in_dim(buf.weights, w_i, buf.count, 0)
        new_v = jax.lax.dynamic_update_index_in_dim(buf.versions, v0, buf.count, 0)
        count = buf.count + (p_i > 0).astype(jnp.int32)

        def flush(op):
            params, opt_state, flushed, t_last, stale_sum, applied = op
            s = (buf.version - new_v).astype(jnp.float32)  # (B,) >= 0
            disc = staleness_discount(s, beta)
            # Discount BEFORE aggregation: the aggregator normalizes its
            # weights, so a uniform per-flush discount folded into the
            # weights would cancel exactly.
            scaled = jax.tree.map(
                lambda d: d * disc.reshape((B,) + (1,) * (d.ndim - 1)), new_deltas
            )
            fkey = jax.random.fold_in(akey, buf.version)
            wbar = plane.aggregate(scaled, new_w, jnp.ones((B,), jnp.float32), fkey)
            updates, opt_state = server_opt.update(wbar, opt_state, params)
            params = apply_updates(params, updates)
            return (params, opt_state, flushed + 1, t_i, stale_sum + s.sum(),
                    applied + B, jnp.zeros((), jnp.int32), buf.version + 1)

        def hold(op):
            params, opt_state, flushed, t_last, stale_sum, applied = op
            return (params, opt_state, flushed, t_last, stale_sum, applied,
                    count, buf.version)

        params, opt_state, flushed, t_last, stale_sum, applied, count, version = jax.lax.cond(
            count == B, flush, hold,
            (params, opt_state, flushed, t_last, stale_sum, applied),
        )
        buf = AsyncBuffer(new_deltas, new_w, new_v, count, version)
        return (params, opt_state, buf, flushed, t_last, stale_sum, applied), None

    init = (state.params, state.opt_state, state.abuf, jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
            jnp.zeros((), jnp.int32))
    (params, opt_state, buf, flushed, t_last, stale_sum, applied), _ = jax.lax.scan(
        arrival, init, arr
    )

    # Wave wall-clock: the last flush's arrival time. Updates buffered
    # past the last flush are paid for by the wave that flushes them; a
    # flushless wave still observes its stream to the last participant.
    t_stream = (times * pmask).max()
    sim_time = jnp.where(flushed > 0, t_last, t_stream)
    # delta_norm here is the wave's total parameter displacement (the
    # sync engines report the aggregated pseudo-gradient norm; a wave
    # applies 0..K server steps, so displacement is the analogue).
    disp = jax.tree.map(lambda a, b: a - b, params, state.params)
    n = jnp.maximum(n_k.sum(), 1.0)
    metrics = {
        "loss": (losses * n_k).sum() / n,
        "examples": n_k.sum(),
        "delta_norm": jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(disp))),
        "corrupted": cmask.sum(),
        **_wire_metrics(plane, state.params, pmask, K),
        "sim_time_s": sim_time,
        "server_steps": flushed.astype(jnp.float32),
        "staleness_mean": stale_sum / jnp.maximum(applied.astype(jnp.float32), 1.0),
    }
    return ServerState(params, opt_state, state.round_idx + 1, ef, stale, buf), metrics


def make_async_round(
    loss_fn: Callable,
    plan: FederatedPlan,
    base_key,
    client_sharding=None,
) -> Callable[[ServerState, PyTree], tuple[ServerState, dict]]:
    """Returns round_step(state, round_batch) -> (state, metrics) for
    plan.engine == "async". round_batch layout matches the fedavg
    engine: (K, S_local, b, ...) with a "weight" leaf. The state must
    come from ``init_server_state`` (it carries the AsyncBuffer). The
    arrival latency model is plan.latency — the async engine always
    draws arrival times (it needs the order), whether or not
    ``latency.enabled`` marks sync rounds for wall-clock pricing."""
    client_opt = sgd(plan.client_lr)
    server_opt = make_server_optimizer(plan)
    sigma_fn = (lambda r: fvn_lib.fvn_sigma(plan.fvn, r)) if plan.fvn.enabled else None
    plane = _plan_server_plane(plan)
    latency_fn = make_latency_fn(plan.latency)
    buffer_size = plan.asynchrony.resolve_buffer(plan.clients_per_round)
    beta = plan.asynchrony.staleness_beta
    if client_sharding is not None:
        client_sharding.check_clients(plan.clients_per_round)

    def round_step(state: ServerState, round_batch: PyTree):
        return _async_round_body(
            loss_fn, client_opt, server_opt, sigma_fn, base_key, state, round_batch,
            plane, latency_fn, buffer_size, beta, client_sharding,
        )

    return round_step
