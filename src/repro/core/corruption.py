"""Adversarial client-corruption plane — the Byzantine side of the
quality/cost frontier.

The paper's Alg. 1 assumes every client update is honest; production
cross-device FL does not (Hard et al. 2005.10406, Cui et al.
2102.04429): updates arrive corrupted, stale, or adversarial. This
module models those adversaries *inside* the jitted round step, as a
per-client transform of the post-compression deltas — i.e. it corrupts
what the server *receives*, which is the threat model the robust
aggregators (``repro.core.aggregation``) exist to survive:

- ``sign_flip``  — corrupted clients report ``-scale * delta`` (the
  classic Byzantine gradient-ascent attack);
- ``gaussian``   — corrupted clients add ``scale * rms(delta)`` white
  noise (a faulty sensor / garbage update);
- ``zero``       — corrupted clients report an all-zero delta (a
  dropped payload that still claims its examples: with the paper's
  example-weighted mean, its ``n_k`` drags the aggregate toward 0);
- ``stale``      — corrupted clients replay ``scale x`` their last
  *honestly-computed* (post-compression) delta from a
  ``ServerState``-threaded cache (``ServerState.stale``; see
  ``init_server_state``) — the stale-worker failure mode of
  asynchronous deployments. The cache always tracks the honest
  stream (never the replayed one), so staleness stays bounded at one
  round instead of collapsing to a replay-of-replay fixed point;
- ``label_shuffle`` — a *data-plane* adversary: the client trains
  honestly on features whose transcripts were permuted host-side (see
  ``repro.data.synthetic.label_shuffle`` and the
  ``FederatedSampler(label_shuffle_rate=...)`` knob). In-graph it is
  the identity — the poison enters through the gradients.

Corruption composes with the rest of the server plane exactly like the
cohort stage: the *kind* is compile-time structure (it changes the
graph), while ``rate`` and ``scale`` are traced ``HYPER_KEYS`` scalars
(see ``fedavg.plan_hypers``), so an entire adversary grid — every rate
x magnitude point — shares ONE compilation per (aggregator, kind).
Which clients are corrupted is a per-round Bernoulli(rate) draw on a
dedicated RNG stream tag.

Two invariants the round engine relies on:

- a corrupted client that is also a non-participant contributes
  neither delta nor EF residual update: the corruption mask is
  ``Bernoulli(rate) * pmask``, so cohort dropout always wins (the
  cohort x corruption regression in tests/test_corruption.py);
- corruption never changes wire accounting: a corrupted participant
  still uploads a full payload (a zero or sign-flipped delta costs the
  same bytes), so CFMQ stays byte-exact under attack.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

PyTree = Any

# In-graph delta corruptions + the data-plane kind. "none"/"label_shuffle"
# keep the identity plane in the graph (no corruption RNG is traced).
DELTA_KINDS = ("sign_flip", "gaussian", "zero", "stale")
KINDS = ("none",) + DELTA_KINDS + ("label_shuffle",)

Corruption = Callable[..., PyTree]

_CORRUPTIONS: Dict[str, Corruption] = {}


def register_corruption(name: str):
    def deco(fn: Corruption) -> Corruption:
        _CORRUPTIONS[name] = fn
        return fn

    return deco


def get_corruption(name: str) -> Corruption:
    try:
        return _CORRUPTIONS[name]
    except KeyError:
        raise KeyError(f"unknown corruption {name!r}; "
                       f"available: {sorted(_CORRUPTIONS)}") from None


def available_corruptions() -> list[str]:
    return sorted(_CORRUPTIONS)


@dataclasses.dataclass(frozen=True)
class CorruptionConfig:
    """Static adversary spec. ``kind`` is compile-time structure (part
    of the jit cache key); ``rate`` and ``scale`` are traced scalars so
    a whole adversary grid shares one compilation per kind."""
    kind: str = "none"      # see KINDS
    rate: float = 0.0       # P(participating client is corrupted), per round
    scale: float = 1.0      # magnitude knob (sign_flip/gaussian/stale)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown corruption kind {self.kind!r}; available: {KINDS}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"corruption rate must be in [0, 1], got {self.rate}")

    @property
    def in_graph(self) -> bool:
        """True iff the corruption transforms deltas inside the jitted
        round step (label_shuffle poisons host-side, in the data plane)."""
        return self.kind in DELTA_KINDS

    @property
    def active(self) -> bool:
        return self.kind != "none" and self.rate > 0.0


# ----------------------------------------------------------------------
# Registry entries: fn(deltas, key, scale, stale) -> corrupted deltas
# for the FULL (K, ...) batch; the wrapper below selects per client.
# ----------------------------------------------------------------------

@register_corruption("sign_flip")
def sign_flip(deltas: PyTree, key, scale, stale) -> PyTree:
    """Report -scale * delta (gradient ascent at scale >= 1)."""
    return jax.tree.map(lambda d: -scale * d.astype(jnp.float32), deltas)


@register_corruption("gaussian")
def gaussian(deltas: PyTree, key, scale, stale) -> PyTree:
    """Add white noise at ``scale x`` each leaf's per-client RMS, so
    the attack magnitude tracks the honest update magnitude (a fixed
    absolute sigma would be invisible early and fatal late)."""
    leaves, treedef = jax.tree_util.tree_flatten(deltas)
    keys = jax.random.split(key, len(leaves))

    def leaf(d, k):
        d32 = d.astype(jnp.float32)
        axes = tuple(range(1, d32.ndim))
        rms = jnp.sqrt(jnp.mean(jnp.square(d32), axis=axes, keepdims=True) + 1e-12)
        return d32 + scale * rms * jax.random.normal(k, d32.shape, jnp.float32)

    return jax.tree_util.tree_unflatten(
        treedef, [leaf(d, k) for d, k in zip(leaves, keys)])


@register_corruption("zero")
def zero(deltas: PyTree, key, scale, stale) -> PyTree:
    """An all-zero update that still claims its n_k examples and still
    pays its uplink bytes — the free-rider / dropped-payload client."""
    return jax.tree.map(jnp.zeros_like, deltas)


@register_corruption("stale")
def stale_replay(deltas: PyTree, key, scale, stale) -> PyTree:
    """Replay scale x the client's last honestly-computed delta from
    the ServerState-threaded cache (zeros on round 0: a stale worker
    that has not reported yet sends nothing useful). The cache update
    (see ``make_corruption_fn``) stores the honest stream even for
    corrupted clients, keeping staleness one round deep."""
    if stale is None:
        raise ValueError(
            "stale corruption replays from the ServerState-threaded delta "
            "cache (ServerState.stale), which init_server_state only "
            "allocates when plan.corruption.kind == 'stale'")
    return jax.tree.map(lambda s: scale * s, stale)


# ----------------------------------------------------------------------
# The composed stage: (key, deltas, pmask, stale) ->
#                     (deltas', cmask, stale')
# ----------------------------------------------------------------------

def identity_corruption(key, deltas: PyTree, pmask, stale: Optional[PyTree]):
    """The honest plane ("none" / data-plane label_shuffle): no
    corruption RNG enters the graph, the cache passes through."""
    K = jax.tree.leaves(deltas)[0].shape[0]
    return deltas, jnp.zeros((K,), jnp.float32), stale


def _bcast(mask, leaf):
    return mask.reshape((-1,) + (1,) * (leaf.ndim - 1))


def make_corruption_fn(kind: str, rate, scale):
    """Returns corrupt(key, deltas, pmask, stale) -> (deltas', cmask,
    stale'). ``kind`` is static; ``rate``/``scale`` may be Python
    floats (plan path) or traced scalars (hyper path) — the graph is
    identical either way, so rate=0.0 rides the same compilation as
    any other rate of the same kind.

    ``cmask`` is the realized corrupted-client mask, already multiplied
    by ``pmask``: a non-participant can never be a corrupted
    *contributor* (its delta stays the cohort's zero and its EF
    residual stays untouched). ``stale'`` caches this round's *honest*
    post-compression deltas for participants — corrupted ones included,
    so a replay is always of last round's honest upload, never a
    replay-of-replay — while non-participants keep their cache entry.
    """
    if kind in ("none", "label_shuffle"):
        return identity_corruption
    fn = get_corruption(kind)

    def corrupt(key, deltas: PyTree, pmask, stale: Optional[PyTree]):
        K = jax.tree.leaves(deltas)[0].shape[0]
        mkey, nkey = jax.random.split(key)
        drawn = (jax.random.uniform(mkey, (K,)) < rate).astype(jnp.float32)
        cmask = drawn * pmask
        bad = fn(deltas, nkey, scale, stale)
        out = jax.tree.map(
            lambda b, d: jnp.where(_bcast(cmask, d) > 0,
                                   b.astype(jnp.float32),
                                   d.astype(jnp.float32)),
            bad, deltas)
        new_stale = stale
        if stale is not None:
            new_stale = jax.tree.map(
                lambda d, s: jnp.where(_bcast(pmask, d) > 0,
                                       d.astype(jnp.float32), s),
                deltas, stale)
        return out, cmask, new_stale

    return corrupt
