"""Cohort dynamics — partial participation, dropout, stragglers.

The paper samples K clients and assumes all K report back. Production
cross-device FL does not get that luxury: clients go offline mid-round
(dropout), report late (stragglers cut off at the aggregation
deadline), or never start. This module models those dynamics *inside*
the jitted round step as weight-mask transforms, which composes
exactly with the engine's n_k example-weighting:

- a dropped client's weights go to 0 for every local step, so its
  local optimization is a provable no-op (zero grads), its delta is 0
  and its n_k is 0 — it contributes nothing to the aggregate and
  uploads nothing (the round metrics count uplink bytes only for
  participants);
- a straggler keeps only the first ``ceil(straggler_keep * S)`` local
  steps — the deadline cuts its local pass short, but its partial
  delta still aggregates (weighted by the examples it actually saw).

All rates are *traced* scalars (see ``fedavg.HYPER_KEYS``), so one
compiled round function serves a whole participation/straggler grid.
Draws are derived from fold_in(base_key, round) on a dedicated stream
tag — deterministic per round, independent of the FVN stream.

A round is guaranteed at least one participant: when every Bernoulli
draw fails, the client with the smallest uniform draw (the "most
available" one) is kept, keeping n > 0 without biasing full-
participation parity (participation=1.0 never triggers the rescue).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LatencyConfig:
    """Per-client round-trip latency model (device tiers + jitter).

    Arrival time of client k's upload, in simulated seconds from the
    wave start::

        t_k = base_s * tier_speeds[tier_k] * exp(spread * normal())

    with ``tier_k`` a categorical draw over ``tier_probs`` — the
    device-tier heterogeneity of production cross-device FL (2109.15108
    §3: phone fleets span >4x single-round latency between flagship and
    entry tiers). ``base_s`` and ``spread`` are traced hyper scalars
    (``HYPER_KEYS``); the tier tables are compile-time structure.

    ``enabled`` gates the *sync* engines' wall-clock metric: when True
    a barrier round's simulated duration is the slowest participant's
    arrival. The async engine always draws arrival times (it needs the
    order), whether or not ``enabled`` is set.

    The parity configuration — one tier, ``spread=0.0`` — draws equal
    times for every client: exp(0) == 1 exactly, so the stable
    arrival argsort is the identity permutation.
    """

    enabled: bool = False
    base_s: float = 60.0                       # median round-trip seconds
    spread: float = 0.25                       # lognormal jitter sigma
    tier_speeds: tuple = (1.0, 2.0, 4.0)       # slowdown per device tier
    tier_probs: tuple = (0.5, 0.3, 0.2)        # tier mix of the fleet

    def __post_init__(self):
        if len(self.tier_speeds) != len(self.tier_probs):
            raise ValueError(
                f"tier_speeds ({len(self.tier_speeds)}) and tier_probs "
                f"({len(self.tier_probs)}) must pair up one speed per tier")


def tier_assignments(key, K: int, tier_probs):
    """(K,) int32 categorical tier draw from the static fleet mix."""
    u = jax.random.uniform(key, (K,))
    cum = jnp.cumsum(jnp.asarray(tier_probs, jnp.float32))
    idx = (u[:, None] >= cum[None, :]).sum(axis=1)
    return jnp.minimum(idx, len(tier_probs) - 1).astype(jnp.int32)


def draw_latencies(key, K: int, base_s, spread, tier_speeds, tier_probs):
    """(K,) f32 simulated upload arrival times (seconds from wave
    start). ``base_s`` / ``spread`` may be Python floats (plan path) or
    traced scalars (hyper path); the tier tables are static."""
    tkey, jkey = jax.random.split(key)
    tiers = tier_assignments(tkey, K, tier_probs)
    speed = jnp.asarray(tier_speeds, jnp.float32)[tiers]
    jitter = jnp.exp(spread * jax.random.normal(jkey, (K,)))
    return base_s * speed * jitter


def make_latency_fn(cfg: LatencyConfig, base_s=None, spread=None):
    """Returns latencies(key, K) -> (K,) f32 arrival times, with the
    traced knobs overridable (the hyper path passes hyper scalars; the
    plan path uses the config's constants)."""
    base_s = cfg.base_s if base_s is None else base_s
    spread = cfg.spread if spread is None else spread

    def latencies(key, K):
        return draw_latencies(key, K, base_s, spread,
                              cfg.tier_speeds, cfg.tier_probs)

    return latencies


def rescue_mask(u):
    """One-hot over argmin: exactly ONE most-available client. A value
    comparison (``u == u.min()``) would mark every tied client — ties
    are real at large K in f32 — and an all-draws-fail round would then
    rescue a whole sub-cohort instead of a single straggler."""
    return jnp.arange(u.shape[0]) == jnp.argmin(u)


def participation_mask(key, K: int, participation):
    """(K,) float32 mask of reporting clients; never all-zero."""
    u = jax.random.uniform(key, (K,))
    survivors = u < participation
    return jnp.where(survivors.any(), survivors, rescue_mask(u)).astype(jnp.float32)


def straggler_step_mask(key, weight, straggler_frac, straggler_keep):
    """(K, S) float32 mask: stragglers keep only the first
    ``ceil(straggler_keep * real_steps)`` of their *real* local steps.

    real_steps counts steps with any nonzero example weight per client,
    so zero-weight padding appended for shape sharing (``pad_steps``)
    never changes straggler semantics — a padded round gives the same
    deadline cut as the unpadded one.
    """
    K, S = weight.shape[:2]
    is_straggler = jax.random.uniform(key, (K,)) < straggler_frac
    real_steps = (weight.max(axis=2) > 0).sum(axis=1).astype(jnp.float32)
    keep_steps = jnp.ceil(straggler_keep * real_steps)                # (K,)
    step_ok = jnp.arange(S, dtype=jnp.float32)[None, :] < keep_steps[:, None]
    return jnp.where(is_straggler[:, None], step_ok, True).astype(jnp.float32)


def make_cohort_fn(participation, straggler_frac, straggler_keep):
    """Returns cohort(key, weight) -> (weight', pmask).

    ``weight`` is the round batch's (K, S, b) example mask; rates may
    be Python floats (plan path) or traced scalars (hyper path) — the
    graph is identical either way.
    """
    def cohort(key, weight):
        K = weight.shape[0]
        pmask = participation_mask(jax.random.fold_in(key, 0), K, participation)
        smask = straggler_step_mask(jax.random.fold_in(key, 1), weight,
                                    straggler_frac, straggler_keep)
        return weight * pmask[:, None, None] * smask[:, :, None], pmask

    return cohort


def identity_cohort(key, weight):
    """Full participation (the paper/parity path): no RNG in the graph."""
    K = weight.shape[0]
    return weight, jnp.ones((K,), jnp.float32)
