"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff=1408 (routed
expert) vocab=102400 — MLA kv_lora=512, 64 routed experts top-6 + 2
shared, dense first layer (ff=10944). [arXiv:2405.04434]

The assignment header's "160 routed" is inconsistent with the model's
64-expert config; we follow the bracketed per-layer spec (64e top-6,
2 shared) and note the discrepancy. MLA: qk_nope=128 qk_rope=64 v=128;
the decode cache stores only (c_kv, k_rope) = 576 floats/token — the
architecture's memory-roofline play. long_500k via SW variant per the
assignment's dense-arch policy (MLA itself is full-attention).
Engine: fedavg.
"""
from repro.configs import base
from repro.models.mla import MLAConfig
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

ARCH_ID = "deepseek-v2-lite-16b"


def make_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=27, d_model=2048, n_heads=16, n_kv=16, head_dim=128,
        d_ff=1408, vocab=102400,
        moe=MoEConfig(n_experts=64, top_k=6, expert_ff=1408,
                      n_shared=2, shared_ff=2816),
        moe_first_dense=1, first_dense_ff=10944,
        mla=MLAConfig(d_model=2048, n_heads=16, kv_lora=512,
                      qk_nope_dim=128, qk_rope_dim=64, v_dim=128),
        rope_theta=10000.0, act="silu",
        dtype="bfloat16", param_dtype="bfloat16",
        **kw,
    )


def make_smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        n_layers=3, d_model=128, n_heads=4, n_kv=4, head_dim=32,
        d_ff=96, vocab=128,
        moe=MoEConfig(n_experts=4, top_k=2, expert_ff=96,
                      n_shared=1, shared_ff=96, capacity_factor=4.0),
        moe_first_dense=1, first_dense_ff=192,
        mla=MLAConfig(d_model=128, n_heads=4, kv_lora=64,
                      qk_nope_dim=32, qk_rope_dim=16, v_dim=32),
        dtype="float32", param_dtype="float32", loss_chunk=16,
    )


ARCH = base.ArchSpec(
    arch_id=ARCH_ID,
    citation="arXiv:2405.04434",
    kind="moe",
    make_config=make_config,
    make_smoke_config=make_smoke_config,
    engine="fedavg",
    param_rules=base.transformer_param_rules(16, 16, mla=True, moe=True),
    cache_rules=base.transformer_cache_rules(),
    long_policy="sw_variant",
    make_long_config=lambda: make_config(window=4096),
)
