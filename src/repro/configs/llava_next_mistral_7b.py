"""llava-next-mistral-7b [vlm] — 32L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=32000 — anyres tiling (ViT STUBBED: input_specs
provides tile patch embeddings; the MLP projector + LM side are
implemented). [hf:llava-hf/llava-v1.6-mistral-7b-hf]

Mistral's native sliding window (4096) makes long_500k legitimate
without a variant config. Engine: fedavg.
"""
from repro.configs import base
from repro.models.transformer import TransformerConfig
from repro.models.vlm import VLMConfig

ARCH_ID = "llava-next-mistral-7b"


def _lm(**kw) -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-lm",
        n_layers=32, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
        d_ff=14336, vocab=32000,
        window=4096,                      # mistral native SW
        rope_theta=10000.0, act="silu",
        dtype="bfloat16", param_dtype="bfloat16",
        **kw,
    )


def make_config() -> VLMConfig:
    return VLMConfig(name=ARCH_ID, lm=_lm(), vit_dim=1024, n_img_tokens=576)


def make_smoke_config() -> VLMConfig:
    lm = TransformerConfig(
        name=ARCH_ID + "-smoke-lm",
        n_layers=2, d_model=128, n_heads=4, n_kv=2, head_dim=32,
        d_ff=256, vocab=128, window=32,
        dtype="float32", param_dtype="float32", loss_chunk=16,
    )
    return VLMConfig(name=ARCH_ID + "-smoke", lm=lm, vit_dim=48, n_img_tokens=8)


ARCH = base.ArchSpec(
    arch_id=ARCH_ID,
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    kind="vlm",
    make_config=make_config,
    make_smoke_config=make_smoke_config,
    engine="fedavg",
    param_rules=base.transformer_param_rules(32, 8) + [(r"projector/w1$", base.P(None, "model")),
                                                       (r"projector/w2$", base.P("model", None))],
    cache_rules=base.transformer_cache_rules(),
    long_policy="native",                 # mistral SW=4096 is the window variant
)
