"""deepseek-67b [dense] — 95L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=102400 — llama architecture. [arXiv:2401.02954]

Engine: fedsgd + FSDP (67B). kv (8 < 16) replicates per the Megatron
fallback. long_500k via the sliding-window variant (W=4096).
"""
from repro.configs import base
from repro.models.transformer import TransformerConfig

ARCH_ID = "deepseek-67b"


def make_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=95, d_model=8192, n_heads=64, n_kv=8, head_dim=128,
        d_ff=22016, vocab=102400,
        rope_theta=10000.0, act="silu",
        dtype="bfloat16", param_dtype="bfloat16",
        **kw,
    )


def make_smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2, d_model=128, n_heads=4, n_kv=2, head_dim=32,
        d_ff=256, vocab=128,
        dtype="float32", param_dtype="float32", loss_chunk=16,
    )


ARCH = base.ArchSpec(
    arch_id=ARCH_ID,
    citation="arXiv:2401.02954",
    kind="dense",
    make_config=make_config,
    make_smoke_config=make_smoke_config,
    engine="fedsgd",
    param_rules=base.transformer_param_rules(64, 8),
    cache_rules=base.transformer_cache_rules(),
    long_policy="sw_variant",
    make_long_config=lambda: make_config(window=4096),
)
