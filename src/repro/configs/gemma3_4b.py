"""gemma3-4b [dense] — 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144 — 5:1 local(W=1024):global attention, 128k context.
[hf:google/gemma-3-1b-pt family]

qk_norm, (1+scale) RMSNorm, sqrt(d) embedding scale, gelu_tanh gating.
Attention params replicate (8 heads < model axis — Megatron fallback,
noted); FFN/vocab shard. long_500k NATIVE: the 5:1 local:global
pattern IS the sub-quadratic variant (full cache kept on the 1-in-6
global layers; the ring-buffer local cache is a §Perf optimization).
Engine: fedavg. Single rope theta (10k) vs gemma3's split local/global
bases — noted simplification.
"""
from repro.configs import base
from repro.models.transformer import TransformerConfig

ARCH_ID = "gemma3-4b"


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=34, d_model=2560, n_heads=8, n_kv=4, head_dim=256,
        d_ff=10240, vocab=262144,
        window=1024, global_every=6,
        qk_norm=True, rms_plus_one=True, emb_scale=True,
        act="gelu_tanh", rope_theta=10000.0,
        dtype="bfloat16", param_dtype="bfloat16", loss_chunk=128,
    )


def make_smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2, d_model=128, n_heads=4, n_kv=2, head_dim=32,
        d_ff=256, vocab=128,
        window=16, global_every=2,
        qk_norm=True, rms_plus_one=True, emb_scale=True, act="gelu_tanh",
        dtype="float32", param_dtype="float32", loss_chunk=16,
    )


ARCH = base.ArchSpec(
    arch_id=ARCH_ID,
    citation="hf:google/gemma-3-1b-pt",
    kind="dense",
    make_config=make_config,
    make_smoke_config=make_smoke_config,
    engine="fedavg",
    param_rules=base.transformer_param_rules(8, 4),
    cache_rules=base.transformer_cache_rules(),
    long_policy="native",                # 5:1 local:global pattern
)
