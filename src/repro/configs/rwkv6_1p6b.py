"""rwkv6-1.6b [ssm] — 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536 — Finch: data-dependent per-channel decay. [arXiv:2404.05892]

O(1)-in-context decode state (H x P x P per layer) — long_500k native.
The FedAvg/FVN technique applies unchanged (optimizer-level). Engine:
fedavg. Token-shift mixing uses static coefficients (5.2-style; the
6.0 dynamic-mix LoRAs are omitted — DESIGN.md).
"""
from repro.configs import base
from repro.models.model_zoo import RWKVModelConfig
from repro.models.rwkv import RWKVConfig

ARCH_ID = "rwkv6-1.6b"


def make_config() -> RWKVModelConfig:
    return RWKVModelConfig(
        name=ARCH_ID,
        n_layers=24,
        rwkv=RWKVConfig(d_model=2048, head_size=64, d_ff=7168, decay_lora=64),
        vocab=65536,
        dtype="bfloat16", param_dtype="bfloat16",
    )


def make_smoke_config() -> RWKVModelConfig:
    return RWKVModelConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        rwkv=RWKVConfig(d_model=128, head_size=32, d_ff=256, decay_lora=16),
        vocab=128,
        dtype="float32", param_dtype="float32", loss_chunk=16,
    )


ARCH = base.ArchSpec(
    arch_id=ARCH_ID,
    citation="arXiv:2404.05892",
    kind="ssm",
    make_config=make_config,
    make_smoke_config=make_smoke_config,
    engine="fedavg",
    param_rules=base.rwkv_param_rules(),
    cache_rules=base.rwkv_cache_rules(),
    long_policy="native",
)
