"""Assigned-architecture configs (+ the paper's RNN-T).

Every module defines an ``ARCH`` ArchSpec with the exact assigned
hyper-parameters (citation in the docstring), a reduced smoke variant,
pjit sharding rules, and per-shape input specs. ``registry.get(id)``
resolves ``--arch <id>``.
"""
from repro.configs.registry import get_arch, list_archs

__all__ = ["get_arch", "list_archs"]
