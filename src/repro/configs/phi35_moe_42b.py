"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (GQA kv=8)
d_ff=6400 (per expert) vocab=32064, MoE 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct]

42B total / 6.6B active params. Experts shard 1-per-chip over the
model axis (expert parallelism); kv (8 < 16) replicates. Engine:
fedsgd + FSDP (42B > one model-parallel group's HBM for the fedavg
per-client-replica layout). long_500k via the sliding-window variant
(W=4096), noted in DESIGN.md.
"""
from repro.configs import base
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

ARCH_ID = "phi3.5-moe-42b-a6.6b"


def make_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=32, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
        d_ff=6400, vocab=32064,
        moe=MoEConfig(n_experts=16, top_k=2, expert_ff=6400),
        rope_theta=10000.0, act="silu",
        dtype="bfloat16", param_dtype="bfloat16",
        **kw,
    )


def make_smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2, d_model=128, n_heads=4, n_kv=2, head_dim=32,
        d_ff=192, vocab=128,
        moe=MoEConfig(n_experts=4, top_k=2, expert_ff=192, capacity_factor=4.0),
        dtype="float32", param_dtype="float32", loss_chunk=16,
    )


ARCH = base.ArchSpec(
    arch_id=ARCH_ID,
    citation="hf:microsoft/Phi-3.5-MoE-instruct",
    kind="moe",
    make_config=make_config,
    make_smoke_config=make_smoke_config,
    engine="fedsgd",
    param_rules=base.transformer_param_rules(32, 8, moe=True),
    cache_rules=base.transformer_cache_rules(),
    long_policy="sw_variant",
    make_long_config=lambda: make_config(window=4096),
)
