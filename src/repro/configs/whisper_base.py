"""whisper-base [audio] — 6L (enc+dec) d_model=512 8H d_ff=2048
vocab=51865 — enc-dec; conv/mel frontend STUBBED (input_specs provides
frame embeddings — the assignment's carve-out). [arXiv:2212.04356]

Shapes map to the DECODER token axis (mechanical lowering; whisper's
designed decode context is 448 — positions wrap, noted in DESIGN.md).
long_500k SKIPPED: enc-dec with a bounded decoder context and full
attention; a 512k decode state is architecturally meaningless.
Attention params replicate (8 heads < model axis, 72M model).
"""
from repro.configs import base
from repro.models.encdec import EncDecConfig

ARCH_ID = "whisper-base"


def make_config() -> EncDecConfig:
    return EncDecConfig(
        name=ARCH_ID,
        enc_layers=6, dec_layers=6, d_model=512, n_heads=8, n_kv=8,
        head_dim=64, d_ff=2048, vocab=51865,
        max_source=1500, max_target=448,
        dtype="bfloat16", param_dtype="bfloat16",
    )


def make_smoke_config() -> EncDecConfig:
    return EncDecConfig(
        name=ARCH_ID + "-smoke",
        enc_layers=2, dec_layers=2, d_model=64, n_heads=4, n_kv=4,
        head_dim=16, d_ff=128, vocab=128, max_source=24, max_target=16,
        dtype="float32", param_dtype="float32", loss_chunk=8,
    )


ARCH = base.ArchSpec(
    arch_id=ARCH_ID,
    citation="arXiv:2212.04356",
    kind="audio",
    make_config=make_config,
    make_smoke_config=make_smoke_config,
    engine="fedavg",
    param_rules=base.audio_param_rules(),
    cache_rules=base.audio_cache_rules(),
    long_policy="skip",
    skip_notes=("enc-dec with full attention and a 448-token decoder "
                "design context; long_500k decode state is meaningless "
                "for this architecture (DESIGN.md §Arch-applicability)."),
)
