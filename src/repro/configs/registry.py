"""--arch <id> registry + per-(arch, shape) input-spec construction."""
from __future__ import annotations

import importlib

import jax

from repro.configs import base
from repro.configs.base import ArchSpec, InputShape

_MODULES = {
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi35_moe_42b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "command-r-35b": "repro.configs.command_r_35b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "whisper-base": "repro.configs.whisper_base",
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "rwkv6-1.6b": "repro.configs.rwkv6_1p6b",
    "rnnt-librispeech": "repro.configs.rnnt_librispeech",
}

ASSIGNED = [k for k in _MODULES if k != "rnnt-librispeech"]


def list_archs() -> list[str]:
    return list(_MODULES)


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).ARCH


def input_specs(arch: ArchSpec, shape: InputShape, cfg, bundle,
                n_client_shards: int = 16):
    """ShapeDtypeStruct stand-ins for every input of the lowered step.

    Returns (args_struct, specs_tree) where args_struct matches the
    step function's (batch,) / (cache, tokens, pos) arguments and
    specs_tree is the matching PartitionSpec intent tree.
    """
    from jax.sharding import PartitionSpec as P

    from repro.launch.sharding import make_param_specs

    if shape.kind == "train":
        K, S, b = base.round_layout(shape, n_client_shards, arch.engine)
        if arch.kind == "audio":
            batch = base.audio_train_batch(shape, K, S, b, cfg)
        elif arch.kind == "vlm":
            batch = base.vlm_train_batch(shape, K, S, b, cfg)
        elif arch.kind == "rnnt":
            batch = base.rnnt_train_batch(shape, K, S, b, cfg)
        else:
            batch = base.lm_train_batch(shape, K, S, b)
        return batch, base.batch_specs(batch)

    if shape.kind == "prefill":
        if arch.kind == "audio":
            batch = base.audio_prefill_batch(shape, cfg)
        elif arch.kind == "vlm":
            batch = base.vlm_prefill_batch(shape, cfg)
        else:
            batch = base.lm_prefill_batch(shape)
        return batch, base.batch_specs(batch)

    # decode: (cache, tokens, pos)
    long = shape.name == "long_500k"
    ring = False      # baseline: full-length cache, window masking
    cache = jax.eval_shape(
        lambda: bundle.init_cache(shape.global_batch, shape.seq_len, ring=ring))
    cache_specs = make_param_specs(cache, arch.cache_rules if not long
                                   else _long_rules(arch))
    tokens = base.sds((shape.global_batch, 1), "int32")
    pos = base.sds((), "int32")
    args = (cache, tokens, pos)
    specs = (cache_specs, P(base.BAT), P())
    return args, specs


def _long_rules(arch: ArchSpec):
    maker = {
        "dense": base.transformer_cache_rules,
        "moe": base.transformer_cache_rules,
        "vlm": base.transformer_cache_rules,
        "hybrid": base.hybrid_cache_rules,
        "ssm": base.rwkv_cache_rules,
        "audio": base.audio_cache_rules,
    }[arch.kind]
    return maker(long=True)
