"""rnnt-librispeech — the paper's own model (Fig. 1): ~122M-class
RNN-T — 8x LSTM audio encoder, 2x LSTM label encoder, joint dim 640,
4096 word-pieces, 128-dim log-mel inputs, SpecAugment + FVN.

Not part of the assigned 10-arch matrix; included as the paper-
faithful reproduction target (train shape only — RNN-T streaming
decode is the greedy loop in repro/models/rnnt.py, not a KV-cache
serve step). Engine: fedavg (the paper's setting: K up to 128
Librispeech speakers per round).
"""
from repro.asr.specaugment import SpecAugmentConfig
from repro.configs import base
from repro.models.rnnt import RNNTConfig

ARCH_ID = "rnnt-librispeech"


def make_config() -> RNNTConfig:
    return RNNTConfig(
        name=ARCH_ID,
        feat_dim=128, vocab=4096,
        enc_layers=8, enc_hidden=1152,
        pred_layers=2, pred_hidden=1152, pred_embed=512,
        joint_dim=640, time_stride=2,
        specaug=SpecAugmentConfig(),
        dtype="bfloat16", param_dtype="float32",
    )


def make_smoke_config() -> RNNTConfig:
    return RNNTConfig(
        name=ARCH_ID + "-smoke",
        feat_dim=16, vocab=64,
        enc_layers=2, enc_hidden=64,
        pred_layers=1, pred_hidden=64, pred_embed=32,
        joint_dim=48, time_stride=1,
        specaug=SpecAugmentConfig(freq_masks=1, freq_mask_width=4, time_masks=1),
        dtype="float32", param_dtype="float32",
    )


ARCH = base.ArchSpec(
    arch_id=ARCH_ID,
    citation="paper Fig.1 / He et al. 2019",
    kind="rnnt",
    make_config=make_config,
    make_smoke_config=make_smoke_config,
    engine="fedavg",
    param_rules=base.rnnt_param_rules(),
    cache_rules=[],
    long_policy="skip",
    skip_notes="ASR training model; serve shapes don't apply (DESIGN.md).",
)
