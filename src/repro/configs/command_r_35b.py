"""command-r-35b [dense] — 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000 — GQA, no-bias, parallel attn+FFN blocks, LayerNorm.
[hf:CohereForAI/c4ai-command-r-v01]

Engine: fedsgd + FSDP (35B). long_500k via sliding-window variant.
"""
from repro.configs import base
from repro.models.transformer import TransformerConfig

ARCH_ID = "command-r-35b"


def make_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=40, d_model=8192, n_heads=64, n_kv=8, head_dim=128,
        d_ff=22528, vocab=256000,
        norm="ln", parallel_block=True, use_bias=False,
        rope_theta=10000.0, act="silu",
        dtype="bfloat16", param_dtype="bfloat16",
        **kw,
    )


def make_smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2, d_model=128, n_heads=4, n_kv=2, head_dim=32,
        d_ff=256, vocab=128,
        norm="ln", parallel_block=True,
        dtype="float32", param_dtype="float32", loss_chunk=16,
    )


ARCH = base.ArchSpec(
    arch_id=ARCH_ID,
    citation="hf:CohereForAI/c4ai-command-r-v01",
    kind="dense",
    make_config=make_config,
    make_smoke_config=make_smoke_config,
    engine="fedsgd",
    param_rules=base.transformer_param_rules(64, 8),
    cache_rules=base.transformer_cache_rules(),
    long_policy="sw_variant",
    make_long_config=lambda: make_config(window=4096),
)
