"""qwen3-8b [dense] — 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936 — qk_norm, GQA. [hf:Qwen/Qwen3-8B]

Engine: fedavg (per-client replicas fit). long_500k via SW variant.
"""
from repro.configs import base
from repro.models.transformer import TransformerConfig

ARCH_ID = "qwen3-8b"


def make_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=36, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
        d_ff=12288, vocab=151936,
        qk_norm=True, rope_theta=1000000.0, act="silu",
        dtype="bfloat16", param_dtype="bfloat16",
        **kw,
    )


def make_smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2, d_model=128, n_heads=4, n_kv=2, head_dim=32,
        d_ff=256, vocab=128, qk_norm=True,
        dtype="float32", param_dtype="float32", loss_chunk=16,
    )


ARCH = base.ArchSpec(
    arch_id=ARCH_ID,
    citation="hf:Qwen/Qwen3-8B",
    kind="dense",
    make_config=make_config,
    make_smoke_config=make_smoke_config,
    engine="fedavg",
    param_rules=base.transformer_param_rules(32, 8),
    cache_rules=base.transformer_cache_rules(),
    long_policy="sw_variant",
    make_long_config=lambda: make_config(window=4096),
)
