"""zamba2-7b [hybrid] — 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention block.
[arXiv:2411.15242]

81 Mamba2 layers with the single shared attn+MLP block applied every 6
(13 groups + a 3-layer tail => 14 applications, one weight set).
long_500k native: SSM state is O(1) in context; the shared attention's
decode is linear per step. Engine: fedavg (6.8B fits a model group).
"""
from repro.configs import base
from repro.models.hybrid import HybridConfig

ARCH_ID = "zamba2-7b"


def make_config() -> HybridConfig:
    return HybridConfig(
        name=ARCH_ID,
        n_layers=81, d_model=3584, n_heads=32, n_kv=32, head_dim=112,
        d_ff=14336, vocab=32000, attn_every=6,
        ssm_state=64, ssm_headdim=64,
        dtype="bfloat16", param_dtype="bfloat16",
    )


def make_smoke_config() -> HybridConfig:
    return HybridConfig(
        name=ARCH_ID + "-smoke",
        n_layers=8, d_model=128, n_heads=4, n_kv=4, head_dim=32,
        d_ff=256, vocab=128, attn_every=3,
        ssm_state=16, ssm_headdim=32,
        dtype="float32", param_dtype="float32", loss_chunk=16,
    )


ARCH = base.ArchSpec(
    arch_id=ARCH_ID,
    citation="arXiv:2411.15242",
    kind="hybrid",
    make_config=make_config,
    make_smoke_config=make_smoke_config,
    engine="fedavg",
    param_rules=base.hybrid_param_rules(),
    cache_rules=base.hybrid_cache_rules(),
    long_policy="native",
)
