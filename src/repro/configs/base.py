"""ArchSpec plumbing: input shapes, batch structs, cache sharding rules.

The four assigned input shapes; decode shapes lower ``serve_step`` (one
token vs. a seq_len cache), train_4k lowers ``fed_round_step`` (a full
federated round — that IS the paper's training step), prefill_32k
lowers ``prefill_step``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.plan import FederatedPlan


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass
class ArchSpec:
    arch_id: str
    citation: str
    kind: str                                    # dense|moe|hybrid|ssm|audio|vlm|rnnt
    make_config: Callable[[], Any]
    make_smoke_config: Callable[[], Any]
    engine: str                                  # fedavg | fedsgd
    param_rules: Sequence[tuple[str, P]]
    cache_rules: Sequence[tuple[str, P]]
    long_policy: str = "native"                  # native | sw_variant | skip
    make_long_config: Optional[Callable[[], Any]] = None
    skip_notes: str = ""

    def config_for(self, shape_name: str):
        if shape_name == "long_500k" and self.make_long_config is not None:
            return self.make_long_config()
        return self.make_config()


def default_plan(engine: str, clients: int) -> FederatedPlan:
    """The dry-run training plan: K = client shards, 2 local steps for
    the fedavg engine (exercises the local scan), 1 for fedsgd."""
    return FederatedPlan(
        clients_per_round=clients,
        local_batch_size=8,
        engine=engine,
        server_optimizer="adam",
    )


def round_layout(shape: InputShape, n_client_shards: int, engine: str):
    """(K, S_local, b) with K*S*b == global_batch."""
    K = n_client_shards
    gb = shape.global_batch
    assert gb % K == 0, (gb, K)
    per_client = gb // K
    if engine == "fedsgd":
        return K, 1, per_client
    b = min(8, per_client)
    while per_client % b:
        b -= 1
    return K, per_client // b, b


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


# --------------------------------------------------- train batch structs

def lm_train_batch(shape: InputShape, K: int, S: int, b: int, dtype="bfloat16"):
    return {
        "tokens": sds((K, S, b, shape.seq_len), "int32"),
        "weight": sds((K, S, b), "float32"),
    }


def audio_train_batch(shape: InputShape, K: int, S: int, b: int, cfg):
    return {
        "frames": sds((K, S, b, cfg.max_source, cfg.d_model), cfg.dtype),
        "tokens": sds((K, S, b, shape.seq_len), "int32"),
        "weight": sds((K, S, b), "float32"),
    }


def vlm_train_batch(shape: InputShape, K: int, S: int, b: int, cfg):
    n_img = cfg.n_img_tokens
    return {
        "image_embeds": sds((K, S, b, n_img, cfg.vit_dim), cfg.lm.dtype),
        "tokens": sds((K, S, b, shape.seq_len - n_img), "int32"),
        "weight": sds((K, S, b), "float32"),
    }


def rnnt_train_batch(shape: InputShape, K: int, S: int, b: int, cfg):
    t = shape.seq_len            # audio frames
    u = max(32, shape.seq_len // 32)
    return {
        "features": sds((K, S, b, t, cfg.feat_dim), "float32"),
        "labels": sds((K, S, b, u), "int32"),
        "frame_len": sds((K, S, b), "int32"),
        "label_len": sds((K, S, b), "int32"),
        "weight": sds((K, S, b), "float32"),
    }


# --------------------------------------------------- serve batch structs

def lm_prefill_batch(shape: InputShape):
    return {"tokens": sds((shape.global_batch, shape.seq_len), "int32")}


def audio_prefill_batch(shape: InputShape, cfg):
    return {
        "frames": sds((shape.global_batch, cfg.max_source, cfg.d_model), cfg.dtype),
        "tokens": sds((shape.global_batch, shape.seq_len), "int32"),
    }


def vlm_prefill_batch(shape: InputShape, cfg):
    return {
        "image_embeds": sds((shape.global_batch, cfg.n_img_tokens, cfg.vit_dim), cfg.lm.dtype),
        "tokens": sds((shape.global_batch, shape.seq_len - cfg.n_img_tokens), "int32"),
    }


# --------------------------------------------------- shared spec rules

BAT = ("pod", "data")            # sanitized down to ("data",) on single-pod


def batch_specs(batch_struct, leading_axis=BAT):
    """Shard the leading client/batch axis of every input leaf."""
    return jax.tree.map(lambda _: P(leading_axis), batch_struct)


def transformer_cache_rules(long: bool = False) -> list:
    s_ax = ("pod", "data", "model") if long else ("model",)
    bat = None if long else BAT
    return [
        (r"(layers|dense_layers)/(k|v)$", P(None, bat, s_ax)),
        (r"(layers|dense_layers)/(ckv|krope)$", P(None, bat, s_ax)),
    ]


def hybrid_cache_rules(long: bool = False) -> list:
    s_ax = ("pod", "data", "model") if long else ("model",)
    bat = None if long else BAT
    return [
        (r"attn_(k|v)$", P(None, bat, s_ax)),
        (r"groups/ssm$", P(None, None, bat, "model")),
        (r"tail/ssm$", P(None, bat, "model")),
        (r"groups/conv/x$", P(None, None, bat, None, "model")),
        (r"tail/conv/x$", P(None, bat, None, "model")),
        (r"conv/bc$", P()),
    ]


def rwkv_cache_rules(long: bool = False) -> list:
    bat = None if long else BAT
    return [
        (r"tm/S$", P(None, bat, "model")),
        (r"(tm|cm)/last$", P(None, bat, "model")),
    ]


def audio_cache_rules(long: bool = False) -> list:
    bat = None if long else BAT
    return [
        (r"self_(k|v)$", P(None, bat, ("model",))),
        (r"cross_(k|v)$", P(None, bat, None)),
    ]


# --------------------------------------------------- param spec rules

MODEL_AXIS_SIZE = 16             # model axis of both production meshes


def transformer_param_rules(n_heads: int, n_kv: int, *, mla: bool = False,
                            moe: bool = False) -> list:
    """Head-aligned tensor parallelism: shard q/o when heads divide the
    model axis, k/v when kv-heads do (else Megatron-style replication);
    FFN hidden and vocab always shard. Leading Nones cover the layer
    stack axis."""
    rules = [
        # vocab-sharded embedding: a d-sharded table would leak feature
        # sharding into the residual stream and GSPMD then partial-sums
        # full activations per layer (observed; see EXPERIMENTS.md §Perf)
        (r"(^|/)embed$", P("model", None)),
        (r"(^|/)unembed$", P(None, "model")),
    ]
    layer = r"(layers|dense_layers)"
    if mla:
        rules += [
            (layer + r"/attn/wq$", P(None, None, "model")),
            (layer + r"/attn/w_(uk|uv)$", P(None, None, "model")),
            (layer + r"/attn/wo$", P(None, "model", None)),
            (layer + r"/attn/(w_dkv|w_krope|kv_norm)$", P()),
        ]
    else:
        if n_heads % MODEL_AXIS_SIZE == 0:
            rules += [
                (layer + r"/attn/wq$", P(None, None, "model")),
                (layer + r"/attn/wo$", P(None, "model", None)),
            ]
        if n_kv % MODEL_AXIS_SIZE == 0:
            rules += [
                (layer + r"/attn/w(k|v)$", P(None, None, "model")),
            ]
    if moe:
        rules += [
            (layer + r"/moe/w_(gate|up)$", P(None, "model", None, None)),
            (layer + r"/moe/w_down$", P(None, "model", None, None)),
            (layer + r"/moe/shared/w_(gate|up)$", P(None, None, "model")),
            (layer + r"/moe/shared/w_down$", P(None, "model", None)),
            (layer + r"/moe/router$", P()),
        ]
    rules += [
        (layer + r"/mlp/w_(gate|up)$", P(None, None, "model")),
        (layer + r"/mlp/w_down$", P(None, "model", None)),
    ]
    return rules


def hybrid_param_rules() -> list:
    """zamba2: groups params have two leading stack axes (G, E)."""
    return [
        (r"(^|/)embed$", P("model", None)),
        (r"(^|/)unembed$", P(None, "model")),
        (r"shared_attn/attn/wq$", P(None, "model")),
        (r"shared_attn/attn/w(k|v)$", P(None, "model")),
        (r"shared_attn/attn/wo$", P("model", None)),
        (r"shared_attn/mlp/w_(gate|up)$", P(None, "model")),
        (r"shared_attn/mlp/w_down$", P("model", None)),
        (r"groups/.*/mamba/in_(z|x|dt)$", P(None, None, None, "model")),
        (r"groups/.*/mamba/in_bc$", P()),
        (r"groups/.*/mamba/conv_x_w$", P(None, None, None, "model")),
        (r"groups/.*/mamba/(conv_x_b|norm)$", P(None, None, "model")),
        (r"groups/.*/mamba/(A_log|D|dt_bias)$", P(None, None, "model")),
        (r"groups/.*/mamba/out_proj$", P(None, None, "model", None)),
        (r"tail/.*/mamba/in_(z|x|dt)$", P(None, None, "model")),
        (r"tail/.*/mamba/in_bc$", P()),
        (r"tail/.*/mamba/conv_x_w$", P(None, None, "model")),
        (r"tail/.*/mamba/(conv_x_b|norm)$", P(None, "model")),
        (r"tail/.*/mamba/(A_log|D|dt_bias)$", P(None, "model")),
        (r"tail/.*/mamba/out_proj$", P(None, "model", None)),
    ]


def rwkv_param_rules() -> list:
    return [
        (r"(^|/)embed$", P("model", None)),
        (r"(^|/)unembed$", P(None, "model")),
        (r"layers/(wr|wk|wv|wg|cr)$", P(None, None, "model")),
        (r"layers/(w_out|cv)$", P(None, "model", None)),
        (r"layers/ck$", P(None, None, "model")),
        (r"layers/wB$", P(None, None, "model")),
        (r"layers/wA$", P()),
        (r"layers/(u|gn_scale|gn_bias)$", P(None, "model")),
    ]


def audio_param_rules() -> list:
    """whisper-base: 8 heads < model axis -> attention replicated
    (72M model; Megatron fallback); FFN + embedding-d sharded."""
    return [
        (r"tok_embed$", P("model", None)),
        (r"(enc|dec)_layers/mlp/w_up$", P(None, None, "model")),
        (r"(enc|dec)_layers/mlp/w_down$", P(None, "model", None)),
    ]


def rnnt_param_rules() -> list:
    """122M model: LSTMs replicated (recurrent deps), vocab-sharded joint."""
    return [
        (r"joint_out$", P(None, "model")),
        (r"joint_enc$", P(None, "model")),
        (r"joint_pred$", P(None, "model")),
    ]


def prefix_rules(prefix: str, rules: list) -> list:
    return [(prefix + rx if rx.startswith("(^|/)") is False else rx, sp)
            for rx, sp in rules]
