"""Synthetic token-LM client data for exercising the federated engine on
the assigned (non-ASR) architectures: per-client Dirichlet-skewed token
distributions give a language-model analogue of speaker non-IID-ness."""
from __future__ import annotations

import numpy as np


def synthetic_lm_clients(
    num_clients: int,
    vocab_size: int,
    seq_len: int,
    examples_per_client: int,
    concentration: float = 0.5,
    seed: int = 0,
):
    """Returns tokens (C, N, S) int32 with per-client unigram skew.

    Sequences follow a shared bigram backbone (so there is signal to
    learn) re-weighted by a per-client unigram prior (the non-IID part).
    """
    rng = np.random.default_rng(seed)
    V = vocab_size
    ranks = np.arange(1, V + 1)
    base = (1.0 / ranks) / (1.0 / ranks).sum()
    # shared deterministic "grammar": next-token preference table
    shift = rng.integers(1, V, size=V)
    out = np.zeros((num_clients, examples_per_client, seq_len), np.int32)
    for c in range(num_clients):
        crng = np.random.default_rng(seed * 9176 + c + 1)
        prior = crng.dirichlet(base * V * concentration)
        for i in range(examples_per_client):
            t = crng.choice(V, p=prior)
            for s in range(seq_len):
                out[c, i, s] = t
                # mix grammar-following with client-prior resampling
                if crng.random() < 0.7:
                    t = (t + shift[t]) % V
                else:
                    t = crng.choice(V, p=prior)
    return out


def synthetic_lm_batch(batch: int, seq_len: int, vocab_size: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab_size, size=(batch, seq_len)).astype(np.int32)


def label_shuffle(labels, label_len, valid, rng) -> int:
    """Data-plane adversary: permute one client's (labels, label_len)
    rows among its valid example slots, IN PLACE, so features no longer
    match their transcripts — the client then trains honestly on
    poisoned pairs (the gradient, not the wire, carries the damage).

    ``labels`` is (E, U), ``label_len`` (E,), ``valid`` an (E,) bool
    mask of real (non-padding) slots: only valid rows move, so padded
    zero-length transcripts never land on real features (which would
    change the loss masking, not just the supervision). Returns the
    number of shuffled examples (0 when fewer than two are valid —
    nothing to permute).
    """
    pos = np.flatnonzero(valid)
    if pos.size < 2:
        return 0
    perm = rng.permutation(pos.size)
    labels[pos] = labels[pos[perm]]
    label_len[pos] = label_len[pos[perm]]
    return int(pos.size)
