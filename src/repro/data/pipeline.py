"""Federated round batching: client selection, data limiting, packing.

A federated round batch is a fixed-shape pytree:
    features : (K, S, B, T, F)   S = local steps, B = local batch
    labels   : (K, S, B, U)
    label_len, frame_len : (K, S, B)
    mask     : (K, S, B)  1.0 for real examples, 0.0 for padding
    n_k      : (K,)       number of real examples per client (paper's n_k)

The *data limit* L (paper §4.2.1) caps how many examples a client
contributes in one round — the paper's dial between non-IID (L=None)
and near-IID (L=1). The full per-speaker dataset is still traversed
over multiple rounds via per-client cursors.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class RoundBatch:
    features: np.ndarray
    labels: np.ndarray
    label_len: np.ndarray
    frame_len: np.ndarray
    mask: np.ndarray
    n_k: np.ndarray

    def tree(self):
        return dataclasses.asdict(self)


class FederatedSampler:
    """Selects K clients per round and packs their (possibly limited)
    local datasets into fixed-shape round batches."""

    def __init__(
        self,
        corpus,
        clients_per_round: int,
        local_batch_size: int,
        data_limit: Optional[int] = None,
        local_epochs: int = 1,
        seed: int = 0,
        max_steps=None,
    ):
        self.corpus = corpus
        self.K = clients_per_round
        self.b = local_batch_size
        self.data_limit = data_limit
        self.local_epochs = local_epochs
        self.rng = np.random.default_rng(seed)
        # Per-client cursors so data-limited rounds still traverse all data.
        self._cursors = np.zeros(corpus.num_speakers, np.int64)
        self._orders = [
            np.random.default_rng(seed + 7 * i).permutation(s["n"])
            for i, s in enumerate(corpus.speakers)
        ]
        # Fixed max local steps for jit-stable shapes.
        if data_limit is not None:
            n_max = data_limit
        else:
            n_max = int(max(s["n"] for s in corpus.speakers))
        self.steps = max(1, int(np.ceil(local_epochs * n_max / self.b)))
        if max_steps is not None:
            self.steps = min(self.steps, max_steps)

    def _client_examples(self, cid: int):
        sp = self.corpus.speakers[cid]
        n = sp["n"]
        order = self._orders[cid]
        limit = min(self.data_limit, n) if self.data_limit is not None else n
        idx = []
        for _ in range(limit):
            c = self._cursors[cid]
            if c % n == 0 and c > 0:
                # reshuffle each full pass
                self._orders[cid] = self.rng.permutation(n)
                order = self._orders[cid]
            idx.append(order[c % n])
            self._cursors[cid] += 1
        return np.asarray(idx, np.int64)

    def next_round(self) -> RoundBatch:
        K, b, S = self.K, self.b, self.steps
        chosen = self.rng.choice(self.corpus.num_speakers, size=K, replace=False)
        c0 = self.corpus.speakers[0]
        T, F = c0["features"].shape[1:]
        U = c0["labels"].shape[1]
        feats = np.zeros((K, S, b, T, F), np.float32)
        labels = np.zeros((K, S, b, U), np.int32)
        label_len = np.zeros((K, S, b), np.int32)
        frame_len = np.zeros((K, S, b), np.int32)
        mask = np.zeros((K, S, b), np.float32)
        n_k = np.zeros((K,), np.float32)
        for j, cid in enumerate(chosen):
            idx = self._client_examples(int(cid))
            idx = np.tile(idx, self.local_epochs)[: S * b]
            n_k[j] = len(idx)
            sp = self.corpus.speakers[int(cid)]
            for e, ei in enumerate(idx):
                s, bi = divmod(e, b)
                feats[j, s, bi] = sp["features"][ei]
                labels[j, s, bi] = sp["labels"][ei]
                label_len[j, s, bi] = sp["label_len"][ei]
                frame_len[j, s, bi] = sp["frame_len"][ei]
                mask[j, s, bi] = 1.0
        return RoundBatch(feats, labels, label_len, frame_len, mask, n_k)


def pack_round(examples: dict, K: int, steps: int, batch: int) -> RoundBatch:
    """Pack a flat example dict into a (K, steps, batch, ...) round —
    used for IID baselines where examples are drawn from the global pool."""
    need = K * steps * batch
    n = examples["labels"].shape[0]
    idx = np.resize(np.arange(n), need)
    feats = examples["features"][idx].reshape(K, steps, batch, *examples["features"].shape[1:])
    labels = examples["labels"][idx].reshape(K, steps, batch, -1)
    label_len = examples["label_len"][idx].reshape(K, steps, batch)
    frame_len = examples["frame_len"][idx].reshape(K, steps, batch)
    mask = np.ones((K, steps, batch), np.float32)
    n_k = np.full((K,), steps * batch, np.float32)
    return RoundBatch(feats, labels, label_len, frame_len, mask, n_k)
