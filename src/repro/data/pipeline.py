"""Federated round batching: client selection, data limiting, packing.

A federated round batch is a fixed-shape pytree:
    features : (K, S, B, T, F)   S = local steps, B = local batch
    labels   : (K, S, B, U)
    label_len, frame_len : (K, S, B)
    mask     : (K, S, B)  1.0 for real examples, 0.0 for padding
    n_k      : (K,)       number of real examples per client (paper's n_k)

The *data limit* L (paper §4.2.1) caps how many examples a client
contributes in one round — the paper's dial between non-IID (L=None)
and near-IID (L=1). The full per-speaker dataset is still traversed
over multiple rounds via per-client cursors.

Packing is pure numpy fancy-indexing against the corpus arena
(one gather per field, no per-example Python loop); the original
per-example loop survives behind ``legacy=True`` solely as the parity
oracle for tests/benchmarks and will be removed once a few PRs of CI
history have exercised the vectorized path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.data.strategies import get_strategy
from repro.data.synthetic import label_shuffle


@dataclasses.dataclass
class RoundBatch:
    features: np.ndarray
    labels: np.ndarray
    label_len: np.ndarray
    frame_len: np.ndarray
    mask: np.ndarray
    n_k: np.ndarray

    def tree(self):
        return dataclasses.asdict(self)

    def engine_batch(self) -> dict:
        """The round engine's input layout (mask enters as "weight")."""
        return {"features": self.features, "labels": self.labels,
                "frame_len": self.frame_len, "label_len": self.label_len,
                "weight": self.mask}

    def pad_steps(self, steps: int) -> "RoundBatch":
        """Append weight-0 local steps up to ``steps`` (exact no-ops
        under the engine's n_k weighting) so differently-shaped rounds
        can share one compiled round fn."""
        S = self.mask.shape[1]
        if steps <= S:
            return self

        def pad(a):
            extra = np.zeros((a.shape[0], steps - S) + a.shape[2:], a.dtype)
            return np.concatenate([a, extra], axis=1)

        return RoundBatch(pad(self.features), pad(self.labels),
                          pad(self.label_len), pad(self.frame_len),
                          pad(self.mask), self.n_k)


class FederatedSampler:
    """Selects K clients per round and packs their (possibly limited)
    local datasets into fixed-shape round batches."""

    def __init__(
        self,
        corpus,
        clients_per_round: int,
        local_batch_size: int,
        data_limit: Optional[int] = None,
        local_epochs: int = 1,
        seed: int = 0,
        max_steps=None,
        steps: Optional[int] = None,
        strategy: str = "uniform",
        legacy: bool = False,
        label_shuffle_rate: float = 0.0,
    ):
        self.corpus = corpus
        self.K = clients_per_round
        self.b = local_batch_size
        self.data_limit = data_limit
        self.local_epochs = local_epochs
        self.rng = np.random.default_rng(seed)
        self.legacy = legacy
        self._select = get_strategy(strategy)
        # Data-plane adversary (repro.core.corruption "label_shuffle"):
        # each round, Bernoulli(rate)-selected clients get their round
        # labels permuted among their real examples. A dedicated RNG
        # keeps the selection/packing stream byte-identical to an
        # uncorrupted run at rate 0.
        self.label_shuffle_rate = float(label_shuffle_rate)
        self._corrupt_rng = np.random.default_rng((seed + 1) * 0xC0FFEE)
        self.corrupted_counts: list = []
        # Per-client cursors so data-limited rounds still traverse all
        # data. LAZY dicts keyed by client id: under a VirtualPopulation
        # N >> K and only visited clients may cost memory (each order is
        # seeded by its own id, so lazy creation is bit-identical to the
        # historical eager list for plain corpora).
        self._seed = seed
        self._cursors: dict = {}
        self._orders: dict = {}
        self._base_counts, self._base_of = self._corpus_counts(corpus)
        if legacy and self._base_of is not None:
            raise ValueError(
                "the legacy per-example packer is the plain-corpus parity "
                "oracle; virtual populations use the vectorized path"
            )
        # Fixed max local steps for jit-stable shapes. ``steps`` forces
        # an exact S (sweep runners pad every point to one shape so a
        # single compiled round fn serves the whole grid).
        self.steps = (int(steps) if steps is not None else
                      self.natural_steps(corpus, local_batch_size,
                                         data_limit=data_limit,
                                         local_epochs=local_epochs,
                                         max_steps=max_steps))

    @staticmethod
    def _corpus_counts(corpus):
        """(counts histogram, virtual->base map or None). The histogram
        is indexed by BASE speaker row; plain corpora are their own
        base (identity, ``base_of`` None)."""
        base_of = getattr(corpus, "base_of", None)
        counts = getattr(corpus, "base_counts", None)
        if counts is None:
            counts = getattr(corpus, "counts", None)
        if counts is None:
            counts = np.array([s["n"] for s in corpus.speakers], np.int64)
        return np.asarray(counts, np.int64), base_of

    @staticmethod
    def natural_steps(corpus, local_batch_size: int,
                      data_limit: Optional[int] = None, local_epochs: int = 1,
                      max_steps: Optional[int] = None) -> int:
        """The local-step count a round needs to hold every selected
        client's (possibly limited) contribution — the single source of
        truth for batch shapes AND for CFMQ mu accounting (sweeps)."""
        if data_limit is not None:
            n_max = data_limit
        else:
            counts, _ = FederatedSampler._corpus_counts(corpus)
            n_max = int(counts.max())
        steps = max(1, int(np.ceil(local_epochs * n_max / local_batch_size)))
        if max_steps is not None:
            steps = min(steps, max_steps)
        return steps

    def _count(self, cid: int) -> int:
        """Example count of one client (virtual ids map to their base
        speaker's histogram slot — a clone holds the same data)."""
        base = cid % len(self._base_counts) if self._base_of is not None else cid
        return int(self._base_counts[base])

    def _order(self, cid: int) -> np.ndarray:
        """The client's live shuffle order, created on first visit from
        its id-seeded generator (clones of one speaker get independent
        orders; plain corpora get the historical eager order bitwise)."""
        o = self._orders.get(cid)
        if o is None:
            o = np.random.default_rng(self._seed + 7 * cid).permutation(self._count(cid))
            self._orders[cid] = o
        return o

    def _client_indices(self, cid: int) -> np.ndarray:
        """This round's example indices for one client (length = limit),
        advancing the cursor with a reshuffle at each full pass. Loops
        over *passes* (segments), never over examples."""
        n = self._count(cid)
        limit = min(self.data_limit, n) if self.data_limit is not None else n
        c = int(self._cursors.get(cid, 0))
        order = self._order(cid)
        pos = c % n
        if limit <= n - pos and not (pos == 0 and c > 0):
            # fast path: the whole contribution sits inside the current
            # pass — return a view of the live order, no copies
            self._cursors[cid] = c + limit
            return order[pos:pos + limit]
        out = np.empty(limit, np.int64)
        filled = 0
        while filled < limit:
            if c % n == 0 and c > 0:
                order = self.rng.permutation(n)
                self._orders[cid] = order
            take = min(n - c % n, limit - filled)
            out[filled:filled + take] = order[c % n:c % n + take]
            filled += take
            c += take
        self._cursors[cid] = c
        return out

    def _gather_indices(self, chosen: np.ndarray):
        """(K, S*b) example-index matrix (-1 = padding) + per-client n_k.

        The K-iteration loop only advances cursors/reshuffles (which is
        inherently sequential in the RNG stream); all example payloads
        move in the single arena gather in ``next_round``."""
        E = self.steps * self.b
        ex = np.full((len(chosen), E), -1, np.int64)
        n_k = np.zeros((len(chosen),), np.float32)
        for j, cid in enumerate(chosen):
            idx = self._client_indices(int(cid))
            if self.local_epochs > 1:
                idx = np.tile(idx, self.local_epochs)
            m = min(len(idx), E)
            ex[j, :m] = idx[:m]
            n_k[j] = m
        return ex, n_k

    def _shuffle_labels(self, rb: RoundBatch) -> RoundBatch:
        """Apply the label_shuffle adversary to Bernoulli-selected
        clients, in place on the freshly-packed (copied) arrays; the
        realized corrupted-client count is appended per round so
        drivers can report it next to the in-graph corruption metric."""
        K = rb.labels.shape[0]
        hit = self._corrupt_rng.random(K) < self.label_shuffle_rate
        # (K, S, b, ...) -> flat (K, S*b, ...) views onto the same memory
        labels = rb.labels.reshape(K, -1, rb.labels.shape[-1])
        label_len = rb.label_len.reshape(K, -1)
        mask = rb.mask.reshape(K, -1)
        for k in np.flatnonzero(hit):
            label_shuffle(labels[k], label_len[k], mask[k] > 0,
                          self._corrupt_rng)
        self.corrupted_counts.append(int(hit.sum()))
        return rb

    def next_round(self) -> RoundBatch:
        rb = self._next_round()
        if self.label_shuffle_rate > 0.0:
            rb = self._shuffle_labels(rb)
        return rb

    def _next_round(self) -> RoundBatch:
        K, b, S = self.K, self.b, self.steps
        chosen = np.asarray(self._select(self.rng, self.corpus, K), np.int64)
        if self.legacy:
            return self._next_round_legacy(chosen)
        ex, n_k = self._gather_indices(chosen)
        pad = ex < 0
        np.copyto(ex, 0, where=pad)                  # safe gather index
        # (K, 1) arena rows: virtual client ids gather their base
        # speaker's row — the only O(K) touch of the population
        base = self._base_of(chosen) if self._base_of is not None else chosen
        rows = np.asarray(base, np.int64)[:, None]
        c = self.corpus
        # fancy-indexing copies, so padded slots can be zeroed in place
        feats = c.arena_features[rows, ex]           # (K, S*b, T, F)
        labels = c.arena_labels[rows, ex]
        label_len = c.arena_label_len[rows, ex]
        frame_len = c.arena_frame_len[rows, ex]
        if pad.any():
            feats[pad] = 0.0
            labels[pad] = 0
            label_len[pad] = 0
            frame_len[pad] = 0
        mask = (~pad).astype(np.float32)
        T, F = feats.shape[2:]
        U = labels.shape[-1]
        return RoundBatch(
            feats.reshape(K, S, b, T, F),
            labels.reshape(K, S, b, U),
            label_len.reshape(K, S, b),
            frame_len.reshape(K, S, b),
            mask.reshape(K, S, b),
            n_k,
        )

    # ------------------------------------------------------------------
    # Legacy per-example packing: parity oracle only (see module doc).
    # ------------------------------------------------------------------

    def _client_examples(self, cid: int):
        n = self._count(cid)
        order = self._order(cid)
        limit = min(self.data_limit, n) if self.data_limit is not None else n
        idx = []
        for _ in range(limit):
            c = self._cursors.get(cid, 0)
            if c % n == 0 and c > 0:
                # reshuffle each full pass
                self._orders[cid] = self.rng.permutation(n)
                order = self._orders[cid]
            idx.append(order[c % n])
            self._cursors[cid] = c + 1
        return np.asarray(idx, np.int64)

    def _next_round_legacy(self, chosen) -> RoundBatch:
        K, b, S = self.K, self.b, self.steps
        c0 = self.corpus.speakers[0]
        T, F = c0["features"].shape[1:]
        U = c0["labels"].shape[1]
        feats = np.zeros((K, S, b, T, F), np.float32)
        labels = np.zeros((K, S, b, U), np.int32)
        label_len = np.zeros((K, S, b), np.int32)
        frame_len = np.zeros((K, S, b), np.int32)
        mask = np.zeros((K, S, b), np.float32)
        n_k = np.zeros((K,), np.float32)
        for j, cid in enumerate(chosen):
            idx = self._client_examples(int(cid))
            idx = np.tile(idx, self.local_epochs)[: S * b]
            n_k[j] = len(idx)
            sp = self.corpus.speakers[int(cid)]
            for e, ei in enumerate(idx):
                s, bi = divmod(e, b)
                feats[j, s, bi] = sp["features"][ei]
                labels[j, s, bi] = sp["labels"][ei]
                label_len[j, s, bi] = sp["label_len"][ei]
                frame_len[j, s, bi] = sp["frame_len"][ei]
                mask[j, s, bi] = 1.0
        return RoundBatch(feats, labels, label_len, frame_len, mask, n_k)


def per_client_eval_batch(corpus, client_ids, n: int = 4) -> dict:
    """A stacked per-client eval batch for the per-client evaluation
    plane (``repro.core.clienteval``): each tracked client's first
    ``n`` arena examples, in the engine-batch layout with a leading
    client axis —

        features : (C, n, T, F)    labels : (C, n, U)
        frame_len, label_len, weight : (C, n)

    The FIRST examples, not a draw: the panel must measure the same
    utterances every round so per-client curves move only because the
    model moved. Clients with fewer than ``n`` examples pad with
    weight-0 slots (clipped gather, then zeroed). Virtual client ids
    gather their base speaker's arena row."""
    ids = np.asarray(client_ids, np.int64)
    base_of = getattr(corpus, "base_of", None)
    base = np.asarray(base_of(ids) if base_of is not None else ids, np.int64)
    counts = np.asarray(
        getattr(corpus, "base_counts", None)
        if getattr(corpus, "base_counts", None) is not None
        else corpus.counts,
        np.int64,
    )[base]
    cols = np.arange(n, dtype=np.int64)[None, :]
    pad = cols >= counts[:, None]
    ex = np.minimum(cols, np.maximum(counts[:, None] - 1, 0))
    rows = base[:, None]
    feats = corpus.arena_features[rows, ex]
    labels = corpus.arena_labels[rows, ex]
    label_len = corpus.arena_label_len[rows, ex]
    frame_len = corpus.arena_frame_len[rows, ex]
    if pad.any():
        feats[pad] = 0.0
        labels[pad] = 0
        label_len[pad] = 0
        frame_len[pad] = 0
    return {
        "features": feats,
        "labels": labels,
        "frame_len": frame_len,
        "label_len": label_len,
        "weight": (~pad).astype(np.float32),
    }


def pack_round(examples: dict, K: int, steps: int, batch: int) -> RoundBatch:
    """Pack a flat example dict into a (K, steps, batch, ...) round —
    used for IID baselines where examples are drawn from the global pool."""
    need = K * steps * batch
    n = examples["labels"].shape[0]
    idx = np.resize(np.arange(n), need)
    feats = examples["features"][idx].reshape(K, steps, batch, *examples["features"].shape[1:])
    labels = examples["labels"][idx].reshape(K, steps, batch, -1)
    label_len = examples["label_len"][idx].reshape(K, steps, batch)
    frame_len = examples["frame_len"][idx].reshape(K, steps, batch)
    mask = np.ones((K, steps, batch), np.float32)
    n_k = np.full((K,), steps * batch, np.float32)
    return RoundBatch(feats, labels, label_len, frame_len, mask, n_k)
