"""Data substrate: synthetic speaker-split corpora + federated round batching."""
from repro.data.corpus import SpeakerCorpus, CorpusConfig, VirtualPopulation, make_speaker_corpus
from repro.data.pipeline import (
    RoundBatch,
    FederatedSampler,
    pack_round,
    per_client_eval_batch,
)
from repro.data.prefetch import PrefetchIterator, round_batches
from repro.data.strategies import available_strategies, get_strategy, register_strategy
from repro.data.synthetic import label_shuffle, synthetic_lm_clients, synthetic_lm_batch

__all__ = [
    "SpeakerCorpus",
    "CorpusConfig",
    "VirtualPopulation",
    "make_speaker_corpus",
    "RoundBatch",
    "FederatedSampler",
    "pack_round",
    "per_client_eval_batch",
    "PrefetchIterator",
    "round_batches",
    "available_strategies",
    "get_strategy",
    "register_strategy",
    "synthetic_lm_clients",
    "synthetic_lm_batch",
    "label_shuffle",
]
