"""Synthetic speaker-split ASR corpus (the Librispeech stand-in).

The paper trains on Librispeech split by its 2338 speakers; speaker
splits are non-IID through differences in voice, vocabulary, recording
quality and utterance counts (paper Fig. 2 shows a roughly log-normal
utterance histogram). No audio corpus is available offline (repro band
2/5 — data gate), so we *simulate the gate* with a generator that
reproduces each of those non-IID factors with a controllable strength:

- voice / recording quality -> per-speaker additive bias + channel gain
  in log-mel feature space,
- vocabulary               -> per-speaker Dirichlet skew over the
  word-piece unigram distribution,
- utterance counts          -> log-normal per-speaker example counts.

Labels are word-piece id sequences; features are generated from the
labels through a *shared* random emission codebook (token -> a few
frames of log-mel), so the token<->acoustics mapping is learnable and
the IID-vs-non-IID quality gap is measurable, mirroring the paper's
E0-vs-E1 contrast qualitatively.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    num_speakers: int = 64
    vocab_size: int = 64           # word-pieces (paper: 4096)
    feat_dim: int = 16             # log-mel bins (paper: 128)
    frames_per_token: int = 2      # emission length per word-piece
    min_label_len: int = 4
    max_label_len: int = 12
    mean_utterances: float = 40.0  # log-normal mean (Fig. 2 shape)
    utterance_sigma: float = 0.6
    # non-IID strength dials
    speaker_bias_std: float = 1.0      # voice / channel offset strength
    speaker_gain_std: float = 0.15     # recording-quality gain spread
    vocab_concentration: float = 0.5   # Dirichlet conc.; small => skewed
    noise_std: float = 0.3             # per-frame acoustic noise
    seed: int = 0


class SpeakerCorpus:
    """Container of per-speaker (features, labels) example lists.

    All examples live in one padded arena built once at construction —
    (num_speakers, n_max, ...) arrays — so the federated sampler packs
    round batches by pure fancy-indexing with no per-example Python
    loop. ``speakers[i]`` entries are views into the arena rows:
      features: (n_i, T_max, feat_dim) float32
      labels:   (n_i, U_max)           int32   (0 is blank / pad)
      label_len:(n_i,)                 int32
      frame_len:(n_i,)                 int32
    """

    def __init__(self, cfg: CorpusConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V, F, r = cfg.vocab_size, cfg.feat_dim, cfg.frames_per_token
        self.t_max = cfg.max_label_len * r
        self.u_max = cfg.max_label_len

        # Shared emission codebook: token -> r frames of log-mel.
        self.codebook = rng.normal(0.0, 1.0, size=(V, r, F)).astype(np.float32)
        # Global word-piece unigram (zipf-ish), excluding blank id 0.
        ranks = np.arange(1, V)
        base_p = 1.0 / ranks
        self.base_unigram = base_p / base_p.sum()

        # Pass 1: per-speaker metadata draws. Each speaker has its own
        # generator, carried into pass 2 so the example stream continues
        # exactly where the metadata draws left off.
        metas = []
        for s in range(cfg.num_speakers):
            srng = np.random.default_rng(cfg.seed * 100003 + s + 1)
            bias = srng.normal(0.0, cfg.speaker_bias_std, size=(F,)).astype(np.float32)
            gain = 1.0 + srng.normal(0.0, cfg.speaker_gain_std)
            if cfg.vocab_concentration >= 1e6:   # IID limit: no vocab skew
                unigram = self.base_unigram
            else:
                unigram = srng.dirichlet(self.base_unigram * (V - 1) * cfg.vocab_concentration)
            n = max(2, int(srng.lognormal(np.log(cfg.mean_utterances), cfg.utterance_sigma)))
            metas.append((srng, bias, gain, unigram, n))

        # Pass 2: one padded arena for every speaker's examples.
        P = cfg.num_speakers
        self.counts = np.array([m[4] for m in metas], np.int64)
        self.n_max = int(self.counts.max())
        self.arena_features = np.zeros((P, self.n_max, self.t_max, F), np.float32)
        self.arena_labels = np.zeros((P, self.n_max, self.u_max), np.int32)
        self.arena_label_len = np.zeros((P, self.n_max), np.int32)
        self.arena_frame_len = np.zeros((P, self.n_max), np.int32)

        self.speakers = []
        for s, (srng, bias, gain, unigram, n) in enumerate(metas):
            feats = self.arena_features[s]
            labels = self.arena_labels[s]
            label_len = self.arena_label_len[s]
            frame_len = self.arena_frame_len[s]
            for i in range(n):
                u = int(srng.integers(cfg.min_label_len, cfg.max_label_len + 1))
                toks = srng.choice(np.arange(1, V), size=u, p=unigram)
                labels[i, :u] = toks
                label_len[i] = u
                t = u * r
                frame_len[i] = t
                emission = self.codebook[toks].reshape(t, F)
                noise = srng.normal(0.0, cfg.noise_std, size=(t, F))
                feats[i, :t] = gain * emission + bias + noise
            self.speakers.append(
                dict(features=feats[:n], labels=labels[:n], label_len=label_len[:n],
                     frame_len=frame_len[:n], bias=bias, gain=gain, n=n)
            )

    @property
    def num_speakers(self) -> int:
        return len(self.speakers)

    def utterance_histogram(self):
        """Per-speaker utterance counts (paper Fig. 2)."""
        return np.array([s["n"] for s in self.speakers])

    def iid_pool(self):
        """Flatten all speakers into one pool (central/Baseline training)."""
        feats = np.concatenate([s["features"] for s in self.speakers])
        labels = np.concatenate([s["labels"] for s in self.speakers])
        label_len = np.concatenate([s["label_len"] for s in self.speakers])
        frame_len = np.concatenate([s["frame_len"] for s in self.speakers])
        return dict(features=feats, labels=labels, label_len=label_len, frame_len=frame_len)

    def eval_split(self, num_examples: int, seed: int = 1234, hard: bool = False):
        """Held-out eval set; ``hard=True`` mimics the *Other* sets by
        doubling acoustic noise and halving gains (harder recognition)."""
        cfg = self.cfg
        rng = np.random.default_rng(seed + (1 if hard else 0))
        F, r = cfg.feat_dim, cfg.frames_per_token
        feats = np.zeros((num_examples, self.t_max, F), np.float32)
        labels = np.zeros((num_examples, self.u_max), np.int32)
        label_len = np.zeros((num_examples,), np.int32)
        frame_len = np.zeros((num_examples,), np.int32)
        noise_std = cfg.noise_std * (2.5 if hard else 1.0)
        for i in range(num_examples):
            u = int(rng.integers(cfg.min_label_len, cfg.max_label_len + 1))
            toks = rng.choice(np.arange(1, cfg.vocab_size), size=u, p=self.base_unigram)
            labels[i, :u] = toks
            label_len[i] = u
            t = u * r
            frame_len[i] = t
            emission = self.codebook[toks].reshape(t, F)
            bias = rng.normal(0.0, cfg.speaker_bias_std, size=(F,))
            gain = 1.0 + rng.normal(0.0, cfg.speaker_gain_std)
            feats[i, :t] = gain * emission + bias + rng.normal(0.0, noise_std, size=(t, F))
        return dict(features=feats, labels=labels, label_len=label_len, frame_len=frame_len)


class VirtualPopulation:
    """N virtual clients (millions) over a P-speaker base corpus,
    without EVER materializing an N-sized array.

    The paper's deployment is millions of phones; the synthetic corpus
    materializes P speakers of real example data. This layer maps
    virtual client ``v`` onto base speaker ``v % P`` — clone ``j`` of
    speaker ``s`` is ``v = s + j * P`` — so every virtual client has a
    real local dataset (its base speaker's arena row) while keeping its
    OWN sampling identity: the federated sampler keys cursors and
    shuffle orders by the *virtual* id (lazily, only for visited
    clients), so two clones of one speaker traverse their shared data
    in independent orders, exactly like two phones holding similar
    data. Memory is O(P + visited), fully decoupled from N.

    Everything a strategy needs is histogram-shaped: ``base_counts``
    (P,) per-speaker example counts and ``clone_counts()`` (P,) virtual
    clients per speaker (``N // P`` + 1 for the first ``N % P``
    speakers). Strategies detect a virtual population by exactly these
    two attributes and switch to O(K log P) histogram draws.

    Deliberately NOT provided: ``.speakers`` / ``.counts`` /
    ``.utterance_histogram`` — any consumer that would iterate
    per-client state must go through the histogram API or it would
    reintroduce the O(N) scan this layer exists to remove.
    """

    def __init__(self, base: SpeakerCorpus, num_clients: int):
        P = base.num_speakers
        if num_clients < P:
            raise ValueError(
                f"virtual population ({num_clients}) smaller than the base "
                f"corpus ({P} speakers) — shrink the corpus instead"
            )
        self.base = base
        self.num_clients = int(num_clients)
        self.base_counts = np.asarray(base.counts, np.int64)
        # arena + shape surface: identical layout, indexed by BASE ids
        # (the sampler maps virtual -> base via base_of before gathers)
        self.cfg = base.cfg
        self.n_max = base.n_max
        self.t_max = base.t_max
        self.u_max = base.u_max
        self.arena_features = base.arena_features
        self.arena_labels = base.arena_labels
        self.arena_label_len = base.arena_label_len
        self.arena_frame_len = base.arena_frame_len

    @property
    def num_speakers(self) -> int:
        """The sampling universe: strategies draw from N virtual ids."""
        return self.num_clients

    def base_of(self, ids):
        """Virtual client ids -> base speaker rows (vectorized)."""
        return np.asarray(ids, np.int64) % self.base.num_speakers

    def count_of(self, ids):
        """Per-virtual-client example counts, by histogram lookup."""
        return self.base_counts[self.base_of(ids)]

    def clone_counts(self) -> np.ndarray:
        """(P,) virtual clients per base speaker; sums to N."""
        P = self.base.num_speakers
        q, r = divmod(self.num_clients, P)
        return q + (np.arange(P) < r).astype(np.int64)

    def iid_pool(self):
        return self.base.iid_pool()

    def eval_split(self, num_examples: int, seed: int = 1234, hard: bool = False):
        return self.base.eval_split(num_examples, seed=seed, hard=hard)


def make_speaker_corpus(**kwargs) -> SpeakerCorpus:
    return SpeakerCorpus(CorpusConfig(**kwargs))
