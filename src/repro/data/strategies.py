"""Client sampling strategies for federated rounds.

The paper samples K clients uniformly per round; its Fig. 2 shows the
per-speaker utterance histogram is roughly log-normal, so uniform
sampling makes a round's *example* mass very uneven across rounds.
This registry opens the dial on that second non-IID axis:

- ``uniform``: the paper's default — every speaker equally likely.
- ``weighted-by-examples``: selection probability proportional to the
  client's utterance count, so heavy speakers appear in more rounds
  (round example-mass variance shrinks; per-speaker coverage skews).
- ``stratified``: split speakers into utterance-count quantile strata
  and draw round-robin across strata, guaranteeing every round mixes
  data-rich and data-poor clients.

A strategy is ``fn(rng, corpus, k) -> (k,) int64`` of distinct client
ids. Register new ones with ``@register_strategy("name")``.
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np

Strategy = Callable[[np.random.Generator, object, int], np.ndarray]

_STRATEGIES: Dict[str, Strategy] = {}


def register_strategy(name: str):
    def deco(fn: Strategy) -> Strategy:
        _STRATEGIES[name] = fn
        return fn

    return deco


def get_strategy(name: str) -> Strategy:
    try:
        return _STRATEGIES[name]
    except KeyError:
        raise KeyError(
            f"unknown client sampling strategy {name!r}; "
            f"available: {sorted(_STRATEGIES)}") from None


def available_strategies() -> list[str]:
    return sorted(_STRATEGIES)


def _counts(corpus) -> np.ndarray:
    """Per-speaker example counts without per-round Python iteration
    (the arena builds ``counts`` once; fall back for duck-typed corpora)."""
    c = getattr(corpus, "counts", None)
    return c if c is not None else corpus.utterance_histogram()


@register_strategy("uniform")
def uniform(rng: np.random.Generator, corpus, k: int) -> np.ndarray:
    return rng.choice(corpus.num_speakers, size=k, replace=False)


@register_strategy("weighted-by-examples")
def weighted_by_examples(rng: np.random.Generator, corpus, k: int) -> np.ndarray:
    counts = _counts(corpus).astype(np.float64)
    p = counts / counts.sum()
    return rng.choice(corpus.num_speakers, size=k, replace=False, p=p)


@register_strategy("stratified")
def stratified(rng: np.random.Generator, corpus, k: int) -> np.ndarray:
    """Round-robin over utterance-count quantile strata (Fig. 2 skew)."""
    counts = _counts(corpus)
    n_strata = int(min(4, k, corpus.num_speakers))
    # speakers sorted by count, split into n_strata near-equal bins
    order = np.argsort(counts, kind="stable")
    strata = np.array_split(order, n_strata)
    # shuffle within each stratum, then deal clients round-robin
    pools = [rng.permutation(s) for s in strata]
    chosen = []
    i = 0
    while len(chosen) < k:
        pool = pools[i % n_strata]
        j = i // n_strata
        if j < len(pool):
            chosen.append(pool[j])
        i += 1
        if i >= n_strata * max(len(p) for p in pools):
            break
    return np.asarray(chosen[:k], np.int64)
