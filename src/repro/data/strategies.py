"""Client sampling strategies for federated rounds.

The paper samples K clients uniformly per round; its Fig. 2 shows the
per-speaker utterance histogram is roughly log-normal, so uniform
sampling makes a round's *example* mass very uneven across rounds.
This registry opens the dial on that second non-IID axis:

- ``uniform``: the paper's default — every speaker equally likely.
- ``weighted-by-examples``: selection probability proportional to the
  client's utterance count, so heavy speakers appear in more rounds
  (round example-mass variance shrinks; per-speaker coverage skews).
- ``stratified``: split speakers into utterance-count quantile strata
  and draw round-robin across strata, guaranteeing every round mixes
  data-rich and data-poor clients.

A strategy is ``fn(rng, corpus, k) -> (k,) int64`` of distinct client
ids. Register new ones with ``@register_strategy("name")``.

Virtual populations (``corpus.VirtualPopulation``, N clients over a
P-speaker base) are detected by their ``base_counts``/``clone_counts``
histogram API, and every strategy switches to a draw that touches
O(K log P) state — never an N-sized array: clone counts of one base
speaker are equal, so "draw a virtual client by weight" factors into
"draw a base speaker from the P-bin histogram, then a clone uniformly".
Plain corpora keep the historical draws byte-for-byte (same RNG
consumption), so existing fixed-seed runs are unchanged.
"""

from __future__ import annotations

from typing import Callable, Dict

import numpy as np

Strategy = Callable[[np.random.Generator, object, int], np.ndarray]

_STRATEGIES: Dict[str, Strategy] = {}

# A virtual population must dwarf the round for rejection-style distinct
# draws to be cheap; below this margin the plain O(N) draw is fine.
_VIRTUAL_MARGIN = 8


def register_strategy(name: str):
    def deco(fn: Strategy) -> Strategy:
        _STRATEGIES[name] = fn
        return fn

    return deco


def get_strategy(name: str) -> Strategy:
    try:
        return _STRATEGIES[name]
    except KeyError:
        raise KeyError(
            f"unknown client sampling strategy {name!r}; available: {sorted(_STRATEGIES)}"
        ) from None


def available_strategies() -> list[str]:
    return sorted(_STRATEGIES)


def _counts(corpus) -> np.ndarray:
    """Per-speaker example counts without per-round Python iteration
    (the arena builds ``counts`` once; fall back for duck-typed corpora)."""
    c = getattr(corpus, "counts", None)
    return c if c is not None else corpus.utterance_histogram()


def _virtual(corpus):
    """The corpus if it speaks the virtual-population histogram API
    (``base_counts`` + ``clone_counts``) AND is large enough that the
    O(K log P) draws are worth their rejection loop, else None."""
    if hasattr(corpus, "base_counts") and hasattr(corpus, "clone_counts"):
        return corpus
    return None


def _use_virtual(corpus, k: int):
    vp = _virtual(corpus)
    if vp is not None and corpus.num_speakers >= _VIRTUAL_MARGIN * k:
        return vp
    return None


def _distinct(rng, draw, k: int) -> np.ndarray:
    """k DISTINCT ids from a batched sampler ``draw(size) -> (size,)``
    by rejection: keep first occurrences in draw order (deterministic
    for a fixed rng stream), redraw until k survive. With the
    population >= _VIRTUAL_MARGIN * k the expected number of rounds is
    ~1, so the cost is O(k log k) sorting — independent of N."""
    chosen = np.empty(0, np.int64)
    while chosen.size < k:
        cand = np.concatenate([chosen, np.asarray(draw(2 * (k - chosen.size)), np.int64)])
        _, first = np.unique(cand, return_index=True)
        chosen = cand[np.sort(first)]
    return chosen[:k]


@register_strategy("uniform")
def uniform(rng: np.random.Generator, corpus, k: int) -> np.ndarray:
    vp = _use_virtual(corpus, k)
    if vp is None:
        return rng.choice(corpus.num_speakers, size=k, replace=False)
    n = corpus.num_speakers
    return _distinct(rng, lambda size: rng.integers(0, n, size=size), k)


@register_strategy("weighted-by-examples")
def weighted_by_examples(rng: np.random.Generator, corpus, k: int) -> np.ndarray:
    vp = _use_virtual(corpus, k)
    if vp is None:
        counts = _counts(corpus).astype(np.float64)
        p = counts / counts.sum()
        return rng.choice(corpus.num_speakers, size=k, replace=False, p=p)
    # Factored draw: base speaker s with prob ∝ base_counts[s] *
    # clone_counts[s] (total example mass of s's clones), then a clone
    # uniformly — every virtual client v lands with prob ∝ count_of(v),
    # via one P-bin categorical + one bounded integer draw.
    base_counts = vp.base_counts.astype(np.float64)
    clones = vp.clone_counts()
    P = len(base_counts)
    w = base_counts * clones
    p = w / w.sum()

    def draw(size):
        s = rng.choice(P, size=size, p=p)
        return s + P * rng.integers(0, clones[s])

    return _distinct(rng, draw, k)


def _stratified_virtual(rng, vp, k: int) -> np.ndarray:
    """Round-robin over count-quantile strata of the VIRTUAL population
    without materializing it: sort the P base speakers by count, take
    the clone-weighted cumsum (each speaker contributes clone_counts[s]
    virtual clients, all with the same count), cut it into near-equal
    strata of virtual mass, and turn a uniform integer in a stratum's
    cumsum range back into a (speaker, clone) pair by binary search —
    O(log P) per draw."""
    base_counts = vp.base_counts
    clones = vp.clone_counts()
    P = len(base_counts)
    order = np.argsort(base_counts, kind="stable")
    cum = np.cumsum(clones[order])
    total = int(cum[-1])
    n_strata = int(min(4, k, total))
    bounds = np.linspace(0, total, n_strata + 1).astype(np.int64)
    chosen: list = []
    seen: set = set()
    i = 0
    while len(chosen) < k and i < 64 * k * n_strata:
        lo, hi = bounds[i % n_strata], bounds[i % n_strata + 1]
        i += 1
        if hi <= lo:
            continue
        r = int(rng.integers(lo, hi))
        j = int(np.searchsorted(cum, r, side="right"))
        clone_idx = r - (int(cum[j - 1]) if j > 0 else 0)
        v = int(order[j]) + P * clone_idx
        if v not in seen:
            seen.add(v)
            chosen.append(v)
    return np.asarray(chosen[:k], np.int64)


@register_strategy("stratified")
def stratified(rng: np.random.Generator, corpus, k: int) -> np.ndarray:
    """Round-robin over utterance-count quantile strata (Fig. 2 skew)."""
    vp = _use_virtual(corpus, k)
    if vp is not None:
        return _stratified_virtual(rng, vp, k)
    counts = _counts(corpus)
    n_strata = int(min(4, k, corpus.num_speakers))
    # speakers sorted by count, split into n_strata near-equal bins
    order = np.argsort(counts, kind="stable")
    strata = np.array_split(order, n_strata)
    # shuffle within each stratum, then deal clients round-robin
    pools = [rng.permutation(s) for s in strata]
    chosen = []
    i = 0
    while len(chosen) < k:
        pool = pools[i % n_strata]
        j = i // n_strata
        if j < len(pool):
            chosen.append(pool[j])
        i += 1
        if i >= n_strata * max(len(p) for p in pools):
            break
    return np.asarray(chosen[:k], np.int64)
