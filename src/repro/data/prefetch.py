"""Async host->device prefetch for the federated round loop.

The round step is a single pjit'd function, so the host is idle while
the device runs a round — and the device is idle while the host packs
the next round batch and transfers it. ``PrefetchIterator`` overlaps
the two with a background thread and a small bounded buffer
(double-buffering by default): the worker packs round r+1 (and
``jax.device_put``s it) while the device crunches round r.

One worker thread keeps the sampler's RNG stream strictly ordered, so
prefetched runs are bit-identical to serial runs.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterable, Iterator, Optional

_END = object()


class PrefetchIterator:
    """Iterate ``source`` with a background worker and a depth-bounded
    buffer; optionally ``jax.device_put`` each item on the worker thread
    so device transfer also overlaps compute.

    ``sharding`` (a ``jax.sharding.Sharding``) routes the worker-thread
    transfer straight to the target placement — for mesh-sharded rounds
    each item lands pre-split over the ``clients`` axis, so the round
    step starts without a host-side gather/reshard stall.

    Use as a context manager (or call ``close()``) to guarantee the
    worker is torn down when the consumer stops early.
    """

    def __init__(
        self,
        source: Iterable[Any],
        depth: int = 2,
        device_put: bool = True,
        transform: Optional[Callable[[Any], Any]] = None,
        sharding: Optional[Any] = None,
    ):
        if depth < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {depth}")
        self._queue: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None
        self._done = False
        self._transform = transform
        self._device_put = device_put or sharding is not None
        self._sharding = sharding
        self._thread = threading.Thread(
            target=self._worker, args=(iter(source),), daemon=True,
            name="repro-prefetch")
        self._thread.start()

    def _put(self, item) -> bool:
        """Blocking put that aborts when close() is requested."""
        while not self._stop.is_set():
            try:
                self._queue.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _worker(self, it: Iterator[Any]) -> None:
        try:
            for item in it:
                if self._stop.is_set():
                    return
                if self._transform is not None:
                    item = self._transform(item)
                if self._device_put:
                    import jax

                    if self._sharding is not None:
                        item = jax.device_put(item, self._sharding)
                    else:
                        item = jax.device_put(item)
                if not self._put(item):
                    return
        except BaseException as e:  # surfaced on the consumer thread
            self._error = e
        finally:
            self._put(_END)

    def __iter__(self):
        return self

    def __next__(self):
        if self._done:
            raise StopIteration
        while True:
            try:
                item = self._queue.get(timeout=0.5)
            except queue.Empty:
                if not self._thread.is_alive():
                    # worker died without posting the sentinel
                    self._done = True
                    if self._error is not None:
                        raise self._error
                    raise StopIteration
                continue
            if item is _END:
                self._done = True
                if self._error is not None:
                    raise self._error
                raise StopIteration
            return item

    def close(self) -> None:
        """Stop the worker and release the buffer. Idempotent."""
        self._stop.set()
        # drain so a blocked worker can observe the stop event
        try:
            while True:
                self._queue.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)
        self._done = True

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False


def round_batches(sampler, rounds: int) -> Iterator[dict]:
    """Host-side round batch stream in the engine's input layout."""
    for _ in range(rounds):
        yield sampler.next_round().engine_batch()
