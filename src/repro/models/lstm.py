"""LSTM stack (the paper's RNN-T encoder substrate).

Gates are computed as one fused (in+hidden) x 4h matmul per step; the
elementwise gate nonlinearities + state update are the Pallas
``lstm_gates`` kernel's target (ref path inline here). Sequence
iteration is ``lax.scan``; multi-layer stacks scan over a stacked
parameter axis when dims are homogeneous, else loop per layer.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


@dataclasses.dataclass(frozen=True)
class LSTMConfig:
    d_in: int
    d_hidden: int
    n_layers: int


def lstm_cell_init(key, d_in: int, d_hidden: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "w_ih": dense_init(k1, d_in, 4 * d_hidden, dtype),
        "w_hh": dense_init(k2, d_hidden, 4 * d_hidden, dtype),
        "b": jnp.zeros((4 * d_hidden,), dtype),
    }


def lstm_gates(gates: jnp.ndarray, c: jnp.ndarray):
    """Fused gate nonlinearities + cell update (jnp reference of the
    Pallas kernel). gates: (..., 4h) pre-activation [i, f, g, o]."""
    h4 = gates.shape[-1]
    h = h4 // 4
    gf = gates.astype(jnp.float32)
    i = jax.nn.sigmoid(gf[..., :h])
    f = jax.nn.sigmoid(gf[..., h : 2 * h] + 1.0)  # forget-gate bias +1
    g = jnp.tanh(gf[..., 2 * h : 3 * h])
    o = jax.nn.sigmoid(gf[..., 3 * h :])
    c_new = f * c.astype(jnp.float32) + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new.astype(gates.dtype), c_new.astype(c.dtype)


def _fused_tile(h: int):
    """Largest lane-friendly tile dividing h (None: shape won't tile)."""
    for th in (256, 128):
        if h % th == 0:
            return th
    return None


def _lstm_gates_dispatch(gates: jnp.ndarray, c: jnp.ndarray):
    """TPU: the Pallas fused kernel with its fused custom-VJP backward
    (the cell's backward dominates the federated round's per-client
    scan). CPU and non-tiling hidden sizes: the jnp reference — same
    math, XLA-fused, and what every parity test pins."""
    th = _fused_tile(c.shape[-1]) if gates.ndim == 2 else None
    if th is None or jax.default_backend() == "cpu":
        return lstm_gates(gates, c)
    from repro.kernels.lstm_gates import lstm_gates_fused_vjp

    return lstm_gates_fused_vjp(gates, c, th=th)


def lstm_cell_step(p, x, h, c):
    """x: (B, d_in); h, c: (B, d_hidden)."""
    gates = x @ p["w_ih"].astype(x.dtype) + h @ p["w_hh"].astype(x.dtype) + p["b"].astype(x.dtype)
    return lstm_gates(gates, c)


def _scan_kernel_eligible(S: int, d_h: int, chunk: int) -> bool:
    """Static shape gate for the full-scan Pallas kernel, resolved
    against the tuning registry: lane-tileable hidden size, sequence
    long enough that the per-step w_hh refetch dominates, and the
    resident (H x 4H) weight within the VMEM budget. ``chunk`` requests
    gradient-checkpointed scanning the kernel does not implement, so it
    always keeps lax.scan."""
    from repro.profile.tuner import get_knob

    mode = get_knob("lstm.scan_dispatch")
    if mode == "ref" or chunk:
        return False
    if d_h % 128 != 0:
        return False
    whh_mb = d_h * 4 * d_h * 4 / 2**20
    if S < int(get_knob("lstm.scan_min_seq")) or whh_mb > float(get_knob("lstm.scan_max_vmem_mb")):
        return False
    return mode == "pallas" or jax.default_backend() != "cpu"


def lstm_layer(p, xs, h0=None, c0=None, unroll: int = 1, chunk: int = 0):
    """xs: (B, S, d_in) -> (B, S, d_hidden), (h, c) final.

    ``unroll`` replicates the step body inside each while iteration so
    the recurrent weight matrix is fetched once per ``unroll`` steps
    (the §Perf weight-amortization lever). On TPU, eligible shapes
    dispatch the full-scan Pallas kernel instead (``lstm_scan_fused``):
    the whole sequence runs in ONE pallas_call whose w_hh block is
    fetched once and stays VMEM-resident for all S steps, with a fused
    reversed-scan custom-VJP backward that recomputes the gate
    preactivations in VMEM (thresholds in the tuning registry;
    `--autotune lstm` re-measures them)."""
    B, S, _ = xs.shape
    d_h = p["w_hh"].shape[0]
    h = jnp.zeros((B, d_h), xs.dtype) if h0 is None else h0
    c = jnp.zeros((B, d_h), jnp.float32) if c0 is None else c0
    # hoist the input matmul out of the scan (one big MXU matmul)
    xg = xs @ p["w_ih"].astype(xs.dtype) + p["b"].astype(xs.dtype)  # (B, S, 4h)

    if _scan_kernel_eligible(S, d_h, chunk):
        from repro.kernels.lstm_gates import lstm_scan_fused_vjp
        from repro.profile.tuner import get_knob

        interpret = get_knob("lstm.scan_dispatch") == "pallas" and jax.default_backend() == "cpu"
        ys, hT, cT = lstm_scan_fused_vjp(
            xg.swapaxes(0, 1), p["w_hh"], h, c.astype(jnp.float32), interpret=interpret
        )
        return ys.swapaxes(0, 1), (hT.astype(xs.dtype), cT)

    def step(carry, xg_t):
        h, c = carry
        gates = xg_t + h @ p["w_hh"].astype(xg_t.dtype)
        h, c = _lstm_gates_dispatch(gates, c)
        return (h, c), h

    if chunk:
        from repro.models.layers import chunked_scan

        (h, c), ys = chunked_scan(step, (h, c), xg.swapaxes(0, 1), chunk=chunk, unroll=unroll)
    else:
        (h, c), ys = jax.lax.scan(step, (h, c), xg.swapaxes(0, 1), unroll=unroll)
    return ys.swapaxes(0, 1), (h, c)


def lstm_stack_init(key, cfg: LSTMConfig, dtype=jnp.float32):
    keys = jax.random.split(key, cfg.n_layers)
    return [
        lstm_cell_init(keys[i], cfg.d_in if i == 0 else cfg.d_hidden, cfg.d_hidden, dtype)
        for i in range(cfg.n_layers)
    ]


def lstm_stack(params, xs, unroll: int = 1, chunk: int = 0):
    """List-of-layers forward. Returns (B, S, d_hidden)."""
    states = []
    for p in params:
        xs, st = lstm_layer(p, xs, unroll=unroll, chunk=chunk)
        states.append(st)
    return xs, states


def lstm_stack_step(params, x, states):
    """Single-step (decode). x: (B, d_in); states: [(h, c)] per layer."""
    new_states = []
    for p, (h, c) in zip(params, states):
        x, c = lstm_cell_step(p, x, h, c)
        new_states.append((x, c))
    return x, new_states


def lstm_stack_init_state(cfg: LSTMConfig, batch: int, dtype=jnp.float32):
    return [
        (jnp.zeros((batch, cfg.d_hidden), dtype), jnp.zeros((batch, cfg.d_hidden), jnp.float32))
        for _ in range(cfg.n_layers)
    ]
