"""Encoder-decoder transformer (Whisper-style) — [audio] backbone.

Per the assignment carve-out, the mel-spectrogram + conv feature
extractor is a STUB: ``input_specs`` provides precomputed frame
embeddings (B, T_frames, d_model). This module implements the
transformer backbone: sinusoidal-position bidirectional encoder,
causal decoder with cross-attention, teacher-forced CE loss, and a
cached decode step (self-attn KV cache + precomputed cross KV).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.attention import (
    AttnConfig,
    attn_init,
    blockwise_attention,
    decode_attention,
    _project_qkv,
)
from repro.models.layers import (
    embed_init,
    layer_norm,
    lm_loss,
    mlp_apply,
    mlp_init,
    sinusoidal_positions,
    stacked,
)


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str
    enc_layers: int
    dec_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    max_source: int = 1500
    max_target: int = 448
    act: str = "gelu"
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    loss_chunk: int = 64

    @property
    def cdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def attn_cfg(self, causal: bool) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads, n_kv=self.n_kv,
            head_dim=self.head_dim, rope_theta=0.0, causal=causal,
        )


def _ln_init(d, dt):
    return jnp.ones((d,), dt), jnp.zeros((d,), dt)


def _enc_layer_init(key, cfg: EncDecConfig):
    ka, km = jax.random.split(key)
    dt = cfg.pdtype
    s1, b1 = _ln_init(cfg.d_model, dt)
    s2, b2 = _ln_init(cfg.d_model, dt)
    return {
        "norm1": s1, "norm1_b": b1, "norm2": s2, "norm2_b": b2,
        "attn": attn_init(ka, cfg.attn_cfg(False), dt),
        "mlp": mlp_init(km, cfg.d_model, cfg.d_ff, gated=False, dtype=dt),
    }


def _dec_layer_init(key, cfg: EncDecConfig):
    ka, kx, km = jax.random.split(key, 3)
    dt = cfg.pdtype
    s1, b1 = _ln_init(cfg.d_model, dt)
    s2, b2 = _ln_init(cfg.d_model, dt)
    s3, b3 = _ln_init(cfg.d_model, dt)
    return {
        "norm1": s1, "norm1_b": b1, "norm2": s2, "norm2_b": b2,
        "norm3": s3, "norm3_b": b3,
        "self_attn": attn_init(ka, cfg.attn_cfg(True), dt),
        "cross_attn": attn_init(kx, cfg.attn_cfg(False), dt),
        "mlp": mlp_init(km, cfg.d_model, cfg.d_ff, gated=False, dtype=dt),
    }


def init_params(cfg: EncDecConfig, key) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = cfg.pdtype
    fs, fb = _ln_init(cfg.d_model, dt)
    es, eb = _ln_init(cfg.d_model, dt)
    return {
        "enc_layers": stacked(_enc_layer_init, k1, cfg.enc_layers, cfg),
        "enc_norm": es, "enc_norm_b": eb,
        "tok_embed": embed_init(k2, cfg.vocab, cfg.d_model, dt),
        "pos_embed": (jax.random.normal(k3, (cfg.max_target, cfg.d_model)) * 0.01).astype(dt),
        "dec_layers": stacked(_dec_layer_init, k4, cfg.dec_layers, cfg),
        "final_norm": fs, "final_norm_b": fb,
    }


def encode(cfg: EncDecConfig, params, frames):
    """frames: (B, T, d_model) stub embeddings -> (B, T, d_model)."""
    x = frames.astype(cfg.cdtype)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    acfg = cfg.attn_cfg(False)

    @jax.checkpoint
    def body(xc, lp):
        h = layer_norm(xc, lp["norm1"], lp["norm1_b"])
        q, k, v = _project_qkv(lp["attn"], acfg, h, jnp.zeros(xc.shape[:2], jnp.int32))
        o = blockwise_attention(q, k, v, causal=False, block_kv=min(512, xc.shape[1]))
        xc = xc + o.reshape(xc.shape[0], xc.shape[1], -1) @ lp["attn"]["wo"].astype(xc.dtype)
        h2 = layer_norm(xc, lp["norm2"], lp["norm2_b"])
        xc = xc + mlp_apply(lp["mlp"], h2, cfg.act)
        return xc, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return layer_norm(x, params["enc_norm"], params["enc_norm_b"])


def _cross_kv(lp, acfg, enc_out):
    """Precompute cross-attention K, V from encoder output."""
    B, T, _ = enc_out.shape
    k = (enc_out @ lp["cross_attn"]["wk"].astype(enc_out.dtype)).reshape(B, T, acfg.n_kv, acfg.head_dim)
    v = (enc_out @ lp["cross_attn"]["wv"].astype(enc_out.dtype)).reshape(B, T, acfg.n_kv, acfg.head_dim)
    return k, v


def _dec_layer(cfg, lp, x, enc_out, pos_q):
    acfg = cfg.attn_cfg(True)
    xcfg = cfg.attn_cfg(False)
    h = layer_norm(x, lp["norm1"], lp["norm1_b"])
    q, k, v = _project_qkv(lp["self_attn"], acfg, h, pos_q)
    o = blockwise_attention(q, k, v, causal=True, block_kv=min(512, x.shape[1]))
    x = x + o.reshape(*x.shape[:2], -1) @ lp["self_attn"]["wo"].astype(x.dtype)
    h2 = layer_norm(x, lp["norm2"], lp["norm2_b"])
    q2, _, _ = _project_qkv(lp["cross_attn"], xcfg, h2, jnp.zeros_like(pos_q))
    ck, cv = _cross_kv(lp, xcfg, enc_out)
    o2 = blockwise_attention(q2, ck, cv, causal=False, block_kv=min(512, enc_out.shape[1]))
    x = x + o2.reshape(*x.shape[:2], -1) @ lp["cross_attn"]["wo"].astype(x.dtype)
    h3 = layer_norm(x, lp["norm3"], lp["norm3_b"])
    x = x + mlp_apply(lp["mlp"], h3, cfg.act)
    return x, (k, v)


def decode_train(cfg: EncDecConfig, params, tokens, enc_out):
    """Teacher-forced decoder. tokens: (B, U)."""
    B, U = tokens.shape
    x = params["tok_embed"].astype(cfg.cdtype)[tokens]
    pe = params["pos_embed"].astype(x.dtype)
    x = x + pe[jnp.arange(U) % pe.shape[0]][None]   # wraps past max_target
    pos_q = jnp.broadcast_to(jnp.arange(U), (B, U))

    @jax.checkpoint
    def body(xc, lp):
        xo, _ = _dec_layer(cfg, lp, xc, enc_out, pos_q)
        return xo, None

    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return layer_norm(x, params["final_norm"], params["final_norm_b"])


def loss_fn(cfg: EncDecConfig, params, batch, rng=None):
    """batch: frames (B, T, d_model), tokens (B, U)."""
    enc_out = encode(cfg, params, batch["frames"])
    h = decode_train(cfg, params, batch["tokens"], enc_out)
    loss = lm_loss(h, params["tok_embed"].astype(cfg.cdtype).T, batch["tokens"],
                   chunk=min(cfg.loss_chunk, h.shape[1]),
                   weight=batch.get("weight"))
    return loss, {"lm_loss": loss}


# -------------------------------------------------------------- serving

def init_cache(cfg: EncDecConfig, batch: int, seq_len: int):
    dt = cfg.cdtype
    L, Kv, D = cfg.dec_layers, cfg.n_kv, cfg.head_dim
    T = cfg.max_source
    return {
        "self_k": jnp.zeros((L, batch, seq_len, Kv, D), dt),
        "self_v": jnp.zeros((L, batch, seq_len, Kv, D), dt),
        "cross_k": jnp.zeros((L, batch, T, Kv, D), dt),
        "cross_v": jnp.zeros((L, batch, T, Kv, D), dt),
    }


def prefill(cfg: EncDecConfig, params, frames, tokens):
    """Encode source + teacher-forced pass over a token prefix, building
    the decode cache. Returns (last logits, cache)."""
    enc_out = encode(cfg, params, frames)
    B, U = tokens.shape
    x = params["tok_embed"].astype(cfg.cdtype)[tokens]
    pe = params["pos_embed"].astype(x.dtype)
    pos = jnp.arange(U) % pe.shape[0]
    x = x + pe[pos][None]
    pos_q = jnp.broadcast_to(jnp.arange(U), (B, U))
    xcfg = cfg.attn_cfg(False)

    def body(xc, lp):
        xo, kv = _dec_layer(cfg, lp, xc, enc_out, pos_q)
        ck, cv = _cross_kv(lp, xcfg, enc_out)
        return xo, (kv[0], kv[1], ck, cv)

    x, (sk, sv, ck, cv) = jax.lax.scan(body, x, params["dec_layers"])
    x = layer_norm(x, params["final_norm"], params["final_norm_b"])
    logits = (x[:, -1] @ params["tok_embed"].astype(cfg.cdtype).T).astype(jnp.float32)
    cache = {"self_k": sk, "self_v": sv, "cross_k": ck, "cross_v": cv}
    return logits, cache


def decode_step(cfg: EncDecConfig, params, cache, tokens, pos):
    """One decoder token against the cache. tokens: (B, 1); pos scalar."""
    B = tokens.shape[0]
    x = params["tok_embed"].astype(cfg.cdtype)[tokens]
    pe = params["pos_embed"].astype(x.dtype)
    x = x + pe[pos % pe.shape[0]][None, None]
    acfg = cfg.attn_cfg(True)
    xcfg = cfg.attn_cfg(False)

    def body(xc, inp):
        lp, sk, sv, ck, cv = inp
        h = layer_norm(xc, lp["norm1"], lp["norm1_b"])
        q, k, v = _project_qkv(lp["self_attn"], acfg, h,
                               jnp.broadcast_to(pos[None], (B, 1)) if pos.ndim == 0 else pos)
        sk = jax.lax.dynamic_update_slice(sk, k.astype(sk.dtype), (0, pos, 0, 0))
        sv = jax.lax.dynamic_update_slice(sv, v.astype(sv.dtype), (0, pos, 0, 0))
        o = decode_attention(q[:, 0], sk, sv, pos)
        xc = xc + o.reshape(B, 1, -1) @ lp["self_attn"]["wo"].astype(xc.dtype)
        h2 = layer_norm(xc, lp["norm2"], lp["norm2_b"])
        q2, _, _ = _project_qkv(lp["cross_attn"], xcfg, h2, jnp.zeros((B, 1), jnp.int32))
        T = ck.shape[1]
        o2 = decode_attention(q2[:, 0], ck, cv, jnp.asarray(T - 1, jnp.int32))
        xc = xc + o2.reshape(B, 1, -1) @ lp["cross_attn"]["wo"].astype(xc.dtype)
        h3 = layer_norm(xc, lp["norm3"], lp["norm3_b"])
        xc = xc + mlp_apply(lp["mlp"], h3, cfg.act)
        return xc, (sk, sv)

    x, (sk, sv) = jax.lax.scan(
        body, x,
        (params["dec_layers"], cache["self_k"], cache["self_v"],
         cache["cross_k"], cache["cross_v"]),
    )
    x = layer_norm(x, params["final_norm"], params["final_norm_b"])
    logits = (x[:, 0] @ params["tok_embed"].astype(cfg.cdtype).T).astype(jnp.float32)
    new_cache = dict(cache, self_k=sk, self_v=sv)
    return logits, new_cache
