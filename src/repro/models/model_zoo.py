"""Unified model interface: build_model(config) -> ModelBundle.

A ModelBundle binds a config to the pure functions the federated
engine, launcher, and dry-run consume. Dispatch is on config dataclass
type; every assigned architecture's config file constructs one of the
four config families (TransformerConfig / HybridConfig / RWKV stack /
EncDecConfig / VLMConfig / RNNTConfig).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, keyword, rnnt, transformer, vlm
from repro.models.layers import dense_init, embed_init, lm_loss, stacked
from repro.models.rwkv import (
    RWKVConfig,
    rwkv_init_state,
    rwkv_layer_forward,
    rwkv_layer_init,
)


@dataclasses.dataclass(frozen=True)
class RWKVModelConfig:
    name: str
    n_layers: int
    rwkv: RWKVConfig
    vocab: int
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    loss_chunk: int = 256

    @property
    def cdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)


@dataclasses.dataclass
class ModelBundle:
    name: str
    kind: str                    # dense | moe | hybrid | ssm | audio | vlm | rnnt | keyword
    config: Any
    init: Callable               # (key) -> params
    loss_fn: Callable            # (params, batch, rng) -> (loss, aux)
    prefill: Optional[Callable] = None      # (params, batch) -> (logits, cache)
    decode_step: Optional[Callable] = None  # (params, cache, tokens, pos) -> (logits, cache)
    init_cache: Optional[Callable] = None   # (batch, seq_len, ring=False) -> cache

    def param_count(self, params) -> int:
        return sum(int(jnp.size(x)) for x in jax.tree.leaves(params))


# ------------------------------------------------------------- rwkv model

def _rwkv_init(cfg: RWKVModelConfig, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "embed": embed_init(k1, cfg.vocab, cfg.rwkv.d_model, cfg.pdtype),
        "layers": stacked(rwkv_layer_init, k2, cfg.n_layers, cfg.rwkv, cfg.pdtype),
        "final_norm": jnp.ones((cfg.rwkv.d_model,), cfg.pdtype),
        "final_norm_b": jnp.zeros((cfg.rwkv.d_model,), cfg.pdtype),
        "unembed": dense_init(k3, cfg.rwkv.d_model, cfg.vocab, cfg.pdtype),
    }


def _rwkv_forward(cfg: RWKVModelConfig, params, tokens, states=None):
    from repro.models.rwkv import _ln

    x = params["embed"].astype(cfg.cdtype)[tokens]

    def body(xc, inp):
        if states is None:
            lp = inp
            xo, _ = rwkv_layer_forward(lp, cfg.rwkv, xc, None)
            return xo, None
        lp, st = inp
        xo, st2 = rwkv_layer_forward(lp, cfg.rwkv, xc, st)
        return xo, st2

    if states is None:
        body = jax.checkpoint(body)

    xs = params["layers"] if states is None else (params["layers"], states)
    x, new_states = jax.lax.scan(body, x, xs)
    x = _ln(x, params["final_norm"], params["final_norm_b"])
    return x, new_states


def _rwkv_loss(cfg: RWKVModelConfig, params, batch, rng=None):
    h, _ = _rwkv_forward(cfg, params, batch["tokens"])
    loss = lm_loss(h, params["unembed"].astype(cfg.cdtype), batch["tokens"],
                   chunk=min(cfg.loss_chunk, batch["tokens"].shape[1]),
                   weight=batch.get("weight"))
    return loss, {"lm_loss": loss}


def _rwkv_init_cache(cfg: RWKVModelConfig, batch: int, seq_len: int, ring: bool = False):
    one = rwkv_init_state(cfg.rwkv, batch, cfg.cdtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(), one)


def _rwkv_decode(cfg: RWKVModelConfig, params, cache, tokens, pos, ring: bool = False):
    h, states = _rwkv_forward(cfg, params, tokens, states=cache)
    logits = (h[:, 0] @ params["unembed"].astype(cfg.cdtype)).astype(jnp.float32)
    return logits, states


def _rwkv_prefill(cfg: RWKVModelConfig, params, batch):
    tokens = batch["tokens"]
    cache = _rwkv_init_cache(cfg, tokens.shape[0], 0)
    h, states = _rwkv_forward(cfg, params, tokens, states=cache)
    logits = (h[:, -1] @ params["unembed"].astype(cfg.cdtype)).astype(jnp.float32)
    return logits, states


# ------------------------------------------------------------- dispatch

def build_model(cfg, kind: Optional[str] = None) -> ModelBundle:
    if isinstance(cfg, transformer.TransformerConfig):
        kind = kind or ("moe" if cfg.moe is not None else "dense")
        return ModelBundle(
            name=cfg.name, kind=kind, config=cfg,
            init=partial(transformer.init_params, cfg),
            loss_fn=partial(transformer.loss_fn, cfg),
            prefill=lambda params, batch: transformer.prefill(cfg, params, batch["tokens"]),
            decode_step=partial(transformer.decode_step, cfg),
            init_cache=partial(transformer.init_cache, cfg),
        )
    if isinstance(cfg, hybrid.HybridConfig):
        return ModelBundle(
            name=cfg.name, kind="hybrid", config=cfg,
            init=partial(hybrid.init_params, cfg),
            loss_fn=partial(hybrid.loss_fn, cfg),
            prefill=None,   # hybrid serving enters via decode (SSM prefill = scan)
            decode_step=partial(hybrid.decode_step, cfg),
            init_cache=lambda batch, seq_len, ring=False: hybrid.init_cache(cfg, batch, seq_len),
        )
    if isinstance(cfg, RWKVModelConfig):
        return ModelBundle(
            name=cfg.name, kind="ssm", config=cfg,
            init=partial(_rwkv_init, cfg),
            loss_fn=partial(_rwkv_loss, cfg),
            prefill=partial(_rwkv_prefill, cfg),
            decode_step=partial(_rwkv_decode, cfg),
            init_cache=partial(_rwkv_init_cache, cfg),
        )
    if isinstance(cfg, encdec.EncDecConfig):
        return ModelBundle(
            name=cfg.name, kind="audio", config=cfg,
            init=partial(encdec.init_params, cfg),
            loss_fn=partial(encdec.loss_fn, cfg),
            prefill=lambda params, batch: encdec.prefill(cfg, params, batch["frames"], batch["tokens"]),
            decode_step=partial(encdec.decode_step, cfg),
            init_cache=lambda batch, seq_len, ring=False: encdec.init_cache(cfg, batch, seq_len),
        )
    if isinstance(cfg, vlm.VLMConfig):
        return ModelBundle(
            name=cfg.name, kind="vlm", config=cfg,
            init=partial(vlm.init_params, cfg),
            loss_fn=partial(vlm.loss_fn, cfg),
            prefill=partial(vlm.prefill, cfg),
            decode_step=partial(vlm.decode_step, cfg),
            init_cache=partial(vlm.init_cache, cfg),
        )
    if isinstance(cfg, rnnt.RNNTConfig):
        return ModelBundle(
            name=cfg.name, kind="rnnt", config=cfg,
            init=partial(rnnt.init_params, cfg),
            loss_fn=partial(rnnt.loss_fn, cfg),
        )
    if isinstance(cfg, keyword.KeywordConfig):
        return ModelBundle(
            name=cfg.name, kind="keyword", config=cfg,
            init=partial(keyword.init_params, cfg),
            loss_fn=partial(keyword.loss_fn, cfg),
        )
    raise TypeError(f"unknown config type {type(cfg)}")
