"""Zamba2-style hybrid (arXiv:2411.15242): a Mamba2 backbone with a
single *shared* attention+MLP block applied periodically.

Layout for n_layers Mamba2 layers with the shared block every
``attn_every``: G full groups of [shared-attn -> attn_every x mamba]
plus a tail [shared-attn -> rem x mamba]. The shared block's weights
are identical at every application (that is Zamba's trick — attention
quality at ~1/13 of the parameter cost) but each application has its
own KV cache. Zamba2's concatenated-embedding input to the shared
block is simplified to the plain residual stream (noted in DESIGN.md).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.attention import AttnConfig, attn_init, attn_forward, attn_decode
from repro.models.layers import (
    dense_init,
    embed_init,
    lm_loss,
    mlp_apply,
    mlp_init,
    rms_norm,
    stacked,
)
from repro.models.ssm import MambaConfig, mamba_forward, mamba_init, mamba_init_state, mamba_step


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    name: str
    n_layers: int                 # number of Mamba2 layers
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int                     # shared attn block MLP
    vocab: int
    attn_every: int = 6
    ssm_state: int = 64
    ssm_headdim: int = 64
    ssm_chunked: bool = False     # chunked SSD formulation (see ssm.py)
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    loss_chunk: int = 256

    @property
    def cdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def n_groups(self):
        return self.n_layers // self.attn_every

    @property
    def tail(self):
        return self.n_layers - self.n_groups * self.attn_every

    @property
    def n_attn_applications(self):
        return self.n_groups + (1 if self.tail else 0)

    def mamba_cfg(self) -> MambaConfig:
        return MambaConfig(d_model=self.d_model, headdim=self.ssm_headdim,
                           d_state=self.ssm_state)

    def attn_cfg(self) -> AttnConfig:
        return AttnConfig(d_model=self.d_model, n_heads=self.n_heads,
                          n_kv=self.n_kv, head_dim=self.head_dim)


def _mamba_layer_init(key, cfg: HybridConfig):
    return {"norm": jnp.ones((cfg.d_model,), cfg.pdtype),
            "mamba": mamba_init(key, cfg.mamba_cfg(), cfg.pdtype)}


def init_params(cfg: HybridConfig, key) -> dict:
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    dt = cfg.pdtype
    G, E = cfg.n_groups, cfg.attn_every
    params = {
        "embed": embed_init(k1, cfg.vocab, cfg.d_model, dt),
        "shared_attn": {
            "norm1": jnp.ones((cfg.d_model,), dt),
            "norm2": jnp.ones((cfg.d_model,), dt),
            "attn": attn_init(k2, cfg.attn_cfg(), dt),
            "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, gated=True, dtype=dt),
        },
        "groups": stacked(lambda k: stacked(_mamba_layer_init, k, E, cfg), k4, G),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "unembed": dense_init(k5, cfg.d_model, cfg.vocab, dt),
    }
    if cfg.tail:
        params["tail"] = stacked(_mamba_layer_init, k6, cfg.tail, cfg)
    return params


def _shared_block_forward(cfg: HybridConfig, sp, x):
    h = rms_norm(x, sp["norm1"])
    a, kv = attn_forward(sp["attn"], cfg.attn_cfg(), h, block_kv=min(512, x.shape[1]))
    x = x + a
    h2 = rms_norm(x, sp["norm2"])
    x = x + mlp_apply(sp["mlp"], h2, "silu")
    return x, kv


def _mamba_layer_fwd(cfg: HybridConfig, lp, x):
    h = rms_norm(x, lp["norm"])
    if cfg.ssm_chunked:
        from repro.models.ssm import mamba_forward_chunked

        return x + mamba_forward_chunked(lp["mamba"], cfg.mamba_cfg(), h)
    return x + mamba_forward(lp["mamba"], cfg.mamba_cfg(), h)


def forward(cfg: HybridConfig, params, tokens):
    x = params["embed"].astype(cfg.cdtype)[tokens]
    sp = params["shared_attn"]

    @jax.checkpoint
    def mamba_body(xi, lp):
        return _mamba_layer_fwd(cfg, lp, xi), None

    shared_fwd = jax.checkpoint(
        lambda xc, sp_: _shared_block_forward(cfg, sp_, xc)[0])

    def group_body(xc, gp):
        xc = shared_fwd(xc, sp)
        xc, _ = jax.lax.scan(mamba_body, xc, gp)
        return xc, None

    x, _ = jax.lax.scan(group_body, x, params["groups"])
    if cfg.tail:
        x = shared_fwd(x, sp)
        x, _ = jax.lax.scan(mamba_body, x, params["tail"])
    return rms_norm(x, params["final_norm"])


def loss_fn(cfg: HybridConfig, params, batch, rng=None):
    h = forward(cfg, params, batch["tokens"])
    loss = lm_loss(h, params["unembed"].astype(cfg.cdtype), batch["tokens"],
                   chunk=min(cfg.loss_chunk, h.shape[1]),
                   weight=batch.get("weight"))
    return loss, {"lm_loss": loss}


# -------------------------------------------------------------- serving

def init_cache(cfg: HybridConfig, batch: int, seq_len: int):
    dt = cfg.cdtype
    mc = cfg.mamba_cfg()
    G, E = cfg.n_groups, cfg.attn_every
    one = mamba_init_state(mc, batch, dt)

    def rep(tree, *dims):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, dims + a.shape).copy() if dims else a, tree)

    cache = {
        "attn_k": jnp.zeros((cfg.n_attn_applications, batch, seq_len, cfg.n_kv, cfg.head_dim), dt),
        "attn_v": jnp.zeros((cfg.n_attn_applications, batch, seq_len, cfg.n_kv, cfg.head_dim), dt),
        "groups": rep(one, G, E),
    }
    if cfg.tail:
        cache["tail"] = rep(one, cfg.tail)
    return cache


def _shared_block_decode(cfg: HybridConfig, sp, x, kc, vc, pos):
    h = rms_norm(x, sp["norm1"])
    a, kc, vc = attn_decode(sp["attn"], cfg.attn_cfg(), h, kc, vc, pos)
    x = x + a
    h2 = rms_norm(x, sp["norm2"])
    x = x + mlp_apply(sp["mlp"], h2, "silu")
    return x, kc, vc


def decode_step(cfg: HybridConfig, params, cache, tokens, pos):
    """tokens (B, 1); pos scalar. Returns (logits (B, V), cache)."""
    x = params["embed"].astype(cfg.cdtype)[tokens]
    sp = params["shared_attn"]
    mc = cfg.mamba_cfg()
    G = cfg.n_groups

    def group_body(xc, inp):
        gp, gstate, kc, vc = inp
        xc, kc, vc = _shared_block_decode(cfg, sp, xc, kc, vc, pos)

        def mamba_body(xi, inp2):
            lp, st = inp2
            out, st2 = mamba_step(lp["mamba"], mc, rms_norm(xi, lp["norm"]), st)
            return xi + out, st2

        xc, gstate = jax.lax.scan(mamba_body, xc, (gp, gstate))
        return xc, (gstate, kc, vc)

    x, (gstates, kcs, vcs) = jax.lax.scan(
        group_body, x,
        (params["groups"], cache["groups"], cache["attn_k"][:G], cache["attn_v"][:G]))
    new_cache = dict(cache, groups=gstates)
    attn_k = cache["attn_k"].at[:G].set(kcs)
    attn_v = cache["attn_v"].at[:G].set(vcs)
    if cfg.tail:
        x, kt, vt = _shared_block_decode(cfg, sp, x, cache["attn_k"][G], cache["attn_v"][G], pos)

        def mamba_body(xi, inp2):
            lp, st = inp2
            out, st2 = mamba_step(lp["mamba"], mc, rms_norm(xi, lp["norm"]), st)
            return xi + out, st2

        x, tstates = jax.lax.scan(mamba_body, x, (params["tail"], cache["tail"]))
        new_cache["tail"] = tstates
        attn_k = attn_k.at[G].set(kt)
        attn_v = attn_v.at[G].set(vt)
    new_cache["attn_k"] = attn_k
    new_cache["attn_v"] = attn_v
    x = rms_norm(x, params["final_norm"])
    logits = (x[:, 0] @ params["unembed"].astype(cfg.cdtype)).astype(jnp.float32)
    return logits, new_cache
