"""RWKV-6 "Finch" (arXiv:2404.05892) — attention-free, data-dependent
decay linear recurrence.

Per head (key dim P -> value dim P), with per-channel decay w_t
produced by a low-rank MLP of the token-shifted input (the Finch
hallmark):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
Token-shift mixing uses static per-channel coefficients (RWKV-5.2
style; the fully dynamic 6.0 mixing LoRAs are omitted — noted in
DESIGN.md). GroupNorm per head, silu(g) output gate, squared-ReLU
channel mix. Decode state is O(1) in context length — long_500k runs
natively.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    d_model: int
    head_size: int = 64
    d_ff: int = 0                # default 3.5x d_model
    decay_lora: int = 64

    def __post_init__(self):
        if self.d_ff == 0:
            object.__setattr__(self, "d_ff", int(3.5 * self.d_model))

    @property
    def n_heads(self):
        assert self.d_model % self.head_size == 0
        return self.d_model // self.head_size


def rwkv_layer_init(key, cfg: RWKVConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 12)
    D, H, P = cfg.d_model, cfg.n_heads, cfg.head_size
    return {
        "ln1": jnp.ones((D,), dtype), "ln1_b": jnp.zeros((D,), dtype),
        "ln2": jnp.ones((D,), dtype), "ln2_b": jnp.zeros((D,), dtype),
        # time-mix
        "mu_r": jnp.full((D,), 0.5, dtype), "mu_k": jnp.full((D,), 0.5, dtype),
        "mu_v": jnp.full((D,), 0.5, dtype), "mu_g": jnp.full((D,), 0.5, dtype),
        "mu_w": jnp.full((D,), 0.5, dtype),
        "wr": dense_init(ks[0], D, D, dtype), "wk": dense_init(ks[1], D, D, dtype),
        "wv": dense_init(ks[2], D, D, dtype), "wg": dense_init(ks[3], D, D, dtype),
        "w_out": dense_init(ks[4], D, D, dtype),
        # data-dependent decay (low-rank)
        "w0": jnp.full((D,), -6.0, dtype),
        "wA": dense_init(ks[5], D, cfg.decay_lora, dtype),
        "wB": dense_init(ks[6], cfg.decay_lora, D, dtype, scale=0.01),
        "u": (jax.random.normal(ks[7], (D,)) * 0.1).astype(dtype),
        "gn_scale": jnp.ones((D,), dtype), "gn_bias": jnp.zeros((D,), dtype),
        # channel-mix
        "mu_ck": jnp.full((D,), 0.5, dtype), "mu_cr": jnp.full((D,), 0.5, dtype),
        "ck": dense_init(ks[8], D, cfg.d_ff, dtype),
        "cv": dense_init(ks[9], cfg.d_ff, D, dtype),
        "cr": dense_init(ks[10], D, D, dtype),
    }


def _ln(x, s, b, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * s.astype(jnp.float32)
            + b.astype(jnp.float32)).astype(dt)


def _group_norm(x, H, scale, bias, eps=1e-5):
    """x: (..., D) grouped into H heads."""
    shp = x.shape
    xg = x.astype(jnp.float32).reshape(*shp[:-1], H, shp[-1] // H)
    mu = xg.mean(-1, keepdims=True)
    var = ((xg - mu) ** 2).mean(-1, keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return (xg.reshape(shp) * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def _shift(x, last=None):
    """Token shift: previous token per position. x: (B, S, D)."""
    if last is None:
        prev = jnp.pad(x[:, :-1], ((0, 0), (1, 0), (0, 0)))
    else:
        prev = jnp.concatenate([last[:, None].astype(x.dtype), x[:, :-1]], axis=1)
    return prev


def _decay(p, xw):
    """Per-channel decay in (0,1): exp(-exp(w0 + lora(xw)))."""
    lora = jnp.tanh(xw @ p["wA"].astype(xw.dtype)) @ p["wB"].astype(xw.dtype)
    logw = p["w0"].astype(jnp.float32) + lora.astype(jnp.float32)
    return jnp.exp(-jnp.exp(logw))


def _time_mix_inputs(p, x, prev):
    def mix(mu):
        m = p[mu].astype(x.dtype)
        return x * m + prev * (1 - m)
    return mix("mu_r"), mix("mu_k"), mix("mu_v"), mix("mu_g"), mix("mu_w")


def rwkv_time_mix(p, cfg: RWKVConfig, x, state=None):
    """x: (B, S, D). state: {"last": (B,D), "S": (B,H,P,P)} or None (train).
    Returns (out, new_state)."""
    B, S, D = x.shape
    H, P = cfg.n_heads, cfg.head_size
    xn = _ln(x, p["ln1"], p["ln1_b"])
    prev = _shift(xn, None if state is None else state["last"])
    xr, xk, xv, xg, xw = _time_mix_inputs(p, xn, prev)
    r = (xr @ p["wr"].astype(x.dtype)).reshape(B, S, H, P)
    k = (xk @ p["wk"].astype(x.dtype)).reshape(B, S, H, P)
    v = (xv @ p["wv"].astype(x.dtype)).reshape(B, S, H, P)
    g = xg @ p["wg"].astype(x.dtype)
    w = _decay(p, xw).reshape(B, S, H, P)                      # (0,1) decays
    u = p["u"].astype(jnp.float32).reshape(H, P)

    def step(Smat, inp):
        r_t, k_t, v_t, w_t = inp                               # (B,H,P) each
        kv = k_t[..., :, None] * v_t[..., None, :]             # (B,H,P,P)
        y = jnp.einsum("bhp,bhpq->bhq", r_t, Smat + u[None, :, :, None] * kv)
        Smat = w_t[..., :, None] * Smat + kv
        return Smat, y

    from repro.models.layers import chunked_scan

    rf = r.astype(jnp.float32).swapaxes(0, 1)
    kf = k.astype(jnp.float32).swapaxes(0, 1)
    vf = v.astype(jnp.float32).swapaxes(0, 1)
    wf = w.swapaxes(0, 1)
    S0 = jnp.zeros((B, H, P, P), jnp.float32) if state is None else state["S"]
    Sn, ys = chunked_scan(step, S0, (rf, kf, vf, wf), chunk=64)
    y = ys.swapaxes(0, 1).reshape(B, S, D).astype(x.dtype)
    y = _group_norm(y, H, p["gn_scale"], p["gn_bias"])
    out = (y * jax.nn.silu(g)) @ p["w_out"].astype(x.dtype)
    new_state = {"last": xn[:, -1], "S": Sn}
    return out, new_state


def rwkv_channel_mix(p, cfg: RWKVConfig, x, state=None):
    """state: {"last": (B, D)} or None. Returns (out, new_state)."""
    xn = _ln(x, p["ln2"], p["ln2_b"])
    prev = _shift(xn, None if state is None else state["last"])
    mk, mr = p["mu_ck"].astype(x.dtype), p["mu_cr"].astype(x.dtype)
    xk = xn * mk + prev * (1 - mk)
    xr = xn * mr + prev * (1 - mr)
    k = jnp.square(jax.nn.relu(xk @ p["ck"].astype(x.dtype)))
    out = jax.nn.sigmoid(xr @ p["cr"].astype(x.dtype)) * (k @ p["cv"].astype(x.dtype))
    return out, {"last": xn[:, -1]}


def rwkv_layer_forward(p, cfg: RWKVConfig, x, state=None):
    """Full layer (time mix + channel mix). state: dict or None."""
    tm_state = None if state is None else state["tm"]
    cm_state = None if state is None else state["cm"]
    a, tm_new = rwkv_time_mix(p, cfg, x, tm_state)
    x = x + a
    b, cm_new = rwkv_channel_mix(p, cfg, x, cm_state)
    x = x + b
    return x, {"tm": tm_new, "cm": cm_new}


def rwkv_init_state(cfg: RWKVConfig, batch: int, dtype=jnp.float32):
    H, P, D = cfg.n_heads, cfg.head_size, cfg.d_model
    return {
        "tm": {"last": jnp.zeros((batch, D), dtype), "S": jnp.zeros((batch, H, P, P), jnp.float32)},
        "cm": {"last": jnp.zeros((batch, D), dtype)},
    }
