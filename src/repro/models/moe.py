"""Mixture-of-Experts FFN: top-k routing, capacity-bounded sort-based
dispatch, expert-parallel sharding, load-balance aux loss.

Dispatch is *sort-based and per-batch-row* (vmapped over B): each row
sorts its (token, choice) pairs by expert id and scatters into a
static (E, C, D) capacity buffer; overflow tokens drop to an
out-of-bounds slot (``mode='drop'``) and fall through the residual.
Keeping the sort row-local means the batch axis stays sharded over
``data`` and only the (B, E, C, D) buffer reshards token->expert — the
all-to-all a production expert-parallel MoE performs — because the
expert axis of the weight stacks is sharded over ``model``.
Memory is O(B·S·K·D·capacity_factor), never O(T·E·C).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, mlp_apply, mlp_init


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    expert_ff: int
    n_shared: int = 0            # dense "shared experts" (DeepSeek-V2 style)
    shared_ff: int = 0           # hidden dim of the shared-expert MLP
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    renormalize: bool = True     # renormalize top-k gates to sum to 1


def moe_init(key, d_model: int, cfg: MoEConfig, dtype=jnp.float32):
    kr, ke1, ke2, ke3, ks = jax.random.split(key, 5)
    E, F = cfg.n_experts, cfg.expert_ff
    scale = d_model ** -0.5
    p = {
        "router": dense_init(kr, d_model, E, jnp.float32),  # router kept f32
        "w_gate": (jax.random.normal(ke1, (E, d_model, F)) * scale).astype(dtype),
        "w_up": (jax.random.normal(ke2, (E, d_model, F)) * scale).astype(dtype),
        "w_down": (jax.random.normal(ke3, (E, F, d_model)) * (F ** -0.5)).astype(dtype),
    }
    if cfg.n_shared > 0:
        shared_ff = cfg.shared_ff or cfg.n_shared * cfg.expert_ff
        p["shared"] = mlp_init(ks, d_model, shared_ff, gated=True, dtype=dtype)
    return p


def _route(logits, cfg: MoEConfig):
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.renormalize:
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    return probs, gate_vals, expert_idx


def _dispatch_row(xt, gate_vals, expert_idx, E: int, C: int):
    """One batch row. xt: (S, D); gate/expert: (S, K).
    Returns (buf (E, C, D), slot (S*K,), keep (S*K,), tok (S*K,), gate (S*K,))."""
    S, D = xt.shape
    K = expert_idx.shape[-1]
    flat_e = expert_idx.reshape(S * K)
    flat_g = gate_vals.reshape(S * K)
    flat_t = jnp.arange(S * K) // K
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.zeros((E,), jnp.int32).at[se].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(S * K) - starts[se]
    keep = pos_in_e < C
    slot = jnp.where(keep, se * C + jnp.clip(pos_in_e, 0, C - 1), E * C)
    buf = jnp.zeros((E * C, D), xt.dtype).at[slot].set(xt[st], mode="drop")
    return buf.reshape(E, C, D), slot, keep, st, sg


def _combine_row(eout, slot, keep, st, sg, S: int):
    """eout: (E, C, D) -> out (S, D), gathering each kept slot back."""
    E, C, D = eout.shape
    flat = eout.reshape(E * C, D)
    vals = flat.at[slot].get(mode="fill", fill_value=0.0)
    w = (sg * keep.astype(sg.dtype))[:, None].astype(vals.dtype)
    return jnp.zeros((S, D), eout.dtype).at[st].add(vals * w)


def moe_apply(p, cfg: MoEConfig, x, act: str = "silu"):
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = max(1, int(cfg.capacity_factor * S * K / E))
    C = min(C, S * K)

    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (B, S, E)
    probs, gate_vals, expert_idx = _route(logits, cfg)

    buf, slot, keep, st, sg = jax.vmap(
        lambda xr, gr, er: _dispatch_row(xr, gr, er, E, C)
    )(x, gate_vals, expert_idx)                                         # buf (B, E, C, D)

    actf = {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "gelu_tanh": lambda v: jax.nn.gelu(v, approximate=True)}[act]
    g = jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(x.dtype))
    eout = jnp.einsum("becf,efd->becd", actf(g) * u, p["w_down"].astype(x.dtype))

    out = jax.vmap(lambda eo, sl, kp, t, g_: _combine_row(eo, sl, kp, t, g_, S))(
        eout, slot, keep, st, sg
    )

    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    top1 = jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32)
    frac_tokens = jnp.mean(top1, axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = cfg.aux_loss_weight * E * jnp.sum(frac_tokens * frac_probs)

    if cfg.n_shared > 0:
        out = out + mlp_apply(p["shared"], x, act)

    return out, aux
