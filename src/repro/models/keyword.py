"""Keyword-spotting classifier — the tiny-model federated workload.

The keyword-spotting non-IID study (PAPERS.md, 2005.10406) runs the
paper's quality/cost framework on models small enough that
million-client rounds are cheap. This is that workload on the shared
speaker-split corpus: a masked mean-pool over the frame axis followed
by a two-layer MLP over word-piece classes (the class of an utterance
is its first word-piece, so the corpus's per-speaker Dirichlet vocab
skew becomes per-client class skew — real non-IID label shift).

~10k parameters at the container config: a full ``VirtualPopulation``
round (K = 32 over N = 1e6 virtual clients) runs at real scale in CI.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


@dataclasses.dataclass(frozen=True)
class KeywordConfig:
    name: str = "keyword-tiny"
    feat_dim: int = 16
    n_classes: int = 64  # word-piece vocab doubles as the class set
    hidden: int = 64
    dtype: str = "float32"
    param_dtype: str = "float32"

    @property
    def cdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)


def init_params(cfg: KeywordConfig, key) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    dt = cfg.pdtype
    return {
        "w1": dense_init(k1, cfg.feat_dim, cfg.hidden, dt),
        "b1": jnp.zeros((cfg.hidden,), dt),
        "w2": dense_init(k2, cfg.hidden, cfg.hidden, dt),
        "b2": jnp.zeros((cfg.hidden,), dt),
        "w_out": dense_init(k3, cfg.hidden, cfg.n_classes, dt),
        "b_out": jnp.zeros((cfg.n_classes,), dt),
    }


def forward(cfg: KeywordConfig, params, features, frame_len):
    """features (B, T, F), frame_len (B,) -> logits (B, n_classes).

    Mean-pool over the real frames only (padded frames are zero but
    still must not dilute the mean — frame_len is the divisor)."""
    t = jnp.arange(features.shape[1])
    mask = (t[None, :] < frame_len[:, None]).astype(cfg.cdtype)
    pooled = (features.astype(cfg.cdtype) * mask[:, :, None]).sum(axis=1)
    pooled = pooled / jnp.maximum(frame_len, 1).astype(cfg.cdtype)[:, None]
    h = jax.nn.relu(pooled @ params["w1"].astype(cfg.cdtype) + params["b1"])
    h = jax.nn.relu(h @ params["w2"].astype(cfg.cdtype) + params["b2"])
    return (h @ params["w_out"].astype(cfg.cdtype) + params["b_out"]).astype(
        jnp.float32
    )


def class_of(batch) -> jnp.ndarray:
    """The utterance's keyword class: its first word-piece id."""
    return batch["labels"][..., 0]


def loss_fn(cfg: KeywordConfig, params, batch, rng=None):
    """Weighted CE over {features, labels, frame_len, weight} — the
    engine-batch layout consumed directly (no adapter needed)."""
    logits = forward(cfg, params, batch["features"], batch["frame_len"])
    labels = class_of(batch)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    w = batch.get("weight")
    w = jnp.ones_like(ce) if w is None else w.astype(ce.dtype)
    denom = jnp.maximum(w.sum(), 1.0)
    loss = (ce * w).sum() / denom
    acc = ((jnp.argmax(logits, axis=-1) == labels) * w).sum() / denom
    return loss, {"ce": loss, "acc": acc}


def predict(cfg: KeywordConfig, params, features, frame_len) -> jnp.ndarray:
    """(B,) argmax class ids."""
    return jnp.argmax(forward(cfg, params, features, frame_len), axis=-1)
