"""Generic decoder-only transformer LM covering the dense/MoE/MLA
assigned architectures (qwen3, deepseek-67b, command-r, gemma3,
mistral/llava backbone, phi3.5-moe, deepseek-v2-lite).

The layer stack is stored with a leading L axis and consumed with
``lax.scan`` (HLO size and compile time are depth-independent; the
95-layer deepseek-67b config must compile on this container).
Heterogeneity is expressed per-layer *data*, not per-layer code:
- sliding-window vs global layers: an (L,) window-width array
  (0 = full attention), so gemma3's 5:1 local:global pattern is a
  scanned input, and the all-window long-context variant of the dense
  archs is a config change;
- deepseek-v2-lite's dense first layer is a separate unscanned block.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import moe as moe_lib
from repro.models import mla as mla_lib
from repro.models.attention import AttnConfig, attn_init
from repro.models.layers import (
    dense_init,
    embed_init,
    layer_norm,
    lm_loss,
    mlp_apply,
    mlp_init,
    rms_norm,
    stacked,
)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "silu"
    gated_mlp: bool = True
    norm: str = "rms"                  # "rms" | "ln"
    rms_plus_one: bool = False         # gemma convention
    qk_norm: bool = False
    use_bias: bool = False
    parallel_block: bool = False       # command-r style attn+mlp in parallel
    rope_theta: float = 10000.0
    window: Optional[int] = None       # sliding window width for local layers
    global_every: int = 0              # 0 = all layers follow `window`;
                                       # k>0 = every k-th layer is global (gemma3)
    logit_softcap: float = 0.0
    emb_scale: bool = False            # multiply embeddings by sqrt(d) (gemma)
    moe: Optional[moe_lib.MoEConfig] = None
    moe_first_dense: int = 0           # leading dense layers (deepseek-v2)
    first_dense_ff: int = 0
    mla: Optional[mla_lib.MLAConfig] = None
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    loss_chunk: int = 256

    @property
    def cdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def attn_cfg(self) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads, n_kv=self.n_kv,
            head_dim=self.head_dim, rope_theta=self.rope_theta,
            qk_norm=self.qk_norm, use_bias=self.use_bias,
            logit_softcap=self.logit_softcap,
        )

    def layer_windows(self) -> jnp.ndarray:
        """(n_scanned_layers,) int32; 0 = full attention."""
        n = self.n_layers - self.moe_first_dense
        if self.window is None:
            return jnp.zeros((n,), jnp.int32)
        w = jnp.full((n,), self.window, jnp.int32)
        if self.global_every > 0:
            idx = jnp.arange(self.moe_first_dense, self.n_layers)
            w = jnp.where((idx + 1) % self.global_every == 0, 0, w)
        return w


# ------------------------------------------------------------------ init

def _layer_init(key, cfg: TransformerConfig):
    ka, km, kn = jax.random.split(key, 3)
    dt = cfg.pdtype
    p = {"norm1": jnp.ones((cfg.d_model,), dt)}
    if not cfg.parallel_block:
        p["norm2"] = jnp.ones((cfg.d_model,), dt)
    if cfg.norm == "ln":
        p["norm1_b"] = jnp.zeros((cfg.d_model,), dt)
        if not cfg.parallel_block:
            p["norm2_b"] = jnp.zeros((cfg.d_model,), dt)
    if cfg.mla is not None:
        p["attn"] = mla_lib.mla_init(ka, cfg.mla, dt)
    else:
        p["attn"] = attn_init(ka, cfg.attn_cfg(), dt)
    if cfg.moe is not None:
        p["moe"] = moe_lib.moe_init(km, cfg.d_model, cfg.moe, dt)
    else:
        p["mlp"] = mlp_init(km, cfg.d_model, cfg.d_ff, cfg.gated_mlp, dt)
    return p


def init_params(cfg: TransformerConfig, key) -> dict:
    k_emb, k_layers, k_out, k_dense = jax.random.split(key, 4)
    n_scan = cfg.n_layers - cfg.moe_first_dense
    params = {
        "embed": embed_init(k_emb, cfg.vocab, cfg.d_model, cfg.pdtype),
        "layers": stacked(_layer_init, k_layers, n_scan, cfg),
        "final_norm": jnp.ones((cfg.d_model,), cfg.pdtype),
        "unembed": dense_init(k_out, cfg.d_model, cfg.vocab, cfg.pdtype),
    }
    if cfg.norm == "ln":
        params["final_norm_b"] = jnp.zeros((cfg.d_model,), cfg.pdtype)
    if cfg.moe_first_dense > 0:
        dense_cfg = dataclasses.replace(cfg, moe=None, moe_first_dense=0,
                                        d_ff=cfg.first_dense_ff or cfg.d_ff)
        params["dense_layers"] = stacked(_layer_init, k_dense, cfg.moe_first_dense, dense_cfg)
    return params


# ------------------------------------------------------------------ fwd

def _norm(cfg, p, x, which):
    if cfg.norm == "ln":
        return layer_norm(x, p[which], p[which + "_b"])
    return rms_norm(x, p[which], plus_one=cfg.rms_plus_one)


def _layer_forward(cfg: TransformerConfig, lp, x, window, is_moe: bool, block_kv: int = 512):
    """One layer, full-sequence. window: traced int32 scalar (0 = full)."""
    acfg = cfg.attn_cfg()
    h = _norm(cfg, lp, x, "norm1")
    if cfg.mla is not None:
        attn_out, kv = mla_lib.mla_forward(lp["attn"], cfg.mla, h, block_kv=block_kv)
    else:
        # dynamic window: pass as masked width via AttnConfig None + manual mask
        attn_out, kv = _attn_forward_dynwin(lp["attn"], acfg, h, window, block_kv)
    aux = jnp.zeros(())
    if cfg.parallel_block:
        if is_moe:
            m, aux = moe_lib.moe_apply(lp["moe"], cfg.moe, h, cfg.act)
        else:
            m = mlp_apply(lp["mlp"], h, cfg.act)
        x = x + attn_out + m
    else:
        x = x + attn_out
        h2 = _norm(cfg, lp, x, "norm2")
        if is_moe:
            m, aux = moe_lib.moe_apply(lp["moe"], cfg.moe, h2, cfg.act)
        else:
            m = mlp_apply(lp["mlp"], h2, cfg.act)
        x = x + m
    return x, kv, aux


def _attn_forward_dynwin(p, acfg: AttnConfig, x, window, block_kv):
    """attn_forward with a *traced* per-layer window (0 = full)."""
    from repro.models.attention import _project_qkv, blockwise_attention

    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _project_qkv(p, acfg, x, positions)
    eff_window = jnp.where(window > 0, window, S + 1)   # wide window == full causal
    o = blockwise_attention(
        q, k, v, causal=True, window=eff_window,
        logit_softcap=acfg.logit_softcap, block_kv=min(block_kv, S),
        query_scale=acfg.query_scale,
    )
    out = o.reshape(B, S, acfg.n_heads * acfg.head_dim) @ p["wo"].astype(x.dtype)
    return out, (k, v)


def embed_tokens(cfg: TransformerConfig, params, tokens):
    x = params["embed"].astype(cfg.cdtype)[tokens]
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def forward(cfg: TransformerConfig, params, tokens, return_hidden: bool = False):
    """tokens (B, S) -> (final hidden (B, S, D), aux loss)."""
    return trunk(cfg, params, embed_tokens(cfg, params, tokens))


def trunk(cfg: TransformerConfig, params, x):
    """Layer stack from embeddings x (B, S, D) -> (hidden, aux loss)."""
    aux_total = jnp.zeros(())

    if cfg.moe_first_dense > 0:
        @jax.checkpoint
        def dense_body(xc, lp):
            xo, _, _ = _layer_forward(cfg, lp, xc, jnp.zeros((), jnp.int32), is_moe=False)
            return xo, None
        x, _ = jax.lax.scan(dense_body, x, params["dense_layers"])

    windows = cfg.layer_windows()

    @jax.checkpoint
    def body(carry, inp):
        xc, aux = carry
        lp, w = inp
        xo, _, a = _layer_forward(cfg, lp, xc, w, is_moe=cfg.moe is not None)
        return (xo, aux + a), None

    (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), (params["layers"], windows))
    x = _norm(cfg, {"final_norm": params["final_norm"],
                    **({"final_norm_b": params["final_norm_b"]} if cfg.norm == "ln" else {})},
              x, "final_norm")
    return (x, aux_total)


def loss_fn(cfg: TransformerConfig, params, batch, rng=None):
    """Next-token LM loss. batch: {"tokens": (B, S) int32}."""
    h, aux = forward(cfg, params, batch["tokens"])
    loss = lm_loss(h, params["unembed"].astype(cfg.cdtype), batch["tokens"],
                   chunk=cfg.loss_chunk, logit_softcap=cfg.logit_softcap,
                   weight=batch.get("weight"))
    return loss + aux, {"lm_loss": loss, "aux_loss": aux}


# ------------------------------------------------------------------ cache

def init_cache(cfg: TransformerConfig, batch: int, seq_len: int, ring: bool = False):
    """Cache pytree. ``ring=True`` sizes windowed layers at their window
    (ring buffer) instead of seq_len — the long-context memory saver."""
    n_scan = cfg.n_layers - cfg.moe_first_dense
    dt = cfg.cdtype

    def kv_cache(n, s):
        if cfg.mla is not None:
            return {
                "ckv": jnp.zeros((n, batch, s, cfg.mla.kv_lora), dt),
                "krope": jnp.zeros((n, batch, s, cfg.mla.qk_rope_dim), dt),
            }
        return {
            "k": jnp.zeros((n, batch, s, cfg.n_kv, cfg.head_dim), dt),
            "v": jnp.zeros((n, batch, s, cfg.n_kv, cfg.head_dim), dt),
        }

    s_main = seq_len
    if ring and cfg.window is not None and cfg.global_every == 0:
        s_main = min(seq_len, cfg.window)
    cache = {"layers": kv_cache(n_scan, s_main)}
    if cfg.moe_first_dense > 0:
        cache["dense_layers"] = kv_cache(cfg.moe_first_dense, seq_len)
    return cache


def _layer_decode(cfg: TransformerConfig, lp, x, cache_row, pos, window, is_moe, ring):
    acfg = dataclasses.replace(cfg.attn_cfg(), window=None)
    h = _norm(cfg, lp, x, "norm1")
    if cfg.mla is not None:
        attn_out, ckv, krope = mla_lib.mla_decode(lp["attn"], cfg.mla, h,
                                                  cache_row["ckv"], cache_row["krope"], pos)
        new_cache = {"ckv": ckv, "krope": krope}
    else:
        attn_out, kc, vc = _attn_decode_dynwin(lp["attn"], acfg, h, cache_row, pos, window, ring)
        new_cache = {"k": kc, "v": vc}
    if cfg.parallel_block:
        m = mlp_apply(lp["mlp"], h, cfg.act) if not is_moe else moe_lib.moe_apply(lp["moe"], cfg.moe, h, cfg.act)[0]
        x = x + attn_out + m
    else:
        x = x + attn_out
        h2 = _norm(cfg, lp, x, "norm2")
        m = mlp_apply(lp["mlp"], h2, cfg.act) if not is_moe else moe_lib.moe_apply(lp["moe"], cfg.moe, h2, cfg.act)[0]
        x = x + m
    return x, new_cache


def _attn_decode_dynwin(p, acfg: AttnConfig, x, cache_row, pos, window, ring):
    from repro.models.attention import _project_qkv, decode_attention

    B = x.shape[0]
    S = cache_row["k"].shape[1]
    positions = jnp.broadcast_to(pos[None], (B, 1))
    q, k, v = _project_qkv(p, acfg, x, positions)
    slot = jnp.mod(pos, S) if ring else pos
    kc = jax.lax.dynamic_update_slice(cache_row["k"], k.astype(cache_row["k"].dtype), (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache_row["v"], v.astype(cache_row["v"].dtype), (0, slot, 0, 0))
    eff_window = jnp.where(window > 0, window, pos + 2)  # wide == full
    o = decode_attention(q[:, 0], kc, vc, pos, window=eff_window, ring=ring,
                         logit_softcap=acfg.logit_softcap, query_scale=acfg.query_scale)
    out = o.reshape(B, 1, acfg.n_heads * acfg.head_dim) @ p["wo"].astype(x.dtype)
    return out, kc, vc


def decode_step(cfg: TransformerConfig, params, cache, tokens, pos, ring: bool = False):
    """tokens (B, 1); pos scalar int32 = position being written.
    Returns (logits (B, V), new cache)."""
    x = params["embed"].astype(cfg.cdtype)[tokens]
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    new_cache = {}
    if cfg.moe_first_dense > 0:
        def dense_body(xc, inp):
            lp, cr = inp
            xo, nc = _layer_decode(cfg, lp, xc, cr, pos, jnp.zeros((), jnp.int32),
                                   is_moe=False, ring=False)
            return xo, nc
        x, nc = jax.lax.scan(dense_body, x, (params["dense_layers"], cache["dense_layers"]))
        new_cache["dense_layers"] = nc

    windows = cfg.layer_windows()

    def body(xc, inp):
        lp, cr, w = inp
        xo, nc = _layer_decode(cfg, lp, xc, cr, pos, w, is_moe=cfg.moe is not None, ring=ring)
        return xo, nc

    x, nc = jax.lax.scan(body, x, (params["layers"], cache["layers"], windows))
    new_cache["layers"] = nc
    x = _norm(cfg, {"final_norm": params["final_norm"],
                    **({"final_norm_b": params["final_norm_b"]} if cfg.norm == "ln" else {})},
              x, "final_norm")
    logits = (x[:, 0] @ params["unembed"].astype(cfg.cdtype)).astype(jnp.float32)
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits, new_cache


def prefill(cfg: TransformerConfig, params, tokens):
    """Causal forward building a cache; returns (last-token logits, cache).

    Cache layout matches ``init_cache(..., ring=False)`` with
    seq_len = tokens.shape[1].
    """
    return prefill_embeds(cfg, params, embed_tokens(cfg, params, tokens))


def prefill_embeds(cfg: TransformerConfig, params, x):
    """Prefill from embeddings x (B, S, D) — the VLM entry point."""
    cache = {}
    if cfg.moe_first_dense > 0:
        def dense_body(xc, lp):
            xo, kv, _ = _layer_forward(cfg, lp, xc, jnp.zeros((), jnp.int32), is_moe=False)
            return xo, kv
        x, kvs = jax.lax.scan(dense_body, x, params["dense_layers"])
        cache["dense_layers"] = _kv_to_cache(cfg, kvs)

    windows = cfg.layer_windows()

    def body(xc, inp):
        lp, w = inp
        xo, kv, _ = _layer_forward(cfg, lp, xc, w, is_moe=cfg.moe is not None)
        return xo, kv

    x, kvs = jax.lax.scan(body, x, (params["layers"], windows))
    cache["layers"] = _kv_to_cache(cfg, kvs)
    x = _norm(cfg, {"final_norm": params["final_norm"],
                    **({"final_norm_b": params["final_norm_b"]} if cfg.norm == "ln" else {})},
              x, "final_norm")
    logits = (x[:, -1] @ params["unembed"].astype(cfg.cdtype)).astype(jnp.float32)
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits, cache


def _kv_to_cache(cfg, kvs):
    if cfg.mla is not None:
        ckv, krope = kvs
        return {"ckv": ckv, "krope": krope}
    k, v = kvs
    return {"k": k, "v": v}
