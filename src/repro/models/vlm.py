"""LLaVA-NeXT-style VLM: a Mistral-7B language backbone consuming
projected vision embeddings.

Per the assignment carve-out, the ViT/SigLIP encoder is a STUB —
``input_specs`` provides anyres tile patch embeddings (B, N_img,
vit_dim). The LM side is fully implemented: the 2-layer MLP projector,
token/image interleaving (image tiles prefixed), LM loss masked to
text positions, and decode against a cache whose prefix holds the
projected image tokens.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.layers import chunked_softmax_xent, dense_init


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    name: str
    lm: tfm.TransformerConfig
    vit_dim: int = 1024
    n_img_tokens: int = 576        # tokens per anyres tile grid (stubbed)

    @property
    def cdtype(self):
        return self.lm.cdtype


def init_params(cfg: VLMConfig, key) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    dt = cfg.lm.pdtype
    return {
        "lm": tfm.init_params(cfg.lm, k1),
        "projector": {
            "w1": dense_init(k2, cfg.vit_dim, cfg.lm.d_model, dt),
            "b1": jnp.zeros((cfg.lm.d_model,), dt),
            "w2": dense_init(k3, cfg.lm.d_model, cfg.lm.d_model, dt),
            "b2": jnp.zeros((cfg.lm.d_model,), dt),
        },
    }


def project(params, cfg: VLMConfig, image_embeds):
    """(B, N_img, vit_dim) -> (B, N_img, d_model); 2-layer GELU MLP."""
    p = params["projector"]
    x = image_embeds.astype(cfg.cdtype)
    h = jax.nn.gelu(x @ p["w1"].astype(x.dtype) + p["b1"].astype(x.dtype))
    return h @ p["w2"].astype(x.dtype) + p["b2"].astype(x.dtype)


def _embed_multimodal(cfg: VLMConfig, params, batch):
    img = project(params, cfg, batch["image_embeds"])          # (B, N, D)
    txt = tfm.embed_tokens(cfg.lm, params["lm"], batch["tokens"])
    return jnp.concatenate([img, txt], axis=1)


def loss_fn(cfg: VLMConfig, params, batch, rng=None):
    """batch: image_embeds (B, N_img, vit_dim), tokens (B, S_text)."""
    x = _embed_multimodal(cfg, params, batch)
    h, aux = tfm.trunk(cfg.lm, params["lm"], x)
    n_img = batch["image_embeds"].shape[1]
    h_txt = h[:, n_img:]
    tokens = batch["tokens"]
    targets = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones_like(tokens, jnp.float32).at[:, -1].set(0.0)
    if "weight" in batch:
        mask = mask * batch["weight"][:, None].astype(mask.dtype)
    tot, cnt = chunked_softmax_xent(
        h_txt, params["lm"]["unembed"].astype(cfg.cdtype), targets, mask,
        chunk=min(cfg.lm.loss_chunk, tokens.shape[1]))
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss + aux, {"lm_loss": loss, "aux_loss": aux}


def prefill(cfg: VLMConfig, params, batch):
    """Image tiles + text prompt -> (last logits, cache). The cache's
    leading n_img positions hold the image tokens."""
    x = _embed_multimodal(cfg, params, batch)
    return tfm.prefill_embeds(cfg.lm, params["lm"], x)


def init_cache(cfg: VLMConfig, batch: int, seq_len: int, ring: bool = False):
    return tfm.init_cache(cfg.lm, batch, seq_len, ring=ring)


def decode_step(cfg: VLMConfig, params, cache, tokens, pos, ring: bool = False):
    return tfm.decode_step(cfg.lm, params["lm"], cache, tokens, pos, ring=ring)
