"""Shared neural-net layers (pure functions over pytree params).

Conventions:
- params are nested dicts of jnp arrays; init fns take an explicit key.
- compute dtype is the caller's (we cast weights at use); params are
  created in ``param_dtype``.
- big stacks are created with a leading layer axis and consumed with
  ``jax.lax.scan`` so HLO size is depth-independent (95-layer configs
  must compile on a single-core container).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- init

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32, scale: float = 1.0):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * scale / jnp.sqrt(d)).astype(dtype)


def stacked(init_fn: Callable, key, n: int, *args, **kwargs):
    """Stack ``n`` independent inits along a leading axis (for lax.scan)."""
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_fn(k, *args, **kwargs))(keys)


# ---------------------------------------------------------------- norms

def rms_norm(x, scale, eps: float = 1e-6, plus_one: bool = False):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    s = scale.astype(jnp.float32)
    if plus_one:  # gemma convention
        s = 1.0 + s
    return (x * s).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------- rope

def rope_freqs(head_dim: int, theta: float = 10000.0):
    return theta ** (-jnp.arange(0, head_dim // 2, dtype=jnp.float32) / (head_dim // 2))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, D) or (..., S, D); positions: (..., S) int32.

    Split-half convention: pairs (x[..., :D/2], x[..., D/2:]).
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    if x.ndim == ang.ndim + 1:                         # head axis present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, d: int):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------- mlp

def mlp_init(key, d_model: int, d_ff: int, gated: bool = True, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_up": dense_init(k1, d_model, d_ff, dtype), "w_down": dense_init(k3, d_ff, d_model, dtype)}
    if gated:
        p["w_gate"] = dense_init(k2, d_model, d_ff, dtype)
    return p


def mlp_apply(p, x, act: str = "silu"):
    actf = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "gelu_tanh": lambda v: jax.nn.gelu(v, approximate=True), "relu": jax.nn.relu}[act]
    up = x @ p["w_up"].astype(x.dtype)
    if "w_gate" in p:
        up = actf(x @ p["w_gate"].astype(x.dtype)) * up
    else:
        up = actf(up)
    return up @ p["w_down"].astype(x.dtype)


# ---------------------------------------------------------------- losses

def chunked_softmax_xent(h, unembed, targets, mask=None, chunk: int = 256, logit_softcap: float = 0.0):
    """Next-token CE without materializing (B, S, V) logits.

    h: (B, S, D); unembed: (D, V); targets: (B, S) int32; mask: (B, S).
    Scans over S in chunks; each chunk's logits are transient (and
    vocab-sharded under pjit). Returns (sum_loss, sum_mask).
    """
    B, S, D = h.shape
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    n_chunks = max(1, S // chunk)
    while S % n_chunks:          # largest divisor of S near the target chunk
        n_chunks -= 1
    c = S // n_chunks
    hs = h.reshape(B, n_chunks, c, D).swapaxes(0, 1)           # (n, B, c, D)
    ts = targets.reshape(B, n_chunks, c).swapaxes(0, 1)
    ms = mask.reshape(B, n_chunks, c).swapaxes(0, 1)

    def body(carry, inp):
        hh, tt, mm = inp
        logits = (hh @ unembed.astype(hh.dtype)).astype(jnp.float32)
        if logit_softcap > 0:
            logits = logit_softcap * jnp.tanh(logits / logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tt[..., None], axis=-1)[..., 0]
        loss = (lse - gold) * mm
        return (carry[0] + loss.sum(), carry[1] + mm.sum()), None

    (tot, cnt), _ = jax.lax.scan(jax.checkpoint(body),
                                 (jnp.zeros(()), jnp.zeros(())), (hs, ts, ms))
    return tot, cnt


def lm_loss(h, unembed, tokens, chunk: int = 256, logit_softcap: float = 0.0, weight=None):
    """Shifted next-token loss over (B, S) tokens given final hidden h.
    ``weight``: optional per-example (B,) weights (0 = padding example,
    used by the federated engine's fixed-shape round batches)."""
    targets = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones_like(tokens, jnp.float32).at[:, -1].set(0.0)
    if weight is not None:
        mask = mask * weight[:, None].astype(mask.dtype)
    tot, cnt = chunked_softmax_xent(h, unembed, targets, mask, chunk, logit_softcap)
    return tot / jnp.maximum(cnt, 1.0)


def chunked_scan(body, carry, xs, chunk: int, remat: bool = True, unroll: int = 1):
    """O(sqrt(S))-memory scan: outer scan over chunks whose (optionally
    rematerialized) body runs an inner scan. Backward stores only chunk
    -boundary carries and recomputes within a chunk — the memory fix
    for long recurrent scans (Mamba2 / RWKV / LSTM time axes).

    xs leaves: (S, ...) with S % chunk == 0.
    """
    S = jax.tree.leaves(xs)[0].shape[0]
    if S % chunk or S <= chunk:
        return jax.lax.scan(body, carry, xs, unroll=unroll)
    n = S // chunk
    xs_c = jax.tree.map(lambda a: a.reshape((n, chunk) + a.shape[1:]), xs)

    def chunk_body(c, xc):
        return jax.lax.scan(body, c, xc, unroll=unroll)

    if remat:
        chunk_body = jax.checkpoint(chunk_body)
    carry, ys_c = jax.lax.scan(chunk_body, carry, xs_c)
    ys = jax.tree.map(lambda a: a.reshape((S,) + a.shape[2:]), ys_c)
    return carry, ys
