"""Attention: blockwise (online-softmax) causal/windowed GQA + decode.

Even the pure-jnp path is *blockwise* — a ``lax.scan`` over KV blocks
carrying the running (max, denominator, accumulator) — so prefill at
32k never materializes an S×S score matrix. This is the TPU-native
working-set formulation (HBM->VMEM thinking); the Pallas kernel in
``repro/kernels/flash_attention.py`` is the same algorithm with
explicit BlockSpec VMEM tiles, and this module is its oracle.

Decode attention (one query vs. a long cache) computes per-shard
partials; under pjit with the cache's sequence axis sharded over
``model``, the softmax's max/sum reductions lower to small all-reduces
(the flash-decode logsumexp merge) instead of cache all-gathers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, rms_norm

NEG_INF = -1.0e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    rope_theta: float = 10000.0
    qk_norm: bool = False
    use_bias: bool = False
    causal: bool = True
    window: Optional[int] = None        # sliding-window width (None = full)
    logit_softcap: float = 0.0
    query_scale: Optional[float] = None  # default 1/sqrt(head_dim)


def attn_init(key, cfg: AttnConfig, dtype=jnp.float32):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    H, Kv, D, M = cfg.n_heads, cfg.n_kv, cfg.head_dim, cfg.d_model
    p = {
        "wq": dense_init(k1, M, H * D, dtype),
        "wk": dense_init(k2, M, Kv * D, dtype),
        "wv": dense_init(k3, M, Kv * D, dtype),
        "wo": dense_init(k4, H * D, M, dtype),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((H * D,), dtype)
        p["bk"] = jnp.zeros((Kv * D,), dtype)
        p["bv"] = jnp.zeros((Kv * D,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((D,), dtype)
        p["k_norm"] = jnp.ones((D,), dtype)
    return p


def _project_qkv(p, cfg: AttnConfig, x, positions):
    """x: (B, S, M) -> q (B,S,H,D), k/v (B,S,Kv,D), rope applied."""
    B, S, _ = x.shape
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.use_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def blockwise_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_softcap: float = 0.0,
    q_offset: int = 0,
    block_kv: int = 512,
    query_scale: Optional[float] = None,
) -> jnp.ndarray:
    """Online-softmax attention. q: (B, Sq, H, D); k,v: (B, Sk, Kv, D).

    GQA via head grouping. Returns (B, Sq, H, D) in q.dtype.
    """
    B, Sq, H, D = q.shape
    Sk, Kv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // Kv
    scale = query_scale if query_scale is not None else D ** -0.5
    qf = q.astype(jnp.float32) * scale

    # GQA: repeat kv to H so every tensor keeps the head axis intact —
    # under pjit this preserves head-aligned model-parallel sharding
    # (a (Kv, G) reshape would split the sharded head dim and force
    # GSPMD to replicate the whole attention computation).
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)

    block_kv = min(block_kv, Sk)
    while Sk % block_kv:         # largest divisor of Sk at ~the target block
        block_kv -= 1
    n_blocks = Sk // block_kv

    kb = k.astype(jnp.float32).reshape(B, n_blocks, block_kv, H, D).swapaxes(0, 1)
    vb = v.astype(jnp.float32).reshape(B, n_blocks, block_kv, H, Dv).swapaxes(0, 1)
    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, blk_idx = inp
        k_pos = blk_idx * block_kv + jnp.arange(block_kv)
        # scores: (B, Sq, H, block_kv)
        s = jnp.einsum("bqhd,bjhd->bqhj", qf, kblk)
        if logit_softcap > 0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        mask = jnp.ones((Sq, block_kv), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[None, :, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard fully-masked rows (m_new == NEG_INF)
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, :, None, :], p, 0.0)
        corr = jnp.exp(jnp.where(m <= NEG_INF / 2, NEG_INF, m) - m_safe)
        corr = jnp.where(m <= NEG_INF / 2, 0.0, corr)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bqhj,bjhd->bqhd", p, vblk)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, H), NEG_INF)
    l0 = jnp.zeros((B, Sq, H))
    acc0 = jnp.zeros((B, Sq, H, Dv))
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, acc0),
                                  (kb, vb, jnp.arange(n_blocks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,
    *,
    window: Optional[int] = None,
    ring: bool = False,
    logit_softcap: float = 0.0,
    query_scale: Optional[float] = None,
) -> jnp.ndarray:
    """One-token attention. q: (B, H, D); caches: (B, S, Kv, D);
    pos: scalar int32 — index of the *current* token (already written).

    ``ring=True`` means the cache is a ring buffer of width S=window:
    slot j holds absolute position pos - ((pos - j) mod S).
    """
    B, H, D = q.shape
    S, Kv = k_cache.shape[1], k_cache.shape[2]
    G = H // Kv
    scale = query_scale if query_scale is not None else D ** -0.5
    # Grouped-query form: the cache stays (B, S, Kv, D) — decode's
    # parallel axis is the (model-sharded) sequence, so repeating kv to
    # H would force GSPMD to reshard multi-GB caches (observed); the
    # softmax's max/sum over the S shards lower to scalar-sized
    # all-reduces (the flash-decode logsumexp merge).
    qf = q.astype(jnp.float32).reshape(B, Kv, G, D) * scale
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    s = jnp.einsum("bkgd,bjkd->bkgj", qf, kf)          # (B, Kv, G, S)
    if logit_softcap > 0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    j = jnp.arange(S)
    if ring:
        abs_pos = pos - jnp.mod(pos - j, S)
    else:
        abs_pos = j
    valid = (abs_pos >= 0) & (abs_pos <= pos)
    if window is not None:
        valid &= abs_pos > pos - window
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(valid[None, None, None, :], p, 0.0)
    out = jnp.einsum("bkgj,bjkd->bkgd", p, vf) / jnp.maximum(
        p.sum(axis=-1), 1e-30
    )[..., None]
    return out.reshape(B, H, D).astype(q.dtype)


def attn_forward(p, cfg: AttnConfig, x, positions=None, block_kv: int = 512):
    """Full-sequence (train / prefill) attention. Returns (out, (k, v))."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _project_qkv(p, cfg, x, positions)
    o = blockwise_attention(
        q, k, v,
        causal=cfg.causal, window=cfg.window,
        logit_softcap=cfg.logit_softcap, block_kv=min(block_kv, S),
        query_scale=cfg.query_scale,
    )
    out = o.reshape(B, S, cfg.n_heads * cfg.head_dim) @ p["wo"].astype(x.dtype)
    return out, (k, v)


def attn_decode(p, cfg: AttnConfig, x, k_cache, v_cache, pos, ring: bool = False):
    """Single-token decode. x: (B, 1, M); caches (B, S, Kv, D); pos scalar.

    Writes the new token's k/v at slot (pos % S if ring else pos), then
    attends. Returns (out (B,1,M), k_cache, v_cache).
    """
    B = x.shape[0]
    S = k_cache.shape[1]
    positions = jnp.broadcast_to(pos[None], (B, 1)) if pos.ndim == 0 else pos
    q, k, v = _project_qkv(p, cfg, x, positions)
    slot = jnp.mod(pos, S) if ring else pos
    k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype), (0, slot, 0, 0))
    o = decode_attention(
        q[:, 0], k_cache, v_cache, pos,
        window=cfg.window, ring=ring, logit_softcap=cfg.logit_softcap,
        query_scale=cfg.query_scale,
    )
    out = o.reshape(B, 1, cfg.n_heads * cfg.head_dim) @ p["wo"].astype(x.dtype)
    return out, k_cache, v_cache
