"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV states are compressed into a rank-``kv_lora`` latent c_kv plus a
single shared RoPE key head; the cache stores only (c_kv, k_rope) —
(S, kv_lora + rope_dim) per token instead of (S, 2·H·D). Per-head
no-RoPE keys/values are re-expanded from the latent at attention time.
This is the architecture's whole point: the decode-time memory term of
the roofline drops by ~an order of magnitude vs. GQA.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, rms_norm

NEG_INF = -1.0e30


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    kv_lora: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128
    rope_theta: float = 10000.0


def mla_init(key, cfg: MLAConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    H = cfg.n_heads
    M = cfg.d_model
    return {
        "wq": dense_init(ks[0], M, H * (cfg.qk_nope_dim + cfg.qk_rope_dim), dtype),
        "w_dkv": dense_init(ks[1], M, cfg.kv_lora, dtype),          # down-proj latent
        "w_krope": dense_init(ks[2], M, cfg.qk_rope_dim, dtype),    # shared rope key
        "w_uk": dense_init(ks[3], cfg.kv_lora, H * cfg.qk_nope_dim, dtype),
        "w_uv": dense_init(ks[4], cfg.kv_lora, H * cfg.v_dim, dtype),
        "wo": dense_init(ks[5], H * cfg.v_dim, M, dtype),
        "kv_norm": jnp.ones((cfg.kv_lora,), dtype),
    }


def _queries(p, cfg: MLAConfig, x, positions):
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latents(p, cfg: MLAConfig, x, positions):
    c_kv = rms_norm(x @ p["w_dkv"].astype(x.dtype), p["kv_norm"])     # (B, S, R)
    k_rope = x @ p["w_krope"].astype(x.dtype)                          # (B, S, dr)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return c_kv, k_rope


def _expand(p, cfg: MLAConfig, c_kv):
    """latent (B, S, R) -> k_nope (B, S, H, dn), v (B, S, H, dv)."""
    B, S, _ = c_kv.shape
    H = cfg.n_heads
    k_nope = (c_kv @ p["w_uk"].astype(c_kv.dtype)).reshape(B, S, H, cfg.qk_nope_dim)
    v = (c_kv @ p["w_uv"].astype(c_kv.dtype)).reshape(B, S, H, cfg.v_dim)
    return k_nope, v


def mla_forward(p, cfg: MLAConfig, x, positions=None, block_kv: int = 512):
    """Full-sequence causal MLA. Returns (out, (c_kv, k_rope)) for caching."""
    from repro.models.attention import blockwise_attention

    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q_nope, q_rope = _queries(p, cfg, x, positions)
    c_kv, k_rope = _latents(p, cfg, x, positions)
    k_nope, v = _expand(p, cfg, c_kv)
    H = cfg.n_heads
    q = jnp.concatenate([q_nope, q_rope], axis=-1)                     # (B,S,H,dn+dr)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                                  (B, S, H, cfg.qk_rope_dim))], axis=-1)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    o = blockwise_attention(q, k, v, causal=True, block_kv=min(block_kv, S),
                            query_scale=scale)
    out = o.reshape(B, S, H * cfg.v_dim) @ p["wo"].astype(x.dtype)
    return out, (c_kv, k_rope)


def mla_decode(p, cfg: MLAConfig, x, ckv_cache, krope_cache, pos):
    """Single-token decode against the *compressed* cache.

    ckv_cache: (B, S, R); krope_cache: (B, S, dr); pos: scalar.
    Scores are computed in latent space via the absorbed-projection
    trick: q_nope^T k_nope = (q_nope W_uk^T) c_kv, so the per-head key
    never rematerializes over S. Values expand per-head after the
    softmax-weighted latent sum (another rank-R absorption).
    """
    B = x.shape[0]
    S, R = ckv_cache.shape[1], ckv_cache.shape[2]
    H, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_dim
    positions = jnp.broadcast_to(pos[None], (B, 1))
    q_nope, q_rope = _queries(p, cfg, x, positions)        # (B,1,H,dn),(B,1,H,dr)
    c_kv, k_rope = _latents(p, cfg, x, positions)          # (B,1,R),(B,1,dr)
    ckv_cache = jax.lax.dynamic_update_slice(ckv_cache, c_kv.astype(ckv_cache.dtype), (0, pos, 0))
    krope_cache = jax.lax.dynamic_update_slice(krope_cache, k_rope.astype(krope_cache.dtype), (0, pos, 0))

    # absorb W_uk into q: (B,H,dn) @ (R,H,dn)->(B,H,R)
    w_uk = p["w_uk"].astype(x.dtype).reshape(R, H, dn)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)
    s = jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.float32), ckv_cache.astype(jnp.float32))
    s = s + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                       krope_cache.astype(jnp.float32))
    s = s * ((dn + dr) ** -0.5)
    valid = jnp.arange(S) <= pos
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    lat = jnp.einsum("bhs,bsr->bhr", w, ckv_cache.astype(jnp.float32))  # (B,H,R)
    w_uv = p["w_uv"].astype(x.dtype).reshape(R, H, dv)
    o = jnp.einsum("bhr,rhd->bhd", lat.astype(x.dtype), w_uv)           # (B,H,dv)
    out = o.reshape(B, 1, H * dv) @ p["wo"].astype(x.dtype)
    return out, ckv_cache, krope_cache
