"""Mamba2 (state-space duality) block — the zamba2 substrate.

Faithful to the Mamba2 recurrence with scalar-per-head decay:
    h_t = exp(A · dt_t) · h_{t-1} + dt_t · (x_t ⊗ B_t)      h: (P, N)
    y_t = h_t C_t + D · x_t
with a depthwise causal conv over (x, B, C), softplus dt, and a gated
RMSNorm before out-projection. Training uses a time scan (the baseline;
the chunked SSD formulation is the §Perf optimization target) — decode
is the natural O(1)-state step, which is why the hybrid archs run
long_500k natively.

Projections are stored *per segment* (z / x / BC / dt) rather than as
one fused in_proj so the head-aligned dims (z, x, dt) can shard over
the mesh ``model`` axis while the head-shared B/C stay replicated —
the tensor-parallel layout a production Mamba uses. (XLA fuses the
segment matmuls back together where profitable.)
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_inner: int = 0         # default 2*d_model
    headdim: int = 64        # P
    d_state: int = 64        # N
    conv_width: int = 4

    def __post_init__(self):
        if self.d_inner == 0:
            object.__setattr__(self, "d_inner", 2 * self.d_model)

    @property
    def n_heads(self):
        assert self.d_inner % self.headdim == 0
        return self.d_inner // self.headdim


def mamba_init(key, cfg: MambaConfig, dtype=jnp.float32):
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 7)
    H, N = cfg.n_heads, cfg.d_state
    d_in = cfg.d_inner
    return {
        "in_z": dense_init(k1, cfg.d_model, d_in, dtype),
        "in_x": dense_init(k2, cfg.d_model, d_in, dtype),
        "in_bc": dense_init(k3, cfg.d_model, 2 * N, dtype),
        "in_dt": dense_init(k4, cfg.d_model, H, dtype),
        "conv_x_w": (jax.random.normal(k5, (cfg.conv_width, d_in)) * 0.1).astype(dtype),
        "conv_x_b": jnp.zeros((d_in,), dtype),
        "conv_bc_w": (jax.random.normal(k6, (cfg.conv_width, 2 * N)) * 0.1).astype(dtype),
        "conv_bc_b": jnp.zeros((2 * N,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dtype),
        "D": jnp.ones((H,), dtype),
        # Mamba2 convention: dt ~ 0.05 at init (softplus^-1); a zero
        # bias gives dt~0.7, whose 40+-step decay products underflow
        # and NaN the VJP for the fast heads.
        "dt_bias": jnp.full((H,), math.log(math.expm1(0.05)), dtype),
        "norm": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(k7, d_in, cfg.d_model, dtype),
    }


def _conv(w, b, x, conv_state=None):
    """Depthwise causal conv, width W. x: (B, S, C); returns (out, new
    left-context state (B, W-1, C)) — silu applied."""
    W = w.shape[0]
    if conv_state is None:
        xp = jnp.concatenate([jnp.zeros_like(x[:, : W - 1]), x], axis=1)
    else:
        xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w.astype(x.dtype)[i] for i in range(W))
    out = jax.nn.silu(out + b.astype(x.dtype))
    return out, xp[:, -(W - 1):]


def _project(p, cfg: MambaConfig, x, conv_states=None):
    """x (B, S, D) -> z, xin (B,S,H,P), Bc, Cc (B,S,N), dt (B,S,H), states."""
    B, S, _ = x.shape
    H, P, N = cfg.n_heads, cfg.headdim, cfg.d_state
    z = x @ p["in_z"].astype(x.dtype)
    xi = x @ p["in_x"].astype(x.dtype)
    bc = x @ p["in_bc"].astype(x.dtype)
    dt = x @ p["in_dt"].astype(x.dtype)
    cs_x = None if conv_states is None else conv_states["x"]
    cs_bc = None if conv_states is None else conv_states["bc"]
    xi, ns_x = _conv(p["conv_x_w"], p["conv_x_b"], xi, cs_x)
    bc, ns_bc = _conv(p["conv_bc_w"], p["conv_bc_b"], bc, cs_bc)
    xin = xi.reshape(B, S, H, P)
    Bc, Cc = bc[..., :N], bc[..., N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    return z, xin, Bc, Cc, dt, {"x": ns_x, "bc": ns_bc}


def mamba_forward(p, cfg: MambaConfig, x):
    """Full-sequence training forward. x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    H, P, N = cfg.n_heads, cfg.headdim, cfg.d_state
    z, xin, Bc, Cc, dt, _ = _project(p, cfg, x)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                # (H,)
    decay = jnp.exp(dt * A)                                     # (B,S,H)

    def step(h, inp):
        x_t, B_t, C_t, dec_t, dt_t = inp
        h = h * dec_t[..., None, None] + (dt_t[..., None] * x_t.astype(jnp.float32))[..., None] \
            * B_t.astype(jnp.float32)[:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", h, C_t.astype(jnp.float32))
        return h, y

    from repro.models.layers import chunked_scan

    xs = (xin.swapaxes(0, 1), Bc.swapaxes(0, 1), Cc.swapaxes(0, 1),
          decay.swapaxes(0, 1), dt.swapaxes(0, 1))
    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    _, ys = chunked_scan(step, h0, xs, chunk=64)                # (S, B, H, P)
    y = ys.swapaxes(0, 1) + p["D"].astype(jnp.float32)[None, None, :, None] \
        * xin.astype(jnp.float32)
    y = y.reshape(B, S, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["out_proj"].astype(x.dtype)


def mamba_init_state(cfg: MambaConfig, batch: int, dtype=jnp.float32):
    H, P, N = cfg.n_heads, cfg.headdim, cfg.d_state
    W = cfg.conv_width
    return {
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": {
            "x": jnp.zeros((batch, W - 1, cfg.d_inner), dtype),
            "bc": jnp.zeros((batch, W - 1, 2 * N), dtype),
        },
    }


def mamba_step(p, cfg: MambaConfig, x, state):
    """Single-token decode. x: (B, 1, D); state from mamba_init_state."""
    B = x.shape[0]
    H, P, N = cfg.n_heads, cfg.headdim, cfg.d_state
    z, xin, Bc, Cc, dt, conv_state = _project(p, cfg, x, conv_states=state["conv"])
    xin, Bc, Cc, dt = xin[:, 0], Bc[:, 0], Cc[:, 0], dt[:, 0]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)                                     # (B,H)
    h = state["ssm"] * decay[..., None, None] + (dt[..., None] * xin.astype(jnp.float32))[..., None] \
        * Bc.astype(jnp.float32)[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", h, Cc.astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xin.astype(jnp.float32)
    y = y.reshape(B, 1, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = y @ p["out_proj"].astype(x.dtype)
    new_conv = jax.tree.map(lambda a, b: a.astype(b.dtype), conv_state, state["conv"])
    return out, {"ssm": h, "conv": new_conv}


def mamba_forward_chunked(p, cfg: MambaConfig, x, chunk: int = 128):
    """Chunked SSD (state-space duality) forward — the MXU formulation.

    Mathematically identical to ``mamba_forward`` (same recurrence),
    restructured per Mamba2's SSD: within a Q-token chunk the output is
    an attention-like einsum
        y_t = C_t . (decay_t h_in) + sum_{tau<=t} Gamma[t,tau] dt_tau
              (C_t.B_tau) x_tau + D x_t,
        Gamma[t,tau] = exp(La_t - La_tau)   (cumulative log-decay)
    and states propagate chunk-to-chunk through a lax.scan of length
    S/chunk. Turns S sequential (P,N)-sized updates into S/Q einsums
    over (Q,Q) tiles — the compute becomes matmul-shaped and the HBM
    stream drops by ~Q (the §Perf optimization for the hybrid archs;
    exactness is tested against the scan path).
    """
    B, S, D = x.shape
    H, P, N = cfg.n_heads, cfg.headdim, cfg.d_state
    z, xin, Bc, Cc, dt, _ = _project(p, cfg, x)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                # (H,)

    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    n_chunks = S // Q

    # (n, B, Q, ...) chunked views, f32
    def ck(a):
        return a.reshape(B, n_chunks, Q, *a.shape[2:]).swapaxes(0, 1)

    xin_c = ck(xin.astype(jnp.float32))                         # (n,B,Q,H,P)
    B_c = ck(Bc.astype(jnp.float32))                            # (n,B,Q,N)
    C_c = ck(Cc.astype(jnp.float32))                            # (n,B,Q,N)
    dt_c = ck(dt)                                               # (n,B,Q,H)

    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk_step(h, inp):
        xq, Bq, Cq, dtq = inp                                   # per chunk
        la = jnp.cumsum(dtq * A, axis=1)                        # (B,Q,H) cumulative log decay
        # intra-chunk attention-like term
        cb = jnp.einsum("btn,bqn->btq", Cq, Bq)                 # (B,Q,Q) shared across heads
        gamma = jnp.exp(la[:, :, None, :] - la[:, None, :, :])  # (B,Q,Q,H)
        gamma = jnp.where(tri[None, :, :, None], gamma, 0.0)
        scores = cb[..., None] * gamma * dtq[:, None, :, :]     # (B,t,tau,H)
        y = jnp.einsum("btqh,bqhp->bthp", scores, xq)           # (B,Q,H,P)
        # cross-chunk: contribution of the carried state
        y = y + jnp.einsum("bqh,bhpn,bqn->bqhp", jnp.exp(la), h, Cq)
        # state update: h_out = exp(La_Q) h + sum_t exp(La_Q - La_t) dt_t x_t B_t
        wts = jnp.exp(la[:, -1:, :] - la) * dtq                 # (B,Q,H)
        h = h * jnp.exp(la[:, -1])[..., None, None] \
            + jnp.einsum("bqh,bqhp,bqn->bhpn", wts, xq, Bq)
        return h, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    _, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0,
                         (xin_c, B_c, C_c, dt_c))
    y = ys.swapaxes(0, 1).reshape(B, S, H, P)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xin.astype(jnp.float32)
    y = y.reshape(B, S, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["out_proj"].astype(x.dtype)
