"""Model zoo: the paper's RNN-T + the 10 assigned architectures.

Every model exposes the same functional interface (pure pytrees):
    init(key) -> params
    loss_fn(params, batch, rng) -> (loss, aux)
    prefill(params, batch) -> (logits, cache)
    decode_step(params, cache, tokens, pos) -> (logits, cache)
    init_cache(batch_size, seq_len) -> cache pytree
plus ``param_spec_rules()`` (path-regex -> PartitionSpec) for pjit.
"""
from repro.models.model_zoo import build_model, ModelBundle

__all__ = ["build_model", "ModelBundle"]
