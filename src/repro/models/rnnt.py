"""RNN-T — the paper's model (Fig. 1): LSTM audio encoder, LSTM label
encoder (prediction network), joint network, softmax over word-pieces.

The joint is the memory hot-spot: naive evaluation materializes
(B, T, U+1, V) logits (V=4096 in the paper). The training path
computes only the (blank, label) log-probs the transducer DP needs —
either via the fused Pallas kernel (repro/kernels/rnnt_joint.py) or
the U-chunked jnp reference here.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.asr.rnnt_loss import rnnt_loss_from_logprobs
from repro.asr.specaugment import SpecAugmentConfig, spec_augment
from repro.models.layers import dense_init, embed_init
from repro.models.lstm import LSTMConfig, lstm_stack, lstm_stack_init, lstm_stack_init_state, lstm_stack_step


@dataclasses.dataclass(frozen=True)
class RNNTConfig:
    name: str = "rnnt"
    feat_dim: int = 128
    vocab: int = 4096              # word-pieces; id 0 = blank
    enc_layers: int = 8
    enc_hidden: int = 1152
    pred_layers: int = 2
    pred_hidden: int = 1152
    pred_embed: int = 512
    joint_dim: int = 640
    time_stride: int = 1           # frame subsampling before the encoder
    specaug: SpecAugmentConfig = dataclasses.field(default_factory=SpecAugmentConfig)
    dtype: str = "float32"
    param_dtype: str = "float32"
    use_kernel: bool = False       # fused Pallas joint (interpret on CPU)
    loss_norm: bool = True         # per-label-token NLL normalization
    scan_unroll: int = 1           # LSTM scan unroll (weight amortization)
    scan_chunk: int = 0            # time-chunked remat scan (grad-buffer traffic)

    @property
    def cdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)


def init_params(cfg: RNNTConfig, key) -> dict:
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    dt = cfg.pdtype
    enc_in = cfg.feat_dim * cfg.time_stride
    return {
        "encoder": lstm_stack_init(k1, LSTMConfig(enc_in, cfg.enc_hidden, cfg.enc_layers), dt),
        "pred_embed": embed_init(k2, cfg.vocab, cfg.pred_embed, dt),
        "predictor": lstm_stack_init(k3, LSTMConfig(cfg.pred_embed, cfg.pred_hidden, cfg.pred_layers), dt),
        "joint_enc": dense_init(k4, cfg.enc_hidden, cfg.joint_dim, dt),
        "joint_pred": dense_init(k5, cfg.pred_hidden, cfg.joint_dim, dt),
        "joint_out": dense_init(k6, cfg.joint_dim, cfg.vocab, dt),
        "joint_bias": jnp.zeros((cfg.vocab,), dt),
    }


def encode(cfg: RNNTConfig, params, features):
    """features: (B, T, F) -> (B, T', enc_hidden)."""
    x = features.astype(cfg.cdtype)
    if cfg.time_stride > 1:
        B, T, F = x.shape
        T2 = T // cfg.time_stride
        x = x[:, : T2 * cfg.time_stride].reshape(B, T2, F * cfg.time_stride)
    out, _ = lstm_stack(params["encoder"], x, unroll=cfg.scan_unroll, chunk=cfg.scan_chunk)
    return out


def predict(cfg: RNNTConfig, params, labels):
    """labels: (B, U) -> (B, U+1, pred_hidden); position 0 is the
    blank-start state (zero embedding)."""
    B, U = labels.shape
    emb = params["pred_embed"].astype(cfg.cdtype)[labels]       # (B, U, E)
    emb = jnp.concatenate([jnp.zeros_like(emb[:, :1]), emb], axis=1)
    out, _ = lstm_stack(params["predictor"], emb, unroll=cfg.scan_unroll, chunk=cfg.scan_chunk)
    return out


def joint_logprobs_ref(cfg: RNNTConfig, params, enc, pred, labels, u_chunk: int = 8):
    """(blank_lp, label_lp): (B, T, U1) each, never materializing
    (B, T, U1, V) — scans over U1 in chunks (jnp oracle of the kernel)."""
    B, T, _ = enc.shape
    U1 = pred.shape[1]
    e = enc @ params["joint_enc"].astype(enc.dtype)             # (B, T, J)
    g = pred @ params["joint_pred"].astype(pred.dtype)          # (B, U1, J)
    w = params["joint_out"].astype(enc.dtype)
    b = params["joint_bias"].astype(jnp.float32)
    lbl = jnp.concatenate([labels, jnp.zeros((B, 1), labels.dtype)], axis=1)  # (B, U1)

    n_chunks = max(1, U1 // u_chunk)
    pad = (-U1) % n_chunks
    if pad:
        g = jnp.pad(g, ((0, 0), (0, pad), (0, 0)))
        lbl = jnp.pad(lbl, ((0, 0), (0, pad)))
    c = g.shape[1] // n_chunks
    gc = g.reshape(B, n_chunks, c, -1).swapaxes(0, 1)
    lc = lbl.reshape(B, n_chunks, c).swapaxes(0, 1)

    def body(_, inp):
        g_i, l_i = inp
        h = jnp.tanh(e[:, :, None, :] + g_i[:, None, :, :])    # (B, T, c, J)
        logits = (h @ w).astype(jnp.float32) + b               # (B, T, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        blank = logits[..., 0] - lse
        lab = jnp.take_along_axis(
            logits, l_i[:, None, :, None].astype(jnp.int32), axis=-1
        )[..., 0] - lse
        return None, (blank, lab)

    _, (blanks, labs) = jax.lax.scan(jax.checkpoint(body), None, (gc, lc))
    blank_lp = blanks.swapaxes(0, 1).reshape(B, T, -1)[:, :, :U1]
    label_lp = labs.swapaxes(0, 1).reshape(B, T, -1)[:, :, :U1]
    return blank_lp, label_lp


def joint_logits(cfg: RNNTConfig, params, enc_t, pred_u):
    """Pointwise joint for decoding. enc_t: (B, H); pred_u: (B, H) ->
    (B, V) logits."""
    e = enc_t @ params["joint_enc"].astype(enc_t.dtype)
    g = pred_u @ params["joint_pred"].astype(pred_u.dtype)
    h = jnp.tanh(e + g)
    return (h @ params["joint_out"].astype(h.dtype)).astype(jnp.float32) + \
        params["joint_bias"].astype(jnp.float32)


def loss_fn(cfg: RNNTConfig, params, batch, rng=None):
    """batch: features (B,T,F), labels (B,U), frame_len (B,), label_len (B,),
    optional weight (B,). Returns (mean loss, aux)."""
    feats = batch["features"]
    if rng is not None and cfg.specaug.enabled:
        feats = spec_augment(rng, feats, cfg.specaug)
    enc = encode(cfg, params, feats)
    pred = predict(cfg, params, batch["labels"])
    if cfg.use_kernel:
        from repro.kernels.ops import rnnt_joint
        e = enc @ params["joint_enc"].astype(enc.dtype)
        g = pred @ params["joint_pred"].astype(pred.dtype)
        lbl = jnp.concatenate(
            [batch["labels"], jnp.zeros((batch["labels"].shape[0], 1), batch["labels"].dtype)],
            axis=1)
        blank_lp, label_lp = rnnt_joint(
            e, g, params["joint_out"], params["joint_bias"], lbl)
    else:
        blank_lp, label_lp = joint_logprobs_ref(cfg, params, enc, pred, batch["labels"])
    frame_len = jnp.maximum(batch["frame_len"] // cfg.time_stride, 1)
    nll = rnnt_loss_from_logprobs(blank_lp, label_lp, frame_len, batch["label_len"])
    if cfg.loss_norm:
        nll = nll / jnp.maximum(batch["label_len"].astype(jnp.float32), 1.0)
    w = batch.get("weight", jnp.ones_like(nll))
    denom = jnp.maximum(w.sum(), 1.0)
    loss = (nll * w).sum() / denom
    return loss, {"nll": nll}


def greedy_decode(cfg: RNNTConfig, params, features, frame_len, max_symbols: int = 4):
    """Greedy transducer decode. Returns (B, T*max_symbols) padded token ids
    (0 = blank/pad). Small-scale (eval on the synthetic corpus)."""
    enc = encode(cfg, params, features)                 # (B, T, H)
    B, T, _ = enc.shape
    pcfg = LSTMConfig(cfg.pred_embed, cfg.pred_hidden, cfg.pred_layers)
    state0 = lstm_stack_init_state(pcfg, B, cfg.cdtype)
    # initial predictor output from the zero (start) embedding
    zero_emb = jnp.zeros((B, cfg.pred_embed), cfg.cdtype)
    g0, state0 = lstm_stack_step(params["predictor"], zero_emb, state0)

    def frame_body(carry, t):
        g, state, out, n_out = carry

        def symbol_body(c, _):
            g, state, out, n_out, done = c
            logits = joint_logits(cfg, params, enc[:, t], g)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)       # (B,)
            emit = (tok != 0) & ~done
            emb = params["pred_embed"].astype(cfg.cdtype)[tok]
            g_new, state_new = lstm_stack_step(params["predictor"], emb, state)
            g = jnp.where(emit[:, None], g_new, g)
            state = jax.tree.map(
                lambda new, old: jnp.where(emit.reshape((-1,) + (1,) * (new.ndim - 1)), new, old),
                state_new, state)
            out = out.at[jnp.arange(B), n_out].set(jnp.where(emit, tok, out[jnp.arange(B), n_out]))
            n_out = n_out + emit.astype(jnp.int32)
            done = done | ~emit
            return (g, state, out, n_out, done), None

        mask_t = (t < frame_len)
        (g2, state2, out2, n_out2, _), _ = jax.lax.scan(
            symbol_body, (g, state, out, n_out, jnp.zeros((B,), bool)),
            jnp.arange(max_symbols))
        g = jnp.where(mask_t[:, None], g2, g)
        state = jax.tree.map(
            lambda new, old: jnp.where(mask_t.reshape((-1,) + (1,) * (new.ndim - 1)), new, old),
            state2, state)
        out = jnp.where(mask_t[:, None], out2, out)
        n_out = jnp.where(mask_t, n_out2, n_out)
        return (g, state, out, n_out), None

    out0 = jnp.zeros((B, T * max_symbols), jnp.int32)
    (g, state, out, n_out), _ = jax.lax.scan(
        frame_body, (g0, state0, out0, jnp.zeros((B,), jnp.int32)), jnp.arange(T))
    return out
