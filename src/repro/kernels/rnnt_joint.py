"""Fused RNN-T joint Pallas TPU kernel — the paper-model's memory wall.

The naive joint materializes (B, T, U1, V) logits in HBM (V = 4096
word-pieces): for the paper's batches that tensor dwarfs everything
else in the step and its HBM round-trip dominates. On TPU this is a
capacity/bandwidth problem (not a CUDA-occupancy one), so the
adaptation is VMEM-resident fusion: tile the (T, U1) lattice, and for
each tile stream V in MXU-aligned slabs, computing

    h      = tanh(e_t + g_u)            (tq, tu, J)   VMEM scratch
    logits = h @ Wo[:, v0:v1] + b       (tq, tu, tv)  transient
    m, l   : running max / sum-exp      (tq, tu)      VMEM scratch
    blank  = logits[..., 0]             (tq, tu)
    label  = logits[..., labels[u]]     one-hot within the slab

and emitting only blank/label log-probs (B, T, U1, 2) — a V/2 (=2048x)
reduction in joint HBM traffic. Grid: (B, T/tq, U1/tu, V/tv) with the
vocab axis innermost/sequential carrying the scratch.

Backward: wrapped in ``jax.custom_vjp`` whose bwd re-materializes
through the U-chunked jnp reference (rematerialization keeps the
memory win during training); see ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(e_ref, g_ref, wo_ref, b_ref, lbl_ref,
            blank_ref, label_ref, lse_ref,
            h_ref, m_ref, l_ref, blk_ref, lab_ref, *,
            tv: int, n_v: int):
    vi = pl.program_id(3)

    @pl.when(vi == 0)
    def _init():
        h_ref[...] = jnp.tanh(
            e_ref[0].astype(jnp.float32)[:, None, :]
            + g_ref[0].astype(jnp.float32)[None, :, :])
        m_ref[...] = jnp.full_like(m_ref, -1.0e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        blk_ref[...] = jnp.zeros_like(blk_ref)
        lab_ref[...] = jnp.zeros_like(lab_ref)

    h = h_ref[...]  # (tq, tu, J)
    wo = wo_ref[...].astype(jnp.float32)  # (J, tv)
    logits = jax.lax.dot_general(
        h.reshape(-1, h.shape[-1]), wo,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(h.shape[0], h.shape[1], tv) + b_ref[...].astype(jnp.float32)

    # running log-sum-exp over the vocab axis
    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
    p = jnp.exp(logits - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1)
    m_ref[...] = m_new

    # blank logit lives in vocab slab 0, column 0
    @pl.when(vi == 0)
    def _blank():
        blk_ref[...] = logits[..., 0]

    # label logit: labels[u] may fall in this slab
    lbl = lbl_ref[0]  # (tu,) int32
    col = lbl - vi * tv  # position within slab
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (logits.shape[1], tv), 1)
              == col[:, None]).astype(jnp.float32)  # (tu, tv)
    lab_ref[...] += jnp.einsum("quv,uv->qu", logits, onehot)

    @pl.when(vi == n_v - 1)
    def _finalize():
        lse = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))
        blank_ref[0] = (blk_ref[...] - lse).astype(blank_ref.dtype)
        label_ref[0] = (lab_ref[...] - lse).astype(label_ref.dtype)
        lse_ref[0] = lse.astype(lse_ref.dtype)


def rnnt_joint_fused(
    enc_proj: jnp.ndarray,  # (B, T, J)  enc @ W_enc
    pred_proj: jnp.ndarray,  # (B, U1, J) pred @ W_pred
    w_out: jnp.ndarray,  # (J, V)
    bias: jnp.ndarray,  # (V,)
    labels: jnp.ndarray,  # (B, U1) int32 (labels[:, -1] unused)
    *,
    tq: int = 16,
    tu: int = 8,
    tv: int = 512,
    interpret: bool = False,
    return_lse: bool = False,
):
    """Returns (blank_lp, label_lp): (B, T, U1) log-probs.

    With ``return_lse`` also returns the per-lattice-point log-sum-exp
    (B, T, U1) — the backward kernels' recompute anchor (they rebuild
    softmax probabilities from the saved lse without a second max
    pass over the vocab axis)."""
    B, T, J = enc_proj.shape
    U1 = pred_proj.shape[1]
    V = w_out.shape[1]
    tq, tu, tv = min(tq, T), min(tu, U1), min(tv, V)
    assert T % tq == 0 and U1 % tu == 0 and V % tv == 0, (T, tq, U1, tu, V, tv)
    n_v = V // tv

    bias2d = bias.reshape(1, V)
    grid = (B, T // tq, U1 // tu, n_v)
    blank, label, lse = pl.pallas_call(
        functools.partial(_kernel, tv=tv, n_v=n_v),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, J), lambda b, ti, ui, vi: (b, ti, 0)),
            pl.BlockSpec((1, tu, J), lambda b, ti, ui, vi: (b, ui, 0)),
            pl.BlockSpec((J, tv), lambda b, ti, ui, vi: (0, vi)),
            pl.BlockSpec((1, tv), lambda b, ti, ui, vi: (0, vi)),
            pl.BlockSpec((1, tu), lambda b, ti, ui, vi: (b, ui)),
        ],
        out_specs=[
            pl.BlockSpec((1, tq, tu), lambda b, ti, ui, vi: (b, ti, ui)),
            pl.BlockSpec((1, tq, tu), lambda b, ti, ui, vi: (b, ti, ui)),
            pl.BlockSpec((1, tq, tu), lambda b, ti, ui, vi: (b, ti, ui)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, U1), jnp.float32),
            jax.ShapeDtypeStruct((B, T, U1), jnp.float32),
            jax.ShapeDtypeStruct((B, T, U1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tq, tu, J), jnp.float32),
            pltpu.VMEM((tq, tu), jnp.float32),
            pltpu.VMEM((tq, tu), jnp.float32),
            pltpu.VMEM((tq, tu), jnp.float32),
            pltpu.VMEM((tq, tu), jnp.float32),
        ],
        interpret=interpret,
    )(enc_proj, pred_proj, w_out, bias2d, labels.astype(jnp.int32))
    if return_lse:
        return blank, label, lse
    return blank, label


def _dlogits(h, wo_ref, b_ref, lse, dbl, dlb, lbl, vi, tv):
    """Softmax-cotangent slab shared by both backward kernels.

    dlogits_v = dblank * [v == 0] + dlabel * [v == labels[u]]
              - (dblank + dlabel) * p_v,     p_v = exp(logits_v - lse)

    The deltas are built as iota one-hots against the slab-local column
    index, so slabs not containing the blank (col 0) or the label column
    contribute only the -p_v term."""
    tq, tu = h.shape[0], h.shape[1]
    wo = wo_ref[...].astype(jnp.float32)  # (J, tv)
    logits = jax.lax.dot_general(
        h.reshape(-1, h.shape[-1]), wo,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(tq, tu, tv) + b_ref[...].astype(jnp.float32)
    p = jnp.exp(logits - lse[..., None])  # (tq, tu, tv)

    iota = jax.lax.broadcasted_iota(jnp.int32, (tu, tv), 1)
    blank_oh = (iota == -vi * tv).astype(jnp.float32)  # col 0, slab 0
    label_oh = (iota == (lbl - vi * tv)[:, None]).astype(jnp.float32)
    d = (-(dbl + dlb)[..., None] * p
         + dbl[..., None] * blank_oh[None]
         + dlb[..., None] * label_oh[None])  # (tq, tu, tv)
    return d


def _bwd_eg_kernel(e_ref, g_ref, wo_ref, b_ref, lbl_ref, lse_ref,
                   dbl_ref, dlb_ref,
                   de_ref, dgp_ref,
                   h_ref, dh_ref, *, tv: int, n_v: int):
    """Backward wrt the encoder/prediction projections.

    Grid (B, T/tq, U1/tu, V/tv), vocab innermost: dh accumulates over
    vocab slabs in VMEM scratch; at the last slab the tanh backward
    turns it into dpre, which folds into the (b, ti)-resident de block
    (accumulated across the whole U axis while the block stays in VMEM)
    and the per-(ti, ui) dg partial (summed over T outside — the dg
    output block leaves residency between ti revisits, so in-kernel
    accumulation over T would be unsound)."""
    ui = pl.program_id(2)
    vi = pl.program_id(3)

    @pl.when(jnp.logical_and(ui == 0, vi == 0))
    def _zero_de():
        de_ref[...] = jnp.zeros_like(de_ref)

    @pl.when(vi == 0)
    def _init():
        h_ref[...] = jnp.tanh(
            e_ref[0].astype(jnp.float32)[:, None, :]
            + g_ref[0].astype(jnp.float32)[None, :, :])
        dh_ref[...] = jnp.zeros_like(dh_ref)

    h = h_ref[...]  # (tq, tu, J)
    d = _dlogits(h, wo_ref, b_ref, lse_ref[0],
                 dbl_ref[0], dlb_ref[0], lbl_ref[0], vi, tv)
    dh_ref[...] += jax.lax.dot_general(
        d.reshape(-1, tv), wo_ref[...].astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(h.shape)

    @pl.when(vi == n_v - 1)
    def _finalize():
        dpre = dh_ref[...] * (1.0 - h * h)  # (tq, tu, J)
        de_ref[0] += jnp.sum(dpre, axis=1)  # (tq, J)
        dgp_ref[0, 0] = jnp.sum(dpre, axis=0)  # (tu, J)


def _bwd_w_kernel(e_ref, g_ref, wo_ref, b_ref, lbl_ref, lse_ref,
                  dbl_ref, dlb_ref,
                  dw_ref, db_ref, *, tv: int):
    """Backward wrt the output projection / bias.

    Grid (V/tv, B, T/tq, U1/tu), vocab OUTERMOST: the (J, tv) dW slab
    and (1, tv) db slab stay VMEM-resident while the whole (b, t, u)
    lattice streams past, so each vocab slab is accumulated exactly once
    with no HBM-revisit hazard (the mirror of the eg-kernel's ordering,
    which must keep vocab innermost for the lse recompute)."""
    bi = pl.program_id(1)
    ti = pl.program_id(2)
    ui = pl.program_id(3)
    vi = pl.program_id(0)

    @pl.when(jnp.logical_and(bi == 0, jnp.logical_and(ti == 0, ui == 0)))
    def _zero():
        dw_ref[...] = jnp.zeros_like(dw_ref)
        db_ref[...] = jnp.zeros_like(db_ref)

    h = jnp.tanh(
        e_ref[0].astype(jnp.float32)[:, None, :]
        + g_ref[0].astype(jnp.float32)[None, :, :])  # (tq, tu, J)
    d = _dlogits(h, wo_ref, b_ref, lse_ref[0],
                 dbl_ref[0], dlb_ref[0], lbl_ref[0], vi, tv)
    dw_ref[...] += jax.lax.dot_general(
        h.reshape(-1, h.shape[-1]), d.reshape(-1, tv),
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (J, tv)
    db_ref[0] += jnp.sum(d, axis=(0, 1))  # (tv,)


def rnnt_joint_bwd_fused(
    enc_proj: jnp.ndarray,  # (B, T, J)
    pred_proj: jnp.ndarray,  # (B, U1, J)
    w_out: jnp.ndarray,  # (J, V)
    bias: jnp.ndarray,  # (V,)
    labels: jnp.ndarray,  # (B, U1) int32
    lse: jnp.ndarray,  # (B, T, U1) saved by the forward
    dblank: jnp.ndarray,  # (B, T, U1) cotangent of blank_lp
    dlabel: jnp.ndarray,  # (B, T, U1) cotangent of label_lp
    *,
    tq: int = 16,
    tu: int = 8,
    tv: int = 512,
    interpret: bool = False,
):
    """Fused-backward of :func:`rnnt_joint_fused`.

    Recomputes the joint tile (h = tanh(e + g), slab logits) in VMEM
    with the same (tq, tu, tv) bucketing as the forward — the (B, T,
    U1, V) logits tensor never exists in HBM in either direction.
    Returns (d_enc_proj, d_pred_proj, d_w_out, d_bias) in float32."""
    B, T, J = enc_proj.shape
    U1 = pred_proj.shape[1]
    V = w_out.shape[1]
    tq, tu, tv = min(tq, T), min(tu, U1), min(tv, V)
    assert T % tq == 0 and U1 % tu == 0 and V % tv == 0, (T, tq, U1, tu, V, tv)
    n_v = V // tv

    bias2d = bias.reshape(1, V)
    labels = labels.astype(jnp.int32)

    de, dg_part = pl.pallas_call(
        functools.partial(_bwd_eg_kernel, tv=tv, n_v=n_v),
        grid=(B, T // tq, U1 // tu, n_v),
        in_specs=[
            pl.BlockSpec((1, tq, J), lambda b, ti, ui, vi: (b, ti, 0)),
            pl.BlockSpec((1, tu, J), lambda b, ti, ui, vi: (b, ui, 0)),
            pl.BlockSpec((J, tv), lambda b, ti, ui, vi: (0, vi)),
            pl.BlockSpec((1, tv), lambda b, ti, ui, vi: (0, vi)),
            pl.BlockSpec((1, tu), lambda b, ti, ui, vi: (b, ui)),
            pl.BlockSpec((1, tq, tu), lambda b, ti, ui, vi: (b, ti, ui)),
            pl.BlockSpec((1, tq, tu), lambda b, ti, ui, vi: (b, ti, ui)),
            pl.BlockSpec((1, tq, tu), lambda b, ti, ui, vi: (b, ti, ui)),
        ],
        out_specs=[
            pl.BlockSpec((1, tq, J), lambda b, ti, ui, vi: (b, ti, 0)),
            pl.BlockSpec((1, 1, tu, J), lambda b, ti, ui, vi: (b, ti, ui, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, J), jnp.float32),
            jax.ShapeDtypeStruct((B, T // tq, U1, J), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tq, tu, J), jnp.float32),
            pltpu.VMEM((tq, tu, J), jnp.float32),
        ],
        interpret=interpret,
    )(enc_proj, pred_proj, w_out, bias2d, labels, lse, dblank, dlabel)
    dg = jnp.sum(dg_part, axis=1)  # (B, U1, J)

    dw, db2d = pl.pallas_call(
        functools.partial(_bwd_w_kernel, tv=tv),
        grid=(n_v, B, T // tq, U1 // tu),
        in_specs=[
            pl.BlockSpec((1, tq, J), lambda vi, b, ti, ui: (b, ti, 0)),
            pl.BlockSpec((1, tu, J), lambda vi, b, ti, ui: (b, ui, 0)),
            pl.BlockSpec((J, tv), lambda vi, b, ti, ui: (0, vi)),
            pl.BlockSpec((1, tv), lambda vi, b, ti, ui: (0, vi)),
            pl.BlockSpec((1, tu), lambda vi, b, ti, ui: (b, ui)),
            pl.BlockSpec((1, tq, tu), lambda vi, b, ti, ui: (b, ti, ui)),
            pl.BlockSpec((1, tq, tu), lambda vi, b, ti, ui: (b, ti, ui)),
            pl.BlockSpec((1, tq, tu), lambda vi, b, ti, ui: (b, ti, ui)),
        ],
        out_specs=[
            pl.BlockSpec((J, tv), lambda vi, b, ti, ui: (0, vi)),
            pl.BlockSpec((1, tv), lambda vi, b, ti, ui: (0, vi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((J, V), jnp.float32),
            jax.ShapeDtypeStruct((1, V), jnp.float32),
        ],
        interpret=interpret,
    )(enc_proj, pred_proj, w_out, bias2d, labels, lse, dblank, dlabel)
    return de, dg, dw, db2d.reshape(V)
