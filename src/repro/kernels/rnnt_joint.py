"""Fused RNN-T joint Pallas TPU kernel — the paper-model's memory wall.

The naive joint materializes (B, T, U1, V) logits in HBM (V = 4096
word-pieces): for the paper's batches that tensor dwarfs everything
else in the step and its HBM round-trip dominates. On TPU this is a
capacity/bandwidth problem (not a CUDA-occupancy one), so the
adaptation is VMEM-resident fusion: tile the (T, U1) lattice, and for
each tile stream V in MXU-aligned slabs, computing

    h      = tanh(e_t + g_u)            (tq, tu, J)   VMEM scratch
    logits = h @ Wo[:, v0:v1] + b       (tq, tu, tv)  transient
    m, l   : running max / sum-exp      (tq, tu)      VMEM scratch
    blank  = logits[..., 0]             (tq, tu)
    label  = logits[..., labels[u]]     one-hot within the slab

and emitting only blank/label log-probs (B, T, U1, 2) — a V/2 (=2048x)
reduction in joint HBM traffic. Grid: (B, T/tq, U1/tu, V/tv) with the
vocab axis innermost/sequential carrying the scratch.

Backward: wrapped in ``jax.custom_vjp`` whose bwd re-materializes
through the U-chunked jnp reference (rematerialization keeps the
memory win during training); see ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(e_ref, g_ref, wo_ref, b_ref, lbl_ref,
            blank_ref, label_ref,
            h_ref, m_ref, l_ref, blk_ref, lab_ref, *,
            tv: int, n_v: int):
    vi = pl.program_id(3)

    @pl.when(vi == 0)
    def _init():
        h_ref[...] = jnp.tanh(
            e_ref[0].astype(jnp.float32)[:, None, :]
            + g_ref[0].astype(jnp.float32)[None, :, :])
        m_ref[...] = jnp.full_like(m_ref, -1.0e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        blk_ref[...] = jnp.zeros_like(blk_ref)
        lab_ref[...] = jnp.zeros_like(lab_ref)

    h = h_ref[...]                                             # (tq, tu, J)
    wo = wo_ref[...].astype(jnp.float32)                       # (J, tv)
    logits = jax.lax.dot_general(
        h.reshape(-1, h.shape[-1]), wo,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(h.shape[0], h.shape[1], tv) + b_ref[...].astype(jnp.float32)

    # running log-sum-exp over the vocab axis
    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
    p = jnp.exp(logits - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1)
    m_ref[...] = m_new

    # blank logit lives in vocab slab 0, column 0
    @pl.when(vi == 0)
    def _blank():
        blk_ref[...] = logits[..., 0]

    # label logit: labels[u] may fall in this slab
    lbl = lbl_ref[0]                                           # (tu,) int32
    col = lbl - vi * tv                                        # position within slab
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (logits.shape[1], tv), 1)
              == col[:, None]).astype(jnp.float32)             # (tu, tv)
    lab_ref[...] += jnp.einsum("quv,uv->qu", logits, onehot)

    @pl.when(vi == n_v - 1)
    def _finalize():
        lse = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))
        blank_ref[0] = (blk_ref[...] - lse).astype(blank_ref.dtype)
        label_ref[0] = (lab_ref[...] - lse).astype(label_ref.dtype)


def rnnt_joint_fused(
    enc_proj: jnp.ndarray,      # (B, T, J)  enc @ W_enc
    pred_proj: jnp.ndarray,     # (B, U1, J) pred @ W_pred
    w_out: jnp.ndarray,         # (J, V)
    bias: jnp.ndarray,          # (V,)
    labels: jnp.ndarray,        # (B, U1) int32 (labels[:, -1] unused)
    *,
    tq: int = 16,
    tu: int = 8,
    tv: int = 512,
    interpret: bool = False,
):
    """Returns (blank_lp, label_lp): (B, T, U1) log-probs."""
    B, T, J = enc_proj.shape
    U1 = pred_proj.shape[1]
    V = w_out.shape[1]
    tq, tu, tv = min(tq, T), min(tu, U1), min(tv, V)
    assert T % tq == 0 and U1 % tu == 0 and V % tv == 0, (T, tq, U1, tu, V, tv)
    n_v = V // tv

    bias2d = bias.reshape(1, V)
    grid = (B, T // tq, U1 // tu, n_v)
    blank, label = pl.pallas_call(
        functools.partial(_kernel, tv=tv, n_v=n_v),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, J), lambda b, ti, ui, vi: (b, ti, 0)),
            pl.BlockSpec((1, tu, J), lambda b, ti, ui, vi: (b, ui, 0)),
            pl.BlockSpec((J, tv), lambda b, ti, ui, vi: (0, vi)),
            pl.BlockSpec((1, tv), lambda b, ti, ui, vi: (0, vi)),
            pl.BlockSpec((1, tu), lambda b, ti, ui, vi: (b, ui)),
        ],
        out_specs=[
            pl.BlockSpec((1, tq, tu), lambda b, ti, ui, vi: (b, ti, ui)),
            pl.BlockSpec((1, tq, tu), lambda b, ti, ui, vi: (b, ti, ui)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, U1), jnp.float32),
            jax.ShapeDtypeStruct((B, T, U1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tq, tu, J), jnp.float32),
            pltpu.VMEM((tq, tu), jnp.float32),
            pltpu.VMEM((tq, tu), jnp.float32),
            pltpu.VMEM((tq, tu), jnp.float32),
            pltpu.VMEM((tq, tu), jnp.float32),
        ],
        interpret=interpret,
    )(enc_proj, pred_proj, w_out, bias2d, labels.astype(jnp.int32))
    return blank, label
