"""Flash attention (causal / sliding-window / GQA) Pallas TPU kernel.

TPU-native adaptation: HBM->VMEM tiles are explicit BlockSpecs, the
(tq, tk) score tile and the (tq, D) accumulator live in VMEM scratch
persisted across the sequential k-grid dimension, and all matmul dims
are MXU-aligned (tiles are multiples of 128 where shapes allow). GQA
is expressed in the index_map: kv blocks for q-head h come from kv
head h // (H // Kv) — no KV replication in HBM.

Grid: (B * H, Sq / tq, Sk / tk); the kv axis is innermost and
sequential, carrying (m, l, acc) scratch — the online-softmax
recurrence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, window: int, scale: float, tq: int, tk: int,
            n_k: int, logit_softcap: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (tq, D)
    k = k_ref[0].astype(jnp.float32)                  # (tk, D)
    v = v_ref[0].astype(jnp.float32)                  # (tk, Dv)
    s = q @ k.T                                       # (tq, tk)
    if logit_softcap > 0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)

    q_pos = qi * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
    k_pos = ki * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    acc_new = acc_prev * corr[:, None] + p @ v
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,             # (B, Sq, H, D)
    k: jnp.ndarray,             # (B, Sk, Kv, D)
    v: jnp.ndarray,             # (B, Sk, Kv, Dv)
    *,
    causal: bool = True,
    window: int = 0,            # 0 = no window
    logit_softcap: float = 0.0,
    tq: int = 128,
    tk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Sq, H, D = q.shape
    Sk, Kv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // Kv
    tq = min(tq, Sq)
    tk = min(tk, Sk)
    assert Sq % tq == 0 and Sk % tk == 0, (Sq, tq, Sk, tk)
    n_q, n_k = Sq // tq, Sk // tk
    scale = D ** -0.5

    # layouts: q -> (B*H, Sq, D); kv -> (B*Kv, Sk, D)
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Kv, Sk, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Kv, Sk, Dv)

    def q_map(h, qi, ki):
        return (h, qi, 0)

    def kv_map(h, qi, ki):
        return ((h // H) * Kv + (h % H) // G, ki, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, causal=causal, window=window, scale=scale,
                          tq=tq, tk=tk, n_k=n_k, logit_softcap=logit_softcap),
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, tq, D), q_map),
            pl.BlockSpec((1, tk, D), kv_map),
            pl.BlockSpec((1, tk, Dv), kv_map),
        ],
        out_specs=pl.BlockSpec((1, tq, Dv), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq,), jnp.float32),
            pltpu.VMEM((tq,), jnp.float32),
            pltpu.VMEM((tq, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, Sq, Dv).transpose(0, 2, 1, 3)
