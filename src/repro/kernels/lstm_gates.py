"""Fused LSTM gate nonlinearities + state update (Pallas TPU).

The per-step LSTM cell after the matmuls is four sigmoids/tanhs and
two multiplies over (B, H) — on TPU a chain of small VPU ops whose
HBM round-trips between unfused HLOs dominate the step at decode
batch sizes. The kernel fuses them in one VMEM-resident pass.
Gates layout: (B, 4, H) [i | f | g | o]; grid tiles (B, H).

``lstm_gates_fused_vjp`` adds a custom-VJP wrapper whose backward is a
second fused kernel: it saves only (gates, c) — the matmul outputs the
training graph keeps alive anyway — recomputes the four cheap
activations in VMEM and emits (dgates, dc_prev) in one pass. Without
it, autodiff through the cell stores every intermediate activation
(i, f, g, o, c_new, tanh(c_new): 6 extra (B, H) residuals *per scan
step*) and replays the chain as ~a dozen unfused HLOs; the LSTM cell
dominates the per-client ``lax.scan`` inside the federated round's
vmapped local steps, so this backward is the round step's hottest
gradient path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(g_ref, c_ref, h_out_ref, c_out_ref):
    g = g_ref[0].astype(jnp.float32)  # (4, th)... block (1, 4, th)
    c = c_ref[0].astype(jnp.float32)  # (th,)  block (1, th)
    i = jax.nn.sigmoid(g[0])
    f = jax.nn.sigmoid(g[1] + 1.0)
    gg = jnp.tanh(g[2])
    o = jax.nn.sigmoid(g[3])
    c_new = f * c + i * gg
    h_new = o * jnp.tanh(c_new)
    h_out_ref[0] = h_new.astype(h_out_ref.dtype)
    c_out_ref[0] = c_new.astype(c_out_ref.dtype)


def lstm_gates_fused(
    gates: jnp.ndarray, c: jnp.ndarray, *, th: int = 256, interpret: bool = False
):
    """gates: (B, 4H) preactivations [i|f|g|o]; c: (B, H).
    Returns (h_new, c_new) matching ref.lstm_gates_ref."""
    B, H4 = gates.shape
    H = H4 // 4
    th = min(th, H)
    assert H % th == 0, (H, th)
    g3 = gates.reshape(B, 4, H)

    h_new, c_new = pl.pallas_call(
        _kernel,
        grid=(B, H // th),
        in_specs=[
            pl.BlockSpec((1, 4, th), lambda b, hi: (b, 0, hi)),
            pl.BlockSpec((1, th), lambda b, hi: (b, hi)),
        ],
        out_specs=[
            pl.BlockSpec((1, th), lambda b, hi: (b, hi)),
            pl.BlockSpec((1, th), lambda b, hi: (b, hi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H), gates.dtype),
            jax.ShapeDtypeStruct((B, H), c.dtype),
        ],
        interpret=interpret,
    )(g3, c)
    return h_new, c_new


def _bwd_kernel(g_ref, c_ref, dh_ref, dcn_ref, dg_ref, dc_ref):
    g = g_ref[0].astype(jnp.float32)  # (4, th)
    c = c_ref[0].astype(jnp.float32)  # (th,)
    dh = dh_ref[0].astype(jnp.float32)
    dcn = dcn_ref[0].astype(jnp.float32)
    i = jax.nn.sigmoid(g[0])
    f = jax.nn.sigmoid(g[1] + 1.0)
    gg = jnp.tanh(g[2])
    o = jax.nn.sigmoid(g[3])
    t = jnp.tanh(f * c + i * gg)  # tanh(c_new), recomputed in VMEM
    dc = dcn + dh * o * (1.0 - t * t)
    dg_ref[0, 0] = (dc * gg * i * (1.0 - i)).astype(dg_ref.dtype)
    dg_ref[0, 1] = (dc * c * f * (1.0 - f)).astype(dg_ref.dtype)
    dg_ref[0, 2] = (dc * i * (1.0 - gg * gg)).astype(dg_ref.dtype)
    dg_ref[0, 3] = (dh * t * o * (1.0 - o)).astype(dg_ref.dtype)
    dc_ref[0] = (dc * f).astype(dc_ref.dtype)


def lstm_gates_bwd_fused(gates, c, dh, dc_next, *, th: int = 256, interpret: bool = False):
    """Fused backward of the cell: (gates, c, dh, dc_next) ->
    (dgates (B, 4H), dc_prev (B, H)) in one VMEM pass, recomputing the
    activations from the saved pre-activations instead of storing six
    per-step residual tensors."""
    B, H4 = gates.shape
    H = H4 // 4
    th = min(th, H)
    assert H % th == 0, (H, th)
    g3 = gates.reshape(B, 4, H)

    dg3, dc_prev = pl.pallas_call(
        _bwd_kernel,
        grid=(B, H // th),
        in_specs=[
            pl.BlockSpec((1, 4, th), lambda b, hi: (b, 0, hi)),
            pl.BlockSpec((1, th), lambda b, hi: (b, hi)),
            pl.BlockSpec((1, th), lambda b, hi: (b, hi)),
            pl.BlockSpec((1, th), lambda b, hi: (b, hi)),
        ],
        out_specs=[
            pl.BlockSpec((1, 4, th), lambda b, hi: (b, 0, hi)),
            pl.BlockSpec((1, th), lambda b, hi: (b, hi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 4, H), gates.dtype),
            jax.ShapeDtypeStruct((B, H), c.dtype),
        ],
        interpret=interpret,
    )(g3, c, dh, dc_next)
    return dg3.reshape(B, H4), dc_prev


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _lstm_gates_vjp(gates, c, th, interpret):
    return lstm_gates_fused(gates, c, th=th, interpret=interpret)


def _lstm_gates_fwd(gates, c, th, interpret):
    out = lstm_gates_fused(gates, c, th=th, interpret=interpret)
    return out, (gates, c)


def _lstm_gates_bwd(th, interpret, res, cts):
    gates, c = res
    dh, dc_next = cts
    return lstm_gates_bwd_fused(gates, c, dh, dc_next, th=th, interpret=interpret)


_lstm_gates_vjp.defvjp(_lstm_gates_fwd, _lstm_gates_bwd)


def lstm_gates_fused_vjp(gates, c, *, th: int = 256, interpret: bool = False):
    """The training-path entry point: fused forward AND fused custom-VJP
    backward (autodiff through the raw ``pallas_call`` is unsupported,
    and the unfused jnp backward is the round step's hot spot)."""
    return _lstm_gates_vjp(gates, c, th, interpret)


# ---------------------------------------------------------- full-scan kernel
# The per-step gates kernel above still re-streams w_hh (H x 4H) from
# HBM every scan iteration — at the paper's hidden sizes that refetch
# is the LSTM layer's dominant HBM traffic. The scan kernel below runs
# the WHOLE sequence in one pallas_call with grid=(S,): w_hh is a
# constant-index input block (fetched once, VMEM-resident for all S
# steps — TPU grids are sequential, so revisited blocks stay put), the
# (h, c) carry lives in VMEM scratch, and each step does one (B, H) x
# (H, 4H) MXU matmul plus the fused gate math. The backward is a second
# scan kernel over the reversed grid that recomputes each step's gate
# preactivations in VMEM from the saved (ys, cs) sequences — only two
# (S, B, H) residuals instead of autodiff's ~six per-step activation
# tensors — and accumulates dw_hh in a VMEM scratch written once at the
# end.


from jax.experimental.pallas import tpu as pltpu  # noqa: E402


def _split_gates(gates, H: int):
    i = jax.nn.sigmoid(gates[:, :H])
    f = jax.nn.sigmoid(gates[:, H : 2 * H] + 1.0)
    g = jnp.tanh(gates[:, 2 * H : 3 * H])
    o = jax.nn.sigmoid(gates[:, 3 * H :])
    return i, f, g, o


def _scan_kernel(xg_ref, whh_ref, h0_ref, c0_ref, ys_ref, cs_ref, h_s, c_s):
    s = pl.program_id(0)
    H = whh_ref.shape[0]

    @pl.when(s == 0)
    def _init():
        h_s[...] = h0_ref[...].astype(jnp.float32)
        c_s[...] = c0_ref[...].astype(jnp.float32)

    h, c = h_s[...], c_s[...]
    gates = xg_ref[0].astype(jnp.float32) + jax.lax.dot_general(
        h, whh_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    i, f, g, o = _split_gates(gates, H)
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    h_s[...] = h_new
    c_s[...] = c_new
    ys_ref[0] = h_new.astype(ys_ref.dtype)
    cs_ref[0] = c_new.astype(cs_ref.dtype)


def lstm_scan_fused(xg, w_hh, h0, c0, *, interpret: bool = False):
    """xg: (S, B, 4H) time-major hoisted input preactivations (x @ w_ih
    + b); w_hh: (H, 4H); h0, c0: (B, H). Returns (ys, cs): (S, B, H)
    hidden and cell sequences (cs is the backward's recompute anchor —
    the training graph keeps ys alive anyway)."""
    S, B, H4 = xg.shape
    H = H4 // 4
    ys, cs = pl.pallas_call(
        _scan_kernel,
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, B, H4), lambda s: (s, 0, 0)),
            pl.BlockSpec((H, H4), lambda s: (0, 0)),
            pl.BlockSpec((B, H), lambda s: (0, 0)),
            pl.BlockSpec((B, H), lambda s: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, B, H), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, B, H), lambda s: (s, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, B, H), xg.dtype),
            jax.ShapeDtypeStruct((S, B, H), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((B, H), jnp.float32),
            pltpu.VMEM((B, H), jnp.float32),
        ],
        interpret=interpret,
    )(xg, w_hh, h0, c0)
    return ys, cs


def _scan_bwd_kernel(
    xg_ref, whh_ref, h0_ref, c0_ref, ysp_ref, csp_ref, dys_ref, dhT_ref, dcT_ref,
    dxg_ref, dwhh_ref, dh0_ref, dc0_ref,
    dh_s, dc_s, dw_s,
):
    s = pl.program_id(0)
    S = pl.num_programs(0)
    t = S - 1 - s
    H = whh_ref.shape[0]
    whh = whh_ref[...].astype(jnp.float32)

    @pl.when(s == 0)
    def _init():
        dh_s[...] = dhT_ref[...].astype(jnp.float32)
        dc_s[...] = dcT_ref[...].astype(jnp.float32)
        dw_s[...] = jnp.zeros_like(dw_s)

    # step-(t-1) carry, read from the saved sequences (blocks indexed at
    # max(t-1, 0)); at t == 0 the true predecessor is the initial state
    first = t == 0
    h_prev = jnp.where(first, h0_ref[...].astype(jnp.float32),
                       ysp_ref[0].astype(jnp.float32))
    c_prev = jnp.where(first, c0_ref[...].astype(jnp.float32),
                       csp_ref[0].astype(jnp.float32))

    # recompute this step's gate preactivations in VMEM
    gates = xg_ref[0].astype(jnp.float32) + jax.lax.dot_general(
        h_prev, whh, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    i, f, g, o = _split_gates(gates, H)
    c_t = f * c_prev + i * g
    tct = jnp.tanh(c_t)

    dh = dh_s[...] + dys_ref[0].astype(jnp.float32)
    dc = dc_s[...] + dh * o * (1.0 - tct * tct)
    dgates = jnp.concatenate(
        [
            dc * g * i * (1.0 - i),
            dc * c_prev * f * (1.0 - f),
            dc * i * (1.0 - g * g),
            dh * tct * o * (1.0 - o),
        ],
        axis=-1,
    )  # (B, 4H)
    dxg_ref[0] = dgates.astype(dxg_ref.dtype)
    dw_s[...] += jax.lax.dot_general(
        h_prev, dgates, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (H, 4H)
    dh_s[...] = jax.lax.dot_general(
        dgates, whh, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (B, H)
    dc_s[...] = dc * f

    @pl.when(s == S - 1)
    def _final():
        dwhh_ref[...] = dw_s[...].astype(dwhh_ref.dtype)
        dh0_ref[...] = dh_s[...].astype(dh0_ref.dtype)
        dc0_ref[...] = dc_s[...].astype(dc0_ref.dtype)


def lstm_scan_bwd_fused(xg, w_hh, h0, c0, ys, cs, dys, dhT, dcT, *, interpret: bool = False):
    """Reversed-grid backward of ``lstm_scan_fused``: one grid step per
    time step t = S-1..0, gate preactivations recomputed in VMEM from
    (xg, ys, cs), dw_hh accumulated in VMEM scratch and written once.
    Returns (dxg, dw_hh, dh0, dc0)."""
    S, B, H4 = xg.shape
    H = H4 // 4

    def rev(s):
        return S - 1 - s

    def prev(s):
        return jnp.maximum(S - 2 - s, 0)

    dxg, dwhh, dh0, dc0 = pl.pallas_call(
        _scan_bwd_kernel,
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, B, H4), lambda s: (rev(s), 0, 0)),
            pl.BlockSpec((H, H4), lambda s: (0, 0)),
            pl.BlockSpec((B, H), lambda s: (0, 0)),
            pl.BlockSpec((B, H), lambda s: (0, 0)),
            pl.BlockSpec((1, B, H), lambda s: (prev(s), 0, 0)),
            pl.BlockSpec((1, B, H), lambda s: (prev(s), 0, 0)),
            pl.BlockSpec((1, B, H), lambda s: (rev(s), 0, 0)),
            pl.BlockSpec((B, H), lambda s: (0, 0)),
            pl.BlockSpec((B, H), lambda s: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, B, H4), lambda s: (rev(s), 0, 0)),
            pl.BlockSpec((H, H4), lambda s: (0, 0)),
            pl.BlockSpec((B, H), lambda s: (0, 0)),
            pl.BlockSpec((B, H), lambda s: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, B, H4), jnp.float32),
            jax.ShapeDtypeStruct((H, H4), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((B, H), jnp.float32),
            pltpu.VMEM((B, H), jnp.float32),
            pltpu.VMEM((H, H4), jnp.float32),
        ],
        interpret=interpret,
    )(xg, w_hh, h0, c0, ys, cs, dys, dhT, dcT)
    return dxg, dwhh, dh0, dc0


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _lstm_scan_vjp(xg, w_hh, h0, c0, interpret):
    ys, cs = lstm_scan_fused(xg, w_hh, h0, c0, interpret=interpret)
    return ys, ys[-1], cs[-1]


def _lstm_scan_fwd(xg, w_hh, h0, c0, interpret):
    ys, cs = lstm_scan_fused(xg, w_hh, h0, c0, interpret=interpret)
    return (ys, ys[-1], cs[-1]), (xg, w_hh, h0, c0, ys, cs)


def _lstm_scan_bwd(interpret, res, cts):
    xg, w_hh, h0, c0, ys, cs = res
    dys, dhT, dcT = cts
    dxg, dwhh, dh0, dc0 = lstm_scan_bwd_fused(
        xg, w_hh, h0, c0, ys, cs, dys, dhT, dcT, interpret=interpret
    )
    return (dxg.astype(xg.dtype), dwhh.astype(w_hh.dtype),
            dh0.astype(h0.dtype), dc0.astype(c0.dtype))


_lstm_scan_vjp.defvjp(_lstm_scan_fwd, _lstm_scan_bwd)


def lstm_scan_fused_vjp(xg, w_hh, h0, c0, *, interpret: bool = False):
    """Training-path entry point for the full-scan kernel: returns
    (ys (S, B, H), h_final, c_final) with the fused reversed-scan
    custom-VJP backward. The outer input matmul (xs @ w_ih + b) stays
    under normal autodiff — only the recurrence is kernel-resident."""
    return _lstm_scan_vjp(xg, w_hh, h0, c0, interpret)
