"""Fused LSTM gate nonlinearities + state update (Pallas TPU).

The per-step LSTM cell after the matmuls is four sigmoids/tanhs and
two multiplies over (B, H) — on TPU a chain of small VPU ops whose
HBM round-trips between unfused HLOs dominate the step at decode
batch sizes. The kernel fuses them in one VMEM-resident pass.
Gates layout: (B, 4, H) [i | f | g | o]; grid tiles (B, H).

``lstm_gates_fused_vjp`` adds a custom-VJP wrapper whose backward is a
second fused kernel: it saves only (gates, c) — the matmul outputs the
training graph keeps alive anyway — recomputes the four cheap
activations in VMEM and emits (dgates, dc_prev) in one pass. Without
it, autodiff through the cell stores every intermediate activation
(i, f, g, o, c_new, tanh(c_new): 6 extra (B, H) residuals *per scan
step*) and replays the chain as ~a dozen unfused HLOs; the LSTM cell
dominates the per-client ``lax.scan`` inside the federated round's
vmapped local steps, so this backward is the round step's hottest
gradient path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(g_ref, c_ref, h_out_ref, c_out_ref):
    g = g_ref[0].astype(jnp.float32)  # (4, th)... block (1, 4, th)
    c = c_ref[0].astype(jnp.float32)  # (th,)  block (1, th)
    i = jax.nn.sigmoid(g[0])
    f = jax.nn.sigmoid(g[1] + 1.0)
    gg = jnp.tanh(g[2])
    o = jax.nn.sigmoid(g[3])
    c_new = f * c + i * gg
    h_new = o * jnp.tanh(c_new)
    h_out_ref[0] = h_new.astype(h_out_ref.dtype)
    c_out_ref[0] = c_new.astype(c_out_ref.dtype)


def lstm_gates_fused(
    gates: jnp.ndarray, c: jnp.ndarray, *, th: int = 256, interpret: bool = False
):
    """gates: (B, 4H) preactivations [i|f|g|o]; c: (B, H).
    Returns (h_new, c_new) matching ref.lstm_gates_ref."""
    B, H4 = gates.shape
    H = H4 // 4
    th = min(th, H)
    assert H % th == 0, (H, th)
    g3 = gates.reshape(B, 4, H)

    h_new, c_new = pl.pallas_call(
        _kernel,
        grid=(B, H // th),
        in_specs=[
            pl.BlockSpec((1, 4, th), lambda b, hi: (b, 0, hi)),
            pl.BlockSpec((1, th), lambda b, hi: (b, hi)),
        ],
        out_specs=[
            pl.BlockSpec((1, th), lambda b, hi: (b, hi)),
            pl.BlockSpec((1, th), lambda b, hi: (b, hi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H), gates.dtype),
            jax.ShapeDtypeStruct((B, H), c.dtype),
        ],
        interpret=interpret,
    )(g3, c)
    return h_new, c_new


def _bwd_kernel(g_ref, c_ref, dh_ref, dcn_ref, dg_ref, dc_ref):
    g = g_ref[0].astype(jnp.float32)  # (4, th)
    c = c_ref[0].astype(jnp.float32)  # (th,)
    dh = dh_ref[0].astype(jnp.float32)
    dcn = dcn_ref[0].astype(jnp.float32)
    i = jax.nn.sigmoid(g[0])
    f = jax.nn.sigmoid(g[1] + 1.0)
    gg = jnp.tanh(g[2])
    o = jax.nn.sigmoid(g[3])
    t = jnp.tanh(f * c + i * gg)  # tanh(c_new), recomputed in VMEM
    dc = dcn + dh * o * (1.0 - t * t)
    dg_ref[0, 0] = (dc * gg * i * (1.0 - i)).astype(dg_ref.dtype)
    dg_ref[0, 1] = (dc * c * f * (1.0 - f)).astype(dg_ref.dtype)
    dg_ref[0, 2] = (dc * i * (1.0 - gg * gg)).astype(dg_ref.dtype)
    dg_ref[0, 3] = (dh * t * o * (1.0 - o)).astype(dg_ref.dtype)
    dc_ref[0] = (dc * f).astype(dc_ref.dtype)


def lstm_gates_bwd_fused(gates, c, dh, dc_next, *, th: int = 256, interpret: bool = False):
    """Fused backward of the cell: (gates, c, dh, dc_next) ->
    (dgates (B, 4H), dc_prev (B, H)) in one VMEM pass, recomputing the
    activations from the saved pre-activations instead of storing six
    per-step residual tensors."""
    B, H4 = gates.shape
    H = H4 // 4
    th = min(th, H)
    assert H % th == 0, (H, th)
    g3 = gates.reshape(B, 4, H)

    dg3, dc_prev = pl.pallas_call(
        _bwd_kernel,
        grid=(B, H // th),
        in_specs=[
            pl.BlockSpec((1, 4, th), lambda b, hi: (b, 0, hi)),
            pl.BlockSpec((1, th), lambda b, hi: (b, hi)),
            pl.BlockSpec((1, th), lambda b, hi: (b, hi)),
            pl.BlockSpec((1, th), lambda b, hi: (b, hi)),
        ],
        out_specs=[
            pl.BlockSpec((1, 4, th), lambda b, hi: (b, 0, hi)),
            pl.BlockSpec((1, th), lambda b, hi: (b, hi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 4, H), gates.dtype),
            jax.ShapeDtypeStruct((B, H), c.dtype),
        ],
        interpret=interpret,
    )(g3, c, dh, dc_next)
    return dg3.reshape(B, H4), dc_prev


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _lstm_gates_vjp(gates, c, th, interpret):
    return lstm_gates_fused(gates, c, th=th, interpret=interpret)


def _lstm_gates_fwd(gates, c, th, interpret):
    out = lstm_gates_fused(gates, c, th=th, interpret=interpret)
    return out, (gates, c)


def _lstm_gates_bwd(th, interpret, res, cts):
    gates, c = res
    dh, dc_next = cts
    return lstm_gates_bwd_fused(gates, c, dh, dc_next, th=th, interpret=interpret)


_lstm_gates_vjp.defvjp(_lstm_gates_fwd, _lstm_gates_bwd)


def lstm_gates_fused_vjp(gates, c, *, th: int = 256, interpret: bool = False):
    """The training-path entry point: fused forward AND fused custom-VJP
    backward (autodiff through the raw ``pallas_call`` is unsupported,
    and the unfused jnp backward is the round step's hot spot)."""
    return _lstm_gates_vjp(gates, c, th, interpret)
