"""Fused LSTM gate nonlinearities + state update (Pallas TPU).

The per-step LSTM cell after the matmuls is four sigmoids/tanhs and
two multiplies over (B, H) — on TPU a chain of small VPU ops whose
HBM round-trips between unfused HLOs dominate the step at decode
batch sizes. The kernel fuses them in one VMEM-resident pass.
Gates layout: (B, 4, H) [i | f | g | o]; grid tiles (B, H).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(g_ref, c_ref, h_out_ref, c_out_ref):
    g = g_ref[0].astype(jnp.float32)      # (4, th)... block (1, 4, th)
    c = c_ref[0].astype(jnp.float32)      # (th,)  block (1, th)
    i = jax.nn.sigmoid(g[0])
    f = jax.nn.sigmoid(g[1] + 1.0)
    gg = jnp.tanh(g[2])
    o = jax.nn.sigmoid(g[3])
    c_new = f * c + i * gg
    h_new = o * jnp.tanh(c_new)
    h_out_ref[0] = h_new.astype(h_out_ref.dtype)
    c_out_ref[0] = c_new.astype(c_out_ref.dtype)


def lstm_gates_fused(gates: jnp.ndarray, c: jnp.ndarray, *,
                     th: int = 256, interpret: bool = False):
    """gates: (B, 4H) preactivations [i|f|g|o]; c: (B, H).
    Returns (h_new, c_new) matching ref.lstm_gates_ref."""
    B, H4 = gates.shape
    H = H4 // 4
    th = min(th, H)
    assert H % th == 0, (H, th)
    g3 = gates.reshape(B, 4, H)

    h_new, c_new = pl.pallas_call(
        _kernel,
        grid=(B, H // th),
        in_specs=[
            pl.BlockSpec((1, 4, th), lambda b, hi: (b, 0, hi)),
            pl.BlockSpec((1, th), lambda b, hi: (b, hi)),
        ],
        out_specs=[
            pl.BlockSpec((1, th), lambda b, hi: (b, hi)),
            pl.BlockSpec((1, th), lambda b, hi: (b, hi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H), gates.dtype),
            jax.ShapeDtypeStruct((B, H), c.dtype),
        ],
        interpret=interpret,
    )(g3, c)
    return h_new, c_new
