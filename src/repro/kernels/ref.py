"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are *definitions*, deliberately simple and memory-naive — tests
sweep shapes/dtypes and assert the kernels (interpret=True on CPU)
match them. Production jnp fallbacks live in repro/models (blockwise
formulations); these oracles materialize everything for clarity.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


def attention_ref(q, k, v, *, causal=True, window=None, q_offset=0, logit_softcap=0.0):
    """q: (B, Sq, H, D); k, v: (B, Sk, Kv, D). Returns (B, Sq, H, Dv)."""
    B, Sq, H, D = q.shape
    Sk, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    qf = q.astype(jnp.float32).reshape(B, Sq, Kv, G, D) * (D**-0.5)
    s = jnp.einsum("bqkgd,bjkd->bqkgj", qf, k.astype(jnp.float32))
    if logit_softcap > 0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgj,bjkd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, pos, *, window=None):
    """q: (B, H, D); caches: (B, S, Kv, D); pos scalar (current token
    index, already written into the cache)."""
    B, H, D = q.shape
    S, Kv = k_cache.shape[1], k_cache.shape[2]
    G = H // Kv
    qf = q.astype(jnp.float32).reshape(B, Kv, G, D) * (D**-0.5)
    s = jnp.einsum("bkgd,bjkd->bkgj", qf, k_cache.astype(jnp.float32))
    j = jnp.arange(S)
    valid = j <= pos
    if window is not None:
        valid &= j > pos - window
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgj,bjkd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, v_cache.shape[-1]).astype(q.dtype)


def rnnt_joint_ref(enc_proj, pred_proj, w_out, bias, labels):
    """Fused joint oracle: materializes (B, T, U1, V) logits.

    enc_proj: (B, T, J); pred_proj: (B, U1, J); w_out: (J, V);
    bias: (V,); labels: (B, U1-? ) — (B, U1) label ids (last unused).
    Returns (blank_lp, label_lp): (B, T, U1).
    """
    h = jnp.tanh(
        enc_proj[:, :, None, :].astype(jnp.float32) + pred_proj[:, None, :, :].astype(jnp.float32)
    )
    logits = h @ w_out.astype(jnp.float32) + bias.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    blank_lp = logits[..., 0] - lse
    lbl = labels[:, None, :, None].astype(jnp.int32)  # (B,1,U1,1)
    lbl = jnp.broadcast_to(lbl, logits.shape[:3] + (1,))
    label_lp = jnp.take_along_axis(logits, lbl, axis=-1)[..., 0] - lse
    return blank_lp, label_lp


def nibble_pack_ref(codes):
    """int4 wire packing oracle: (n,) int8 codes in [-8, 7] -> the
    ((n+1)//2,) int8 nibble-packed payload (element 2i in the low
    nibble, 2i+1 in the high; odd n pads the last high nibble with 0)."""
    n = codes.shape[0]
    c = codes.astype(jnp.int32) & 0xF
    c = jnp.pad(c, (0, n % 2))
    pairs = c.reshape(-1, 2)
    b = pairs[:, 0] | (pairs[:, 1] << 4)
    return (((b & 0xFF) ^ 0x80) - 0x80).astype(jnp.int8)  # two's-complement byte


def nibble_unpack_ref(packed, n: int):
    """Inverse of ``nibble_pack_ref``: sign-extend both nibbles of each
    byte and drop the odd-n pad -> (n,) int8 codes."""
    b = packed.astype(jnp.int32) & 0xFF
    lo = ((b & 0xF) ^ 8) - 8
    hi = (((b >> 4) & 0xF) ^ 8) - 8
    return jnp.stack([lo, hi], axis=-1).reshape(-1)[:n].astype(jnp.int8)


def dequantize_ref(codes, scale):
    """intN codes + fp32 scale -> f32 (the uplink dequantization)."""
    return codes.astype(jnp.float32) * scale


def quantize_codes_with_scale_ref(x, scale, u, levels: float):
    """Stochastic-round/clamp oracle for a *given* scale: (n,) f32 +
    scale () + uniforms (n,) (None = nearest rounding) -> (n,) int8
    codes in [-levels, levels].

    The clamp precedes the rounding draw (the PR 3 ulp regression: f32
    division can land the absmax coordinate one ulp outside the grid,
    and a boundary draw would round to levels+1 and wrap the int8
    cast). ``u < frac`` is exactly ``jax.random.bernoulli``'s
    uniform-threshold draw, so given the same key this matches the
    historical bernoulli-based path bit for bit."""
    y = jnp.clip(x.astype(jnp.float32) / scale, -levels, levels)
    if u is None:
        return jnp.round(y).astype(jnp.int8)
    lo = jnp.floor(y)
    return (lo + (u < (y - lo)).astype(jnp.float32)).astype(jnp.int8)


def quantize_pack_ref(x, scale, u, bits: int):
    """Fused quantize->pack oracle: one tensor's intN wire buffer from
    (x, shared-or-own scale, uniforms). int8 -> the codes themselves;
    int4 -> the nibble-packed bytes (pack_ref of the codes)."""
    levels = 2.0 ** (bits - 1) - 1.0
    codes = quantize_codes_with_scale_ref(x, scale, u, levels)
    return nibble_pack_ref(codes) if bits == 4 else codes


def topk_unpack_ref(values, idx, n: int):
    """Scatter a top-k (value, index) payload into a dense (n,) f32."""
    return jnp.zeros((n,), jnp.float32).at[idx].set(values.astype(jnp.float32))


# ------------------------------------------------- in-kernel PRNG oracle
# jax's threefry2x32 PRNG, restated elementwise so the quantize kernels
# can draw each element's uniform from its flat position alone — no
# (n,)-shaped uniform field ever streams through HBM. With the repo's
# pinned threefry (non-partitionable) impl, jax.random.uniform(key, (n,))
# hashes counters iota(n) split into halves (x0 = counts[:half],
# x1 = counts[half:], half = (n+1)//2; odd n pads one zero counter) and
# concatenates the two output lanes. Position j therefore owns lane 0 of
# pair (j, j+half) when j < half (the pad turns the missing counter into
# 0), else lane 1 of pair (j-half, j). ``threefry_uniform_at`` computes
# exactly that, so it equals the streamed draw bit for bit by
# construction — the tolerance-free parity contract of the keyed
# quantize kernels (tests/test_wire_pack.py sweeps even/odd n).

_THREEFRY_C = 0x1BD11BDA
_THREEFRY_ROT = ((13, 15, 26, 6), (17, 29, 16, 24))


def _rotl32(x, r: int):
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def threefry2x32_pair(k0, k1, c0, c1):
    """One threefry2x32 block hash: uint32 key words x uint32 counter
    words -> both uint32 output lanes (jax's 20-round schedule)."""
    ks2 = k0 ^ k1 ^ jnp.uint32(_THREEFRY_C)
    x0 = c0 + k0
    x1 = c1 + k1
    inject = ((k1, ks2), (ks2, k0), (k0, k1), (k1, ks2), (ks2, k0))
    for i, (i0, i1) in enumerate(inject):
        for r in _THREEFRY_ROT[i % 2]:
            x0 = x0 + x1
            x1 = _rotl32(x1, r)
            x1 = x1 ^ x0
        x0 = x0 + i0
        x1 = x1 + i1 + jnp.uint32(i + 1)
    return x0, x1


def threefry_random_bits_at(k0, k1, pos, n: int):
    """Random uint32 at flat position(s) ``pos`` of a size-``n`` draw —
    elementwise jax.random.bits(key, (n,))."""
    half = (n + 1) // 2
    pos = pos.astype(jnp.uint32)
    lo = pos < jnp.uint32(half)
    pair = jnp.where(lo, pos, pos - jnp.uint32(half))
    c1 = pair + jnp.uint32(half)
    c1 = jnp.where(c1 < jnp.uint32(n), c1, jnp.uint32(0))
    o0, o1 = threefry2x32_pair(k0, k1, pair, c1)
    return jnp.where(lo, o0, o1)


def bits_to_uniform(bits):
    """uint32 -> [0, 1) f32, jax.random.uniform's exact mantissa fill."""
    f = jax.lax.bitcast_convert_type(
        (bits >> jnp.uint32(9)) | jnp.uint32(0x3F800000), jnp.float32
    )
    return f - 1.0


def threefry_uniform_ref(key_data, n: int):
    """(2,) uint32 key words -> (n,) f32 == jax.random.uniform(key, (n,))
    bit for bit (the streamed-field oracle the in-kernel PRNG must
    reproduce exactly)."""
    k0 = key_data[0].astype(jnp.uint32)
    k1 = key_data[1].astype(jnp.uint32)
    pos = jnp.arange(n, dtype=jnp.uint32)
    return bits_to_uniform(threefry_random_bits_at(k0, k1, pos, n))


def topk_scatter_add_ref(values, idx, weights, n: int):
    """Weighted scatter-ADD of a stacked top-k payload: values (K, k)
    f32, idx (K, k) int32 flat indices, weights (K,) f32 -> dense (n,)
    f32 sum over clients (duplicate indices accumulate). The code-domain
    aggregation oracle for the top-k plane."""
    flat_vals = (weights[:, None] * values.astype(jnp.float32)).reshape(-1)
    flat_idx = idx.reshape(-1)
    return jnp.zeros((n,), jnp.float32).at[flat_idx].add(flat_vals)


def lstm_gates_ref(gates, c):
    """gates: (B, 4H) preactivation [i|f|g|o]; c: (B, H)."""
    h4 = gates.shape[-1]
    hd = h4 // 4
    gf = gates.astype(jnp.float32)
    i = jax.nn.sigmoid(gf[..., :hd])
    f = jax.nn.sigmoid(gf[..., hd : 2 * hd] + 1.0)
    g = jnp.tanh(gf[..., 2 * hd : 3 * hd])
    o = jax.nn.sigmoid(gf[..., 3 * hd :])
    c_new = f * c.astype(jnp.float32) + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new.astype(gates.dtype), c_new.astype(c.dtype)
