"""Packed-wire kernels for the uplink compression plane (Pallas TPU).

The byte formulas in ``repro.core.compression`` price three wire
layouts; these kernels materialize them so the per-client uplink is a
real packed buffer, not an accounting fiction:

- ``nibble_pack`` / ``nibble_unpack``: int4 codes two-per-byte (low
  nibble = even element, high nibble = odd; odd sizes pad one nibble),
  sign-extended back on unpack — a pure VPU bit-twiddle pass.
- ``dequantize``: intN codes x fp32 scale -> f32, fused in one
  VMEM-resident pass (the server-side unpack of every intN payload).
- ``topk_unpack``: scatter a (value, index) payload into the dense
  tensor. Serial over k inside one VMEM block — k is a few percent of
  the tensor, and the sorted-by-magnitude payload makes the stores
  conflict-free; a production variant would segment the index space
  across the grid.

Each kernel has a jnp oracle in ``ref.py`` (the parity target,
interpret=True on CPU) and a public auto-dispatch wrapper (Pallas on
TPU, the oracle as the CPU production path — same convention as the
model kernels). Pack->unpack is the identity on codes by construction,
which is what makes the packed compression path bit-exact against the
in-graph quantize->dequantize (tested in tests/test_wire_pack.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref

_TILE = 512                     # lane-aligned (4 x 128) payload tile


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x, m: int):
    return jnp.pad(x, (0, (-x.shape[0]) % m))


# ------------------------------------------------------------ nibble pack

def _nibble_pack_kernel(ev_ref, od_ref, out_ref):
    ev = ev_ref[...].astype(jnp.int32) & 0xF
    od = od_ref[...].astype(jnp.int32) & 0xF
    b = ev | (od << 4)
    out_ref[...] = (((b & 0xFF) ^ 0x80) - 0x80).astype(jnp.int8)


def nibble_pack_pallas(codes, *, tile: int = _TILE, interpret: bool = False):
    """codes: (n,) int8 in [-8, 7] -> ((n+1)//2,) int8 nibble-packed."""
    n = codes.shape[0]
    nb = (n + 1) // 2
    c = _pad_to(codes, 2 * tile).reshape(-1, 2)       # (nbp, 2) pairs
    ev, od = c[:, 0][None, :], c[:, 1][None, :]        # (1, nbp)
    nbp = ev.shape[1]
    out = pl.pallas_call(
        _nibble_pack_kernel,
        grid=(nbp // tile,),
        in_specs=[pl.BlockSpec((1, tile), lambda i: (0, i))] * 2,
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, nbp), jnp.int8),
        interpret=interpret,
    )(ev, od)
    return out[0, :nb]


def _nibble_unpack_kernel(b_ref, lo_ref, hi_ref):
    b = b_ref[...].astype(jnp.int32) & 0xFF
    lo_ref[...] = (((b & 0xF) ^ 8) - 8).astype(jnp.int8)
    hi_ref[...] = ((((b >> 4) & 0xF) ^ 8) - 8).astype(jnp.int8)


def nibble_unpack_pallas(packed, n: int, *, tile: int = _TILE,
                         interpret: bool = False):
    """packed: ((n+1)//2,) int8 -> (n,) int8 sign-extended codes."""
    b = _pad_to(packed, tile)[None, :]
    nbp = b.shape[1]
    lo, hi = pl.pallas_call(
        _nibble_unpack_kernel,
        grid=(nbp // tile,),
        in_specs=[pl.BlockSpec((1, tile), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((1, tile), lambda i: (0, i))] * 2,
        out_shape=[jax.ShapeDtypeStruct((1, nbp), jnp.int8)] * 2,
        interpret=interpret,
    )(b)
    return jnp.stack([lo[0], hi[0]], axis=-1).reshape(-1)[:n]


# -------------------------------------------------------------- dequantize

def _dequantize_kernel(c_ref, s_ref, out_ref):
    out_ref[...] = c_ref[...].astype(jnp.float32) * s_ref[0, 0]


def dequantize_pallas(codes, scale, *, tile: int = _TILE,
                      interpret: bool = False):
    """codes: (n,) int8 + fp32 scale () -> (n,) f32, one fused pass."""
    n = codes.shape[0]
    c = _pad_to(codes, tile)[None, :]
    npad = c.shape[1]
    out = pl.pallas_call(
        _dequantize_kernel,
        grid=(npad // tile,),
        in_specs=[pl.BlockSpec((1, tile), lambda i: (0, i)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, npad), jnp.float32),
        interpret=interpret,
    )(c, scale.reshape(1, 1))
    return out[0, :n]


# ------------------------------------------------------------- topk unpack

def _topk_unpack_kernel(v_ref, i_ref, out_ref):
    out_ref[...] = jnp.zeros_like(out_ref)

    def body(j, carry):
        idx = pl.load(i_ref, (slice(0, 1), pl.ds(j, 1)))[0, 0]
        val = pl.load(v_ref, (slice(0, 1), pl.ds(j, 1)))
        pl.store(out_ref, (slice(0, 1), pl.ds(idx, 1)), val)
        return carry

    jax.lax.fori_loop(0, i_ref.shape[1], body, 0)


def topk_unpack_pallas(values, idx, n: int, *, interpret: bool = False):
    """(k,) f32 values + (k,) int32 flat indices -> dense (n,) f32."""
    out = pl.pallas_call(
        _topk_unpack_kernel,
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(values[None, :], idx[None, :])
    return out[0]


# ---------------------------------------------------- public auto-dispatch
# Pallas on TPU; the jnp oracle is the CPU production path (interpret
# mode is for tests only — same convention as repro.kernels.ops).

def nibble_pack(codes):
    if _on_cpu():
        return ref.nibble_pack_ref(codes)
    return nibble_pack_pallas(codes)


def nibble_unpack(packed, n: int):
    if _on_cpu():
        return ref.nibble_unpack_ref(packed, n)
    return nibble_unpack_pallas(packed, n)


def dequantize(codes, scale):
    if _on_cpu():
        return ref.dequantize_ref(codes, scale)
    return dequantize_pallas(codes, jnp.asarray(scale, jnp.float32))


def topk_unpack(values, idx, n: int):
    if _on_cpu():
        return ref.topk_unpack_ref(values, idx, n)
    return topk_unpack_pallas(values, idx, n)
