"""Packed-wire kernels for the uplink compression plane (Pallas TPU).

The byte formulas in ``repro.core.compression`` price three wire
layouts; these kernels materialize them so the per-client uplink is a
real packed buffer, not an accounting fiction:

- ``nibble_pack`` / ``nibble_unpack``: int4 codes two-per-byte (low
  nibble = even element, high nibble = odd; odd sizes pad one nibble),
  sign-extended back on unpack — a pure VPU bit-twiddle pass.
- ``dequantize``: intN codes x fp32 scale -> f32, fused in one
  VMEM-resident pass (the server-side unpack of every intN payload).
- ``topk_unpack``: scatter a (value, index) payload into the dense
  tensor. Two variants: the original serial kernel (all k stores into
  one VMEM-resident block) and the *segmented* scatter
  (``topk_unpack_segmented_pallas``) that sorts the payload by index
  once, computes per-segment bounds with a searchsorted, and lets each
  grid cell store only its own contiguous slice — small VMEM blocks,
  pipelined output windows, and no serial pass over the whole tensor.
- ``quantize_pack``: the *fused* uplink client kernel — grid-divide by
  the (shared or per-tensor) scale, clamp into the code grid,
  stochastic-round against a uniform field, and (for int4) nibble-pack
  — one VMEM pass per leaf instead of a quantize HLO chain followed by
  a separate pack pass. The absmax reduction stays outside so the
  4-byte scales can be max-reduced across the client axis first
  (shared-scale negotiation: exact code-domain sums).

Each kernel has a jnp oracle in ``ref.py`` (the parity target,
interpret=True on CPU) and a public auto-dispatch wrapper (Pallas on
TPU, the oracle as the CPU production path — same convention as the
model kernels). Pack->unpack is the identity on codes by construction,
which is what makes the packed compression path bit-exact against the
in-graph quantize->dequantize (tested in tests/test_wire_pack.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref

_TILE = 512  # lane-aligned (4 x 128) payload tile


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x, m: int):
    return jnp.pad(x, (0, (-x.shape[0]) % m))


# ------------------------------------------------------------ nibble pack


def _nibble_pack_kernel(ev_ref, od_ref, out_ref):
    ev = ev_ref[...].astype(jnp.int32) & 0xF
    od = od_ref[...].astype(jnp.int32) & 0xF
    b = ev | (od << 4)
    out_ref[...] = (((b & 0xFF) ^ 0x80) - 0x80).astype(jnp.int8)


def nibble_pack_pallas(codes, *, tile: int = _TILE, interpret: bool = False):
    """codes: (n,) int8 in [-8, 7] -> ((n+1)//2,) int8 nibble-packed."""
    n = codes.shape[0]
    nb = (n + 1) // 2
    c = _pad_to(codes, 2 * tile).reshape(-1, 2)  # (nbp, 2) pairs
    ev, od = c[:, 0][None, :], c[:, 1][None, :]  # (1, nbp)
    nbp = ev.shape[1]
    out = pl.pallas_call(
        _nibble_pack_kernel,
        grid=(nbp // tile,),
        in_specs=[pl.BlockSpec((1, tile), lambda i: (0, i))] * 2,
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, nbp), jnp.int8),
        interpret=interpret,
    )(ev, od)
    return out[0, :nb]


def _nibble_unpack_kernel(b_ref, lo_ref, hi_ref):
    b = b_ref[...].astype(jnp.int32) & 0xFF
    lo_ref[...] = (((b & 0xF) ^ 8) - 8).astype(jnp.int8)
    hi_ref[...] = ((((b >> 4) & 0xF) ^ 8) - 8).astype(jnp.int8)


def nibble_unpack_pallas(packed, n: int, *, tile: int = _TILE, interpret: bool = False):
    """packed: ((n+1)//2,) int8 -> (n,) int8 sign-extended codes."""
    b = _pad_to(packed, tile)[None, :]
    nbp = b.shape[1]
    lo, hi = pl.pallas_call(
        _nibble_unpack_kernel,
        grid=(nbp // tile,),
        in_specs=[pl.BlockSpec((1, tile), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((1, tile), lambda i: (0, i))] * 2,
        out_shape=[jax.ShapeDtypeStruct((1, nbp), jnp.int8)] * 2,
        interpret=interpret,
    )(b)
    return jnp.stack([lo[0], hi[0]], axis=-1).reshape(-1)[:n]


# -------------------------------------------------------------- dequantize


def _dequantize_kernel(c_ref, s_ref, out_ref):
    out_ref[...] = c_ref[...].astype(jnp.float32) * s_ref[0, 0]


def dequantize_pallas(codes, scale, *, tile: int = _TILE, interpret: bool = False):
    """codes: (n,) int8 + fp32 scale () -> (n,) f32, one fused pass."""
    n = codes.shape[0]
    c = _pad_to(codes, tile)[None, :]
    npad = c.shape[1]
    out = pl.pallas_call(
        _dequantize_kernel,
        grid=(npad // tile,),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, npad), jnp.float32),
        interpret=interpret,
    )(c, scale.reshape(1, 1))
    return out[0, :n]


# ---------------------------------------------------- fused quantize->pack


def _quantize_kernel(levels: float, x_ref, s_ref, u_ref, out_ref):
    y = jnp.clip(x_ref[...] / s_ref[0, 0], -levels, levels)
    lo = jnp.floor(y)
    out_ref[...] = (lo + (u_ref[...] < (y - lo)).astype(jnp.float32)).astype(jnp.int8)


def _quantize_nearest_kernel(levels: float, x_ref, s_ref, out_ref):
    y = jnp.clip(x_ref[...] / s_ref[0, 0], -levels, levels)
    out_ref[...] = jnp.round(y).astype(jnp.int8)


def _pack_byte(qe, qo):
    b = (qe.astype(jnp.int32) & 0xF) | ((qo.astype(jnp.int32) & 0xF) << 4)
    return (((b & 0xFF) ^ 0x80) - 0x80).astype(jnp.int8)


def _quantize_pack4_kernel(xe_ref, xo_ref, s_ref, ue_ref, uo_ref, out_ref):
    s = s_ref[0, 0]

    def q(x_ref, u_ref):
        y = jnp.clip(x_ref[...] / s, -7.0, 7.0)
        lo = jnp.floor(y)
        return (lo + (u_ref[...] < (y - lo)).astype(jnp.float32)).astype(jnp.int8)

    out_ref[...] = _pack_byte(q(xe_ref, ue_ref), q(xo_ref, uo_ref))


def _quantize_pack4_nearest_kernel(xe_ref, xo_ref, s_ref, out_ref):
    s = s_ref[0, 0]

    def q(x_ref):
        return jnp.round(jnp.clip(x_ref[...] / s, -7.0, 7.0)).astype(jnp.int8)

    out_ref[...] = _pack_byte(q(xe_ref), q(xo_ref))


def quantize_with_scale_pallas(
    x, scale, u, bits: int, *, tile: int = _TILE, interpret: bool = False
):
    """x: (n,) f32 + scale () [+ uniforms u: (n,) f32, None = nearest]
    -> (n,) int8 codes in [-levels, levels]: scale-divide, clamp and
    stochastic-round fused in one VMEM pass (the quantize half of the
    fused uplink kernel, for the unpacked int8/int4 planes)."""
    levels = 2.0 ** (bits - 1) - 1.0
    n = x.shape[0]
    xp = _pad_to(x, tile)[None, :]
    npad = xp.shape[1]
    spec = pl.BlockSpec((1, tile), lambda i: (0, i))
    sspec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    if u is None:
        out = pl.pallas_call(
            functools.partial(_quantize_nearest_kernel, levels),
            grid=(npad // tile,),
            in_specs=[spec, sspec],
            out_specs=spec,
            out_shape=jax.ShapeDtypeStruct((1, npad), jnp.int8),
            interpret=interpret,
        )(xp, scale.reshape(1, 1))
    else:
        out = pl.pallas_call(
            functools.partial(_quantize_kernel, levels),
            grid=(npad // tile,),
            in_specs=[spec, sspec, spec],
            out_specs=spec,
            out_shape=jax.ShapeDtypeStruct((1, npad), jnp.int8),
            interpret=interpret,
        )(xp, scale.reshape(1, 1), _pad_to(u, tile)[None, :])
    return out[0, :n]


def quantize_pack4_pallas(x, scale, u, *, tile: int = _TILE, interpret: bool = False):
    """Fully fused int4 client kernel: (n,) f32 + scale [+ uniforms]
    -> ((n+1)//2,) int8 nibble-packed wire bytes. Quantization and the
    even/odd nibble interleave happen in the same VMEM pass — the codes
    are never materialized in HBM."""
    n = x.shape[0]
    nb = (n + 1) // 2

    def pairs(a):
        p = _pad_to(a, 2 * tile).reshape(-1, 2)
        return p[:, 0][None, :], p[:, 1][None, :]

    xe, xo = pairs(x)
    nbp = xe.shape[1]
    spec = pl.BlockSpec((1, tile), lambda i: (0, i))
    sspec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    if u is None:
        out = pl.pallas_call(
            _quantize_pack4_nearest_kernel,
            grid=(nbp // tile,),
            in_specs=[spec, spec, sspec],
            out_specs=spec,
            out_shape=jax.ShapeDtypeStruct((1, nbp), jnp.int8),
            interpret=interpret,
        )(xe, xo, scale.reshape(1, 1))
    else:
        ue, uo = pairs(u)
        out = pl.pallas_call(
            _quantize_pack4_kernel,
            grid=(nbp // tile,),
            in_specs=[spec, spec, sspec, spec, spec],
            out_specs=spec,
            out_shape=jax.ShapeDtypeStruct((1, nbp), jnp.int8),
            interpret=interpret,
        )(xe, xo, scale.reshape(1, 1), ue, uo)
    return out[0, :nb]


# ------------------------------------------- keyed (in-kernel PRNG) variants
# Same fused quantize->pack math, but the stochastic-rounding uniforms
# are *generated inside the kernel* from the leaf's threefry key words +
# each element's flat position (repro.kernels.ref.threefry_random_bits_at
# — plain uint32 jnp ops, so the identical 20-round hash runs on every
# backend). The (n,)-sized uniform field never exists in HBM, and the
# draw equals jax.random.uniform(key, (n,)) bit for bit, which keeps the
# packed plane's tolerance-free parity with the historical streamed-field
# path (the PR 5 contract).


def _iota_pos(tile: int):
    pid = pl.program_id(0)
    base = (pid * tile).astype(jnp.uint32)
    return base + jax.lax.broadcasted_iota(jnp.uint32, (1, tile), 1)


def _keyed_uniform(k_ref, pos, n: int):
    k0 = k_ref[0, 0]
    k1 = k_ref[0, 1]
    return ref.bits_to_uniform(ref.threefry_random_bits_at(k0, k1, pos, n))


def _quantize_keyed_kernel(levels: float, n: int, tile: int, x_ref, s_ref, k_ref, out_ref):
    u = _keyed_uniform(k_ref, _iota_pos(tile), n)
    y = jnp.clip(x_ref[...] / s_ref[0, 0], -levels, levels)
    lo = jnp.floor(y)
    out_ref[...] = (lo + (u < (y - lo)).astype(jnp.float32)).astype(jnp.int8)


def _quantize_pack4_keyed_kernel(n: int, tile: int, xe_ref, xo_ref, s_ref, k_ref, out_ref):
    pair = _iota_pos(tile)
    ue = _keyed_uniform(k_ref, pair * jnp.uint32(2), n)
    uo = _keyed_uniform(k_ref, pair * jnp.uint32(2) + jnp.uint32(1), n)
    s = s_ref[0, 0]

    def q(x_ref, u):
        y = jnp.clip(x_ref[...] / s, -7.0, 7.0)
        lo = jnp.floor(y)
        return (lo + (u < (y - lo)).astype(jnp.float32)).astype(jnp.int8)

    out_ref[...] = _pack_byte(q(xe_ref, ue), q(xo_ref, uo))


def quantize_with_scale_keyed_pallas(
    x, scale, key_data, bits: int, *, tile: int = _TILE, interpret: bool = False
):
    """x: (n,) f32 + scale () + key_data (2,) uint32 -> (n,) int8 codes,
    stochastic-rounded against in-kernel threefry draws (positionally
    identical to streaming jax.random.uniform(key, (n,)) in)."""
    levels = 2.0 ** (bits - 1) - 1.0
    n = x.shape[0]
    xp = _pad_to(x, tile)[None, :]
    npad = xp.shape[1]
    spec = pl.BlockSpec((1, tile), lambda i: (0, i))
    sspec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    kspec = pl.BlockSpec((1, 2), lambda i: (0, 0))
    out = pl.pallas_call(
        functools.partial(_quantize_keyed_kernel, levels, n, tile),
        grid=(npad // tile,),
        in_specs=[spec, sspec, kspec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((1, npad), jnp.int8),
        interpret=interpret,
    )(xp, scale.reshape(1, 1), key_data.astype(jnp.uint32).reshape(1, 2))
    return out[0, :n]


def quantize_pack4_keyed_pallas(x, scale, key_data, *, tile: int = _TILE, interpret: bool = False):
    """Fully fused keyed int4 client kernel: quantize, stochastic-round
    from in-kernel PRNG, and nibble-pack in one VMEM pass — neither the
    codes nor the uniform field ever land in HBM."""
    n = x.shape[0]
    nb = (n + 1) // 2

    def pairs(a):
        p = _pad_to(a, 2 * tile).reshape(-1, 2)
        return p[:, 0][None, :], p[:, 1][None, :]

    xe, xo = pairs(x)
    nbp = xe.shape[1]
    spec = pl.BlockSpec((1, tile), lambda i: (0, i))
    sspec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    kspec = pl.BlockSpec((1, 2), lambda i: (0, 0))
    out = pl.pallas_call(
        functools.partial(_quantize_pack4_keyed_kernel, n, tile),
        grid=(nbp // tile,),
        in_specs=[spec, spec, sspec, kspec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((1, nbp), jnp.int8),
        interpret=interpret,
    )(xe, xo, scale.reshape(1, 1), key_data.astype(jnp.uint32).reshape(1, 2))
    return out[0, :nb]


# ------------------------------------------------------------- topk unpack


def _topk_unpack_kernel(v_ref, i_ref, out_ref):
    out_ref[...] = jnp.zeros_like(out_ref)

    def body(j, carry):
        idx = pl.load(i_ref, (slice(0, 1), pl.ds(j, 1)))[0, 0]
        val = pl.load(v_ref, (slice(0, 1), pl.ds(j, 1)))
        pl.store(out_ref, (slice(0, 1), pl.ds(idx, 1)), val)
        return carry

    jax.lax.fori_loop(0, i_ref.shape[1], body, 0)


def topk_unpack_pallas(values, idx, n: int, *, interpret: bool = False):
    """(k,) f32 values + (k,) int32 flat indices -> dense (n,) f32.

    The serial variant: every store lands in one n-wide VMEM block.
    Kept as the small-n fallback and the parity reference for the
    segmented kernel below."""
    out = pl.pallas_call(
        _topk_unpack_kernel,
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(values[None, :], idx[None, :])
    return out[0]


def _topk_unpack_seg_kernel(seg: int, b_ref, v_ref, i_ref, out_ref):
    """One grid cell owns output segment [pid*seg, (pid+1)*seg): the
    payload arrives sorted by index, so this cell's entries are the
    contiguous slice b[pid] .. b[pid+1] of the payload — a dynamic-
    bound loop over *its own* entries only, instead of every cell (or
    one serial pass) scanning all k."""
    pid = pl.program_id(0)
    base = pid * seg
    start = pl.load(b_ref, (slice(0, 1), pl.ds(pid, 1)))[0, 0]
    end = pl.load(b_ref, (slice(0, 1), pl.ds(pid + 1, 1)))[0, 0]
    out_ref[...] = jnp.zeros_like(out_ref)

    def body(j, carry):
        idx = pl.load(i_ref, (slice(0, 1), pl.ds(j, 1)))[0, 0]
        val = pl.load(v_ref, (slice(0, 1), pl.ds(j, 1)))
        pl.store(out_ref, (slice(0, 1), pl.ds(idx - base, 1)), val)
        return carry

    jax.lax.fori_loop(start, end, body, 0)


def topk_unpack_segmented_pallas(values, idx, n: int, *, seg: int = 2048, interpret: bool = False):
    """Segmented (grid-parallel) top-k scatter: sort the (value, index)
    payload by index, searchsorted the segment boundaries, and give
    each grid cell one seg-wide output window plus the payload slice
    that lands in it. VMEM holds one segment (not the whole tensor),
    output windows pipeline, and total store work stays O(k)."""
    k = values.shape[0]
    seg = min(seg, max(n, 1))
    npad = n + (-n) % seg
    nseg = npad // seg
    order = jnp.argsort(idx)
    sv, si = values[order], idx[order]
    bounds = jnp.searchsorted(si, jnp.arange(nseg + 1, dtype=jnp.int32) * seg).astype(jnp.int32)
    out = pl.pallas_call(
        functools.partial(_topk_unpack_seg_kernel, seg),
        grid=(nseg,),
        in_specs=[
            pl.BlockSpec((1, nseg + 1), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, seg), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, npad), jnp.float32),
        interpret=interpret,
    )(bounds[None, :], sv[None, :], si[None, :])
    return out[0, :n]


# -------------------------------------------------------- topk scatter-add


def _topk_scatter_add_seg_kernel(seg: int, b_ref, v_ref, i_ref, out_ref):
    """Segmented weighted scatter-ADD: like the segmented unpack, each
    grid cell owns one seg-wide output window and walks only its own
    contiguous (sorted-by-index) payload slice — but read-add-store, so
    duplicate indices (the same coordinate picked by several clients)
    accumulate instead of overwriting. The serial walk within a segment
    is what makes the accumulation race-free."""
    pid = pl.program_id(0)
    base = pid * seg
    start = pl.load(b_ref, (slice(0, 1), pl.ds(pid, 1)))[0, 0]
    end = pl.load(b_ref, (slice(0, 1), pl.ds(pid + 1, 1)))[0, 0]
    out_ref[...] = jnp.zeros_like(out_ref)

    def body(j, carry):
        idx = pl.load(i_ref, (slice(0, 1), pl.ds(j, 1)))[0, 0]
        val = pl.load(v_ref, (slice(0, 1), pl.ds(j, 1)))
        cur = pl.load(out_ref, (slice(0, 1), pl.ds(idx - base, 1)))
        pl.store(out_ref, (slice(0, 1), pl.ds(idx - base, 1)), cur + val)
        return carry

    jax.lax.fori_loop(start, end, body, 0)


def topk_scatter_add_pallas(values, idx, n: int, *, seg: int = 2048, interpret: bool = False):
    """(m,) f32 pre-weighted values + (m,) int32 flat indices (possibly
    duplicated across clients) -> dense (n,) f32 accumulated sum."""
    m = values.shape[0]
    seg = min(seg, max(n, 1))
    npad = n + (-n) % seg
    nseg = npad // seg
    order = jnp.argsort(idx)
    sv, si = values[order], idx[order]
    bounds = jnp.searchsorted(si, jnp.arange(nseg + 1, dtype=jnp.int32) * seg).astype(jnp.int32)
    out = pl.pallas_call(
        functools.partial(_topk_scatter_add_seg_kernel, seg),
        grid=(nseg,),
        in_specs=[
            pl.BlockSpec((1, nseg + 1), lambda i: (0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, seg), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, npad), jnp.float32),
        interpret=interpret,
    )(bounds[None, :], sv[None, :], si[None, :])
    return out[0, :n]


# ---------------------------------------------------- public auto-dispatch
# Pallas on TPU; the jnp oracle is the CPU production path (interpret
# mode is for tests only — same convention as repro.kernels.ops).
# Every dispatch constant lives in the tuning registry
# (repro.profile.tuner): 'wire_pack.dispatch' overrides the
# backend choice per device, 'wire_pack.topk_seg_min_n' /
# 'wire_pack.topk_seg_size' are the PR 5 segmented-scatter thresholds,
# re-measurable on this machine via `python -m repro.profile.tuner
# --autotune topk`. The lazy import keeps the kernel layer free of any
# import-order coupling (tuner is stdlib-only at module level).


def _dispatch() -> tuple:
    """(use_ref, interpret) for this call site, honoring the tuning
    registry's measured per-device override."""
    from repro.profile.tuner import get_knob

    mode = get_knob("wire_pack.dispatch")
    if mode == "ref":
        return True, False
    if mode == "pallas":
        return False, _on_cpu()
    return _on_cpu(), False


def nibble_pack(codes):
    use_ref, interpret = _dispatch()
    if use_ref:
        return ref.nibble_pack_ref(codes)
    return nibble_pack_pallas(codes, interpret=interpret)


def nibble_unpack(packed, n: int):
    use_ref, interpret = _dispatch()
    if use_ref:
        return ref.nibble_unpack_ref(packed, n)
    return nibble_unpack_pallas(packed, n, interpret=interpret)


def dequantize(codes, scale):
    use_ref, interpret = _dispatch()
    if use_ref:
        return ref.dequantize_ref(codes, scale)
    return dequantize_pallas(codes, jnp.asarray(scale, jnp.float32), interpret=interpret)


def topk_unpack(values, idx, n: int):
    from repro.profile.tuner import get_knob

    use_ref, interpret = _dispatch()
    if use_ref:
        return ref.topk_unpack_ref(values, idx, n)
    # below the measured crossover the serial kernel's single block is
    # cheaper than sorting the payload + a multi-cell grid
    if n < int(get_knob("wire_pack.topk_seg_min_n")):
        return topk_unpack_pallas(values, idx, n, interpret=interpret)
    return topk_unpack_segmented_pallas(
        values, idx, n, seg=int(get_knob("wire_pack.topk_seg_size")), interpret=interpret
    )


def quantize_with_scale(x, scale, u, bits: int):
    """Fused scale-divide -> clamp -> (stochastic) round: x (any
    shape) -> int8 codes shaped like x. ``u`` is the uniform rounding
    field (x-shaped; None = nearest). Bit-identical to the historical
    quantize_codes math for the same key — ``u < frac`` IS
    jax.random.bernoulli's draw."""
    use_ref, interpret = _dispatch()
    if use_ref:
        levels = 2.0 ** (bits - 1) - 1.0
        return ref.quantize_codes_with_scale_ref(x, scale, u, levels)
    flat = x.reshape(-1)
    uf = None if u is None else u.reshape(-1)
    out = quantize_with_scale_pallas(
        flat, jnp.asarray(scale, jnp.float32), uf, bits, interpret=interpret
    )
    return out.reshape(jnp.shape(x))


def quantize_pack(x, scale, u, bits: int):
    """Fused uplink client kernel: (n,) f32 -> the intN wire buffer
    (int8: the codes; int4: nibble-packed bytes), quantized against a
    caller-supplied (shared or per-tensor) scale in one pass."""
    use_ref, interpret = _dispatch()
    if use_ref:
        return ref.quantize_pack_ref(x, scale, u, bits)
    if bits == 4:
        return quantize_pack4_pallas(x, jnp.asarray(scale, jnp.float32), u, interpret=interpret)
    return quantize_with_scale_pallas(
        x, jnp.asarray(scale, jnp.float32), u, bits, interpret=interpret
    )


def quantize_with_scale_keyed(x, scale, key_data, bits: int):
    """Keyed twin of ``quantize_with_scale``: the rounding uniforms come
    from the in-kernel threefry hash of ``key_data`` ((2,) uint32 words,
    i.e. the per-leaf fold_in key) instead of a streamed field. Codes
    are bit-identical to quantize_with_scale(x, scale,
    jax.random.uniform(key, x.shape), bits) on every backend."""
    use_ref, interpret = _dispatch()
    n = int(jnp.size(x))
    if use_ref:
        levels = 2.0 ** (bits - 1) - 1.0
        u = ref.threefry_uniform_ref(key_data, n).reshape(jnp.shape(x))
        return ref.quantize_codes_with_scale_ref(x, scale, u, levels)
    out = quantize_with_scale_keyed_pallas(
        x.reshape(-1), jnp.asarray(scale, jnp.float32), key_data, bits, interpret=interpret
    )
    return out.reshape(jnp.shape(x))


def quantize_pack_keyed(x, scale, key_data, bits: int):
    """Keyed twin of ``quantize_pack``: fused quantize -> stochastic
    round (in-kernel PRNG) -> pack. Neither the uniform field nor (for
    int4) the codes touch HBM; the wire bytes equal quantize_pack with
    the streamed jax.random.uniform(key, (n,)) field bit for bit."""
    use_ref, interpret = _dispatch()
    n = x.shape[0]
    if use_ref:
        u = ref.threefry_uniform_ref(key_data, n)
        return ref.quantize_pack_ref(x, scale, u, bits)
    if bits == 4:
        return quantize_pack4_keyed_pallas(
            x, jnp.asarray(scale, jnp.float32), key_data, interpret=interpret
        )
    return quantize_with_scale_keyed_pallas(
        x, jnp.asarray(scale, jnp.float32), key_data, bits, interpret=interpret
    )


def topk_scatter_add(values, idx, weights, n: int):
    """Aggregate stacked top-k payloads in the code domain: values
    (K, k) f32, idx (K, k) int32, weights (K,) -> dense (n,) f32
    weighted sum. Duplicate coordinates accumulate. Dispatch follows the
    same registry knobs as ``topk_unpack`` (the segmented kernel shares
    its segment-size crossover)."""
    from repro.profile.tuner import get_knob

    use_ref, interpret = _dispatch()
    if use_ref:
        return ref.topk_scatter_add_ref(values, idx, weights, n)
    flat_vals = (weights[:, None] * values.astype(jnp.float32)).reshape(-1)
    flat_idx = idx.reshape(-1)
    return topk_scatter_add_pallas(
        flat_vals, flat_idx, n, seg=int(get_knob("wire_pack.topk_seg_size")), interpret=interpret
    )
