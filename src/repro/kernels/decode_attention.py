"""Flash-decode Pallas TPU kernel: one query token vs. a long KV cache.

Decode attention is memory-bound (the whole cache streams HBM->VMEM
once per step); the kernel's job is to keep that stream dense and
fuse the softmax so nothing round-trips. Grid: (B, Kv, S / ts) with
the sequence axis innermost/sequential carrying (m, l, acc) scratch —
per kv-head, all G grouped q-heads are processed together as the
(G, D) left operand of the MXU matmuls.

Under sequence-sharded caches (long_500k), each shard runs this
kernel on its S/shards slice and the partials merge with the standard
logsumexp combine (GSPMD all-reduce) — see repro/models/attention.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            window: int, scale: float, ts: int, n_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0]
    q = q_ref[0].astype(jnp.float32) * scale          # (G, D)
    k = k_ref[0].astype(jnp.float32)                  # (ts, D)
    v = v_ref[0].astype(jnp.float32)                  # (ts, Dv)
    s = q @ k.T                                       # (G, ts)
    j = si * ts + jax.lax.broadcasted_iota(jnp.int32, (1, ts), 1)
    valid = j <= pos
    if window > 0:
        valid &= j > pos - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(valid, p, 0.0)
    corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_prev * corr[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(si == n_s - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_decode(
    q: jnp.ndarray,             # (B, H, D)
    k_cache: jnp.ndarray,       # (B, S, Kv, D)
    v_cache: jnp.ndarray,       # (B, S, Kv, Dv)
    pos: jnp.ndarray,           # scalar int32: current token index
    *,
    window: int = 0,
    ts: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, D = q.shape
    S, Kv = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    G = H // Kv
    ts = min(ts, S)
    assert S % ts == 0, (S, ts)
    n_s = S // ts
    scale = D ** -0.5

    qr = q.reshape(B, Kv, G, D).reshape(B * Kv, G, D)
    kr = k_cache.transpose(0, 2, 1, 3).reshape(B * Kv, S, D)
    vr = v_cache.transpose(0, 2, 1, 3).reshape(B * Kv, S, Dv)
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (1,))

    out = pl.pallas_call(
        functools.partial(_kernel, window=window, scale=scale, ts=ts, n_s=n_s),
        grid=(B, Kv, n_s),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, G, D), lambda b, h, si: (b * pl.num_programs(1) + h, 0, 0)),
            pl.BlockSpec((1, ts, D), lambda b, h, si: (b * pl.num_programs(1) + h, si, 0)),
            pl.BlockSpec((1, ts, Dv), lambda b, h, si: (b * pl.num_programs(1) + h, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, Dv), lambda b, h, si: (b * pl.num_programs(1) + h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Kv, G, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, qr, kr, vr)
    return out.reshape(B, H, Dv)
