"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode; on TPU
they compile to Mosaic. ``rnnt_joint`` carries a custom_vjp whose
backward dispatches via the ``rnnt.joint_bwd_dispatch`` tuning knob:
the fused Pallas backward (recomputing the joint tile in VMEM with the
forward's shape bucketing) off-CPU, the U-chunked jnp rematerializer
on CPU — both preserve the forward's O(B·T·U) memory during training.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import flash_decode
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lstm_gates import lstm_gates_fused
from repro.kernels.rnnt_joint import rnnt_joint_bwd_fused, rnnt_joint_fused


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "logit_softcap"))
def attention(q, k, v, causal: bool = True, window: int = 0, logit_softcap: float = 0.0):
    return flash_attention(
        q, k, v, causal=causal, window=window, logit_softcap=logit_softcap, interpret=_on_cpu()
    )


@functools.partial(jax.jit, static_argnames=("window",))
def decode_attention(q, k_cache, v_cache, pos, window: int = 0):
    return flash_decode(q, k_cache, v_cache, pos, window=window, interpret=_on_cpu())


@jax.jit
def lstm_gates(gates, c):
    return lstm_gates_fused(gates, c, interpret=_on_cpu())


# ------------------------------------------------------------ rnnt joint

def _joint_ref_chunked(enc_proj, pred_proj, w_out, bias, labels, u_chunk: int = 8):
    """U-chunked jnp joint (differentiable; used for the custom bwd)."""
    B, T, J = enc_proj.shape
    U1 = pred_proj.shape[1]
    n_chunks = max(1, U1 // u_chunk)
    pad = (-U1) % n_chunks
    g = jnp.pad(pred_proj, ((0, 0), (0, pad), (0, 0))) if pad else pred_proj
    l = jnp.pad(labels, ((0, 0), (0, pad))) if pad else labels
    c = g.shape[1] // n_chunks
    gc = g.reshape(B, n_chunks, c, J).swapaxes(0, 1)
    lc = l.reshape(B, n_chunks, c).swapaxes(0, 1)

    def body(_, inp):
        g_i, l_i = inp
        h = jnp.tanh(
            enc_proj[:, :, None, :].astype(jnp.float32) + g_i[:, None, :, :].astype(jnp.float32)
        )
        logits = h @ w_out.astype(jnp.float32) + bias.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        blank = logits[..., 0] - lse
        idx = l_i[:, None, :, None].astype(jnp.int32)
        lab = jnp.take_along_axis(logits, idx, axis=-1)[..., 0] - lse
        return None, (blank, lab)

    _, (blanks, labs) = jax.lax.scan(body, None, (gc, lc))
    # (n_chunks, B, T, c) -> (B, T, n_chunks*c): chunk axis must land
    # OUTSIDE the within-chunk axis, adjacent to it, before flattening
    blank_lp = jnp.moveaxis(blanks, 0, 2).reshape(B, T, -1)[:, :, :U1]
    label_lp = jnp.moveaxis(labs, 0, 2).reshape(B, T, -1)[:, :, :U1]
    return blank_lp, label_lp


@jax.custom_vjp
def rnnt_joint(enc_proj, pred_proj, w_out, bias, labels):
    return rnnt_joint_fused(enc_proj, pred_proj, w_out, bias, labels, interpret=_on_cpu())


def _rnnt_joint_fwd(enc_proj, pred_proj, w_out, bias, labels):
    blank, label, lse = rnnt_joint_fused(
        enc_proj, pred_proj, w_out, bias, labels, interpret=_on_cpu(), return_lse=True
    )
    return (blank, label), (enc_proj, pred_proj, w_out, bias, labels, lse)


def _use_joint_bwd_pallas() -> bool:
    from repro.profile.tuner import get_knob

    mode = get_knob("rnnt.joint_bwd_dispatch")
    if mode == "pallas":
        return True
    return mode == "auto" and not _on_cpu()


def _rnnt_joint_bwd(res, cts):
    enc_proj, pred_proj, w_out, bias, labels, lse = res
    if _use_joint_bwd_pallas():
        de, dg, dw, db = rnnt_joint_bwd_fused(
            enc_proj, pred_proj, w_out, bias, labels, lse, cts[0], cts[1], interpret=_on_cpu()
        )
    else:
        _, vjp = jax.vjp(
            lambda e, g, w, b: _joint_ref_chunked(e, g, w, b, labels),
            enc_proj,
            pred_proj,
            w_out,
            bias,
        )
        de, dg, dw, db = vjp(cts)
    return (
        de.astype(enc_proj.dtype),
        dg.astype(pred_proj.dtype),
        dw.astype(w_out.dtype),
        db.astype(bias.dtype),
        None,
    )


rnnt_joint.defvjp(_rnnt_joint_fwd, _rnnt_joint_bwd)
