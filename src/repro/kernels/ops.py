"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode; on TPU
they compile to Mosaic. ``rnnt_joint`` carries a custom_vjp whose
backward re-materializes through the U-chunked jnp path, preserving
the forward's O(B·T·U) memory during training.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import flash_decode
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lstm_gates import lstm_gates_fused
from repro.kernels.rnnt_joint import rnnt_joint_fused


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "logit_softcap"))
def attention(q, k, v, causal: bool = True, window: int = 0, logit_softcap: float = 0.0):
    return flash_attention(q, k, v, causal=causal, window=window,
                           logit_softcap=logit_softcap, interpret=_on_cpu())


@functools.partial(jax.jit, static_argnames=("window",))
def decode_attention(q, k_cache, v_cache, pos, window: int = 0):
    return flash_decode(q, k_cache, v_cache, pos, window=window, interpret=_on_cpu())


@jax.jit
def lstm_gates(gates, c):
    return lstm_gates_fused(gates, c, interpret=_on_cpu())


# ------------------------------------------------------------ rnnt joint

def _joint_ref_chunked(enc_proj, pred_proj, w_out, bias, labels, u_chunk: int = 8):
    """U-chunked jnp joint (differentiable; used for the custom bwd)."""
    B, T, J = enc_proj.shape
    U1 = pred_proj.shape[1]
    n_chunks = max(1, U1 // u_chunk)
    pad = (-U1) % n_chunks
    g = jnp.pad(pred_proj, ((0, 0), (0, pad), (0, 0))) if pad else pred_proj
    l = jnp.pad(labels, ((0, 0), (0, pad))) if pad else labels
    c = g.shape[1] // n_chunks
    gc = g.reshape(B, n_chunks, c, J).swapaxes(0, 1)
    lc = l.reshape(B, n_chunks, c).swapaxes(0, 1)

    def body(_, inp):
        g_i, l_i = inp
        h = jnp.tanh(enc_proj[:, :, None, :].astype(jnp.float32)
                     + g_i[:, None, :, :].astype(jnp.float32))
        logits = h @ w_out.astype(jnp.float32) + bias.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        blank = logits[..., 0] - lse
        lab = jnp.take_along_axis(
            logits, l_i[:, None, :, None].astype(jnp.int32), axis=-1)[..., 0] - lse
        return None, (blank, lab)

    _, (blanks, labs) = jax.lax.scan(body, None, (gc, lc))
    blank_lp = blanks.swapaxes(0, 1).reshape(B, T, -1)[:, :, :U1]
    label_lp = labs.swapaxes(0, 1).reshape(B, T, -1)[:, :, :U1]
    return blank_lp, label_lp


@jax.custom_vjp
def rnnt_joint(enc_proj, pred_proj, w_out, bias, labels):
    return rnnt_joint_fused(enc_proj, pred_proj, w_out, bias, labels,
                            interpret=_on_cpu())


def _rnnt_joint_fwd(enc_proj, pred_proj, w_out, bias, labels):
    out = rnnt_joint(enc_proj, pred_proj, w_out, bias, labels)
    return out, (enc_proj, pred_proj, w_out, bias, labels)


def _rnnt_joint_bwd(res, cts):
    enc_proj, pred_proj, w_out, bias, labels = res
    _, vjp = jax.vjp(
        lambda e, g, w, b: _joint_ref_chunked(e, g, w, b, labels),
        enc_proj, pred_proj, w_out, bias)
    de, dg, dw, db = vjp(cts)
    return de, dg, dw, db, None


rnnt_joint.defvjp(_rnnt_joint_fwd, _rnnt_joint_bwd)
