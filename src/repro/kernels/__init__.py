"""Pallas TPU kernels for the perf-critical compute layers.

- ``rnnt_joint``      — fused RNN-T joint + log-softmax + (blank, label)
                        gather (the paper-model's memory hot-spot)
- ``flash_attention`` — blockwise causal/window/GQA attention
- ``decode_attention``— flash-decode (one token vs. a long cache)
- ``lstm_gates``      — fused LSTM cell pointwise update
- ``wire_pack``       — packed-wire payloads for the compression plane
                        (int4 nibble pack/unpack, intN dequant, top-k
                        scatter-unpack)

Each has a jnp oracle in ``ref.py`` and a jit'd wrapper in ``ops.py``
(``wire_pack`` carries its own backend dispatch).
On this CPU-only container they run in interpret mode; TPU is the
compile target (BlockSpec VMEM tiling, MXU-aligned tiles).
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
