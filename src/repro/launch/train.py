"""End-to-end federated training driver (the paper's experiment loop).

Runs federated rounds of any registered ``FederatedTask`` — the
paper's RNN-T, the enc-dec/LM/MoE/RWKV zoo tasks, or the
keyword-spotting tiny model — on the synthetic speaker-split corpus,
with the paper's knobs (data limit, FVN, server LR schedule), CFMQ
accounting per round, and the optional per-client evaluation plane.
On this container it runs the reduced configs on CPU; the same driver
pjits onto the production mesh when one is available.

Usage:
    PYTHONPATH=src python -m repro.launch.train --task asr-rnnt --rounds 40
    PYTHONPATH=src python -m repro.launch.train --task keyword \
        --population 1000000 --clients 32
    PYTHONPATH=src python -m repro.launch.train --arch rnnt-librispeech ...

The task carries the model AND its eval contract, so this module has
no model-specific code: quality is WER, perplexity or error rate
depending on the task (the ``quality_metric`` summary field says
which).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.core import (
    FederatedPlan,
    FederatedTask,
    FVNConfig,
    available_tasks,
    build_round_engine,
    cfmq,
    get_task,
    measured_payload,
    plan_wire_accounting,
    round_wire_bytes,
    summary_row,
    task_for_config,
)
from repro.core.clienteval import ClientEvalPlane, empty_spread
from repro.core.task import arch_task, default_corpus
from repro.data import (
    FederatedSampler,
    PrefetchIterator,
    available_strategies,
    pack_round,
)
from repro.launch.cli import (
    add_client_eval_args,
    add_plan_args,
    add_scale_args,
    plan_kwargs,
)


def tiny_asr_setup(seed: int = 0):
    """Container-scale RNN-T config + corpus (the benchmarks'
    workhorse) — the 'asr-rnnt' task's pieces, kept as a tuple for the
    callers that predate FederatedTask."""
    return get_task("asr-rnnt").bundle.config, default_corpus(seed)


def _check_iid_corruption(plan: FederatedPlan, iid: bool) -> None:
    if iid and plan.corruption.kind == "label_shuffle":
        raise ValueError(
            "label_shuffle corrupts labels inside the FederatedSampler, but "
            "--iid packs rounds from the global pool and bypasses the "
            "sampler — the adversary would silently never fire. Use a "
            "non-IID run (or a delta corruption kind, which is engine-side "
            "and composes with --iid)")


def _scaled_task(task: FederatedTask, specaug_scale: float) -> FederatedTask:
    """Rebuild the task around a specaug-scaled config (E10-style
    regularization sweeps); only defined for models that carry a
    ``specaug`` policy."""
    cfg = task.bundle.config
    if getattr(cfg, "specaug", None) is None:
        raise ValueError(
            f"specaug_scale={specaug_scale} but task {task.name!r} "
            f"({type(cfg).__name__}) has no specaug policy")
    sa = cfg.specaug
    cfg = dataclasses.replace(
        cfg, specaug=dataclasses.replace(
            sa, freq_masks=max(1, int(round(sa.freq_masks * specaug_scale))),
            time_masks=max(1, int(round(sa.time_masks * specaug_scale)))))
    return task_for_config(cfg, name=task.name)


def run_federated(
    task: FederatedTask,
    corpus,
    plan: FederatedPlan,
    rounds: int,
    seed: int = 0,
    iid: bool = False,
    eval_every: int = 0,
    eval_examples: int = 64,
    specaug_scale: float = 1.0,
    log=print,
    ckpt_dir: str | None = None,
    prefetch: bool = True,
    trace_path: str | None = None,
    mesh_clients: int = 0,
    client_eval: int = 0,
    client_eval_examples: int = 4,
):
    """Returns (state, history): per-round losses + the task's final
    quality + CFMQ, in the shared ``SUMMARY_KEYS`` schema.

    ``trace_path`` routes pack/round/eval section timers through the
    profiling plane's single writer (``repro.profile.trace``), keyed by
    the engine's structural key — the train-side calibration feed.
    ``mesh_clients`` > 0 shards the round's client axis over a
    ``clients`` mesh (bit-for-bit the vmap round on 1 device).
    ``client_eval`` > 0 tracks that many clients' per-round
    loss/quality (``repro.core.clienteval``): the fairness spread
    joins the summary fields and the full curves ride in
    ``extras["client_eval"]``."""
    _check_iid_corruption(plan, iid)
    if specaug_scale != 1.0:
        task = _scaled_task(task, specaug_scale)
    bundle = task.bundle
    key = jax.random.PRNGKey(seed)
    params = bundle.init(key)
    n_params = bundle.param_count(params)
    client_sharding = None
    if mesh_clients:
        from repro.core.fedavg import ClientSharding
        from repro.launch.mesh import make_federated_mesh

        client_sharding = ClientSharding(make_federated_mesh(mesh_clients))
    engine = build_round_engine(plan, task,
                                base_key=jax.random.PRNGKey(seed + 1),
                                client_sharding=client_sharding)
    state = engine.init_state(params)
    round_step = jax.jit(engine.step)

    sampler = FederatedSampler(
        corpus, clients_per_round=plan.clients_per_round,
        local_batch_size=plan.local_batch_size, data_limit=plan.data_limit,
        local_epochs=plan.local_epochs, seed=seed,
        max_steps=plan.local_steps, strategy=plan.client_sampling,
        label_shuffle_rate=(plan.corruption.rate
                            if plan.corruption.kind == "label_shuffle"
                            else 0.0))
    rng = np.random.default_rng(seed)
    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    eval_plane = (ClientEvalPlane(task, corpus, clients=client_eval,
                                  n=client_eval_examples)
                  if client_eval > 0 else None)

    from repro.profile.trace import TraceRecorder

    rec = TraceRecorder()

    def host_batches():
        """Host packing stream — runs on the prefetch worker thread so
        round r+1 packs (and transfers) while the device runs round r."""
        for _ in range(rounds):
            with rec.section("pack"):
                if iid:
                    # fresh IID shuffle each round
                    pool = corpus.iid_pool()
                    idx = rng.permutation(pool["labels"].shape[0])
                    pool = {k: v[idx] for k, v in pool.items()}
                    rb = pack_round(pool, plan.clients_per_round, sampler.steps,
                                    plan.local_batch_size)
                else:
                    rb = sampler.next_round()
                batch = rb.engine_batch()
            yield batch

    # wire accounting: exact per-client byte counts over the param
    # shapes, accumulated as host-side Python ints — the in-graph f32
    # byte metrics round above ~16 MB/round, exact ints never do
    up_per_client, down_per_round = plan_wire_accounting(plan, params)

    t0 = time.time()
    wire_total = 0
    losses = []
    participants = []
    corrupted = []
    sim_times = []
    server_steps = []
    staleness = []
    # per-shard prefetch: with a client mesh the worker thread puts each
    # round batch pre-split over the ``clients`` axis, so the sharded
    # round step never stalls on a consumer-thread reshard; depth comes
    # from the tuning registry (``prefetch.depth``)
    batch_sharding = None
    if client_sharding is not None:
        from jax.sharding import NamedSharding, PartitionSpec

        batch_sharding = NamedSharding(client_sharding.mesh,
                                       PartitionSpec(client_sharding.axis))
    if prefetch:
        from repro.profile.tuner import get_knob

        batches = PrefetchIterator(host_batches(),
                                   depth=int(get_knob("prefetch.depth")),
                                   sharding=batch_sharding)
    else:
        batches = map(lambda b: jax.tree.map(jnp.asarray, b), host_batches())
    try:
        for r, batch in enumerate(batches):
            # float() blocks, so the section covers dispatch + device
            # compute; round 1 includes compile — min_s is steady-state
            with rec.section("round"):
                state, metrics = round_step(state, batch)
                losses.append(float(metrics["loss"]))
            participants.append(float(metrics["participants"]))
            corrupted.append(float(metrics["corrupted"]))
            sim_times.append(float(metrics["sim_time_s"]))
            server_steps.append(float(metrics["server_steps"]))
            staleness.append(float(metrics["staleness_mean"]))
            wire_total += round_wire_bytes(up_per_client, down_per_round,
                                           participants[-1])
            if eval_plane is not None:
                eval_plane.measure(state.params)
            if eval_every and (r + 1) % eval_every == 0:
                q = task.evaluate(state.params, corpus, eval_examples)
                log(f"round {r+1}: loss={losses[-1]:.4f} "
                    f"{task.quality_metric}={q['quality']:.3f} "
                    f"{task.quality_metric}_hard={q['quality_hard']:.3f}")
            if ckpt and (r + 1) % max(1, rounds // 3) == 0:
                ckpt.save(r + 1, state.params,
                          extra={"wire_bytes": wire_total,
                                 "participants_mean": float(np.mean(participants))})
    finally:
        if prefetch:
            batches.close()

    train_time_s = time.time() - t0
    with rec.section("eval"):
        quality = task.evaluate(state.params, corpus, eval_examples)
    mu = plan.local_epochs * (plan.data_limit or sampler.steps * plan.local_batch_size)
    payload = measured_payload(plan, params, float(np.mean(participants)))
    terms = cfmq(
        rounds=rounds, clients_per_round=plan.clients_per_round,
        model_bytes=n_params * plan.param_bytes,
        local_steps=mu / plan.local_batch_size, alpha=plan.alpha,
        payload_bytes=payload)
    if plan.corruption.kind == "label_shuffle":
        # data-plane adversary: realized counts live on the sampler
        corrupted = [float(c) for c in sampler.corrupted_counts]
    steps_total = sum(server_steps)
    extras = {
        "loss": losses,
        "wire_bytes": wire_total,
        "train_time_s": train_time_s,
    }
    if eval_plane is not None:
        extras["client_eval"] = eval_plane.curves()
    spread = eval_plane.spread() if eval_plane is not None else empty_spread()
    # same round-metrics schema as the sweep rows and bench summaries
    # (repro.core.metrics.SUMMARY_KEYS); the loss curve and the legacy
    # "wire_bytes"/"train_time_s" aliases ride along as extras
    history = summary_row(
        rounds=rounds,
        final_loss=float(np.mean(losses[-5:])),
        quality=quality["quality"], quality_hard=quality["quality_hard"],
        quality_metric=task.quality_metric,
        **spread,
        cfmq_tb=terms.total_terabytes, cfmq_bytes=terms.total_bytes,
        payload_bytes=terms.payload_bytes,
        uplink_bytes_client=up_per_client,
        uplink_bytes_total=wire_total - down_per_round * rounds,
        wire_bytes_total=wire_total,
        downlink_bytes_round=down_per_round,
        participants_mean=float(np.mean(participants)),
        corrupted_mean=float(np.mean(corrupted)) if corrupted else 0.0,
        corrupted_total=int(round(sum(corrupted))),
        n_params=n_params,
        sim_time_s=sum(sim_times),
        server_steps_total=steps_total,
        staleness_mean=(sum(s * w for s, w in zip(staleness, server_steps))
                        / steps_total if steps_total else 0.0),
        wall_s=train_time_s,
        extras=extras,
    )
    if trace_path:
        from repro.core.engine import structural_key_str
        from repro.profile.predict import plan_round_features
        from repro.profile.trace import write_trace

        write_trace(
            trace_path, "round",
            structural_key=structural_key_str(engine.structural_key),
            sections=rec,
            counters={"rounds": rounds, "n_params": n_params,
                      "local_steps": sampler.steps},
            features=plan_round_features(plan, params, sampler.steps,
                                         client_shards=mesh_clients or 1),
            meta={"wall_s": train_time_s, "final_loss": history["final_loss"]},
        )
        log(f"[trace] {trace_path}")
    return state, history


def run_federated_asr(cfg, corpus, plan: FederatedPlan, rounds: int, **kwargs):
    """Config-first compatibility wrapper: the pre-FederatedTask entry
    point. Builds the task from the model config and delegates to
    ``run_federated`` (new code should construct the task directly)."""
    _check_iid_corruption(plan, kwargs.get("iid", False))
    return run_federated(task_for_config(cfg), corpus, plan, rounds, **kwargs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default=None, choices=available_tasks(),
                    help="a registered FederatedTask (model + eval metric); "
                         "overrides --preset/--arch")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "arch"])
    ap.add_argument("--arch", default="rnnt-librispeech")
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--data-limit", type=int, default=None)
    ap.add_argument("--fvn-std", type=float, default=0.0)
    ap.add_argument("--fvn-ramp", type=int, default=0)
    ap.add_argument("--iid", action="store_true")
    ap.add_argument("--server-lr", type=float, default=0.01)
    ap.add_argument("--client-lr", type=float, default=0.05)
    ap.add_argument("--client-sampling", default="uniform",
                    choices=available_strategies())
    add_scale_args(ap)
    add_plan_args(ap)
    add_client_eval_args(ap)
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable the async host->device prefetch")
    ap.add_argument("--eval-every", type=int, default=10)
    ap.add_argument("--out", default=None)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a profiling-plane trace JSON (pack/round/"
                         "eval section timers, keyed by the engine's "
                         "structural key + device fingerprint)")
    args = ap.parse_args()

    if args.task is not None:
        task = get_task(args.task)
    elif args.preset == "tiny":
        task = get_task("asr-rnnt")
    else:
        task = arch_task(args.arch)
    corpus = default_corpus(0)
    if args.population:
        from repro.data import VirtualPopulation

        corpus = VirtualPopulation(corpus, args.population)

    plan = FederatedPlan(
        clients_per_round=args.clients, local_batch_size=args.batch,
        data_limit=args.data_limit, client_lr=args.client_lr,
        client_sampling=args.client_sampling,
        server_lr=args.server_lr, server_warmup_rounds=max(2, args.rounds // 8),
        fvn=FVNConfig(enabled=args.fvn_std > 0, std=args.fvn_std,
                      ramp_rounds=args.fvn_ramp),
        **plan_kwargs(args),
    )
    _, hist = run_federated(task, corpus, plan, args.rounds, iid=args.iid,
                            eval_every=args.eval_every,
                            prefetch=not args.no_prefetch,
                            trace_path=args.trace,
                            mesh_clients=args.mesh_clients,
                            client_eval=args.client_eval,
                            client_eval_examples=args.client_eval_examples)
    print(json.dumps({k: v for k, v in hist.items()
                      if k not in ("loss", "client_eval")}, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(hist, f)


if __name__ == "__main__":
    main()
