"""End-to-end federated training driver (the paper's experiment loop).

Runs FedAvg rounds of the RNN-T (or any registered arch) on the
synthetic speaker-split corpus, with the paper's knobs — data limit,
FVN, server LR schedule — and CFMQ accounting per round. On this
container it runs the reduced configs on CPU; the same driver pjits
onto the production mesh when one is available.

Usage:
    PYTHONPATH=src python -m repro.launch.train --preset tiny --rounds 40
    PYTHONPATH=src python -m repro.launch.train --arch rnnt-librispeech ...
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.asr.wer import wer
from repro.checkpoint import Checkpointer
from repro.configs import get_arch
from repro.core import (
    AggregatorConfig,
    AsyncConfig,
    CohortConfig,
    CompressionConfig,
    CorruptionConfig,
    FederatedPlan,
    FVNConfig,
    LatencyConfig,
    available_aggregators,
    available_corruptions,
    build_round_engine,
    cfmq,
    measured_payload,
    plan_wire_accounting,
    round_wire_bytes,
    summary_row,
)
from repro.core.compression import KINDS
from repro.data import (
    FederatedSampler,
    PrefetchIterator,
    available_strategies,
    make_speaker_corpus,
    pack_round,
)
from repro.models import build_model
from repro.models.rnnt import greedy_decode


def tiny_asr_setup(seed: int = 0):
    """Container-scale RNN-T + corpus (the benchmarks' workhorse)."""
    from repro.asr.specaugment import SpecAugmentConfig
    from repro.models.rnnt import RNNTConfig

    cfg = RNNTConfig(
        name="rnnt-tiny", feat_dim=16, vocab=64,
        enc_layers=2, enc_hidden=96, pred_layers=1, pred_hidden=96,
        pred_embed=32, joint_dim=64, time_stride=1,
        specaug=SpecAugmentConfig(freq_masks=1, freq_mask_width=3,
                                  time_masks=1, time_mask_frac=0.05),
        dtype="float32", param_dtype="float32",
    )
    corpus = make_speaker_corpus(num_speakers=48, vocab_size=64, feat_dim=16,
                                 mean_utterances=24.0, seed=seed)
    return cfg, corpus


def run_federated_asr(
    cfg,
    corpus,
    plan: FederatedPlan,
    rounds: int,
    seed: int = 0,
    iid: bool = False,
    eval_every: int = 0,
    eval_examples: int = 64,
    specaug_scale: float = 1.0,
    log=print,
    ckpt_dir: str | None = None,
    prefetch: bool = True,
    trace_path: str | None = None,
    mesh_clients: int = 0,
):
    """Returns history dict with per-round losses + final WERs + CFMQ.

    ``trace_path`` routes pack/round/eval section timers through the
    profiling plane's single writer (``repro.profile.trace``), keyed by
    the engine's structural key — the train-side calibration feed.
    ``mesh_clients`` > 0 shards the round's client axis over a
    ``clients`` mesh of that many devices (bit-for-bit the vmap round
    on 1 device; see ``core.fedavg.ClientSharding``)."""
    if iid and plan.corruption.kind == "label_shuffle":
        raise ValueError(
            "label_shuffle corrupts labels inside the FederatedSampler, but "
            "--iid packs rounds from the global pool and bypasses the "
            "sampler — the adversary would silently never fire. Use a "
            "non-IID run (or a delta corruption kind, which is engine-side "
            "and composes with --iid)")
    if specaug_scale != 1.0:
        sa = cfg.specaug
        cfg = dataclasses.replace(
            cfg, specaug=dataclasses.replace(
                sa, freq_masks=max(1, int(round(sa.freq_masks * specaug_scale))),
                time_masks=max(1, int(round(sa.time_masks * specaug_scale)))))
    bundle = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = bundle.init(key)
    n_params = bundle.param_count(params)
    client_sharding = None
    if mesh_clients:
        from repro.core.fedavg import ClientSharding
        from repro.launch.mesh import make_federated_mesh

        client_sharding = ClientSharding(make_federated_mesh(mesh_clients))
    engine = build_round_engine(plan, bundle.loss_fn,
                                base_key=jax.random.PRNGKey(seed + 1),
                                client_sharding=client_sharding)
    state = engine.init_state(params)
    round_step = jax.jit(engine.step)

    sampler = FederatedSampler(
        corpus, clients_per_round=plan.clients_per_round,
        local_batch_size=plan.local_batch_size, data_limit=plan.data_limit,
        local_epochs=plan.local_epochs, seed=seed,
        max_steps=plan.local_steps, strategy=plan.client_sampling,
        label_shuffle_rate=(plan.corruption.rate
                            if plan.corruption.kind == "label_shuffle"
                            else 0.0))
    rng = np.random.default_rng(seed)
    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None

    from repro.profile.trace import TraceRecorder

    rec = TraceRecorder()

    def host_batches():
        """Host packing stream — runs on the prefetch worker thread so
        round r+1 packs (and transfers) while the device runs round r."""
        for _ in range(rounds):
            with rec.section("pack"):
                if iid:
                    # fresh IID shuffle each round
                    pool = corpus.iid_pool()
                    idx = rng.permutation(pool["labels"].shape[0])
                    pool = {k: v[idx] for k, v in pool.items()}
                    rb = pack_round(pool, plan.clients_per_round, sampler.steps,
                                    plan.local_batch_size)
                else:
                    rb = sampler.next_round()
                batch = rb.engine_batch()
            yield batch

    # wire accounting: exact per-client byte counts over the param
    # shapes, accumulated as host-side Python ints — the in-graph f32
    # byte metrics round above ~16 MB/round, exact ints never do
    up_per_client, down_per_round = plan_wire_accounting(plan, params)

    t0 = time.time()
    wire_total = 0
    losses = []
    participants = []
    corrupted = []
    sim_times = []
    server_steps = []
    staleness = []
    batches = (PrefetchIterator(host_batches(), depth=2) if prefetch
               else map(lambda b: jax.tree.map(jnp.asarray, b), host_batches()))
    try:
        for r, batch in enumerate(batches):
            # float() blocks, so the section covers dispatch + device
            # compute; round 1 includes compile — min_s is steady-state
            with rec.section("round"):
                state, metrics = round_step(state, batch)
                losses.append(float(metrics["loss"]))
            participants.append(float(metrics["participants"]))
            corrupted.append(float(metrics["corrupted"]))
            sim_times.append(float(metrics["sim_time_s"]))
            server_steps.append(float(metrics["server_steps"]))
            staleness.append(float(metrics["staleness_mean"]))
            wire_total += round_wire_bytes(up_per_client, down_per_round,
                                           participants[-1])
            if eval_every and (r + 1) % eval_every == 0:
                w = evaluate_wer(cfg, bundle, state.params, corpus, eval_examples)
                log(f"round {r+1}: loss={losses[-1]:.4f} "
                    f"wer={w['wer']:.3f} wer_hard={w['wer_hard']:.3f}")
            if ckpt and (r + 1) % max(1, rounds // 3) == 0:
                ckpt.save(r + 1, state.params,
                          extra={"wire_bytes": wire_total,
                                 "participants_mean": float(np.mean(participants))})
    finally:
        if prefetch:
            batches.close()

    train_time_s = time.time() - t0
    with rec.section("eval"):
        wers = evaluate_wer(cfg, bundle, state.params, corpus, eval_examples)
    mu = plan.local_epochs * (plan.data_limit or sampler.steps * plan.local_batch_size)
    payload = measured_payload(plan, params, float(np.mean(participants)))
    terms = cfmq(
        rounds=rounds, clients_per_round=plan.clients_per_round,
        model_bytes=n_params * plan.param_bytes,
        local_steps=mu / plan.local_batch_size, alpha=plan.alpha,
        payload_bytes=payload)
    if plan.corruption.kind == "label_shuffle":
        # data-plane adversary: realized counts live on the sampler
        corrupted = [float(c) for c in sampler.corrupted_counts]
    steps_total = sum(server_steps)
    # same round-metrics schema as the sweep rows and bench summaries
    # (repro.core.metrics.SUMMARY_KEYS); the loss curve and the legacy
    # "wire_bytes"/"train_time_s" aliases ride along as extras
    history = summary_row(
        rounds=rounds,
        final_loss=float(np.mean(losses[-5:])),
        wer=wers["wer"], wer_hard=wers["wer_hard"],
        cfmq_tb=terms.total_terabytes, cfmq_bytes=terms.total_bytes,
        payload_bytes=terms.payload_bytes,
        uplink_bytes_client=up_per_client,
        uplink_bytes_total=wire_total - down_per_round * rounds,
        wire_bytes_total=wire_total,
        downlink_bytes_round=down_per_round,
        participants_mean=float(np.mean(participants)),
        corrupted_mean=float(np.mean(corrupted)) if corrupted else 0.0,
        corrupted_total=int(round(sum(corrupted))),
        n_params=n_params,
        sim_time_s=sum(sim_times),
        server_steps_total=steps_total,
        staleness_mean=(sum(s * w for s, w in zip(staleness, server_steps))
                        / steps_total if steps_total else 0.0),
        wall_s=train_time_s,
        extras={
            "loss": losses,
            "wire_bytes": wire_total,
            "train_time_s": train_time_s,
        },
    )
    if trace_path:
        from repro.core.engine import structural_key_str
        from repro.profile.predict import plan_round_features
        from repro.profile.trace import write_trace

        write_trace(
            trace_path, "round",
            structural_key=structural_key_str(engine.structural_key),
            sections=rec,
            counters={"rounds": rounds, "n_params": n_params,
                      "local_steps": sampler.steps},
            features=plan_round_features(plan, params, sampler.steps,
                                         client_shards=mesh_clients or 1),
            meta={"wall_s": train_time_s, "final_loss": history["final_loss"]},
        )
        log(f"[trace] {trace_path}")
    return state, history


@functools.lru_cache(maxsize=None)
def _jitted_decode(cfg):
    """One jitted greedy_decode per config; jit's own cache then keys
    on the eval-batch shapes, so repeated sweep-point evals at the
    same (cfg, shape) reuse one compilation instead of re-tracing the
    whole decode scan every call."""
    return jax.jit(functools.partial(greedy_decode, cfg))


def evaluate_wer(cfg, bundle, params, corpus, n: int = 64):
    decode = _jitted_decode(cfg)
    out = {}
    for name, hard in (("wer", False), ("wer_hard", True)):
        ev = corpus.eval_split(n, hard=hard)
        hyp = decode(params, jnp.asarray(ev["features"]),
                     jnp.asarray(ev["frame_len"]))
        refs = [ev["labels"][i, : ev["label_len"][i]].tolist() for i in range(n)]
        hyps = [h[h != 0].tolist() for h in np.asarray(hyp)]
        out[name] = wer(refs, hyps)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "arch"])
    ap.add_argument("--arch", default="rnnt-librispeech")
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--data-limit", type=int, default=None)
    ap.add_argument("--fvn-std", type=float, default=0.0)
    ap.add_argument("--fvn-ramp", type=int, default=0)
    ap.add_argument("--iid", action="store_true")
    ap.add_argument("--server-lr", type=float, default=0.01)
    ap.add_argument("--client-lr", type=float, default=0.05)
    ap.add_argument("--client-sampling", default="uniform",
                    choices=available_strategies())
    # population-scale rounds: virtual clients + client-axis sharding
    pop = ap.add_argument_group("population scale")
    pop.add_argument("--population", type=int, default=0,
                     help="simulate this many VIRTUAL clients over the "
                          "corpus (sampling sees N clients; host memory "
                          "stays O(corpus + K); 0 = plain corpus)")
    pop.add_argument("--mesh-clients", type=int, default=0,
                     help="shard the round's client axis over this many "
                          "devices (clients mesh axis; CPU smoke via "
                          "XLA_FLAGS=--xla_force_host_platform_device_"
                          "count=N; 0 = unsharded vmap)")
    # round engine: sync barrier vs buffered-async streaming server
    eng = ap.add_argument_group("round engine")
    eng.add_argument("--engine", default="fedavg",
                     choices=["fedavg", "fedsgd", "async"],
                     help="barrier FedAvg/FedSGD or the buffered-async "
                          "(FedBuff-style) streaming server")
    eng.add_argument("--buffer-size", type=int, default=0,
                     help="async: server steps when this many updates are "
                          "buffered (0 = clients-per-round)")
    eng.add_argument("--staleness-beta", type=float, default=0.5,
                     help="async: discount buffered deltas by 1/(1+s)^beta, "
                          "s in server versions since download")
    eng.add_argument("--latency", action="store_true",
                     help="price sync rounds in simulated seconds too "
                          "(async always draws arrival times)")
    eng.add_argument("--latency-base-s", type=float, default=60.0,
                     help="device-tier latency model: base upload seconds")
    eng.add_argument("--latency-spread", type=float, default=0.25,
                     help="device-tier latency model: lognormal jitter std")
    # server aggregation rule + its knobs (AggregatorConfig)
    agg = ap.add_argument_group("aggregation")
    agg.add_argument("--aggregator", default="weighted_mean",
                     choices=available_aggregators())
    agg.add_argument("--trim-frac", type=float, default=0.1,
                     help="trimmed_mean: fraction trimmed per side")
    agg.add_argument("--dp-clip", type=float, default=1.0,
                     help="clipped_mean: per-client L2 clip norm")
    agg.add_argument("--dp-sigma", type=float, default=0.0,
                     help="clipped_mean: DP Gaussian noise multiplier")
    # server-plane: compression / cohort dynamics
    ap.add_argument("--compression", default="none", choices=list(KINDS),
                    help="uplink delta compression (exact wire bytes in CFMQ)")
    ap.add_argument("--topk-frac", type=float, default=0.05)
    ap.add_argument("--packed-wire", action="store_true",
                    help="materialize + round-trip the packed uplink payload "
                         "(wire_pack kernels; bit-identical numerics)")
    ap.add_argument("--error-feedback", action="store_true",
                    help="EF21 per-client residual accumulation (compensates "
                         "top-k/int4 error across rounds; same wire bytes)")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="P(sampled client reports back)")
    ap.add_argument("--straggler-frac", type=float, default=0.0)
    ap.add_argument("--straggler-keep", type=float, default=0.5,
                    help="fraction of local steps a straggler completes")
    # adversarial client corruption (see repro.core.corruption)
    ap.add_argument("--corrupt-kind", default="none",
                    choices=["none", "label_shuffle"] + available_corruptions(),
                    help="adversary: delta corruption (sign_flip/gaussian/"
                         "zero/stale) or the data-plane label_shuffle")
    ap.add_argument("--corrupt-rate", type=float, default=0.0,
                    help="P(participating client is corrupted) per round")
    ap.add_argument("--corrupt-scale", type=float, default=1.0,
                    help="adversary magnitude (sign_flip/gaussian/stale)")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable the async host->device prefetch")
    ap.add_argument("--eval-every", type=int, default=10)
    ap.add_argument("--out", default=None)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a profiling-plane trace JSON (pack/round/"
                         "eval section timers, keyed by the engine's "
                         "structural key + device fingerprint)")
    args = ap.parse_args()

    if args.preset == "tiny":
        cfg, corpus = tiny_asr_setup()
    else:
        cfg = get_arch(args.arch).make_smoke_config()
        _, corpus = tiny_asr_setup()
    if args.population:
        from repro.data import VirtualPopulation

        corpus = VirtualPopulation(corpus, args.population)

    plan = FederatedPlan(
        clients_per_round=args.clients, local_batch_size=args.batch,
        data_limit=args.data_limit, client_lr=args.client_lr,
        client_sampling=args.client_sampling,
        server_lr=args.server_lr, server_warmup_rounds=max(2, args.rounds // 8),
        engine=args.engine,
        asynchrony=AsyncConfig(buffer_size=args.buffer_size,
                               staleness_beta=args.staleness_beta),
        latency=LatencyConfig(enabled=args.latency,
                              base_s=args.latency_base_s,
                              spread=args.latency_spread),
        fvn=FVNConfig(enabled=args.fvn_std > 0, std=args.fvn_std,
                      ramp_rounds=args.fvn_ramp),
        cohort=CohortConfig(participation=args.participation,
                            straggler_frac=args.straggler_frac,
                            straggler_keep=args.straggler_keep),
        compression=CompressionConfig(kind=args.compression,
                                      topk_frac=args.topk_frac,
                                      packed=args.packed_wire,
                                      error_feedback=args.error_feedback),
        aggregation=AggregatorConfig(name=args.aggregator,
                                     trim_frac=args.trim_frac,
                                     dp_clip=args.dp_clip,
                                     dp_sigma=args.dp_sigma),
        corruption=CorruptionConfig(kind=args.corrupt_kind,
                                    rate=args.corrupt_rate,
                                    scale=args.corrupt_scale),
    )
    _, hist = run_federated_asr(cfg, corpus, plan, args.rounds, iid=args.iid,
                                eval_every=args.eval_every,
                                prefetch=not args.no_prefetch,
                                trace_path=args.trace,
                                mesh_clients=args.mesh_clients)
    print(json.dumps({k: v for k, v in hist.items() if k != "loss"}, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(hist, f)


if __name__ == "__main__":
    main()
