import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape x mesh), build the real step
function — ``fed_round_step`` for train_4k (a full federated round IS
the paper's training step), ``prefill_step`` for prefill_32k,
``serve_step`` for the decode shapes — and ``.lower().compile()`` it
against ShapeDtypeStruct inputs on the production mesh. Emits JSON
with memory analysis, the trip-count-aware HLO cost model's roofline
terms, and the collective schedule.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
        --shape train_4k [--multi-pod] [--out results/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import math
import sys
import time
import traceback

import jax

from repro.configs import get_arch
from repro.configs.base import SHAPES, default_plan
from repro.configs.registry import ASSIGNED, input_specs
from repro.core.fedavg import init_server_state, make_round_step, server_state_specs
from repro.launch import hlo_cost
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh
from repro.launch.sharding import fsdpify, make_param_specs, named, sanitize_specs
from repro.models import build_model

MODEL_FLOPS_NOTE = "6*N*D dense / 6*N_active*D MoE (train); 2*N*D per decoded token"


def active_params(arch, cfg, n_params):
    """N_active for MoE archs (routed experts scaled by top_k/E)."""
    if arch.kind != "moe" or getattr(cfg, "moe", None) is None:
        return n_params
    moe = cfg.moe
    n_scan = cfg.n_layers - cfg.moe_first_dense
    expert_params = n_scan * moe.n_experts * 3 * cfg.d_model * moe.expert_ff
    active_expert = expert_params * moe.top_k / moe.n_experts
    return n_params - expert_params + active_expert


def build_case(arch_id: str, shape_name: str, mesh, serve_ring: bool = False):
    """Returns (jitted_fn, args_struct) ready to lower."""
    arch = get_arch(arch_id)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and arch.long_policy == "skip":
        return None, None, arch, None, f"skipped: {arch.skip_notes}"
    if arch.kind == "rnnt" and shape.kind != "train":
        return None, None, arch, None, "skipped: ASR training model (no serve step)"
    if arch.kind == "hybrid" and shape.kind == "prefill":
        # SSM prefill = the train-shape scan without the backward; lower
        # the loss forward as the prefill proxy (documented).
        pass

    cfg = arch.config_for(shape_name)
    variant = os.environ.get("REPRO_VARIANT")
    if variant:
        import dataclasses as _dc
        import json as _json

        cfg = _dc.replace(cfg, **_json.loads(variant))
    bundle = build_model(cfg)
    names = mesh.axis_names
    n_client_shards = math.prod(
        s for s, n in zip(mesh.devices.shape, names) if n in ("pod", "data"))

    params_struct = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    pspecs = make_param_specs(params_struct, arch.param_rules)
    pspecs = sanitize_specs(params_struct, pspecs, mesh)

    args, aspecs = input_specs(arch, shape, cfg, bundle, n_client_shards)
    aspecs = sanitize_specs(args, aspecs, mesh)

    if shape.kind == "train":
        plan = default_plan(arch.engine, n_client_shards)
        if arch.engine == "fedsgd" and not os.environ.get("REPRO_FEDSGD_ZERO1"):
            live_pspecs = fsdpify(params_struct, pspecs, mesh)   # ZeRO-3 default
        else:
            live_pspecs = pspecs                                  # ZeRO-1: weights TP-only
        moment_specs = fsdpify(params_struct, pspecs, mesh)
        state_struct = jax.eval_shape(
            lambda p: init_server_state(plan, p), params_struct)
        sspecs = server_state_specs(plan, live_pspecs, moment_specs)
        round_step = make_round_step(bundle.loss_fn, plan, jax.random.PRNGKey(7))
        fn = jax.jit(
            round_step,
            in_shardings=(named(mesh, sspecs), named(mesh, aspecs)),
            out_shardings=(named(mesh, sspecs), None),
        )
        return fn, (state_struct, args), arch, cfg, None

    if shape.kind == "prefill":
        if bundle.prefill is None:
            # hybrid: prefill proxy = forward loss (scan over sequence)
            def fwd(params, batch):
                return bundle.loss_fn(params, batch, None)[0]
            fn = jax.jit(fwd, in_shardings=(named(mesh, pspecs), named(mesh, aspecs)),
                         out_shardings=None)
            return fn, (params_struct, args), arch, cfg, None
        fn = jax.jit(
            bundle.prefill,
            in_shardings=(named(mesh, pspecs), named(mesh, aspecs)),
            out_shardings=None,
        )
        return fn, (params_struct, args), arch, cfg, None

    # decode
    cache, tokens, pos = args
    cache_specs, tok_specs, pos_specs = aspecs

    def serve_step(params, cache, tokens, pos):
        return bundle.decode_step(params, cache, tokens, pos)

    fn = jax.jit(
        serve_step,
        in_shardings=(named(mesh, pspecs), named(mesh, cache_specs),
                      named(mesh, tok_specs), named(mesh, pos_specs)),
        out_shardings=(None, named(mesh, cache_specs)),
    )
    return fn, (params_struct, cache, tokens, pos), arch, cfg, None


def run_case(arch_id: str, shape_name: str, multi_pod: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = math.prod(mesh.devices.shape)
    rec = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "chips": n_chips,
    }
    t0 = time.time()
    try:
        fn, args, arch, cfg, skip = build_case(arch_id, shape_name, mesh)
        if skip:
            rec["status"] = "skip"
            rec["reason"] = skip
            return rec
        lowered = fn.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo_text = compiled.as_text()
        if os.environ.get("REPRO_DUMP_HLO"):
            with open(f"/tmp/hlo_{arch_id}_{shape_name}.txt", "w") as f:
                f.write(hlo_text)
        hlo_dir = os.environ.get("REPRO_HLO_DIR")
        if hlo_dir:
            import gzip
            os.makedirs(hlo_dir, exist_ok=True)
            tag = "mp" if multi_pod else "sp"
            with gzip.open(os.path.join(
                    hlo_dir, f"{arch_id}__{shape_name}__{tag}.hlo.gz"), "wt") as f:
                f.write(hlo_text)
        cost = hlo_cost.analyze(hlo_text)

        compute_s = cost["flops"] / PEAK_FLOPS_BF16
        memory_s = cost["bytes"] / HBM_BW
        collective_s = cost["link_bytes"] / ICI_BW
        terms = {"compute_s": compute_s, "memory_s": memory_s,
                 "collective_s": collective_s}
        dominant = max(terms, key=terms.get)

        bundle = build_model(cfg)
        params_struct = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
        n_params = sum(int(math.prod(l.shape)) for l in jax.tree.leaves(params_struct))
        n_active = active_params(arch, cfg, n_params)
        shape = SHAPES[shape_name]
        if shape.kind == "train":
            tokens = shape.global_batch * shape.seq_len
            model_flops = 6.0 * n_active * tokens
        elif shape.kind == "prefill":
            tokens = shape.global_batch * shape.seq_len
            model_flops = 2.0 * n_active * tokens
        else:
            tokens = shape.global_batch
            model_flops = 2.0 * n_active * tokens
        model_flops_per_chip = model_flops / n_chips

        rec.update({
            "status": "ok",
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "total_bytes": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                                + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
            },
            "xla_cost_analysis": {k: ca.get(k) for k in ("flops", "bytes accessed")},
            "hlo_cost": {
                "flops_per_chip": cost["flops"],
                "hbm_bytes_per_chip": cost["bytes"],
                "collective_payload_bytes": cost["collective_bytes"],
                "link_bytes": cost["link_bytes"],
                "collectives": cost["collectives"],
            },
            "roofline": {
                **terms,
                "dominant": dominant,
                "model_flops_per_chip": model_flops_per_chip,
                "useful_flop_ratio": (model_flops_per_chip / cost["flops"]
                                      if cost["flops"] else None),
                "n_params": n_params,
                "n_active_params": n_active,
            },
        })
    except Exception as e:  # noqa: BLE001 — dry-run failures are data
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cases = []
    if args.all:
        for a in ASSIGNED + ["rnnt-librispeech"]:
            for s in SHAPES:
                cases.append((a, s, args.multi_pod))
    else:
        assert args.arch and args.shape
        cases.append((args.arch, args.shape, args.multi_pod))

    for arch_id, shape_name, mp in cases:
        rec = run_case(arch_id, shape_name, multi_pod=mp)
        tag = "mp" if mp else "sp"
        fname = os.path.join(args.out, f"{arch_id}__{shape_name}__{tag}.json")
        with open(fname, "w") as f:
            json.dump(rec, f, indent=1)
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f" compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
                     f"collective={r['collective_s']:.3e}s dom={r['dominant']}")
        elif status == "error":
            extra = " " + rec["error"][:160]
        print(f"[{status}] {arch_id} {shape_name} {rec['mesh']}{extra}", flush=True)
        if status == "error":
            sys.exitcode = 1


if __name__ == "__main__":
    main()
