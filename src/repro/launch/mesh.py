"""Production mesh construction (TPU v5e pods).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first
init; tests and benches must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist from jax 0.5; older releases
    default every axis to Auto anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke runs of the pjit code paths."""
    return compat_make_mesh((1, 1), ("data", "model"))


def make_federated_mesh(clients: int = 1):
    """1-D mesh whose single ``clients`` axis shards the federated
    round's client dimension (see ``core.fedavg.ClientSharding``): each
    of the ``clients`` devices owns K/clients participants of a round.
    On CPU, smoke-test multi-shard rounds with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (must be set
    before jax first initializes)."""
    if clients < 1:
        raise ValueError(f"mesh needs >= 1 client shard, got {clients}")
    avail = jax.device_count()
    if clients > avail:
        raise ValueError(
            f"make_federated_mesh({clients}) needs {clients} devices but "
            f"only {avail} are visible — on CPU, export "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={clients} "
            "before jax initializes"
        )
    return compat_make_mesh((clients,), ("clients",))


# TPU v5e hardware constants for the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
