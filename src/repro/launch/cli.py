"""Shared argparse builders for the federated-plan knobs.

``launch.train`` and ``launch.sweeps`` expose the same plan surface —
engine, aggregation, compression, cohort, corruption, population
scale — and used to copy the flag definitions (and their help text)
between the two parsers, which is exactly how CLIs drift. The builders
here are the single source of those flags:

- ``add_plan_args(parser)``: every FederatedPlan-shaping knob
  (engine/async/latency, aggregation, compression, cohort dynamics,
  adversarial corruption) as argument groups;
- ``add_scale_args(parser)``: population scale (``--population``
  virtual clients, ``--mesh-clients`` client-axis sharding);
- ``add_client_eval_args(parser)``: the per-client evaluation plane's
  panel size and per-client example budget;
- ``plan_kwargs(args)``: the parsed flags as FederatedPlan keyword
  arguments (the config-dataclass fields, never the deprecated flat
  kwargs), for drivers to splice with their own schedule/budget knobs.

``tests/test_cli_shared.py`` snapshots the flag inventory of both
CLIs' ``--help`` against these builders.
"""

from __future__ import annotations

import argparse

from repro.core import (
    AggregatorConfig,
    AsyncConfig,
    CohortConfig,
    CompressionConfig,
    CorruptionConfig,
    LatencyConfig,
    available_aggregators,
    available_corruptions,
)
from repro.core.compression import KINDS

# The flags each builder owns (test_cli_shared snapshots parsers
# against these, so a flag added to a builder without updating the
# inventory — or vice versa — fails fast).
PLAN_FLAGS = (
    "--engine",
    "--buffer-size",
    "--staleness-beta",
    "--latency",
    "--latency-base-s",
    "--latency-spread",
    "--aggregator",
    "--trim-frac",
    "--dp-clip",
    "--dp-sigma",
    "--compression",
    "--topk-frac",
    "--packed-wire",
    "--error-feedback",
    "--participation",
    "--straggler-frac",
    "--straggler-keep",
    "--corrupt-kind",
    "--corrupt-rate",
    "--corrupt-scale",
)
SCALE_FLAGS = ("--population", "--mesh-clients")
CLIENT_EVAL_FLAGS = ("--client-eval", "--client-eval-examples")


def add_plan_args(ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
    """The FederatedPlan-shaping knobs, as argument groups."""
    # round engine: sync barrier vs buffered-async streaming server
    eng = ap.add_argument_group("round engine")
    eng.add_argument("--engine", default="fedavg",
                     choices=["fedavg", "fedsgd", "async"],
                     help="barrier FedAvg/FedSGD or the buffered-async "
                          "(FedBuff-style) streaming server")
    eng.add_argument("--buffer-size", type=int, default=0,
                     help="async: server steps when this many updates are "
                          "buffered (0 = clients-per-round)")
    eng.add_argument("--staleness-beta", type=float, default=0.5,
                     help="async: discount buffered deltas by 1/(1+s)^beta, "
                          "s in server versions since download")
    eng.add_argument("--latency", action="store_true",
                     help="price sync rounds in simulated seconds too "
                          "(async always draws arrival times)")
    eng.add_argument("--latency-base-s", type=float, default=60.0,
                     help="device-tier latency model: base upload seconds")
    eng.add_argument("--latency-spread", type=float, default=0.25,
                     help="device-tier latency model: lognormal jitter std")
    # server aggregation rule + its knobs (AggregatorConfig)
    agg = ap.add_argument_group("aggregation")
    agg.add_argument("--aggregator", default="weighted_mean",
                     choices=available_aggregators())
    agg.add_argument("--trim-frac", type=float, default=0.1,
                     help="trimmed_mean: fraction trimmed per side")
    agg.add_argument("--dp-clip", type=float, default=1.0,
                     help="clipped_mean: per-client L2 clip norm")
    agg.add_argument("--dp-sigma", type=float, default=0.0,
                     help="clipped_mean: DP Gaussian noise multiplier")
    # server-plane: compression / cohort dynamics
    comp = ap.add_argument_group("compression")
    comp.add_argument("--compression", default="none", choices=list(KINDS),
                      help="uplink delta compression (exact wire bytes in "
                           "CFMQ)")
    comp.add_argument("--topk-frac", type=float, default=0.05)
    comp.add_argument("--packed-wire", action="store_true",
                      help="materialize + round-trip the packed uplink "
                           "payload (wire_pack kernels; bit-identical "
                           "numerics)")
    comp.add_argument("--error-feedback", action="store_true",
                      help="EF21 per-client residual accumulation "
                           "(compensates top-k/int4 error across rounds; "
                           "same wire bytes)")
    coh = ap.add_argument_group("cohort dynamics")
    coh.add_argument("--participation", type=float, default=1.0,
                     help="P(sampled client reports back)")
    coh.add_argument("--straggler-frac", type=float, default=0.0)
    coh.add_argument("--straggler-keep", type=float, default=0.5,
                     help="fraction of local steps a straggler completes")
    # adversarial client corruption (see repro.core.corruption)
    cor = ap.add_argument_group("corruption")
    cor.add_argument("--corrupt-kind", default="none",
                     choices=["none", "label_shuffle"] + available_corruptions(),
                     help="adversary: delta corruption (sign_flip/gaussian/"
                          "zero/stale) or the data-plane label_shuffle")
    cor.add_argument("--corrupt-rate", type=float, default=0.0,
                     help="P(participating client is corrupted) per round")
    cor.add_argument("--corrupt-scale", type=float, default=1.0,
                     help="adversary magnitude (sign_flip/gaussian/stale)")
    return ap


def add_scale_args(ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
    """Population-scale knobs: virtual clients + client-axis sharding."""
    pop = ap.add_argument_group("population scale")
    pop.add_argument("--population", type=int, default=0,
                     help="simulate this many VIRTUAL clients over the "
                          "corpus (sampling sees N clients; host memory "
                          "stays O(corpus + K); 0 = plain corpus)")
    pop.add_argument("--mesh-clients", type=int, default=0,
                     help="shard the client axis over this many devices "
                          "(clients mesh axis; CPU smoke via XLA_FLAGS="
                          "--xla_force_host_platform_device_count=N; "
                          "0 = unsharded)")
    return ap


def add_client_eval_args(ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
    """The per-client evaluation plane (repro.core.clienteval)."""
    ce = ap.add_argument_group("per-client evaluation")
    ce.add_argument("--client-eval", type=int, default=0,
                    help="track this many clients' per-round loss/quality "
                         "and emit the fairness spread (0 = off)")
    ce.add_argument("--client-eval-examples", type=int, default=4,
                    help="eval examples per tracked client (the client's "
                         "first n utterances, fixed across rounds)")
    return ap


def plan_overrides(args: argparse.Namespace) -> dict:
    """The subset of ``plan_kwargs`` the user actually moved off its
    default — the sweep driver's grid-wide override surface: each grid
    point keeps its own plan except for the groups the command line
    touched (e.g. ``--grid noniid_fvn --aggregator trimmed_mean`` runs
    the whole frontier under a robust aggregator)."""
    ref = plan_kwargs(add_plan_args(
        argparse.ArgumentParser(add_help=False)).parse_args([]))
    return {k: v for k, v in plan_kwargs(args).items() if v != ref[k]}


def plan_kwargs(args: argparse.Namespace) -> dict:
    """The ``add_plan_args`` flags as FederatedPlan keyword arguments
    (always the config dataclasses — never the deprecated flat agg
    kwargs). Drivers splice these with their own budget/schedule
    fields: ``FederatedPlan(clients_per_round=..., **plan_kwargs(a))``."""
    return dict(
        engine=args.engine,
        asynchrony=AsyncConfig(buffer_size=args.buffer_size,
                               staleness_beta=args.staleness_beta),
        latency=LatencyConfig(enabled=args.latency,
                              base_s=args.latency_base_s,
                              spread=args.latency_spread),
        cohort=CohortConfig(participation=args.participation,
                            straggler_frac=args.straggler_frac,
                            straggler_keep=args.straggler_keep),
        compression=CompressionConfig(kind=args.compression,
                                      topk_frac=args.topk_frac,
                                      packed=args.packed_wire,
                                      error_feedback=args.error_feedback),
        aggregation=AggregatorConfig(name=args.aggregator,
                                     trim_frac=args.trim_frac,
                                     dp_clip=args.dp_clip,
                                     dp_sigma=args.dp_sigma),
        corruption=CorruptionConfig(kind=args.corrupt_kind,
                                    rate=args.corrupt_rate,
                                    scale=args.corrupt_scale),
    )
