"""Roofline report: aggregate dry-run JSONs into the §Roofline table.

Usage:
    PYTHONPATH=src python -m repro.launch.roofline --dir results/dryrun \
        [--format md|csv]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load_records(d: str):
    recs = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_s(x):
    return f"{x:.3e}" if x is not None else "-"


def one_liner(rec) -> str:
    """What would move the dominant term down."""
    if rec.get("status") != "ok":
        return ""
    r = rec["roofline"]
    dom = r["dominant"]
    shape = rec["shape"]
    hints = {
        ("memory_s", "train"): "bf16 intermediates + fewer remat round-trips",
        ("memory_s", "prefill"): "fused (Pallas) attention keeps tiles in VMEM",
        ("memory_s", "decode"): "quantized / windowed KV cache shrinks the stream",
        ("compute_s", "train"): "drop remat recompute (more HBM) or pack MXU tiles",
        ("compute_s", "prefill"): "skip fully-masked window blocks",
        ("compute_s", "decode"): "batch decode steps (speculative/multi-token)",
        ("collective_s", "train"): "overlap delta all-reduce with local compute",
        ("collective_s", "prefill"): "reshard to cut cross-pod gathers",
        ("collective_s", "decode"): "seq-shard cache so merges stay scalar-sized",
    }
    kind = "train" if "train" in shape else ("prefill" if "prefill" in shape else "decode")
    return hints.get((dom, kind), "")


def to_markdown(recs) -> str:
    lines = [
        "| arch | shape | mesh | status | compute (s) | memory (s) | collective (s) | dominant | useful FLOP ratio | per-dev GB | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in recs:
        if rec.get("status") == "ok":
            r = rec["roofline"]
            mem_gb = rec["memory"]["total_bytes"] / 1e9
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | ok "
                f"| {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
                f"| {fmt_s(r['collective_s'])} | {r['dominant'].replace('_s','')} "
                f"| {r['useful_flop_ratio']:.2f} | {mem_gb:.1f} | {one_liner(rec)} |")
        else:
            reason = rec.get("reason", rec.get("error", ""))[:60]
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | {rec.get('mesh','-')} "
                f"| {rec['status']} | - | - | - | - | - | - | {reason} |")
    return "\n".join(lines)


def to_csv(recs) -> str:
    rows = ["arch,shape,mesh,status,compute_s,memory_s,collective_s,dominant,"
            "useful_flop_ratio,flops_per_chip,hbm_bytes,link_bytes,per_dev_bytes"]
    for rec in recs:
        if rec.get("status") == "ok":
            r = rec["roofline"]
            h = rec["hlo_cost"]
            rows.append(
                f"{rec['arch']},{rec['shape']},{rec['mesh']},ok,"
                f"{r['compute_s']:.6e},{r['memory_s']:.6e},{r['collective_s']:.6e},"
                f"{r['dominant']},{r['useful_flop_ratio']:.4f},{h['flops_per_chip']:.4e},"
                f"{h['hbm_bytes_per_chip']:.4e},{h['link_bytes']:.4e},"
                f"{rec['memory']['total_bytes']}")
        else:
            rows.append(f"{rec['arch']},{rec['shape']},{rec.get('mesh','-')},"
                        f"{rec['status']},,,,,,,,,")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--format", default="md", choices=["md", "csv"])
    args = ap.parse_args()
    recs = load_records(args.dir)
    print(to_markdown(recs) if args.format == "md" else to_csv(recs))


if __name__ == "__main__":
    main()
