"""Roofline report: aggregate dry-run JSONs into the §Roofline table,
plus the profiling plane's predicted-vs-measured fed-round report.

Usage:
    PYTHONPATH=src python -m repro.launch.roofline --dir results/dryrun \
        [--format md|csv]
    PYTHONPATH=src python -m repro.launch.roofline --predict [--strict]
    PYTHONPATH=src python -m repro.launch.roofline --drift \
        [--baseline results/predict_baseline.json]

``--predict`` calibrates per-device cost coefficients against the five
tiny-RNN-T acceptance plans (fp32 / int8 / int4_packed / top5 / async),
prints predicted-vs-measured round seconds for BOTH feature sources
(closed-form analytic and HLO-derived), persists the coefficients to
``results/tuning.json`` and the report to ``results/predict_report.json``.
With ``--strict`` the exit code is nonzero when any plan's relative
error exceeds the documented tolerance. ``--drift`` re-measures and
compares against a committed baseline report — warn-only by design
(machine variance is expected); CI runs it with continue-on-error.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def load_records(d: str):
    recs = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_s(x):
    return f"{x:.3e}" if x is not None else "-"


def one_liner(rec) -> str:
    """What would move the dominant term down."""
    if rec.get("status") != "ok":
        return ""
    r = rec["roofline"]
    dom = r["dominant"]
    shape = rec["shape"]
    hints = {
        ("memory_s", "train"): "bf16 intermediates + fewer remat round-trips",
        ("memory_s", "prefill"): "fused (Pallas) attention keeps tiles in VMEM",
        ("memory_s", "decode"): "quantized / windowed KV cache shrinks the stream",
        ("compute_s", "train"): "drop remat recompute (more HBM) or pack MXU tiles",
        ("compute_s", "prefill"): "skip fully-masked window blocks",
        ("compute_s", "decode"): "batch decode steps (speculative/multi-token)",
        ("collective_s", "train"): "overlap delta all-reduce with local compute",
        ("collective_s", "prefill"): "reshard to cut cross-pod gathers",
        ("collective_s", "decode"): "seq-shard cache so merges stay scalar-sized",
    }
    kind = "train" if "train" in shape else ("prefill" if "prefill" in shape else "decode")
    return hints.get((dom, kind), "")


def to_markdown(recs) -> str:
    lines = [
        "| arch | shape | mesh | status | compute (s) | memory (s) | collective (s) | dominant | useful FLOP ratio | per-dev GB | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in recs:
        if rec.get("status") == "ok":
            r = rec["roofline"]
            mem_gb = rec["memory"]["total_bytes"] / 1e9
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | ok "
                f"| {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
                f"| {fmt_s(r['collective_s'])} | {r['dominant'].replace('_s','')} "
                f"| {r['useful_flop_ratio']:.2f} | {mem_gb:.1f} | {one_liner(rec)} |")
        else:
            reason = rec.get("reason", rec.get("error", ""))[:60]
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | {rec.get('mesh','-')} "
                f"| {rec['status']} | - | - | - | - | - | - | {reason} |")
    return "\n".join(lines)


def to_csv(recs) -> str:
    rows = ["arch,shape,mesh,status,compute_s,memory_s,collective_s,dominant,"
            "useful_flop_ratio,flops_per_chip,hbm_bytes,link_bytes,per_dev_bytes"]
    for rec in recs:
        if rec.get("status") == "ok":
            r = rec["roofline"]
            h = rec["hlo_cost"]
            rows.append(
                f"{rec['arch']},{rec['shape']},{rec['mesh']},ok,"
                f"{r['compute_s']:.6e},{r['memory_s']:.6e},{r['collective_s']:.6e},"
                f"{r['dominant']},{r['useful_flop_ratio']:.4f},{h['flops_per_chip']:.4e},"
                f"{h['hbm_bytes_per_chip']:.4e},{h['link_bytes']:.4e},"
                f"{rec['memory']['total_bytes']}")
        else:
            rows.append(f"{rec['arch']},{rec['shape']},{rec.get('mesh','-')},"
                        f"{rec['status']},,,,,,,,,")
    return "\n".join(rows)


# ----------------------------------------------------------------------
# Predicted-vs-measured fed-round report (repro.profile.predict)
# ----------------------------------------------------------------------

def predict_table(report: dict) -> str:
    """The --predict report as a markdown table."""
    lines = [
        "| plan | measured (s) | analytic (s) | err | hlo (s) | err | unparsed |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in report["rows"]:
        lines.append(
            f"| {r['plan']} | {r['measured_s']:.4f} "
            f"| {r['predicted_analytic_s']:.4f} | {r['rel_err_analytic']:.1%} "
            f"| {r['predicted_hlo_s']:.4f} | {r['rel_err_hlo']:.1%} "
            f"| {r['unparsed_ops']:.0f} |")
    m = report["max_rel_err"]
    lines.append(
        f"\nmax rel err: analytic={m['analytic']:.1%} hlo={m['hlo']:.1%} "
        f"(tolerance {report['tolerance']:.0%}) on {report['device_key']}")
    return "\n".join(lines)


def run_predict(reps: int, report_out: str, trace_out: str,
                strict: bool) -> int:
    from repro.profile.predict import predict_report

    report = predict_report(reps=reps, trace_path=trace_out)
    os.makedirs(os.path.dirname(report_out) or ".", exist_ok=True)
    with open(report_out, "w") as f:
        json.dump(report, f, indent=1)
    print(predict_table(report))
    print(f"[roofline] predict report -> {report_out}")
    worst = max(report["max_rel_err"].values())
    if worst > report["tolerance"]:
        print(f"[roofline] WARNING: max rel err {worst:.1%} exceeds "
              f"tolerance {report['tolerance']:.0%}")
        return 1 if strict else 0
    return 0


# Measured round times may drift this factor either way before the
# (warn-only) drift step flags them: CI runners share a device_key but
# not load conditions, so the bar is deliberately loose — it exists to
# catch order-of-magnitude engine regressions, not scheduler noise.
DRIFT_FACTOR = 2.0


def run_drift(baseline_path: str, reps: int, strict: bool) -> int:
    from repro.profile.predict import predict_report

    if not os.path.exists(baseline_path):
        print(f"[roofline] no baseline at {baseline_path}; run --predict "
              "and commit the report to enable drift checks")
        return 0
    with open(baseline_path) as f:
        base = json.load(f)
    fresh = predict_report(reps=reps, persist_coeffs=False)
    if fresh["device_key"] != base.get("device_key"):
        print(f"[roofline] drift skipped: baseline device "
              f"{base.get('device_key')!r} != current {fresh['device_key']!r}")
        return 0
    base_rows = {r["plan"]: r for r in base.get("rows", [])}
    drifted = []
    for r in fresh["rows"]:
        b = base_rows.get(r["plan"])
        if b is None:
            continue
        ratio = r["measured_s"] / max(b["measured_s"], 1e-12)
        marker = ""
        if ratio > DRIFT_FACTOR or ratio < 1.0 / DRIFT_FACTOR:
            drifted.append(r["plan"])
            marker = "  <-- DRIFT"
        print(f"[drift] {r['plan']:>12s}: {b['measured_s']:.4f}s -> "
              f"{r['measured_s']:.4f}s (x{ratio:.2f}){marker}")
    if drifted:
        print(f"[roofline] WARNING: round time drifted >x{DRIFT_FACTOR} "
              f"on {drifted} — refresh results/predict_baseline.json if "
              "the change is intentional")
        return 1 if strict else 0
    print("[roofline] no drift beyond "
          f"x{DRIFT_FACTOR} across {len(fresh['rows'])} plans")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--format", default="md", choices=["md", "csv"])
    ap.add_argument("--predict", action="store_true",
                    help="calibrate + report predicted-vs-measured "
                         "fed-round seconds on the acceptance plans")
    ap.add_argument("--drift", action="store_true",
                    help="re-measure and compare against --baseline "
                         "(warn-only unless --strict)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--report-out", default="results/predict_report.json")
    ap.add_argument("--trace-out", default="results/trace_predict.json")
    ap.add_argument("--baseline", default="results/predict_baseline.json")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero on tolerance/drift violations")
    args = ap.parse_args()
    if args.predict:
        sys.exit(run_predict(args.reps, args.report_out, args.trace_out,
                             args.strict))
    if args.drift:
        sys.exit(run_drift(args.baseline, args.reps, args.strict))
    recs = load_records(args.dir)
    print(to_markdown(recs) if args.format == "md" else to_csv(recs))


if __name__ == "__main__":
    main()
