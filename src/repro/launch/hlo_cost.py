"""HLO-text cost model: FLOPs / HBM bytes / collective bytes with
while-loop trip-count scaling.

XLA's built-in ``compiled.cost_analysis()`` visits each while body
ONCE — for scan-over-layers/time models (everything here) that
under-counts by the trip count, so the roofline would be fiction. This
walks the optimized post-SPMD HLO text instead:

- builds the computation call graph (while/fusion/call/conditional),
- multiplies while bodies by ``backend_config known_trip_count``,
- dot FLOPs = 2 * prod(result dims) * prod(contracting dims),
- ~1 FLOP/element for arithmetic ops (transcendentals included),
- HBM bytes = operands + results of *top-level* ops per computation
  (fusion interiors don't round-trip HBM — XLA's own model),
- collectives recorded per-op with replica-group size and scaled by
  the enclosing trip multiplier; link traffic uses ring factors.

Shapes in post-SPMD HLO are per-device shards, so every number is
per-chip — divide by per-chip peaks for roofline terms.

Robustness: HLO text evolves across XLA releases (dynamic ``<=N``
bounded dims, new narrow dtypes, opcode syntax we have never seen).
Instructions this parser cannot price degrade to a counted
``unparsed_ops`` field on :class:`CostSummary` instead of raising
mid-parse, so the profiling plane's predictor keeps working on newer
jax HLO text — consumers decide how much unparsed mass they tolerate.
"""

from __future__ import annotations

import dataclasses
import math
import re

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1,
    "u4": 1,
    "s8": 1,
    "u8": 1,
    "s16": 2,
    "u16": 2,
    "f16": 2,
    "bf16": 2,
    "s32": 4,
    "u32": 4,
    "f32": 4,
    "s64": 8,
    "u64": 8,
    "f64": 8,
    "c64": 8,
    "c128": 16,
    "f8e4m3fn": 1,
    "f8e5m2": 1,
    "f8e4m3b11fnuz": 1,
    "f8e4m3": 1,
    "f8e5m2fnuz": 1,
    "token": 0,
    "opaque": 0,
}

_NO_BYTES = {
    "parameter",
    "constant",
    "tuple",
    "get-tuple-element",
    "bitcast",
    "after-all",
    "partition-id",
    "replica-id",
}
_NO_FLOPS = _NO_BYTES | {
    "copy",
    "reshape",
    "broadcast",
    "transpose",
    "slice",
    "dynamic-slice",
    "dynamic-update-slice",
    "concatenate",
    "gather",
    "iota",
    "convert",
    "reverse",
    "pad",
    "reduce",
    "while",
    "fusion",
    "call",
    "conditional",
    "custom-call",
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "select",
    "compare",
    "rng-bit-generator",
    "dot",
    "scatter",
    "sort",
    "optimization-barrier",
    "convolution",
    "copy-start",
    "copy-done",
    "send",
    "recv",
    "send-done",
    "recv-done",
    "infeed",
    "outfeed",
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# every bracketed shape token, including dims this parser cannot price
# (dynamic "<=128", "?", ...) — the delta vs _SHAPE_RE is what degrades
# to unparsed_ops instead of raising.
_ANY_SHAPE_RE = re.compile(r"(\w+)\[([^\]]*)\]")
_DIMS_OK_RE = re.compile(r"^[\d,]*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^=]*?\)|\S+)\s+([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")


def shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += _DTYPE_BYTES[dtype] * n
    return total


def shape_elems(type_str: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def shape_unparsed(type_str: str) -> int:
    """Count array tokens in ``type_str`` this parser cannot price:
    non-literal dims (``f32[<=128]``) or dtypes missing from the byte
    table (``u2[64]``). Zero for every shape the cost model fully
    understands."""
    bad = 0
    for dtype, dims in _ANY_SHAPE_RE.findall(type_str):
        if not _DIMS_OK_RE.match(dims):
            bad += 1
        elif dtype not in _DTYPE_BYTES and not dtype.isdigit():
            # pure-digit "tokens" are layout minor-to-major annotations
            # ({1,0:T(8,128)} fragments), not dtypes
            bad += 1
    return bad


def _first_array_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclasses.dataclass
class CostSummary:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0  # raw payload bytes
    link_bytes: float = 0.0  # ring-model link traffic
    unparsed_ops: float = 0.0  # instructions priced best-effort (or not at all)
    collectives: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "CostSummary", mult: float = 1.0):
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.collective_bytes += mult * other.collective_bytes
        self.link_bytes += mult * other.link_bytes
        self.unparsed_ops += mult * other.unparsed_ops
        for k, v in other.collectives.items():
            cur = self.collectives.get(k, {"count": 0.0, "bytes": 0.0, "link_bytes": 0.0})
            # tolerate partially-populated entries (older trace JSON,
            # hand-built summaries): missing keys count as zero
            self.collectives[k] = {
                "count": cur.get("count", 0.0) + mult * v.get("count", 0.0),
                "bytes": cur.get("bytes", 0.0) + mult * v.get("bytes", 0.0),
                "link_bytes": cur.get("link_bytes", 0.0) + mult * v.get("link_bytes", 0.0),
            }


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: dict[str, list[Instr]] = {}
        self._parse(hlo_text)
        self._memo: dict[str, CostSummary] = {}
        self._dus_memo: dict[str, tuple] = {}

    def _parse(self, text: str):
        cur = None
        for line in text.splitlines():
            stripped = re.sub(r"/\*.*?\*/", "", line.rstrip())
            if not stripped:
                continue
            if stripped.endswith("{") and "->" in stripped:
                # "=" before "->" means an instruction, not a header —
                # but ignore "=" inside shape brackets (dynamic "<=N"
                # bounded dims appear in newer XLA signatures)
                head = re.sub(r"\[[^\]]*\]", "", stripped.split("->")[0])
                if "=" not in head:
                    mc = _COMP_RE.match(stripped)
                    if mc:
                        cur = mc.group(1)
                        self.computations[cur] = []
                        continue
            if stripped.startswith("}"):
                cur = None
                continue
            if cur is None:
                continue
            mi = _INSTR_RE.match(stripped)
            if mi:
                self.computations[cur].append(
                    Instr(mi.group(1), mi.group(2), mi.group(3), stripped)
                )

    # ---------------------------------------------------------- helpers

    def _symbols(self, instrs):
        return {i.name: i.type_str for i in instrs}

    def _operands(self, instr: Instr, symbols):
        # operand names are %refs inside the (...) after the opcode
        m = re.search(re.escape(instr.opcode) + r"\((.*)$", instr.line)
        if not m:
            return []
        args = m.group(1)
        names = re.findall(r"%([\w.\-]+)", args.split("), ")[0] if ")," in args else args)
        return [symbols[n] for n in names if n in symbols]

    def _dot_flops(self, instr: Instr, symbols) -> float:
        result_elems = shape_elems(instr.type_str)
        ops = self._operands(instr, symbols)
        if not ops:
            return 0.0
        lhs_dims = _first_array_dims(ops[0])
        mdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.line)
        contract = 1
        if mdims and mdims.group(1):
            for d in mdims.group(1).split(","):
                contract *= lhs_dims[int(d)] if int(d) < len(lhs_dims) else 1
        return 2.0 * result_elems * contract

    def _conv_flops(self, instr: Instr, symbols) -> float:
        # flops = 2 * out_elems * (in_channels/feature_group * prod(kernel spatial))
        ops = self._operands(instr, symbols)
        if len(ops) < 2:
            return 0.0
        rhs = _first_array_dims(ops[1])
        out_elems = shape_elems(instr.type_str)
        k = math.prod(rhs[:-1]) if rhs else 1  # rough: kernel elems / out_features
        return 2.0 * out_elems * k

    def _trip_count(self, instr: Instr) -> float:
        m = re.search(r"known_trip_count[^\d]*(\d+)", instr.line)
        if m:
            return float(m.group(1))
        return 1.0

    def _called(self, instr: Instr, attr: str):
        m = re.search(attr + r"=%?([\w.\-]+)", instr.line)
        return m.group(1) if m else None

    def _branches(self, instr: Instr):
        m = re.search(r"branch_computations=\{([^}]*)\}", instr.line)
        if m:
            return re.findall(r"%?([\w.\-]+)", m.group(1))
        out = []
        for attr in ("true_computation", "false_computation"):
            c = self._called(instr, attr)
            if c:
                out.append(c)
        return out

    def _dus_signature(self, comp_name: str):
        """For a fusion computation: byte sizes of buffers updated
        in place by interior dynamic-update-slices (counted with
        multiplicity: {full_buffer_bytes: count}) and the total bytes
        of their slice updates."""
        if comp_name in self._dus_memo:
            return self._dus_memo[comp_name]
        bufs: dict[int, int] = {}
        upd_total = 0
        instrs = self.computations.get(comp_name, [])
        symbols = self._symbols(instrs)
        for ins in instrs:
            if ins.opcode == "dynamic-update-slice":
                ops_ = self._operands(ins, symbols)
                if ops_:
                    b = shape_bytes(ops_[0])
                    bufs[b] = bufs.get(b, 0) + 1
                if len(ops_) > 1:
                    upd_total += shape_bytes(ops_[1])
        # also count the fusion result matching each updated buffer
        bufs = {k: v * 2 for k, v in bufs.items()}  # operand + result slot
        self._dus_memo[comp_name] = (bufs, upd_total)
        return bufs, upd_total

    def _group_size(self, instr: Instr) -> int:
        # replica_groups=[8,64]<=[512] -> groups of 64 / {{0,1},...}
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]", instr.line)
        if m:
            return int(m.group(2))
        m = re.search(r"replica_groups=\{\{([\d,]+)\}", instr.line)
        if m:
            return len(m.group(1).split(","))
        return 2

    def _collective_traffic(self, instr: Instr, symbols):
        """(payload_bytes, link_bytes) per-device ring estimates."""
        out_b = shape_bytes(instr.type_str)
        ops = self._operands(instr, symbols)
        in_b = sum(shape_bytes(o) for o in ops) if ops else out_b
        n = max(self._group_size(instr), 2)
        ring = (n - 1) / n
        if instr.opcode == "all-reduce":
            return out_b, 2.0 * ring * out_b
        if instr.opcode == "all-gather":
            return out_b, ring * out_b
        if instr.opcode == "reduce-scatter":
            return in_b, ring * in_b
        if instr.opcode == "all-to-all":
            return out_b, ring * out_b
        return out_b, float(out_b)  # collective-permute

    # ---------------------------------------------------------- cost

    def _accumulate(self, ins: Instr, symbols: dict, total: CostSummary) -> None:
        """Price one instruction into ``total``. May raise on HLO text
        this parser has never seen — cost() catches and counts it."""
        op = ins.opcode
        if op == "while":
            trips = self._trip_count(ins)
            body = self._called(ins, "body")
            cond = self._called(ins, "condition")
            if body:
                total.add(self.cost(body), trips)
            if cond:
                total.add(self.cost(cond), trips)
            return
        if op == "fusion":
            called = self._called(ins, "calls")
            dus_bufs, dus_updates = {}, 0
            if called:
                sub = self.cost(called)
                total.flops += sub.flops  # interior flops only
                total.unparsed_ops += sub.unparsed_ops
                dus_bufs, dus_updates = self._dus_signature(called)
            # HBM traffic: operands + result of the fusion itself —
            # EXCEPT buffers updated in place by an interior
            # dynamic-update-slice: those cost the slice, not the
            # full buffer (scan carries would otherwise be charged
            # thousands of times their real traffic).
            io = [shape_bytes(ins.type_str)]
            io += [shape_bytes(o) for o in self._operands(ins, symbols)]
            remaining = dict(dus_bufs)
            for b in io:
                if remaining.get(b, 0) > 0:
                    remaining[b] -= 1
                else:
                    total.bytes += b
            total.bytes += 2 * dus_updates  # slice read-modify-write
            return
        if op == "dynamic-update-slice":
            ops_ = self._operands(ins, symbols)
            upd = shape_bytes(ops_[1]) if len(ops_) > 1 else 0
            total.bytes += 2 * upd
            return
        if op == "call":
            called = self._called(ins, "to_apply")
            if called:
                total.add(self.cost(called))
            return
        if op == "conditional":
            branches = [self.cost(b) for b in self._branches(ins)]
            if branches:
                worst = max(branches, key=lambda c: c.flops + c.bytes)
                total.add(worst)
            return
        base_op = op[:-6] if op.endswith("-start") else op
        if op.endswith("-done") and base_op[:-5] in COLLECTIVES:
            return
        if base_op in COLLECTIVES:
            payload, link = self._collective_traffic(ins, symbols)
            total.collective_bytes += payload
            total.link_bytes += link
            key = base_op
            cur = total.collectives.get(key, {"count": 0.0, "bytes": 0.0, "link_bytes": 0.0})
            total.collectives[key] = {
                "count": cur["count"] + 1,
                "bytes": cur["bytes"] + payload,
                "link_bytes": cur["link_bytes"] + link,
            }
            total.bytes += shape_bytes(ins.type_str)
            return
        # plain op
        if op not in _NO_BYTES:
            total.bytes += shape_bytes(ins.type_str)
            total.bytes += sum(shape_bytes(o) for o in self._operands(ins, symbols))
        if op == "dot":
            total.flops += self._dot_flops(ins, symbols)
        elif op == "convolution":
            total.flops += self._conv_flops(ins, symbols)
        elif op in ("reduce", "scatter", "select"):
            total.flops += shape_elems(ins.type_str)
        elif op not in _NO_FLOPS:
            total.flops += shape_elems(ins.type_str)

    def cost(self, comp_name: str) -> CostSummary:
        if comp_name in self._memo:
            return self._memo[comp_name]
        total = CostSummary()
        instrs = self.computations.get(comp_name, [])
        symbols = self._symbols(instrs)
        for ins in instrs:
            try:
                self._accumulate(ins, symbols, total)
                if shape_unparsed(ins.type_str):
                    # priced best-effort: the parsable fraction of the
                    # result shape is in the totals, the rest is not
                    total.unparsed_ops += 1.0
            except Exception:
                total.unparsed_ops += 1.0
        self._memo[comp_name] = total
        return total

    def entry_cost(self) -> CostSummary:
        # entry computation = the one not called by anyone; parse order:
        # ENTRY is usually last, and _COMP_RE tagged it; find by name "main"
        # or fall back to the computation with max cost reachability.
        names = list(self.computations)
        if not names:
            return CostSummary()
        called = set()
        for comp, instrs in self.computations.items():
            for ins in instrs:
                for attr in ("body", "condition", "calls", "to_apply"):
                    c = self._called(ins, attr)
                    if c:
                        called.add(c)
                for b in self._branches(ins):
                    called.add(b)
        roots = [n for n in names if n not in called]
        if not roots:
            roots = names[-1:]
        best = None
        for r in roots:
            c = self.cost(r)
            if best is None or c.flops > best[1].flops:
                best = (r, c)
        return best[1]


def analyze(hlo_text: str) -> dict:
    model = HloCostModel(hlo_text)
    c = model.entry_cost()
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collective_bytes": c.collective_bytes,
        "link_bytes": c.link_bytes,
        "unparsed_ops": c.unparsed_ops,
        "collectives": c.collectives,
    }
